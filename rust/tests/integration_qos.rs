//! Integration: the `repro bench qos` exhibit end to end — the ISSUE 5
//! acceptance criterion (shaping strictly lowers p99 exchange-phase
//! slowdown), byte-determinism of the JSON for a fixed seed, the schema
//! contract, and the committed-artifact pin.

use deeper::bench::{qos_points, qos_report, QosBenchConfig};
use deeper::util::json::{self, Json};

fn small_cfg() -> QosBenchConfig {
    QosBenchConfig { iterations: 40, seed: 1, ..QosBenchConfig::default() }
}

#[test]
fn acceptance_shaping_strictly_lowers_p99_exchange_slowdown() {
    // The ISSUE 5 acceptance scenario: a latency-sensitive job's
    // exchange phases while a neighbor flushes checkpoints over the
    // oversubscribed fabric.  Shaped (CkptFlush ceiling + Exchange
    // floor/weight) must have strictly lower p99 slowdown than unshaped.
    let r = qos_points(&small_cfg());
    assert_eq!(r.isolated_s.len(), 40);
    assert_eq!(r.unshaped.slowdown.len(), 40);
    assert_eq!(r.shaped.slowdown.len(), 40);
    // Contention is real: the unshaped run is visibly slowed down.
    assert!(
        r.unshaped.p99_slowdown() > 2.0,
        "neighbor flush must actually contend: p99={}",
        r.unshaped.p99_slowdown()
    );
    assert!(
        r.shaped.p99_slowdown() < r.unshaped.p99_slowdown(),
        "shaping must strictly lower p99 slowdown: shaped {} !< unshaped {}",
        r.shaped.p99_slowdown(),
        r.unshaped.p99_slowdown()
    );
    // The neighbor kept flushing in both contended runs.
    assert!(r.unshaped.flushes_issued > 0 && r.shaped.flushes_issued > 0);
    // Slowdowns are ratios vs isolated: never meaningfully below 1.
    for run in [&r.unshaped, &r.shaped] {
        for &s in &run.slowdown {
            assert!(s > 0.99, "{}: slowdown {s} below 1", run.mode);
        }
    }
}

#[test]
fn qos_json_is_byte_deterministic_and_seed_sensitive() {
    let (_, a) = qos_report(&small_cfg());
    let (_, b) = qos_report(&small_cfg());
    assert_eq!(
        a.to_pretty_string(),
        b.to_pretty_string(),
        "same seed must produce byte-identical qos JSON"
    );
    let (_, c) = qos_report(&QosBenchConfig { seed: 2, ..small_cfg() });
    assert_ne!(
        a.to_pretty_string(),
        c.to_pretty_string(),
        "a different seed must change the trajectory"
    );
}

#[test]
fn qos_report_exhibits_and_schema() {
    let (exhibits, json) = qos_report(&small_cfg());
    assert_eq!(exhibits.len(), 3, "slowdown figure, summary table, class-latency table");
    for e in &exhibits {
        assert!(!e.render().is_empty());
        assert!(!e.render_csv().is_empty());
    }
    let parsed = json::parse(&json.to_pretty_string()).expect("qos JSON parses");
    assert_eq!(parsed, json);
    assert_eq!(json.get("bench").and_then(Json::as_str), Some("qos"));
    assert_eq!(json.get("schema_version").and_then(Json::as_f64), Some(1.0));
    assert_eq!(json.get("seed").and_then(Json::as_f64), Some(1.0));
    assert_eq!(json.get("iterations").and_then(Json::as_f64), Some(40.0));
    assert!(json.get("scenario").is_some());
    assert!(json.get("shaping").is_some());
    assert!(json
        .get("isolated_exchange_s")
        .and_then(|d| d.get("p99"))
        .and_then(Json::as_f64)
        .unwrap()
        > 0.0);
    let runs = json.get("runs").and_then(Json::as_arr).expect("runs array");
    assert_eq!(runs.len(), 2);
    for (run, mode) in runs.iter().zip(["unshaped", "shaped"]) {
        assert_eq!(run.get("mode").and_then(Json::as_str), Some(mode));
        assert!(run.get("flushes_issued").and_then(Json::as_f64).unwrap() > 0.0);
        for key in ["p50", "p95", "p99", "mean", "max"] {
            assert!(
                run.get("slowdown").and_then(|d| d.get(key)).and_then(Json::as_f64).unwrap()
                    > 0.0
            );
        }
        // The per-class latency summary names at least the two classes
        // the scenario is made of.
        let classes = run.get("class_latency_s").expect("class latency object");
        for c in ["exchange", "ckpt-flush"] {
            let entry = classes.get(c).unwrap_or_else(|| panic!("class {c} missing"));
            assert!(entry.get("n").and_then(Json::as_f64).unwrap() > 0.0);
            assert!(entry.get("p99_s").and_then(Json::as_f64).unwrap() > 0.0);
        }
    }
    let up = json.get("p99_slowdown_unshaped").and_then(Json::as_f64).unwrap();
    let sp = json.get("p99_slowdown_shaped").and_then(Json::as_f64).unwrap();
    let imp = json.get("p99_improvement").and_then(Json::as_f64).unwrap();
    assert!(sp < up, "headline must mirror the acceptance criterion");
    assert!((imp - up / sp).abs() < 1e-9);
}

#[test]
fn committed_qos_artifact_parses() {
    // BENCH_qos.json at the repo root is the cross-PR trajectory record;
    // whatever regenerates it (make bench-qos / the CI bench-smoke job)
    // must keep it parseable with the pinned schema.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_qos.json");
    let text = std::fs::read_to_string(path).expect("BENCH_qos.json exists");
    let doc = json::parse(&text).expect("artifact parses");
    assert_eq!(doc.get("bench").and_then(Json::as_str), Some("qos"));
    assert_eq!(doc.get("schema_version").and_then(Json::as_f64), Some(1.0));
    assert!(doc.get("runs").and_then(Json::as_arr).is_some());
}

//! Smoke tests pinned by ISSUE 1: RNG determinism across runs, and a
//! checkpoint/restart round-trip through every SCR strategy (both flat and
//! via the multi-level composition) without losing the ability to recover.

use deeper::scr::multilevel::{MultiLevelConfig, MultiLevelScr};
use deeper::scr::{Scr, Strategy};
use deeper::sim::rng::SplitMix64;
use deeper::system::{presets, Machine, NodeKind};

/// Two generators with the same seed must produce bit-identical streams of
/// every draw kind the simulation uses (u64, f64, bounded int, exp).
#[test]
fn smoke_rng_deterministic_across_two_runs() {
    let run = |seed: u64| -> Vec<u64> {
        let mut rng = SplitMix64::new(seed);
        let mut child = rng.split(3);
        let mut out = Vec::with_capacity(4 * 64);
        for _ in 0..64 {
            out.push(rng.next_u64());
            out.push(rng.next_f64().to_bits());
            out.push(rng.next_below(1 << 20));
            out.push(child.next_exp(5.0).to_bits());
        }
        out
    };
    assert_eq!(run(0xDEE9E5), run(0xDEE9E5));
    assert_ne!(run(1), run(2), "different seeds must differ");
}

/// Every strategy must round-trip: checkpoint, then restart from it.
/// Transient errors are recoverable by all five; node loss by all except
/// Single (which only keeps node-local data — the paper's own caveat).
#[test]
fn smoke_every_strategy_checkpoint_restart_roundtrip() {
    for strat in Strategy::ALL {
        let mut m = Machine::build(presets::deep_er());
        let nodes = m.nodes_of(NodeKind::Cluster);
        let mut scr = Scr::new(strat);
        let rep = scr.checkpoint(&mut m, &nodes, 5e8).unwrap();
        assert!(rep.blocked > 0.0, "{strat:?}: checkpoint must cost time");
        assert_eq!(scr.database().len(), 1, "{strat:?}");

        // Transient (process) error: read the checkpoint back.
        let r = scr.restart(&mut m, &nodes, None).unwrap();
        assert!(!r.rebuilt && r.time > 0.0, "{strat:?}");

        // Node loss: recover if and only if the strategy claims to.
        m.kill_node(nodes[1]);
        m.revive_node(nodes[1]);
        let r = scr.restart(&mut m, &nodes, Some(nodes[1]));
        if strat.survives_node_loss() {
            let r = r.unwrap_or_else(|e| panic!("{strat:?} lost data: {e}"));
            assert!(r.rebuilt && r.time > 0.0, "{strat:?}");
        } else {
            assert!(r.is_err(), "{strat:?} must refuse node-loss restart");
        }
    }
}

/// The multi-level composition must round-trip through each L2 strategy
/// that survives node loss (Partner, Buddy, DistXor, NamXor): after a mix
/// of L1/L2 checkpoints, both a transient restart (L1) and a node-loss
/// restart (L2) must succeed.
#[test]
fn smoke_multilevel_roundtrip_each_l2_strategy() {
    for l2 in [Strategy::Partner, Strategy::Buddy, Strategy::DistXor, Strategy::NamXor] {
        let mut m = Machine::build(presets::deep_er());
        let nodes = m.nodes_of(NodeKind::Cluster);
        let cfg = MultiLevelConfig {
            l1_every: 1,
            l2_every: 2,
            l3_every: 2,
            l2_strategy: l2,
            ..MultiLevelConfig::default()
        };
        let mut ml = MultiLevelScr::new(cfg);
        for iter in 1..=4 {
            ml.checkpoint_at(&mut m, &nodes, 5e8, iter).unwrap();
        }
        assert_eq!(ml.stats.l1_count, 4, "{l2:?}");
        assert_eq!(ml.stats.l2_count, 2, "{l2:?}");

        let t1 = ml.restart(&mut m, &nodes, None).unwrap();
        assert!(t1 > 0.0, "{l2:?}: transient restart");

        m.kill_node(nodes[2]);
        m.revive_node(nodes[2]);
        let t2 = ml
            .restart(&mut m, &nodes, Some(nodes[2]))
            .unwrap_or_else(|e| panic!("{l2:?} node-loss restart failed: {e}"));
        assert!(t2 > 0.0, "{l2:?}: node-loss restart");
        ml.drain(&mut m);
    }
}

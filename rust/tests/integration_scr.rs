//! Integration: full checkpoint/restart cycles under failure injection,
//! across every strategy and failure kind.

use deeper::apps::{run_iterations, xpic, IterationJob};
use deeper::scr::{Scr, Strategy};
use deeper::system::failure::FailurePlan;
use deeper::system::{presets, Machine, NodeKind};

fn machine() -> Machine {
    Machine::build(presets::deep_er())
}

#[test]
fn every_strategy_full_cycle_with_node_loss() {
    for strat in Strategy::ALL {
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let mut scr = Scr::new(strat);
        scr.checkpoint(&mut m, &nodes, 1e9).unwrap();
        m.kill_node(nodes[2]);
        m.revive_node(nodes[2]);
        let r = scr.restart(&mut m, &nodes, Some(nodes[2]));
        if strat.survives_node_loss() {
            let r = r.unwrap_or_else(|e| panic!("{strat:?} restart failed: {e}"));
            assert!(r.rebuilt && r.time > 0.0, "{strat:?}");
        } else {
            assert!(r.is_err(), "{strat:?} must not survive node loss");
        }
    }
}

#[test]
fn every_strategy_transient_restart() {
    for strat in Strategy::ALL {
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let mut scr = Scr::new(strat);
        scr.checkpoint(&mut m, &nodes, 1e9).unwrap();
        let r = scr.restart(&mut m, &nodes, None).unwrap();
        assert!(!r.rebuilt && r.time > 0.0, "{strat:?}");
    }
}

#[test]
fn checkpoint_bandwidth_ordering_matches_fig4() {
    let bytes = 2e9;
    let mut results = Vec::new();
    for strat in Strategy::ALL {
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let mut scr = Scr::new(strat);
        let r = scr.checkpoint(&mut m, &nodes, bytes).unwrap();
        results.push((strat, r.blocked));
    }
    let t = |s: Strategy| results.iter().find(|(x, _)| *x == s).unwrap().1;
    assert!(t(Strategy::Buddy) < t(Strategy::Partner));
    assert!(t(Strategy::NamXor) < t(Strategy::DistXor));
    assert!(t(Strategy::Single) <= t(Strategy::NamXor) + 1e-9);
}

#[test]
fn repeated_checkpoints_grow_database_and_recycle_nam() {
    let mut m = machine();
    let nodes = m.nodes_of(NodeKind::Cluster);
    let mut scr = Scr::new(Strategy::NamXor);
    // Table II: xPic on DEEP-ER wrote 11 checkpoints.
    for i in 0..11 {
        scr.checkpoint(&mut m, &nodes, 2e9).unwrap();
        assert_eq!(scr.database().len(), i + 1);
    }
    // HMC still within capacity: only one parity window alive per board.
    for nam in &m.nams {
        assert!(nam.hmc.used() <= nam.hmc.params.capacity + 1.0);
    }
}

#[test]
fn multiple_failures_multiple_rollbacks() {
    let mut m = machine();
    let nodes: Vec<usize> = (0..8).collect();
    let mut job = IterationJob {
        profile: xpic::profile_nam(),
        iterations: 40,
        cp_interval: 5,
        failures: FailurePlan {
            at_iterations: vec![
                deeper::system::failure::Failure { node: 1, at: 12.0 },
                deeper::system::failure::Failure { node: 5, at: 27.0 },
            ],
            at_times: Vec::new(),
        },
    };
    job.profile.ckpt_bytes_per_node = 1e9;
    let mut scr = Scr::new(Strategy::Buddy);
    let stats = run_iterations(&mut m, &nodes, &job, Some(&mut scr));
    assert_eq!(stats.failures_hit, 2);
    // 12 + (12-10 rollback) + 15 + (27-25 rollback) + 13 = 44.
    assert_eq!(stats.iterations_run, 44);
    assert!(stats.restart_time > 0.0);
}

#[test]
fn failure_before_first_checkpoint_restarts_from_zero() {
    let mut m = machine();
    let nodes: Vec<usize> = (0..4).collect();
    let job = IterationJob {
        profile: xpic::profile_nam(),
        iterations: 12,
        cp_interval: 10,
        failures: FailurePlan::one_at_iteration(0, 5),
    };
    let mut scr = Scr::new(Strategy::Buddy);
    let stats = run_iterations(&mut m, &nodes, &job, Some(&mut scr));
    // No checkpoint yet at iteration 5 -> full restart: 5 lost + 12 = 17.
    assert_eq!(stats.iterations_run, 17);
}

#[test]
fn storage_accounting_respects_strategy_factor() {
    // Partner stores 2x, DistXor 1 + 1/(k-1), NamXor 1x on nodes.
    for (strat, factor) in [
        (Strategy::Single, 1.0),
        (Strategy::Partner, 2.0),
        (Strategy::Buddy, 2.0),
        (Strategy::NamXor, 1.0),
    ] {
        assert_eq!(strat.storage_factor(4), factor, "{strat:?}");
    }
    assert!((Strategy::DistXor.storage_factor(4) - (1.0 + 1.0 / 3.0)).abs() < 1e-12);
}

#[test]
fn xor_group_boundaries_rebuild_correct_group() {
    // 16 nodes, group 4: failure in the last group must not touch the
    // first group's read pattern (smoke: rebuild still succeeds).
    let mut m = machine();
    let nodes = m.nodes_of(NodeKind::Cluster);
    let mut scr = Scr::new(Strategy::DistXor).with_group(4);
    scr.checkpoint(&mut m, &nodes, 1e9).unwrap();
    let victim = nodes[14]; // in the 4th group
    m.kill_node(victim);
    m.revive_node(victim);
    let r = scr.restart(&mut m, &nodes, Some(victim)).unwrap();
    assert!(r.rebuilt);
}

#[test]
fn measured_optimal_interval_matches_young_prediction() {
    // Capstone consistency check: sweep the checkpoint interval under an
    // exponential-MTBF failure schedule and verify the waste-minimizing
    // interval sits near the Young optimum sqrt(2 C M) — i.e. the DES,
    // the SCR cost model and the analytic formula agree with each other.
    use deeper::scr::multilevel::optimal_interval;

    let profile = xpic::profile_nam(); // 2 GB CP, ~22.5 s iterations
    let nodes: Vec<usize> = (0..16).collect();
    let iter_time = profile.flops_per_iter_per_node / (1e12 * profile.cpu_efficiency);

    // Measure the checkpoint cost once.
    let ckpt_cost = {
        let mut m = Machine::build(presets::deep_er());
        let mut scr = Scr::new(Strategy::Buddy);
        scr.checkpoint(&mut m, &nodes, profile.ckpt_bytes_per_node)
            .unwrap()
            .blocked
    };
    let mtbf_system = 2500.0; // seconds
    let tau = optimal_interval(ckpt_cost, mtbf_system);
    let predicted_iters = (tau / iter_time).round() as usize;

    let run = |cp_interval: usize| -> f64 {
        let mut m = Machine::build(presets::deep_er());
        let job = IterationJob {
            profile: profile.clone(),
            iterations: 150,
            cp_interval,
            failures: FailurePlan::exponential(nodes.len(), mtbf_system * 16.0, 1e6, 99),
        };
        let mut scr = Scr::new(Strategy::Buddy);
        run_iterations(&mut m, &nodes, &job, Some(&mut scr)).total_time
    };

    let candidates = [1usize, 2, 5, 10, 25, 60];
    let times: Vec<f64> = candidates.iter().map(|&c| run(c)).collect();
    let best = candidates[times
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0];
    // The empirical optimum must be within a factor ~3 of Young's, and
    // both extremes must be worse than the optimum region.
    assert!(
        best as f64 >= predicted_iters as f64 / 3.0
            && best as f64 <= predicted_iters as f64 * 3.0,
        "best={best} predicted={predicted_iters} (tau={tau:.0}s, C={ckpt_cost:.1}s)"
    );
    let t_best = times.iter().copied().fold(f64::INFINITY, f64::min);
    assert!(times[0] > t_best, "interval=1 should overpay in CP time");
    assert!(times[candidates.len() - 1] > t_best, "interval=60 should overpay in rework");
}

#[test]
fn namxor_restart_faster_than_distxor_restart() {
    let bytes = 2e9;
    let run = |strat: Strategy| {
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let mut scr = Scr::new(strat);
        scr.checkpoint(&mut m, &nodes, bytes).unwrap();
        m.kill_node(nodes[1]);
        m.revive_node(nodes[1]);
        scr.restart(&mut m, &nodes, Some(nodes[1])).unwrap().time
    };
    let dist = run(Strategy::DistXor);
    let nam = run(Strategy::NamXor);
    // NAM rebuild skips the survivors' NVMe re-read.
    assert!(nam < dist, "nam {nam} !< dist {dist}");
}

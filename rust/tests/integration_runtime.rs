//! Integration: the PJRT runtime executes the real AOT artifacts and the
//! numerics agree with physics-level invariants (the Python-side pytest
//! suite pins kernels against their jnp oracles; these tests pin the
//! rust-side marshalling + execution path).
//!
//! SKIP CONDITIONS (every test below self-skips, equivalent to
//! `#[ignore]`, rather than being deleted):
//!  * the AOT inputs `artifacts/manifest.json` + `artifacts/*.hlo.txt`
//!    (`nbody_step`, `nbody_energy`, `xpic_step`, `fwi_step`,
//!    `fwi_forward8`, `gershwin_step`, `nam_parity`) are produced by
//!    `make artifacts` (python/compile/aot.py) and are not checked in;
//!  * this offline workspace links the vendored `vendor/xla` stub, whose
//!    `PjRtClient::cpu()` reports "unavailable", so `Runtime::open` fails
//!    even when the artifacts exist.
//! With a real xla-rs dependency and `make artifacts` run, all tests
//! execute in full.

use deeper::runtime::{Runtime, Tensor};

fn open_runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Runtime::open(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping PJRT tests (missing artifacts/ or stub xla backend): {e}");
            None
        }
    }
}

fn lcg(seed: &mut u64) -> f32 {
    *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    ((*seed >> 33) as f32 / (1u64 << 31) as f32) - 0.5
}

#[test]
fn manifest_covers_all_expected_artifacts() {
    let Some(rt) = open_runtime() else { return };
    let names = rt.artifact_names();
    for expected in [
        "nbody_step",
        "nbody_energy",
        "xpic_step",
        "fwi_step",
        "fwi_forward8",
        "gershwin_step",
        "nam_parity",
    ] {
        assert!(names.iter().any(|n| n == expected), "missing {expected}");
    }
}

#[test]
fn nam_parity_matches_host_xor() {
    let Some(mut rt) = open_runtime() else { return };
    let spec = rt.spec("nam_parity").unwrap().clone();
    let (n, m) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let mut seed = 7u64;
    let data: Vec<i32> = (0..n * m).map(|_| (lcg(&mut seed) * 1e6) as i32).collect();
    let out = rt
        .execute("nam_parity", &[Tensor::I32 { shape: vec![n, m], data: data.clone() }])
        .unwrap();
    let got = out[0].as_i32().unwrap();
    // Host-side XOR fold is the oracle.
    for j in 0..m {
        let mut want = 0i32;
        for i in 0..n {
            want ^= data[i * m + j];
        }
        assert_eq!(got[j], want, "parity word {j}");
    }
}

#[test]
fn xpic_step_conserves_charge_and_stays_in_box() {
    let Some(mut rt) = open_runtime() else { return };
    let spec = rt.spec("xpic_step").unwrap().clone();
    let p = spec.inputs[0].shape[0];
    let g3 = spec.inputs[2].shape[0];
    let mut seed = 3u64;
    let x: Vec<f32> = (0..p * 3).map(|_| lcg(&mut seed) * 0.5 + 0.5).collect();
    let v: Vec<f32> = (0..p * 3).map(|_| lcg(&mut seed) * 0.02).collect();
    let e: Vec<f32> = (0..g3 * 3).map(|_| lcg(&mut seed) * 0.1).collect();
    let b: Vec<f32> = vec![0.0; g3 * 3];
    let out = rt
        .execute(
            "xpic_step",
            &[
                Tensor::F32 { shape: vec![p, 3], data: x },
                Tensor::F32 { shape: vec![p, 3], data: v },
                Tensor::F32 { shape: vec![g3, 3], data: e },
                Tensor::F32 { shape: vec![g3, 3], data: b },
            ],
        )
        .unwrap();
    let x_new = out[0].as_f32().unwrap();
    assert!(x_new.iter().all(|&a| (0.0..1.0).contains(&a)), "periodic box violated");
    let rho = out[3].as_f32().unwrap();
    let total: f32 = rho.iter().sum();
    assert!((total - p as f32).abs() < 1.0, "charge {total} != {p}");
}

#[test]
fn fwi_forward8_equals_eight_single_steps() {
    let Some(mut rt) = open_runtime() else { return };
    let spec = rt.spec("fwi_step").unwrap().clone();
    let (h, w) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let mut seed = 11u64;
    let mut p: Vec<f32> = (0..h * w).map(|_| lcg(&mut seed) * 0.1).collect();
    // Zero the Dirichlet ring.
    for i in 0..h {
        p[i * w] = 0.0;
        p[i * w + w - 1] = 0.0;
    }
    for j in 0..w {
        p[j] = 0.0;
        p[(h - 1) * w + j] = 0.0;
    }
    let p_prev = p.clone();
    let c2 = vec![1.0f32; h * w];
    let mk = |d: &Vec<f32>| Tensor::F32 { shape: vec![h, w], data: d.clone() };

    // Path A: fwi_forward8 once.
    let fwd = rt
        .execute("fwi_forward8", &[mk(&p), mk(&p_prev), mk(&c2)])
        .unwrap();
    // Path B: fwi_step eight times.
    let (mut a, mut b) = (p.clone(), p_prev.clone());
    for _ in 0..8 {
        let out = rt.execute("fwi_step", &[mk(&a), mk(&b), mk(&c2)]).unwrap();
        b = out[1].as_f32().unwrap().to_vec();
        a = out[0].as_f32().unwrap().to_vec();
    }
    let fa = fwd[0].as_f32().unwrap();
    for (i, (x, y)) in fa.iter().zip(&a).enumerate() {
        assert!((x - y).abs() < 1e-4, "mismatch at {i}: {x} vs {y}");
    }
}

#[test]
fn nbody_energy_is_finite_and_negative_for_bound_cloud() {
    let Some(mut rt) = open_runtime() else { return };
    let spec = rt.spec("nbody_energy").unwrap().clone();
    let n = spec.inputs[0].shape[0];
    let mut seed = 5u64;
    let pos: Vec<f32> = (0..n * 3).map(|_| lcg(&mut seed) * 0.1).collect(); // tight cloud
    let vel: Vec<f32> = vec![0.0; n * 3];
    let mass: Vec<f32> = vec![1.0 / n as f32; n];
    let out = rt
        .execute(
            "nbody_energy",
            &[
                Tensor::F32 { shape: vec![n, 3], data: pos },
                Tensor::F32 { shape: vec![n, 3], data: vel },
                Tensor::F32 { shape: vec![n], data: mass },
            ],
        )
        .unwrap();
    let e = out[0].as_f32().unwrap()[0];
    assert!(e.is_finite());
    assert!(e < 0.0, "cold tight cloud must be bound, got {e}");
}

#[test]
fn execute_rejects_shape_and_dtype_mismatches() {
    let Some(mut rt) = open_runtime() else { return };
    let spec = rt.spec("nam_parity").unwrap().clone();
    let (n, m) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    // Wrong shape.
    let bad = Tensor::I32 { shape: vec![n, m / 2], data: vec![0; n * m / 2] };
    assert!(rt.execute("nam_parity", &[bad]).is_err());
    // Wrong dtype.
    let bad = Tensor::F32 { shape: vec![n, m], data: vec![0.0; n * m] };
    assert!(rt.execute("nam_parity", &[bad]).is_err());
    // Wrong arity.
    assert!(rt.execute("nam_parity", &[]).is_err());
    // Unknown artifact.
    assert!(rt.execute("not_a_kernel", &[]).is_err());
}

#[test]
fn compilation_is_cached() {
    let Some(mut rt) = open_runtime() else { return };
    assert_eq!(rt.compiled_count(), 0);
    rt.compile("fwi_step").unwrap();
    assert_eq!(rt.compiled_count(), 1);
    rt.compile("fwi_step").unwrap();
    assert_eq!(rt.compiled_count(), 1);
}

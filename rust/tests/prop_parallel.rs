//! Thread-count equivalence sweep for the component-parallel DES engine
//! (DESIGN.md section 14).
//!
//! Every workload below runs at each thread count in
//! [`deeper::testing::THREAD_SWEEP`] ({1, 2, 4, 8}).  Completion times
//! and `op_trace` rates must match threads=1 *exactly* — the partitioned
//! engine performs the identical per-component arithmetic, so any
//! divergence is a partitioning bug, not float noise — and the naive
//! `RefSim` differential oracle must agree to 1e-9 relative.  The last
//! property replays real machine routes across the whole topology zoo.

use std::collections::BTreeMap;

use deeper::sim::reference::RefSim;
use deeper::sim::{FlowId, ResId, Sim, SimTime};
use deeper::system::Machine;
use deeper::testing::{check, check_zoo, Config, Gen, THREAD_SWEEP};

fn cfg(cases: usize) -> Config {
    Config { cases, seed: 0xDEE9E5, ..Config::default() }
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0)
}

/// Run every flow to completion and collect the observables the sweep
/// compares: per-flow completion times plus final `op_trace` rates.
fn observe(mut sim: Sim, ids: Vec<FlowId>) -> (Vec<SimTime>, Vec<f64>) {
    let times = sim.wait_each(&ids);
    let trace = sim.op_trace();
    let rates = ids.iter().map(|&f| trace[f.0].rate).collect();
    (times, rates)
}

/// Check a builder against the whole sweep: threads=1 is the baseline,
/// every other count must reproduce it bit-for-bit.
fn sweep_matches(build: impl Fn(usize) -> (Sim, Vec<FlowId>)) -> bool {
    let (sim, ids) = build(THREAD_SWEEP[0]);
    let base = observe(sim, ids);
    THREAD_SWEEP[1..].iter().all(|&t| {
        let (sim, ids) = build(t);
        observe(sim, ids) == base
    })
}

// ----------------------------------------------------------------------
// Incast: private per-flow NICs into a few shared backends plus
// local-only flows — many single-flow components around a few big ones.
// ----------------------------------------------------------------------

/// (backend capacities, flows as (bytes, delay, incast?, backend)).
type IncastWl = (Vec<f64>, Vec<(f64, f64, bool, usize)>);

fn gen_incast(g: &mut Gen) -> IncastWl {
    let n_backends = g.usize_in(1, 3);
    let caps: Vec<f64> = g.vec(n_backends, |g| g.f64_in(1e9, 5e9));
    let n = g.usize_in(2, 32);
    let flows = g.vec(n, |g| {
        (
            g.f64_in(1e6, 5e8),
            g.f64_in(0.0, 0.05),
            g.bool(),
            g.usize_in(0, n_backends - 1),
        )
    });
    (caps, flows)
}

fn build_incast(wl: &IncastWl, threads: usize) -> (Sim, Vec<FlowId>) {
    let (caps, flows) = wl;
    let mut sim = Sim::new();
    sim.set_threads(threads);
    let backends: Vec<_> = caps.iter().map(|&c| sim.resource("oss", c)).collect();
    let ids = flows
        .iter()
        .map(|&(bytes, delay, incast, b)| {
            let nic = sim.resource("nic", 12.5e9);
            if incast {
                sim.flow(bytes, delay, &[nic, backends[b]])
            } else {
                sim.flow(bytes, delay, &[nic])
            }
        })
        .collect();
    (sim, ids)
}

#[test]
fn prop_parallel_incast_matches_serial_and_oracle() {
    check(cfg(60), gen_incast, |wl| {
        // Oracle first: threads=1 must track the naive engine to 1e-9.
        let (caps, flows) = wl;
        let mut rsim = RefSim::new();
        let rbackends: Vec<_> = caps.iter().map(|&c| rsim.resource(c)).collect();
        let rids: Vec<_> = flows
            .iter()
            .map(|&(bytes, delay, incast, b)| {
                let rnic = rsim.resource(12.5e9);
                if incast {
                    rsim.flow(bytes, delay, &[rnic, rbackends[b]])
                } else {
                    rsim.flow(bytes, delay, &[rnic])
                }
            })
            .collect();
        let tref = rsim.wait_each(&rids);
        let (sim, ids) = build_incast(wl, 1);
        let (t1, _) = observe(sim, ids);
        t1.iter().zip(&tref).all(|(a, b)| close(*a, *b))
            && sweep_matches(|t| build_incast(wl, t))
    });
}

// ----------------------------------------------------------------------
// Disjoint: k independent groups, each a shared resource fed by its own
// members' NICs — the embarrassingly parallel case.
// ----------------------------------------------------------------------

/// (group capacities, flows as (bytes, delay, group)).
type DisjointWl = (Vec<f64>, Vec<(f64, f64, usize)>);

fn gen_disjoint(g: &mut Gen) -> DisjointWl {
    let k = g.usize_in(2, 8);
    let caps: Vec<f64> = g.vec(k, |g| g.f64_in(5e8, 8e9));
    let n = g.usize_in(2, 40);
    let flows = g.vec(n, |g| {
        (g.f64_in(1e5, 3e8), g.f64_in(0.0, 0.03), g.usize_in(0, k - 1))
    });
    (caps, flows)
}

fn build_disjoint(wl: &DisjointWl, threads: usize) -> (Sim, Vec<FlowId>) {
    let (caps, flows) = wl;
    let mut sim = Sim::new();
    sim.set_threads(threads);
    let groups: Vec<_> = caps.iter().map(|&c| sim.resource("grp", c)).collect();
    let ids = flows
        .iter()
        .map(|&(bytes, delay, k)| {
            let nic = sim.resource("nic", 12.5e9);
            sim.flow(bytes, delay, &[nic, groups[k]])
        })
        .collect();
    (sim, ids)
}

#[test]
fn prop_parallel_disjoint_components_match_serial_and_oracle() {
    check(cfg(60), gen_disjoint, |wl| {
        let (caps, flows) = wl;
        let mut rsim = RefSim::new();
        let rgroups: Vec<_> = caps.iter().map(|&c| rsim.resource(c)).collect();
        let rids: Vec<_> = flows
            .iter()
            .map(|&(bytes, delay, k)| {
                let rnic = rsim.resource(12.5e9);
                rsim.flow(bytes, delay, &[rnic, rgroups[k]])
            })
            .collect();
        let tref = rsim.wait_each(&rids);
        let (sim, ids) = build_disjoint(wl, 1);
        let (t1, _) = observe(sim, ids);
        t1.iter().zip(&tref).all(|(a, b)| close(*a, *b))
            && sweep_matches(|t| build_disjoint(wl, t))
    });
}

// ----------------------------------------------------------------------
// Merge-heavy: phase 1 fills k disjoint groups, a parallel region runs
// mid-flight, then phase 2 issues bridge flows whose routes span two
// groups — each issue is a merge barrier coarsening the partition.
// ----------------------------------------------------------------------

/// (group capacities, phase-1 flows (bytes, delay, group), advance gap,
/// bridges (bytes, delay, group a, group b)).
type MergeWl = (Vec<f64>, Vec<(f64, f64, usize)>, f64, Vec<(f64, f64, usize, usize)>);

fn gen_merge(g: &mut Gen) -> MergeWl {
    let k = g.usize_in(2, 6);
    let caps: Vec<f64> = g.vec(k, |g| g.f64_in(5e8, 8e9));
    let n1 = g.usize_in(2, 24);
    let phase1 = g.vec(n1, |g| {
        (g.f64_in(1e6, 3e8), g.f64_in(0.0, 0.02), g.usize_in(0, k - 1))
    });
    let gap = g.f64_in(0.005, 0.05);
    let nb = g.usize_in(1, 8);
    let bridges = g.vec(nb, |g| {
        (
            g.f64_in(1e6, 3e8),
            g.f64_in(0.0, 0.02),
            g.usize_in(0, k - 1),
            g.usize_in(0, k - 1),
        )
    });
    (caps, phase1, gap, bridges)
}

/// Observables: mid-flight rates right after the parallel region (these
/// catch a merge-back that loses or staleness-corrupts rates) plus the
/// final completion times and rates of every flow.
fn run_merge(wl: &MergeWl, threads: usize) -> (Vec<f64>, Vec<SimTime>, Vec<f64>) {
    let (caps, phase1, gap, bridges) = wl;
    let mut sim = Sim::new();
    sim.set_threads(threads);
    let groups: Vec<_> = caps.iter().map(|&c| sim.resource("grp", c)).collect();
    let mut ids: Vec<FlowId> = phase1
        .iter()
        .map(|&(bytes, delay, k)| {
            let nic = sim.resource("nic", 12.5e9);
            sim.flow(bytes, delay, &[nic, groups[k]])
        })
        .collect();
    sim.advance(*gap); // closed-horizon region: splits at threads > 1
    let trace = sim.op_trace();
    let mid: Vec<f64> = ids.iter().map(|&f| trace[f.0].rate).collect();
    for &(bytes, delay, a, b) in bridges {
        // Distinct groups: a bridge spanning one group is not a merge.
        let b = if a == b { (a + 1) % groups.len() } else { b };
        let nic = sim.resource("nic", 12.5e9);
        ids.push(sim.flow(bytes, delay, &[nic, groups[a], groups[b]]));
    }
    let times = sim.wait_each(&ids);
    let trace = sim.op_trace();
    let rates = ids.iter().map(|&f| trace[f.0].rate).collect();
    (mid, times, rates)
}

#[test]
fn prop_parallel_merge_heavy_matches_serial() {
    check(cfg(60), gen_merge, |wl| {
        let base = run_merge(wl, THREAD_SWEEP[0]);
        THREAD_SWEEP[1..].iter().all(|&t| run_merge(wl, t) == base)
    });
}

// ----------------------------------------------------------------------
// Mid-run capacity change (`set_resource_capacity`, the degraded-mode
// enabler of DESIGN.md section 15): rescale shared resources while their
// flows are in flight, then finish the run.
// ----------------------------------------------------------------------

/// (group capacities, flows (bytes, delay, group), advance gap,
/// rescales (group, scale)).
type DegradeWl = (Vec<f64>, Vec<(f64, f64, usize)>, f64, Vec<(usize, f64)>);

fn gen_degrade(g: &mut Gen) -> DegradeWl {
    let k = g.usize_in(2, 6);
    let caps: Vec<f64> = g.vec(k, |g| g.f64_in(5e8, 8e9));
    let n = g.usize_in(2, 32);
    let flows = g.vec(n, |g| {
        (g.f64_in(1e6, 3e8), g.f64_in(0.0, 0.02), g.usize_in(0, k - 1))
    });
    let gap = g.f64_in(0.005, 0.05);
    let nr = g.usize_in(1, 4);
    // Scales span degrade and upgrade; repeats on one group are fine
    // (last write wins in both engines).
    let rescales = g.vec(nr, |g| (g.usize_in(0, k - 1), g.f64_in(0.1, 3.0)));
    (caps, flows, gap, rescales)
}

fn run_degrade(wl: &DegradeWl, threads: usize) -> (Vec<SimTime>, Vec<f64>) {
    let (caps, flows, gap, rescales) = wl;
    let mut sim = Sim::new();
    sim.set_threads(threads);
    let groups: Vec<_> = caps.iter().map(|&c| sim.resource("grp", c)).collect();
    let ids: Vec<FlowId> = flows
        .iter()
        .map(|&(bytes, delay, k)| {
            let nic = sim.resource("nic", 12.5e9);
            sim.flow(bytes, delay, &[nic, groups[k]])
        })
        .collect();
    sim.advance(*gap); // parallel region: capacities change mid-flight
    for &(k, scale) in rescales {
        sim.set_resource_capacity(groups[k], caps[k] * scale);
    }
    observe(sim, ids)
}

#[test]
fn prop_parallel_capacity_change_matches_serial_and_oracle() {
    check(cfg(60), gen_degrade, |wl| {
        // Oracle: the naive engine applies the identical rescales at the
        // identical virtual time.
        let (caps, flows, gap, rescales) = wl;
        let mut rsim = RefSim::new();
        let rgroups: Vec<_> = caps.iter().map(|&c| rsim.resource(c)).collect();
        let rids: Vec<_> = flows
            .iter()
            .map(|&(bytes, delay, k)| {
                let rnic = rsim.resource(12.5e9);
                rsim.flow(bytes, delay, &[rnic, rgroups[k]])
            })
            .collect();
        rsim.advance(*gap);
        for &(k, scale) in rescales {
            rsim.set_capacity(rgroups[k], caps[k] * scale);
        }
        let tref = rsim.wait_each(&rids);
        let base = run_degrade(wl, 1);
        base.0.iter().zip(&tref).all(|(a, b)| close(*a, *b))
            && THREAD_SWEEP[1..].iter().all(|&t| run_degrade(wl, t) == base)
    });
}

#[test]
fn prop_parallel_same_capacity_set_is_bit_identical_noop() {
    // Re-installing the capacity a resource already has must not perturb
    // the trajectory at all — the no-op path `set_resource_capacity`
    // guarantees (a revert applied to a node that was never allocated,
    // say) — at every thread count.
    check(cfg(40), gen_disjoint, |wl| {
        let run = |noop_sets: bool, threads: usize| {
            let (caps, flows) = wl;
            let mut sim = Sim::new();
            sim.set_threads(threads);
            let groups: Vec<_> = caps.iter().map(|&c| sim.resource("grp", c)).collect();
            let ids: Vec<FlowId> = flows
                .iter()
                .map(|&(bytes, delay, k)| {
                    let nic = sim.resource("nic", 12.5e9);
                    sim.flow(bytes, delay, &[nic, groups[k]])
                })
                .collect();
            sim.advance(0.002);
            if noop_sets {
                for (i, &c) in caps.iter().enumerate() {
                    sim.set_resource_capacity(groups[i], c);
                }
            }
            let times = sim.wait_each(&ids);
            let events = sim.events();
            (times, events)
        };
        THREAD_SWEEP
            .iter()
            .all(|&t| run(true, t) == run(false, t))
    });
}

// ----------------------------------------------------------------------
// Zoo sweep: real machine routes — leaf crossbars, uplinks, rails,
// bridges, device channels — on every topology family.
// ----------------------------------------------------------------------

fn route_of(m: &mut Machine, src: usize, dst: usize, to_server: bool) -> Vec<ResId> {
    if to_server {
        let srv = &m.servers[dst % m.servers.len()];
        let mut r = m.fabric.path(m.nodes[src].ep, srv.ep);
        r.push(srv.device.write_res());
        r
    } else {
        m.fabric.path(m.nodes[src].ep, m.nodes[dst].ep)
    }
}

#[test]
fn prop_parallel_zoo_machine_traffic_matches_serial_and_oracle() {
    check_zoo(
        cfg(40),
        |g, spec| {
            let nodes = spec.total_nodes();
            let n = g.usize_in(1, 20);
            g.vec(n, |g| {
                (
                    g.usize_in(0, nodes - 1),
                    g.usize_in(0, nodes - 1),
                    g.f64_in(1e5, 5e8),
                    g.f64_in(0.0, 0.02),
                    g.bool(),
                )
            })
        },
        |spec, traffic| {
            let run = |threads: usize| -> (Vec<SimTime>, Vec<f64>) {
                let mut m = Machine::build(spec.clone());
                m.sim.set_threads(threads);
                let ids: Vec<_> = traffic
                    .iter()
                    .map(|&(src, dst, bytes, delay, to_server)| {
                        let route = route_of(&mut m, src, dst, to_server);
                        m.sim.flow(bytes, delay, &route)
                    })
                    .collect();
                let times = m.sim.wait_each(&ids);
                let trace = m.sim.op_trace();
                let rates = ids.iter().map(|&f| trace[f.0].rate).collect();
                (times, rates)
            };
            let base = run(THREAD_SWEEP[0]);
            // RefSim oracle over a resource-for-resource mirror.
            let mut m = Machine::build(spec.clone());
            let mut rsim = RefSim::new();
            let mut mirror: BTreeMap<ResId, ResId> = BTreeMap::new();
            let rids: Vec<_> = traffic
                .iter()
                .map(|&(src, dst, bytes, delay, to_server)| {
                    let route = route_of(&mut m, src, dst, to_server);
                    let rroute: Vec<ResId> = route
                        .iter()
                        .map(|&r| {
                            *mirror
                                .entry(r)
                                .or_insert_with(|| rsim.resource(m.sim.capacity(r)))
                        })
                        .collect();
                    rsim.flow(bytes, delay, &rroute)
                })
                .collect();
            let tref = rsim.wait_each(&rids);
            base.0.iter().zip(&tref).all(|(a, b)| close(*a, *b))
                && THREAD_SWEEP[1..].iter().all(|&t| run(t) == base)
        },
    );
}

//! Differential oracle for the incremental backfill profile (ISSUE 9).
//!
//! `sched::profile::ProfileBook` (BTreeMap capacity deltas, O(log n)
//! insert/remove/shift, maintained across dispatch rounds) must answer
//! **bit-identically** to `sched::policy::CapProfile`, the from-scratch
//! rebuild it replaced — for `earliest_fit`, `fits_window`, and the full
//! `plan_starts` output — under randomized hold insert/remove/shift
//! churn, swept across the topology zoo.  The scheduler additionally
//! cross-checks every debug-build dispatch round against the oracle;
//! this suite drives the pair far harder than dispatch ever does.

use deeper::sched::policy::{plan_starts, CapProfile, NodeReq, Policy, QueuedReq, RunningRes};
use deeper::sched::profile::{plan_starts_book, ProfileBook};
use deeper::testing::{check_zoo, Config, Gen};

/// A request of at least one node fitting under the per-partition caps.
fn gen_req(g: &mut Gen, max_c: usize, max_b: usize) -> NodeReq {
    assert!(max_c + max_b > 0, "cannot request nodes from an empty pool");
    let mut c = g.usize_in(0, max_c);
    let mut b = g.usize_in(0, max_b);
    if c + b == 0 {
        if max_c > 0 {
            c = 1;
        } else {
            b = 1;
        }
    }
    NodeReq { cluster: c, booster: b }
}

#[test]
fn incremental_profile_matches_the_from_scratch_oracle_across_rounds() {
    check_zoo(
        Config { cases: 96, ..Config::default() },
        |g, _spec| g.u64(),
        |spec, &case_seed| {
            let mut g = Gen::new(case_seed);
            let total = NodeReq { cluster: spec.n_cluster, booster: spec.n_booster };
            // One long-lived book per case; the oracle is rebuilt from
            // scratch every round — exactly the production arrangement.
            let mut book = ProfileBook::new();
            let mut holds: Vec<(usize, f64, NodeReq)> = Vec::new();
            let mut next_id = 0usize;
            let mut now = 0.0f64;
            for _round in 0..6 {
                now += g.f64_in(0.0, 20.0);
                // Churn the running set: insert / remove / shift holds.
                for _ in 0..g.usize_in(1, 4) {
                    match g.usize_in(0, 2) {
                        0 => {
                            let (hc, hb) = holds
                                .iter()
                                .fold((0, 0), |a, h| (a.0 + h.2.cluster, a.1 + h.2.booster));
                            let (fc, fb) = (total.cluster - hc, total.booster - hb);
                            if fc + fb > 0 {
                                let req = gen_req(&mut g, fc, fb);
                                // Sometimes already overdue (est <= now):
                                // the fold-into-base path must agree with
                                // the oracle's est_end.max(now) clamp.
                                let est = if g.bool() {
                                    now + g.f64_in(0.0, 40.0)
                                } else {
                                    (now - g.f64_in(0.0, 10.0)).max(0.0)
                                };
                                book.hold_set(next_id, est, req);
                                holds.push((next_id, est, req));
                                next_id += 1;
                            }
                        }
                        1 => {
                            if !holds.is_empty() {
                                let i = g.usize_in(0, holds.len() - 1);
                                let (id, _, _) = holds.remove(i);
                                book.hold_clear(id);
                            }
                        }
                        _ => {
                            if !holds.is_empty() {
                                let i = g.usize_in(0, holds.len() - 1);
                                holds[i].1 = now + g.f64_in(0.0, 60.0);
                                book.hold_set(holds[i].0, holds[i].1, holds[i].2);
                            }
                        }
                    }
                }
                let (hc, hb) = holds
                    .iter()
                    .fold((0, 0), |a, h| (a.0 + h.2.cluster, a.1 + h.2.booster));
                let free = NodeReq { cluster: total.cluster - hc, booster: total.booster - hb };
                let running: Vec<RunningRes> = holds
                    .iter()
                    .map(|&(_, t, r)| RunningRes { req: r, est_end: t })
                    .collect();
                let queue: Vec<QueuedReq> = (0..g.usize_in(0, 8))
                    .map(|i| QueuedReq {
                        id: i,
                        req: gen_req(&mut g, total.cluster, total.booster),
                        est: g.f64_in(0.1, 30.0),
                    })
                    .collect();
                // Identical plan output under both policies.
                for policy in Policy::ALL {
                    let want = plan_starts(policy, now, free, &queue, &running);
                    let got = plan_starts_book(policy, now, free, &queue, &mut book);
                    if want != got {
                        return false;
                    }
                }
                // Bit-exact earliest_fit along the reservation chain the
                // planner builds, plus random window probes.
                let mut oracle = CapProfile::new(now, free, &running);
                book.begin_round();
                for q in &queue {
                    let to = oracle.earliest_fit(now, q.est, q.req);
                    let tb = book.earliest_fit(now, free, q.est, q.req);
                    if to.to_bits() != tb.to_bits() {
                        return false;
                    }
                    let t0 = now + g.f64_in(0.0, 60.0);
                    let dur = g.f64_in(0.0, 30.0);
                    if oracle.fits_window(t0, dur, q.req)
                        != book.fits_window(now, free, t0, dur, q.req)
                    {
                        return false;
                    }
                    oracle.reserve(to, q.est, q.req);
                    book.reserve(tb, q.est, q.req);
                }
            }
            true
        },
    );
}

#[test]
fn churned_book_drains_back_to_an_empty_profile() {
    // Whatever sequence of holds, shifts and round reservations ran, a
    // fully drained book (all holds cleared, round undone) must plan
    // like a fresh one: integer deltas leave no floating residue.
    let mut g = Gen::new(0x90F11E);
    let total = NodeReq { cluster: 16, booster: 8 };
    let mut book = ProfileBook::new();
    let mut live: Vec<usize> = Vec::new();
    for id in 0..40 {
        let req = gen_req(&mut g, total.cluster, total.booster);
        book.hold_set(id, g.f64_in(0.0, 100.0), req);
        live.push(id);
        if g.bool() {
            book.hold_set(id, g.f64_in(0.0, 100.0), req); // shift
        }
        if g.bool() && live.len() > 1 {
            let victim = live.remove(g.usize_in(0, live.len() - 2));
            book.hold_clear(victim);
        }
        // A planning round on top of the churn (full machine free, so
        // any generated request is guaranteed placeable).
        let queue = [QueuedReq { id: 0, req: gen_req(&mut g, 16, 8), est: g.f64_in(0.1, 20.0) }];
        let _ = plan_starts_book(Policy::Backfill, g.f64_in(0.0, 50.0), total, &queue, &mut book);
    }
    for id in live {
        book.hold_clear(id);
    }
    book.begin_round();
    assert_eq!(book.hold_count(), 0);
    // An empty profile answers "now" for anything that fits the machine.
    let t = book.earliest_fit(7.0, total, 5.0, NodeReq { cluster: 16, booster: 8 });
    assert_eq!(t.to_bits(), 7.0f64.to_bits());
}

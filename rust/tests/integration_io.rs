//! Integration: the I/O path through SIONlib -> BeeOND -> BeeGFS, and the
//! fabric/NAM transfer stack.

use deeper::beegfs::beeond::{concurrent_cache_write, concurrent_global_write, CacheDevice};
use deeper::beegfs::{BeeGfs, BeeOnd, CacheMode};
use deeper::fabric::TOURMALET_BW;
use deeper::nam::LibNam;
use deeper::sionlib::{self, TaskLocalWorkload};
use deeper::system::{presets, Machine, NodeKind};

#[test]
fn sionlib_over_beegfs_full_path() {
    // GERShWIN-like workload through both code paths on one machine; the
    // metadata + payload accounting must match the workload description.
    let w = TaskLocalWorkload {
        nodes: 4,
        tasks_per_node: 48,
        bytes_per_task: 8e6,
        records_per_task: 96,
    };
    let mut m = Machine::build(presets::deep_er());
    let base = sionlib::write_task_local(&mut m, &w);
    assert_eq!(base.files_created, 4 * 48);
    assert_eq!(base.meta_ops, 2 * 4 * 48);
    let sion = sionlib::write_sionlib(&mut m, &w);
    assert_eq!(sion.files_created, 1);
    assert_eq!(sion.meta_ops, 1 + 4);
    assert!(sion.write_time < base.write_time);
}

#[test]
fn beeond_async_overlaps_with_next_phase() {
    // The async flush must keep running while compute proceeds, and
    // drain() must account the remaining time.
    let mut m = Machine::build(presets::deep_er());
    let mut cache = BeeOnd::new(CacheDevice::Nvme, CacheMode::Async);
    let t_vis = cache.write(&mut m, 0, 4e9, 4);
    assert!(cache.pending_flushes() > 0);
    // Simulate a compute phase; the flush progresses during it.
    let f = m.compute(0, 2e12, 0.5);
    m.sim.wait_all(&[f]);
    let t_drain = cache.drain(&mut m);
    assert!(t_drain >= t_vis);
    // A sync write of the same size takes longer than the visible async
    // write did.
    let mut sync = BeeOnd::new(CacheDevice::Nvme, CacheMode::Sync);
    let t0 = m.sim.now();
    let t_sync = sync.write(&mut m, 1, 4e9, 4) - t0;
    assert!(t_sync > t_vis * 1.2);
}

#[test]
fn qpace3_weak_scaling_crossover() {
    // Below the backend saturation point global and local are comparable
    // in *aggregate* terms; past it, global degrades linearly.
    let bytes = 10e9;
    let mut times = Vec::new();
    for &n in &[8usize, 64, 512] {
        let nodes: Vec<usize> = (0..n).collect();
        let mut m = Machine::build(presets::qpace3().with_cluster_nodes(n));
        times.push(concurrent_global_write(&mut m, &nodes, bytes));
    }
    // 8 nodes: unsaturated; 64 -> 512 is 8x nodes -> ~8x time.
    let growth = times[2] / times[1];
    assert!((6.0..=10.0).contains(&growth), "growth {growth}");

    let nodes: Vec<usize> = (0..512).collect();
    let mut m = Machine::build(presets::qpace3().with_cluster_nodes(512));
    let mut cache = BeeOnd::new(CacheDevice::RamDisk, CacheMode::Async);
    let t_local = concurrent_cache_write(&mut m, &mut cache, &nodes, bytes, 64);
    assert!(times[2] / t_local > 100.0, "local {t_local} vs global {}", times[2]);
}

#[test]
fn beegfs_metadata_storms_serialize() {
    let mut m = Machine::build(presets::deep_er());
    let fs = BeeGfs::new();
    // 768 file creates (16 nodes x 48 tasks) at ~0.8 ms each ~ 0.6 s.
    let mut flows = Vec::new();
    for node in 0..16 {
        flows.extend(fs.meta_ops(&mut m, node, 48));
    }
    let t = m.sim.wait_all(&flows);
    assert!(t > 0.4 && t < 1.5, "t={t}");
}

#[test]
fn libnam_ring_credits_are_finite() {
    let mut sim = deeper::sim::Sim::new();
    let mut fabric = deeper::fabric::Fabric::new(&mut sim, 1e12);
    let node = fabric.endpoint(&mut sim, "n", TOURMALET_BW, deeper::fabric::LAT_CLUSTER);
    let nam = deeper::nam::NamDevice::new(&mut sim, &mut fabric, 0);
    let mut lib = LibNam::new();
    // Pump 256 slot-sized messages through a 16-slot ring: back-pressure
    // must bound in-flight transfers to the ring depth.
    for _ in 0..256 {
        lib.put(&mut sim, &fabric, &nam, node, 512.0 * 1024.0);
        assert!(lib.send_ring.in_flight() <= 16);
    }
    lib.fence(&mut sim);
    assert_eq!(lib.send_ring.in_flight(), 0);
}

#[test]
fn buddy_stream_lands_on_buddy_nvme() {
    let mut m = Machine::build(presets::deep_er());
    let bytes = 1e9;
    // Stream node0 -> node1 while node1 also writes locally: both share
    // node1's NVMe write channel, so each takes ~2x the solo time.
    let solo = {
        let mut m2 = Machine::build(presets::deep_er());
        let f = sionlib::buddy_stream(&mut m2, 0, 1, bytes);
        m2.sim.wait_all(&[f])
    };
    let f1 = sionlib::buddy_stream(&mut m, 0, 1, bytes);
    let dev = m.nodes[1].nvme.as_ref().unwrap().clone();
    let f2 = dev.write(&mut m.sim, bytes, 1, &[]);
    let t = m.sim.wait_all(&[f1, f2]);
    assert!(t > 1.6 * solo, "t={t} solo={solo}");
}

#[test]
fn booster_nodes_do_io_too() {
    // The Booster's KNL nodes have the same NVMe (Table I); checkpoints
    // from the Booster side must work identically.
    let mut m = Machine::build(presets::deep_er());
    let boosters = m.nodes_of(NodeKind::Booster);
    let mut cache = BeeOnd::new(CacheDevice::Nvme, CacheMode::Async);
    let t = concurrent_cache_write(&mut m, &mut cache, &boosters, 2e9, 4);
    assert!(t > 0.0 && t.is_finite());
}

//! Integration: the topology zoo (DESIGN.md section 13) — every registry
//! entry builds into a machine whose fabric shape matches its generator
//! parameters, routes resolve end to end on every family, names
//! round-trip, and the topology-selected bench exhibits stay
//! byte-deterministic with the canonical label pinned in their JSON.

use deeper::beegfs::BeeGfs;
use deeper::bench::{qos_report, scale_points, scale_report, QosBenchConfig, ScaleConfig};
use deeper::fabric::TopologySpec;
use deeper::system::{zoo, Machine};
use deeper::util::json::Json;

#[test]
fn every_zoo_entry_builds_with_matching_shape() {
    for (name, spec) in zoo::all() {
        let m = Machine::build(spec.clone());
        assert_eq!(m.spec.topology.label(), name, "label must round-trip through the machine");
        assert_eq!(m.nodes.len(), spec.n_cluster + spec.n_booster, "{name}: node count");
        // Every fabric endpoint, in registration order: compute nodes,
        // storage servers, the MDS, the NAM boards.
        let eps = m.nodes.len() + m.servers.len() + 1 + m.nams.len();
        let core = m.fabric.core_resources();
        let caps: Vec<f64> = core.iter().map(|&r| m.sim.capacity(r)).collect();
        match spec.topology {
            TopologySpec::Flat { backplane_bw } => {
                assert_eq!(core.len(), 1, "{name}: one backplane");
                assert_eq!(caps[0], backplane_bw);
            }
            TopologySpec::FatTree { arity, link_bw, oversub } => {
                assert_eq!(core.len(), eps.div_ceil(arity), "{name}: one uplink per leaf");
                for &c in &caps {
                    assert_eq!(c, arity as f64 * link_bw / oversub, "{name}: uplink capacity");
                }
            }
            TopologySpec::Dragonfly { group_size, link_bw, taper } => {
                assert_eq!(core.len(), eps.div_ceil(group_size), "{name}: one global per group");
                for &c in &caps {
                    assert_eq!(c, group_size as f64 * link_bw / taper, "{name}: global capacity");
                }
            }
            TopologySpec::MultiRail { rails, rail_bw } => {
                assert_eq!(core.len(), rails, "{name}: one core entry per rail");
                for &c in &caps {
                    assert_eq!(c, rail_bw, "{name}: rail capacity");
                }
            }
            TopologySpec::Split { cluster_bw, booster_bw, bridge_bw, .. } => {
                assert_eq!(core.len(), 3, "{name}: cluster switch, bridge, booster switch");
                assert_eq!(caps, vec![cluster_bw, bridge_bw, booster_bw]);
            }
            TopologySpec::Tiered { top_bw, .. } => {
                assert_eq!(core.len(), 1, "{name}: one top switch");
                assert_eq!(caps[0], top_bw);
            }
        }
    }
}

#[test]
fn routes_resolve_end_to_end_on_every_topology() {
    // Node-to-node puts (both directions plus a loopback pair) and
    // striped writes from both partitions complete with finite times on
    // every registry member — no family may strand a route.
    for (name, spec) in zoo::all() {
        let mut m = Machine::build(spec);
        let n = m.nodes.len();
        let mut flows = Vec::new();
        for (src, dst) in [(0, n - 1), (n - 1, 0), (1, 1)] {
            let route = m.fabric.path(m.nodes[src].ep, m.nodes[dst].ep);
            assert!(route.len() >= 2, "{name}: path {src}->{dst} has tx and rx at least");
            flows.push(m.sim.flow(1e8, 0.0, &route));
        }
        let mut fs = BeeGfs::new();
        flows.extend(fs.write_striped(&mut m, 0, 5e8));
        flows.extend(fs.write_striped(&mut m, n - 1, 5e8));
        let t = m.sim.wait_all(&flows);
        assert!(t > 0.0 && t.is_finite(), "{name}: transfers must complete, t={t}");
    }
}

#[test]
fn names_round_trip_and_junk_errors() {
    for name in zoo::NAMES {
        let spec = zoo::by_name(name).expect("canonical name resolves");
        assert_eq!(&spec.topology.label(), name, "by_name must round-trip {name}");
    }
    // Partial parameter lists canonicalize to the full label.
    assert_eq!(zoo::by_name("fat-tree:2").unwrap().topology.label(), "fat-tree:2,8");
    for junk in ["nope", "fat-tree:zero", "flat:9", "multi-rail:0", ""] {
        assert!(zoo::by_name(junk).is_err(), "{junk:?} must not resolve");
    }
}

#[test]
fn qos_bench_on_fat_tree_is_deterministic_and_labeled() {
    // The acceptance pin: `repro bench qos --topology fat-tree:2` is
    // byte-deterministic per seed and records the canonical label.
    let cfg = QosBenchConfig {
        iterations: 30,
        seed: 3,
        topology: Some("fat-tree:2".into()),
        ..QosBenchConfig::default()
    };
    let (_, a) = qos_report(&cfg);
    let (_, b) = qos_report(&cfg);
    assert_eq!(
        a.to_pretty_string(),
        b.to_pretty_string(),
        "fat-tree qos JSON must be byte-identical per seed"
    );
    let scenario = a.get("scenario").expect("scenario object");
    assert_eq!(scenario.get("topology").and_then(Json::as_str), Some("fat-tree:2,8"));
    assert!(scenario.get("backplane_bw").and_then(Json::as_f64).unwrap() > 0.0);
    for key in ["p99_slowdown_unshaped", "p99_slowdown_shaped"] {
        let v = a.get(key).and_then(Json::as_f64).unwrap();
        assert!(v.is_finite() && v > 0.0, "{key}={v}");
    }
}

#[test]
fn scale_bench_runs_on_zoo_topology() {
    // The zoo-routed scale workload passes the in-run differential oracle
    // (scale_points panics on divergence) and records the label.
    let cfg = ScaleConfig {
        sweep: vec![64],
        seed: 1,
        baseline_max: 64,
        topology: Some("multi-rail:4".into()),
        threads: vec![1, 2],
    };
    let pts = scale_points(&cfg);
    assert_eq!(pts.len(), 1);
    assert!(pts[0].baseline.is_some(), "naive engine must run at 64 flows");
    assert!(pts[0].engine.events > 0);
    let (_, json) = scale_report(&cfg);
    assert_eq!(json.get("topology").and_then(Json::as_str), Some("multi-rail:4"));
}

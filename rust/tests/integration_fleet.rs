//! Integration: the multi-tenant fleet scheduler end to end — golden
//! determinism of the JSON summary, the ISSUE 4 acceptance scenario
//! (8 co-scheduled jobs under backfill with MTBF-driven failures), the
//! `repro bench fleet` schema contract, and the topology-zoo fleet
//! goldens (asymmetric split machine, heterogeneous-pool backfill).

use deeper::apps::AppProfile;
use deeper::bench::{fleet_report, FleetBenchConfig};
use deeper::sched::policy::Policy;
use deeper::sched::{
    run_fleet, run_fleet_on, synthetic_jobs, CkptStrategy, FleetConfig, FleetReport, JobSpec,
};
use deeper::system::faults::{Fault, FaultKind, FaultPlan};
use deeper::system::zoo;
use deeper::util::json::{self, Json};

fn run_once(policy: Policy, jobs: usize, seed: u64, mtbf: Option<f64>) -> FleetReport {
    run_fleet(
        synthetic_jobs(jobs, seed),
        FleetConfig { policy, seed, mtbf_node: mtbf, ..FleetConfig::default() },
    )
    .expect("synthetic fleet fits the DEEP-ER prototype")
}

#[test]
fn fleet_summary_is_bit_identical_per_seed_for_both_policies() {
    // Golden determinism: same seed -> byte-identical JSON summary (job
    // finish order, completion times, per-Sim event count) across two
    // in-process runs, for both policies.  The per-Sim event counter is
    // the anchor here (unlike the process-wide sim::events_total(),
    // which concurrent test threads would pollute).
    for policy in Policy::ALL {
        let a = run_once(policy, 6, 42, Some(8_000.0));
        let b = run_once(policy, 6, 42, Some(8_000.0));
        assert_eq!(
            a.to_json().to_pretty_string(),
            b.to_json().to_pretty_string(),
            "fleet JSON must be bit-identical under policy {}",
            policy.name()
        );
        assert_eq!(a.finish_order, b.finish_order);
        assert_eq!(a.sim_events, b.sim_events);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.utilization.to_bits(), b.utilization.to_bits());
        // The seed genuinely steers the fleet: a different seed yields a
        // different trajectory.
        let c = run_once(policy, 6, 43, Some(8_000.0));
        assert_ne!(
            a.to_json().to_pretty_string(),
            c.to_json().to_pretty_string(),
            "a different seed must change the fleet trajectory"
        );
    }
}

#[test]
fn acceptance_eight_jobs_backfill_with_failures() {
    // The ISSUE 4 acceptance criterion: `repro fleet --jobs 8 --policy
    // backfill --seed 1 --mtbf 3600` completes with every job finished
    // (or restarted-then-finished), and reports fleet utilization plus
    // per-job checkpoint overhead.
    let r = run_once(Policy::Backfill, 8, 1, Some(3_600.0));
    assert_eq!(r.jobs.len(), 8);
    assert_eq!(r.finish_order.len(), 8, "every job must finish");
    for j in &r.jobs {
        assert!(
            j.stats.iterations_run >= j.iterations,
            "job {} finished short: {} of {}",
            j.name,
            j.stats.iterations_run,
            j.iterations
        );
        assert!(j.finished_at > j.first_start);
        assert!(j.stats.ckpt_overhead().is_finite());
        // A job that was failure-hit must have been requeued and charged
        // restart time.
        if j.stats.failures_hit > 0 {
            assert!(j.requeues >= 1, "job {} hit but never requeued", j.name);
            assert!(j.stats.restart_time > 0.0);
            assert!(j.stats.iterations_run > j.iterations, "rollback re-runs iterations");
        }
    }
    assert!(r.utilization > 0.0 && r.utilization <= 1.0, "util={}", r.utilization);
    assert!(r.makespan > 0.0);
    // A 3600 s per-node MTBF over 24 nodes means a ~150 s system MTBF:
    // failures certainly land inside a multi-hundred-second makespan.
    assert!(
        r.failures_injected + r.idle_failures > 0,
        "the MTBF schedule must actually fire inside the run"
    );
}

#[test]
fn fleet_json_schema_round_trips() {
    let r = run_once(Policy::Fcfs, 3, 7, None);
    let doc = r.to_json();
    let parsed = json::parse(&doc.to_pretty_string()).expect("fleet JSON parses");
    assert_eq!(parsed, doc);
    assert_eq!(doc.get("bench").and_then(Json::as_str), Some("fleet"));
    assert_eq!(doc.get("schema_version").and_then(Json::as_f64), Some(1.0));
    assert_eq!(doc.get("policy").and_then(Json::as_str), Some("fcfs"));
    assert!(doc.get("makespan_s").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(doc.get("utilization").and_then(Json::as_f64).unwrap() > 0.0);
    let jobs = doc.get("jobs").and_then(Json::as_arr).expect("jobs array");
    assert_eq!(jobs.len(), 3);
    for j in jobs {
        assert!(j.get("iterations_run").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(j.get("finished_s").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(j.get("ckpt_overhead").is_some());
    }
    assert_eq!(
        doc.get("finish_order").and_then(Json::as_arr).map(<[Json]>::len),
        Some(3)
    );
}

#[test]
fn bench_fleet_exhibits_and_schema() {
    let cfg = FleetBenchConfig { sweep: vec![2, 3], seed: 5, mtbf_node: None, topology: None };
    let (exhibits, json) = fleet_report(&cfg);
    assert_eq!(exhibits.len(), 4, "makespan fig, utilization fig, wait fig, summary");
    for e in &exhibits {
        assert!(!e.render().is_empty());
        assert!(!e.render_csv().is_empty());
    }
    let parsed = json::parse(&json.to_pretty_string()).expect("bench JSON parses");
    assert_eq!(parsed, json);
    assert_eq!(json.get("bench").and_then(Json::as_str), Some("fleet"));
    assert_eq!(json.get("schema_version").and_then(Json::as_f64), Some(1.0));
    let points = json.get("points").and_then(Json::as_arr).expect("points array");
    assert_eq!(points.len(), 4, "2 sweep points x 2 policies");
    for p in points {
        assert!(p.get("makespan_s").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(p.get("utilization").and_then(Json::as_f64).unwrap() > 0.0);
        let policy = p.get("policy").and_then(Json::as_str).unwrap();
        assert!(policy == "fcfs" || policy == "backfill");
    }
    assert_eq!(json.get("largest_point_jobs").and_then(Json::as_f64), Some(3.0));
    assert!(json.get("backfill_wait_saving_at_largest_point_s").is_some());
}

#[test]
fn bench_fleet_is_deterministic() {
    let cfg = FleetBenchConfig {
        sweep: vec![2],
        seed: 11,
        mtbf_node: Some(6_000.0),
        topology: None,
    };
    let (_, a) = fleet_report(&cfg);
    let (_, b) = fleet_report(&cfg);
    assert_eq!(a.to_pretty_string(), b.to_pretty_string());
}

#[test]
fn fleet_on_asymmetric_split_is_deterministic_and_labeled() {
    // Topology-zoo golden: the same synthetic mix on the asymmetric
    // split machine (8 cluster + 16 booster nodes behind a constrained
    // bridge) is byte-deterministic per seed, and the report carries the
    // canonical topology label.
    let run = || {
        run_fleet_on(
            zoo::by_name("split:8,16").expect("zoo entry resolves"),
            synthetic_jobs(6, 42),
            FleetConfig { policy: Policy::Backfill, seed: 42, ..FleetConfig::default() },
        )
        .expect("synthetic jobs fit the split machine")
    };
    let a = run();
    let b = run();
    assert_eq!(
        a.to_json().to_pretty_string(),
        b.to_json().to_pretty_string(),
        "split-machine fleet JSON must be bit-identical per seed"
    );
    assert_eq!(a.topology, "split:8,16");
    assert_eq!(a.to_json().get("topology").and_then(Json::as_str), Some("split:8,16"));
    assert_eq!(a.finish_order.len(), 6, "every job must finish on the split machine");
}

#[test]
fn backfill_never_delays_jobs_on_heterogeneous_pool() {
    // On the split machine's heterogeneous pool (8 cluster + 16 booster
    // nodes), compute-only jobs of mixed shapes: conservative backfill
    // may only pull starts earlier than FCFS, never push one later.
    let jobs = || -> Vec<JobSpec> {
        (0..8)
            .map(|i| JobSpec {
                name: format!("job{i}"),
                profile: deeper::apps::nbody::profile(),
                cluster_nodes: 1 + i % 6,
                booster_nodes: (i * 2) % 5,
                iterations: 4 + i,
                cp_interval: 0,
                ckpt: CkptStrategy::None,
                priority: 0,
                qos: None,
            })
            .collect()
    };
    let run = |policy: Policy| {
        run_fleet_on(
            zoo::by_name("split:8,16").expect("zoo entry resolves"),
            jobs(),
            FleetConfig { policy, seed: 9, mtbf_node: None, ..FleetConfig::default() },
        )
        .expect("jobs fit the split machine")
    };
    let f = run(Policy::Fcfs);
    let b = run(Policy::Backfill);
    for (fj, bj) in f.jobs.iter().zip(&b.jobs) {
        assert!(
            bj.first_start <= fj.first_start + 1e-6,
            "backfill delayed {}: {} vs fcfs {}",
            fj.name,
            bj.first_start,
            fj.first_start
        );
    }
}

#[test]
fn degraded_jobs_est_end_is_refreshed_so_backfill_windows_track_reality() {
    // ISSUE 9 bugfix regression: running jobs' est_end must be recomputed
    // every dispatch round from live iteration progress and the nodes'
    // *current* compute/link scales.  A x4 straggler stretches J0 (8
    // nodes, healthy estimate ~10 s) to ~34 s.  With the per-round
    // refresh, the dispatch at F's completion (~2 s) re-prices J0's
    // release, H's full-machine reservation moves to ~34 s, and B's 20 s
    // window backfills the 8 freed nodes immediately.  On the old
    // stale-estimate path H stays reserved at the healthy ~10 s release,
    // B's window collides with it, and B idles until J0 actually drains
    // — this test fails there.
    let compute_only = AppProfile {
        name: "stale-est-probe",
        flops_per_iter_per_node: 2e12, // 2 s/iter on the 1 TF/s cluster node
        cpu_efficiency: 1.0,
        ckpt_bytes_per_node: 0.0,
        halo_bytes: 0.0,
        io_tasks_per_node: 1,
        io_records_per_task: 1,
        artifact: "",
    };
    let job = |name: &str, nodes: usize, iters: usize| JobSpec {
        name: name.into(),
        profile: compute_only.clone(),
        cluster_nodes: nodes,
        booster_nodes: 0,
        iterations: iters,
        cp_interval: 0,
        ckpt: CkptStrategy::None,
        priority: 0,
        qos: None,
    };
    // Straggle node 0 (x4 compute) from t=1 for the whole run; no kill —
    // this is pure degradation, the mode the stale path mispredicts.
    let plan = FaultPlan {
        faults: vec![Fault {
            node: 0,
            kind: FaultKind::Straggler { factor: 4.0 },
            from: 1.0,
            until: 1e6,
        }],
        kills: vec![],
    };
    let r = run_fleet(
        vec![
            job("J0", 8, 5),  // nodes 0-7: the straggler's victim
            job("F", 8, 1),   // nodes 8-15, frees them at ~2 s
            job("H", 16, 5),  // whole machine: must wait for J0
            job("B", 8, 10),  // the backfill candidate behind H
        ],
        FleetConfig {
            policy: Policy::Backfill,
            fault_plan: Some(plan),
            ..FleetConfig::default()
        },
    )
    .expect("jobs fit the prototype");
    assert_eq!(r.finish_order.len(), 4, "every job must finish");
    assert!(
        (r.jobs[1].finished_at - 2.0).abs() < 0.1,
        "F must drain healthy at ~2 s, got {}",
        r.jobs[1].finished_at
    );
    assert!(
        r.jobs[0].finished_at > 30.0,
        "the straggler must stretch J0 far past its 10 s estimate, got {}",
        r.jobs[0].finished_at
    );
    assert!(
        r.jobs[3].first_start < 5.0,
        "B must backfill the freed nodes as soon as F drains (refreshed \
         est_end), got {}",
        r.jobs[3].first_start
    );
    assert!(
        r.jobs[2].first_start > 30.0,
        "H must wait for J0's actual drain, got {}",
        r.jobs[2].first_start
    );
}

#[test]
fn committed_fleet_artifact_parses() {
    // BENCH_fleet.json at the repo root is the cross-PR trajectory
    // record; whatever regenerates it (make bench-fleet / the CI
    // bench-smoke job) must keep it parseable with the pinned schema.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_fleet.json");
    let text = std::fs::read_to_string(path).expect("BENCH_fleet.json exists");
    let doc = json::parse(&text).expect("artifact parses");
    assert_eq!(doc.get("bench").and_then(Json::as_str), Some("fleet"));
    assert_eq!(doc.get("schema_version").and_then(Json::as_f64), Some(1.0));
    assert!(doc.get("points").and_then(Json::as_arr).is_some());
}

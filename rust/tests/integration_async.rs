//! Integration: the async operation engine end to end — background
//! multi-level flushes overlapping compute, failure-during-flush falling
//! back to the deepest *settled* level, and determinism of the overlapped
//! path (the ISSUE 2 acceptance scenarios).

use deeper::apps::{run_iterations_multilevel, AppProfile, IterationJob, RunStats};
use deeper::scr::multilevel::{MultiLevelConfig, MultiLevelScr, RestartLevel};
use deeper::scr::Strategy;
use deeper::system::failure::FailurePlan;
use deeper::system::{presets, Machine, NodeKind};

fn machine() -> Machine {
    Machine::build(presets::deep_er())
}

/// Fast iterations (1.25 s) against a slow 8 GB promotion (~12 s), so an
/// L2 flush issued at a checkpoint boundary is genuinely still in flight
/// several iterations later.
fn slow_flush_profile() -> AppProfile {
    AppProfile {
        name: "slow-flush",
        flops_per_iter_per_node: 0.1e12,
        cpu_efficiency: 0.08,
        ckpt_bytes_per_node: 8e9,
        halo_bytes: 0.0,
        io_tasks_per_node: 1,
        io_records_per_task: 1,
        artifact: "xpic_step",
    }
}

fn ml_cfg(async_flush: bool) -> MultiLevelConfig {
    MultiLevelConfig {
        l1_every: 1,
        l2_every: 2,
        l3_every: 100, // keep L3 out of these scenarios
        l2_strategy: Strategy::Buddy,
        async_flush,
    }
}

/// The Fig. 8-style acceptance scenario: xPic, 100 iterations, CP every
/// 10, multi-level Buddy promotion — blocking or background flush.
fn fig8_style_run(async_flush: bool, failures: FailurePlan) -> RunStats {
    let mut m = machine();
    let nodes = m.nodes_of(NodeKind::Cluster);
    let job = IterationJob {
        profile: deeper::apps::xpic::profile_deep_er(),
        iterations: 100,
        cp_interval: 10,
        failures,
    };
    let mut ml = MultiLevelScr::new(MultiLevelConfig {
        l1_every: 1,
        l2_every: 2,
        l3_every: 2,
        l2_strategy: Strategy::Buddy,
        async_flush,
    });
    run_iterations_multilevel(&mut m, &nodes, &job, &mut ml)
}

#[test]
fn async_flush_deterministic_with_seeded_failures() {
    // Same seed -> bit-identical run; the seed genuinely drives the
    // schedule (a different seed yields a different plan).
    let seed = 0xA5FC;
    let plan = |s: u64| FailurePlan::exponential(16, 40_000.0, 5_000.0, s);
    let a = fig8_style_run(true, plan(seed));
    let b = fig8_style_run(true, plan(seed));
    assert_eq!(a.total_time, b.total_time);
    assert_eq!(a.blocked_time, b.blocked_time);
    assert_eq!(a.overlap_time, b.overlap_time);
    assert_eq!(a.iterations_run, b.iterations_run);
    assert_eq!(a.failures_hit, b.failures_hit);
    // Long-horizon schedules (hundreds of draws) make a seed collision
    // impossible in practice: the seed must actually steer the schedule.
    assert_ne!(
        FailurePlan::exponential(16, 40_000.0, 1e6, seed).at_times,
        FailurePlan::exponential(16, 40_000.0, 1e6, seed + 1).at_times,
        "the seed must actually steer the failure schedule"
    );
}

#[test]
fn async_flush_blocks_strictly_less_on_fig8_scenario() {
    // Iteration-keyed failure (the paper's error at iteration 60) so both
    // runs observe the identical failure/rollback sequence.
    let fail = || FailurePlan::one_at_iteration(3, 60);
    let blocking = fig8_style_run(false, fail());
    let overlapped = fig8_style_run(true, fail());
    assert_eq!(blocking.failures_hit, 1);
    assert_eq!(overlapped.failures_hit, 1);
    assert!(
        overlapped.blocked_time < blocking.blocked_time,
        "async blocked {} !< blocking {}",
        overlapped.blocked_time,
        blocking.blocked_time
    );
    assert!(overlapped.overlap_time > 0.0);
    assert_eq!(blocking.overlap_time, 0.0);
    assert!(overlapped.total_time < blocking.total_time);
}

#[test]
fn node_loss_mid_flight_restarts_from_settled_level() {
    // Timeline (cp_interval=2, l2_every=2, 1.25 s iterations, ~12 s
    // flush): L2#1 issued at iter 4; still in flight at the iter-8
    // boundary, where back-pressure settles it before L2#2 is issued;
    // the node dies at iteration 9 with L2#2 genuinely in flight.
    let mut m = machine();
    let nodes = m.nodes_of(NodeKind::Cluster);
    let job = IterationJob {
        profile: slow_flush_profile(),
        iterations: 12,
        cp_interval: 2,
        failures: FailurePlan::one_at_iteration(2, 9),
    };
    let mut ml = MultiLevelScr::new(ml_cfg(true));
    let stats = run_iterations_multilevel(&mut m, &nodes, &job, &mut ml);
    assert_eq!(stats.failures_hit, 1);
    assert_eq!(
        ml.stats.flush_aborted, 1,
        "the in-flight promotion must be discarded, not restored from"
    );
    // Rolled back to the settled L2 (iter 4): 9 iterations before the
    // failure + (12 - 4) after the rollback.
    assert_eq!(stats.iterations_run, 9 + 8);
    assert!(stats.restart_time > 0.0);
}

#[test]
fn restart_level_reporting_matches_flush_state() {
    let mut m = machine();
    let nodes = m.nodes_of(NodeKind::Cluster);
    let mut ml = MultiLevelScr::new(ml_cfg(true));
    // Two L1s; the second also issues the L2 promotion.
    ml.checkpoint_at(&mut m, &nodes, 4e9, 1).unwrap();
    ml.checkpoint_at(&mut m, &nodes, 4e9, 2).unwrap();
    assert!(ml.flush_in_flight());
    // Transient error while the promotion is in flight: L1 serves it and
    // the promotion survives (it only reads intact node-local state).
    let r = ml.restart_detailed(&mut m, &nodes, None).unwrap();
    assert_eq!(r.level, RestartLevel::L1);
    assert_eq!(r.iter, 2);
    assert!(ml.flush_in_flight(), "transient error must not abort the flush");
    // Node loss after the promotion settled in background: polling
    // BEFORE the failure (as the driver does) commits it, and restart
    // serves from L2 at its iteration.
    m.sim.advance(300.0);
    ml.poll_flush(&mut m);
    m.kill_node(nodes[0]);
    m.revive_node(nodes[0]);
    let r = ml.restart_detailed(&mut m, &nodes, Some(nodes[0])).unwrap();
    assert_eq!(r.level, RestartLevel::L2);
    assert_eq!(r.iter, 2, "settled-in-background promotion is restorable");
    assert_eq!(ml.stats.flush_aborted, 0);
    assert_eq!(ml.l2_records().len(), 1);
}

#[test]
fn async_flush_overlap_accounted_against_compute() {
    // Clean run: every promotion settles inside the following compute
    // window, so overlap ~= the promotions' full duration and the
    // blocked share of L2 is (near) zero.
    let mut m = machine();
    let nodes = m.nodes_of(NodeKind::Cluster);
    let job = IterationJob {
        profile: deeper::apps::xpic::profile_deep_er(),
        iterations: 50,
        cp_interval: 10,
        failures: FailurePlan::none(),
    };
    let mut ml = MultiLevelScr::new(ml_cfg(true));
    let stats = run_iterations_multilevel(&mut m, &nodes, &job, &mut ml);
    assert!(ml.stats.flush_overlap > 0.0);
    assert_eq!(ml.stats.flush_blocked, 0.0, "22.5 s iterations dwarf the flush");
    assert_eq!(stats.overlap_time, ml.stats.flush_overlap);
    // Blocked time is the L1 cost only — strictly under the total
    // checkpoint machinery cost (L1 + promotions).
    assert!(stats.blocked_time < stats.ckpt_time + ml.stats.flush_overlap);
}

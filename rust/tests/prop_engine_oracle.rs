//! Differential oracle for the optimized DES engine (DESIGN.md §10).
//!
//! The optimized engine (lazy progression + indexed finish heap +
//! component-scoped refills) is run against the deliberately naive
//! reference engine (`sim::reference::RefSim`: per-event sweep, linear
//! next-event scan, global recompute) on randomized workloads — random
//! routes over random resources, random sizes and latencies.  Both must
//! produce identical per-flow completion times and identical mid-flight
//! `op_trace` rates to within 1e-9 relative.

use std::collections::BTreeMap;

use deeper::sim::reference::RefSim;
use deeper::sim::{FlowId, ResId, Sim};
use deeper::system::Machine;
use deeper::testing::{check, check_zoo, Config};

fn cfg(cases: usize) -> Config {
    Config { cases, seed: 0xDEE9E5, ..Config::default() }
}

/// (capacities, flows as (bytes, delay, resource bitmask))
type Workload = (Vec<f64>, Vec<(f64, f64, usize)>);

fn gen_workload(g: &mut deeper::testing::Gen) -> Workload {
    let nres = g.usize_in(1, 5);
    let caps: Vec<f64> = g.vec(nres, |g| g.f64_in(1e8, 1e10));
    let nflows = g.usize_in(1, 40);
    let flows: Vec<(f64, f64, usize)> = g.vec(nflows, |g| {
        (
            g.f64_in(1e3, 1e9),
            g.f64_in(0.0, 0.01),
            g.usize_in(1, (1 << nres) - 1),
        )
    });
    (caps, flows)
}

fn build_optimized(caps: &[f64], flows: &[(f64, f64, usize)]) -> (Sim, Vec<FlowId>) {
    let mut sim = Sim::new();
    let res: Vec<_> = caps
        .iter()
        .enumerate()
        .map(|(i, &c)| sim.resource(format!("r{i}"), c))
        .collect();
    let ids = flows
        .iter()
        .map(|&(bytes, delay, mask)| {
            let route: Vec<_> = res
                .iter()
                .enumerate()
                .filter(|(i, _)| mask >> i & 1 == 1)
                .map(|(_, &r)| r)
                .collect();
            sim.flow(bytes, delay, &route)
        })
        .collect();
    (sim, ids)
}

fn build_reference(caps: &[f64], flows: &[(f64, f64, usize)]) -> (RefSim, Vec<FlowId>) {
    let mut sim = RefSim::new();
    let res: Vec<_> = caps.iter().map(|&c| sim.resource(c)).collect();
    let ids = flows
        .iter()
        .map(|&(bytes, delay, mask)| {
            let route: Vec<_> = res
                .iter()
                .enumerate()
                .filter(|(i, _)| mask >> i & 1 == 1)
                .map(|(_, &r)| r)
                .collect();
            sim.flow(bytes, delay, &route)
        })
        .collect();
    (sim, ids)
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0)
}

#[test]
fn prop_oracle_completion_times_match() {
    check(
        cfg(150),
        gen_workload,
        |(caps, flows)| {
            let (mut sim, ids) = build_optimized(caps, flows);
            let (mut rsim, rids) = build_reference(caps, flows);
            let a = sim.wait_each(&ids);
            let b = rsim.wait_each(&rids);
            a.iter().zip(&b).all(|(x, y)| close(*x, *y))
        },
    );
}

#[test]
fn prop_oracle_matches_on_zoo_machine_traffic() {
    // The same differential oracle, but over *real* machine routes from
    // every topology-zoo member: node-to-node puts and node-to-storage
    // streams whose routes cross leaf crossbars, uplinks, rails, bridges
    // and device channels.  Each machine route is mirrored resource-for-
    // resource into the naive engine; completion times must agree on all
    // topologies.
    check_zoo(
        cfg(60),
        |g, spec| {
            let nodes = spec.total_nodes();
            let n = g.usize_in(1, 24);
            g.vec(n, |g| {
                (
                    g.usize_in(0, nodes - 1),
                    g.usize_in(0, nodes - 1),
                    g.f64_in(1e5, 5e8),
                    g.f64_in(0.0, 0.02),
                    g.bool(), // true: stream to a storage server instead
                )
            })
        },
        |spec, traffic| {
            let mut m = Machine::build(spec.clone());
            let mut rsim = RefSim::new();
            let mut mirror: BTreeMap<ResId, ResId> = BTreeMap::new();
            let mut ids = Vec::new();
            let mut rids = Vec::new();
            for &(src, dst, bytes, delay, to_server) in traffic {
                let route = if to_server {
                    let srv = &m.servers[dst % m.servers.len()];
                    let mut r = m.fabric.path(m.nodes[src].ep, srv.ep);
                    r.push(srv.device.write_res());
                    r
                } else {
                    m.fabric.path(m.nodes[src].ep, m.nodes[dst].ep)
                };
                let rroute: Vec<ResId> = route
                    .iter()
                    .map(|&r| {
                        *mirror
                            .entry(r)
                            .or_insert_with(|| rsim.resource(m.sim.capacity(r)))
                    })
                    .collect();
                ids.push(m.sim.flow(bytes, delay, &route));
                rids.push(rsim.flow(bytes, delay, &rroute));
            }
            let a = m.sim.wait_each(&ids);
            let b = rsim.wait_each(&rids);
            a.iter().zip(&b).all(|(x, y)| close(*x, *y))
        },
    );
}

#[test]
fn prop_oracle_mid_flight_rates_match() {
    // Probe the allocation mid-run: pick the median completion time from
    // a throwaway full run, advance fresh instances of both engines to
    // just before it, and require every per-flow rate to agree.  This is
    // what catches an incremental refill that forgets to update (or
    // wrongly updates) a neighboring component.
    check(
        cfg(100),
        gen_workload,
        |(caps, flows)| {
            let (mut probe_sim, probe_ids) = build_optimized(caps, flows);
            let mut times = probe_sim.wait_each(&probe_ids);
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let t_mid = times[times.len() / 2] * 0.999;
            let (mut sim, ids) = build_optimized(caps, flows);
            let (mut rsim, rids) = build_reference(caps, flows);
            sim.advance(t_mid);
            rsim.advance(t_mid);
            let trace = sim.op_trace();
            ids.iter().zip(&rids).all(|(&f, &rf)| {
                close(trace[f.0].rate, rsim.rate_of(rf))
            })
        },
    );
}

#[test]
fn prop_oracle_early_rates_match() {
    // All flows active almost immediately: compare the very first
    // allocation (t = 1e-8 is before any possible completion: bytes >=
    // 1e3 over <= 1e10 B/s takes >= 1e-7 s).
    check(
        cfg(100),
        |g| {
            let (caps, mut flows) = gen_workload(g);
            for f in &mut flows {
                f.1 = 0.0; // no stagger: one big joint activation
            }
            (caps, flows)
        },
        |(caps, flows)| {
            let (mut sim, ids) = build_optimized(caps, flows);
            let (mut rsim, rids) = build_reference(caps, flows);
            sim.advance(1e-8);
            rsim.advance(1e-8);
            let trace = sim.op_trace();
            ids.iter()
                .zip(&rids)
                .all(|(&f, &rf)| close(trace[f.0].rate, rsim.rate_of(rf)))
        },
    );
}

#[test]
fn prop_oracle_incast_pattern_matches() {
    // The scale-bench shape: private per-flow NICs into few shared
    // backends plus node-local-only flows — stresses exactly the
    // component boundaries the optimized engine exploits.
    check(
        cfg(80),
        |g| {
            let n_backends = g.usize_in(1, 3);
            let backend_caps: Vec<f64> = g.vec(n_backends, |g| g.f64_in(1e9, 5e9));
            let n = g.usize_in(2, 32);
            let flows: Vec<(f64, f64, bool, usize)> = g.vec(n, |g| {
                (
                    g.f64_in(1e6, 5e8),
                    g.f64_in(0.0, 0.05),
                    g.bool(), // true: incast via a backend, false: local only
                    g.usize_in(0, n_backends - 1),
                )
            });
            (backend_caps, flows)
        },
        |(backend_caps, flows)| {
            let mut sim = Sim::new();
            let mut rsim = RefSim::new();
            let backends: Vec<_> = backend_caps
                .iter()
                .map(|&c| sim.resource("oss", c))
                .collect();
            let rbackends: Vec<_> =
                backend_caps.iter().map(|&c| rsim.resource(c)).collect();
            let mut ids = Vec::new();
            let mut rids = Vec::new();
            for &(bytes, delay, incast, b) in flows {
                let nic = sim.resource("nic", 12.5e9);
                let rnic = rsim.resource(12.5e9);
                if incast {
                    ids.push(sim.flow(bytes, delay, &[nic, backends[b]]));
                    rids.push(rsim.flow(bytes, delay, &[rnic, rbackends[b]]));
                } else {
                    ids.push(sim.flow(bytes, delay, &[nic]));
                    rids.push(rsim.flow(bytes, delay, &[rnic]));
                }
            }
            let a = sim.wait_each(&ids);
            let b = rsim.wait_each(&rids);
            a.iter().zip(&b).all(|(x, y)| close(*x, *y))
        },
    );
}

//! Observability integration (ISSUE 10): the virtual-clock trace is
//! byte-deterministic for a fixed seed, zero-perturbation (reports are
//! byte-identical traced vs untraced), bounded (ring cap drops oldest,
//! deterministically), and covers every instrumented layer.

use deeper::bench::{self, QosBenchConfig};
use deeper::obs::Trace;
use deeper::sched::{
    self, run_fleet, serve_fleet, synthetic_jobs, ArrivalSpec, FleetConfig, ServeConfig,
};
use deeper::util::json::{self, Json};

/// A traced fleet config exercising every system lane: qos admission,
/// failure injection (hence restart/requeue paths) and the multilevel
/// checkpoint mix that `synthetic_jobs` draws.
fn fleet_cfg(trace: Option<Trace>) -> FleetConfig {
    FleetConfig { qos: true, mtbf_node: Some(4000.0), trace, ..FleetConfig::default() }
}

fn fleet_json(jobs: usize, trace: Option<Trace>) -> String {
    let cfg = fleet_cfg(trace);
    let specs = synthetic_jobs(jobs, cfg.seed);
    run_fleet(specs, cfg).unwrap().to_json().to_pretty_string()
}

/// The zero-perturbation gate, fleet side: installing a trace must not
/// change a single byte of the report.  Recording observes sim state but
/// never advances the clock, issues flows, or feeds back into policy.
#[test]
fn fleet_report_is_byte_identical_traced_vs_untraced() {
    let tr = Trace::new();
    let traced = fleet_json(6, Some(tr.clone()));
    let untraced = fleet_json(6, None);
    assert_eq!(traced, untraced, "tracing must not perturb the fleet report");
    assert!(tr.span_count() > 0, "the traced run must actually record spans");
}

/// Zero-perturbation, serve side: open-arrival service mode with qos
/// admission and tumbling windows, traced vs untraced.
#[test]
fn serve_report_is_byte_identical_traced_vs_untraced() {
    let mk = |trace: Option<Trace>| ServeConfig {
        jobs: 40,
        arrivals: ArrivalSpec::Poisson { rate_hz: 0.5 },
        queue_cap: 4,
        fleet: fleet_cfg(trace),
        ..ServeConfig::default()
    };
    let tr = Trace::new();
    let traced = serve_fleet(mk(Some(tr.clone()))).unwrap().to_json().to_pretty_string();
    let untraced = serve_fleet(mk(None)).unwrap().to_json().to_pretty_string();
    assert_eq!(traced, untraced, "tracing must not perturb the serve report");
    assert!(tr.counter("serve_windows_total") > 0.0);
}

/// Zero-perturbation, bench side: BENCH_qos.json is a committed
/// trajectory artifact, so its bytes must not depend on whether the
/// measuring run carried a trace.
#[test]
fn qos_bench_artifact_is_byte_identical_traced_vs_untraced() {
    let mk = |trace: Option<Trace>| QosBenchConfig {
        iterations: 4,
        trace,
        ..QosBenchConfig::default()
    };
    let (_, traced) = bench::qos_report(&mk(Some(Trace::new())));
    let (_, untraced) = bench::qos_report(&mk(None));
    assert_eq!(
        traced.to_pretty_string(),
        untraced.to_pretty_string(),
        "tracing must not perturb BENCH_qos.json"
    );
}

/// The `--trace-out` acceptance property: two identical-seed traced
/// fleet runs export byte-identical Chrome JSON and Prometheus text —
/// every timestamp is virtual, every map is ordered.
#[test]
fn fleet_trace_is_byte_deterministic_across_runs() {
    let run = || {
        let tr = Trace::new();
        let _ = fleet_json(4, Some(tr.clone()));
        (tr.chrome_trace().to_pretty_string(), tr.prometheus_text())
    };
    let (a_json, a_prom) = run();
    let (b_json, b_prom) = run();
    assert_eq!(a_json, b_json, "chrome trace must be byte-deterministic");
    assert_eq!(a_prom, b_prom, "prometheus text must be byte-deterministic");
}

/// Golden-shape check on a 2-job fleet at the default seed: the trace is
/// valid Chrome trace-event JSON (round-trips through the repo's own
/// parser) and covers spans from the sim engine, the scheduler, scr and
/// qos admission, with jobs as processes.
#[test]
fn two_job_fleet_trace_covers_all_layers() {
    let tr = Trace::new();
    let _ = fleet_json(2, Some(tr.clone()));
    let text = tr.chrome_trace().to_pretty_string();
    let doc = json::parse(&text).expect("chrome trace parses with util::json");

    let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    let names: Vec<&str> = events
        .iter()
        .filter_map(|e| e.get("name").and_then(Json::as_str))
        .collect();
    // One representative span per instrumented layer.
    for needed in [
        "sim.region",           // engine: closed-horizon region ticks
        "sched.dispatch_round", // scheduler: dispatch loop
        "job.submit",           // scheduler: job lifecycle
        "job.done",
        "phase.compute",        // driver: lifecycle slices
        "scr.ckpt",             // scr: checkpoint begin/commit
        "qos.admit",            // qos: admission verdicts
    ] {
        assert!(names.contains(&needed), "trace must contain {needed}: got {names:?}");
    }
    // Jobs render as their own trace processes (pid = job + 1), named.
    let pids: Vec<f64> =
        events.iter().filter_map(|e| e.get("pid").and_then(Json::as_f64)).collect();
    assert!(pids.contains(&0.0) && pids.contains(&1.0) && pids.contains(&2.0));
    assert!(text.contains("process_name") && text.contains("job0"));
    // Begin/End balance per (pid, name): every slice opened is closed.
    let mut open = std::collections::BTreeMap::new();
    for e in events {
        let ph = e.get("ph").and_then(Json::as_str).unwrap_or("");
        let key = (
            e.get("pid").and_then(Json::as_f64).unwrap_or(-1.0) as i64,
            e.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
        );
        match ph {
            "B" => *open.entry(key).or_insert(0i64) += 1,
            "E" => *open.entry(key).or_insert(0i64) -= 1,
            _ => {}
        }
    }
    assert!(
        open.values().all(|&n| n == 0),
        "unbalanced begin/end slices: {open:?}"
    );
    // Counters flushed from the engine agree with what ran.
    assert!(tr.counter("sim_events_total") > 0.0);
    assert!(tr.counter("sched_jobs_finished_total") == 2.0);
}

/// Boundedness: a tiny ring cap drops the *oldest* events, counts them,
/// and stays deterministic — two identical runs drop identically.
#[test]
fn ring_cap_drops_oldest_deterministically_under_load() {
    let run = || {
        let tr = Trace::with_capacity(64);
        let _ = fleet_json(3, Some(tr.clone()));
        (tr.dropped(), tr.span_count(), tr.chrome_trace().to_pretty_string())
    };
    let (dropped_a, count_a, json_a) = run();
    let (dropped_b, _, json_b) = run();
    assert!(dropped_a > 0, "a 64-slot ring must overflow on a fleet run");
    assert_eq!(count_a, 64, "ring holds exactly its capacity");
    assert_eq!(dropped_a, dropped_b, "drop count must be deterministic");
    assert_eq!(json_a, json_b, "the surviving tail must be deterministic");
    // The drop count is surfaced in the metrics export.
    let full = Trace::new();
    let _ = fleet_json(3, Some(full.clone()));
    assert_eq!(full.dropped(), 0);
    assert!(full.prometheus_text().contains("obs_dropped_spans_total 0"));
}

/// `repro bench obs` artifact: schema fields present, the traced arm
/// recorded spans, and the embedded zero-perturbation verdict holds.
#[test]
fn obs_bench_artifact_schema_and_verdict() {
    let cfg = bench::ObsBenchConfig { jobs: 3, repeats: 1, ..bench::ObsBenchConfig::default() };
    let (exhibits, jsonv) = bench::obs_report(&cfg);
    assert!(!exhibits.is_empty());
    let text = jsonv.to_pretty_string();
    let doc = json::parse(&text).expect("BENCH_obs.json parses");
    assert_eq!(doc.get("bench").and_then(Json::as_str), Some("obs"));
    assert_eq!(doc.get("schema_version").and_then(Json::as_f64), Some(1.0));
    assert_eq!(
        doc.get("report_identical_traced_vs_untraced").and_then(Json::as_bool),
        Some(true),
        "tracing must not perturb the measured fleet report"
    );
    assert!(doc.get("spans").and_then(Json::as_f64).unwrap_or(0.0) > 0.0);
    assert!(doc.get("wall_s_traced").and_then(Json::as_f64).is_some());
    assert!(doc.get("wall_s_untraced").and_then(Json::as_f64).is_some());
}

/// Threaded engines record through the same serial barriers, so the
/// trace — not just the report — is identical across `--threads`.
#[test]
fn trace_is_identical_across_thread_counts() {
    let run = |threads| {
        let tr = Trace::new();
        let cfg = FleetConfig { threads, ..fleet_cfg(Some(tr.clone())) };
        let specs = synthetic_jobs(4, cfg.seed);
        let report = sched::run_fleet(specs, cfg).unwrap().to_json().to_pretty_string();
        (report, tr.chrome_trace().to_pretty_string())
    };
    let (r1, t1) = run(1);
    let (r2, t2) = run(4);
    assert_eq!(r1, r2, "threaded fleet reports must stay bit-identical");
    // Worker merges add engine-lane barrier instants; everything else —
    // every span the serial run records — must agree.  Compare after
    // stripping the merge-only events.
    let strip = |text: &str| {
        let doc = json::parse(text).unwrap();
        doc.get("traceEvents")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) != Some("sim.merge"))
            .map(Json::to_pretty_string)
            .collect::<Vec<_>>()
    };
    assert_eq!(strip(&t1), strip(&t2), "traces must agree modulo merge barriers");
}

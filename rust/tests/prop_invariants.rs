//! Property-based invariants over the coordinator (via the in-tree
//! `testing` runner; see Cargo.toml for why proptest itself is absent).
//!
//! Invariants from DESIGN.md section 7: XOR reconstruction, buddy mapping
//! derangement, SIONlib chunk layout disjointness, DES determinism and
//! monotonicity, ring-buffer conservation, conservation of bytes in the
//! fluid model, the traffic-class QoS invariants (weighted-fill
//! conservation, floors/ceilings respected, default-weight equivalence
//! with the reference engine — DESIGN.md section 12), and JSON parser
//! robustness.  The `prop_zoo_*` properties sweep the machine-backed
//! invariants across every topology-zoo family via `testing::check_zoo`
//! (DESIGN.md section 13).

use deeper::fabric::ring::RingBuffer;
use deeper::scr::Scr;
use deeper::sim::reference::RefSim;
use deeper::sim::{Sim, TrafficClass};
use deeper::sionlib;
use deeper::system::Machine;
use deeper::testing::{check, check_with, check_zoo, Config};
use deeper::util::json;

fn cfg(cases: usize) -> Config {
    Config { cases, seed: 0xDEE9E5, ..Config::default() }
}

#[test]
fn prop_xor_reconstruction_any_single_loss() {
    // RAID-5 property of the parity fold, on host-side data (the PJRT
    // path is pinned in integration_runtime.rs).
    check(
        cfg(200),
        |g| {
            let n = g.usize_in(2, 12);
            let m = g.usize_in(1, 64);
            let blocks: Vec<Vec<i32>> = (0..n).map(|_| g.vec(m, |g| g.i32())).collect();
            let lost = g.usize_in(0, n - 1);
            (blocks, lost)
        },
        |(blocks, lost)| {
            let m = blocks[0].len();
            let mut parity = vec![0i32; m];
            for b in blocks {
                for (p, x) in parity.iter_mut().zip(b) {
                    *p ^= *x;
                }
            }
            let mut rebuilt = parity;
            for (i, b) in blocks.iter().enumerate() {
                if i != *lost {
                    for (r, x) in rebuilt.iter_mut().zip(b) {
                        *r ^= *x;
                    }
                }
            }
            rebuilt == blocks[*lost]
        },
    );
}

#[test]
fn prop_partner_map_is_derangement_and_bijection() {
    check(
        cfg(200),
        |g| g.usize_in(2, 512),
        |&n| {
            let mut seen = vec![false; n];
            for i in 0..n {
                let p = Scr::partner_of(i, n);
                if p == i || p >= n || seen[p] {
                    return false;
                }
                seen[p] = true;
            }
            seen.iter().all(|&s| s)
        },
    );
}

#[test]
fn prop_sionlib_layout_aligned_disjoint_complete() {
    check(
        cfg(200),
        |g| {
            let n = g.usize_in(1, 64);
            g.vec(n, |g| g.f64_in(1.0, 8e6))
        },
        |reqs| {
            let l = sionlib::layout(reqs);
            if l.chunks.len() != reqs.len() {
                return false;
            }
            let mut end = 0.0;
            for (i, &(task, off, size)) in l.chunks.iter().enumerate() {
                let aligned = off % sionlib::CHUNK_ALIGN == 0.0
                    && size % sionlib::CHUNK_ALIGN == 0.0;
                let covers = size >= reqs[i];
                let disjoint = off >= end - 1e-9;
                if task != i || !aligned || !covers || !disjoint {
                    return false;
                }
                end = off + size;
            }
            (l.container_bytes - end).abs() < 1e-9
        },
    );
}

#[test]
fn prop_des_completion_conserves_bytes_and_order() {
    // For any batch of flows on one shared link: every flow's measured
    // duration >= bytes/capacity (no flow beats the link), completions
    // are deterministic, and total time >= total bytes / capacity.
    check(
        cfg(150),
        |g| {
            let cap = g.f64_in(1e8, 1e10);
            let n = g.usize_in(1, 24);
            let flows: Vec<(f64, f64)> =
                g.vec(n, |g| (g.f64_in(1.0, 1e9), g.f64_in(0.0, 0.01)));
            (cap, flows)
        },
        |(cap, flows)| {
            let run = || {
                let mut sim = Sim::new();
                let link = sim.resource("l", *cap);
                let ids: Vec<_> = flows
                    .iter()
                    .map(|&(bytes, delay)| sim.flow(bytes, delay, &[link]))
                    .collect();
                sim.wait_each(&ids)
            };
            let t1 = run();
            let t2 = run();
            if t1 != t2 {
                return false; // determinism
            }
            let total_bytes: f64 = flows.iter().map(|f| f.0).sum();
            let t_end = t1.iter().copied().fold(0.0, f64::max);
            let min_delay = flows.iter().map(|f| f.1).fold(f64::INFINITY, f64::min);
            if t_end + 1e-9 < total_bytes / cap + min_delay {
                return false; // conservation: can't move bytes faster than capacity
            }
            for (i, &(bytes, delay)) in flows.iter().enumerate() {
                if t1[i] + 1e-9 < bytes / cap + delay {
                    return false; // no flow beats the link alone
                }
            }
            true
        },
    );
}

#[test]
fn prop_des_rates_within_capacity_and_max_min_fair() {
    // For any random flow set over shared resources: (1) the allocated
    // rates on every resource sum to at most its capacity, and (2) the
    // allocation is max-min fair — every active flow is capped by some
    // *saturated* bottleneck resource on which no other flow holds a
    // larger share (equivalently: all unfixed flows tied at a bottleneck
    // receive equal shares).  Audited through Sim::op_trace.
    check(
        cfg(120),
        |g| {
            let nres = g.usize_in(1, 4);
            let caps: Vec<f64> = g.vec(nres, |g| g.f64_in(1e8, 1e10));
            let nflows = g.usize_in(1, 20);
            let flows: Vec<(f64, usize)> =
                g.vec(nflows, |g| (g.f64_in(1e6, 1e9), g.usize_in(1, (1 << nres) - 1)));
            (caps, flows)
        },
        |(caps, flows)| {
            let mut sim = Sim::new();
            let res: Vec<_> = (0..caps.len())
                .map(|i| sim.resource(format!("r{i}"), caps[i]))
                .collect();
            for &(bytes, mask) in flows {
                let route: Vec<_> = res
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| mask >> i & 1 == 1)
                    .map(|(_, &r)| r)
                    .collect();
                sim.flow(bytes, 0.0, &route);
            }
            // Activate everything; far too little time for any completion
            // (>= 1e6 bytes against <= 1e10 B/s).
            sim.advance(1e-9);
            let trace = sim.op_trace();
            let active: Vec<_> = trace.iter().filter(|e| !e.done).collect();
            if active.len() != flows.len() {
                return false; // nothing may have completed yet
            }
            // (1) per-resource allocated rate never exceeds capacity.
            let mut load = vec![0.0f64; caps.len()];
            for e in &active {
                for r in &e.route {
                    load[r.0] += e.rate;
                }
            }
            for (i, &l) in load.iter().enumerate() {
                if l > caps[i] * (1.0 + 1e-9) + 1e-6 {
                    return false;
                }
            }
            // (2) max-min: each flow has a saturated bottleneck where its
            // share is maximal (ties share equally by construction).
            active.iter().all(|e| {
                e.route.iter().any(|r| {
                    let saturated = load[r.0] >= caps[r.0] * (1.0 - 1e-6);
                    let max_share = active
                        .iter()
                        .filter(|o| o.route.contains(r))
                        .fold(0.0f64, |m, o| m.max(o.rate));
                    saturated && e.rate >= max_share * (1.0 - 1e-6)
                })
            })
        },
    );
}

#[test]
fn prop_des_insertion_order_permutation_invariant() {
    // Completion times are a property of the flow *set*, not of the order
    // the flows were registered in: re-inserting the same flows in any
    // permutation yields the same per-flow completion times.
    check(
        cfg(100),
        |g| {
            let nres = g.usize_in(1, 3);
            let caps: Vec<f64> = g.vec(nres, |g| g.f64_in(1e8, 5e9));
            let n = g.usize_in(1, 16);
            let flows: Vec<(f64, f64, usize)> = g.vec(n, |g| {
                (g.f64_in(1.0, 1e9), g.f64_in(0.0, 0.01), g.usize_in(1, (1 << nres) - 1))
            });
            // Fisher-Yates permutation of 0..n.
            let mut perm: Vec<usize> = (0..n).collect();
            for i in (1..n).rev() {
                let j = g.usize_in(0, i);
                perm.swap(i, j);
            }
            (caps, flows, perm)
        },
        |(caps, flows, perm)| {
            let run = |order: &[usize]| -> Vec<f64> {
                let mut sim = Sim::new();
                let res: Vec<_> = (0..caps.len())
                    .map(|i| sim.resource(format!("r{i}"), caps[i]))
                    .collect();
                let mut ids = vec![None; flows.len()];
                for &k in order {
                    let (bytes, delay, mask) = flows[k];
                    let route: Vec<_> = res
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| mask >> i & 1 == 1)
                        .map(|(_, &r)| r)
                        .collect();
                    ids[k] = Some(sim.flow(bytes, delay, &route));
                }
                let ids: Vec<_> = ids.into_iter().map(Option::unwrap).collect();
                sim.wait_each(&ids)
            };
            let identity: Vec<usize> = (0..flows.len()).collect();
            let a = run(&identity);
            let b = run(perm);
            a.iter()
                .zip(&b)
                .all(|(x, y)| (x - y).abs() <= 1e-6 * x.abs().max(1.0))
        },
    );
}

#[test]
fn prop_des_work_conserving_single_resource() {
    // With all flows present from t=0 on one link, the last completion is
    // EXACTLY total/capacity (the fluid model wastes nothing).
    check(
        cfg(150),
        |g| {
            let n = g.usize_in(1, 16);
            g.vec(n, |g| g.f64_in(1e3, 1e9))
        },
        |sizes| {
            let mut sim = Sim::new();
            let link = sim.resource("l", 1e9);
            let ids: Vec<_> = sizes.iter().map(|&b| sim.flow(b, 0.0, &[link])).collect();
            let t = sim.wait_all(&ids);
            let expect = sizes.iter().sum::<f64>() / 1e9;
            (t - expect).abs() / expect < 1e-6
        },
    );
}

#[test]
fn prop_qos_weighted_fill_conserves_and_respects_ceilings() {
    // For any random flow set with random classes, weights, ceilings and
    // admissible floors: (1) the allocated rates on every resource
    // (including ceiling shadow resources) sum to at most its capacity,
    // and (2) every (resource, class) ceiling bounds that class's
    // aggregate rate.  Audited through Sim::op_trace.
    check(
        cfg(120),
        |g| {
            let nres = g.usize_in(1, 3);
            let caps: Vec<f64> = g.vec(nres, |g| g.f64_in(1e8, 1e10));
            // Ceilings: at most one per (resource, class) — re-configuring
            // overrides, so duplicates would invalidate the audit below.
            let mut ceilings: Vec<(usize, usize, f64)> = Vec::new();
            for r in 0..nres {
                let k = g.usize_in(0, 2);
                for _ in 0..k {
                    let c = g.usize_in(0, TrafficClass::COUNT - 1);
                    if !ceilings.iter().any(|&(cr, cc, _)| cr == r && cc == c) {
                        ceilings.push((r, c, g.f64_in(0.05, 0.9)));
                    }
                }
            }
            // Floors: at most one class per resource, fraction <= 0.4 of
            // capacity (admissible by construction).
            let mut floors: Vec<(usize, usize, f64)> = Vec::new();
            for r in 0..nres {
                if g.bool() {
                    floors.push((r, g.usize_in(0, TrafficClass::COUNT - 1), g.f64_in(0.05, 0.4)));
                }
            }
            let nflows = g.usize_in(1, 16);
            let flows: Vec<(f64, usize, usize, f64)> = g.vec(nflows, |g| {
                (
                    g.f64_in(1e6, 1e9),
                    g.usize_in(1, (1 << nres) - 1),
                    g.usize_in(0, TrafficClass::COUNT - 1),
                    g.f64_in(0.1, 8.0),
                )
            });
            (caps, ceilings, floors, flows)
        },
        |(caps, ceilings, floors, flows)| {
            let mut sim = Sim::new();
            let res: Vec<_> = (0..caps.len())
                .map(|i| sim.resource(format!("r{i}"), caps[i]))
                .collect();
            // Bounds must be configured before the flows they shape.
            for &(r, c, frac) in ceilings {
                sim.set_class_ceiling(res[r], TrafficClass::ALL[c], frac * caps[r]);
            }
            for &(r, c, frac) in floors {
                sim.set_class_floor(res[r], TrafficClass::ALL[c], frac * caps[r]);
            }
            for &(bytes, mask, class, weight) in flows {
                let route: Vec<_> = res
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| mask >> i & 1 == 1)
                    .map(|(_, &r)| r)
                    .collect();
                sim.flow_weighted(bytes, 0.0, &route, TrafficClass::ALL[class], weight);
            }
            sim.advance(1e-9); // activate everything; nothing completes
            let trace = sim.op_trace();
            let active: Vec<_> = trace.iter().filter(|e| !e.done).collect();
            if active.len() != flows.len() {
                return false;
            }
            // (1) conservation on every resource, shadows included.
            let mut load: std::collections::HashMap<usize, f64> = Default::default();
            for e in &active {
                for r in &e.route {
                    *load.entry(r.0).or_insert(0.0) += e.rate;
                }
            }
            for (&r, &l) in &load {
                let cap = sim.capacity(deeper::sim::ResId(r));
                if l > cap * (1.0 + 1e-9) + 1e-6 {
                    return false;
                }
            }
            // (2) explicit per-(resource, class) ceiling audit on the
            // base resources.
            for &(r, c, frac) in ceilings {
                let class = TrafficClass::ALL[c];
                let agg: f64 = active
                    .iter()
                    .filter(|e| e.class == class && e.route.contains(&res[r]))
                    .map(|e| e.rate)
                    .sum();
                if agg > frac * caps[r] * (1.0 + 1e-9) + 1e-6 {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_qos_floor_respected_on_single_resource() {
    // On one shared resource with admissible floors (sum <= 0.9 of
    // capacity): every floored class with at least one active flow
    // receives at least its floor in aggregate (fluid flows always have
    // demand), and the total stays within capacity.
    check(
        cfg(150),
        |g| {
            let cap = g.f64_in(1e8, 1e10);
            // Distinct floored classes with fractions summing <= 0.9.
            let k = g.usize_in(1, 3);
            let mut budget = 0.9;
            let mut floors = Vec::new();
            let mut used = [false; TrafficClass::COUNT];
            for _ in 0..k {
                if budget < 0.06 {
                    break;
                }
                let c = g.usize_in(0, TrafficClass::COUNT - 1);
                if used[c] {
                    continue;
                }
                used[c] = true;
                let frac = g.f64_in(0.05, budget.min(0.5));
                budget -= frac;
                floors.push((c, frac));
            }
            let nflows = g.usize_in(2, 20);
            let flows: Vec<(f64, usize, f64)> = g.vec(nflows, |g| {
                (
                    g.f64_in(1e6, 1e9),
                    g.usize_in(0, TrafficClass::COUNT - 1),
                    g.f64_in(0.1, 8.0),
                )
            });
            (cap, floors, flows)
        },
        |(cap, floors, flows)| {
            let mut sim = Sim::new();
            let link = sim.resource("l", *cap);
            for &(c, frac) in floors {
                sim.set_class_floor(link, TrafficClass::ALL[c], frac * cap);
            }
            for &(bytes, class, weight) in flows {
                sim.flow_weighted(bytes, 0.0, &[link], TrafficClass::ALL[class], weight);
            }
            sim.advance(1e-9);
            let trace = sim.op_trace();
            let active: Vec<_> = trace.iter().filter(|e| !e.done).collect();
            if active.len() != flows.len() {
                return false;
            }
            let total: f64 = active.iter().map(|e| e.rate).sum();
            if total > cap * (1.0 + 1e-9) + 1e-6 {
                return false;
            }
            for &(c, frac) in floors {
                let class = TrafficClass::ALL[c];
                let members: Vec<_> =
                    active.iter().filter(|e| e.class == class).collect();
                if members.is_empty() {
                    continue; // no demand: nothing to guarantee
                }
                let agg: f64 = members.iter().map(|e| e.rate).sum();
                if agg + 1e-6 < frac * cap * (1.0 - 1e-9) {
                    return false; // floor violated despite demand
                }
            }
            true
        },
    );
}

#[test]
fn prop_zoo_machine_traffic_conserves_capacity() {
    // Real routed traffic swept across every zoo machine: mid-flight, the
    // allocated rates on every touched resource (endpoint ports, leaf
    // crossbars, uplinks, rails, bridges, device channels) sum to at most
    // its capacity.
    check_zoo(
        cfg(60),
        |g, spec| {
            let nodes = spec.total_nodes();
            let n = g.usize_in(2, 20);
            g.vec(n, |g| {
                (
                    g.usize_in(0, nodes - 1),
                    g.usize_in(0, nodes - 1),
                    g.f64_in(1e7, 5e8),
                    g.bool(), // true: stream to a storage server instead
                )
            })
        },
        |spec, traffic| {
            let mut m = Machine::build(spec.clone());
            for &(src, dst, bytes, to_server) in traffic {
                let route = if to_server {
                    let srv = &m.servers[dst % m.servers.len()];
                    let mut r = m.fabric.path(m.nodes[src].ep, srv.ep);
                    r.push(srv.device.write_res());
                    r
                } else {
                    m.fabric.path(m.nodes[src].ep, m.nodes[dst].ep)
                };
                m.sim.flow(bytes, 0.0, &route);
            }
            // Activate everything; far too little time for any completion
            // (>= 1e7 bytes against every capacity in the zoo).
            m.sim.advance(1e-9);
            let trace = m.sim.op_trace();
            let active: Vec<_> = trace.iter().filter(|e| !e.done).collect();
            if active.len() != traffic.len() {
                return false;
            }
            let mut load: std::collections::HashMap<usize, f64> = Default::default();
            for e in &active {
                for r in &e.route {
                    *load.entry(r.0).or_insert(0.0) += e.rate;
                }
            }
            load.iter().all(|(&r, &l)| {
                l <= m.sim.capacity(deeper::sim::ResId(r)) * (1.0 + 1e-9) + 1e-6
            })
        },
    );
}

#[test]
fn prop_zoo_ceilings_bound_class_rates_on_core_resources() {
    // A CkptFlush ceiling installed on every fabric-core resource of a
    // zoo machine bounds that class's aggregate mid-flight rate on each,
    // with Bulk cross-traffic contending on the same machine routes.
    check_zoo(
        cfg(60),
        |g, spec| {
            let nodes = spec.total_nodes();
            let frac = g.f64_in(0.1, 0.6);
            let n = g.usize_in(4, 24);
            let transfers = g.vec(n, |g| {
                (
                    g.usize_in(0, nodes - 1),
                    g.usize_in(0, nodes - 1),
                    g.f64_in(1e7, 5e8),
                    g.bool(), // true: CkptFlush, false: Bulk
                )
            });
            (frac, transfers)
        },
        |spec, (frac, transfers)| {
            let mut m = Machine::build(spec.clone());
            let core = m.fabric.core_resources();
            for &r in &core {
                let cap = m.sim.capacity(r);
                m.sim.set_class_ceiling(r, TrafficClass::CkptFlush, frac * cap);
            }
            for &(src, dst, bytes, flush) in transfers {
                let route = m.fabric.path(m.nodes[src].ep, m.nodes[dst].ep);
                let class =
                    if flush { TrafficClass::CkptFlush } else { TrafficClass::Bulk };
                m.sim.flow_classed(bytes, 0.0, &route, class);
            }
            m.sim.advance(1e-9);
            let trace = m.sim.op_trace();
            let active: Vec<_> = trace.iter().filter(|e| !e.done).collect();
            if active.len() != transfers.len() {
                return false;
            }
            core.iter().all(|&r| {
                let cap = m.sim.capacity(r);
                let agg: f64 = active
                    .iter()
                    .filter(|e| e.class == TrafficClass::CkptFlush && e.route.contains(&r))
                    .map(|e| e.rate)
                    .sum();
                agg <= frac * cap * (1.0 + 1e-9) + 1e-6
            })
        },
    );
}

#[test]
fn prop_zoo_floors_hold_on_every_core_resource() {
    // An Exchange floor on each fabric-core resource of a zoo machine is
    // honored under saturating contention: with Bulk competitors pinned
    // to the same resource, the Exchange aggregate mid-flight rate is at
    // least the floor.  Floors are per-resource reservations, not
    // end-to-end guarantees, so the probe flows route through the floored
    // resource alone (a multi-hop flow bottlenecked elsewhere may
    // legitimately deliver less).
    check_zoo(
        cfg(60),
        |g, _spec| {
            (
                g.f64_in(0.1, 0.5),  // floor fraction
                g.usize_in(1, 4),    // exchange flows per core resource
                g.usize_in(1, 6),    // bulk competitors per core resource
            )
        },
        |spec, &(frac, n_ex, n_bulk)| {
            let mut m = Machine::build(spec.clone());
            let core = m.fabric.core_resources();
            for &r in &core {
                let cap = m.sim.capacity(r);
                m.sim.set_class_floor(r, TrafficClass::Exchange, frac * cap);
                for _ in 0..n_ex {
                    m.sim.flow_classed(1e9, 0.0, &[r], TrafficClass::Exchange);
                }
                for _ in 0..n_bulk {
                    m.sim.flow_classed(1e9, 0.0, &[r], TrafficClass::Bulk);
                }
            }
            m.sim.advance(1e-9);
            let trace = m.sim.op_trace();
            let active: Vec<_> = trace.iter().filter(|e| !e.done).collect();
            core.iter().all(|&r| {
                let cap = m.sim.capacity(r);
                let agg: f64 = active
                    .iter()
                    .filter(|e| {
                        e.class == TrafficClass::Exchange
                            && e.route.len() == 1
                            && e.route[0] == r
                    })
                    .map(|e| e.rate)
                    .sum();
                agg + 1e-6 >= frac * cap * (1.0 - 1e-9)
            })
        },
    );
}

#[test]
fn prop_qos_default_weights_match_reference_engine() {
    // The engine regression gate: flows issued through the classed API
    // with default weights, no floors and no ceilings must reproduce the
    // naive reference engine's completion times within 1e-9 — classes
    // alone may not change behavior.
    check(
        cfg(100),
        |g| {
            let nres = g.usize_in(1, 3);
            let caps: Vec<f64> = g.vec(nres, |g| g.f64_in(1e8, 5e9));
            let n = g.usize_in(1, 16);
            let flows: Vec<(f64, f64, usize, usize)> = g.vec(n, |g| {
                (
                    g.f64_in(1.0, 1e9),
                    g.f64_in(0.0, 0.01),
                    g.usize_in(1, (1 << nres) - 1),
                    g.usize_in(0, TrafficClass::COUNT - 1),
                )
            });
            (caps, flows)
        },
        |(caps, flows)| {
            let mut sim = Sim::new();
            let mut reference = RefSim::new();
            let res: Vec<_> = (0..caps.len())
                .map(|i| sim.resource(format!("r{i}"), caps[i]))
                .collect();
            let rres: Vec<_> = caps.iter().map(|&c| reference.resource(c)).collect();
            let mut ids = Vec::new();
            let mut rids = Vec::new();
            for &(bytes, delay, mask, class) in flows {
                let route: Vec<_> = res
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| mask >> i & 1 == 1)
                    .map(|(_, &r)| r)
                    .collect();
                let rroute: Vec<_> = rres
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| mask >> i & 1 == 1)
                    .map(|(_, &r)| r)
                    .collect();
                ids.push(sim.flow_classed(bytes, delay, &route, TrafficClass::ALL[class]));
                rids.push(reference.flow(bytes, delay, &rroute));
            }
            let a = sim.wait_each(&ids);
            let b = reference.wait_each(&rids);
            a.iter()
                .zip(&b)
                .all(|(x, y)| (x - y).abs() <= 1e-9 * x.abs().max(1.0))
        },
    );
}

#[test]
fn prop_ring_buffer_never_loses_or_duplicates() {
    check(
        cfg(200),
        |g| {
            let slots = g.usize_in(1, 32);
            let slot_bytes = g.usize_in(64, 8192);
            let n_msgs = g.usize_in(1, 100);
            let msgs = g.vec(n_msgs, |g| g.usize_in(0, 4 * slot_bytes));
            (slots, slot_bytes, msgs)
        },
        |(slots, slot_bytes, msgs)| {
            let mut ring = RingBuffer::new(*slots, *slot_bytes);
            let mut claimed: Vec<(u64, usize)> = Vec::new();
            let mut retired: Vec<(u64, usize)> = Vec::new();
            for &len in msgs {
                loop {
                    match ring.claim(len) {
                        Ok(seq) => {
                            claimed.push((seq, len));
                            break;
                        }
                        Err(_) => {
                            if ring.slots_needed(len) > *slots {
                                // Never fits; skip this message.
                                break;
                            }
                            match ring.retire_oldest() {
                                Some(r) => retired.push(r),
                                None => return false, // full yet empty: bug
                            }
                        }
                    }
                }
            }
            while let Some(r) = ring.retire_oldest() {
                retired.push(r);
            }
            // Conservation: everything claimed was retired exactly once,
            // in order.
            retired == claimed
        },
    );
}

#[test]
fn prop_failure_plan_exponential_sorted_and_in_horizon() {
    check(
        cfg(100),
        |g| {
            let nodes = g.usize_in(1, 128);
            let mtbf = g.f64_in(1e3, 1e6);
            let horizon = g.f64_in(1.0, 1e5);
            let seed = g.u64();
            (nodes, mtbf, horizon, seed)
        },
        |&(nodes, mtbf, horizon, seed)| {
            let plan =
                deeper::system::failure::FailurePlan::exponential(nodes, mtbf, horizon, seed);
            let mut last = 0.0;
            for f in &plan.at_times {
                if f.at <= last || f.at >= horizon || f.node >= nodes {
                    return false;
                }
                last = f.at;
            }
            true
        },
    );
}

#[test]
fn prop_json_roundtrip_numbers_and_strings() {
    check_with(
        cfg(300),
        |g| {
            // Build a small random JSON doc and its expected value.
            let n = g.usize_in(0, 8);
            let items: Vec<(String, f64)> = (0..n)
                .map(|i| (format!("k{i}"), (g.i32() as f64) / 16.0))
                .collect();
            items
        },
        |items| {
            if items.is_empty() {
                return vec![];
            }
            vec![items[..items.len() - 1].to_vec()]
        },
        |items| {
            let body: Vec<String> =
                items.iter().map(|(k, v)| format!("\"{k}\": {v}")).collect();
            let doc = format!("{{{}}}", body.join(", "));
            let parsed = match json::parse(&doc) {
                Ok(p) => p,
                Err(_) => return false,
            };
            items.iter().all(|(k, v)| {
                parsed.get(k).and_then(json::Json::as_f64).map(|x| (x - v).abs() < 1e-9)
                    == Some(true)
            })
        },
    );
}

#[test]
fn prop_ompss_waves_topologically_consistent() {
    use deeper::ompss::{Task, TaskGraph};
    check(
        cfg(150),
        |g| {
            let n = g.usize_in(1, 40);
            // Random DAG: each task depends on a random subset of earlier ones.
            let mut deps: Vec<Vec<usize>> = Vec::with_capacity(n);
            for i in 0..n {
                let k = g.usize_in(0, i.min(3));
                let mut d = Vec::new();
                for _ in 0..k {
                    d.push(g.usize_in(0, i.max(1) - 1));
                }
                d.sort_unstable();
                d.dedup();
                deps.push(d);
            }
            deps
        },
        |deps| {
            let mut graph = TaskGraph::new();
            for d in deps {
                graph.add(Task {
                    name: String::new(),
                    flops: 1.0,
                    input_bytes: 0.0,
                    output_bytes: 0.0,
                    deps: d.clone(),
                });
            }
            let waves = graph.waves();
            // Each task appears exactly once, and strictly after its deps.
            let mut wave_of = vec![usize::MAX; deps.len()];
            let mut count = 0;
            for (wi, wave) in waves.iter().enumerate() {
                for &t in wave {
                    if wave_of[t] != usize::MAX {
                        return false;
                    }
                    wave_of[t] = wi;
                    count += 1;
                }
            }
            if count != deps.len() {
                return false;
            }
            deps.iter().enumerate().all(|(i, d)| {
                d.iter().all(|&dep| wave_of[dep] < wave_of[i])
            })
        },
    );
}

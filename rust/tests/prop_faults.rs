//! Properties of the checkpoint-corruption taxonomy (DESIGN.md §15):
//! restart always lands on the deepest *verified* checkpoint — never a
//! corrupted record, never iteration 0 while a verified record exists —
//! swept across every single-level SCR strategy and every multi-level
//! tier.

use deeper::scr::multilevel::{MultiLevelConfig, MultiLevelScr, RestartLevel};
use deeper::scr::{Scr, Strategy};
use deeper::system::{presets, Machine, NodeKind};
use deeper::testing::{check, Config, Gen};

fn cfg(cases: usize) -> Config {
    Config { cases, seed: 0xDEE9E5, ..Config::default() }
}

fn machine() -> Machine {
    Machine::build(presets::deep_er())
}

/// One corruption scenario for the single-level sweep.
#[derive(Debug, Clone)]
struct SingleWl {
    /// Checkpoints taken, stamped iters 10, 20, ... 10*n.
    n_ckpts: usize,
    /// `corrupt_latest` calls (may exceed `n_ckpts`: walks off the end).
    corruptions: usize,
    /// Transient restart (None) vs node loss (Some).
    transient: bool,
}

fn gen_single(g: &mut Gen) -> SingleWl {
    SingleWl {
        n_ckpts: g.usize_in(1, 5),
        corruptions: g.usize_in(0, 6),
        transient: g.bool(),
    }
}

/// Every strategy: corruption walks the restart target backwards through
/// the database one verified record at a time, and restart errs exactly
/// when nothing verified covers the failure.
#[test]
fn prop_every_strategy_restarts_from_deepest_verified() {
    check(cfg(32), gen_single, |wl| {
        for strat in Strategy::ALL {
            let mut m = machine();
            let nodes: Vec<usize> = m.nodes_of(NodeKind::Cluster)[..4].to_vec();
            let mut scr = Scr::new(strat);
            for k in 1..=wl.n_ckpts {
                scr.checkpoint_iter(&mut m, &nodes, 1e8, 10 * k).unwrap();
            }
            let mut hits = 0;
            for _ in 0..wl.corruptions {
                if scr.corrupt_latest() {
                    hits += 1;
                }
            }
            // Corruption consumes exactly the verified records, newest
            // first, and reports exhaustion honestly.
            if hits != wl.corruptions.min(wl.n_ckpts) {
                return false;
            }
            let survivors = wl.n_ckpts.saturating_sub(wl.corruptions);
            let failed = if wl.transient {
                None
            } else {
                m.kill_node(nodes[1]);
                m.revive_node(nodes[1]);
                Some(nodes[1])
            };
            let covered = survivors > 0
                && (failed.is_none() || strat.survives_node_loss());
            match scr.restart(&mut m, &nodes, failed) {
                Ok(r) => {
                    // Deepest verified record, by its iter stamp — and
                    // never iteration 0 while one exists.
                    if !covered || r.iter != 10 * survivors || r.iter == 0 {
                        return false;
                    }
                }
                Err(_) => {
                    if covered {
                        return false;
                    }
                }
            }
        }
        true
    });
}

/// One corruption scenario for the multi-level tier sweep.
#[derive(Debug, Clone)]
struct TierWl {
    /// Iterations run (L1 every iter, L2 every 2 L1s, L3 every 2 L2s).
    iters: usize,
    /// `corrupt_level(L1)` calls.
    c1: usize,
    /// `corrupt_level(L2)` calls.
    c2: usize,
    /// Corrupt the global (L3) copy too.
    c3: bool,
    /// Transient restart (None) vs node loss (Some).
    transient: bool,
}

fn gen_tier(g: &mut Gen) -> TierWl {
    let iters = g.usize_in(4, 12);
    TierWl {
        iters,
        c1: g.usize_in(0, iters + 1),
        c2: g.usize_in(0, iters / 2 + 1),
        c3: g.bool(),
        transient: g.bool(),
    }
}

/// What the verified-fallback chain must serve, from the cadence model:
/// newest verified L1, else newest verified L2, else the L3 copy.
fn expected_tier(
    wl: &TierWl,
    skip_l1: bool,
) -> Option<(RestartLevel, usize)> {
    let l1: Vec<usize> = (1..=wl.iters).collect();
    let l2: Vec<usize> = (1..=wl.iters).filter(|i| i % 2 == 0).collect();
    let l3_iter = (wl.iters / 4) * 4; // every 2nd L2 = every 4th iter
    if !skip_l1 {
        if let Some(&i) = l1.get(l1.len().wrapping_sub(wl.c1 + 1)) {
            return Some((RestartLevel::L1, i));
        }
    }
    if let Some(&i) = l2.get(l2.len().wrapping_sub(wl.c2 + 1)) {
        return Some((RestartLevel::L2, i));
    }
    if l3_iter > 0 && !wl.c3 {
        return Some((RestartLevel::L3, l3_iter));
    }
    None
}

/// Multi-level: corrupting tiers walks restart down the L1 -> L2 -> L3
/// chain level by level; it errs only once every tier is unverified.
#[test]
fn prop_multilevel_restart_walks_verified_tiers() {
    check(cfg(32), gen_tier, |wl| {
        let mut m = machine();
        let nodes: Vec<usize> = m.nodes_of(NodeKind::Cluster)[..4].to_vec();
        let config = MultiLevelConfig {
            l1_every: 1,
            l2_every: 2,
            l3_every: 2,
            ..MultiLevelConfig::default()
        };
        let mut ml = MultiLevelScr::new(config);
        for i in 1..=wl.iters {
            ml.checkpoint_at(&mut m, &nodes, 1e8, i).unwrap();
        }
        let n_l2 = wl.iters / 2;
        for k in 0..wl.c1 {
            let hit = ml.corrupt_level(RestartLevel::L1);
            if hit != (k < wl.iters) {
                return false; // exhaustion must be reported honestly
            }
        }
        for k in 0..wl.c2 {
            if ml.corrupt_level(RestartLevel::L2) != (k < n_l2) {
                return false;
            }
        }
        if wl.c3 {
            // L3 exists iff at least one flush fired (iters >= 4 here).
            if ml.corrupt_level(RestartLevel::L3) != (wl.iters >= 4) {
                return false;
            }
        }
        let failed = if wl.transient {
            None
        } else {
            m.kill_node(nodes[1]);
            m.revive_node(nodes[1]);
            Some(nodes[1])
        };
        // Node loss skips L1 (node-local NVMe died with the node).
        let want = expected_tier(wl, failed.is_some());
        match ml.restart_detailed(&mut m, &nodes, failed) {
            Ok(out) => match want {
                Some((level, iter)) => {
                    out.level == level && out.iter == iter && out.iter != 0
                }
                None => false,
            },
            Err(_) => want.is_none(),
        }
    });
}

/// `corrupt_latest` (the fleet scheduler's injection point) drains the
/// L1/L2 databases completely — newest-first across levels — and restart
/// then falls through to L3 or errs.
#[test]
fn prop_multilevel_corrupt_latest_drains_to_l3() {
    check(cfg(24), |g| g.usize_in(2, 10), |&iters| {
        let mut m = machine();
        let nodes: Vec<usize> = m.nodes_of(NodeKind::Cluster)[..4].to_vec();
        let config = MultiLevelConfig {
            l1_every: 1,
            l2_every: 2,
            l3_every: 2,
            ..MultiLevelConfig::default()
        };
        let mut ml = MultiLevelScr::new(config);
        for i in 1..=iters {
            ml.checkpoint_at(&mut m, &nodes, 1e8, i).unwrap();
        }
        let total = iters + iters / 2; // L1 records + L2 records
        let mut drained = 0;
        while ml.corrupt_latest().is_some() {
            drained += 1;
            if drained > total {
                return false; // must terminate exactly at the db size
            }
        }
        if drained != total {
            return false;
        }
        let l3_iter = (iters / 4) * 4;
        match ml.restart_detailed(&mut m, &nodes, None) {
            Ok(out) => {
                out.level == RestartLevel::L3 && out.iter == l3_iter && l3_iter > 0
            }
            Err(_) => l3_iter == 0,
        }
    });
}

//! Service-mode integration: open-arrival runs drain deterministically
//! and the `BENCH_serve.json` artifact is byte-stable (ISSUE 9).

use deeper::sched::{serve_fleet, ArrivalSpec, ServeConfig};
use deeper::util::json;

/// Two identical-seed runs must serialize to byte-identical JSON — the
/// acceptance property behind the committed BENCH_serve.json artifact.
/// (The `#[ignore]`d production-scale variant below runs the same check
/// at 10^5 jobs.)
#[test]
fn same_seed_serve_runs_are_byte_identical() {
    let mk = || ServeConfig {
        jobs: 800,
        arrivals: ArrivalSpec::Poisson { rate_hz: 1.0 },
        ..ServeConfig::default()
    };
    let a = serve_fleet(mk()).unwrap().to_json().to_pretty_string();
    let b = serve_fleet(mk()).unwrap().to_json().to_pretty_string();
    assert_eq!(a, b, "same seed must produce a byte-identical artifact");
    // And the seed matters: a different arrival stream changes the doc.
    let mut scfg = mk();
    scfg.fleet.seed ^= 1;
    let c = serve_fleet(scfg).unwrap().to_json().to_pretty_string();
    assert_ne!(a, c, "a different seed must change the artifact");
}

/// Production scale: 10^5 Poisson arrivals through rolling admission,
/// byte-deterministic across runs.  Ignored by default (several minutes
/// in release mode); run with `cargo test --release -- --ignored`.
#[test]
#[ignore]
fn hundred_thousand_job_serve_run_is_byte_identical() {
    let mk = || ServeConfig {
        jobs: 100_000,
        arrivals: ArrivalSpec::Poisson { rate_hz: 20.0 },
        queue_cap: 512,
        ..ServeConfig::default()
    };
    let a = serve_fleet(mk()).unwrap();
    assert_eq!(
        a.jobs_admitted + a.jobs_rejected,
        100_000,
        "every arrival is admitted or rejected"
    );
    assert_eq!(a.jobs_completed, a.jobs_admitted);
    let b = serve_fleet(mk()).unwrap();
    assert_eq!(
        a.to_json().to_pretty_string(),
        b.to_json().to_pretty_string(),
        "production-scale runs must stay byte-deterministic"
    );
}

/// The artifact round-trips through the repo's own JSON parser and
/// carries the schema the CI smoke step greps for.
#[test]
fn serve_artifact_schema_round_trips() {
    let scfg = ServeConfig {
        jobs: 40,
        arrivals: ArrivalSpec::Poisson { rate_hz: 0.1 },
        ..ServeConfig::default()
    };
    let r = serve_fleet(scfg).unwrap();
    let text = r.to_json().to_pretty_string();
    let doc = json::parse(&text).expect("artifact parses");
    assert_eq!(doc.get("bench").and_then(|j| j.as_str()), Some("serve"));
    assert_eq!(doc.get("schema_version").and_then(|j| j.as_f64()), Some(1.0));
    assert_eq!(doc.get("arrivals").and_then(|j| j.as_str()), Some("poisson"));
    assert_eq!(
        doc.get("jobs_arrived").and_then(|j| j.as_f64()),
        Some(40.0)
    );
    let classes = doc.get("classes").and_then(|j| j.as_arr()).expect("classes array");
    assert_eq!(classes.len(), 3);
    let windows = doc.get("windows").and_then(|j| j.as_arr()).expect("windows array");
    assert!(!windows.is_empty() && windows.len() <= 64);
    for w in windows {
        let p99 = w.get("p99_wait_s").and_then(|j| j.as_arr()).expect("per-class p99");
        assert_eq!(p99.len(), 3);
    }
    assert_eq!(
        doc.get("qos_grants_open").and_then(|j| j.as_f64()),
        Some(0.0),
        "a drained fleet must hold no qos grants"
    );
}

/// BENCH_serve.json at the repo root is the cross-PR trajectory record;
/// whatever regenerates it (make bench-serve / the CI bench-smoke job)
/// must keep it parseable with the pinned schema.
#[test]
fn committed_serve_artifact_parses() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_serve.json");
    let text = std::fs::read_to_string(path).expect("BENCH_serve.json exists");
    let doc = json::parse(&text).expect("artifact parses");
    assert_eq!(doc.get("bench").and_then(|j| j.as_str()), Some("serve"));
    assert_eq!(doc.get("schema_version").and_then(|j| j.as_f64()), Some(1.0));
    assert!(doc.get("classes").and_then(|j| j.as_arr()).is_some());
    assert!(doc.get("windows").and_then(|j| j.as_arr()).is_some());
}

/// A burst trace against a tiny queue bound: admission control rejects
/// the overflow, the report accounts every arrival exactly once, and the
/// rejected arrivals land in the per-class and per-window tallies.
#[test]
fn queue_cap_rejections_are_accounted_per_class_and_window() {
    let scfg = ServeConfig {
        jobs: 24,
        arrivals: ArrivalSpec::Trace { times: vec![0.0; 24] },
        queue_cap: 3,
        ..ServeConfig::default()
    };
    let r = serve_fleet(scfg).unwrap();
    assert_eq!(r.jobs_arrived, 24);
    assert!(r.jobs_rejected > 0);
    assert_eq!(r.jobs_admitted + r.jobs_rejected, 24);
    assert_eq!(r.jobs_completed, r.jobs_admitted);
    let by_class: usize = r.classes.iter().map(|c| c.rejected).sum();
    assert_eq!(by_class, r.jobs_rejected);
    let by_window: usize = r.windows.iter().map(|w| w.rejected).sum();
    assert_eq!(by_window, r.jobs_rejected);
    let arrivals_by_window: usize = r.windows.iter().map(|w| w.arrivals).sum();
    assert_eq!(arrivals_by_window, 24);
}

//! Property suite for the fleet scheduler: randomized job mixes assert
//! (a) the allocation ledger never hands one node to two jobs at once,
//! (b) conservative backfill never starts any job later than FCFS would
//! (the all-jobs form of the head-reservation guarantee, which holds
//! because every queued job carries a reservation and the compute-only
//! estimates are exact), and (c) every submitted job eventually completes
//! when failures are disabled.
//!
//! Seeds are fixed, so every "random" mix is reproducible; the runs are
//! deterministic, so a green suite stays green under repetition.

use deeper::apps::AppProfile;
use deeper::sched::policy::Policy;
use deeper::sched::{run_fleet, synthetic_jobs, CkptStrategy, FleetConfig, FleetReport, JobSpec};
use deeper::sim::rng::SplitMix64;

/// Randomized compute-only mix: zero halo, zero checkpointing, so the
/// walltime estimate the backfill reservations use is *exact* (compute
/// runs on private per-node CPUs and never contends across jobs).
fn compute_only_mix(seed: u64) -> Vec<JobSpec> {
    let mut rng = SplitMix64::new(seed ^ 0x5C4ED);
    let n = 4 + rng.next_below(5) as usize; // 4..=8 jobs
    (0..n)
        .map(|i| JobSpec {
            name: format!("p{i}"),
            profile: AppProfile {
                name: "prop-compute",
                flops_per_iter_per_node: (0.2 + rng.next_f64()) * 1e12,
                cpu_efficiency: 0.25,
                ckpt_bytes_per_node: 0.0,
                halo_bytes: 0.0,
                io_tasks_per_node: 1,
                io_records_per_task: 1,
                artifact: "xpic_step",
            },
            cluster_nodes: 1 + rng.next_below(16) as usize, // 1..=16
            booster_nodes: 0,
            iterations: 3 + rng.next_below(20) as usize,
            cp_interval: 0,
            ckpt: CkptStrategy::None,
            priority: rng.next_below(3) as u32,
            qos: None,
        })
        .collect()
}

fn run(specs: Vec<JobSpec>, policy: Policy, seed: u64, mtbf: Option<f64>) -> FleetReport {
    run_fleet(
        specs,
        FleetConfig { policy, seed, mtbf_node: mtbf, ..FleetConfig::default() },
    )
    .expect("property mixes fit the DEEP-ER prototype")
}

#[test]
fn prop_no_node_is_ever_double_allocated() {
    // Mixed apps + aggressive failure injection (many requeues churn the
    // ledger); the allocation audit trail must stay pairwise disjoint in
    // time wherever two segments share a node.
    for seed in 0..6u64 {
        for policy in Policy::ALL {
            let r = run(synthetic_jobs(5, seed), policy, seed, Some(4_000.0));
            let segs = &r.allocations;
            for i in 0..segs.len() {
                for j in (i + 1)..segs.len() {
                    let (a, b) = (&segs[i], &segs[j]);
                    if a.nodes.iter().all(|n| !b.nodes.contains(n)) {
                        continue; // disjoint node sets may overlap freely
                    }
                    // Half-open intervals [from, until): touching at the
                    // boundary (release then immediate re-dispatch) is
                    // legal, genuine overlap is oversubscription.
                    assert!(
                        a.until <= b.from || b.until <= a.from,
                        "seed {seed} {}: jobs {} and {} share a node during \
                         [{:.3},{:.3}) vs [{:.3},{:.3})",
                        policy.name(),
                        a.job,
                        b.job,
                        a.from,
                        a.until,
                        b.from,
                        b.until
                    );
                }
            }
            // Sanity: the ledger actually recorded work.
            assert!(!segs.is_empty());
        }
    }
}

#[test]
fn prop_backfill_never_delays_any_job_vs_fcfs() {
    // Conservative backfill with exact estimates dominates FCFS per job:
    // every reservation is computed in queue order against the profile of
    // all earlier jobs, so no job can start later than its FCFS slot.
    // The epsilon absorbs ulp-level drift between the estimate and the
    // simulated completion times.
    for seed in 0..8u64 {
        let specs = compute_only_mix(seed);
        let fcfs = run(specs.clone(), Policy::Fcfs, seed, None);
        let bf = run(specs, Policy::Backfill, seed, None);
        for (f, b) in fcfs.jobs.iter().zip(&bf.jobs) {
            assert_eq!(f.id, b.id);
            assert!(
                b.first_start <= f.first_start + 1e-6,
                "seed {seed}: backfill delayed job {} ({} vs fcfs {})",
                f.name,
                b.first_start,
                f.first_start
            );
        }
        // And the fleet as a whole can only get tighter.
        assert!(bf.makespan <= fcfs.makespan + 1e-6, "seed {seed}");
        assert!(bf.avg_wait <= fcfs.avg_wait + 1e-6, "seed {seed}");
    }
}

#[test]
fn prop_every_job_completes_without_failures() {
    for seed in 0..6u64 {
        for policy in Policy::ALL {
            let r = run(synthetic_jobs(6, seed), policy, seed, None);
            assert_eq!(r.finish_order.len(), r.jobs.len(), "seed {seed}");
            assert_eq!(r.failures_injected, 0);
            for j in &r.jobs {
                assert_eq!(
                    j.stats.iterations_run, j.iterations,
                    "seed {seed} {}: job {} ran {} of {} iterations",
                    policy.name(),
                    j.name,
                    j.stats.iterations_run,
                    j.iterations
                );
                assert_eq!(j.requeues, 0);
                assert_eq!(j.stats.failures_hit, 0);
                assert!(j.finished_at > 0.0);
            }
            // Utilization is a genuine fraction of the machine.
            assert!(r.utilization > 0.0 && r.utilization <= 1.0, "seed {seed}");
        }
    }
}

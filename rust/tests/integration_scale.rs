//! The `repro bench scale` harness at a small sweep: schema, equivalence
//! and determinism checks that run everywhere (the full 1k/10k/100k sweep
//! with its >= 5x events/sec acceptance bar is a release-binary
//! measurement — `make bench-scale` — not a unit-test assertion, because
//! wall-clock ratios are machine- and profile-dependent).

use deeper::bench::{scale_points, scale_report, ScaleConfig};
use deeper::util::json::{self, Json};

fn small_cfg() -> ScaleConfig {
    ScaleConfig {
        sweep: vec![64, 256],
        seed: 1,
        baseline_max: 256,
        topology: None,
        threads: vec![1, 2],
    }
}

#[test]
fn scale_report_exhibits_and_schema() {
    let (exhibits, json) = scale_report(&small_cfg());
    assert_eq!(exhibits.len(), 3, "events/sec figure, wall figure, summary table");
    for e in &exhibits {
        assert!(!e.render().is_empty());
        assert!(!e.render_csv().is_empty());
    }

    // The JSON must round-trip through our own parser and carry the
    // schema the CI artifact consumers rely on.
    let parsed = json::parse(&json.to_pretty_string()).expect("pretty JSON parses");
    assert_eq!(parsed, json);
    assert_eq!(json.get("bench").and_then(Json::as_str), Some("sim_scale"));
    assert_eq!(json.get("schema_version").and_then(Json::as_f64), Some(2.0));
    assert_eq!(json.get("seed").and_then(Json::as_f64), Some(1.0));
    // No --topology: the synthetic flat workload, recorded as null.
    assert_eq!(json.get("topology"), Some(&Json::Null));
    // Schema v2: the top-level threads axis mirrors the config.
    let threads = json.get("threads").and_then(Json::as_arr).expect("threads axis");
    assert_eq!(
        threads.iter().map(|t| t.as_f64().unwrap()).collect::<Vec<_>>(),
        vec![1.0, 2.0]
    );
    let points = json.get("points").and_then(Json::as_arr).expect("points array");
    assert_eq!(points.len(), 2);
    for p in points {
        let flows = p.get("flows").and_then(Json::as_f64).unwrap();
        assert!(flows == 64.0 || flows == 256.0);
        let engine = p.get("engine").expect("engine measurement");
        assert!(engine.get("events").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(engine.get("events_per_sec").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(engine.get("wall_s").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(engine.get("last_finish_virtual_s").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(p.get("peak_component_flows").and_then(Json::as_f64).unwrap() >= 1.0);
        // Schema v2: one run per thread count, each with per-worker event
        // counters summing to that run's event total; virtual completion
        // identical across counts (scale_points gates this at 1e-9, the
        // artifact lets trajectory tooling re-check it exactly).
        let runs = p.get("runs").and_then(Json::as_arr).expect("runs array");
        assert_eq!(runs.len(), 2);
        for (r, want_t) in runs.iter().zip([1.0, 2.0]) {
            assert_eq!(r.get("threads").and_then(Json::as_f64), Some(want_t));
            let events = r.get("events").and_then(Json::as_f64).unwrap();
            assert!(events > 0.0);
            let workers = r.get("worker_events").and_then(Json::as_arr).unwrap();
            assert_eq!(workers.len(), want_t as usize);
            let sum: f64 = workers.iter().map(|w| w.as_f64().unwrap()).sum();
            assert_eq!(sum, events, "worker counters must sum to the run's events");
        }
        assert_eq!(
            runs[0].get("last_finish_virtual_s").and_then(Json::as_f64),
            runs[1].get("last_finish_virtual_s").and_then(Json::as_f64),
        );
        // The v1 anchor keys survive: `engine` is runs[0]'s measurement.
        assert_eq!(
            engine.get("events").and_then(Json::as_f64),
            runs[0].get("events").and_then(Json::as_f64),
        );
        // Both sweep points sit inside baseline_max: the naive engine ran
        // and the speedup ratio is recorded (its magnitude is the
        // release-bench's business, not this test's).
        assert!(p.get("baseline").unwrap().get("events").is_some());
        assert!(p.get("speedup_events_per_sec").and_then(Json::as_f64).unwrap() > 0.0);
    }
    assert!(json
        .get("speedup_at_largest_baselined_point")
        .and_then(Json::as_f64)
        .is_some());
    assert_eq!(
        json.get("largest_baselined_flows").and_then(Json::as_f64),
        Some(256.0)
    );
}

#[test]
fn scale_points_are_deterministic_in_virtual_terms() {
    // Wall-clock varies run to run; the simulated trajectory must not.
    let a = scale_points(&small_cfg());
    let b = scale_points(&small_cfg());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.flows, y.flows);
        assert_eq!(x.engine.events, y.engine.events);
        assert_eq!(x.engine.last_finish, y.engine.last_finish);
        assert_eq!(x.peak_component, y.peak_component);
        let (bx, by) = (x.baseline.as_ref().unwrap(), y.baseline.as_ref().unwrap());
        assert_eq!(bx.events, by.events);
        assert_eq!(bx.last_finish, by.last_finish);
    }
    // scale_points itself asserts optimized-vs-naive equivalence on every
    // baselined point; reaching here means both sweeps passed it.
}

#[test]
fn scale_workload_keeps_components_bounded() {
    // The DEEP-ER-shaped workload is mostly node-local: the peak refill
    // component must stay well below the total flow count (that locality
    // is the whole point of component scoping).
    let pts = scale_points(&ScaleConfig {
        sweep: vec![512],
        seed: 1,
        baseline_max: 0,
        topology: None,
        threads: vec![1],
    });
    assert_eq!(pts.len(), 1);
    assert!(pts[0].baseline.is_none(), "512 > baseline_max 0: naive engine skipped");
    let peak = pts[0].peak_component;
    assert!(
        peak < 512 / 2,
        "peak component {peak} should be far below the 512 concurrent flows"
    );
    assert!(peak >= 1);
}

#[test]
fn committed_trajectory_artifact_parses() {
    // BENCH_sim_scale.json at the repo root is the cross-PR perf
    // trajectory record; whatever regenerates it (make bench-scale / the
    // CI bench-smoke job) must keep it parseable with the pinned schema.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_sim_scale.json");
    let text = std::fs::read_to_string(path).expect("BENCH_sim_scale.json exists");
    let doc = json::parse(&text).expect("artifact parses");
    assert_eq!(doc.get("bench").and_then(Json::as_str), Some("sim_scale"));
    assert_eq!(doc.get("schema_version").and_then(Json::as_f64), Some(2.0));
    assert!(doc.get("threads").and_then(Json::as_arr).is_some());
    assert!(doc.get("points").and_then(Json::as_arr).is_some());
}

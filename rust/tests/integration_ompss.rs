//! Integration: OmpSs task runtime + ParaStation offload under failures.

use deeper::apps::fwi;
use deeper::ompss::{OmpssRuntime, Resilience, Task, TaskGraph};
use deeper::psmpi::{comm_spawn, Comm};
use deeper::system::failure::FailurePlan;
use deeper::system::{presets, Machine, NodeKind};

fn mn3() -> Machine {
    Machine::build(presets::marenostrum3())
}

#[test]
fn offload_cluster_to_booster_runs() {
    // The DEEP-ER headline pattern: master on the Cluster spawns the
    // task group on the Booster (MPI_Comm_spawn across the divide).
    let mut m = Machine::build(presets::deep_er());
    let boosters = m.nodes_of(NodeKind::Booster);
    let g = comm_spawn(&mut m, boosters.clone());
    assert_eq!(g.comm.size(), 8);
    let rt = OmpssRuntime::new(0, Resilience::ResilientOffload);
    let graph = fwi::task_graph(2, 4, 1e11);
    let out = rt.execute(&mut m, &graph, &boosters, &FailurePlan::none());
    assert_eq!(out.tasks_run, graph.tasks.len());
    assert_eq!(out.app_restarts, 0);
}

#[test]
fn all_resilience_modes_complete_under_failure() {
    let graph = fwi::task_graph(3, 3, 1e11);
    let fail = FailurePlan::one_at_iteration(0, fwi::last_task(&graph));
    for res in [
        Resilience::None,
        Resilience::Lightweight,
        Resilience::Persistent,
        Resilience::ResilientOffload,
    ] {
        let mut m = mn3();
        let out = OmpssRuntime::new(0, res).execute(&mut m, &graph, &[1, 2, 3], &fail);
        assert!(out.time > 0.0, "{res:?}");
        if res == Resilience::None {
            assert_eq!(out.app_restarts, 1, "{res:?}");
            assert!(out.tasks_run > graph.tasks.len(), "{res:?}");
        } else {
            assert_eq!(out.app_restarts, 0, "{res:?}");
            assert_eq!(out.tasks_run, graph.tasks.len() + 1, "{res:?}");
        }
    }
}

#[test]
fn resilience_cost_ordering() {
    // Persistent writes inputs to storage -> more protection overhead than
    // the in-memory lightweight mode on a clean run.
    let graph = fwi::task_graph(3, 4, 1e11);
    let run = |res: Resilience| {
        let mut m = mn3();
        OmpssRuntime::new(0, res)
            .execute(&mut m, &graph, &[1, 2], &FailurePlan::none())
            .protection_overhead
    };
    let none = run(Resilience::None);
    let light = run(Resilience::Lightweight);
    let persist = run(Resilience::Persistent);
    assert_eq!(none, 0.0);
    assert!(light > 0.0);
    assert!(persist > light, "persist {persist} !> light {light}");
}

#[test]
fn early_failure_cheaper_to_recover_than_late_without_resiliency() {
    let graph = fwi::task_graph(5, 2, 1e11);
    let run = |at: usize| {
        let mut m = mn3();
        OmpssRuntime::new(0, Resilience::None)
            .execute(&mut m, &graph, &[1, 2], &FailurePlan::one_at_iteration(0, at))
            .time
    };
    let early = run(0);
    let late = run(fwi::last_task(&graph));
    assert!(late > early, "late {late} !> early {early}");
}

#[test]
fn wave_scheduling_parallelizes_independent_tasks() {
    // 8 equal independent tasks on 4 workers should take ~2 task times,
    // not 8.
    let mk_graph = |n: usize| {
        let mut g = TaskGraph::new();
        for i in 0..n {
            g.add(Task {
                name: format!("t{i}"),
                flops: 5e11,
                input_bytes: 1e6,
                output_bytes: 1e6,
                deps: vec![],
            });
        }
        g
    };
    let mut m1 = mn3();
    let rt = OmpssRuntime::new(0, Resilience::None);
    let t1 = rt.execute(&mut m1, &mk_graph(1), &[1, 2, 3, 4], &FailurePlan::none()).time;
    let mut m8 = mn3();
    let t8 = rt.execute(&mut m8, &mk_graph(8), &[1, 2, 3, 4], &FailurePlan::none()).time;
    assert!(t8 < 3.0 * t1, "t1={t1} t8={t8}");
    assert!(t8 > 1.5 * t1, "t1={t1} t8={t8}");
}

#[test]
fn dependency_chain_serializes() {
    let mut g = TaskGraph::new();
    let a = g.add(Task { name: "a".into(), flops: 2e11, input_bytes: 1e6, output_bytes: 1e6, deps: vec![] });
    let b = g.add(Task { name: "b".into(), flops: 2e11, input_bytes: 1e6, output_bytes: 1e6, deps: vec![a] });
    let _c = g.add(Task { name: "c".into(), flops: 2e11, input_bytes: 1e6, output_bytes: 1e6, deps: vec![b] });
    assert_eq!(g.waves().len(), 3);
    let mut m = mn3();
    let rt = OmpssRuntime::new(0, Resilience::None);
    let out = rt.execute(&mut m, &g, &[1, 2, 3], &FailurePlan::none());
    assert_eq!(out.tasks_run, 3);
}

#[test]
fn pmd_heartbeat_cost_visible_in_recovery() {
    let graph = fwi::task_graph(1, 2, 1e11);
    let fail = FailurePlan::one_at_iteration(0, 0);
    let mut m1 = mn3();
    let rt = OmpssRuntime::new(0, Resilience::ResilientOffload);
    let t_fail = rt.execute(&mut m1, &graph, &[1, 2], &fail).time;
    let mut m2 = mn3();
    let t_clean = rt.execute(&mut m2, &graph, &[1, 2], &FailurePlan::none()).time;
    // Recovery includes detection (heartbeat/2 + cleanup) + respawn + rerun.
    assert!(t_fail > t_clean + deeper::psmpi::PMD_CLEANUP);
}

#[test]
fn collectives_compose_with_offload() {
    // Smoke: a gather over the spawned group after execution.
    let mut m = Machine::build(presets::deep_er());
    let boosters = m.nodes_of(NodeKind::Booster);
    let g = comm_spawn(&mut m, boosters);
    let t0 = m.sim.now();
    let t = Comm::of(g.comm.nodes.clone()).gather(&mut m, 0, 10e6) - t0;
    assert!(t > 0.0 && t < 1.0);
}

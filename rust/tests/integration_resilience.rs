//! Integration: proactive resilience end to end — the ISSUE 8 acceptance
//! criterion (under the same seeded correlated fault schedule, proactive
//! wastes strictly less work than reactive), byte-determinism of the
//! `repro bench resilience` document, its schema contract, and the
//! no-fault invariant (no fault plan -> no resilience block in the JSON).

use deeper::bench::{resilience_points, resilience_report, ResilienceBenchConfig};
use deeper::sched::{run_fleet, synthetic_jobs, FleetConfig, ResiliencePolicy};
use deeper::system::faults::FaultPlan;
use deeper::util::json::{self, Json};

#[test]
fn proactive_wastes_strictly_less_work_than_reactive() {
    // The acceptance scenario: the default bench config (8 jobs, 6
    // correlated faults sized to the healthy makespan) under both
    // policies, sharing one fault schedule.
    let cfg = ResilienceBenchConfig::default();
    let (probe_makespan, horizon, points) = resilience_points(&cfg);
    assert!(probe_makespan > 0.0 && horizon > 0.0 && horizon < probe_makespan);
    assert_eq!(points.len(), 2);

    let by = |policy: ResiliencePolicy| {
        points
            .iter()
            .find(|p| p.policy == policy)
            .expect("both policies ran")
    };
    let reactive = by(ResiliencePolicy::Reactive);
    let proactive = by(ResiliencePolicy::Proactive);

    let rs_reactive = reactive.report.resilience.as_ref().expect("fault plan active");
    let rs_proactive = proactive.report.resilience.as_ref().expect("fault plan active");

    // The schedule genuinely degraded the machine in both runs — same
    // plan, so the same precursor mix.
    for rs in [rs_reactive, rs_proactive] {
        assert!(
            rs.link_degrades + rs.stragglers + rs.corruptions > 0,
            "correlated schedule must apply precursors inside the run"
        );
    }
    assert_eq!(rs_reactive.link_degrades, rs_proactive.link_degrades);
    assert_eq!(rs_reactive.stragglers, rs_proactive.stragglers);
    assert!(
        reactive.report.failures_injected + reactive.report.idle_failures > 0,
        "paired kills must fire"
    );

    // Reactive never migrates; proactive acts on suspicion.
    assert_eq!(rs_reactive.migrations, 0);
    assert!(rs_proactive.migrations > 0, "precursors must trigger migration");
    assert!(rs_proactive.suspects > 0);

    // ISSUE 8 acceptance: strictly less wasted work when acting on
    // precursors instead of waiting for the kill.
    assert!(
        rs_proactive.wasted_iterations < rs_reactive.wasted_iterations,
        "proactive ({}) must waste strictly fewer iterations than reactive ({})",
        rs_proactive.wasted_iterations,
        rs_reactive.wasted_iterations
    );
}

#[test]
fn bench_resilience_is_byte_deterministic() {
    let cfg = ResilienceBenchConfig { jobs: 4, faults: 3, seed: 11, topology: None };
    let (_, a) = resilience_report(&cfg);
    let (_, b) = resilience_report(&cfg);
    assert_eq!(a.to_pretty_string(), b.to_pretty_string());

    // The seed genuinely steers the schedule.
    let (_, c) = resilience_report(&ResilienceBenchConfig { seed: 12, ..cfg });
    assert_ne!(a.to_pretty_string(), c.to_pretty_string());
}

#[test]
fn bench_resilience_exhibits_and_schema() {
    let cfg = ResilienceBenchConfig { jobs: 4, faults: 3, seed: 5, topology: None };
    let (exhibits, doc) = resilience_report(&cfg);
    assert_eq!(exhibits.len(), 1, "one reactive-vs-proactive summary table");
    for e in &exhibits {
        assert!(!e.render().is_empty());
        assert!(!e.render_csv().is_empty());
    }

    let parsed = json::parse(&doc.to_pretty_string()).expect("resilience JSON parses");
    assert_eq!(parsed, doc);
    assert_eq!(doc.get("bench").and_then(Json::as_str), Some("resilience"));
    assert_eq!(doc.get("schema_version").and_then(Json::as_f64), Some(1.0));
    assert_eq!(doc.get("jobs").and_then(Json::as_f64), Some(4.0));
    assert_eq!(doc.get("faults").and_then(Json::as_f64), Some(3.0));
    assert!(doc.get("healthy_makespan_s").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(doc.get("fault_horizon_s").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(
        doc.get("proactive_wasted_iteration_saving")
            .and_then(Json::as_f64)
            .is_some(),
        "headline must be present when both policies ran"
    );

    let points = doc.get("points").and_then(Json::as_arr).expect("points array");
    assert_eq!(points.len(), 2, "reactive + proactive");
    for p in points {
        let policy = p.get("policy").and_then(Json::as_str).unwrap();
        assert!(policy == "reactive" || policy == "proactive");
        assert!(p.get("makespan_s").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(p.get("utilization").and_then(Json::as_f64).unwrap() > 0.0);
        for key in [
            "wasted_iterations",
            "migrations",
            "requeues",
            "failures_injected",
            "idle_failures",
            "suspects",
            "link_degrades",
            "stragglers",
            "corruptions",
            "sim_events",
        ] {
            assert!(
                p.get(key).and_then(Json::as_f64).is_some(),
                "point key {key} must be a number"
            );
        }
    }
}

#[test]
fn no_fault_plan_means_no_resilience_block() {
    // The bit-identity guard: without a fault plan the report carries no
    // resilience summary and the JSON document has no "resilience" key —
    // the schema of healthy runs is unchanged by this subsystem.
    let jobs = synthetic_jobs(3, 7);
    let r = run_fleet(jobs, FleetConfig { seed: 7, ..FleetConfig::default() })
        .expect("synthetic fleet fits the DEEP-ER prototype");
    assert!(r.resilience.is_none());
    assert!(r.to_json().get("resilience").is_none());

    // And with one, the block appears (policy defaults to reactive).
    let plan = FaultPlan::correlated(16, 2, r.makespan * 0.8, 7);
    let jobs = synthetic_jobs(3, 7);
    let r2 = run_fleet(
        jobs,
        FleetConfig { seed: 7, fault_plan: Some(plan), ..FleetConfig::default() },
    )
    .expect("synthetic fleet fits the DEEP-ER prototype");
    let rs = r2.resilience.as_ref().expect("fault plan was active");
    assert_eq!(rs.policy, "reactive");
    assert!(r2.to_json().get("resilience").is_some());
}

//! Integration: the figure harnesses reproduce the paper's shape targets
//! (DESIGN.md section 4 — who wins, by roughly what factor, where the
//! crossovers fall).

use deeper::bench::{self, Exhibit};
use deeper::metrics::Figure;

fn fig(exhibits: &[Exhibit], idx: usize) -> &Figure {
    match &exhibits[idx] {
        Exhibit::Fig(f) => f,
        Exhibit::Table(_) => panic!("exhibit {idx} is a table"),
    }
}

#[test]
fn fig3_nam_rma_close_to_raw_extoll() {
    let ex = bench::fig3();
    let bw = fig(&ex, 0);
    let raw = bw.series_named("EXTOLL best").unwrap();
    let put = bw.series_named("NAM put").unwrap();
    let get = bw.series_named("NAM get").unwrap();
    // Large-message bandwidth: NAM within 10% of raw fabric (paper: "very
    // close to the best achievable values on the network alone").
    let raw_peak = raw.last_y().unwrap();
    assert!(put.last_y().unwrap() > 0.90 * raw_peak);
    assert!(get.last_y().unwrap() > 0.88 * raw_peak);
    // Latency floor: a few microseconds, get > put.
    let lat = fig(&ex, 1);
    let l_put = lat.series_named("NAM put").unwrap().points[0].1;
    let l_get = lat.series_named("NAM get").unwrap().points[0].1;
    assert!(l_put > 1.0 && l_put < 15.0, "put lat {l_put} us");
    assert!(l_get > l_put, "get {l_get} <= put {l_put}");
}

#[test]
fn fig4_strategy_ordering_holds_at_every_node_count() {
    let ex = bench::fig4();
    let f = fig(&ex, 0);
    let series = |n: &str| f.series_named(n).unwrap();
    for &(x, _) in &series("Single").points.clone() {
        let single = series("Single").y_at(x).unwrap();
        let partner = series("SCR_PARTNER").y_at(x).unwrap();
        let buddy = series("Buddy").y_at(x).unwrap();
        let dist = series("Distributed XOR").y_at(x).unwrap();
        let nam = series("NAM XOR").y_at(x).unwrap();
        // Paper Fig. 4: Buddy beats SCR_PARTNER; NAM XOR beats Distributed
        // XOR; Single is the cheapest (it provides the least protection).
        assert!(buddy < partner, "n={x}: buddy {buddy} !< partner {partner}");
        assert!(nam < dist, "n={x}: nam {nam} !< dist {dist}");
        assert!(single <= buddy + 1e-9, "n={x}: single not cheapest");
        // Weak scaling: node-local strategies stay roughly flat (within
        // 50% of their 2-node cost).
        let base = series("Single").points[0].1;
        assert!((single - base).abs() / base < 0.5, "Single not flat");
    }
}

#[test]
fn fig5_sionlib_speedups_in_band() {
    let ex = bench::fig5();
    let sp = fig(&ex, 1);
    let p1 = sp.series_named("speedup P1").unwrap();
    let p3 = sp.series_named("speedup P3").unwrap();
    // Paper: up to 7.4x for P1, up to 3.7x for P3; P1 > P3 throughout and
    // the gain grows with node count.
    let p1_max = p1.points.iter().map(|&(_, y)| y).fold(0.0, f64::max);
    let p3_max = p3.points.iter().map(|&(_, y)| y).fold(0.0, f64::max);
    assert!(p1_max > 4.0 && p1_max < 12.0, "P1 max speedup {p1_max}");
    assert!(p3_max > 2.5 && p3_max < 7.0, "P3 max speedup {p3_max}");
    for (a, b) in p1.points.iter().zip(&p3.points) {
        assert!(a.1 > b.1, "P1 {} !> P3 {} at n={}", a.1, b.1, a.0);
    }
    assert!(p1.points.last().unwrap().1 > p1.points[0].1, "P1 gain must grow");
}

#[test]
fn fig6_local_flat_global_saturates() {
    let ex = bench::fig6();
    let f = fig(&ex, 0);
    let global = f.series_named("global BeeGFS").unwrap();
    let local = f.series_named("BeeOND local").unwrap();
    // Local: constant per-node bandwidth — write time flat in node count.
    let l0 = local.points[0].1;
    for &(_, y) in &local.points {
        assert!((y - l0).abs() / l0 < 0.05, "local not flat: {y} vs {l0}");
    }
    // Global: saturated backend — time grows ~linearly at scale.
    let g_first = global.y_at(16.0).unwrap();
    let g_last = global.y_at(672.0).unwrap();
    assert!(g_last > 20.0 * g_first, "global does not saturate");
    // Paper: local storage makes the write phase >> faster at full scale.
    assert!(g_last / local.y_at(672.0).unwrap() > 50.0);
}

#[test]
fn fig7_nvme_vs_hdd_factor() {
    let ex = bench::fig7();
    let f = fig(&ex, 0);
    let nvme = f.series_named("NVMe").unwrap();
    let hdd = f.series_named("HDD").unwrap();
    for (a, b) in nvme.points.iter().zip(&hdd.points) {
        let ratio = b.1 / a.1;
        // Paper: writing to NVMe up to 4.5x faster than node-local HDD.
        assert!(ratio > 3.0 && ratio < 20.0, "n={}: ratio {ratio}", a.0);
    }
}

#[test]
fn fig8_overhead_and_saving_bands() {
    let ex = bench::fig8();
    let table = match &ex[0] {
        Exhibit::Table(t) => t,
        _ => panic!(),
    };
    let get = |k: &str| -> f64 {
        table
            .rows
            .iter()
            .find(|(key, _)| key == k)
            .map(|(_, v)| v.trim_end_matches([' ', '%', 's']).trim().parse().unwrap())
            .unwrap()
    };
    // Paper: ~8% average overhead; ~23% saving for the error-at-60 case.
    let overhead = get("CP overhead");
    let saving = get("saving on failure");
    assert!((3.0..=15.0).contains(&overhead), "overhead {overhead}%");
    assert!((15.0..=40.0).contains(&saving), "saving {saving}%");
}

#[test]
fn fig9_nam_xor_bands() {
    let ex = bench::fig9();
    let bw = fig(&ex, 0);
    let time = fig(&ex, 1);
    let dist_bw = bw.series_named("Distributed XOR").unwrap();
    let nam_bw = bw.series_named("NAM XOR").unwrap();
    for (d, n) in dist_bw.points.iter().zip(&nam_bw.points) {
        let ratio = n.1 / d.1;
        // Paper: up to 3x higher bandwidth.
        assert!((1.5..=3.5).contains(&ratio), "bw ratio {ratio} at n={}", d.0);
    }
    let dist_t = time.series_named("Distributed XOR").unwrap();
    let nam_t = time.series_named("NAM XOR").unwrap();
    for (d, n) in dist_t.points.iter().zip(&nam_t.points) {
        let saving = 1.0 - n.1 / d.1;
        // Paper: between 50% and 65% of write time saved.
        assert!((0.40..=0.70).contains(&saving), "saving {saving} at n={}", d.0);
    }
}

#[test]
fn fig10_ompss_bands() {
    let ex = bench::fig10();
    let table = match &ex[0] {
        Exhibit::Table(t) => t,
        _ => panic!(),
    };
    let get = |k: &str| -> f64 {
        table
            .rows
            .iter()
            .find(|(key, _)| key == k)
            .map(|(_, v)| {
                v.trim_start_matches('+')
                    .trim_end_matches([' ', '%', 's'])
                    .trim()
                    .parse()
                    .unwrap()
            })
            .unwrap()
    };
    // Paper: error near the end ~doubles the unprotected runtime; the
    // OmpSs feature saves ~42% with <1% overhead and ~+15% vs clean.
    let t_clean = get("w/o CP, w/o error");
    let t_err = get("w/o CP, error at end");
    assert!((1.7..=2.2).contains(&(t_err / t_clean)), "{}", t_err / t_clean);
    let overhead = get("resiliency overhead");
    assert!(overhead < 1.0, "overhead {overhead}% (paper <1%)");
    let saving = get("saving on failure");
    assert!((30.0..=55.0).contains(&saving), "saving {saving}%");
    let vs_clean = get("vs clean run");
    assert!(vs_clean < 25.0, "vs clean {vs_clean}% (paper ~15%)");
}

#[test]
fn cb_split_beats_homogeneous() {
    let ex = bench::cb_split();
    let table = match &ex[0] {
        Exhibit::Table(t) => t,
        _ => panic!(),
    };
    let speedup: f64 = table
        .rows
        .iter()
        .find(|(k, _)| k.contains("speedup"))
        .map(|(_, v)| v.trim_end_matches('x').parse().unwrap())
        .unwrap();
    // Companion paper [4]: the split must beat the best homogeneous
    // placement by a clear margin on the prototype shape.
    assert!(speedup > 1.2 && speedup < 3.0, "split speedup {speedup}");
}

#[test]
fn all_exhibits_render_nonempty() {
    for (name, exhibits) in bench::all(bench::DEFAULT_SEED) {
        assert!(!exhibits.is_empty(), "{name} empty");
        for e in &exhibits {
            let text = e.render();
            assert!(text.len() > 40, "{name} render too short:\n{text}");
        }
    }
}

//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Deliberately criterion-shaped: warmup, calibrated iteration counts,
//! mean / stddev / min over sample batches, and a `black_box` to defeat
//! constant folding.  Used by the `cargo bench` targets in rust/benches/.

use std::time::{Duration, Instant};

/// Opaque value barrier (defeats constant folding), re-exported so bench
/// targets don't need `std::hint` directly.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One benchmark's statistics over sample batches.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    /// Mean time per iteration across samples.
    pub mean: Duration,
    /// Population standard deviation of per-iteration time.
    pub stddev: Duration,
    /// Fastest sample's per-iteration time.
    pub min: Duration,
    /// Slowest sample's per-iteration time.
    pub max: Duration,
    /// Iterations executed per timed sample (set by calibration).
    pub iters_per_sample: u64,
    /// Number of timed samples taken.
    pub samples: usize,
}

impl Stats {
    /// Mean time per iteration in seconds.
    pub fn mean_s(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Benchmark runner with fixed warmup/measure budgets.
#[derive(Debug, Clone)]
pub struct Bench {
    /// Warmup + calibration budget before any timing.
    pub warmup: Duration,
    /// Total measurement budget, split across `samples`.
    pub measure: Duration,
    /// Number of timed samples to take.
    pub samples: usize,
    group: String,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(1),
            samples: 10,
            group: String::new(),
        }
    }
}

impl Bench {
    pub fn new(group: impl Into<String>) -> Self {
        Self { group: group.into(), ..Self::default() }
    }

    /// Quick preset for heavier end-to-end cases.
    pub fn quick(group: impl Into<String>) -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            samples: 5,
            group: group.into(),
        }
    }

    /// Run `f` repeatedly, print a criterion-style line, return stats.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Stats {
        // Warmup + calibration: how many iters fit in one sample?
        let w0 = Instant::now();
        let mut warm_iters: u64 = 0;
        while w0.elapsed() < self.warmup {
            f();
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        let sample_budget = self.measure.as_secs_f64() / self.samples as f64;
        let iters = ((sample_budget / per_iter).ceil() as u64).max(1);

        let mut times: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            times.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
            / times.len() as f64;
        let stats = Stats {
            mean: Duration::from_secs_f64(mean),
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: Duration::from_secs_f64(times.iter().copied().fold(f64::INFINITY, f64::min)),
            max: Duration::from_secs_f64(times.iter().copied().fold(0.0, f64::max)),
            iters_per_sample: iters,
            samples: self.samples,
        };
        println!(
            "{:<40} time: [{} {} {}]  ({} iters x {} samples)",
            format!("{}/{}", self.group, name),
            fmt_dur(stats.min),
            fmt_dur(stats.mean),
            fmt_dur(stats.max),
            iters,
            self.samples,
        );
        stats
    }

    /// Run and also report a derived throughput (elements per second).
    pub fn run_throughput<F: FnMut()>(&self, name: &str, elems: f64, f: F) -> Stats {
        let stats = self.run(name, f);
        let eps = elems / stats.mean_s();
        println!("{:<40} thrpt: {:.3e} elem/s", format!("{}/{}", self.group, name), eps);
        stats
    }
}

/// Time a **single** invocation of `f`, returning its result and the
/// elapsed wall-clock time.  For workloads too heavy to sample repeatedly
/// (the 100k-flow point of `repro bench scale` runs once, not in a
/// calibrated warmup/sample loop).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let v = black_box(f());
    (v, t0.elapsed())
}

fn fmt_dur(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench { warmup: Duration::from_millis(5), measure: Duration::from_millis(20), samples: 3, group: "t".into() };
        let stats = b.run("sum-1k", || {
            // Heavy enough that one iteration is always measurable.
            let s: u64 = black_box((0..1000u64).fold(0, |a, x| a ^ x.wrapping_mul(31)));
            black_box(s);
        });
        assert!(stats.mean > Duration::ZERO);
        assert!(stats.iters_per_sample >= 1);
        assert!(stats.min <= stats.mean && stats.mean <= stats.max + Duration::from_nanos(1));
    }

    #[test]
    fn time_once_returns_value_and_duration() {
        let (v, d) = time_once(|| (0..10_000u64).map(|x| x.wrapping_mul(7)).sum::<u64>());
        assert_eq!(v, (0..10_000u64).map(|x| x.wrapping_mul(7)).sum::<u64>());
        assert!(d > Duration::ZERO);
    }

    #[test]
    fn throughput_runs() {
        let b = Bench { warmup: Duration::from_millis(2), measure: Duration::from_millis(10), samples: 2, group: "t".into() };
        let stats = b.run_throughput("sum", 1000.0, || {
            let s: u64 = black_box((0..1000u64).sum());
            black_box(s);
        });
        assert!(stats.samples == 2);
    }
}

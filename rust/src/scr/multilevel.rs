//! Multi-level checkpointing — the full SCR discipline the paper builds on.
//!
//! Moody et al.'s SCR (the paper's [14]) is a *multi-level* checkpoint
//! system: cheap, frequent checkpoints at low levels (node-local) and
//! expensive, rare ones at high levels (partner/XOR, then the parallel
//! file system).  DEEP-ER's contribution slots new mechanisms into those
//! levels — BeeOND caching at L1, Buddy/NAM-XOR at L2, asynchronous
//! global flush at L3 — which is exactly how this module composes them:
//!
//! * **L1** `Single`: node-local NVMe, survives process restarts.
//! * **L2** any of `Buddy` / `Partner` / `DistXor` / `NamXor`: survives
//!   single-node loss.
//! * **L3** global: BeeOND-async flush of the L2 checkpoint to BeeGFS,
//!   survives rack-level faults (and job retirement).
//!
//! With `async_flush` enabled the L1→L2 promotion itself becomes a
//! **background state machine** ([`FlushState`]): the L2 checkpoint is
//! *issued* at its cadence point but settles while the application
//! computes — the checkpoint/compute overlap pattern of Hukerikar &
//! Engelmann (2017) that the paper's deferred Buddy copy and NAM
//! offload exist to enable.  A node loss that lands mid-flight falls
//! back to the deepest **settled** level: an in-flight promotion is
//! never committed to the database, so restart logic cannot pick it.
//!
//! Level frequencies come from the generalized Young/Daly optimum
//! ([`optimal_interval`]): interval_k = sqrt(2 * cost_k * MTBF_k).

use super::{CkptRecord, PendingCkpt, Scr, Strategy};
use crate::beegfs::BeeGfs;
use crate::sim::{OpSet, SimTime, TrafficClass};
use crate::system::Machine;

/// Young's approximation of the optimal checkpoint interval:
/// `sqrt(2 * C * M)` for checkpoint cost `C` and failure MTBF `M`
/// (both in seconds).  Within a few percent of Daly's higher-order
/// formula whenever C << M, which holds for every DEEP-ER level.
pub fn optimal_interval(ckpt_cost: SimTime, mtbf: SimTime) -> SimTime {
    assert!(ckpt_cost > 0.0 && mtbf > 0.0);
    (2.0 * ckpt_cost * mtbf).sqrt()
}

/// Expected wasted time per failure with interval `tau` (half the
/// interval re-computed + restart cost) — the quantity `optimal_interval`
/// balances against checkpoint overhead.
pub fn expected_waste(tau: SimTime, ckpt_cost: SimTime, restart_cost: SimTime, mtbf: SimTime) -> f64 {
    // Overhead fraction: C/tau of useful time + per-failure loss.
    ckpt_cost / tau + (tau / 2.0 + restart_cost) / mtbf
}

/// Configuration of the three levels.
#[derive(Debug, Clone)]
pub struct MultiLevelConfig {
    /// Take an L1 (local) checkpoint every `l1_every` iterations.
    pub l1_every: usize,
    /// Promote to L2 (partner/XOR) every `l2_every` L1 checkpoints.
    pub l2_every: usize,
    /// Flush to the global FS every `l3_every` L2 checkpoints.
    pub l3_every: usize,
    /// Which strategy implements L2.
    pub l2_strategy: Strategy,
    /// Run the L1→L2 promotion as a background flush ([`FlushState`])
    /// instead of blocking the application on it.
    pub async_flush: bool,
}

impl Default for MultiLevelConfig {
    fn default() -> Self {
        Self {
            l1_every: 1,
            l2_every: 5,
            l3_every: 4,
            l2_strategy: Strategy::Buddy,
            async_flush: false,
        }
    }
}

impl MultiLevelConfig {
    /// Derive level frequencies from failure statistics, Young-style:
    /// each level's interval covers the failure class it protects
    /// against.  `iter_time` converts seconds to iteration counts.
    pub fn from_failure_model(
        iter_time: SimTime,
        l1_cost: SimTime,
        l2_cost: SimTime,
        l3_cost: SimTime,
        mtbf_process: SimTime,
        mtbf_node: SimTime,
        mtbf_system: SimTime,
    ) -> Self {
        let to_iters = |tau: SimTime| ((tau / iter_time).round() as usize).max(1);
        let l1 = to_iters(optimal_interval(l1_cost, mtbf_process));
        let l2 = to_iters(optimal_interval(l2_cost, mtbf_node)).max(l1);
        let l3 = to_iters(optimal_interval(l3_cost, mtbf_system)).max(l2);
        Self {
            l1_every: l1,
            l2_every: (l2 / l1).max(1),
            l3_every: (l3 / (l2.max(1))).max(1),
            ..Self::default()
        }
    }

    /// Toggle the background L1→L2 flush (builder style).
    pub fn with_async_flush(mut self, on: bool) -> Self {
        self.async_flush = on;
        self
    }
}

/// The background L1→L2 promotion state machine.
///
/// At most one promotion is outstanding: issuing the next one first
/// settles (waits out) the previous — the back-pressure that keeps the
/// NVMe/fabric from accumulating unbounded flush debt.
#[derive(Debug)]
pub enum FlushState {
    /// No promotion outstanding; every committed level is durable.
    Settled,
    /// An L2 checkpoint is in flight: issued, not yet durable, **not**
    /// in the restart database.
    InFlight {
        pending: PendingCkpt,
        /// Iteration whose state the promotion snapshots.
        iter: usize,
        /// Node set / payload needed to issue the L3 flush on settle.
        nodes: Vec<usize>,
        bytes_per_node: f64,
    },
}

/// Which level a restart was served from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RestartLevel {
    L1,
    L2,
    L3,
}

/// Outcome of a multi-level restart: cost, serving level, and the
/// iteration the application must roll back to.
#[derive(Debug, Clone, Copy)]
pub struct RestartOutcome {
    pub time: SimTime,
    pub level: RestartLevel,
    /// Iteration of the restored checkpoint (the roll-back target).
    pub iter: usize,
}

/// Report of one multi-level run segment.
#[derive(Debug, Clone, Copy, Default)]
pub struct LevelStats {
    pub l1_count: usize,
    pub l2_count: usize,
    pub l3_count: usize,
    pub l1_time: SimTime,
    /// Blocked portion of L2 promotions (in async mode only the
    /// back-pressure waits; in blocking mode the full promotion cost).
    pub l2_time: SimTime,
    /// L3 is asynchronous; this is the *blocked* portion only.
    pub l3_blocked: SimTime,
    /// Background-flush duration that overlapped application compute.
    pub flush_overlap: SimTime,
    /// Time the application stalled waiting for a previous flush to
    /// settle (back-pressure) before issuing the next promotion.
    pub flush_blocked: SimTime,
    /// In-flight promotions discarded because a node loss landed
    /// mid-flight (restart then used the deepest settled level).
    pub flush_aborted: usize,
}

/// The multi-level checkpointer: owns one SCR instance per level.
#[derive(Debug)]
pub struct MultiLevelScr {
    pub config: MultiLevelConfig,
    l1: Scr,
    l2: Scr,
    global: BeeGfs,
    /// Background L3 flush operations (drained at job end or on L3
    /// restart).
    l3: OpSet,
    /// The L1→L2 promotion state machine.
    flush: FlushState,
    pub stats: LevelStats,
    l1_since_l2: usize,
    l2_since_l3: usize,
    /// Iteration of the last flushed L3 checkpoint (its roll-back
    /// target).  L1/L2 roll-back targets come from the per-record `iter`
    /// stamps instead — corruption can force a fall-back to an *older*
    /// record than a newest-iter tracker would point at.
    l3_iter: usize,
    /// The global (L3) copy failed verification — restart must not trust
    /// the parallel file system either (DAOS-style detectable storage
    /// corruption).
    l3_corrupted: bool,
}

impl MultiLevelScr {
    pub fn new(config: MultiLevelConfig) -> Self {
        Self {
            l1: Scr::new(Strategy::Single),
            l2: Scr::new(config.l2_strategy),
            global: BeeGfs::new(),
            l3: OpSet::new(),
            flush: FlushState::Settled,
            stats: LevelStats::default(),
            l1_since_l2: 0,
            l2_since_l3: 0,
            l3_iter: 0,
            l3_corrupted: false,
            config,
        }
    }

    /// True while an L2 promotion is in flight (diagnostics / tests).
    pub fn flush_in_flight(&self) -> bool {
        matches!(self.flush, FlushState::InFlight { .. })
    }

    /// Settled (restorable) L2 checkpoint records.
    pub fn l2_records(&self) -> &[CkptRecord] {
        self.l2.database()
    }

    /// Checkpoint at iteration `iter`; picks the level(s) due.
    /// Returns the time the application was blocked.
    pub fn checkpoint_at(
        &mut self,
        m: &mut Machine,
        nodes: &[usize],
        bytes_per_node: f64,
        iter: usize,
    ) -> crate::Result<SimTime> {
        // Opportunistically commit a flush that settled during compute
        // (no time advances here).
        self.poll_flush(m);
        if self.config.l1_every == 0 || iter % self.config.l1_every != 0 {
            return Ok(0.0);
        }
        let t0 = m.sim.now();
        // L1: always taken when due (cheap, local, blocking).
        let r1 = self.l1.checkpoint_iter(m, nodes, bytes_per_node, iter)?;
        self.stats.l1_count += 1;
        self.stats.l1_time += r1.blocked;
        self.l1_since_l2 += 1;
        if let Some(tr) = m.sim.trace() {
            tr.add("scr_l1_ckpts_total", 1.0);
        }

        // L2: every l2_every L1s.
        if self.l1_since_l2 >= self.config.l2_every {
            self.l1_since_l2 = 0;
            if self.config.async_flush {
                // One outstanding promotion max: settle the previous one
                // first (back-pressure), then issue the next one into the
                // background and return to compute.
                self.settle_flush(m);
                let pending = self.l2.checkpoint_begin_iter(m, nodes, bytes_per_node, iter)?;
                // Trace: the InFlight window opens on the flush lane
                // (closed by `commit_flush` or `abort_flush`).
                if let Some(tr) = m.sim.trace() {
                    tr.begin(
                        pending.issued_at(),
                        m.sim.trace_pid(),
                        crate::obs::lane::FLUSH,
                        "flush.l2",
                        vec![("iter", iter.into()), ("bytes_per_node", bytes_per_node.into())],
                    );
                }
                self.flush = FlushState::InFlight {
                    pending,
                    iter,
                    nodes: nodes.to_vec(),
                    bytes_per_node,
                };
            } else {
                let r2 = self.l2.checkpoint_iter(m, nodes, bytes_per_node, iter)?;
                self.stats.l2_count += 1;
                self.stats.l2_time += r2.blocked;
                if let Some(tr) = m.sim.trace() {
                    tr.add("scr_l2_promotions_total", 1.0);
                }
                self.l2_since_l3 += 1;
                if self.l2_since_l3 >= self.config.l3_every {
                    self.issue_l3(m, nodes, bytes_per_node, iter);
                }
            }
        }
        Ok(m.sim.now() - t0)
    }

    /// Off-cadence forced checkpoint (proactive migration): settle any
    /// in-flight promotion, then take a **blocking** L1 + L2 stamped with
    /// `iter`, so the job's state survives the node set it is about to be
    /// evacuated from.  Cadence counters are untouched — this is an
    /// out-of-band checkpoint, not a scheduled one.  Returns the blocked
    /// time.
    pub fn force_checkpoint(
        &mut self,
        m: &mut Machine,
        nodes: &[usize],
        bytes_per_node: f64,
        iter: usize,
    ) -> crate::Result<SimTime> {
        let t0 = m.sim.now();
        self.settle_flush(m);
        let r1 = self.l1.checkpoint_iter(m, nodes, bytes_per_node, iter)?;
        self.stats.l1_count += 1;
        self.stats.l1_time += r1.blocked;
        let r2 = self.l2.checkpoint_iter(m, nodes, bytes_per_node, iter)?;
        self.stats.l2_count += 1;
        self.stats.l2_time += r2.blocked;
        Ok(m.sim.now() - t0)
    }

    /// Commit the in-flight promotion if it has settled; never advances
    /// virtual time.
    pub fn poll_flush(&mut self, m: &mut Machine) {
        let settled = match &self.flush {
            FlushState::InFlight { pending, .. } => m.sim.poll_op(&pending.op),
            FlushState::Settled => false,
        };
        if settled {
            self.commit_flush(m, 0.0);
        }
    }

    /// Block until the in-flight promotion settles (no-op when settled).
    pub fn settle_flush(&mut self, m: &mut Machine) {
        let op = match &self.flush {
            FlushState::InFlight { pending, .. } => pending.op.clone(),
            FlushState::Settled => return,
        };
        let t0 = m.sim.now();
        m.sim.wait_op(&op);
        let blocked = m.sim.now() - t0;
        self.commit_flush(m, blocked);
    }

    /// Move InFlight -> Settled: commit the L2 record (making it
    /// restorable), account overlap vs blocked time, and fire the L3
    /// flush when its cadence is due.
    fn commit_flush(&mut self, m: &mut Machine, blocked: SimTime) {
        let FlushState::InFlight { pending, iter, nodes, bytes_per_node } =
            std::mem::replace(&mut self.flush, FlushState::Settled)
        else {
            return;
        };
        let r2 = self.l2.checkpoint_commit(m, pending);
        self.stats.l2_count += 1;
        self.stats.l2_time += blocked;
        self.stats.flush_blocked += blocked;
        self.stats.flush_overlap += (r2.blocked - blocked).max(0.0);
        // Trace: InFlight -> Settled closes the flush-lane window at the
        // commit point (state-machine time, not op-completion time).
        if let Some(tr) = m.sim.trace() {
            let pid = m.sim.trace_pid();
            let now = m.sim.now();
            tr.with(|r| {
                r.add("scr_l2_promotions_total", 1.0);
                r.observe("scr_flush_blocked_s", blocked);
                r.observe("scr_flush_overlap_s", (r2.blocked - blocked).max(0.0));
                r.push(crate::obs::SpanEvent {
                    t: now,
                    kind: crate::obs::SpanKind::End,
                    pid,
                    tid: crate::obs::lane::FLUSH,
                    name: "flush.l2",
                    attrs: Vec::new(),
                });
            });
        }
        self.l2_since_l3 += 1;
        if self.l2_since_l3 >= self.config.l3_every {
            self.issue_l3(m, &nodes, bytes_per_node, iter);
        }
    }

    /// Discard an in-flight promotion (a node loss landed mid-flight):
    /// the record was never committed, so restarts fall back to the
    /// deepest settled level.  The promotion's in-flight flows are
    /// **cancelled** (settle-then-retire) — the DMA died with the node,
    /// so its traffic must stop contending with the restart I/O and
    /// other tenants now, not drain unobserved to a phantom finish
    /// (DESIGN.md section 12.4).
    fn abort_flush(&mut self, m: &mut Machine) {
        if let FlushState::InFlight { pending, iter, .. } =
            std::mem::replace(&mut self.flush, FlushState::Settled)
        {
            m.sim.cancel_op(&pending.op);
            self.stats.flush_aborted += 1;
            // Trace: close the flush-lane window and mark the abort.
            // The discarded pending record also leaves an `scr.ckpt`
            // slice open (its begin was recorded by
            // `checkpoint_begin_iter`, and it will never commit) — close
            // it here so Begin/End events stay balanced.
            if let Some(tr) = m.sim.trace() {
                let pid = m.sim.trace_pid();
                let now = m.sim.now();
                tr.with(|r| {
                    r.add("scr_flush_aborts_total", 1.0);
                    r.push(crate::obs::SpanEvent {
                        t: now,
                        kind: crate::obs::SpanKind::End,
                        pid,
                        tid: crate::obs::lane::SCR,
                        name: "scr.ckpt",
                        attrs: Vec::new(),
                    });
                    r.push(crate::obs::SpanEvent {
                        t: now,
                        kind: crate::obs::SpanKind::End,
                        pid,
                        tid: crate::obs::lane::FLUSH,
                        name: "flush.l2",
                        attrs: Vec::new(),
                    });
                    r.push(crate::obs::SpanEvent {
                        t: now,
                        kind: crate::obs::SpanKind::Instant,
                        pid,
                        tid: crate::obs::lane::FLUSH,
                        name: "flush.abort",
                        attrs: vec![("iter", iter.into())],
                    });
                });
            }
        }
    }

    /// Fire the asynchronous L3 flush of the freshly settled L2.
    /// QoS: L3 promotion traffic is [`TrafficClass::CkptFlush`].
    fn issue_l3(&mut self, m: &mut Machine, nodes: &[usize], bytes_per_node: f64, iter: usize) {
        self.l2_since_l3 = 0;
        let t3 = m.sim.now();
        let prev = m.sim.default_issue_class(TrafficClass::CkptFlush);
        for &n in nodes {
            let op = self.global.write_striped_op(m, n, bytes_per_node);
            self.l3.push(op);
        }
        m.sim.set_issue_class(prev);
        self.stats.l3_count += 1;
        self.l3_iter = iter;
        // Only the issue cost blocks; the transfer is background.
        self.stats.l3_blocked += m.sim.now() - t3;
        if let Some(tr) = m.sim.trace() {
            let pid = m.sim.trace_pid();
            tr.with(|r| {
                r.add("scr_l3_flushes_total", 1.0);
                r.push(crate::obs::SpanEvent {
                    t: t3,
                    kind: crate::obs::SpanKind::Instant,
                    pid,
                    tid: crate::obs::lane::FLUSH,
                    name: "flush.l3",
                    attrs: vec![
                        ("iter", iter.into()),
                        ("nodes", nodes.len().into()),
                        ("bytes_per_node", bytes_per_node.into()),
                    ],
                });
            });
        }
    }

    /// Restart after a failure from the cheapest level that covers it,
    /// reporting which level served it and the roll-back iteration.
    ///
    /// `failed=None` -> L1.  `failed=Some(_)` -> the deepest **settled**
    /// L2 (an in-flight promotion is aborted, never restored from); if no
    /// L2 record survives node loss, fall back to L3 (global read), else
    /// error.  Every level only serves *verified* records: a corrupted
    /// checkpoint is skipped and the chain keeps walking — L1's older
    /// records, then L2, then L3 — so restart always lands on the deepest
    /// verified checkpoint, never a corrupted one (DESIGN.md §15).
    pub fn restart_detailed(
        &mut self,
        m: &mut Machine,
        nodes: &[usize],
        failed: Option<usize>,
    ) -> crate::Result<RestartOutcome> {
        match failed {
            None => {
                // Transient process error: node state (and any in-flight
                // promotion, which only reads node-local sources) is
                // intact; L1 covers it — unless every L1 record failed
                // verification, in which case the deeper levels serve the
                // same role they do for node loss.
                if self.l1.latest_usable(None).is_some() {
                    let rep = self.l1.restart(m, nodes, None)?;
                    return Ok(RestartOutcome {
                        time: rep.time,
                        level: RestartLevel::L1,
                        iter: rep.iter,
                    });
                }
                if self.l2.latest_usable(None).is_some() {
                    let rep = self.l2.restart(m, nodes, None)?;
                    return Ok(RestartOutcome {
                        time: rep.time,
                        level: RestartLevel::L2,
                        iter: rep.iter,
                    });
                }
                self.l3_restart(m, nodes)
            }
            Some(f) => {
                // Anything still in flight was invalidated by the node
                // loss: discard it and use the deepest *settled* level.
                // Deliberately NO poll here — between the node dying and
                // this restart running, virtual time has passed (PMD
                // detection/cleanup), and a promotion whose flows
                // "completed" inside that window finished streaming from
                // a dead node.  Callers that want a settled-in-background
                // promotion credited must [`MultiLevelScr::poll_flush`]
                // *before* the failure hits (the driver does, right
                // before injecting the kill).
                self.abort_flush(m);
                if self.l2.latest_usable(Some(f)).is_some() {
                    let rep = self.l2.restart(m, nodes, Some(f))?;
                    Ok(RestartOutcome {
                        time: rep.time,
                        level: RestartLevel::L2,
                        iter: rep.iter,
                    })
                } else {
                    self.l3_restart(m, nodes)
                }
            }
        }
    }

    /// Last-resort global read-back (the end of the verified-fallback
    /// chain).  Errors when no L3 flush ever completed — or when the
    /// global copy itself failed verification.
    fn l3_restart(&mut self, m: &mut Machine, nodes: &[usize]) -> crate::Result<RestartOutcome> {
        if self.stats.l3_count == 0 || self.l3_corrupted {
            anyhow::bail!("no verified checkpoint at any level covers this failure");
        }
        let t0 = m.sim.now();
        // Drain pending flushes first (consistency point).
        self.l3.wait_all(&mut m.sim);
        let bytes = self
            .l1
            .database()
            .last()
            .map(|r| r.bytes_per_node)
            .unwrap_or(0.0);
        let prev = m.sim.default_issue_class(TrafficClass::CkptFlush);
        let mut read = crate::sim::Op::done();
        for &n in nodes {
            read.join(self.global.read_striped_op(m, n, bytes));
        }
        m.sim.set_issue_class(prev);
        let t = m.sim.wait_op(&read);
        Ok(RestartOutcome { time: t - t0, level: RestartLevel::L3, iter: self.l3_iter })
    }

    /// Corruption injection for the fleet scheduler: the newest committed
    /// (verified) record across L1/L2 fails its CRC.  Prefers L2 on a
    /// commit-time tie — corrupting the deeper level is the damaging
    /// case.  Returns the level hit, or `None` when nothing verifiable
    /// remains to corrupt.
    pub fn corrupt_latest(&mut self) -> Option<RestartLevel> {
        let newest = |scr: &Scr| scr.database().iter().rev().find(|r| !r.corrupted).map(|r| r.taken_at);
        match (newest(&self.l1), newest(&self.l2)) {
            (Some(a), Some(b)) if b >= a => {
                self.l2.corrupt_latest();
                Some(RestartLevel::L2)
            }
            (Some(_), _) => {
                self.l1.corrupt_latest();
                Some(RestartLevel::L1)
            }
            (None, Some(_)) => {
                self.l2.corrupt_latest();
                Some(RestartLevel::L2)
            }
            (None, None) => None,
        }
    }

    /// Level-targeted corruption (the property-test sweep's injection
    /// point): mark the newest record of one tier unverifiable.  Returns
    /// whether anything was actually corrupted.
    pub fn corrupt_level(&mut self, level: RestartLevel) -> bool {
        match level {
            RestartLevel::L1 => self.l1.corrupt_latest(),
            RestartLevel::L2 => self.l2.corrupt_latest(),
            RestartLevel::L3 => {
                if self.stats.l3_count == 0 || self.l3_corrupted {
                    return false;
                }
                self.l3_corrupted = true;
                true
            }
        }
    }

    /// Shim over [`MultiLevelScr::restart_detailed`] returning the cost
    /// only.
    pub fn restart(
        &mut self,
        m: &mut Machine,
        nodes: &[usize],
        failed: Option<usize>,
    ) -> crate::Result<SimTime> {
        Ok(self.restart_detailed(m, nodes, failed)?.time)
    }

    /// Job-end barrier: the in-flight promotion settled and all L3
    /// flushes durable.
    pub fn drain(&mut self, m: &mut Machine) -> SimTime {
        self.settle_flush(m);
        self.l3.wait_all(&mut m.sim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::{presets, NodeKind};

    fn machine() -> Machine {
        Machine::build(presets::deep_er())
    }

    #[test]
    fn young_formula_basics() {
        // C=10s, M=10000s -> tau = sqrt(2*10*10000) ~ 447 s.
        let tau = optimal_interval(10.0, 10_000.0);
        assert!((tau - 447.2).abs() < 1.0, "tau={tau}");
        // The optimum beats half and double intervals on expected waste.
        let w_opt = expected_waste(tau, 10.0, 20.0, 10_000.0);
        assert!(w_opt < expected_waste(tau / 2.0, 10.0, 20.0, 10_000.0));
        assert!(w_opt < expected_waste(tau * 2.0, 10.0, 20.0, 10_000.0));
    }

    #[test]
    fn config_from_failure_model_is_ordered() {
        let c = MultiLevelConfig::from_failure_model(
            10.0,   // iteration time
            2.0,    // L1 cost
            6.0,    // L2 cost
            60.0,   // L3 cost
            2_000.0, // process MTBF
            50_000.0, // node MTBF
            500_000.0, // system MTBF
        );
        assert!(c.l1_every >= 1);
        assert!(c.l2_every >= 1);
        assert!(c.l3_every >= 1);
        assert!(!c.async_flush, "async flush is opt-in");
        // L2 period (in iterations) must be >= L1 period.
        assert!(c.l1_every * c.l2_every >= c.l1_every);
    }

    #[test]
    fn levels_fire_at_configured_cadence() {
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let cfg = MultiLevelConfig {
            l1_every: 1,
            l2_every: 3,
            l3_every: 2,
            ..MultiLevelConfig::default()
        };
        let mut ml = MultiLevelScr::new(cfg);
        for iter in 1..=12 {
            ml.checkpoint_at(&mut m, &nodes, 1e9, iter).unwrap();
        }
        assert_eq!(ml.stats.l1_count, 12);
        assert_eq!(ml.stats.l2_count, 4); // every 3rd L1
        assert_eq!(ml.stats.l3_count, 2); // every 2nd L2
        ml.drain(&mut m);
    }

    #[test]
    fn async_cadence_matches_blocking_after_drain() {
        // The background machine must not change *what* is checkpointed,
        // only *when* the application blocks.
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let cfg = MultiLevelConfig {
            l1_every: 1,
            l2_every: 3,
            l3_every: 2,
            async_flush: true,
            ..MultiLevelConfig::default()
        };
        let mut ml = MultiLevelScr::new(cfg);
        for iter in 1..=12 {
            ml.checkpoint_at(&mut m, &nodes, 1e9, iter).unwrap();
            // Give the flush compute time to settle into.
            m.sim.advance(5.0);
        }
        ml.drain(&mut m);
        assert!(!ml.flush_in_flight());
        assert_eq!(ml.stats.l1_count, 12);
        assert_eq!(ml.stats.l2_count, 4);
        assert_eq!(ml.stats.l3_count, 2);
        assert_eq!(ml.l2_records().len(), 4);
        // With 5 s of compute between iterations the Buddy promotion
        // (~1 GB/node) settles in the gaps: overlap dominates blocking.
        assert!(
            ml.stats.flush_overlap > ml.stats.flush_blocked,
            "overlap={} blocked={}",
            ml.stats.flush_overlap,
            ml.stats.flush_blocked
        );
    }

    #[test]
    fn async_flush_blocks_less_than_blocking_promotion() {
        let run = |async_flush: bool| -> (SimTime, LevelStats) {
            let mut m = machine();
            let nodes = m.nodes_of(NodeKind::Cluster);
            let cfg = MultiLevelConfig {
                l1_every: 1,
                l2_every: 2,
                l3_every: 100,
                async_flush,
                ..MultiLevelConfig::default()
            };
            let mut ml = MultiLevelScr::new(cfg);
            // checkpoint_at's return already includes any back-pressure
            // settle wait, so only the final out-of-loop settle is added.
            let mut blocked = 0.0;
            for iter in 1..=8 {
                blocked += ml.checkpoint_at(&mut m, &nodes, 2e9, iter).unwrap();
                m.sim.advance(30.0); // compute window for the flush
            }
            let t0 = m.sim.now();
            ml.settle_flush(&mut m);
            (blocked + (m.sim.now() - t0), ml.stats)
        };
        let (blocked_sync, _) = run(false);
        let (blocked_async, stats) = run(true);
        assert!(
            blocked_async < blocked_sync,
            "async {blocked_async} !< blocking {blocked_sync}"
        );
        assert!(stats.flush_overlap > 0.0);
    }

    #[test]
    fn l1_much_cheaper_than_l2() {
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let mut ml = MultiLevelScr::new(MultiLevelConfig {
            l1_every: 1,
            l2_every: 2,
            l3_every: 100,
            l2_strategy: Strategy::Partner,
            ..MultiLevelConfig::default()
        });
        for iter in 1..=4 {
            ml.checkpoint_at(&mut m, &nodes, 2e9, iter).unwrap();
        }
        let l1_avg = ml.stats.l1_time / ml.stats.l1_count as f64;
        let l2_avg = ml.stats.l2_time / ml.stats.l2_count as f64;
        assert!(l2_avg > 1.5 * l1_avg, "l1={l1_avg} l2={l2_avg}");
    }

    #[test]
    fn restart_picks_cheapest_covering_level() {
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let mut ml = MultiLevelScr::new(MultiLevelConfig::default());
        for iter in 1..=10 {
            ml.checkpoint_at(&mut m, &nodes, 1e9, iter).unwrap();
        }
        // Transient: L1 restart works.
        let r1 = ml.restart_detailed(&mut m, &nodes, None).unwrap();
        assert!(r1.time > 0.0);
        assert_eq!(r1.level, RestartLevel::L1);
        assert_eq!(r1.iter, 10);
        // Node loss: L2 restart works and costs more than L1.
        m.kill_node(nodes[1]);
        m.revive_node(nodes[1]);
        let r2 = ml.restart_detailed(&mut m, &nodes, Some(nodes[1])).unwrap();
        assert!(r2.time > r1.time, "l1={} l2={}", r1.time, r2.time);
        assert_eq!(r2.level, RestartLevel::L2);
        assert_eq!(r2.iter, 10, "L2 fires on iters 5 and 10");
    }

    #[test]
    fn node_loss_before_any_l2_falls_back_or_errors() {
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let mut ml = MultiLevelScr::new(MultiLevelConfig {
            l1_every: 1,
            l2_every: 100, // never during this test
            l3_every: 100,
            ..MultiLevelConfig::default()
        });
        ml.checkpoint_at(&mut m, &nodes, 1e9, 1).unwrap();
        m.kill_node(nodes[0]);
        m.revive_node(nodes[0]);
        assert!(ml.restart(&mut m, &nodes, Some(nodes[0])).is_err());
    }

    #[test]
    fn failure_mid_flight_falls_back_to_settled_level() {
        // The acceptance scenario: one L2 settled (iter 2), another in
        // flight (iter 4) when the node dies.  Restart must use the
        // *settled* record — not the in-flight one — and roll back to
        // iteration 2.
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let cfg = MultiLevelConfig {
            l1_every: 1,
            l2_every: 2,
            l3_every: 100,
            async_flush: true,
            ..MultiLevelConfig::default()
        };
        let mut ml = MultiLevelScr::new(cfg);
        ml.checkpoint_at(&mut m, &nodes, 1e9, 1).unwrap();
        ml.checkpoint_at(&mut m, &nodes, 1e9, 2).unwrap(); // L2 issued
        m.sim.advance(60.0); // settles
        ml.checkpoint_at(&mut m, &nodes, 1e9, 3).unwrap(); // commits settled L2
        assert_eq!(ml.l2_records().len(), 1);
        ml.checkpoint_at(&mut m, &nodes, 1e9, 4).unwrap(); // next L2 issued...
        assert!(ml.flush_in_flight(), "promotion must still be in flight");
        // ...and the node dies before it settles.
        m.kill_node(nodes[3]);
        m.revive_node(nodes[3]);
        let r = ml.restart_detailed(&mut m, &nodes, Some(nodes[3])).unwrap();
        assert_eq!(r.level, RestartLevel::L2);
        assert_eq!(r.iter, 2, "must roll back to the settled L2, not the in-flight one");
        assert!(!ml.flush_in_flight(), "in-flight promotion must be aborted");
        assert_eq!(ml.stats.flush_aborted, 1);
        assert_eq!(ml.l2_records().len(), 1, "aborted promotion never committed");
    }

    #[test]
    fn corruption_walks_down_the_verified_chain() {
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let mut ml = MultiLevelScr::new(MultiLevelConfig {
            l1_every: 1,
            l2_every: 2,
            l3_every: 2,
            ..MultiLevelConfig::default()
        });
        // L1 at 1..=4, L2 at 2 and 4, L3 at 4.
        for iter in 1..=4 {
            ml.checkpoint_at(&mut m, &nodes, 1e9, iter).unwrap();
        }
        // Healthy: node loss restores the iter-4 L2.
        m.kill_node(nodes[1]);
        m.revive_node(nodes[1]);
        let r = ml.restart_detailed(&mut m, &nodes, Some(nodes[1])).unwrap();
        assert_eq!((r.level, r.iter), (RestartLevel::L2, 4));
        // Newest L2 corrupted: fall back to the iter-2 L2.
        assert_eq!(ml.corrupt_latest(), Some(RestartLevel::L2));
        let r = ml.restart_detailed(&mut m, &nodes, Some(nodes[1])).unwrap();
        assert_eq!((r.level, r.iter), (RestartLevel::L2, 2));
        // Both L2s corrupted: only the global copy is left.
        assert!(ml.corrupt_level(RestartLevel::L2));
        let r = ml.restart_detailed(&mut m, &nodes, Some(nodes[1])).unwrap();
        assert_eq!((r.level, r.iter), (RestartLevel::L3, 4));
        // Global copy corrupted too: nothing verified covers node loss.
        assert!(ml.corrupt_level(RestartLevel::L3));
        assert!(!ml.corrupt_level(RestartLevel::L3), "re-corrupting is a no-op");
        assert!(ml.restart_detailed(&mut m, &nodes, Some(nodes[1])).is_err());
        // Transient errors still restart: verified L1 records remain.
        let r = ml.restart_detailed(&mut m, &nodes, None).unwrap();
        assert_eq!((r.level, r.iter), (RestartLevel::L1, 4));
    }

    #[test]
    fn transient_restart_falls_back_when_l1_corrupted() {
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let mut ml = MultiLevelScr::new(MultiLevelConfig {
            l1_every: 1,
            l2_every: 2,
            l3_every: 100,
            ..MultiLevelConfig::default()
        });
        ml.checkpoint_at(&mut m, &nodes, 1e9, 1).unwrap();
        ml.checkpoint_at(&mut m, &nodes, 1e9, 2).unwrap(); // + L2
        // Corrupt every L1 record: a transient error must restore from
        // the verified L2 instead of trusting a bad local checkpoint.
        assert!(ml.corrupt_level(RestartLevel::L1));
        assert!(ml.corrupt_level(RestartLevel::L1));
        let r = ml.restart_detailed(&mut m, &nodes, None).unwrap();
        assert_eq!((r.level, r.iter), (RestartLevel::L2, 2));
    }

    #[test]
    fn async_l3_blocks_less_than_sync_read_back() {
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let mut ml = MultiLevelScr::new(MultiLevelConfig {
            l1_every: 1,
            l2_every: 1,
            l3_every: 1,
            ..MultiLevelConfig::default()
        });
        ml.checkpoint_at(&mut m, &nodes, 1e9, 1).unwrap();
        // The L3 issue cost is (near) zero blocked time...
        assert!(ml.stats.l3_blocked < 0.01, "blocked={}", ml.stats.l3_blocked);
        // ...while the actual flush takes real time to drain.
        let t0 = m.sim.now();
        let t = ml.drain(&mut m) - t0;
        assert!(t > 0.5, "flush drained too fast: {t}");
    }
}

//! Multi-level checkpointing — the full SCR discipline the paper builds on.
//!
//! Moody et al.'s SCR (the paper's [14]) is a *multi-level* checkpoint
//! system: cheap, frequent checkpoints at low levels (node-local) and
//! expensive, rare ones at high levels (partner/XOR, then the parallel
//! file system).  DEEP-ER's contribution slots new mechanisms into those
//! levels — BeeOND caching at L1, Buddy/NAM-XOR at L2, asynchronous
//! global flush at L3 — which is exactly how this module composes them:
//!
//! * **L1** `Single`: node-local NVMe, survives process restarts.
//! * **L2** any of `Buddy` / `Partner` / `DistXor` / `NamXor`: survives
//!   single-node loss.
//! * **L3** global: BeeOND-async flush of the L2 checkpoint to BeeGFS,
//!   survives rack-level faults (and job retirement).
//!
//! Level frequencies come from the generalized Young/Daly optimum
//! ([`optimal_interval`]): interval_k = sqrt(2 * cost_k * MTBF_k).

use super::{Scr, Strategy};
use crate::beegfs::BeeGfs;
use crate::sim::SimTime;
use crate::system::Machine;

/// Young's approximation of the optimal checkpoint interval:
/// `sqrt(2 * C * M)` for checkpoint cost `C` and failure MTBF `M`
/// (both in seconds).  Within a few percent of Daly's higher-order
/// formula whenever C << M, which holds for every DEEP-ER level.
pub fn optimal_interval(ckpt_cost: SimTime, mtbf: SimTime) -> SimTime {
    assert!(ckpt_cost > 0.0 && mtbf > 0.0);
    (2.0 * ckpt_cost * mtbf).sqrt()
}

/// Expected wasted time per failure with interval `tau` (half the
/// interval re-computed + restart cost) — the quantity `optimal_interval`
/// balances against checkpoint overhead.
pub fn expected_waste(tau: SimTime, ckpt_cost: SimTime, restart_cost: SimTime, mtbf: SimTime) -> f64 {
    // Overhead fraction: C/tau of useful time + per-failure loss.
    ckpt_cost / tau + (tau / 2.0 + restart_cost) / mtbf
}

/// Configuration of the three levels.
#[derive(Debug, Clone)]
pub struct MultiLevelConfig {
    /// Take an L1 (local) checkpoint every `l1_every` iterations.
    pub l1_every: usize,
    /// Promote to L2 (partner/XOR) every `l2_every` L1 checkpoints.
    pub l2_every: usize,
    /// Flush to the global FS every `l3_every` L2 checkpoints.
    pub l3_every: usize,
    /// Which strategy implements L2.
    pub l2_strategy: Strategy,
}

impl Default for MultiLevelConfig {
    fn default() -> Self {
        Self { l1_every: 1, l2_every: 5, l3_every: 4, l2_strategy: Strategy::Buddy }
    }
}

impl MultiLevelConfig {
    /// Derive level frequencies from failure statistics, Young-style:
    /// each level's interval covers the failure class it protects
    /// against.  `iter_time` converts seconds to iteration counts.
    pub fn from_failure_model(
        iter_time: SimTime,
        l1_cost: SimTime,
        l2_cost: SimTime,
        l3_cost: SimTime,
        mtbf_process: SimTime,
        mtbf_node: SimTime,
        mtbf_system: SimTime,
    ) -> Self {
        let to_iters = |tau: SimTime| ((tau / iter_time).round() as usize).max(1);
        let l1 = to_iters(optimal_interval(l1_cost, mtbf_process));
        let l2 = to_iters(optimal_interval(l2_cost, mtbf_node)).max(l1);
        let l3 = to_iters(optimal_interval(l3_cost, mtbf_system)).max(l2);
        Self {
            l1_every: l1,
            l2_every: (l2 / l1).max(1),
            l3_every: (l3 / (l2.max(1))).max(1),
            l2_strategy: Strategy::Buddy,
        }
    }
}

/// Report of one multi-level run segment.
#[derive(Debug, Clone, Copy, Default)]
pub struct LevelStats {
    pub l1_count: usize,
    pub l2_count: usize,
    pub l3_count: usize,
    pub l1_time: SimTime,
    pub l2_time: SimTime,
    /// L3 is asynchronous; this is the *blocked* portion only.
    pub l3_blocked: SimTime,
}

/// The multi-level checkpointer: owns one SCR instance per level.
#[derive(Debug)]
pub struct MultiLevelScr {
    pub config: MultiLevelConfig,
    l1: Scr,
    l2: Scr,
    global: BeeGfs,
    /// Background L3 flush flows (drained at job end or on L3 restart).
    l3_flows: Vec<crate::sim::FlowId>,
    pub stats: LevelStats,
    l1_since_l2: usize,
    l2_since_l3: usize,
}

impl MultiLevelScr {
    pub fn new(config: MultiLevelConfig) -> Self {
        Self {
            l1: Scr::new(Strategy::Single),
            l2: Scr::new(config.l2_strategy),
            global: BeeGfs::new(),
            l3_flows: Vec::new(),
            stats: LevelStats::default(),
            l1_since_l2: 0,
            l2_since_l3: 0,
            config,
        }
    }

    /// Checkpoint at iteration `iter`; picks the level(s) due.
    /// Returns the time the application was blocked.
    pub fn checkpoint_at(
        &mut self,
        m: &mut Machine,
        nodes: &[usize],
        bytes_per_node: f64,
        iter: usize,
    ) -> crate::Result<SimTime> {
        if self.config.l1_every == 0 || iter % self.config.l1_every != 0 {
            return Ok(0.0);
        }
        let t0 = m.sim.now();
        // L1: always taken when due (cheap, local).
        let r1 = self.l1.checkpoint(m, nodes, bytes_per_node)?;
        self.stats.l1_count += 1;
        self.stats.l1_time += r1.blocked;
        self.l1_since_l2 += 1;

        // L2: every l2_every L1s.
        if self.l1_since_l2 >= self.config.l2_every {
            self.l1_since_l2 = 0;
            let r2 = self.l2.checkpoint(m, nodes, bytes_per_node)?;
            self.stats.l2_count += 1;
            self.stats.l2_time += r2.blocked;
            self.l2_since_l3 += 1;

            // L3: asynchronous flush of the freshly-taken L2 to BeeGFS.
            if self.l2_since_l3 >= self.config.l3_every {
                self.l2_since_l3 = 0;
                let t3 = m.sim.now();
                for &n in nodes {
                    let flows = self.global.write_striped(m, n, bytes_per_node);
                    self.l3_flows.extend(flows);
                }
                self.stats.l3_count += 1;
                // Only the issue cost blocks; the transfer is background.
                self.stats.l3_blocked += m.sim.now() - t3;
            }
        }
        Ok(m.sim.now() - t0)
    }

    /// Restart after a failure: cheapest level that covers it.
    /// `node_lost=false` -> L1; `node_lost=true` -> L2; if L2 has no
    /// record (node lost before any L2), fall back to L3 (global read).
    pub fn restart(
        &mut self,
        m: &mut Machine,
        nodes: &[usize],
        failed: Option<usize>,
    ) -> crate::Result<SimTime> {
        match failed {
            None => Ok(self.l1.restart(m, nodes, None)?.time),
            Some(f) => {
                if self.l2.latest_usable(Some(f)).is_some() {
                    Ok(self.l2.restart(m, nodes, Some(f))?.time)
                } else if self.stats.l3_count > 0 {
                    // Global read-back for every node.
                    let t0 = m.sim.now();
                    // Drain pending flushes first (consistency point).
                    let pending = std::mem::take(&mut self.l3_flows);
                    if !pending.is_empty() {
                        m.sim.wait_all(&pending);
                    }
                    let mut flows = Vec::new();
                    let bytes = self
                        .l1
                        .database()
                        .last()
                        .map(|r| r.bytes_per_node)
                        .unwrap_or(0.0);
                    for &n in nodes {
                        flows.extend(self.global.read_striped(m, n, bytes));
                    }
                    let t = m.sim.wait_all(&flows);
                    Ok(t - t0)
                } else {
                    anyhow::bail!("no checkpoint level covers a lost node yet")
                }
            }
        }
    }

    /// Job-end barrier: all L3 flushes durable.
    pub fn drain(&mut self, m: &mut Machine) -> SimTime {
        let pending = std::mem::take(&mut self.l3_flows);
        if pending.is_empty() {
            m.sim.now()
        } else {
            m.sim.wait_all(&pending)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::{presets, NodeKind};

    fn machine() -> Machine {
        Machine::build(presets::deep_er())
    }

    #[test]
    fn young_formula_basics() {
        // C=10s, M=10000s -> tau = sqrt(2*10*10000) ~ 447 s.
        let tau = optimal_interval(10.0, 10_000.0);
        assert!((tau - 447.2).abs() < 1.0, "tau={tau}");
        // The optimum beats half and double intervals on expected waste.
        let w_opt = expected_waste(tau, 10.0, 20.0, 10_000.0);
        assert!(w_opt < expected_waste(tau / 2.0, 10.0, 20.0, 10_000.0));
        assert!(w_opt < expected_waste(tau * 2.0, 10.0, 20.0, 10_000.0));
    }

    #[test]
    fn config_from_failure_model_is_ordered() {
        let c = MultiLevelConfig::from_failure_model(
            10.0,   // iteration time
            2.0,    // L1 cost
            6.0,    // L2 cost
            60.0,   // L3 cost
            2_000.0, // process MTBF
            50_000.0, // node MTBF
            500_000.0, // system MTBF
        );
        assert!(c.l1_every >= 1);
        assert!(c.l2_every >= 1);
        assert!(c.l3_every >= 1);
        // L2 period (in iterations) must be >= L1 period.
        assert!(c.l1_every * c.l2_every >= c.l1_every);
    }

    #[test]
    fn levels_fire_at_configured_cadence() {
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let cfg = MultiLevelConfig { l1_every: 1, l2_every: 3, l3_every: 2, l2_strategy: Strategy::Buddy };
        let mut ml = MultiLevelScr::new(cfg);
        for iter in 1..=12 {
            ml.checkpoint_at(&mut m, &nodes, 1e9, iter).unwrap();
        }
        assert_eq!(ml.stats.l1_count, 12);
        assert_eq!(ml.stats.l2_count, 4); // every 3rd L1
        assert_eq!(ml.stats.l3_count, 2); // every 2nd L2
        ml.drain(&mut m);
    }

    #[test]
    fn l1_much_cheaper_than_l2() {
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let mut ml = MultiLevelScr::new(MultiLevelConfig {
            l1_every: 1,
            l2_every: 2,
            l3_every: 100,
            l2_strategy: Strategy::Partner,
        });
        for iter in 1..=4 {
            ml.checkpoint_at(&mut m, &nodes, 2e9, iter).unwrap();
        }
        let l1_avg = ml.stats.l1_time / ml.stats.l1_count as f64;
        let l2_avg = ml.stats.l2_time / ml.stats.l2_count as f64;
        assert!(l2_avg > 1.5 * l1_avg, "l1={l1_avg} l2={l2_avg}");
    }

    #[test]
    fn restart_picks_cheapest_covering_level() {
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let mut ml = MultiLevelScr::new(MultiLevelConfig::default());
        for iter in 1..=10 {
            ml.checkpoint_at(&mut m, &nodes, 1e9, iter).unwrap();
        }
        // Transient: L1 restart works.
        let t1 = ml.restart(&mut m, &nodes, None).unwrap();
        assert!(t1 > 0.0);
        // Node loss: L2 restart works and costs more than L1.
        m.kill_node(nodes[1]);
        m.revive_node(nodes[1]);
        let t2 = ml.restart(&mut m, &nodes, Some(nodes[1])).unwrap();
        assert!(t2 > t1, "l1={t1} l2={t2}");
    }

    #[test]
    fn node_loss_before_any_l2_falls_back_or_errors() {
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let mut ml = MultiLevelScr::new(MultiLevelConfig {
            l1_every: 1,
            l2_every: 100, // never during this test
            l3_every: 100,
            l2_strategy: Strategy::Buddy,
        });
        ml.checkpoint_at(&mut m, &nodes, 1e9, 1).unwrap();
        m.kill_node(nodes[0]);
        m.revive_node(nodes[0]);
        assert!(ml.restart(&mut m, &nodes, Some(nodes[0])).is_err());
    }

    #[test]
    fn async_l3_blocks_less_than_sync_read_back() {
        let mut m = machine();
        let nodes = m.nodes_of(NodeKind::Cluster);
        let mut ml = MultiLevelScr::new(MultiLevelConfig {
            l1_every: 1,
            l2_every: 1,
            l3_every: 1,
            l2_strategy: Strategy::Buddy,
        });
        ml.checkpoint_at(&mut m, &nodes, 1e9, 1).unwrap();
        // The L3 issue cost is (near) zero blocked time...
        assert!(ml.stats.l3_blocked < 0.01, "blocked={}", ml.stats.l3_blocked);
        // ...while the actual flush takes real time to drain.
        let t0 = m.sim.now();
        let t = ml.drain(&mut m) - t0;
        assert!(t > 0.5, "flush drained too fast: {t}");
    }
}

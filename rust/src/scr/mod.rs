//! SCR: Scalable Checkpoint/Restart with the DEEP-ER strategy set.
//!
//! Paper Section III-D1 defines four application-level checkpoint/restart
//! strategies built from SCR + ParaStation MPI + SIONlib + BeeGFS/BeeOND
//! + the NAM, ordered from most basic to most advanced:
//!
//! * **Single** (`SCR_SINGLE`): checkpoint to the node-local NVMe only —
//!   survives transient (process) errors, not node loss.
//! * **Partner** (`SCR_PARTNER`): write locally, *re-read from local
//!   storage*, send to a partner node, partner writes it — survives node
//!   failures, but stores every checkpoint twice and pays the re-read.
//! * **Buddy** (DEEP-ER): SIONlib streams the checkpoint straight from
//!   memory into a single per-node file on the buddy's BeeOND cache,
//!   skipping the intermediate re-read of Partner — same resiliency,
//!   less overhead (Fig. 4).
//! * **Distributed XOR** (`SCR` XOR): store the full checkpoint locally
//!   and only distribute *parity* (RAID-5 style) over the group —
//!   halves the storage and most of the network volume.
//! * **NAM XOR** (DEEP-ER): offload the parity computation and storage to
//!   the Network Attached Memory; the FPGA pulls the data via RDMA, so
//!   node CPUs and NVMe see (almost) only the local write — up to 3x the
//!   checkpoint bandwidth of Distributed XOR (Fig. 9).
//!
//! Every strategy implements both the **checkpoint** path and the
//! **restart/rebuild** path; validity rules (which failures a checkpoint
//! survives) are encoded in [`Strategy::survives_node_loss`] and checked
//! by the integration tests.

pub mod multilevel;

use crate::psmpi::Comm;
use crate::sim::{FlowId, Op, SimTime, TrafficClass};
use crate::sionlib;
use crate::system::Machine;

/// XOR group size used by SCR's distributed parity sets.
pub const DEFAULT_XOR_GROUP: usize = 4;
/// CPU cost of XOR-folding one byte on a compute node.  XOR is memory-
/// bandwidth-bound, not flop-bound: 100 flop-equivalents/byte models an
/// effective ~10 GB/s fold rate on the 1 TFlop/s Haswell node (the cost
/// the NAM strategy offloads to the FPGA).
pub const NODE_XOR_FLOP_PER_BYTE: f64 = 100.0;

/// The five checkpoint strategies of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    Single,
    Partner,
    Buddy,
    DistXor,
    NamXor,
}

impl Strategy {
    pub const ALL: [Strategy; 5] = [
        Strategy::Single,
        Strategy::Partner,
        Strategy::Buddy,
        Strategy::DistXor,
        Strategy::NamXor,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Single => "Single",
            Strategy::Partner => "SCR_PARTNER",
            Strategy::Buddy => "Buddy",
            Strategy::DistXor => "Distributed XOR",
            Strategy::NamXor => "NAM XOR",
        }
    }

    /// Can a checkpoint taken with this strategy recover the state of a
    /// *lost* node (vs only a transient process error)?
    pub fn survives_node_loss(&self) -> bool {
        !matches!(self, Strategy::Single)
    }

    /// Storage written per node per checkpoint, as a multiple of the
    /// checkpoint size (the Partner/Buddy "stores everything twice" cost
    /// the paper calls out).
    pub fn storage_factor(&self, group: usize) -> f64 {
        match self {
            Strategy::Single => 1.0,
            Strategy::Partner | Strategy::Buddy => 2.0,
            Strategy::DistXor => 1.0 + 1.0 / (group.max(2) as f64 - 1.0),
            Strategy::NamXor => 1.0, // parity lives on the NAM
        }
    }
}

/// One checkpoint's bookkeeping entry (the "database of checkpoints and
/// their locations" the paper describes).
#[derive(Debug, Clone)]
pub struct CkptRecord {
    pub id: u64,
    pub strategy: Strategy,
    pub bytes_per_node: f64,
    pub nodes: Vec<usize>,
    pub taken_at: SimTime,
    /// Application iteration this checkpoint snapshots (the roll-back
    /// target restart reports).
    pub iter: usize,
    /// CRC-style verification failed (storage-side corruption injection,
    /// DESIGN.md §15): the record stays in the database — SCR only learns
    /// a checkpoint is bad when restart *verifies* it — but
    /// [`Scr::latest_usable`] will never serve it.
    pub corrupted: bool,
    /// Which NAM board holds the parity (NamXor only).
    pub nam_index: Option<usize>,
}

/// Outcome of one checkpoint operation.
#[derive(Debug, Clone, Copy)]
pub struct CkptReport {
    /// Wall time the application was blocked (checkpoint overhead).
    pub blocked: SimTime,
    /// Aggregate checkpoint bandwidth: payload / blocked time.
    pub bandwidth: f64,
    /// Bytes moved over the fabric (diagnostics / ablations).
    pub network_bytes: f64,
}

/// Outcome of a restart operation.
#[derive(Debug, Clone, Copy)]
pub struct RestartReport {
    pub time: SimTime,
    /// True when data for the failed node had to be reconstructed.
    pub rebuilt: bool,
    /// Iteration of the checkpoint actually served — when corruption
    /// forces a fall-back to an older record, this is older than the
    /// newest checkpoint taken.
    pub iter: usize,
}

/// A checkpoint that has been **issued but not yet sealed**: its flows are
/// in flight and the record is *not* in the database until
/// [`Scr::checkpoint_commit`] runs.  This is the handle the multi-level
/// flush state machine holds while the application keeps computing — and
/// the reason a failure mid-flight cleanly falls back to the previous
/// *settled* checkpoint: an uncommitted record can never be restored from.
#[derive(Debug)]
pub struct PendingCkpt {
    /// Completes when the checkpoint is durable at its level.
    pub op: Op,
    record: CkptRecord,
    issued_at: SimTime,
    network_bytes: f64,
}

impl PendingCkpt {
    /// Checkpoint id this pending record will commit as.
    pub fn id(&self) -> u64 {
        self.record.id
    }

    pub fn strategy(&self) -> Strategy {
        self.record.strategy
    }

    /// Virtual time the checkpoint was issued at.
    pub fn issued_at(&self) -> SimTime {
        self.issued_at
    }
}

/// The SCR instance of a job.
#[derive(Debug)]
pub struct Scr {
    pub strategy: Strategy,
    pub group: usize,
    next_id: u64,
    db: Vec<CkptRecord>,
    /// Live parity bytes held per NAM board (rolling window of one).
    nam_alloc: Vec<f64>,
}

impl Scr {
    pub fn new(strategy: Strategy) -> Self {
        Self { strategy, group: DEFAULT_XOR_GROUP, next_id: 0, db: Vec::new(), nam_alloc: Vec::new() }
    }

    pub fn with_group(mut self, group: usize) -> Self {
        assert!(group >= 2, "XOR group needs >= 2 members");
        self.group = group;
        self
    }

    /// Partner of `i` within `n` nodes: cyclic shift (a derangement — no
    /// node partners itself; property-tested).
    pub fn partner_of(i: usize, n: usize) -> usize {
        assert!(n >= 2, "partner checkpointing needs >= 2 nodes");
        (i + 1) % n
    }

    /// Database of checkpoints taken so far.
    pub fn database(&self) -> &[CkptRecord] {
        &self.db
    }

    /// Latest *verified* checkpoint usable after losing `failed` (None =
    /// none usable).  Records that failed CRC verification are skipped —
    /// restart falls back to the deepest verified one, never a corrupted
    /// one.
    pub fn latest_usable(&self, failed: Option<usize>) -> Option<&CkptRecord> {
        self.db.iter().rev().find(|r| {
            !r.corrupted
                && match failed {
                    None => true,
                    Some(_) => r.strategy.survives_node_loss(),
                }
        })
    }

    /// Corruption injection: the newest still-verified checkpoint fails
    /// its CRC.  Repeated calls walk backwards through the database one
    /// record at a time; returns `false` once nothing verified remains.
    pub fn corrupt_latest(&mut self) -> bool {
        match self.db.iter_mut().rev().find(|r| !r.corrupted) {
            Some(r) => {
                r.corrupted = true;
                true
            }
            None => false,
        }
    }

    /// Issue a checkpoint of `bytes_per_node` on `nodes` **without
    /// waiting for durability**: returns a [`PendingCkpt`] whose `op`
    /// completes when the checkpoint is sealed at its level.
    ///
    /// Single-phase strategies (Single, Buddy, NamXor) issue every flow
    /// up front, so the whole checkpoint can overlap compute.  Multi-phase
    /// strategies (Partner, DistXor) perform their intermediate phases —
    /// local write, re-read, exchange/fold — with internal waits (those
    /// serializations *are* the protocols the paper compares) and return
    /// the final durability phase as the pending op.
    pub fn checkpoint_begin(
        &mut self,
        m: &mut Machine,
        nodes: &[usize],
        bytes_per_node: f64,
    ) -> crate::Result<PendingCkpt> {
        self.checkpoint_begin_iter(m, nodes, bytes_per_node, 0)
    }

    /// [`Scr::checkpoint_begin`] with the application iteration stamped
    /// into the record, so restart can report the exact roll-back target
    /// even after corruption forces a fall-back to an older checkpoint.
    pub fn checkpoint_begin_iter(
        &mut self,
        m: &mut Machine,
        nodes: &[usize],
        bytes_per_node: f64,
        iter: usize,
    ) -> crate::Result<PendingCkpt> {
        assert!(!nodes.is_empty());
        let issued_at = m.sim.now();
        let fabric_bytes = nodes.len() as f64 * bytes_per_node;
        let (op, network_bytes, nam_index) = match self.strategy {
            Strategy::Single => {
                (Op::new(self.local_write_flows(m, nodes, bytes_per_node)), 0.0, None)
            }
            Strategy::Partner => {
                (self.partner_ckpt_op(m, nodes, bytes_per_node), fabric_bytes, None)
            }
            Strategy::Buddy => {
                (self.buddy_ckpt_op(m, nodes, bytes_per_node), fabric_bytes, None)
            }
            Strategy::DistXor => {
                (self.dist_xor_ckpt_op(m, nodes, bytes_per_node), fabric_bytes, None)
            }
            Strategy::NamXor => {
                let (op, idx) = self.nam_xor_ckpt_op(m, nodes, bytes_per_node)?;
                (op, fabric_bytes, Some(idx))
            }
        };
        let record = CkptRecord {
            id: self.next_id,
            strategy: self.strategy,
            bytes_per_node,
            nodes: nodes.to_vec(),
            taken_at: f64::INFINITY, // filled in at commit
            iter,
            corrupted: false,
            nam_index,
        };
        self.next_id += 1;
        // Trace: open the checkpoint slice on the owning job's SCR lane
        // (closed by `checkpoint_commit`).  Pure observation — recorded
        // after every flow of the checkpoint has been issued.
        if let Some(tr) = m.sim.trace() {
            tr.with(|r| {
                r.add("scr_ckpts_begun_total", 1.0);
                r.push(crate::obs::SpanEvent {
                    t: issued_at,
                    kind: crate::obs::SpanKind::Begin,
                    pid: m.sim.trace_pid(),
                    tid: crate::obs::lane::SCR,
                    name: "scr.ckpt",
                    attrs: vec![
                        ("id", record.id.into()),
                        ("strategy", record.strategy.name().into()),
                        ("nodes", record.nodes.len().into()),
                        ("bytes_per_node", record.bytes_per_node.into()),
                        ("iter", record.iter.into()),
                    ],
                });
            });
        }
        Ok(PendingCkpt { op, record, issued_at, network_bytes })
    }

    /// Commit a **settled** pending checkpoint into the database; panics
    /// if its op has not completed yet (poll first, or use
    /// [`Scr::checkpoint_finish`]).
    pub fn checkpoint_commit(&mut self, m: &Machine, mut pending: PendingCkpt) -> CkptReport {
        let done_at = m
            .sim
            .op_completion(&pending.op)
            .unwrap_or_else(|| panic!("commit of unsettled checkpoint {}", pending.record.id));
        let done_at = done_at.max(pending.issued_at);
        pending.record.taken_at = done_at;
        let blocked = done_at - pending.issued_at;
        let payload = pending.record.nodes.len() as f64 * pending.record.bytes_per_node;
        let network_bytes = pending.network_bytes;
        // Trace: close the slice opened at begin (works through `&sim` —
        // the recorder has interior mutability precisely so commit, which
        // only holds `&Machine`, can record).
        if let Some(tr) = m.sim.trace() {
            tr.with(|r| {
                r.add("scr_ckpts_committed_total", 1.0);
                r.observe("scr_ckpt_blocked_s", blocked);
                r.push(crate::obs::SpanEvent {
                    t: done_at,
                    kind: crate::obs::SpanKind::End,
                    pid: m.sim.trace_pid(),
                    tid: crate::obs::lane::SCR,
                    name: "scr.ckpt",
                    attrs: Vec::new(),
                });
            });
        }
        self.db.push(pending.record);
        CkptReport {
            blocked,
            bandwidth: payload / blocked.max(1e-12),
            network_bytes,
        }
    }

    /// Wait for a pending checkpoint to seal, then commit it.
    pub fn checkpoint_finish(&mut self, m: &mut Machine, pending: PendingCkpt) -> CkptReport {
        m.sim.wait_op(&pending.op);
        self.checkpoint_commit(m, pending)
    }

    /// Take a checkpoint of `bytes_per_node` on `nodes`, blocking until
    /// durable — a thin shim over [`Scr::checkpoint_begin`] +
    /// [`Scr::checkpoint_finish`].
    ///
    /// Blocks the application for the returned `blocked` time (the paper's
    /// checkpoint overhead); background activity (async flush, NAM pull
    /// tail) may continue beyond it inside the simulator.
    pub fn checkpoint(
        &mut self,
        m: &mut Machine,
        nodes: &[usize],
        bytes_per_node: f64,
    ) -> crate::Result<CkptReport> {
        let pending = self.checkpoint_begin(m, nodes, bytes_per_node)?;
        Ok(self.checkpoint_finish(m, pending))
    }

    /// Blocking checkpoint with the iteration stamped into the record
    /// (see [`Scr::checkpoint_begin_iter`]).
    pub fn checkpoint_iter(
        &mut self,
        m: &mut Machine,
        nodes: &[usize],
        bytes_per_node: f64,
        iter: usize,
    ) -> crate::Result<CkptReport> {
        let pending = self.checkpoint_begin_iter(m, nodes, bytes_per_node, iter)?;
        Ok(self.checkpoint_finish(m, pending))
    }

    /// Restart after `failed_node` died (replacement node = same index,
    /// revived by the caller).  Reads back the newest usable checkpoint.
    pub fn restart(
        &mut self,
        m: &mut Machine,
        nodes: &[usize],
        failed_node: Option<usize>,
    ) -> crate::Result<RestartReport> {
        let rec = self
            .latest_usable(failed_node)
            .ok_or_else(|| anyhow::anyhow!("no usable checkpoint in database"))?
            .clone();
        let t0 = m.sim.now();
        let end = match (rec.strategy, failed_node) {
            // Everyone re-reads its local checkpoint.
            (_, None) => self.read_local_all(m, nodes, rec.bytes_per_node),
            (Strategy::Single, Some(_)) => unreachable!("latest_usable filtered"),
            (Strategy::Partner | Strategy::Buddy, Some(f)) => {
                // Survivors read locally; the replacement pulls its copy
                // from the partner's storage over the fabric.
                let survivors: Vec<usize> =
                    nodes.iter().copied().filter(|&n| n != f).collect();
                let mut op = Op::new(self.read_local_flows(m, &survivors, rec.bytes_per_node));
                let pos = nodes.iter().position(|&n| n == f).unwrap();
                let partner = nodes[Self::partner_of(pos, nodes.len())];
                let rf = m.nodes[partner].nvme.as_ref().unwrap().read_op(
                    &mut m.sim,
                    rec.bytes_per_node,
                    4,
                    &[],
                );
                m.sim.wait_op(&rf);
                op.join(sionlib::buddy_stream_op(m, partner, f, rec.bytes_per_node));
                m.sim.wait_op(&op)
            }
            (Strategy::DistXor, Some(f)) => {
                self.xor_rebuild(m, nodes, f, rec.bytes_per_node, None)
            }
            (Strategy::NamXor, Some(f)) => {
                self.xor_rebuild(m, nodes, f, rec.bytes_per_node, rec.nam_index)
            }
        };
        if let Some(tr) = m.sim.trace() {
            tr.with(|r| {
                r.add("scr_restarts_total", 1.0);
                r.observe("scr_restart_s", end - t0);
                r.push(crate::obs::SpanEvent {
                    t: end,
                    kind: crate::obs::SpanKind::Instant,
                    pid: m.sim.trace_pid(),
                    tid: crate::obs::lane::SCR,
                    name: "scr.restart",
                    attrs: vec![
                        ("strategy", rec.strategy.name().into()),
                        ("iter", rec.iter.into()),
                        ("rebuilt", u64::from(failed_node.is_some()).into()),
                    ],
                });
            });
        }
        Ok(RestartReport { time: end - t0, rebuilt: failed_node.is_some(), iter: rec.iter })
    }

    // ------------------------------------------------------------------
    // strategy write paths
    // ------------------------------------------------------------------

    /// QoS: local checkpoint writes/reads are [`TrafficClass::CkptLocal`]
    /// unless a more specific ambient class is set (the XOR strategies
    /// run their parity phases under [`TrafficClass::Parity`]).
    fn local_write_flows(
        &self,
        m: &mut Machine,
        nodes: &[usize],
        bytes: f64,
    ) -> Vec<FlowId> {
        let prev = m.sim.default_issue_class(TrafficClass::CkptLocal);
        let flows = nodes
            .iter()
            .map(|&n| {
                let dev = m.nodes[n]
                    .nvme
                    .as_ref()
                    .unwrap_or_else(|| panic!("node {n} has no NVMe for checkpoints"));
                dev.write(&mut m.sim, bytes, 4, &[])
            })
            .collect();
        m.sim.set_issue_class(prev);
        flows
    }

    fn read_local_flows(&self, m: &mut Machine, nodes: &[usize], bytes: f64) -> Vec<FlowId> {
        let prev = m.sim.default_issue_class(TrafficClass::CkptLocal);
        let flows = nodes
            .iter()
            .map(|&n| {
                let dev = m.nodes[n].nvme.as_ref().unwrap();
                dev.read(&mut m.sim, bytes, 4, &[])
            })
            .collect();
        m.sim.set_issue_class(prev);
        flows
    }

    fn write_local_all(&self, m: &mut Machine, nodes: &[usize], bytes: f64) -> SimTime {
        let flows = self.local_write_flows(m, nodes, bytes);
        m.sim.wait_all(&flows)
    }

    fn read_local_all(&self, m: &mut Machine, nodes: &[usize], bytes: f64) -> SimTime {
        let flows = self.read_local_flows(m, nodes, bytes);
        m.sim.wait_all(&flows)
    }

    /// SCR_PARTNER: local write -> local re-read -> send -> partner write.
    /// The first two phases serialize (the protocol's store-and-forward
    /// steps); the partner streams are returned as the pending op.
    fn partner_ckpt_op(&self, m: &mut Machine, nodes: &[usize], bytes: f64) -> Op {
        // Phase 1: everyone writes locally.
        self.write_local_all(m, nodes, bytes);
        // Phase 2: everyone re-reads its own checkpoint (the step Buddy
        // removes).
        self.read_local_all(m, nodes, bytes);
        // Phase 3: stream to partner; partner writes to its NVMe.
        let mut op = Op::done();
        for i in 0..nodes.len() {
            let buddy = nodes[Self::partner_of(i, nodes.len())];
            op.join(sionlib::buddy_stream_op(m, nodes[i], buddy, bytes));
        }
        op
    }

    /// DEEP-ER Buddy: local write || direct memory->buddy SIONlib stream.
    /// Single-phase: everything is issued up front as one op.
    fn buddy_ckpt_op(&self, m: &mut Machine, nodes: &[usize], bytes: f64) -> Op {
        let mut op = Op::new(self.local_write_flows(m, nodes, bytes));
        for i in 0..nodes.len() {
            let buddy = nodes[Self::partner_of(i, nodes.len())];
            op.join(sionlib::buddy_stream_op(m, nodes[i], buddy, bytes));
        }
        op
    }

    /// SCR Distributed XOR: local write -> re-read -> reduce-scatter XOR
    /// on the node CPUs -> parity write to local NVMe.  Phases 1-3
    /// serialize; the final parity write is returned as the pending op.
    fn dist_xor_ckpt_op(&self, m: &mut Machine, nodes: &[usize], bytes: f64) -> Op {
        let k = self.group.min(nodes.len()).max(2);
        // Phase 1+2: local write and re-read (parity needs the data back).
        self.write_local_all(m, nodes, bytes);
        self.read_local_all(m, nodes, bytes);
        // Phases 3+4 are parity traffic (the reduce-scatter keeps this
        // class through psmpi's ring exchange).
        let prev = m.sim.default_issue_class(TrafficClass::Parity);
        // Phase 3: pipelined reduce-scatter within each XOR group — each
        // node sends ~bytes over the ring and XOR-folds on the CPU.
        for group in nodes.chunks(k) {
            if group.len() < 2 {
                continue;
            }
            let comm = Comm::of(group.to_vec());
            comm.ring_exchange(m, bytes * (group.len() as f64 - 1.0) / group.len() as f64);
            // CPU XOR fold, overlapped across nodes (concurrent flows).
            let folds = Op::new(
                group
                    .iter()
                    .map(|&n| {
                        let cpu = m.nodes[n].cpu;
                        m.sim.flow(bytes * NODE_XOR_FLOP_PER_BYTE, 0.0, &[cpu])
                    })
                    .collect(),
            );
            m.sim.wait_op(&folds);
        }
        // Phase 4: parity segment (bytes/(k-1)) written locally.
        let parity = bytes / (k as f64 - 1.0);
        let op = Op::new(self.local_write_flows(m, nodes, parity));
        m.sim.set_issue_class(prev);
        op
    }

    /// DEEP-ER NAM XOR: local write || FPGA pulls data + folds parity on
    /// the NAM.  Node CPUs and NVMe see only the local write.
    /// Single-phase: local writes and FPGA pulls are all issued up front.
    ///
    /// Parity is **striped across all NAM boards** (libNAM addresses the
    /// whole NAM pool, Section II-B2): each board pulls `bytes / n_boards`
    /// from every node, which both aggregates the pull bandwidth of the
    /// two-board prototype and lets checkpoints larger than one 2 GB HMC
    /// fit the pool.
    fn nam_xor_ckpt_op(
        &mut self,
        m: &mut Machine,
        nodes: &[usize],
        bytes: f64,
    ) -> crate::Result<(Op, usize)> {
        if m.nams.is_empty() {
            anyhow::bail!("machine has no NAM board; NamXor unavailable");
        }
        let n_boards = m.nams.len();
        let shard = bytes / n_boards as f64;
        // Recycle parity space from the previous NamXor checkpoint (SCR
        // keeps a rolling window of one on the small HMCs).
        if self.nam_alloc.len() != n_boards {
            self.nam_alloc = vec![0.0; n_boards];
        }
        for (i, alloc) in self.nam_alloc.iter_mut().enumerate() {
            if *alloc > 0.0 {
                m.nams[i].release_parity(*alloc);
                *alloc = 0.0;
            }
        }
        let mut op = Op::new(self.local_write_flows(m, nodes, bytes));
        let eps: Vec<_> = nodes.iter().map(|&n| m.nodes[n].ep).collect();
        // Split the NAM borrow from the machine borrow.
        let (sim, fabric, nams) = (&mut m.sim, &m.fabric, &mut m.nams);
        for (i, nam) in nams.iter_mut().enumerate() {
            let pulls = nam.pull_and_xor(sim, fabric, &eps, shard)?;
            self.nam_alloc[i] = shard;
            op.join(pulls);
        }
        Ok((op, 0))
    }

    /// Rebuild a lost node's checkpoint from parity + survivors.
    /// `nam_index`: Some => parity streams from the NAM (no survivor NVMe
    /// re-read: the FPGA still holds parity); None => Distributed XOR
    /// (survivors re-read their local blocks first).
    fn xor_rebuild(
        &self,
        m: &mut Machine,
        nodes: &[usize],
        failed: usize,
        bytes: f64,
        nam_index: Option<usize>,
    ) -> SimTime {
        let k = self.group.min(nodes.len()).max(2);
        let group: Vec<usize> = nodes
            .chunks(k)
            .find(|g| g.contains(&failed))
            .map(|g| g.to_vec())
            .unwrap_or_else(|| nodes.to_vec());
        let survivors: Vec<usize> = group.iter().copied().filter(|&n| n != failed).collect();
        // Survivors of other groups read their local checkpoints in
        // parallel with the rebuild.
        let others: Vec<usize> = nodes
            .iter()
            .copied()
            .filter(|n| !group.contains(n))
            .collect();
        let mut op = Op::new(self.read_local_flows(m, &others, bytes));
        let prev = m.sim.default_issue_class(TrafficClass::Parity);
        match nam_index {
            Some(_) => {
                // NAM boards stream their parity shards; survivors stream
                // blocks from memory (they still hold the state) — the
                // replacement XOR-folds on the fly.
                let dst = m.nodes[failed].ep;
                let n_boards = m.nams.len().max(1);
                let shard = bytes / n_boards as f64;
                let (sim, fabric, nams) = (&mut m.sim, &m.fabric, &mut m.nams);
                for nam in nams.iter() {
                    op.join(nam.push_parity(sim, fabric, dst, shard));
                }
                for &s in &survivors {
                    let sep = m.nodes[s].ep;
                    op.push(m.fabric.put(&mut m.sim, sep, dst, bytes));
                }
            }
            None => {
                // Survivors re-read local blocks, then incast to the
                // replacement which XOR-folds.
                let rf = Op::new(self.read_local_flows(m, &survivors, bytes));
                m.sim.wait_op(&rf);
                let dst = m.nodes[failed].ep;
                for &s in &survivors {
                    let sep = m.nodes[s].ep;
                    op.push(m.fabric.put(&mut m.sim, sep, dst, bytes));
                }
                let cpu = m.nodes[failed].cpu;
                let xor = m
                    .sim
                    .flow(bytes * survivors.len() as f64 * NODE_XOR_FLOP_PER_BYTE, 0.0, &[cpu]);
                op.push(xor);
            }
        }
        m.sim.set_issue_class(prev);
        // Survivors in the failed group also re-read their own state for
        // the rollback itself.
        op.join(Op::new(self.read_local_flows(m, &survivors, bytes)));
        m.sim.wait_op(&op)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::presets;

    fn machine() -> Machine {
        Machine::build(presets::deep_er())
    }

    fn cluster_nodes(m: &Machine) -> Vec<usize> {
        m.nodes_of(crate::system::NodeKind::Cluster)
    }

    fn ckpt_time(strategy: Strategy, bytes: f64) -> f64 {
        let mut m = machine();
        let nodes = cluster_nodes(&m);
        let mut scr = Scr::new(strategy);
        scr.checkpoint(&mut m, &nodes, bytes).unwrap().blocked
    }

    #[test]
    fn paper_ordering_single_fastest() {
        let bytes = 2e9;
        let single = ckpt_time(Strategy::Single, bytes);
        for s in [Strategy::Partner, Strategy::Buddy, Strategy::DistXor] {
            assert!(ckpt_time(s, bytes) > single, "{s:?} faster than Single");
        }
    }

    #[test]
    fn fig4_buddy_faster_than_partner() {
        let bytes = 2e9;
        let partner = ckpt_time(Strategy::Partner, bytes);
        let buddy = ckpt_time(Strategy::Buddy, bytes);
        assert!(buddy < partner, "buddy={buddy} partner={partner}");
    }

    #[test]
    fn fig4_nam_xor_faster_than_dist_xor() {
        let bytes = 2e9;
        let dist = ckpt_time(Strategy::DistXor, bytes);
        let nam = ckpt_time(Strategy::NamXor, bytes);
        assert!(nam < dist, "nam={nam} dist={dist}");
    }

    #[test]
    fn fig9_nam_xor_bandwidth_2_to_3x() {
        let bytes = 2e9; // Table III: xPic NAM experiment, 2 GB per CP
        let mut m1 = machine();
        let nodes = cluster_nodes(&m1);
        let mut dist = Scr::new(Strategy::DistXor);
        let r_dist = dist.checkpoint(&mut m1, &nodes, bytes).unwrap();
        let mut m2 = machine();
        let mut nam = Scr::new(Strategy::NamXor);
        let r_nam = nam.checkpoint(&mut m2, &nodes, bytes).unwrap();
        let ratio = r_nam.bandwidth / r_dist.bandwidth;
        assert!(
            (1.8..=4.0).contains(&ratio),
            "bandwidth ratio {ratio:.2} outside Fig. 9 band"
        );
        // Time saving 50-65% per the paper.
        let saving = 1.0 - r_nam.blocked / r_dist.blocked;
        assert!(
            (0.40..=0.75).contains(&saving),
            "time saving {saving:.2} outside Fig. 9 band"
        );
    }

    #[test]
    fn async_begin_finish_matches_blocking_checkpoint() {
        let bytes = 2e9;
        for strat in Strategy::ALL {
            let mut m1 = machine();
            let nodes = cluster_nodes(&m1);
            let mut s1 = Scr::new(strat);
            let r1 = s1.checkpoint(&mut m1, &nodes, bytes).unwrap();
            let mut m2 = machine();
            let mut s2 = Scr::new(strat);
            let pending = s2.checkpoint_begin(&mut m2, &nodes, bytes).unwrap();
            assert_eq!(pending.id(), 0);
            assert_eq!(pending.strategy(), strat);
            assert!(s2.database().is_empty(), "no commit before settle");
            let r2 = s2.checkpoint_finish(&mut m2, pending);
            assert!(
                (r1.blocked - r2.blocked).abs() < 1e-9,
                "{strat:?}: blocking {} vs begin/finish {}",
                r1.blocked,
                r2.blocked
            );
            assert_eq!(s2.database().len(), 1);
        }
    }

    #[test]
    #[should_panic(expected = "commit of unsettled checkpoint")]
    fn commit_before_settle_panics() {
        let mut m = machine();
        let nodes = cluster_nodes(&m);
        let mut scr = Scr::new(Strategy::Buddy);
        let pending = scr.checkpoint_begin(&mut m, &nodes, 1e9).unwrap();
        let _ = scr.checkpoint_commit(&m, pending);
    }

    #[test]
    fn storage_factors() {
        assert_eq!(Strategy::Single.storage_factor(8), 1.0);
        assert_eq!(Strategy::Partner.storage_factor(8), 2.0);
        assert!((Strategy::DistXor.storage_factor(8) - (1.0 + 1.0 / 7.0)).abs() < 1e-12);
        assert_eq!(Strategy::NamXor.storage_factor(8), 1.0);
    }

    #[test]
    fn partner_map_is_derangement() {
        for n in 2..64 {
            for i in 0..n {
                let p = Scr::partner_of(i, n);
                assert_ne!(p, i);
                assert!(p < n);
            }
        }
    }

    #[test]
    fn restart_after_node_loss_partner() {
        let mut m = machine();
        let nodes = cluster_nodes(&m);
        let mut scr = Scr::new(Strategy::Partner);
        scr.checkpoint(&mut m, &nodes, 1e9).unwrap();
        m.kill_node(nodes[3]);
        m.revive_node(nodes[3]); // replacement in place
        let r = scr.restart(&mut m, &nodes, Some(nodes[3])).unwrap();
        assert!(r.rebuilt);
        assert!(r.time > 0.0);
    }

    #[test]
    fn single_cannot_restart_after_node_loss() {
        let mut m = machine();
        let nodes = cluster_nodes(&m);
        let mut scr = Scr::new(Strategy::Single);
        scr.checkpoint(&mut m, &nodes, 1e9).unwrap();
        assert!(scr.restart(&mut m, &nodes, Some(nodes[0])).is_err());
        // ...but transient-error restart works.
        assert!(scr.restart(&mut m, &nodes, None).is_ok());
    }

    #[test]
    fn nam_xor_recycles_hmc_space() {
        let mut m = machine();
        let nodes = cluster_nodes(&m);
        let mut scr = Scr::new(Strategy::NamXor);
        // 11 checkpoints of 1.9 GB: without recycling the 2 GB HMC would
        // overflow immediately on the second one (same board reused after
        // round-robin over 2 boards).
        for _ in 0..11 {
            scr.checkpoint(&mut m, &nodes, 1.9e9).unwrap();
        }
        assert_eq!(scr.database().len(), 11);
    }

    #[test]
    fn nam_xor_errors_without_nam() {
        let m = Machine::build(presets::qpace3().with_cluster_nodes(8));
        let nodes: Vec<usize> = (0..8).collect();
        let scr = Scr::new(Strategy::NamXor);
        // QPACE3 has no NVMe either, so use a DEEP-ER machine without NAM:
        let _ = scr; // the qpace3 preset lacks NVMe; rebuild with deep_er
        let mut spec = presets::deep_er();
        spec.n_nam = 0;
        let mut m2 = Machine::build(spec);
        let nodes2: Vec<usize> = m2.nodes_of(crate::system::NodeKind::Cluster);
        let mut scr2 = Scr::new(Strategy::NamXor);
        assert!(scr2.checkpoint(&mut m2, &nodes2, 1e9).is_err());
        drop(m);
        drop(nodes);
    }

    #[test]
    fn xor_rebuild_restores_after_loss() {
        for strat in [Strategy::DistXor, Strategy::NamXor] {
            let mut m = machine();
            let nodes = cluster_nodes(&m);
            let mut scr = Scr::new(strat);
            scr.checkpoint(&mut m, &nodes, 1e9).unwrap();
            m.kill_node(nodes[5]);
            m.revive_node(nodes[5]);
            let r = scr.restart(&mut m, &nodes, Some(nodes[5])).unwrap();
            assert!(r.rebuilt, "{strat:?}");
            assert!(r.time > 0.0, "{strat:?}");
        }
    }

    #[test]
    fn corrupted_checkpoint_falls_back_to_previous_verified() {
        let mut m = machine();
        let nodes = cluster_nodes(&m);
        let mut scr = Scr::new(Strategy::Buddy);
        scr.checkpoint_iter(&mut m, &nodes, 1e9, 10).unwrap();
        scr.checkpoint_iter(&mut m, &nodes, 1e9, 20).unwrap();
        assert_eq!(scr.latest_usable(None).unwrap().iter, 20);
        assert!(scr.corrupt_latest());
        // Restart skips the corrupted iter-20 record and serves iter 10.
        assert_eq!(scr.latest_usable(None).unwrap().iter, 10);
        let r = scr.restart(&mut m, &nodes, Some(nodes[2])).unwrap();
        assert_eq!(r.iter, 10);
        // Corrupt the remaining record: nothing verified is left.
        assert!(scr.corrupt_latest(), "walks back to the iter-10 record");
        assert!(!scr.corrupt_latest(), "database exhausted");
        assert!(scr.latest_usable(None).is_none());
        assert!(scr.restart(&mut m, &nodes, None).is_err());
    }

    #[test]
    fn latest_usable_respects_failure_kind() {
        let mut m = machine();
        let nodes = cluster_nodes(&m);
        let mut scr = Scr::new(Strategy::Single);
        scr.checkpoint(&mut m, &nodes, 1e8).unwrap();
        assert!(scr.latest_usable(None).is_some());
        assert!(scr.latest_usable(Some(0)).is_none());
    }
}

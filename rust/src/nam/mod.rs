//! Network Attached Memory: HMC + FPGA board on the EXTOLL fabric.
//!
//! Paper Section II-B2: the NAM combines Hybrid Memory Cube memory with a
//! Xilinx Virtex 7 FPGA exposing three functions — an HMC controller, an
//! EXTOLL NIC with **two full-speed Tourmalet links**, and the NAM logic.
//! It is *fully autonomous*: PCIe is power/debug only, all data moves via
//! RDMA without any remote CPU.  Each DEEP-ER board holds 2 GB (an HMC
//! generation limit the paper calls out — enforced here via device
//! capacity).
//!
//! [`LibNam`] mirrors the libNAM client API from the paper (put/get over
//! ring-buffered send/recv with notification-managed space, styled after
//! EXTOLL's libRMA), and [`NamDevice::pull_and_xor`] is the checkpoint
//! use-case: the FPGA pulls blocks from the compute nodes and folds parity
//! locally, which is what the *NAM XOR* SCR strategy offloads (Fig. 9).
//! The parity datapath itself is the `xor_parity` Pallas kernel at L1 —
//! `nam_parity.hlo.txt` — executed for real by the e2e example.

use crate::fabric::ring::RingBuffer;
use crate::fabric::{EpId, Fabric, LAT_CLUSTER, MSG_OVERHEAD, TOURMALET_BW};
use crate::sim::{FlowId, Op, Sim, SimTime, TrafficClass};
use crate::storage::{Device, DeviceParams};

/// FPGA pipeline setup per parity job (command decode, DMA programming).
pub const FPGA_JOB_OVERHEAD: SimTime = 5e-6;
/// HMC streaming bandwidth available to the NAM logic.
pub const HMC_BW: f64 = 30e9;
/// HMC capacity per DEEP-ER NAM board.
pub const HMC_CAPACITY: f64 = 2e9;

/// A NAM board instantiated on the fabric.
#[derive(Debug)]
pub struct NamDevice {
    /// Fabric endpoint aggregating the two Tourmalet links.
    pub ep: EpId,
    /// HMC memory behind the FPGA (read/write channels + capacity).
    pub hmc: Device,
    pub index: usize,
}

impl NamDevice {
    pub fn new(sim: &mut Sim, fabric: &mut Fabric, index: usize) -> Self {
        // Two full-speed links aggregated into one endpoint.
        let ep = fabric.endpoint(sim, &format!("nam{index}"), 2.0 * TOURMALET_BW, LAT_CLUSTER);
        let hmc = Device::new(
            sim,
            DeviceParams {
                name: "nam-hmc",
                read_bw: HMC_BW,
                write_bw: HMC_BW,
                op_latency: 0.3e-6,
                op_overhead: 0.1e-6,
                qd1_efficiency: 1.0,
                capacity: HMC_CAPACITY,
            },
            &format!("nam{index}"),
        );
        Self { ep, hmc, index }
    }

    /// RDMA put into NAM memory as an [`Op`] handle: fabric transfer +
    /// HMC write, one flow routed through both (the slower stage is the
    /// bottleneck, as on the real board where the HMC controller outruns
    /// two Tourmalet links).
    pub fn put_op(&self, sim: &mut Sim, fabric: &Fabric, src: EpId, bytes: f64) -> Op {
        let s = fabric.endpoint_info(src);
        let d = fabric.endpoint_info(self.ep);
        let lat = s.latency + d.latency + MSG_OVERHEAD + FPGA_JOB_OVERHEAD;
        let mut route = fabric.path(src, self.ep);
        route.push(self.hmc.write_res());
        Op::single(sim.flow(bytes, lat, &route))
    }

    /// RDMA get from NAM memory as an [`Op`] handle.
    pub fn get_op(&self, sim: &mut Sim, fabric: &Fabric, dst: EpId, bytes: f64) -> Op {
        let s = fabric.endpoint_info(dst);
        let d = fabric.endpoint_info(self.ep);
        let lat = 2.0 * d.latency + s.latency + MSG_OVERHEAD + FPGA_JOB_OVERHEAD;
        // Data path NAM -> dst, fronted by the HMC read stage.
        let mut route = vec![self.hmc.read_res()];
        route.extend(fabric.path(self.ep, dst));
        Op::single(sim.flow(bytes, lat, &route))
    }

    /// Flow-level shim over [`NamDevice::put_op`].
    pub fn put(&self, sim: &mut Sim, fabric: &Fabric, src: EpId, bytes: f64) -> FlowId {
        self.put_op(sim, fabric, src, bytes).flows()[0]
    }

    /// Flow-level shim over [`NamDevice::get_op`].
    pub fn get(&self, sim: &mut Sim, fabric: &Fabric, dst: EpId, bytes: f64) -> FlowId {
        self.get_op(sim, fabric, dst, bytes).flows()[0]
    }

    /// The NAM-XOR offload: the FPGA *pulls* `bytes_per_node` from every
    /// source node and streams the XOR into HMC-resident parity.
    ///
    /// Returns the pull [`Op`] (parity is sealed when it completes) —
    /// node CPUs are NOT involved, which is exactly why the strategy
    /// wins in Fig. 9.  Errors if parity would exceed the 2 GB HMC.
    pub fn pull_and_xor(
        &mut self,
        sim: &mut Sim,
        fabric: &Fabric,
        sources: &[EpId],
        bytes_per_node: f64,
    ) -> crate::Result<Op> {
        self.hmc.allocate(bytes_per_node)?; // parity block only
        // QoS: parity pulls are their own traffic class (what the NAM
        // strategy offloads; shaped independently of checkpoint flushes).
        let prev = sim.default_issue_class(TrafficClass::Parity);
        let mut op = Op::done();
        for &src in sources {
            let s = fabric.endpoint_info(src);
            let d = fabric.endpoint_info(self.ep);
            let lat = 2.0 * d.latency + s.latency + MSG_OVERHEAD + FPGA_JOB_OVERHEAD;
            // Route: source NIC tx -> fabric interior -> NAM links -> HMC
            // write (XOR is folded at stream rate by the FPGA pipeline).
            let mut route = fabric.path(src, self.ep);
            route.push(self.hmc.write_res());
            op.push(sim.flow(bytes_per_node, lat, &route));
        }
        sim.set_issue_class(prev);
        if let Some(tr) = sim.trace() {
            let pid = sim.trace_pid();
            let now = sim.now();
            tr.with(|r| {
                r.add("nam_parity_pulls_total", 1.0);
                r.add("nam_parity_bytes_total", sources.len() as f64 * bytes_per_node);
                r.push(crate::obs::SpanEvent {
                    t: now,
                    kind: crate::obs::SpanKind::Instant,
                    pid,
                    tid: crate::obs::lane::IO,
                    name: "nam.parity_pull",
                    attrs: vec![
                        ("sources", sources.len().into()),
                        ("bytes_per_node", bytes_per_node.into()),
                    ],
                });
            });
        }
        Ok(op)
    }

    /// Release a sealed parity region (checkpoint retired).
    pub fn release_parity(&mut self, bytes: f64) {
        self.hmc.release(bytes);
    }

    /// Reconstruction after a node loss: NAM streams parity to the
    /// replacement node while the survivors stream their blocks (the
    /// replacement XORs on the fly).
    pub fn push_parity(&self, sim: &mut Sim, fabric: &Fabric, dst: EpId, bytes: f64) -> Op {
        let prev = sim.default_issue_class(TrafficClass::Parity);
        let op = self.get_op(sim, fabric, dst, bytes);
        sim.set_issue_class(prev);
        if let Some(tr) = sim.trace() {
            tr.instant(
                sim.now(),
                sim.trace_pid(),
                crate::obs::lane::IO,
                "nam.parity_push",
                vec![("bytes", bytes.into())],
            );
        }
        op
    }
}

/// libNAM client: ring-buffered put/get with notification-managed space
/// (paper: "send and receive buffers organized in a ring structure").
#[derive(Debug)]
pub struct LibNam {
    pub send_ring: RingBuffer,
    pub recv_ring: RingBuffer,
    /// In-flight put flows in claim order (retired on notification).
    outstanding: std::collections::VecDeque<FlowId>,
}

/// Default libNAM ring geometry: 16 slots of 512 KB.
pub const RING_SLOTS: usize = 16;
pub const RING_SLOT_BYTES: usize = 512 * 1024;

impl Default for LibNam {
    fn default() -> Self {
        Self::new()
    }
}

impl LibNam {
    pub fn new() -> Self {
        Self {
            send_ring: RingBuffer::new(RING_SLOTS, RING_SLOT_BYTES),
            recv_ring: RingBuffer::new(RING_SLOTS, RING_SLOT_BYTES),
            outstanding: std::collections::VecDeque::new(),
        }
    }

    /// Put `bytes` to the NAM.  If the send ring is out of credits the
    /// caller first drains the oldest outstanding transfer (blocking on
    /// its notification) — that wait is the back-pressure the paper's
    /// ring scheme creates.
    pub fn put(
        &mut self,
        sim: &mut Sim,
        fabric: &Fabric,
        nam: &NamDevice,
        src: EpId,
        bytes: f64,
    ) -> FlowId {
        while self.send_ring.claim(bytes as usize).is_err() {
            // Ring full: wait for the oldest notification, retire its slots.
            let oldest = self
                .outstanding
                .pop_front()
                .expect("ring full with no outstanding transfers");
            sim.wait_all(&[oldest]);
            self.send_ring.retire_oldest();
        }
        let f = nam.put(sim, fabric, src, bytes);
        self.outstanding.push_back(f);
        f
    }

    /// Get `bytes` from the NAM through the receive ring.
    pub fn get(
        &mut self,
        sim: &mut Sim,
        fabric: &Fabric,
        nam: &NamDevice,
        dst: EpId,
        bytes: f64,
    ) -> FlowId {
        while self.recv_ring.claim(bytes as usize).is_err() {
            let oldest = self
                .outstanding
                .pop_front()
                .expect("ring full with no outstanding transfers");
            sim.wait_all(&[oldest]);
            self.recv_ring.retire_oldest();
        }
        let f = nam.get(sim, fabric, dst, bytes);
        self.outstanding.push_back(f);
        f
    }

    /// Drain all outstanding notifications (quiesce).
    pub fn fence(&mut self, sim: &mut Sim) {
        while let Some(f) = self.outstanding.pop_front() {
            sim.wait_all(&[f]);
            if !self.send_ring.is_empty() {
                self.send_ring.retire_oldest();
            } else if !self.recv_ring.is_empty() {
                self.recv_ring.retire_oldest();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (Sim, Fabric, NamDevice, EpId) {
        let mut sim = Sim::new();
        let mut fabric = Fabric::new(&mut sim, 1e12);
        let node = fabric.endpoint(&mut sim, "n0", TOURMALET_BW, LAT_CLUSTER);
        let nam = NamDevice::new(&mut sim, &mut fabric, 0);
        (sim, fabric, nam, node)
    }

    #[test]
    fn put_bandwidth_close_to_link_speed() {
        let (mut sim, fabric, nam, node) = setup();
        let bytes = 256e6;
        let f = nam.put(&mut sim, &fabric, node, bytes);
        let t = sim.wait_all(&[f]);
        let bw = bytes / t;
        // Bounded by the single node link (12.5 GB/s), close to it (Fig. 3).
        assert!(bw > 0.95 * TOURMALET_BW && bw <= TOURMALET_BW, "bw={bw:e}");
    }

    #[test]
    fn small_put_latency_near_network_floor() {
        let (mut sim, fabric, nam, node) = setup();
        let f = nam.put(&mut sim, &fabric, node, 8.0);
        let t = sim.wait_all(&[f]);
        assert!(t < 10e-6, "t={t}");
        assert!(t > 2e-6, "t={t}");
    }

    #[test]
    fn two_nodes_saturate_both_links() {
        let mut sim = Sim::new();
        let mut fabric = Fabric::new(&mut sim, 1e12);
        let nam = NamDevice::new(&mut sim, &mut fabric, 0);
        let flows: Vec<_> = (0..4)
            .map(|i| {
                let n = fabric.endpoint(&mut sim, &format!("n{i}"), TOURMALET_BW, LAT_CLUSTER);
                nam.put(&mut sim, &fabric, n, 1e9)
            })
            .collect();
        let t = sim.wait_all(&flows);
        let agg = 4e9 / t;
        // Four 12.5 GB/s senders against two NAM links = 25 GB/s ceiling.
        assert!(agg < 25.5e9 && agg > 23e9, "agg={agg:e}");
    }

    #[test]
    fn parity_capacity_enforced() {
        let (mut sim, fabric, mut nam, node) = setup();
        let srcs = vec![node];
        assert!(nam.pull_and_xor(&mut sim, &fabric, &srcs, 1.5e9).is_ok());
        // Second 1.5 GB parity exceeds the 2 GB HMC.
        assert!(nam.pull_and_xor(&mut sim, &fabric, &srcs, 1.5e9).is_err());
        nam.release_parity(1.5e9);
        assert!(nam.pull_and_xor(&mut sim, &fabric, &srcs, 1.5e9).is_ok());
    }

    #[test]
    fn pull_and_xor_uses_no_node_cpu() {
        // The pull flows route through NICs + HMC only; this test pins the
        // structural claim by checking total time matches the link model.
        let mut sim = Sim::new();
        let mut fabric = Fabric::new(&mut sim, 1e12);
        let mut nam = NamDevice::new(&mut sim, &mut fabric, 0);
        let srcs: Vec<_> = (0..8)
            .map(|i| fabric.endpoint(&mut sim, &format!("n{i}"), TOURMALET_BW, LAT_CLUSTER))
            .collect();
        let pulls = nam.pull_and_xor(&mut sim, &fabric, &srcs, 250e6).unwrap();
        let t = sim.wait_op(&pulls);
        // 8 x 250 MB = 2 GB through 25 GB/s of NAM links ~ 80 ms.
        assert!((t - 0.08).abs() / 0.08 < 0.05, "t={t}");
    }

    #[test]
    fn libnam_ring_backpressure() {
        let (mut sim, fabric, nam, node) = setup();
        let mut lib = LibNam::new();
        // 64 puts of 512 KB: ring holds 16; later puts must recycle slots.
        let mut last = None;
        for _ in 0..64 {
            last = Some(lib.put(&mut sim, &fabric, &nam, node, 512.0 * 1024.0));
        }
        let t = sim.wait_all(&[last.unwrap()]);
        assert!(t > 0.0);
        lib.fence(&mut sim);
        assert!(lib.send_ring.in_flight() == 0);
    }

    #[test]
    fn get_roundtrip_latency_exceeds_put() {
        let (mut sim, fabric, nam, node) = setup();
        let p = nam.put(&mut sim, &fabric, node, 64.0);
        let t_put = sim.wait_all(&[p]);
        let g = nam.get(&mut sim, &fabric, node, 64.0);
        let t_get = sim.wait_all(&[g]) - t_put;
        assert!(t_get > t_put, "put={t_put} get={t_get}");
    }
}

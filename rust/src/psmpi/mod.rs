//! ParaStation-style global MPI: communicators, collectives, spawn-offload,
//! and the process-management daemon (PMD).
//!
//! Paper Sections III-A and III-D2: ParaStation MPI runs a *global MPI*
//! across Cluster and Booster; `MPI_Comm_spawn` realizes the offload
//! mechanism that launches process groups on the other side of the
//! machine.  For DEEP-ER the process-management daemon gained an interface
//! to *"detect, isolate and clean up failures of MPI-offloaded tasks,
//! which can then be independently restarted without requiring a full
//! application recovery"* — the foundation of the OmpSs resilient offload
//! evaluated in Fig. 10.

use crate::fabric::EpId;
use crate::sim::{FlowId, Op, SimTime, TrafficClass};
use crate::system::Machine;

/// Time to launch a spawned process group (fork/exec + wire-up), per node.
pub const SPAWN_COST_PER_NODE: SimTime = 120e-3;
/// Fixed collective software overhead per algorithm round.
pub const COLL_ROUND_COST: SimTime = 2e-6;
/// PMD heartbeat interval: failure detection latency upper bound.
pub const PMD_HEARTBEAT: SimTime = 100e-3;
/// Cleanup cost after an isolated offload-group failure (kill + reap).
pub const PMD_CLEANUP: SimTime = 250e-3;

/// A communicator: an ordered set of node indices (one rank per node; the
/// within-node ranks share the NIC so node granularity is what matters for
/// fabric behaviour).
#[derive(Debug, Clone)]
pub struct Comm {
    pub nodes: Vec<usize>,
}

impl Comm {
    pub fn world(m: &Machine) -> Self {
        Self { nodes: (0..m.nodes.len()).collect() }
    }

    pub fn of(nodes: Vec<usize>) -> Self {
        assert!(!nodes.is_empty(), "empty communicator");
        Self { nodes }
    }

    pub fn size(&self) -> usize {
        self.nodes.len()
    }

    pub fn node_of_rank(&self, rank: usize) -> usize {
        self.nodes[rank]
    }

    fn ep(&self, m: &Machine, rank: usize) -> EpId {
        m.nodes[self.nodes[rank]].ep
    }

    /// Point-to-point send: rank -> rank, `bytes`.
    pub fn send(&self, m: &mut Machine, from: usize, to: usize, bytes: f64) -> FlowId {
        let (src, dst) = (self.ep(m, from), self.ep(m, to));
        m.fabric.put(&mut m.sim, src, dst, bytes)
    }

    /// Non-blocking send (`MPI_Isend` shape): the returned [`Op`]
    /// completes when the message has been delivered.
    pub fn isend(&self, m: &mut Machine, from: usize, to: usize, bytes: f64) -> Op {
        Op::single(self.send(m, from, to, bytes))
    }

    /// Barrier: dissemination algorithm, ceil(log2(p)) rounds of zero-byte
    /// messages.  Returns completion time.
    pub fn barrier(&self, m: &mut Machine) -> SimTime {
        let p = self.size();
        if p <= 1 {
            return m.sim.now();
        }
        let rounds = (p as f64).log2().ceil() as u32;
        let mut t = m.sim.now();
        for r in 0..rounds {
            let stride = 1usize << r;
            let flows: Vec<FlowId> = (0..p)
                .map(|i| {
                    let peer = (i + stride) % p;
                    let (src, dst) = (self.ep(m, i), self.ep(m, peer));
                    let f = m.fabric.put(&mut m.sim, src, dst, 8.0);
                    m.sim.delay(COLL_ROUND_COST);
                    f
                })
                .collect();
            t = m.sim.wait_all(&flows);
        }
        t
    }

    /// Allreduce of `bytes` per rank: recursive doubling —
    /// ceil(log2(p)) rounds, each rank exchanging `bytes` with a partner.
    pub fn allreduce(&self, m: &mut Machine, bytes: f64) -> SimTime {
        let p = self.size();
        if p <= 1 {
            return m.sim.now();
        }
        let rounds = (p as f64).log2().ceil() as u32;
        let mut t = m.sim.now();
        for r in 0..rounds {
            let stride = 1usize << r;
            let flows: Vec<FlowId> = (0..p)
                .map(|i| {
                    let peer = i ^ stride.min(p - 1).max(1);
                    let peer = peer % p;
                    let (src, dst) = (self.ep(m, i), self.ep(m, peer));
                    m.fabric.put(&mut m.sim, src, dst, bytes)
                })
                .collect();
            t = m.sim.wait_all(&flows) + COLL_ROUND_COST;
        }
        t
    }

    /// Ring exchange issued without blocking: every rank sends `bytes` to
    /// its right neighbour and receives from the left (one round).  The
    /// communication pattern of SCR's XOR reduce-scatter; the returned
    /// [`Op`] completes when every pairwise transfer has landed.
    ///
    /// QoS: tagged [`TrafficClass::Exchange`] unless a caller already set
    /// a more specific ambient class (the XOR strategies' reduce-scatter
    /// rides this as `Parity`).
    pub fn ring_exchange_op(&self, m: &mut Machine, bytes: f64) -> Op {
        let p = self.size();
        if p <= 1 {
            return Op::done();
        }
        let prev = m.sim.default_issue_class(TrafficClass::Exchange);
        let mut op = Op::done();
        for i in 0..p {
            let peer = (i + 1) % p;
            let (src, dst) = (self.ep(m, i), self.ep(m, peer));
            op.push(m.fabric.put(&mut m.sim, src, dst, bytes));
        }
        m.sim.set_issue_class(prev);
        op
    }

    /// Blocking shim over [`Comm::ring_exchange_op`].
    pub fn ring_exchange(&self, m: &mut Machine, bytes: f64) -> SimTime {
        let op = self.ring_exchange_op(m, bytes);
        m.sim.wait_op(&op)
    }

    /// Broadcast `bytes` from `root` to all ranks: binomial tree,
    /// ceil(log2(p)) rounds with the informed set doubling each round.
    pub fn bcast(&self, m: &mut Machine, root: usize, bytes: f64) -> SimTime {
        let p = self.size();
        if p <= 1 {
            return m.sim.now();
        }
        // Rank labels rotated so `root` is tree-rank 0.
        let rot = |tree_rank: usize| (tree_rank + root) % p;
        let mut informed = 1usize;
        let mut t = m.sim.now();
        while informed < p {
            let senders = informed.min(p - informed);
            let flows: Vec<FlowId> = (0..senders)
                .map(|i| {
                    let src = self.ep(m, rot(i));
                    let dst = self.ep(m, rot(informed + i));
                    m.fabric.put(&mut m.sim, src, dst, bytes)
                })
                .collect();
            t = m.sim.wait_all(&flows) + COLL_ROUND_COST;
            informed *= 2;
        }
        t
    }

    /// Reduce `bytes` per rank to `root`: mirror of the broadcast tree
    /// (combining cost charged on each receiving CPU).
    pub fn reduce(&self, m: &mut Machine, root: usize, bytes: f64) -> SimTime {
        let p = self.size();
        if p <= 1 {
            return m.sim.now();
        }
        let rot = |tree_rank: usize| (tree_rank + root) % p;
        let mut active = p;
        let mut t = m.sim.now();
        while active > 1 {
            let half = active / 2;
            let flows: Vec<FlowId> = (0..half)
                .map(|i| {
                    let src = self.ep(m, rot(active - 1 - i));
                    let dst = self.ep(m, rot(i));
                    m.fabric.put(&mut m.sim, src, dst, bytes)
                })
                .collect();
            m.sim.wait_all(&flows);
            // Combine on the receivers (1 flop/byte class).
            let combines: Vec<FlowId> = (0..half)
                .map(|i| {
                    let cpu = m.nodes[self.nodes[rot(i)]].cpu;
                    m.sim.flow(bytes, 0.0, &[cpu])
                })
                .collect();
            t = m.sim.wait_all(&combines) + COLL_ROUND_COST;
            active -= half;
        }
        t
    }

    /// All-to-all personalized exchange of `bytes` per pair: p-1 pairwise
    /// rounds (the xPic particle-migration pattern between domains).
    pub fn alltoall(&self, m: &mut Machine, bytes_per_pair: f64) -> SimTime {
        let p = self.size();
        if p <= 1 {
            return m.sim.now();
        }
        let mut t = m.sim.now();
        for round in 1..p {
            let flows: Vec<FlowId> = (0..p)
                .map(|i| {
                    let peer = i ^ round;
                    let peer = if peer < p { peer } else { (i + round) % p };
                    let (src, dst) = (self.ep(m, i), self.ep(m, peer));
                    m.fabric.put(&mut m.sim, src, dst, bytes_per_pair)
                })
                .collect();
            t = m.sim.wait_all(&flows) + COLL_ROUND_COST;
        }
        t
    }

    /// Gather `bytes` per rank to `root`, issued without blocking (used by
    /// the field solver side of xPic and by checkpoint metadata
    /// collection).
    pub fn gather_op(&self, m: &mut Machine, root: usize, bytes: f64) -> Op {
        let p = self.size();
        let root_ep = self.ep(m, root);
        let mut op = Op::done();
        for i in (0..p).filter(|&i| i != root) {
            let src = self.ep(m, i);
            op.push(m.fabric.put(&mut m.sim, src, root_ep, bytes));
        }
        op
    }

    /// Blocking shim over [`Comm::gather_op`].
    pub fn gather(&self, m: &mut Machine, root: usize, bytes: f64) -> SimTime {
        let op = self.gather_op(m, root, bytes);
        m.sim.wait_op(&op)
    }
}

/// Result of spawning an offload group (MPI_Comm_spawn).
#[derive(Debug)]
pub struct SpawnedGroup {
    pub comm: Comm,
    /// Inter-communicator latency between parent and child sides.
    pub ready_at: SimTime,
}

/// `MPI_Comm_spawn`: launch a process group on `target_nodes` (typically
/// on the other side of the Cluster-Booster divide).
pub fn comm_spawn(m: &mut Machine, target_nodes: Vec<usize>) -> SpawnedGroup {
    for &n in &target_nodes {
        assert!(m.nodes[n].alive, "spawning on dead node {n}");
    }
    // Group launch cost is paid once (parallel startup), plus a small
    // per-node wire-up handled by the PMD tree.
    let n = target_nodes.len() as f64;
    let d = m.sim.delay(SPAWN_COST_PER_NODE * (1.0 + n.log2().max(0.0) * 0.25));
    let ready_at = m.sim.wait_all(&[d]);
    SpawnedGroup { comm: Comm::of(target_nodes), ready_at }
}

/// The process-management daemon: failure detection + isolation.
#[derive(Debug, Default)]
pub struct Pmd {
    /// Nodes reported failed and already isolated.
    isolated: Vec<usize>,
}

impl Pmd {
    pub fn new() -> Self {
        Self::default()
    }

    /// Poll for failures among `nodes`: any dead node is detected within a
    /// heartbeat, isolated, and reported.  Advances virtual time by the
    /// detection+cleanup cost when something failed.
    pub fn detect_and_isolate(&mut self, m: &mut Machine, nodes: &[usize]) -> Vec<usize> {
        let newly: Vec<usize> = nodes
            .iter()
            .copied()
            .filter(|&n| !m.nodes[n].alive && !self.isolated.contains(&n))
            .collect();
        if !newly.is_empty() {
            let d = m.sim.delay(PMD_HEARTBEAT / 2.0 + PMD_CLEANUP);
            m.sim.wait_all(&[d]);
            self.isolated.extend(newly.iter().copied());
        }
        newly
    }

    /// Clear isolation state for a node that has been replaced/revived.
    pub fn reinstate(&mut self, node: usize) {
        self.isolated.retain(|&n| n != node);
    }

    pub fn isolated(&self) -> &[usize] {
        &self.isolated
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::presets;

    fn machine() -> Machine {
        Machine::build(presets::deep_er())
    }

    #[test]
    fn barrier_scales_logarithmically() {
        let mut m = machine();
        let c4 = Comm::of((0..4).collect());
        let t0 = m.sim.now();
        let t4 = c4.barrier(&mut m) - t0;
        let t1 = m.sim.now();
        let c16 = Comm::of((0..16).collect());
        let t16 = c16.barrier(&mut m) - t1;
        assert!(t16 < 4.0 * t4, "t4={t4:e} t16={t16:e}"); // log, not linear
        assert!(t16 > t4, "t4={t4:e} t16={t16:e}");
    }

    #[test]
    fn allreduce_time_grows_with_bytes() {
        let mut m = machine();
        let c = Comm::of((0..8).collect());
        let t0 = m.sim.now();
        let t_small = c.allreduce(&mut m, 1e3) - t0;
        let t1 = m.sim.now();
        let t_big = c.allreduce(&mut m, 100e6) - t1;
        assert!(t_big > 10.0 * t_small, "small={t_small:e} big={t_big:e}");
    }

    #[test]
    fn ring_exchange_is_single_round() {
        let mut m = machine();
        let c = Comm::of((0..16).collect());
        let bytes = 100e6;
        let t0 = m.sim.now();
        let t = c.ring_exchange(&mut m, bytes) - t0;
        // All sends run concurrently on distinct links: ~bytes/link_bw.
        let expect = bytes / crate::fabric::TOURMALET_BW;
        assert!(t < 2.0 * expect, "t={t} expect~{expect}");
    }

    #[test]
    fn spawn_pays_startup_cost() {
        let mut m = machine();
        let boosters = m.nodes_of(crate::system::NodeKind::Booster);
        let g = comm_spawn(&mut m, boosters.clone());
        assert_eq!(g.comm.size(), 8);
        assert!(g.ready_at >= SPAWN_COST_PER_NODE);
    }

    #[test]
    #[should_panic(expected = "dead node")]
    fn spawn_on_dead_node_panics() {
        let mut m = machine();
        m.kill_node(20);
        let _ = comm_spawn(&mut m, vec![20]);
    }

    #[test]
    fn pmd_detects_failure_once() {
        let mut m = machine();
        let mut pmd = Pmd::new();
        let nodes: Vec<usize> = (0..8).collect();
        assert!(pmd.detect_and_isolate(&mut m, &nodes).is_empty());
        m.kill_node(5);
        let t0 = m.sim.now();
        let got = pmd.detect_and_isolate(&mut m, &nodes);
        assert_eq!(got, vec![5]);
        assert!(m.sim.now() > t0, "detection must cost time");
        // Second poll: already isolated, no re-report.
        assert!(pmd.detect_and_isolate(&mut m, &nodes).is_empty());
        pmd.reinstate(5);
        m.revive_node(5);
        assert!(pmd.detect_and_isolate(&mut m, &nodes).is_empty());
    }

    #[test]
    fn bcast_scales_logarithmically() {
        let mut m = machine();
        let bytes = 10e6;
        let c4 = Comm::of((0..4).collect());
        let t0 = m.sim.now();
        let t4 = c4.bcast(&mut m, 0, bytes) - t0;
        let t1 = m.sim.now();
        let c16 = Comm::of((0..16).collect());
        let t16 = c16.bcast(&mut m, 0, bytes) - t1;
        // 16 ranks = 4 rounds vs 2 rounds: factor ~2, not ~4.
        assert!(t16 < 3.0 * t4, "t4={t4} t16={t16}");
        assert!(t16 > t4);
    }

    #[test]
    fn bcast_rotates_around_root() {
        let mut m = machine();
        let c = Comm::of((0..8).collect());
        let t0 = m.sim.now();
        let ta = c.bcast(&mut m, 0, 1e6) - t0;
        let t1 = m.sim.now();
        let tb = c.bcast(&mut m, 5, 1e6) - t1;
        assert!((ta - tb).abs() / ta < 0.05, "root-0 {ta} vs root-5 {tb}");
    }

    #[test]
    fn reduce_costs_at_least_bcast() {
        // Reduce pays the same tree plus combine flops.
        let mut m = machine();
        let c = Comm::of((0..8).collect());
        let bytes = 50e6;
        let t0 = m.sim.now();
        let tb = c.bcast(&mut m, 0, bytes) - t0;
        let t1 = m.sim.now();
        let tr = c.reduce(&mut m, 0, bytes) - t1;
        assert!(tr >= tb, "reduce {tr} < bcast {tb}");
    }

    #[test]
    fn alltoall_rounds_scale_linearly() {
        let mut m = machine();
        let bytes = 5e6;
        let c4 = Comm::of((0..4).collect());
        let t0 = m.sim.now();
        let t4 = c4.alltoall(&mut m, bytes) - t0;
        let t1 = m.sim.now();
        let c8 = Comm::of((0..8).collect());
        let t8 = c8.alltoall(&mut m, bytes) - t1;
        // 7 rounds vs 3 rounds: between 1.5x and 4x.
        assert!(t8 > 1.5 * t4 && t8 < 4.0 * t4, "t4={t4} t8={t8}");
    }

    #[test]
    fn gather_incasts_to_root() {
        let mut m = machine();
        let c = Comm::of((0..8).collect());
        let bytes = 50e6;
        let t0 = m.sim.now();
        let t = c.gather(&mut m, 0, bytes) - t0;
        // 7 senders share the root rx port: ~7*bytes/link_bw.
        let expect = 7.0 * bytes / crate::fabric::TOURMALET_BW;
        assert!((t - expect).abs() / expect < 0.2, "t={t} expect={expect}");
    }
}

//! PJRT runtime: load and execute the AOT-lowered JAX/Pallas artifacts.
//!
//! This is the only bridge between the rust coordinator and real compute.
//! `python/compile/aot.py` lowers every L2 entry point ONCE to HLO *text*
//! (text, not serialized `HloModuleProto`: jax >= 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids) plus a `manifest.json` describing input/output tensor
//! shapes.  At run time this module compiles each module on the PJRT CPU
//! client exactly once and executes it from the L3 hot path — Python is
//! never on the request path.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};
use crate::Result;

/// Tensor metadata from the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        let shape = v
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest entry missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = v
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("manifest entry missing dtype"))?
            .to_string();
        Ok(Self { shape, dtype })
    }
}

/// One artifact entry from the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    fn from_json(v: &Json) -> Result<Self> {
        let field = |k: &str| -> Result<&Json> {
            v.get(k).ok_or_else(|| anyhow::anyhow!("artifact missing {k}"))
        };
        let specs = |k: &str| -> Result<Vec<TensorSpec>> {
            field(k)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("{k} not an array"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(Self {
            name: field("name")?.as_str().unwrap_or_default().to_string(),
            file: field("file")?.as_str().unwrap_or_default().to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
        })
    }
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub format: String,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let v = json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let format = v
            .get("format")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string();
        let artifacts = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts"))?
            .iter()
            .map(ArtifactSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { format, artifacts })
    }
}

/// A host tensor moving in/out of PJRT.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Some(data),
            _ => None,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Tensor::F32 { shape, data } => xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                shape,
                cast_bytes(data),
            )?,
            Tensor::I32 { shape, data } => xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                shape,
                cast_bytes(data),
            )?,
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Tensor> {
        match spec.dtype.as_str() {
            "f32" => Ok(Tensor::F32 { shape: spec.shape.clone(), data: lit.to_vec::<f32>()? }),
            "i32" => Ok(Tensor::I32 { shape: spec.shape.clone(), data: lit.to_vec::<i32>()? }),
            other => anyhow::bail!("unsupported dtype {other} in manifest"),
        }
    }
}

fn cast_bytes<T>(data: &[T]) -> &[u8] {
    // f32/i32 are plain-old-data; reinterpreting as bytes is sound.
    unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    }
}

/// The PJRT executor: one compiled executable per artifact, compiled
/// lazily on first use and cached for the rest of the process lifetime.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("dir", &self.dir)
            .field("artifacts", &self.manifest.artifacts.len())
            .field("compiled", &self.executables.len())
            .finish()
    }
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.json`; compiles lazily).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            )
        })?;
        let manifest = Manifest::parse(&text)?;
        if manifest.format != "hlo-text" {
            anyhow::bail!("unsupported artifact format {:?}", manifest.format);
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, dir, manifest, executables: HashMap::new() })
    }

    /// Artifact metadata by name.
    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.manifest.artifacts.iter().find(|a| a.name == name)
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.manifest.artifacts.iter().map(|a| a.name.clone()).collect()
    }

    /// Compile `name` now (otherwise it compiles on first execute).
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .spec(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact {name}"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow::anyhow!("non-UTF8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute artifact `name` with `inputs`; returns the output tensors.
    ///
    /// Inputs are validated against the manifest (shape + dtype) — a
    /// mismatch is a caller bug and errors out before touching PJRT.
    pub fn execute(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.compile(name)?;
        let spec = self.spec(name).unwrap().clone();
        if inputs.len() != spec.inputs.len() {
            anyhow::bail!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape() != s.shape.as_slice() {
                anyhow::bail!(
                    "{name}: input {i} shape {:?} != manifest {:?}",
                    t.shape(),
                    s.shape
                );
            }
            let dtype_ok = matches!(
                (t, s.dtype.as_str()),
                (Tensor::F32 { .. }, "f32") | (Tensor::I32 { .. }, "i32")
            );
            if !dtype_ok {
                anyhow::bail!("{name}: input {i} dtype mismatch (manifest {})", s.dtype);
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let exe = self.executables.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: the output is always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            anyhow::bail!(
                "{name}: expected {} outputs, got {}",
                spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(lit, s)| Tensor::from_literal(lit, s))
            .collect()
    }

    /// Number of artifacts compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.executables.len()
    }
}

/// Conventional artifacts directory: `$REPRO_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("REPRO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_accessors() {
        let t = Tensor::F32 { shape: vec![2, 3], data: vec![0.0; 6] };
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert!(t.as_f32().is_some());
        assert!(t.as_i32().is_none());
        let s = TensorSpec { shape: vec![2, 3], dtype: "f32".into() };
        assert_eq!(s.elements(), 6);
    }

    #[test]
    fn manifest_parses() {
        let j = r#"{"format":"hlo-text","artifacts":[
            {"name":"a","file":"a.hlo.txt",
             "inputs":[{"shape":[4],"dtype":"f32"}],
             "outputs":[{"shape":[2,2],"dtype":"i32"}]}]}"#;
        let m = Manifest::parse(j).unwrap();
        assert_eq!(m.format, "hlo-text");
        assert_eq!(m.artifacts.len(), 1);
        assert_eq!(m.artifacts[0].inputs[0].shape, vec![4]);
        assert_eq!(m.artifacts[0].outputs[0].dtype, "i32");
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("{\"artifacts\": 3}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    // PJRT-touching tests live in rust/tests/integration_runtime.rs (they
    // need `make artifacts` to have run).
}

//! # deeper — a reproduction of the DEEP-ER Cluster-Booster I/O + resiliency stack
//!
//! This crate rebuilds, as a calibrated discrete-event simulation plus a real
//! AOT-compiled compute path, the system described in *"The DEEP-ER project:
//! I/O and resiliency extensions for the Cluster-Booster architecture"*
//! (Kreuzer, Eicker, Suarez et al., HPCC 2018).
//!
//! ## Layering (see DESIGN.md)
//!
//! * [`sim`] — fluid-flow discrete-event engine: virtual clock, max-min
//!   fair bandwidth sharing over shared resources, deterministic RNG;
//!   lazy progression + component-scoped refills (DESIGN.md §10) and
//!   component-parallel execution across scoped worker threads with a
//!   bit-identical single-thread mode (DESIGN.md §14), with
//!   [`sim::reference`] as the naive differential oracle.
//! * [`system`] — node/topology models of the DEEP-ER prototype (Table I),
//!   QPACE3 and MareNostrum 3, plus failure injection.
//! * [`fabric`] — the EXTOLL Tourmalet fabric: RDMA put/get/notification,
//!   ring-buffer engines (libRMA semantics used by libNAM).
//! * [`storage`] — node-local device models: NVMe (Intel DC P3700), HDD,
//!   RAM-disk, and storage-server disks.
//! * [`beegfs`] — the BeeGFS parallel file system and the BeeOND cache
//!   layer on node-local devices (sync/async flush).
//! * [`sionlib`] — task-local-I/O aggregation into few shared files.
//! * [`nam`] — Network Attached Memory: HMC + FPGA parity engine on the
//!   fabric, and the libNAM client API.
//! * [`psmpi`] — ParaStation-style global MPI: communicators, collectives,
//!   `spawn`-based Cluster<->Booster offload, process-management daemon.
//! * [`scr`] — Scalable Checkpoint/Restart with the paper's four
//!   strategies: Single, Partner, Buddy, Distributed XOR, NAM XOR.
//! * [`ompss`] — OmpSs task runtime with the three DEEP-ER resiliency
//!   features (lightweight CP, persistent CP, resilient offload).
//! * [`apps`] — the co-design applications: N-body, xPic, GERShWIN, FWI.
//! * [`sched`] — the multi-tenant fleet scheduler: FCFS / conservative
//!   backfill over one shared machine, concurrent jobs on one clock,
//!   failure → restart → requeue (DESIGN.md section 11).
//! * [`qos`] — traffic-class QoS: the [`qos::TrafficClass`] taxonomy every
//!   flow carries, per-class weights / rate floors / shaping ceilings in
//!   the engine's weighted max-min fill, and Chameleon-style admission
//!   control over per-resource guarantee budgets (DESIGN.md section 12).
//! * [`runtime`] — PJRT executor for the AOT-lowered JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`); the only bridge to real compute.
//! * [`obs`] — deterministic observability: virtual-clock spans +
//!   counters/gauges/histograms in a bounded ring-buffer recorder, with
//!   Chrome trace-event and Prometheus-style exporters (DESIGN.md
//!   section 17); every layer above records through it when enabled.
//! * [`bench`] — harnesses regenerating every paper figure/table.
//! * [`metrics`] — series/table collection and fixed-width printers.

#![deny(rustdoc::broken_intra_doc_links)]

pub mod apps;
pub mod beegfs;
pub mod bench;
pub mod fabric;
pub mod metrics;
pub mod microbench;
pub mod nam;
pub mod obs;
pub mod ompss;
pub mod psmpi;
pub mod qos;
pub mod runtime;
pub mod sched;
pub mod scr;
pub mod sim;
pub mod sionlib;
pub mod storage;
pub mod system;
pub mod testing;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

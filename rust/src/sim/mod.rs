//! Fluid-flow discrete-event simulation core.
//!
//! Everything in the DEEP-ER reproduction that takes *time* — RDMA
//! transfers, NVMe writes, BeeGFS striping, checkpoint exchanges, compute
//! phases — is expressed as a **flow**: a number of bytes (or flops) moving
//! through a **route** of shared resources.  The engine advances a virtual
//! clock event-by-event and splits each resource's capacity across the
//! flows traversing it with progressive-filling **max-min fairness** (the
//! same fluid model SimGrid validates against packet-level simulators).
//!
//! This reproduces exactly the contention effects the paper's evaluation
//! hinges on: a BeeGFS storage backend saturating as more nodes write
//! (Fig. 6), node-local NVMe giving constant per-node bandwidth (Fig. 7),
//! and the NAM's two Tourmalet links bounding parity-pull bandwidth
//! (Figs. 3, 9).
//!
//! Determinism: ties are broken by flow id; the only randomness comes from
//! the seeded [`rng::SplitMix64`].

pub mod rng;

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Virtual time in seconds.
pub type SimTime = f64;

/// Index of a shared resource (link, NIC port, device channel, CPU...).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResId(pub usize);

/// Index of a flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FlowId(pub usize);

#[derive(Debug, Clone)]
struct Resource {
    #[allow(dead_code)]
    name: String,
    /// Capacity in bytes/second (or flops/second for compute resources).
    capacity: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FlowState {
    /// Not yet started (latency offset still running).
    Pending,
    Active,
    Done,
}

#[derive(Debug, Clone)]
struct Flow {
    route: Vec<ResId>,
    remaining: f64,
    state: FlowState,
    /// Kept for diagnostics; scheduling reads the PendingKey heap instead.
    #[allow(dead_code)]
    start_at: SimTime,
    finished_at: SimTime,
    /// Current allocated rate (recomputed on every event).
    rate: f64,
}

/// Min-heap key for pending flows: (start_at bits, id).  start_at is
/// always >= 0, and non-negative IEEE-754 doubles order identically to
/// their bit patterns, so the u64 comparison is exact and total.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct PendingKey(u64, usize);

impl PendingKey {
    fn new(start_at: SimTime, id: FlowId) -> Self {
        debug_assert!(start_at >= 0.0);
        Self(start_at.to_bits(), id.0)
    }

    fn time(&self) -> SimTime {
        f64::from_bits(self.0)
    }

    fn id(&self) -> FlowId {
        FlowId(self.1)
    }
}

/// The discrete-event engine.
///
/// ```
/// use deeper::sim::Sim;
/// let mut sim = Sim::new();
/// let link = sim.resource("link", 12.5e9);       // 100 Gbit/s
/// let a = sim.flow(1e9, 1.0e-6, &[link]);        // 1 GB after 1 us latency
/// let b = sim.flow(1e9, 1.0e-6, &[link]);        // contends with `a`
/// let t = sim.wait_all(&[a, b]);
/// assert!((t - 0.16).abs() / 0.16 < 1e-3);       // 2 GB over 12.5 GB/s
/// ```
#[derive(Debug, Default)]
pub struct Sim {
    now: SimTime,
    resources: Vec<Resource>,
    flows: Vec<Flow>,
    /// Active flows in activation order (deterministic; never re-sorted).
    active: Vec<FlowId>,
    /// Pending flows in a min-heap by (start_at, id): O(log P) activation
    /// instead of an O(P) scan per event (see EXPERIMENTS.md section Perf).
    pending: BinaryHeap<Reverse<PendingKey>>,
    /// Scratch buffers reused across rate recomputations (hot path):
    /// per-resource residual capacity / unfixed count / flow lists, plus
    /// the list of touched resources so clearing is O(touched) not O(R).
    scratch_residual: Vec<f64>,
    scratch_unfixed: Vec<u32>,
    scratch_flows_on: Vec<Vec<FlowId>>,
    scratch_touched: Vec<ResId>,
    /// Epoch-stamped "fixed" marks per flow id: no per-call clearing.
    scratch_fixed_epoch: Vec<u64>,
    epoch: u64,
    /// Earliest finish time over active flows, maintained by
    /// recompute_rates so next_event_time is O(1) instead of O(active).
    cached_next_finish: SimTime,
}

impl Sim {
    pub fn new() -> Self {
        Self { cached_next_finish: f64::INFINITY, ..Self::default() }
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Register a shared resource with `capacity` bytes/s (flops/s).
    pub fn resource(&mut self, name: impl Into<String>, capacity: f64) -> ResId {
        assert!(capacity > 0.0, "resource capacity must be positive");
        self.resources.push(Resource { name: name.into(), capacity });
        ResId(self.resources.len() - 1)
    }

    /// Resource capacity in bytes/s.
    pub fn capacity(&self, r: ResId) -> f64 {
        self.resources[r.0].capacity
    }

    /// Start a flow of `bytes` through `route`, beginning after `delay`
    /// seconds of latency (pure offset, consumes no bandwidth).
    pub fn flow(&mut self, bytes: f64, delay: SimTime, route: &[ResId]) -> FlowId {
        assert!(bytes >= 0.0 && delay >= 0.0);
        assert!(!route.is_empty(), "flow route must name at least one resource");
        let id = FlowId(self.flows.len());
        let start_at = self.now + delay;
        self.flows.push(Flow {
            route: route.to_vec(),
            remaining: bytes,
            state: FlowState::Pending,
            start_at,
            finished_at: f64::INFINITY,
            rate: 0.0,
        });
        self.pending.push(Reverse(PendingKey::new(start_at, id)));
        id
    }

    /// A pure-delay flow (no bandwidth consumed): models fixed software
    /// overheads (metadata round-trips, syscalls, kernel-launch latency).
    pub fn delay(&mut self, seconds: SimTime) -> FlowId {
        // Zero bytes on a dummy route: completes exactly at start_at.
        let id = FlowId(self.flows.len());
        let start_at = self.now + seconds;
        self.flows.push(Flow {
            route: Vec::new(),
            remaining: 0.0,
            state: FlowState::Pending,
            start_at,
            finished_at: f64::INFINITY,
            rate: 0.0,
        });
        self.pending.push(Reverse(PendingKey::new(start_at, id)));
        id
    }

    /// Completion time of a finished flow.
    pub fn completed(&self, f: FlowId) -> Option<SimTime> {
        let fl = &self.flows[f.0];
        (fl.state == FlowState::Done).then_some(fl.finished_at)
    }

    /// Advance until all `flows` complete; returns the time of the last one.
    /// Other in-flight flows keep progressing (this is how BeeOND's
    /// asynchronous flush overlaps the next compute phase).
    pub fn wait_all(&mut self, flows: &[FlowId]) -> SimTime {
        // Amortized-O(1) completion check: a cursor over the wait set
        // (flows complete roughly in submission order, so the cursor
        // rarely re-visits) instead of an O(W) scan per event.
        let mut cursor = 0;
        while cursor < flows.len() {
            if self.flows[flows[cursor].0].state == FlowState::Done {
                cursor += 1;
                continue;
            }
            if !self.step() {
                panic!("simulation deadlock: waited-on flow cannot complete");
            }
        }
        flows
            .iter()
            .map(|&f| self.flows[f.0].finished_at)
            .fold(0.0, f64::max)
    }

    /// Per-flow completion times, advancing as needed.
    pub fn wait_each(&mut self, flows: &[FlowId]) -> Vec<SimTime> {
        self.wait_all(flows);
        flows.iter().map(|&f| self.flows[f.0].finished_at).collect()
    }

    /// Run until no pending/active flows remain.
    pub fn run_until_idle(&mut self) {
        while self.step() {}
    }

    /// Jump the clock forward by `seconds` (processing any events inside).
    pub fn advance(&mut self, seconds: SimTime) {
        let target = self.now + seconds;
        loop {
            match self.next_event_time() {
                Some(t) if t <= target => {
                    if !self.step() {
                        break;
                    }
                }
                _ => break,
            }
        }
        self.now = self.now.max(target);
    }

    /// Number of flows ever created (diagnostics).
    pub fn flow_count(&self) -> usize {
        self.flows.len()
    }

    // ------------------------------------------------------------------
    // engine internals
    // ------------------------------------------------------------------

    fn next_event_time(&self) -> Option<SimTime> {
        let start = self
            .pending
            .peek()
            .map(|Reverse(k)| k.time())
            .unwrap_or(f64::INFINITY);
        let t = start.min(self.cached_next_finish);
        t.is_finite().then_some(t)
    }

    /// Process one event; returns false when idle.
    fn step(&mut self) -> bool {
        let Some(t) = self.next_event_time() else {
            return false;
        };
        let dt = (t - self.now).max(0.0);
        // Progress all active flows by dt at their current rates.
        for &f in &self.active {
            let fl = &mut self.flows[f.0];
            fl.remaining = (fl.remaining - fl.rate * dt).max(0.0);
        }
        self.now = t;

        // Activate pending flows whose latency elapsed (heap pops in
        // (start_at, id) order, so activation order is deterministic).
        let mut changed = false;
        while let Some(&Reverse(k)) = self.pending.peek() {
            if k.time() > self.now + 1e-15 {
                break;
            }
            self.pending.pop();
            let f = k.id();
            let fl = &mut self.flows[f.0];
            if fl.remaining == 0.0 {
                fl.state = FlowState::Done;
                fl.finished_at = self.now;
            } else {
                fl.state = FlowState::Active;
                self.active.push(f);
            }
            changed = true;
        }

        // Retire finished flows, preserving activation order (no re-sort).
        let flows = &mut self.flows;
        let now = self.now;
        let before = self.active.len();
        self.active.retain(|&f| {
            let fl = &mut flows[f.0];
            if fl.remaining <= 1e-9 * fl.rate.max(1.0) {
                fl.remaining = 0.0;
                fl.state = FlowState::Done;
                fl.finished_at = now;
                false
            } else {
                true
            }
        });
        changed |= self.active.len() != before;

        if changed {
            self.recompute_rates();
        } else {
            // Rates unchanged but remaining decreased: refresh the cache.
            self.refresh_next_finish();
        }
        true
    }

    /// Recompute the cached earliest finish over active flows.
    fn refresh_next_finish(&mut self) {
        let mut finish = f64::INFINITY;
        for &f in &self.active {
            let fl = &self.flows[f.0];
            let t = if fl.rate > 0.0 {
                self.now + fl.remaining / fl.rate
            } else if fl.remaining == 0.0 {
                self.now
            } else {
                f64::INFINITY
            };
            if t < finish {
                finish = t;
            }
        }
        self.cached_next_finish = finish;
    }

    /// Progressive-filling max-min fair allocation across all active flows.
    ///
    /// Hot-path notes (see EXPERIMENTS.md section Perf): only resources
    /// actually *loaded* by active flows are scanned; clearing is
    /// O(touched), not O(all resources); all bottlenecks tied at the
    /// minimum share are fixed in one pass (672 independent NVMe writers
    /// collapse to a single iteration instead of 672); and the "fixed"
    /// marks are epoch-stamped per flow id so nothing is re-allocated or
    /// re-hashed per call.
    fn recompute_rates(&mut self) {
        let nres = self.resources.len();
        if self.scratch_residual.len() < nres {
            self.scratch_residual.resize(nres, 0.0);
            self.scratch_unfixed.resize(nres, 0);
            self.scratch_flows_on.resize(nres, Vec::new());
        }
        if self.scratch_fixed_epoch.len() < self.flows.len() {
            self.scratch_fixed_epoch.resize(self.flows.len(), 0);
        }
        // Clear only what the previous call touched.
        for &r in &self.scratch_touched {
            self.scratch_unfixed[r.0] = 0;
            self.scratch_flows_on[r.0].clear();
        }
        self.scratch_touched.clear();
        self.epoch += 1;
        let epoch = self.epoch;

        for &f in &self.active {
            for &r in &self.flows[f.0].route {
                if self.scratch_unfixed[r.0] == 0 {
                    self.scratch_touched.push(r);
                    self.scratch_residual[r.0] = self.resources[r.0].capacity;
                }
                self.scratch_unfixed[r.0] += 1;
                self.scratch_flows_on[r.0].push(f);
            }
        }

        let mut remaining = self.active.len();
        while remaining > 0 {
            // Smallest fair share among loaded resources with unfixed flows.
            let mut min_share = f64::INFINITY;
            for &r in &self.scratch_touched {
                let n = self.scratch_unfixed[r.0];
                if n == 0 {
                    continue;
                }
                let share = self.scratch_residual[r.0] / n as f64;
                if share < min_share {
                    min_share = share;
                }
            }
            if !min_share.is_finite() {
                // Remaining flows have no loaded resource left: rate 0.
                for &f in &self.active {
                    if self.scratch_fixed_epoch[f.0] != epoch {
                        self.flows[f.0].rate = 0.0;
                    }
                }
                break;
            }
            // Fix every unfixed flow on every bottleneck tied at min_share.
            let eps = min_share * 1e-12 + 1e-30;
            let mut progressed = false;
            for ti in 0..self.scratch_touched.len() {
                let r = self.scratch_touched[ti];
                let n = self.scratch_unfixed[r.0];
                if n == 0 {
                    continue;
                }
                let share = self.scratch_residual[r.0] / n as f64;
                if share - min_share > eps {
                    continue;
                }
                // This resource is a bottleneck: fix its unfixed flows.
                for fi in 0..self.scratch_flows_on[r.0].len() {
                    let f = self.scratch_flows_on[r.0][fi];
                    if self.scratch_fixed_epoch[f.0] == epoch {
                        continue;
                    }
                    self.scratch_fixed_epoch[f.0] = epoch;
                    self.flows[f.0].rate = min_share;
                    remaining -= 1;
                    progressed = true;
                    for ri in 0..self.flows[f.0].route.len() {
                        let fr = self.flows[f.0].route[ri];
                        self.scratch_residual[fr.0] =
                            (self.scratch_residual[fr.0] - min_share).max(0.0);
                        self.scratch_unfixed[fr.0] -= 1;
                    }
                }
            }
            if !progressed {
                // Numerical corner: nothing progressed; zero out the rest.
                for &f in &self.active {
                    if self.scratch_fixed_epoch[f.0] != epoch {
                        self.flows[f.0].rate = 0.0;
                    }
                }
                break;
            }
        }
        self.refresh_next_finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_flow_takes_bytes_over_capacity() {
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let f = sim.flow(2e9, 0.0, &[link]);
        let t = sim.wait_all(&[f]);
        assert!((t - 2.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn latency_is_pure_offset() {
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let f = sim.flow(1e9, 0.5, &[link]);
        let t = sim.wait_all(&[f]);
        assert!((t - 1.5).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let a = sim.flow(1e9, 0.0, &[link]);
        let b = sim.flow(1e9, 0.0, &[link]);
        let times = sim.wait_each(&[a, b]);
        for t in times {
            assert!((t - 2.0).abs() < 1e-9, "t={t}");
        }
    }

    #[test]
    fn unequal_flows_release_bandwidth() {
        // 1 GB and 3 GB on a 2 GB/s link: first finishes at 1 s (1 GB/s each),
        // the second then gets the full 2 GB/s: 1 + (3-1)/2 = 2 s total.
        let mut sim = Sim::new();
        let link = sim.resource("l", 2e9);
        let a = sim.flow(1e9, 0.0, &[link]);
        let b = sim.flow(3e9, 0.0, &[link]);
        let times = sim.wait_each(&[a, b]);
        assert!((times[0] - 1.0).abs() < 1e-9, "a={}", times[0]);
        assert!((times[1] - 2.0).abs() < 1e-9, "b={}", times[1]);
    }

    #[test]
    fn multi_resource_route_takes_min() {
        let mut sim = Sim::new();
        let fast = sim.resource("fast", 10e9);
        let slow = sim.resource("slow", 1e9);
        let f = sim.flow(1e9, 0.0, &[fast, slow]);
        let t = sim.wait_all(&[f]);
        assert!((t - 1.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn max_min_respects_bottleneck_and_spare() {
        // Flow A crosses L1 (1 GB/s) and L2 (10 GB/s); flow B crosses only L2.
        // A is capped at 1 GB/s by L1; B gets the rest of L2 (9 GB/s).
        let mut sim = Sim::new();
        let l1 = sim.resource("l1", 1e9);
        let l2 = sim.resource("l2", 10e9);
        let a = sim.flow(1e9, 0.0, &[l1, l2]);
        let b = sim.flow(9e9, 0.0, &[l2]);
        let times = sim.wait_each(&[a, b]);
        assert!((times[0] - 1.0).abs() < 1e-6, "a={}", times[0]);
        assert!((times[1] - 1.0).abs() < 1e-6, "b={}", times[1]);
    }

    #[test]
    fn pure_delay_flow() {
        let mut sim = Sim::new();
        let d = sim.delay(0.25);
        let t = sim.wait_all(&[d]);
        assert!((t - 0.25).abs() < 1e-12);
    }

    #[test]
    fn staggered_arrivals() {
        // B arrives at t=1 on a 1 GB/s link while A (2 GB) is mid-transfer.
        // A: 1 GB done by t=1, shares 0.5 each after; A done at t=3.
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let a = sim.flow(2e9, 0.0, &[link]);
        let b = sim.flow(1e9, 1.0, &[link]);
        let times = sim.wait_each(&[a, b]);
        assert!((times[0] - 3.0).abs() < 1e-9, "a={}", times[0]);
        assert!((times[1] - 3.0).abs() < 1e-9, "b={}", times[1]);
    }

    #[test]
    fn background_flow_keeps_progressing() {
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let bg = sim.flow(4e9, 0.0, &[link]);
        let fg = sim.flow(1e9, 0.0, &[link]);
        sim.wait_all(&[fg]);
        // fg done at t=2 (shared 0.5 GB/s each); bg then has 3 GB left at
        // the full 1 GB/s: done at t = 2 + 3 = 5.
        let t = sim.wait_all(&[bg]);
        assert!((t - 5.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn determinism_same_inputs_same_times() {
        let run = || {
            let mut sim = Sim::new();
            let l = sim.resource("l", 3.3e9);
            let flows: Vec<_> = (0..32)
                .map(|i| sim.flow(1e8 * (i + 1) as f64, 1e-6 * i as f64, &[l]))
                .collect();
            sim.wait_each(&flows)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn advance_moves_clock_past_events() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let f = sim.flow(1e9, 0.0, &[l]);
        sim.advance(5.0);
        assert_eq!(sim.now(), 5.0);
        assert!(sim.completed(f).is_some());
        assert!((sim.completed(f).unwrap() - 1.0).abs() < 1e-9);
    }
}

//! Fluid-flow discrete-event simulation core.
//!
//! Everything in the DEEP-ER reproduction that takes *time* — RDMA
//! transfers, NVMe writes, BeeGFS striping, checkpoint exchanges, compute
//! phases — is expressed as a **flow**: a number of bytes (or flops) moving
//! through a **route** of shared resources.  The engine advances a virtual
//! clock event-by-event and splits each resource's capacity across the
//! flows traversing it with progressive-filling **max-min fairness** (the
//! same fluid model SimGrid validates against packet-level simulators).
//!
//! This reproduces exactly the contention effects the paper's evaluation
//! hinges on: a BeeGFS storage backend saturating as more nodes write
//! (Fig. 6), node-local NVMe giving constant per-node bandwidth (Fig. 7),
//! and the NAM's two Tourmalet links bounding parity-pull bandwidth
//! (Figs. 3, 9).
//!
//! Determinism: ties are broken by flow id; the only randomness comes from
//! the seeded [`rng::SplitMix64`].

pub mod rng;

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Virtual time in seconds.
pub type SimTime = f64;

/// Index of a shared resource (link, NIC port, device channel, CPU...).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResId(pub usize);

/// Index of a flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FlowId(pub usize);

#[derive(Debug, Clone)]
struct Resource {
    name: String,
    /// Capacity in bytes/second (or flops/second for compute resources).
    capacity: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FlowState {
    /// Not yet started (latency offset still running).
    Pending,
    Active,
    Done,
}

#[derive(Debug, Clone)]
struct Flow {
    route: Vec<ResId>,
    remaining: f64,
    state: FlowState,
    /// Kept for diagnostics ([`Sim::op_trace`]); scheduling reads the
    /// PendingKey heap instead.
    start_at: SimTime,
    finished_at: SimTime,
    /// Current allocated rate (recomputed on every event).
    rate: f64,
}

/// Handle to one in-flight logical **operation**: a set of flows that
/// jointly complete.  Every I/O layer (storage, BeeGFS/BeeOND, SIONlib,
/// NAM, psmpi) returns `Op`s; blocking calls are thin shims that
/// immediately [`Sim::wait_op`] the handle.  This is what lets lower-tier
/// checkpoint flushes run *in the background* of compute phases (the
/// checkpoint/compute-overlap pattern of Hukerikar & Engelmann 2017).
#[derive(Debug, Clone, Default)]
pub struct Op {
    flows: Vec<FlowId>,
}

impl Op {
    /// An operation over an explicit flow set.
    pub fn new(flows: Vec<FlowId>) -> Self {
        Self { flows }
    }

    /// An operation wrapping a single flow.
    pub fn single(flow: FlowId) -> Self {
        Self { flows: vec![flow] }
    }

    /// An already-complete operation (no flows).
    pub fn done() -> Self {
        Self::default()
    }

    /// Merge several operations into one that completes when all do.
    pub fn merge(ops: impl IntoIterator<Item = Op>) -> Self {
        let mut flows = Vec::new();
        for op in ops {
            flows.extend(op.flows);
        }
        Self { flows }
    }

    /// Absorb another operation into this one.
    pub fn join(&mut self, other: Op) {
        self.flows.extend(other.flows);
    }

    /// Add a bare flow to the operation.
    pub fn push(&mut self, flow: FlowId) {
        self.flows.push(flow);
    }

    /// The underlying flows (diagnostics / fine-grained waits).
    pub fn flows(&self) -> &[FlowId] {
        &self.flows
    }

    /// True when the operation carries no flows (trivially complete).
    pub fn is_empty(&self) -> bool {
        self.flows.is_empty()
    }
}

/// A set of independently issued [`Op`]s polled or awaited together —
/// e.g. the outstanding background flushes of a BeeOND cache domain or
/// the L3 flush queue of the multi-level checkpointer.
#[derive(Debug, Default)]
pub struct OpSet {
    ops: Vec<Op>,
}

impl OpSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, op: Op) {
        if !op.is_empty() {
            self.ops.push(op);
        }
    }

    /// Number of operations still tracked.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Total flows across all tracked operations.
    pub fn flow_count(&self) -> usize {
        self.ops.iter().map(|o| o.flows.len()).sum()
    }

    /// True when every tracked operation has completed (no time advance).
    pub fn poll(&self, sim: &Sim) -> bool {
        self.ops.iter().all(|o| sim.poll_op(o))
    }

    /// Drop every already-complete operation, returning how many settled.
    pub fn reap(&mut self, sim: &Sim) -> usize {
        let before = self.ops.len();
        self.ops.retain(|o| !sim.poll_op(o));
        before - self.ops.len()
    }

    /// Block until every tracked operation completes; empties the set and
    /// returns the completion time of the last one (now when empty).
    pub fn wait_all(&mut self, sim: &mut Sim) -> SimTime {
        let ops = std::mem::take(&mut self.ops);
        let all = Op::merge(ops);
        sim.wait_op(&all)
    }

    /// Discard all tracked operations without waiting (their flows keep
    /// progressing in the simulator, but nobody observes them anymore).
    pub fn abandon(&mut self) {
        self.ops.clear();
    }
}

/// One row of [`Sim::op_trace`]: the diagnostic view of a flow.
#[derive(Debug, Clone)]
pub struct OpTraceEntry {
    pub id: FlowId,
    /// Resources the flow traverses (names via [`Sim::resource_name`]).
    pub route: Vec<ResId>,
    /// When the flow's latency offset elapsed / will elapse.
    pub start_at: SimTime,
    /// Currently allocated rate (0 for pending or finished flows).
    pub rate: f64,
    pub done: bool,
    pub finished_at: Option<SimTime>,
}

/// Min-heap key for pending flows: (start_at bits, id).  start_at is
/// always >= 0, and non-negative IEEE-754 doubles order identically to
/// their bit patterns, so the u64 comparison is exact and total.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct PendingKey(u64, usize);

impl PendingKey {
    fn new(start_at: SimTime, id: FlowId) -> Self {
        debug_assert!(start_at >= 0.0);
        Self(start_at.to_bits(), id.0)
    }

    fn time(&self) -> SimTime {
        f64::from_bits(self.0)
    }

    fn id(&self) -> FlowId {
        FlowId(self.1)
    }
}

/// The discrete-event engine.
///
/// ```
/// use deeper::sim::Sim;
/// let mut sim = Sim::new();
/// let link = sim.resource("link", 12.5e9);       // 100 Gbit/s
/// let a = sim.flow(1e9, 1.0e-6, &[link]);        // 1 GB after 1 us latency
/// let b = sim.flow(1e9, 1.0e-6, &[link]);        // contends with `a`
/// let t = sim.wait_all(&[a, b]);
/// assert!((t - 0.16).abs() / 0.16 < 1e-3);       // 2 GB over 12.5 GB/s
/// ```
#[derive(Debug, Default)]
pub struct Sim {
    now: SimTime,
    resources: Vec<Resource>,
    flows: Vec<Flow>,
    /// Active flows in activation order (deterministic; never re-sorted).
    active: Vec<FlowId>,
    /// Pending flows in a min-heap by (start_at, id): O(log P) activation
    /// instead of an O(P) scan per event (see EXPERIMENTS.md section Perf).
    pending: BinaryHeap<Reverse<PendingKey>>,
    /// Scratch buffers reused across rate recomputations (hot path):
    /// per-resource residual capacity / unfixed count / flow lists, plus
    /// the list of touched resources so clearing is O(touched) not O(R).
    scratch_residual: Vec<f64>,
    scratch_unfixed: Vec<u32>,
    scratch_flows_on: Vec<Vec<FlowId>>,
    scratch_touched: Vec<ResId>,
    /// Epoch-stamped "fixed" marks per flow id: no per-call clearing.
    scratch_fixed_epoch: Vec<u64>,
    epoch: u64,
    /// Earliest finish time over active flows, maintained by
    /// recompute_rates so next_event_time is O(1) instead of O(active).
    cached_next_finish: SimTime,
}

impl Sim {
    pub fn new() -> Self {
        Self { cached_next_finish: f64::INFINITY, ..Self::default() }
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Register a shared resource with `capacity` bytes/s (flops/s).
    pub fn resource(&mut self, name: impl Into<String>, capacity: f64) -> ResId {
        assert!(capacity > 0.0, "resource capacity must be positive");
        self.resources.push(Resource { name: name.into(), capacity });
        ResId(self.resources.len() - 1)
    }

    /// Resource capacity in bytes/s.
    pub fn capacity(&self, r: ResId) -> f64 {
        self.resources[r.0].capacity
    }

    /// Start a flow of `bytes` through `route`, beginning after `delay`
    /// seconds of latency (pure offset, consumes no bandwidth).
    pub fn flow(&mut self, bytes: f64, delay: SimTime, route: &[ResId]) -> FlowId {
        assert!(bytes >= 0.0 && delay >= 0.0);
        assert!(!route.is_empty(), "flow route must name at least one resource");
        let id = FlowId(self.flows.len());
        let start_at = self.now + delay;
        self.flows.push(Flow {
            route: route.to_vec(),
            remaining: bytes,
            state: FlowState::Pending,
            start_at,
            finished_at: f64::INFINITY,
            rate: 0.0,
        });
        self.pending.push(Reverse(PendingKey::new(start_at, id)));
        id
    }

    /// A pure-delay flow (no bandwidth consumed): models fixed software
    /// overheads (metadata round-trips, syscalls, kernel-launch latency).
    pub fn delay(&mut self, seconds: SimTime) -> FlowId {
        // Zero bytes on a dummy route: completes exactly at start_at.
        let id = FlowId(self.flows.len());
        let start_at = self.now + seconds;
        self.flows.push(Flow {
            route: Vec::new(),
            remaining: 0.0,
            state: FlowState::Pending,
            start_at,
            finished_at: f64::INFINITY,
            rate: 0.0,
        });
        self.pending.push(Reverse(PendingKey::new(start_at, id)));
        id
    }

    /// Completion time of a finished flow.
    pub fn completed(&self, f: FlowId) -> Option<SimTime> {
        let fl = &self.flows[f.0];
        (fl.state == FlowState::Done).then_some(fl.finished_at)
    }

    /// Non-advancing completion query: has `f` finished?
    pub fn poll(&self, f: FlowId) -> bool {
        self.flows[f.0].state == FlowState::Done
    }

    /// Non-advancing completion query over an [`Op`] (empty ops are done).
    pub fn poll_op(&self, op: &Op) -> bool {
        op.flows.iter().all(|&f| self.poll(f))
    }

    /// Completion time of an [`Op`]: the latest flow completion, or None
    /// while any flow is still in flight.  Empty ops complete at 0.
    pub fn op_completion(&self, op: &Op) -> Option<SimTime> {
        let mut t = 0.0f64;
        for &f in &op.flows {
            t = t.max(self.completed(f)?);
        }
        Some(t)
    }

    /// Block until `op` completes; returns its completion time (now for
    /// empty ops).  The blocking shim every async layer builds on.
    pub fn wait_op(&mut self, op: &Op) -> SimTime {
        if op.flows.is_empty() {
            return self.now;
        }
        self.wait_all(&op.flows)
    }

    /// Advance until all `flows` complete; returns the time of the last one.
    /// Other in-flight flows keep progressing (this is how BeeOND's
    /// asynchronous flush overlaps the next compute phase).
    pub fn wait_all(&mut self, flows: &[FlowId]) -> SimTime {
        // Amortized-O(1) completion check: a cursor over the wait set
        // (flows complete roughly in submission order, so the cursor
        // rarely re-visits) instead of an O(W) scan per event.
        let mut cursor = 0;
        while cursor < flows.len() {
            if self.flows[flows[cursor].0].state == FlowState::Done {
                cursor += 1;
                continue;
            }
            if !self.step() {
                panic!("simulation deadlock: waited-on flow cannot complete");
            }
        }
        flows
            .iter()
            .map(|&f| self.flows[f.0].finished_at)
            .fold(0.0, f64::max)
    }

    /// Per-flow completion times, advancing as needed.
    pub fn wait_each(&mut self, flows: &[FlowId]) -> Vec<SimTime> {
        self.wait_all(flows);
        flows.iter().map(|&f| self.flows[f.0].finished_at).collect()
    }

    /// Advance until the **first** of `flows` completes; returns its index
    /// in the slice and its completion time.  Determinism: when several
    /// flows are already (or become) complete, the winner is the one with
    /// the earliest completion time, ties broken by the smaller flow id —
    /// never by slice position, so permuting the wait set cannot change
    /// the outcome.
    pub fn wait_any(&mut self, flows: &[FlowId]) -> (usize, SimTime) {
        assert!(!flows.is_empty(), "wait_any on an empty flow set");
        loop {
            let mut best: Option<(SimTime, FlowId, usize)> = None;
            for (i, &f) in flows.iter().enumerate() {
                if let Some(t) = self.completed(f) {
                    let better = match best {
                        None => true,
                        Some((bt, bf, _)) => t < bt || (t == bt && f < bf),
                    };
                    if better {
                        best = Some((t, f, i));
                    }
                }
            }
            if let Some((t, _, i)) = best {
                return (i, t);
            }
            if !self.step() {
                panic!("simulation deadlock: no waited-on flow can complete");
            }
        }
    }

    /// Run until no pending/active flows remain.
    pub fn run_until_idle(&mut self) {
        while self.step() {}
    }

    /// Jump the clock forward by `seconds` (processing any events inside).
    pub fn advance(&mut self, seconds: SimTime) {
        let target = self.now + seconds;
        loop {
            match self.next_event_time() {
                Some(t) if t <= target => {
                    if !self.step() {
                        break;
                    }
                }
                _ => break,
            }
        }
        self.now = self.now.max(target);
    }

    /// Jump the clock to the **absolute** virtual time `target`
    /// (processing any events inside); a no-op when `target` is in the
    /// past.  The absolute-time counterpart of [`Sim::advance`] for
    /// callers that schedule against timestamps (e.g. lining a scenario
    /// up with a recorded completion time).
    pub fn advance_until(&mut self, target: SimTime) {
        let dt = target - self.now;
        if dt > 0.0 {
            self.advance(dt);
        }
    }

    /// Number of flows ever created (diagnostics).
    pub fn flow_count(&self) -> usize {
        self.flows.len()
    }

    /// Name a resource was registered under (diagnostics).
    pub fn resource_name(&self, r: ResId) -> &str {
        &self.resources[r.0].name
    }

    /// Diagnostic snapshot of every flow ever issued: route, start time,
    /// current rate and completion.  This is the observability surface the
    /// overlap bench prints (`repro bench fig8-async`) and the property
    /// suite uses to audit per-resource rate allocations.
    pub fn op_trace(&self) -> Vec<OpTraceEntry> {
        self.flows
            .iter()
            .enumerate()
            .map(|(i, fl)| OpTraceEntry {
                id: FlowId(i),
                route: fl.route.clone(),
                start_at: fl.start_at,
                rate: if fl.state == FlowState::Active { fl.rate } else { 0.0 },
                done: fl.state == FlowState::Done,
                finished_at: (fl.state == FlowState::Done).then_some(fl.finished_at),
            })
            .collect()
    }

    // ------------------------------------------------------------------
    // engine internals
    // ------------------------------------------------------------------

    fn next_event_time(&self) -> Option<SimTime> {
        let start = self
            .pending
            .peek()
            .map(|Reverse(k)| k.time())
            .unwrap_or(f64::INFINITY);
        let t = start.min(self.cached_next_finish);
        t.is_finite().then_some(t)
    }

    /// Process one event; returns false when idle.
    fn step(&mut self) -> bool {
        let Some(t) = self.next_event_time() else {
            return false;
        };
        let dt = (t - self.now).max(0.0);
        // Progress all active flows by dt at their current rates.
        for &f in &self.active {
            let fl = &mut self.flows[f.0];
            fl.remaining = (fl.remaining - fl.rate * dt).max(0.0);
        }
        self.now = t;

        // Activate pending flows whose latency elapsed (heap pops in
        // (start_at, id) order, so activation order is deterministic).
        let mut changed = false;
        while let Some(&Reverse(k)) = self.pending.peek() {
            if k.time() > self.now + 1e-15 {
                break;
            }
            self.pending.pop();
            let f = k.id();
            let fl = &mut self.flows[f.0];
            if fl.remaining == 0.0 {
                fl.state = FlowState::Done;
                fl.finished_at = self.now;
            } else {
                fl.state = FlowState::Active;
                self.active.push(f);
            }
            changed = true;
        }

        // Retire finished flows, preserving activation order (no re-sort).
        let flows = &mut self.flows;
        let now = self.now;
        let before = self.active.len();
        self.active.retain(|&f| {
            let fl = &mut flows[f.0];
            if fl.remaining <= 1e-9 * fl.rate.max(1.0) {
                fl.remaining = 0.0;
                fl.state = FlowState::Done;
                fl.finished_at = now;
                false
            } else {
                true
            }
        });
        changed |= self.active.len() != before;

        if changed {
            self.recompute_rates();
        } else {
            // Rates unchanged but remaining decreased: refresh the cache.
            self.refresh_next_finish();
        }
        true
    }

    /// Recompute the cached earliest finish over active flows.
    fn refresh_next_finish(&mut self) {
        let mut finish = f64::INFINITY;
        for &f in &self.active {
            let fl = &self.flows[f.0];
            let t = if fl.rate > 0.0 {
                self.now + fl.remaining / fl.rate
            } else if fl.remaining == 0.0 {
                self.now
            } else {
                f64::INFINITY
            };
            if t < finish {
                finish = t;
            }
        }
        self.cached_next_finish = finish;
    }

    /// Progressive-filling max-min fair allocation across all active flows.
    ///
    /// Hot-path notes (see EXPERIMENTS.md section Perf): only resources
    /// actually *loaded* by active flows are scanned; clearing is
    /// O(touched), not O(all resources); all bottlenecks tied at the
    /// minimum share are fixed in one pass (672 independent NVMe writers
    /// collapse to a single iteration instead of 672); and the "fixed"
    /// marks are epoch-stamped per flow id so nothing is re-allocated or
    /// re-hashed per call.
    fn recompute_rates(&mut self) {
        let nres = self.resources.len();
        if self.scratch_residual.len() < nres {
            self.scratch_residual.resize(nres, 0.0);
            self.scratch_unfixed.resize(nres, 0);
            self.scratch_flows_on.resize(nres, Vec::new());
        }
        if self.scratch_fixed_epoch.len() < self.flows.len() {
            self.scratch_fixed_epoch.resize(self.flows.len(), 0);
        }
        // Clear only what the previous call touched.
        for &r in &self.scratch_touched {
            self.scratch_unfixed[r.0] = 0;
            self.scratch_flows_on[r.0].clear();
        }
        self.scratch_touched.clear();
        self.epoch += 1;
        let epoch = self.epoch;

        for &f in &self.active {
            for &r in &self.flows[f.0].route {
                if self.scratch_unfixed[r.0] == 0 {
                    self.scratch_touched.push(r);
                    self.scratch_residual[r.0] = self.resources[r.0].capacity;
                }
                self.scratch_unfixed[r.0] += 1;
                self.scratch_flows_on[r.0].push(f);
            }
        }

        let mut remaining = self.active.len();
        while remaining > 0 {
            // Smallest fair share among loaded resources with unfixed flows.
            let mut min_share = f64::INFINITY;
            for &r in &self.scratch_touched {
                let n = self.scratch_unfixed[r.0];
                if n == 0 {
                    continue;
                }
                let share = self.scratch_residual[r.0] / n as f64;
                if share < min_share {
                    min_share = share;
                }
            }
            if !min_share.is_finite() {
                // Remaining flows have no loaded resource left: rate 0.
                for &f in &self.active {
                    if self.scratch_fixed_epoch[f.0] != epoch {
                        self.flows[f.0].rate = 0.0;
                    }
                }
                break;
            }
            // Fix every unfixed flow on every bottleneck tied at min_share.
            let eps = min_share * 1e-12 + 1e-30;
            let mut progressed = false;
            for ti in 0..self.scratch_touched.len() {
                let r = self.scratch_touched[ti];
                let n = self.scratch_unfixed[r.0];
                if n == 0 {
                    continue;
                }
                let share = self.scratch_residual[r.0] / n as f64;
                if share - min_share > eps {
                    continue;
                }
                // This resource is a bottleneck: fix its unfixed flows.
                for fi in 0..self.scratch_flows_on[r.0].len() {
                    let f = self.scratch_flows_on[r.0][fi];
                    if self.scratch_fixed_epoch[f.0] == epoch {
                        continue;
                    }
                    self.scratch_fixed_epoch[f.0] = epoch;
                    self.flows[f.0].rate = min_share;
                    remaining -= 1;
                    progressed = true;
                    for ri in 0..self.flows[f.0].route.len() {
                        let fr = self.flows[f.0].route[ri];
                        self.scratch_residual[fr.0] =
                            (self.scratch_residual[fr.0] - min_share).max(0.0);
                        self.scratch_unfixed[fr.0] -= 1;
                    }
                }
            }
            if !progressed {
                // Numerical corner: nothing progressed; zero out the rest.
                for &f in &self.active {
                    if self.scratch_fixed_epoch[f.0] != epoch {
                        self.flows[f.0].rate = 0.0;
                    }
                }
                break;
            }
        }
        self.refresh_next_finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_flow_takes_bytes_over_capacity() {
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let f = sim.flow(2e9, 0.0, &[link]);
        let t = sim.wait_all(&[f]);
        assert!((t - 2.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn latency_is_pure_offset() {
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let f = sim.flow(1e9, 0.5, &[link]);
        let t = sim.wait_all(&[f]);
        assert!((t - 1.5).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let a = sim.flow(1e9, 0.0, &[link]);
        let b = sim.flow(1e9, 0.0, &[link]);
        let times = sim.wait_each(&[a, b]);
        for t in times {
            assert!((t - 2.0).abs() < 1e-9, "t={t}");
        }
    }

    #[test]
    fn unequal_flows_release_bandwidth() {
        // 1 GB and 3 GB on a 2 GB/s link: first finishes at 1 s (1 GB/s each),
        // the second then gets the full 2 GB/s: 1 + (3-1)/2 = 2 s total.
        let mut sim = Sim::new();
        let link = sim.resource("l", 2e9);
        let a = sim.flow(1e9, 0.0, &[link]);
        let b = sim.flow(3e9, 0.0, &[link]);
        let times = sim.wait_each(&[a, b]);
        assert!((times[0] - 1.0).abs() < 1e-9, "a={}", times[0]);
        assert!((times[1] - 2.0).abs() < 1e-9, "b={}", times[1]);
    }

    #[test]
    fn multi_resource_route_takes_min() {
        let mut sim = Sim::new();
        let fast = sim.resource("fast", 10e9);
        let slow = sim.resource("slow", 1e9);
        let f = sim.flow(1e9, 0.0, &[fast, slow]);
        let t = sim.wait_all(&[f]);
        assert!((t - 1.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn max_min_respects_bottleneck_and_spare() {
        // Flow A crosses L1 (1 GB/s) and L2 (10 GB/s); flow B crosses only L2.
        // A is capped at 1 GB/s by L1; B gets the rest of L2 (9 GB/s).
        let mut sim = Sim::new();
        let l1 = sim.resource("l1", 1e9);
        let l2 = sim.resource("l2", 10e9);
        let a = sim.flow(1e9, 0.0, &[l1, l2]);
        let b = sim.flow(9e9, 0.0, &[l2]);
        let times = sim.wait_each(&[a, b]);
        assert!((times[0] - 1.0).abs() < 1e-6, "a={}", times[0]);
        assert!((times[1] - 1.0).abs() < 1e-6, "b={}", times[1]);
    }

    #[test]
    fn pure_delay_flow() {
        let mut sim = Sim::new();
        let d = sim.delay(0.25);
        let t = sim.wait_all(&[d]);
        assert!((t - 0.25).abs() < 1e-12);
    }

    #[test]
    fn staggered_arrivals() {
        // B arrives at t=1 on a 1 GB/s link while A (2 GB) is mid-transfer.
        // A: 1 GB done by t=1, shares 0.5 each after; A done at t=3.
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let a = sim.flow(2e9, 0.0, &[link]);
        let b = sim.flow(1e9, 1.0, &[link]);
        let times = sim.wait_each(&[a, b]);
        assert!((times[0] - 3.0).abs() < 1e-9, "a={}", times[0]);
        assert!((times[1] - 3.0).abs() < 1e-9, "b={}", times[1]);
    }

    #[test]
    fn background_flow_keeps_progressing() {
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let bg = sim.flow(4e9, 0.0, &[link]);
        let fg = sim.flow(1e9, 0.0, &[link]);
        sim.wait_all(&[fg]);
        // fg done at t=2 (shared 0.5 GB/s each); bg then has 3 GB left at
        // the full 1 GB/s: done at t = 2 + 3 = 5.
        let t = sim.wait_all(&[bg]);
        assert!((t - 5.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn determinism_same_inputs_same_times() {
        let run = || {
            let mut sim = Sim::new();
            let l = sim.resource("l", 3.3e9);
            let flows: Vec<_> = (0..32)
                .map(|i| sim.flow(1e8 * (i + 1) as f64, 1e-6 * i as f64, &[l]))
                .collect();
            sim.wait_each(&flows)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn poll_does_not_advance() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let f = sim.flow(1e9, 0.0, &[l]);
        assert!(!sim.poll(f));
        assert_eq!(sim.now(), 0.0);
        sim.advance(2.0);
        assert!(sim.poll(f));
    }

    #[test]
    fn wait_any_returns_first_completion() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let slow = sim.flow(4e9, 0.0, &[l]);
        let fast = sim.delay(0.5);
        let (idx, t) = sim.wait_any(&[slow, fast]);
        assert_eq!(idx, 1);
        assert!((t - 0.5).abs() < 1e-12, "t={t}");
        assert!(!sim.poll(slow));
    }

    #[test]
    fn wait_any_tie_breaks_by_flow_id() {
        let mut sim = Sim::new();
        let a = sim.delay(1.0);
        let b = sim.delay(1.0);
        // Presented in reverse order: the earlier id must still win.
        let (idx, t) = sim.wait_any(&[b, a]);
        assert_eq!(idx, 1, "tie must resolve to the smaller flow id");
        assert!((t - 1.0).abs() < 1e-12);
    }

    #[test]
    fn op_wait_and_completion() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let op = Op::new(vec![sim.flow(1e9, 0.0, &[l]), sim.flow(2e9, 0.0, &[l])]);
        assert!(!sim.poll_op(&op));
        assert!(sim.op_completion(&op).is_none());
        let t = sim.wait_op(&op);
        assert!((t - 3.0).abs() < 1e-9, "t={t}");
        assert_eq!(sim.op_completion(&op), Some(t));
        // Empty op: trivially complete, waits return `now`.
        let empty = Op::done();
        assert!(sim.poll_op(&empty));
        assert_eq!(sim.wait_op(&empty), sim.now());
    }

    #[test]
    fn opset_poll_reap_wait() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let mut set = OpSet::new();
        set.push(Op::single(sim.flow(1e9, 0.0, &[l])));
        set.push(Op::single(sim.flow(3e9, 0.0, &[l])));
        set.push(Op::done()); // dropped on push
        assert_eq!(set.len(), 2);
        assert!(!set.poll(&sim));
        // Shared link: 0.5 GB/s each, first flow done at t=2; the second
        // then runs at full rate, 2 GB left: done at t=4.
        sim.advance(2.5);
        assert_eq!(set.reap(&sim), 1);
        assert_eq!(set.len(), 1);
        let t = set.wait_all(&mut sim);
        assert!(set.is_empty());
        assert!((t - 4.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn advance_until_is_absolute_and_monotone() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let f = sim.flow(1e9, 0.0, &[l]);
        sim.advance_until(3.0);
        assert_eq!(sim.now(), 3.0);
        assert!(sim.poll(f));
        sim.advance_until(1.0); // in the past: no-op
        assert_eq!(sim.now(), 3.0);
    }

    #[test]
    fn op_trace_reports_routes_rates_and_times() {
        let mut sim = Sim::new();
        let l = sim.resource("link-a", 1e9);
        let a = sim.flow(2e9, 0.0, &[l]);
        let _b = sim.flow(2e9, 1.0, &[l]);
        sim.advance(0.5); // a active alone at full rate
        let tr = sim.op_trace();
        assert_eq!(tr.len(), 2);
        assert_eq!(tr[0].id, a);
        assert_eq!(sim.resource_name(tr[0].route[0]), "link-a");
        assert!((tr[0].rate - 1e9).abs() < 1.0, "rate={}", tr[0].rate);
        assert_eq!(tr[1].start_at, 1.0);
        assert!(!tr[1].done && tr[1].finished_at.is_none());
        sim.run_until_idle();
        let tr = sim.op_trace();
        assert!(tr.iter().all(|e| e.done && e.rate == 0.0));
    }

    #[test]
    fn advance_moves_clock_past_events() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let f = sim.flow(1e9, 0.0, &[l]);
        sim.advance(5.0);
        assert_eq!(sim.now(), 5.0);
        assert!(sim.completed(f).is_some());
        assert!((sim.completed(f).unwrap() - 1.0).abs() < 1e-9);
    }
}

//! Fluid-flow discrete-event simulation core.
//!
//! Everything in the DEEP-ER reproduction that takes *time* — RDMA
//! transfers, NVMe writes, BeeGFS striping, checkpoint exchanges, compute
//! phases — is expressed as a **flow**: a number of bytes (or flops) moving
//! through a **route** of shared resources.  The engine advances a virtual
//! clock event-by-event and splits each resource's capacity across the
//! flows traversing it with progressive-filling **max-min fairness** (the
//! same fluid model SimGrid validates against packet-level simulators).
//!
//! This reproduces exactly the contention effects the paper's evaluation
//! hinges on: a BeeGFS storage backend saturating as more nodes write
//! (Fig. 6), node-local NVMe giving constant per-node bandwidth (Fig. 7),
//! and the NAM's two Tourmalet links bounding parity-pull bandwidth
//! (Figs. 3, 9).
//!
//! Determinism: ties are broken by flow id; the only randomness comes from
//! the seeded [`rng::SplitMix64`].
//!
//! # Hot-path design (DESIGN.md section 10)
//!
//! Per-event cost scales with *what changed*, not with everything active:
//!
//! * **Lazy flow progression** — a flow's byte count is settled only when
//!   its rate changes ([`Sim::flow_remaining`] settles on query); between
//!   rate changes the invariant `remaining(t) = remaining - rate * (t -
//!   touched_at)` holds implicitly, so an event never sweeps the active
//!   set.
//! * **Indexed finish heap** — predicted finish times live in a lazy-
//!   deletion min-heap keyed by `(finish-time bits, flow id)` (the same
//!   bit-ordering trick as [`PendingKey`]); an entry is valid only while
//!   its flow is active *and* still predicts that exact finish, so
//!   `next_event_time` is O(log n) amortized instead of an O(active) scan.
//! * **Component-scoped rate recomputation** — a per-resource incidence
//!   index (`res_flows`) is maintained on activation/retirement, and a
//!   change event re-runs progressive filling only over the connected
//!   component(s) of resources reachable from the changed flows.  Disjoint
//!   subsystems (each node's private NVMe channel, each CPU) keep their
//!   rates, predictions and heap entries untouched.

pub mod reference;
pub mod rng;

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};

/// Virtual time in seconds.
pub type SimTime = f64;

/// Index of a shared resource (link, NIC port, device channel, CPU...).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResId(pub usize);

/// Index of a flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FlowId(pub usize);

/// Process-wide count of simulation events, summed over every [`Sim`]
/// instance (exhibits build many simulators internally; the `repro bench
/// --csv` stats line reports the delta across one exhibit).
static EVENTS_TOTAL: AtomicU64 = AtomicU64::new(0);

/// Total events processed by every simulator in this process so far.
pub fn events_total() -> u64 {
    EVENTS_TOTAL.load(Ordering::Relaxed)
}

#[derive(Debug, Clone)]
struct Resource {
    name: String,
    /// Capacity in bytes/second (or flops/second for compute resources).
    capacity: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FlowState {
    /// Not yet started (latency offset still running).
    Pending,
    Active,
    Done,
}

#[derive(Debug, Clone)]
struct Flow {
    route: Vec<ResId>,
    /// Bytes left **as of `touched_at`** (lazy progression: the live value
    /// at time `t` is `remaining - rate * (t - touched_at)`; it is settled
    /// only when the rate changes or the flow is queried/finished).
    remaining: f64,
    /// Virtual time `remaining` was last settled at.
    touched_at: SimTime,
    state: FlowState,
    /// Kept for diagnostics ([`Sim::op_trace`]); scheduling reads the
    /// PendingKey heap instead.
    start_at: SimTime,
    finished_at: SimTime,
    /// Current allocated rate (updated by the component-scoped refill).
    rate: f64,
    /// Predicted finish at the current rate (INFINITY while rate is 0);
    /// the finish-heap entry carrying exactly these bits is the valid one.
    finish_at: SimTime,
}

impl Flow {
    /// Live remaining bytes at time `now` (does not settle).
    fn remaining_at(&self, now: SimTime) -> f64 {
        if self.state == FlowState::Active && self.rate > 0.0 {
            (self.remaining - self.rate * (now - self.touched_at)).max(0.0)
        } else {
            self.remaining
        }
    }
}

/// Handle to one in-flight logical **operation**: a set of flows that
/// jointly complete.  Every I/O layer (storage, BeeGFS/BeeOND, SIONlib,
/// NAM, psmpi) returns `Op`s; blocking calls are thin shims that
/// immediately [`Sim::wait_op`] the handle.  This is what lets lower-tier
/// checkpoint flushes run *in the background* of compute phases (the
/// checkpoint/compute-overlap pattern of Hukerikar & Engelmann 2017).
#[derive(Debug, Clone, Default)]
pub struct Op {
    flows: Vec<FlowId>,
}

impl Op {
    /// An operation over an explicit flow set.
    pub fn new(flows: Vec<FlowId>) -> Self {
        Self { flows }
    }

    /// An operation wrapping a single flow.
    pub fn single(flow: FlowId) -> Self {
        Self { flows: vec![flow] }
    }

    /// An already-complete operation (no flows).
    pub fn done() -> Self {
        Self::default()
    }

    /// Merge several operations into one that completes when all do.
    pub fn merge(ops: impl IntoIterator<Item = Op>) -> Self {
        let mut flows = Vec::new();
        for op in ops {
            flows.extend(op.flows);
        }
        Self { flows }
    }

    /// Absorb another operation into this one.
    pub fn join(&mut self, other: Op) {
        self.flows.extend(other.flows);
    }

    /// Add a bare flow to the operation.
    pub fn push(&mut self, flow: FlowId) {
        self.flows.push(flow);
    }

    /// The underlying flows (diagnostics / fine-grained waits).
    pub fn flows(&self) -> &[FlowId] {
        &self.flows
    }

    /// True when the operation carries no flows (trivially complete).
    pub fn is_empty(&self) -> bool {
        self.flows.is_empty()
    }
}

/// A set of independently issued [`Op`]s polled or awaited together —
/// e.g. the outstanding background flushes of a BeeOND cache domain or
/// the L3 flush queue of the multi-level checkpointer.
#[derive(Debug, Default)]
pub struct OpSet {
    ops: Vec<Op>,
}

impl OpSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, op: Op) {
        if !op.is_empty() {
            self.ops.push(op);
        }
    }

    /// Number of operations still tracked.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Total flows across all tracked operations.
    pub fn flow_count(&self) -> usize {
        self.ops.iter().map(|o| o.flows.len()).sum()
    }

    /// True when every tracked operation has completed (no time advance).
    pub fn poll(&self, sim: &Sim) -> bool {
        self.ops.iter().all(|o| sim.poll_op(o))
    }

    /// Drop every already-complete operation, returning how many settled.
    pub fn reap(&mut self, sim: &Sim) -> usize {
        let before = self.ops.len();
        self.ops.retain(|o| !sim.poll_op(o));
        before - self.ops.len()
    }

    /// Block until every tracked operation completes; empties the set and
    /// returns the completion time of the last one (now when empty).
    pub fn wait_all(&mut self, sim: &mut Sim) -> SimTime {
        let ops = std::mem::take(&mut self.ops);
        let all = Op::merge(ops);
        sim.wait_op(&all)
    }

    /// Discard all tracked operations without waiting (their flows keep
    /// progressing in the simulator, but nobody observes them anymore).
    pub fn abandon(&mut self) {
        self.ops.clear();
    }
}

/// One row of [`Sim::op_trace`]: the diagnostic view of a flow.
#[derive(Debug, Clone)]
pub struct OpTraceEntry {
    pub id: FlowId,
    /// Resources the flow traverses (names via [`Sim::resource_name`]).
    pub route: Vec<ResId>,
    /// When the flow's latency offset elapsed / will elapse.
    pub start_at: SimTime,
    /// Currently allocated rate (0 for pending or finished flows).
    pub rate: f64,
    pub done: bool,
    pub finished_at: Option<SimTime>,
}

/// Min-heap key for pending flows: (start_at bits, id).  start_at is
/// always >= 0, and non-negative IEEE-754 doubles order identically to
/// their bit patterns, so the u64 comparison is exact and total.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct PendingKey(u64, usize);

impl PendingKey {
    fn new(start_at: SimTime, id: FlowId) -> Self {
        debug_assert!(start_at >= 0.0);
        Self(start_at.to_bits(), id.0)
    }

    fn time(&self) -> SimTime {
        f64::from_bits(self.0)
    }

    fn id(&self) -> FlowId {
        FlowId(self.1)
    }
}

/// Min-heap key for predicted finishes: (finish_at bits, id), same
/// bit-ordering trick as [`PendingKey`].  Entries are **lazy-deletion**:
/// a rate change makes a flow's older entries stale (their bits no longer
/// match the flow's `finish_at`), and stale entries are discarded when
/// they surface at the top of the heap.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct FinishKey(u64, usize);

impl FinishKey {
    fn new(finish_at: SimTime, id: FlowId) -> Self {
        debug_assert!(finish_at >= 0.0);
        Self(finish_at.to_bits(), id.0)
    }

    fn time(&self) -> SimTime {
        f64::from_bits(self.0)
    }
}

/// The discrete-event engine.
///
/// ```
/// use deeper::sim::Sim;
/// let mut sim = Sim::new();
/// let link = sim.resource("link", 12.5e9);       // 100 Gbit/s
/// let a = sim.flow(1e9, 1.0e-6, &[link]);        // 1 GB after 1 us latency
/// let b = sim.flow(1e9, 1.0e-6, &[link]);        // contends with `a`
/// let t = sim.wait_all(&[a, b]);
/// assert!((t - 0.16).abs() / 0.16 < 1e-3);       // 2 GB over 12.5 GB/s
/// ```
#[derive(Debug, Default)]
pub struct Sim {
    now: SimTime,
    resources: Vec<Resource>,
    flows: Vec<Flow>,
    /// Incidence index: **active** flows on each resource (one entry per
    /// route occurrence), maintained on activation/retirement.  These are
    /// both the component-discovery adjacency lists and the progressive-
    /// filling work lists — nothing is rebuilt per event.
    res_flows: Vec<Vec<FlowId>>,
    /// Pending flows in a min-heap by (start_at, id): O(log P) activation
    /// instead of an O(P) scan per event (see DESIGN.md section 10).
    pending: BinaryHeap<Reverse<PendingKey>>,
    /// Predicted finishes, lazy-deletion min-heap (DESIGN.md section 10).
    finish: BinaryHeap<Reverse<FinishKey>>,
    /// Flows whose activation/retirement triggered this event's refill.
    dirty: Vec<FlowId>,
    /// Flows that completed during the most recent [`Sim::step`]; waiters
    /// examine only this delta instead of rescanning their wait sets.
    finished_step: Vec<FlowId>,
    /// Scratch buffers reused across rate recomputations (hot path):
    /// per-resource residual capacity / unfixed count, plus the list of
    /// component resources so clearing is O(component), not O(R).
    scratch_residual: Vec<f64>,
    scratch_unfixed: Vec<u32>,
    scratch_touched: Vec<ResId>,
    /// Flows of the component(s) being refilled, in discovery order.
    comp_flows: Vec<FlowId>,
    /// Epoch stamps (no per-call clearing): resource-in-component,
    /// flow-in-component, flow-rate-fixed.
    scratch_res_epoch: Vec<u64>,
    scratch_comp_epoch: Vec<u64>,
    scratch_fixed_epoch: Vec<u64>,
    epoch: u64,
    /// Events processed by this simulator (diagnostics).
    events: u64,
    /// Largest flow set a single refill had to touch (diagnostics; the
    /// `repro bench scale` exhibit reports this as "peak component").
    peak_component: usize,
}

impl Sim {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Register a shared resource with `capacity` bytes/s (flops/s).
    pub fn resource(&mut self, name: impl Into<String>, capacity: f64) -> ResId {
        assert!(capacity > 0.0, "resource capacity must be positive");
        self.resources.push(Resource { name: name.into(), capacity });
        self.res_flows.push(Vec::new());
        ResId(self.resources.len() - 1)
    }

    /// Resource capacity in bytes/s.
    pub fn capacity(&self, r: ResId) -> f64 {
        self.resources[r.0].capacity
    }

    /// Start a flow of `bytes` through `route`, beginning after `delay`
    /// seconds of latency (pure offset, consumes no bandwidth).
    pub fn flow(&mut self, bytes: f64, delay: SimTime, route: &[ResId]) -> FlowId {
        assert!(bytes >= 0.0 && delay >= 0.0);
        assert!(!route.is_empty(), "flow route must name at least one resource");
        let id = FlowId(self.flows.len());
        let start_at = self.now + delay;
        self.flows.push(Flow {
            route: route.to_vec(),
            remaining: bytes,
            touched_at: start_at,
            state: FlowState::Pending,
            start_at,
            finished_at: f64::INFINITY,
            rate: 0.0,
            finish_at: f64::INFINITY,
        });
        self.pending.push(Reverse(PendingKey::new(start_at, id)));
        id
    }

    /// A pure-delay flow (no bandwidth consumed): models fixed software
    /// overheads (metadata round-trips, syscalls, kernel-launch latency).
    pub fn delay(&mut self, seconds: SimTime) -> FlowId {
        // Zero bytes on a dummy route: completes exactly at start_at.
        let id = FlowId(self.flows.len());
        let start_at = self.now + seconds;
        self.flows.push(Flow {
            route: Vec::new(),
            remaining: 0.0,
            touched_at: start_at,
            state: FlowState::Pending,
            start_at,
            finished_at: f64::INFINITY,
            rate: 0.0,
            finish_at: f64::INFINITY,
        });
        self.pending.push(Reverse(PendingKey::new(start_at, id)));
        id
    }

    /// Completion time of a finished flow.
    pub fn completed(&self, f: FlowId) -> Option<SimTime> {
        let fl = &self.flows[f.0];
        (fl.state == FlowState::Done).then_some(fl.finished_at)
    }

    /// Non-advancing completion query: has `f` finished?
    pub fn poll(&self, f: FlowId) -> bool {
        self.flows[f.0].state == FlowState::Done
    }

    /// Non-advancing completion query over an [`Op`] (empty ops are done).
    pub fn poll_op(&self, op: &Op) -> bool {
        op.flows.iter().all(|&f| self.poll(f))
    }

    /// Completion time of an [`Op`]: the latest flow completion, or None
    /// while any flow is still in flight.  Empty ops complete at 0.
    pub fn op_completion(&self, op: &Op) -> Option<SimTime> {
        let mut t = 0.0f64;
        for &f in &op.flows {
            t = t.max(self.completed(f)?);
        }
        Some(t)
    }

    /// Block until `op` completes; returns its completion time (now for
    /// empty ops).  The blocking shim every async layer builds on.
    pub fn wait_op(&mut self, op: &Op) -> SimTime {
        if op.flows.is_empty() {
            return self.now;
        }
        self.wait_all(&op.flows)
    }

    /// Advance until all `flows` complete; returns the time of the last one.
    /// Other in-flight flows keep progressing (this is how BeeOND's
    /// asynchronous flush overlaps the next compute phase).
    pub fn wait_all(&mut self, flows: &[FlowId]) -> SimTime {
        // Amortized-O(1) completion check: a cursor over the wait set.
        // Each event re-examines exactly one flow (`flows[cursor]`), never
        // the whole set; completions of the others are picked up as the
        // cursor passes them (step() additionally surfaces the per-event
        // finish delta via finished_last_step for wait_any-style waiters).
        let mut cursor = 0;
        while cursor < flows.len() {
            if self.flows[flows[cursor].0].state == FlowState::Done {
                cursor += 1;
                continue;
            }
            if !self.step() {
                panic!("simulation deadlock: waited-on flow cannot complete");
            }
        }
        flows
            .iter()
            .map(|&f| self.flows[f.0].finished_at)
            .fold(0.0, f64::max)
    }

    /// Per-flow completion times, advancing as needed.
    pub fn wait_each(&mut self, flows: &[FlowId]) -> Vec<SimTime> {
        self.wait_all(flows);
        flows.iter().map(|&f| self.flows[f.0].finished_at).collect()
    }

    /// Advance until the **first** of `flows` completes; returns its index
    /// in the slice and its completion time.  Determinism: when several
    /// flows are already (or become) complete, the winner is the one with
    /// the earliest completion time, ties broken by the smaller flow id —
    /// never by slice position, so permuting the wait set cannot change
    /// the outcome.
    ///
    /// Cost: one full scan of the wait set on entry (flows may have
    /// completed before the call); afterwards only the per-event finish
    /// delta surfaced by `step()` is examined, so a large wait set adds
    /// nothing to the per-event cost.
    pub fn wait_any(&mut self, flows: &[FlowId]) -> (usize, SimTime) {
        assert!(!flows.is_empty(), "wait_any on an empty flow set");
        // Duplicate entries keep their first slice position (that is the
        // index the old full-rescan loop would have reported).
        let mut index_of: HashMap<FlowId, usize> = HashMap::with_capacity(flows.len());
        for (i, &f) in flows.iter().enumerate() {
            index_of.entry(f).or_insert(i);
        }
        let mut best: Option<(SimTime, FlowId)> = None;
        let consider = |best: &mut Option<(SimTime, FlowId)>, t: SimTime, f: FlowId| {
            let better = match *best {
                None => true,
                Some((bt, bf)) => t < bt || (t == bt && f < bf),
            };
            if better {
                *best = Some((t, f));
            }
        };
        for &f in flows {
            if let Some(t) = self.completed(f) {
                consider(&mut best, t, f);
            }
        }
        while best.is_none() {
            if !self.step() {
                panic!("simulation deadlock: no waited-on flow can complete");
            }
            for &f in &self.finished_step {
                if index_of.contains_key(&f) {
                    let t = self.flows[f.0].finished_at;
                    consider(&mut best, t, f);
                }
            }
        }
        let (t, f) = best.unwrap();
        (index_of[&f], t)
    }

    /// Run until no pending/active flows remain.
    pub fn run_until_idle(&mut self) {
        while self.step() {}
    }

    /// Jump the clock forward by `seconds` (processing any events inside).
    pub fn advance(&mut self, seconds: SimTime) {
        let target = self.now + seconds;
        loop {
            match self.next_event_time() {
                Some(t) if t <= target => {
                    if !self.step() {
                        break;
                    }
                }
                _ => break,
            }
        }
        // Parking the clock between events is safe: per-flow progress is a
        // function of (remaining, touched_at, rate), not of the event the
        // bytes were last settled at, so nothing is lost by the jump.
        self.now = self.now.max(target);
    }

    /// Jump the clock to the **absolute** virtual time `target`
    /// (processing any events inside); a no-op when `target` is in the
    /// past.  The absolute-time counterpart of [`Sim::advance`] for
    /// callers that schedule against timestamps (e.g. lining a scenario
    /// up with a recorded completion time).
    pub fn advance_until(&mut self, target: SimTime) {
        let dt = target - self.now;
        if dt > 0.0 {
            self.advance(dt);
        }
    }

    /// Number of flows ever created (diagnostics).
    pub fn flow_count(&self) -> usize {
        self.flows.len()
    }

    /// Events processed by this simulator so far (diagnostics; see
    /// [`events_total`] for the process-wide aggregate).
    pub fn events(&self) -> u64 {
        self.events
    }

    /// Largest flow set one rate refill touched (the union of connected
    /// components reachable from an event's changed flows); the scale
    /// bench reports this as "peak component".
    pub fn peak_component_flows(&self) -> usize {
        self.peak_component
    }

    /// Flows that completed during the most recent event (the delta
    /// surfaced for [`Sim::wait_any`]-style waiters).  All entries share
    /// the same `finished_at` (the event time).
    pub fn finished_last_step(&self) -> &[FlowId] {
        &self.finished_step
    }

    /// Name a resource was registered under (diagnostics).
    pub fn resource_name(&self, r: ResId) -> &str {
        &self.resources[r.0].name
    }

    /// Diagnostic snapshot of every flow ever issued: route, start time,
    /// current rate and completion.  This is the observability surface the
    /// overlap bench prints (`repro bench fig8-async`) and the property
    /// suite uses to audit per-resource rate allocations.
    pub fn op_trace(&self) -> Vec<OpTraceEntry> {
        self.flows
            .iter()
            .enumerate()
            .map(|(i, fl)| OpTraceEntry {
                id: FlowId(i),
                route: fl.route.clone(),
                start_at: fl.start_at,
                rate: if fl.state == FlowState::Active { fl.rate } else { 0.0 },
                done: fl.state == FlowState::Done,
                finished_at: (fl.state == FlowState::Done).then_some(fl.finished_at),
            })
            .collect()
    }

    // ------------------------------------------------------------------
    // engine internals
    // ------------------------------------------------------------------

    /// Earliest upcoming event: the pending-heap top or the first *valid*
    /// finish-heap entry (stale entries are discarded on the way).
    fn next_event_time(&mut self) -> Option<SimTime> {
        let start = self
            .pending
            .peek()
            .map(|Reverse(k)| k.time())
            .unwrap_or(f64::INFINITY);
        let finish = loop {
            match self.finish.peek() {
                None => break f64::INFINITY,
                Some(&Reverse(k)) => {
                    let fl = &self.flows[k.1];
                    if fl.state != FlowState::Active || fl.finish_at.to_bits() != k.0 {
                        self.finish.pop(); // lazy deletion
                    } else {
                        break k.time();
                    }
                }
            }
        };
        let t = start.min(finish);
        t.is_finite().then_some(t)
    }

    /// Process one event; returns false when idle.  No per-flow sweep
    /// happens here: progression is implicit in (remaining, touched_at,
    /// rate), and only the flows whose state changes are settled.
    fn step(&mut self) -> bool {
        self.finished_step.clear();
        let Some(t) = self.next_event_time() else {
            return false;
        };
        if t > self.now {
            self.now = t;
        }
        self.events += 1;
        EVENTS_TOTAL.fetch_add(1, Ordering::Relaxed);
        self.dirty.clear();

        // Activate pending flows whose latency elapsed (heap pops in
        // (start_at, id) order, so activation order is deterministic).
        while let Some(&Reverse(k)) = self.pending.peek() {
            if k.time() > self.now + 1e-15 {
                break;
            }
            self.pending.pop();
            let f = k.id();
            let fl = &mut self.flows[f.0];
            // Sub-nanobyte flows (and pure delays) complete on arrival —
            // the same threshold the retirement check applies to a
            // just-activated (rate 0) flow.
            if fl.remaining <= 1e-9 {
                fl.remaining = 0.0;
                fl.state = FlowState::Done;
                fl.finished_at = self.now;
                self.finished_step.push(f);
            } else {
                fl.state = FlowState::Active;
                fl.touched_at = self.now;
                for &r in &self.flows[f.0].route {
                    self.res_flows[r.0].push(f);
                }
                self.dirty.push(f);
            }
        }

        // Retire due finishes: pop valid heap entries whose flows are
        // within the completion epsilon of `now` (remaining <= 1e-9 *
        // max(rate, 1) bytes — near-simultaneous finishes merge into one
        // event, exactly like the eager engine's retirement scan did).
        loop {
            let Some(&Reverse(k)) = self.finish.peek() else {
                break;
            };
            let f = FlowId(k.1);
            {
                let fl = &self.flows[f.0];
                if fl.state != FlowState::Active || fl.finish_at.to_bits() != k.0 {
                    self.finish.pop(); // stale
                    continue;
                }
                let due = k.time() <= self.now
                    || (k.time() - self.now) * fl.rate <= 1e-9 * fl.rate.max(1.0);
                if !due {
                    break;
                }
            }
            self.finish.pop();
            let fl = &mut self.flows[f.0];
            fl.remaining = 0.0;
            fl.touched_at = self.now;
            fl.state = FlowState::Done;
            fl.finished_at = self.now;
            self.finished_step.push(f);
            // One incidence entry is removed per route occurrence; the
            // O(flows-on-resource) scan is dominated by the refill that
            // must visit the same component anyway.
            for &r in &self.flows[f.0].route {
                let v = &mut self.res_flows[r.0];
                if let Some(p) = v.iter().position(|&x| x == f) {
                    v.swap_remove(p);
                }
            }
            self.dirty.push(f);
        }

        if !self.dirty.is_empty() {
            self.recompute_component();
        }
        true
    }

    /// Settle `f`'s progress at `now` and assign a new rate, refreshing
    /// its predicted finish and finish-heap entry.  A no-op when the rate
    /// is unchanged — the standing prediction and heap entry stay valid,
    /// which is what keeps disjoint components entirely untouched.
    ///
    /// An associated function over the two fields it mutates, so callers
    /// can invoke it while iterating the (disjoint) incidence lists.
    fn assign_rate(
        flows: &mut [Flow],
        finish: &mut BinaryHeap<Reverse<FinishKey>>,
        now: SimTime,
        f: FlowId,
        new_rate: f64,
    ) {
        let fl = &mut flows[f.0];
        if fl.rate == new_rate {
            return;
        }
        if fl.rate > 0.0 {
            // Lazy-progression settlement: bank the bytes moved at the
            // old rate since the flow was last touched.
            fl.remaining = (fl.remaining - fl.rate * (now - fl.touched_at)).max(0.0);
        }
        fl.touched_at = now;
        fl.rate = new_rate;
        fl.finish_at = if new_rate > 0.0 {
            now + fl.remaining / new_rate
        } else {
            f64::INFINITY
        };
        if fl.finish_at.is_finite() {
            finish.push(Reverse(FinishKey::new(fl.finish_at, f)));
        }
    }

    /// Component-scoped progressive-filling max-min fair allocation.
    ///
    /// Hot-path notes (DESIGN.md section 10): starting from the routes of
    /// this event's changed flows, the incidence index is walked to close
    /// over the connected component(s) they touch; progressive filling
    /// then runs over exactly that flow/resource set.  Rates, predictions
    /// and heap entries of disjoint subsystems are untouched, and within
    /// the component a flow whose refilled rate is unchanged keeps its
    /// standing finish prediction (no settle, no heap churn).  All
    /// bottlenecks tied at the minimum share fix in one pass (672
    /// independent NVMe writers collapse to a single iteration), and the
    /// "fixed"/"visited" marks are epoch-stamped so nothing is cleared or
    /// re-allocated per call.
    fn recompute_component(&mut self) {
        let nres = self.resources.len();
        if self.scratch_residual.len() < nres {
            self.scratch_residual.resize(nres, 0.0);
            self.scratch_unfixed.resize(nres, 0);
            self.scratch_res_epoch.resize(nres, 0);
        }
        let nflows = self.flows.len();
        if self.scratch_fixed_epoch.len() < nflows {
            self.scratch_fixed_epoch.resize(nflows, 0);
            self.scratch_comp_epoch.resize(nflows, 0);
        }
        self.epoch += 1;
        let epoch = self.epoch;
        self.scratch_touched.clear();
        self.comp_flows.clear();

        // Seed the walk with the routes of the changed flows (finished
        // flows are already out of the incidence lists but their resources
        // must be refilled; activated flows are in and will be found).
        for &f in &self.dirty {
            for &r in &self.flows[f.0].route {
                if self.scratch_res_epoch[r.0] != epoch {
                    self.scratch_res_epoch[r.0] = epoch;
                    self.scratch_touched.push(r);
                }
            }
        }
        // Close over the flow<->resource incidence: `scratch_touched`
        // doubles as the BFS queue (cursor `i`).
        let mut i = 0;
        while i < self.scratch_touched.len() {
            let r = self.scratch_touched[i];
            i += 1;
            for &f in &self.res_flows[r.0] {
                if self.scratch_comp_epoch[f.0] != epoch {
                    self.scratch_comp_epoch[f.0] = epoch;
                    self.comp_flows.push(f);
                    for &r2 in &self.flows[f.0].route {
                        if self.scratch_res_epoch[r2.0] != epoch {
                            self.scratch_res_epoch[r2.0] = epoch;
                            self.scratch_touched.push(r2);
                        }
                    }
                }
            }
        }
        if self.comp_flows.len() > self.peak_component {
            self.peak_component = self.comp_flows.len();
        }

        for &r in &self.scratch_touched {
            self.scratch_residual[r.0] = self.resources[r.0].capacity;
            self.scratch_unfixed[r.0] = self.res_flows[r.0].len() as u32;
        }

        let now = self.now;
        let mut remaining = self.comp_flows.len();
        while remaining > 0 {
            // Smallest fair share among component resources with unfixed
            // flows.
            let mut min_share = f64::INFINITY;
            for &r in &self.scratch_touched {
                let n = self.scratch_unfixed[r.0];
                if n == 0 {
                    continue;
                }
                let share = self.scratch_residual[r.0] / n as f64;
                if share < min_share {
                    min_share = share;
                }
            }
            if !min_share.is_finite() {
                // Remaining flows have no loaded resource left: rate 0.
                for &f in &self.comp_flows {
                    if self.scratch_fixed_epoch[f.0] != epoch {
                        Self::assign_rate(&mut self.flows, &mut self.finish, now, f, 0.0);
                    }
                }
                break;
            }
            // Fix every unfixed flow on every bottleneck tied at min_share.
            let eps = min_share * 1e-12 + 1e-30;
            let mut progressed = false;
            for &r in &self.scratch_touched {
                let n = self.scratch_unfixed[r.0];
                if n == 0 {
                    continue;
                }
                let share = self.scratch_residual[r.0] / n as f64;
                if share - min_share > eps {
                    continue;
                }
                // This resource is a bottleneck: fix its unfixed flows.
                for &f in &self.res_flows[r.0] {
                    if self.scratch_fixed_epoch[f.0] == epoch {
                        continue;
                    }
                    self.scratch_fixed_epoch[f.0] = epoch;
                    Self::assign_rate(&mut self.flows, &mut self.finish, now, f, min_share);
                    remaining -= 1;
                    progressed = true;
                    for &fr in &self.flows[f.0].route {
                        self.scratch_residual[fr.0] =
                            (self.scratch_residual[fr.0] - min_share).max(0.0);
                        self.scratch_unfixed[fr.0] -= 1;
                    }
                }
            }
            if !progressed {
                // Numerical corner: nothing progressed; zero out the rest.
                for &f in &self.comp_flows {
                    if self.scratch_fixed_epoch[f.0] != epoch {
                        Self::assign_rate(&mut self.flows, &mut self.finish, now, f, 0.0);
                    }
                }
                break;
            }
        }
    }

    /// Live remaining bytes of a flow at the current clock (settling is
    /// read-only: the stored state is untouched).  Diagnostics / tests.
    pub fn flow_remaining(&self, f: FlowId) -> f64 {
        self.flows[f.0].remaining_at(self.now)
    }

    /// Process exactly **one** simulation event; returns false when no
    /// pending or active flows remain.  The public single-step entry for
    /// schedulers that interleave many independent waiters on one clock
    /// (the fleet scheduler polls its jobs' front [`Op`]s between events
    /// instead of blocking inside any single job's wait).
    pub fn step_event(&mut self) -> bool {
        self.step()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_flow_takes_bytes_over_capacity() {
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let f = sim.flow(2e9, 0.0, &[link]);
        let t = sim.wait_all(&[f]);
        assert!((t - 2.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn latency_is_pure_offset() {
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let f = sim.flow(1e9, 0.5, &[link]);
        let t = sim.wait_all(&[f]);
        assert!((t - 1.5).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let a = sim.flow(1e9, 0.0, &[link]);
        let b = sim.flow(1e9, 0.0, &[link]);
        let times = sim.wait_each(&[a, b]);
        for t in times {
            assert!((t - 2.0).abs() < 1e-9, "t={t}");
        }
    }

    #[test]
    fn unequal_flows_release_bandwidth() {
        // 1 GB and 3 GB on a 2 GB/s link: first finishes at 1 s (1 GB/s each),
        // the second then gets the full 2 GB/s: 1 + (3-1)/2 = 2 s total.
        let mut sim = Sim::new();
        let link = sim.resource("l", 2e9);
        let a = sim.flow(1e9, 0.0, &[link]);
        let b = sim.flow(3e9, 0.0, &[link]);
        let times = sim.wait_each(&[a, b]);
        assert!((times[0] - 1.0).abs() < 1e-9, "a={}", times[0]);
        assert!((times[1] - 2.0).abs() < 1e-9, "b={}", times[1]);
    }

    #[test]
    fn multi_resource_route_takes_min() {
        let mut sim = Sim::new();
        let fast = sim.resource("fast", 10e9);
        let slow = sim.resource("slow", 1e9);
        let f = sim.flow(1e9, 0.0, &[fast, slow]);
        let t = sim.wait_all(&[f]);
        assert!((t - 1.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn max_min_respects_bottleneck_and_spare() {
        // Flow A crosses L1 (1 GB/s) and L2 (10 GB/s); flow B crosses only L2.
        // A is capped at 1 GB/s by L1; B gets the rest of L2 (9 GB/s).
        let mut sim = Sim::new();
        let l1 = sim.resource("l1", 1e9);
        let l2 = sim.resource("l2", 10e9);
        let a = sim.flow(1e9, 0.0, &[l1, l2]);
        let b = sim.flow(9e9, 0.0, &[l2]);
        let times = sim.wait_each(&[a, b]);
        assert!((times[0] - 1.0).abs() < 1e-6, "a={}", times[0]);
        assert!((times[1] - 1.0).abs() < 1e-6, "b={}", times[1]);
    }

    #[test]
    fn pure_delay_flow() {
        let mut sim = Sim::new();
        let d = sim.delay(0.25);
        let t = sim.wait_all(&[d]);
        assert!((t - 0.25).abs() < 1e-12);
    }

    #[test]
    fn staggered_arrivals() {
        // B arrives at t=1 on a 1 GB/s link while A (2 GB) is mid-transfer.
        // A: 1 GB done by t=1, shares 0.5 each after; A done at t=3.
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let a = sim.flow(2e9, 0.0, &[link]);
        let b = sim.flow(1e9, 1.0, &[link]);
        let times = sim.wait_each(&[a, b]);
        assert!((times[0] - 3.0).abs() < 1e-9, "a={}", times[0]);
        assert!((times[1] - 3.0).abs() < 1e-9, "b={}", times[1]);
    }

    #[test]
    fn background_flow_keeps_progressing() {
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let bg = sim.flow(4e9, 0.0, &[link]);
        let fg = sim.flow(1e9, 0.0, &[link]);
        sim.wait_all(&[fg]);
        // fg done at t=2 (shared 0.5 GB/s each); bg then has 3 GB left at
        // the full 1 GB/s: done at t = 2 + 3 = 5.
        let t = sim.wait_all(&[bg]);
        assert!((t - 5.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn determinism_same_inputs_same_times() {
        let run = || {
            let mut sim = Sim::new();
            let l = sim.resource("l", 3.3e9);
            let flows: Vec<_> = (0..32)
                .map(|i| sim.flow(1e8 * (i + 1) as f64, 1e-6 * i as f64, &[l]))
                .collect();
            sim.wait_each(&flows)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn poll_does_not_advance() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let f = sim.flow(1e9, 0.0, &[l]);
        assert!(!sim.poll(f));
        assert_eq!(sim.now(), 0.0);
        sim.advance(2.0);
        assert!(sim.poll(f));
    }

    #[test]
    fn wait_any_returns_first_completion() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let slow = sim.flow(4e9, 0.0, &[l]);
        let fast = sim.delay(0.5);
        let (idx, t) = sim.wait_any(&[slow, fast]);
        assert_eq!(idx, 1);
        assert!((t - 0.5).abs() < 1e-12, "t={t}");
        assert!(!sim.poll(slow));
    }

    #[test]
    fn wait_any_tie_breaks_by_flow_id() {
        let mut sim = Sim::new();
        let a = sim.delay(1.0);
        let b = sim.delay(1.0);
        // Presented in reverse order: the earlier id must still win.
        let (idx, t) = sim.wait_any(&[b, a]);
        assert_eq!(idx, 1, "tie must resolve to the smaller flow id");
        assert!((t - 1.0).abs() < 1e-12);
    }

    #[test]
    fn wait_any_already_done_prefers_earliest_completion() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let early = sim.flow(1e9, 0.0, &[l]); // alone: done at 1.0
        sim.wait_all(&[early]);
        let late = sim.flow(1e9, 0.0, &[l]); // done at 2.0
        sim.wait_all(&[late]);
        // Both complete before the call: earliest completion wins even
        // though it sits later in the slice.
        let (idx, t) = sim.wait_any(&[late, early]);
        assert_eq!(idx, 1);
        assert!((t - 1.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn op_wait_and_completion() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let op = Op::new(vec![sim.flow(1e9, 0.0, &[l]), sim.flow(2e9, 0.0, &[l])]);
        assert!(!sim.poll_op(&op));
        assert!(sim.op_completion(&op).is_none());
        let t = sim.wait_op(&op);
        assert!((t - 3.0).abs() < 1e-9, "t={t}");
        assert_eq!(sim.op_completion(&op), Some(t));
        // Empty op: trivially complete, waits return `now`.
        let empty = Op::done();
        assert!(sim.poll_op(&empty));
        assert_eq!(sim.wait_op(&empty), sim.now());
    }

    #[test]
    fn opset_poll_reap_wait() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let mut set = OpSet::new();
        set.push(Op::single(sim.flow(1e9, 0.0, &[l])));
        set.push(Op::single(sim.flow(3e9, 0.0, &[l])));
        set.push(Op::done()); // dropped on push
        assert_eq!(set.len(), 2);
        assert!(!set.poll(&sim));
        // Shared link: 0.5 GB/s each, first flow done at t=2; the second
        // then runs at full rate, 2 GB left: done at t=4.
        sim.advance(2.5);
        assert_eq!(set.reap(&sim), 1);
        assert_eq!(set.len(), 1);
        let t = set.wait_all(&mut sim);
        assert!(set.is_empty());
        assert!((t - 4.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn advance_until_is_absolute_and_monotone() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let f = sim.flow(1e9, 0.0, &[l]);
        sim.advance_until(3.0);
        assert_eq!(sim.now(), 3.0);
        assert!(sim.poll(f));
        sim.advance_until(1.0); // in the past: no-op
        assert_eq!(sim.now(), 3.0);
    }

    #[test]
    fn advance_between_events_loses_no_progress() {
        // Park the clock twice between events: lazy progression must not
        // drop the bytes moved across the parks (the eager engine's sweep
        // only ran at events, so mid-gap parking lost the gap's bytes).
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let f = sim.flow(2e9, 0.0, &[l]);
        sim.advance(0.5);
        assert!((sim.flow_remaining(f) - 1.5e9).abs() < 1.0);
        sim.advance(0.5);
        assert!((sim.flow_remaining(f) - 1.0e9).abs() < 1.0);
        let t = sim.wait_all(&[f]);
        assert!((t - 2.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn op_trace_reports_routes_rates_and_times() {
        let mut sim = Sim::new();
        let l = sim.resource("link-a", 1e9);
        let a = sim.flow(2e9, 0.0, &[l]);
        let _b = sim.flow(2e9, 1.0, &[l]);
        sim.advance(0.5); // a active alone at full rate
        let tr = sim.op_trace();
        assert_eq!(tr.len(), 2);
        assert_eq!(tr[0].id, a);
        assert_eq!(sim.resource_name(tr[0].route[0]), "link-a");
        assert!((tr[0].rate - 1e9).abs() < 1.0, "rate={}", tr[0].rate);
        assert_eq!(tr[1].start_at, 1.0);
        assert!(!tr[1].done && tr[1].finished_at.is_none());
        sim.run_until_idle();
        let tr = sim.op_trace();
        assert!(tr.iter().all(|e| e.done && e.rate == 0.0));
    }

    #[test]
    fn advance_moves_clock_past_events() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let f = sim.flow(1e9, 0.0, &[l]);
        sim.advance(5.0);
        assert_eq!(sim.now(), 5.0);
        assert!(sim.completed(f).is_some());
        assert!((sim.completed(f).unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn refill_is_component_scoped() {
        // Two disjoint links with staggered activity: each refill touches
        // only the changed link's component, never the union of both.
        let mut sim = Sim::new();
        let la = sim.resource("la", 1e9);
        let lb = sim.resource("lb", 1e9);
        let a1 = sim.flow(4e9, 0.0, &[la]);
        let a2 = sim.flow(4e9, 0.0, &[la]);
        let _b = sim.flow(1e9, 0.5, &[lb]); // activates alone at t=0.5
        sim.run_until_idle();
        assert!(sim.poll(a1) && sim.poll(a2));
        // Peak refill: the two flows sharing `la` (t=0).  b's activation
        // at t=0.5 and every later finish touch strictly fewer flows.
        assert_eq!(sim.peak_component_flows(), 2);
    }

    #[test]
    fn event_counters_tick() {
        let g0 = events_total();
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        sim.flow(1e9, 0.0, &[l]);
        sim.flow(1e9, 0.1, &[l]);
        sim.run_until_idle();
        assert!(sim.events() >= 3, "events={}", sim.events());
        assert!(events_total() >= g0 + sim.events());
    }

    #[test]
    fn finished_last_step_surfaces_delta() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let a = sim.flow(1e9, 0.0, &[l]);
        let b = sim.flow(1e9, 0.0, &[l]); // same size: both finish at t=2
        sim.advance(3.0);
        // Both completed during the same (final) event.
        assert!(sim.poll(a) && sim.poll(b));
        let delta = sim.finished_last_step();
        assert_eq!(delta.len(), 2, "delta={delta:?}");
        assert!(delta.contains(&a) && delta.contains(&b));
    }

    #[test]
    fn lazy_remaining_matches_rate_integral() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let a = sim.flow(3e9, 0.0, &[l]);
        let _b = sim.flow(1e9, 1.0, &[l]);
        sim.advance(0.25); // a alone at 1 GB/s
        assert!((sim.flow_remaining(a) - 2.75e9).abs() < 1.0);
        sim.advance(1.25); // t=1.5: a ran 1 s at 1 GB/s, then 0.5 s at 0.5
        assert!((sim.flow_remaining(a) - 1.75e9).abs() < 1.0);
    }
}

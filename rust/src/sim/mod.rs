//! Fluid-flow discrete-event simulation core.
//!
//! Everything in the DEEP-ER reproduction that takes *time* — RDMA
//! transfers, NVMe writes, BeeGFS striping, checkpoint exchanges, compute
//! phases — is expressed as a **flow**: a number of bytes (or flops) moving
//! through a **route** of shared resources.  The engine advances a virtual
//! clock event-by-event and splits each resource's capacity across the
//! flows traversing it with progressive-filling **max-min fairness** (the
//! same fluid model SimGrid validates against packet-level simulators).
//!
//! This reproduces exactly the contention effects the paper's evaluation
//! hinges on: a BeeGFS storage backend saturating as more nodes write
//! (Fig. 6), node-local NVMe giving constant per-node bandwidth (Fig. 7),
//! and the NAM's two Tourmalet links bounding parity-pull bandwidth
//! (Figs. 3, 9).
//!
//! Determinism: ties are broken by flow id; the only randomness comes from
//! the seeded [`rng::SplitMix64`].
//!
//! # Hot-path design (DESIGN.md section 10)
//!
//! Per-event cost scales with *what changed*, not with everything active:
//!
//! * **Lazy flow progression** — a flow's byte count is settled only when
//!   its rate changes ([`Sim::flow_remaining`] settles on query); between
//!   rate changes the invariant `remaining(t) = remaining - rate * (t -
//!   touched_at)` holds implicitly, so an event never sweeps the active
//!   set.
//! * **Indexed finish heap** — predicted finish times live in a lazy-
//!   deletion min-heap keyed by `(finish-time bits, flow id)` (the same
//!   bit-ordering trick as [`PendingKey`]); an entry is valid only while
//!   its flow is active *and* still predicts that exact finish, so
//!   `next_event_time` is O(log n) amortized instead of an O(active) scan.
//! * **Component-scoped rate recomputation** — a per-resource incidence
//!   index (`res_flows`) is maintained on activation/retirement, and a
//!   change event re-runs progressive filling only over the connected
//!   component(s) of resources reachable from the changed flows.  Disjoint
//!   subsystems (each node's private NVMe channel, each CPU) keep their
//!   rates, predictions and heap entries untouched.
//!
//! # Traffic-class QoS (DESIGN.md section 12)
//!
//! Every flow carries a [`TrafficClass`] and a weight, and the
//! progressive fill is **weighted** max-min with optional per-(resource,
//! class) rate **floors** (guarantees) and **ceilings** (shaping caps):
//!
//! * The ambient [`Sim::issue_class`] tags newly issued flows; the I/O
//!   layers set/restore it around the flows they issue
//!   ([`Sim::default_issue_class`]), so callers that know a more specific
//!   purpose win.  Weights come from the per-class table
//!   ([`Sim::set_class_weight`]) unless overridden per flow.
//! * A **ceiling** ([`Sim::set_class_ceiling`]) materializes as a shadow
//!   resource of that capacity appended to the routes of matching flows —
//!   shaping reuses the untouched max-min machinery, and the shadow joins
//!   the incidence graph so component scoping stays lossless.  Configure
//!   ceilings before issuing the flows they should cap (routes are fixed
//!   at creation).
//! * A **floor** ([`Sim::set_class_floor`]) reserves aggregate rate for a
//!   class on a resource: the refill first grants each guaranteed flow
//!   its weight-share of the floors on its route (clamped to route
//!   residuals, granted in flow-id order), then runs the weighted fill
//!   over the remaining capacity.  Installed floors on one resource may
//!   never exceed its capacity (asserted — the admission backstop for
//!   [`crate::qos::Policy`]).  Floors may change between events
//!   (grant install/release); rates pick the change up at the next
//!   refill of the component.
//!
//! With every flow in one class, all weights 1 and no floors/ceilings
//! configured, the weighted fill is **bit-identical** to the unweighted
//! engine (the regression gate `rust/tests/prop_invariants.rs` pins this
//! against [`reference::RefSim`]).
//!
//! **Cancellation**: [`Sim::cancel_op`] / [`Sim::cancel_flow`]
//! settle-then-retire in-flight flows — progress is banked, the flow is
//! retired from its resources and the component refilled at the current
//! clock, so contenders' rates recover at cancellation time instead of at
//! the phantom finish time of traffic nobody observes anymore.  A cancel
//! whose retired flows leave no contender behind skips the refill walk
//! entirely (the owning component is empty — there is nothing to
//! refill), which [`Sim::last_refill_component_flows`] surfaces.
//!
//! # Component-parallel execution (DESIGN.md section 14)
//!
//! The per-component engine state lives in an ownable `ComponentState`
//! (`partition` module); [`Sim`] holds one monolithic core plus a
//! union-find **partition map** over resources.  [`Sim::set_threads`]
//! with N > 1 makes the closed-horizon regions — [`Sim::run_until_idle`]
//! and [`Sim::advance`] — split the core by connected component, advance
//! the components on `std::thread` scoped workers and deterministically
//! merge the results (ties by `(time, flow id)`, exactly the serial
//! order).  `--threads 1` (the default) never splits and is
//! bit-identical to the pre-partition engine; `rust/tests/
//! prop_parallel.rs` pins cross-thread-count equality across the
//! topology zoo.

mod partition;
pub mod reference;
pub mod rng;

use std::cmp::Reverse;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

use partition::{ComponentState, Partition};

pub use crate::qos::TrafficClass;

/// Virtual time in seconds.
pub type SimTime = f64;

/// Index of a shared resource (link, NIC port, device channel, CPU...).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResId(pub usize);

/// Index of a flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FlowId(pub usize);

/// Process-wide count of simulation events, summed over every [`Sim`]
/// instance (exhibits build many simulators internally; the `repro bench
/// --csv` stats line reports the delta across one exhibit).
static EVENTS_TOTAL: AtomicU64 = AtomicU64::new(0);

/// Total events processed by every simulator in this process so far.
pub fn events_total() -> u64 {
    EVENTS_TOTAL.load(Ordering::Relaxed)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FlowState {
    /// Not yet started (latency offset still running).
    Pending,
    Active,
    Done,
}

#[derive(Debug, Clone)]
struct Flow {
    route: Vec<ResId>,
    /// Bytes left **as of `touched_at`** (lazy progression: the live value
    /// at time `t` is `remaining - rate * (t - touched_at)`; it is settled
    /// only when the rate changes or the flow is queried/finished).
    remaining: f64,
    /// Virtual time `remaining` was last settled at.
    touched_at: SimTime,
    state: FlowState,
    /// Kept for diagnostics ([`Sim::op_trace`]); scheduling reads the
    /// PendingKey heap instead.
    start_at: SimTime,
    finished_at: SimTime,
    /// Current allocated rate (updated by the component-scoped refill).
    rate: f64,
    /// Predicted finish at the current rate (INFINITY while rate is 0);
    /// the finish-heap entry carrying exactly these bits is the valid one.
    finish_at: SimTime,
    /// QoS class the flow was issued under (selects weights and bounds).
    class: TrafficClass,
    /// Weight in the weighted max-min fill (> 0; default 1.0).
    weight: f64,
    /// True when the flow was retired by [`Sim::cancel_op`] rather than
    /// by completing.
    cancelled: bool,
}

impl Flow {
    /// Live remaining bytes at time `now` (does not settle).
    fn remaining_at(&self, now: SimTime) -> f64 {
        if self.state == FlowState::Active && self.rate > 0.0 {
            (self.remaining - self.rate * (now - self.touched_at)).max(0.0)
        } else {
            self.remaining
        }
    }
}

/// Handle to one in-flight logical **operation**: a set of flows that
/// jointly complete.  Every I/O layer (storage, BeeGFS/BeeOND, SIONlib,
/// NAM, psmpi) returns `Op`s; blocking calls are thin shims that
/// immediately [`Sim::wait_op`] the handle.  This is what lets lower-tier
/// checkpoint flushes run *in the background* of compute phases (the
/// checkpoint/compute-overlap pattern of Hukerikar & Engelmann 2017).
#[derive(Debug, Clone, Default)]
pub struct Op {
    flows: Vec<FlowId>,
}

impl Op {
    /// An operation over an explicit flow set.
    pub fn new(flows: Vec<FlowId>) -> Self {
        Self { flows }
    }

    /// An operation wrapping a single flow.
    pub fn single(flow: FlowId) -> Self {
        Self { flows: vec![flow] }
    }

    /// An already-complete operation (no flows).
    pub fn done() -> Self {
        Self::default()
    }

    /// Merge several operations into one that completes when all do.
    pub fn merge(ops: impl IntoIterator<Item = Op>) -> Self {
        let mut flows = Vec::new();
        for op in ops {
            flows.extend(op.flows);
        }
        Self { flows }
    }

    /// Absorb another operation into this one.
    pub fn join(&mut self, other: Op) {
        self.flows.extend(other.flows);
    }

    /// Add a bare flow to the operation.
    pub fn push(&mut self, flow: FlowId) {
        self.flows.push(flow);
    }

    /// The underlying flows (diagnostics / fine-grained waits).
    pub fn flows(&self) -> &[FlowId] {
        &self.flows
    }

    /// True when the operation carries no flows (trivially complete).
    pub fn is_empty(&self) -> bool {
        self.flows.is_empty()
    }
}

/// A set of independently issued [`Op`]s polled or awaited together —
/// e.g. the outstanding background flushes of a BeeOND cache domain or
/// the L3 flush queue of the multi-level checkpointer.
#[derive(Debug, Default)]
pub struct OpSet {
    ops: Vec<Op>,
}

impl OpSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, op: Op) {
        if !op.is_empty() {
            self.ops.push(op);
        }
    }

    /// Number of operations still tracked.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Total flows across all tracked operations.
    pub fn flow_count(&self) -> usize {
        self.ops.iter().map(|o| o.flows.len()).sum()
    }

    /// True when every tracked operation has completed (no time advance).
    pub fn poll(&self, sim: &Sim) -> bool {
        self.ops.iter().all(|o| sim.poll_op(o))
    }

    /// Drop every already-complete operation, returning how many settled.
    pub fn reap(&mut self, sim: &Sim) -> usize {
        let before = self.ops.len();
        self.ops.retain(|o| !sim.poll_op(o));
        before - self.ops.len()
    }

    /// Block until every tracked operation completes; empties the set and
    /// returns the completion time of the last one (now when empty).
    pub fn wait_all(&mut self, sim: &mut Sim) -> SimTime {
        let ops = std::mem::take(&mut self.ops);
        let all = Op::merge(ops);
        sim.wait_op(&all)
    }

    /// Discard all tracked operations without waiting (their flows keep
    /// progressing in the simulator, but nobody observes them anymore).
    pub fn abandon(&mut self) {
        self.ops.clear();
    }
}

/// One row of [`Sim::op_trace`]: the diagnostic view of a flow.
#[derive(Debug, Clone)]
pub struct OpTraceEntry {
    pub id: FlowId,
    /// Resources the flow traverses (names via [`Sim::resource_name`]);
    /// includes any ceiling shadow resources appended at issue time.
    pub route: Vec<ResId>,
    /// When the flow's latency offset elapsed / will elapse.
    pub start_at: SimTime,
    /// Currently allocated rate (0 for pending or finished flows).
    pub rate: f64,
    pub done: bool,
    pub finished_at: Option<SimTime>,
    /// Traffic class the flow was issued under.
    pub class: TrafficClass,
    /// Weight in the weighted fill.
    pub weight: f64,
    /// Retired by cancellation, not completion ([`Sim::cancel_op`]).
    pub cancelled: bool,
}

/// Per-class weight table; defaults to 1.0 everywhere (plain max-min).
#[derive(Debug, Clone)]
struct ClassWeights([f64; TrafficClass::COUNT]);

impl Default for ClassWeights {
    fn default() -> Self {
        Self([1.0; TrafficClass::COUNT])
    }
}

/// Min-heap key for pending flows: (start_at bits, id).  start_at is
/// always >= 0, and non-negative IEEE-754 doubles order identically to
/// their bit patterns, so the u64 comparison is exact and total.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct PendingKey(u64, usize);

impl PendingKey {
    fn new(start_at: SimTime, id: FlowId) -> Self {
        debug_assert!(start_at >= 0.0);
        Self(start_at.to_bits(), id.0)
    }

    fn time(&self) -> SimTime {
        f64::from_bits(self.0)
    }

    fn id(&self) -> FlowId {
        FlowId(self.1)
    }
}

/// Min-heap key for predicted finishes: (finish_at bits, id), same
/// bit-ordering trick as [`PendingKey`].  Entries are **lazy-deletion**:
/// a rate change makes a flow's older entries stale (their bits no longer
/// match the flow's `finish_at`), and stale entries are discarded when
/// they surface at the top of the heap.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct FinishKey(u64, usize);

impl FinishKey {
    fn new(finish_at: SimTime, id: FlowId) -> Self {
        debug_assert!(finish_at >= 0.0);
        Self(finish_at.to_bits(), id.0)
    }

    fn time(&self) -> SimTime {
        f64::from_bits(self.0)
    }
}

/// The discrete-event engine.
///
/// ```
/// use deeper::sim::Sim;
/// let mut sim = Sim::new();
/// let link = sim.resource("link", 12.5e9);       // 100 Gbit/s
/// let a = sim.flow(1e9, 1.0e-6, &[link]);        // 1 GB after 1 us latency
/// let b = sim.flow(1e9, 1.0e-6, &[link]);        // contends with `a`
/// let t = sim.wait_all(&[a, b]);
/// assert!((t - 0.16).abs() / 0.16 < 1e-3);       // 2 GB over 12.5 GB/s
/// ```
#[derive(Debug)]
pub struct Sim {
    /// The monolithic engine core (all per-component state: flows,
    /// incidence lists, heaps, refill scratch, floors, clock).  Serial
    /// execution runs directly on it; parallel regions split it by
    /// connected component and merge back (DESIGN.md section 14).
    core: ComponentState,
    /// Resource names, indexed by [`ResId`] (diagnostics only; workers
    /// never need them, so they stay out of the ownable core).
    res_names: Vec<String>,
    /// Union-find over resources, unioned along every issued route: the
    /// conservative component decomposition parallel regions split by.
    partition: Partition,
    /// Worker count for closed-horizon regions (1 = always serial).
    threads: usize,
    /// Events processed on each worker during parallel regions (slot 0
    /// additionally absorbs serial events in [`Sim::worker_events`]).
    worker_events: Vec<u64>,
    /// Portion of `core.events` already flushed to [`EVENTS_TOTAL`]
    /// (the flush is batched at region/wait boundaries so worker threads
    /// never touch the shared counter — see [`Sim::flush_events`]).
    events_flushed: u64,
    /// Ambient class newly issued flows are tagged with (Bulk = unset).
    issue_class: TrafficClass,
    /// Per-class default weights for the weighted fill.
    class_weight: ClassWeights,
    /// Shaping ceilings: (resource, class index) -> shadow resource.
    ceilings: HashMap<(usize, usize), ResId>,
    /// Observability recorder (None = tracing disabled; every recording
    /// site is gated on it, so untraced runs pay one branch).  Workers
    /// never see this: engine counters accumulate in the core and are
    /// delta-flushed serially (see [`Sim::flush_events`]).
    obs: Option<crate::obs::Trace>,
    /// Ambient trace process id spans are attributed to (0 = system;
    /// the fleet scheduler sets `job + 1` around job execution, exactly
    /// like the ambient `issue_class`).
    obs_pid: u32,
    /// Engine-counter values already flushed to the recorder.
    obs_snap: ObsSnap,
}

/// Snapshot of the core's monotone engine counters at the last trace
/// flush; [`Sim::flush_events`] pushes only the delta since, so the
/// recorder sees each event exactly once regardless of how regions and
/// waits interleave.
#[derive(Debug, Clone, Copy, Default)]
struct ObsSnap {
    events: u64,
    activations: u64,
    finishes: u64,
    refills: u64,
    refill_size_log2: [u64; 32],
}

impl Default for Sim {
    fn default() -> Self {
        Self {
            core: ComponentState::default(),
            res_names: Vec::new(),
            partition: Partition::default(),
            threads: 1,
            worker_events: vec![0],
            events_flushed: 0,
            issue_class: TrafficClass::default(),
            class_weight: ClassWeights::default(),
            ceilings: HashMap::new(),
            obs: None,
            obs_pid: 0,
            obs_snap: ObsSnap::default(),
        }
    }
}

impl Sim {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the worker count for closed-horizon regions
    /// ([`Sim::run_until_idle`], [`Sim::advance`]); 1 (the default)
    /// keeps execution serial and bit-identical to the pre-partition
    /// engine.  Resets the per-worker event counters.
    pub fn set_threads(&mut self, threads: usize) {
        assert!(threads >= 1, "thread count must be at least 1");
        self.threads = threads;
        self.worker_events = vec![0; threads];
    }

    /// Configured worker count for closed-horizon regions.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Per-worker event counts: slot `w` holds the events worker `w`
    /// processed during parallel regions, with the serial remainder
    /// (interactive waits, single-component regions) folded into slot 0.
    /// The slots always sum to [`Sim::events`].
    pub fn worker_events(&self) -> Vec<u64> {
        let mut v = self.worker_events.clone();
        let parallel: u64 = v.iter().sum();
        if let Some(first) = v.first_mut() {
            *first += self.core.events - parallel;
        }
        v
    }

    /// Flow count of the most recent refill's component closure — 0 when
    /// the last cancellation found no contender on the retired flows'
    /// routes and skipped the walk entirely (diagnostics; pins the
    /// cheap-cancellation path).
    pub fn last_refill_component_flows(&self) -> usize {
        self.core.last_refill_flows
    }

    /// Flush this core's not-yet-flushed events to the process-wide
    /// [`events_total`] counter (batched: one atomic add per region or
    /// wait instead of one per event, and never from a worker thread).
    fn flush_events(&mut self) {
        let delta = self.core.events - self.events_flushed;
        if delta > 0 {
            EVENTS_TOTAL.fetch_add(delta, Ordering::Relaxed);
            self.events_flushed = self.core.events;
        }
        // Trace flush rides the same serial boundary: push the engine
        // counters' delta since the last flush into the recorder.  The
        // counters accumulate inside the (possibly worker-owned) core,
        // so workers never lock the recorder and the flushed totals are
        // identical for every thread count.
        if let Some(tr) = &self.obs {
            let c = &self.core;
            let s = &mut self.obs_snap;
            if c.events != s.events
                || c.activations != s.activations
                || c.finishes != s.finishes
                || c.refills != s.refills
            {
                tr.with(|r| {
                    if c.events > s.events {
                        r.add("sim_events_total", (c.events - s.events) as f64);
                    }
                    if c.activations > s.activations {
                        r.add("sim_activations_total", (c.activations - s.activations) as f64);
                    }
                    if c.finishes > s.finishes {
                        r.add("sim_finishes_total", (c.finishes - s.finishes) as f64);
                    }
                    if c.refills > s.refills {
                        r.add("sim_refills_total", (c.refills - s.refills) as f64);
                    }
                    // Refill component-size histogram: the core buckets by
                    // floor(log2) (index k = sizes in [2^(k-1), 2^k)), which
                    // maps onto the LogHist bucket holding that power of two.
                    let h = r.hist_mut("sim_refill_component_flows");
                    for i in 0..32 {
                        let d = c.refill_size_log2[i] - s.refill_size_log2[i];
                        if d > 0 {
                            let b = if i == 0 { 0 } else { (31 + i).min(63) };
                            h.buckets[b] += d;
                            h.count += d;
                        }
                    }
                });
                *s = ObsSnap {
                    events: c.events,
                    activations: c.activations,
                    finishes: c.finishes,
                    refills: c.refills,
                    refill_size_log2: c.refill_size_log2,
                };
            }
        }
    }

    /// Install an observability recorder: from here on, the engine and
    /// every instrumented layer above record spans/counters into it on
    /// the **virtual** clock (DESIGN.md section 17).  Recording is pure
    /// observation — it never perturbs simulation state — and costs one
    /// branch per site when no trace is installed.
    pub fn set_trace(&mut self, tr: crate::obs::Trace) {
        self.obs = Some(tr);
    }

    /// The installed trace handle, if tracing is enabled.  `&self`
    /// access (the handle records through interior mutability), so
    /// immutable-machine contexts can record too.
    pub fn trace(&self) -> Option<&crate::obs::Trace> {
        self.obs.as_ref()
    }

    /// Set the ambient trace process id (0 = system, `job + 1` = fleet
    /// job) and return the previous one — the same scoped-override
    /// pattern as [`Sim::set_issue_class`].  I/O layers read it via
    /// [`Sim::trace_pid`] so their spans land on the owning job's track
    /// without the layers knowing about jobs.
    pub fn set_trace_pid(&mut self, pid: u32) -> u32 {
        std::mem::replace(&mut self.obs_pid, pid)
    }

    /// Ambient trace process id spans are currently attributed to.
    pub fn trace_pid(&self) -> u32 {
        self.obs_pid
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> SimTime {
        self.core.now
    }

    /// Register a shared resource with `capacity` bytes/s (flops/s).
    pub fn resource(&mut self, name: impl Into<String>, capacity: f64) -> ResId {
        assert!(capacity > 0.0, "resource capacity must be positive");
        self.res_names.push(name.into());
        self.core.caps.push(capacity);
        self.core.res_flows.push(Vec::new());
        self.partition.push();
        ResId(self.res_names.len() - 1)
    }

    /// Resource capacity in bytes/s.
    pub fn capacity(&self, r: ResId) -> f64 {
        self.core.caps[r.0]
    }

    /// Change a resource's capacity mid-run — the enabling primitive for
    /// degraded-mode fault injection (link degradation, straggler
    /// compute; DESIGN.md section 15).  Active flows on the resource are
    /// settled at the current clock and the **owning component** is
    /// refilled immediately, reusing the cancellation path's machinery:
    /// the changed resource's active flows seed the closure walk, so
    /// disjoint components keep their rates, predictions and heap entries
    /// untouched.  Setting the capacity to its current value is a strict
    /// no-op (nothing settles, no refill, no heap churn — bit-identical
    /// to never having called this), and a capacity change on a resource
    /// with no active flows only swaps the stored value (pending flows
    /// pick it up at activation, exactly as if the resource had been
    /// registered with the new capacity).
    ///
    /// QoS note: class floors are validated against capacity at install
    /// time ([`Sim::set_class_floor`]), not re-checked here — a degraded
    /// link may drop below its installed floors.  The refill stays safe
    /// (pass-1 grants clamp to route residuals), guarantees simply become
    /// best-effort on the degraded hop for the window's duration.
    pub fn set_resource_capacity(&mut self, r: ResId, capacity: f64) {
        assert!(
            capacity > 0.0 && capacity.is_finite(),
            "resource capacity must be positive"
        );
        let core = &mut self.core;
        if core.caps[r.0] == capacity {
            return;
        }
        core.caps[r.0] = capacity;
        if core.res_flows[r.0].is_empty() {
            // No active flow routes through `r`: there is no rate to
            // re-derive anywhere (pending flows get rates at activation).
            core.last_refill_flows = 0;
            return;
        }
        core.dirty.clear();
        core.dirty.extend(core.res_flows[r.0].iter().copied());
        core.recompute_component();
    }

    /// Start a flow of `bytes` through `route`, beginning after `delay`
    /// seconds of latency (pure offset, consumes no bandwidth).  The flow
    /// is tagged with the ambient [`Sim::issue_class`].
    pub fn flow(&mut self, bytes: f64, delay: SimTime, route: &[ResId]) -> FlowId {
        self.flow_classed(bytes, delay, route, self.issue_class)
    }

    /// [`Sim::flow`] with an explicit traffic class (weight comes from
    /// the per-class table).
    pub fn flow_classed(
        &mut self,
        bytes: f64,
        delay: SimTime,
        route: &[ResId],
        class: TrafficClass,
    ) -> FlowId {
        let weight = self.class_weight.0[class.index()];
        self.flow_weighted(bytes, delay, route, class, weight)
    }

    /// [`Sim::flow`] with an explicit class **and** per-flow weight
    /// override.  Any ceiling configured for `(r, class)` on a route
    /// resource appends its shadow resource to the route here — shaping
    /// only applies to flows issued after the ceiling was configured.
    pub fn flow_weighted(
        &mut self,
        bytes: f64,
        delay: SimTime,
        route: &[ResId],
        class: TrafficClass,
        weight: f64,
    ) -> FlowId {
        assert!(bytes >= 0.0 && delay >= 0.0);
        assert!(!route.is_empty(), "flow route must name at least one resource");
        assert!(weight > 0.0 && weight.is_finite(), "flow weight must be positive");
        let id = FlowId(self.core.flows.len());
        let start_at = self.core.now + delay;
        let mut full_route = route.to_vec();
        if !self.ceilings.is_empty() {
            for &r in route {
                if let Some(&shadow) = self.ceilings.get(&(r.0, class.index())) {
                    full_route.push(shadow);
                }
            }
        }
        // The issued route (ceiling shadows included) welds its
        // resources into one partition group: a route bridging two
        // groups is the deterministic merge barrier of DESIGN.md §14.
        self.partition.union_route(&full_route);
        self.core.flows.push(Flow {
            route: full_route,
            remaining: bytes,
            touched_at: start_at,
            state: FlowState::Pending,
            start_at,
            finished_at: f64::INFINITY,
            rate: 0.0,
            finish_at: f64::INFINITY,
            class,
            weight,
            cancelled: false,
        });
        self.core.pending.push(Reverse(PendingKey::new(start_at, id)));
        id
    }

    /// A pure-delay flow (no bandwidth consumed): models fixed software
    /// overheads (metadata round-trips, syscalls, kernel-launch latency).
    pub fn delay(&mut self, seconds: SimTime) -> FlowId {
        // Zero bytes on a dummy route: completes exactly at start_at.
        let id = FlowId(self.core.flows.len());
        let start_at = self.core.now + seconds;
        self.core.flows.push(Flow {
            route: Vec::new(),
            remaining: 0.0,
            touched_at: start_at,
            state: FlowState::Pending,
            start_at,
            finished_at: f64::INFINITY,
            rate: 0.0,
            finish_at: f64::INFINITY,
            class: self.issue_class,
            weight: 1.0,
            cancelled: false,
        });
        self.core.pending.push(Reverse(PendingKey::new(start_at, id)));
        id
    }

    // ------------------------------------------------------------------
    // traffic-class QoS configuration (DESIGN.md section 12)
    // ------------------------------------------------------------------

    /// Set the ambient class newly issued flows are tagged with; returns
    /// the previous class so callers can restore it afterwards.
    pub fn set_issue_class(&mut self, class: TrafficClass) -> TrafficClass {
        std::mem::replace(&mut self.issue_class, class)
    }

    /// Ambient class new flows are currently tagged with.
    pub fn issue_class(&self) -> TrafficClass {
        self.issue_class
    }

    /// Tag the ambient class for the duration of one layer call **unless
    /// a caller higher up already set a more specific class** (Bulk is
    /// the unset default).  Returns the previous class; restore it with
    /// [`Sim::set_issue_class`].  This is how e.g. the XOR strategies'
    /// ring exchanges stay `Parity` instead of being re-tagged `Exchange`
    /// by the psmpi layer underneath.
    pub fn default_issue_class(&mut self, class: TrafficClass) -> TrafficClass {
        let prev = self.issue_class;
        if prev == TrafficClass::Bulk {
            self.issue_class = class;
        }
        prev
    }

    /// Set the default weight flows of `class` are issued with (> 0).
    /// Affects only flows issued afterwards.
    pub fn set_class_weight(&mut self, class: TrafficClass, weight: f64) {
        assert!(weight > 0.0 && weight.is_finite(), "class weight must be positive");
        self.class_weight.0[class.index()] = weight;
    }

    /// Current default weight of `class`.
    pub fn class_weight_of(&self, class: TrafficClass) -> f64 {
        self.class_weight.0[class.index()]
    }

    /// Cap the aggregate rate of `class` traffic on `r` at `ceiling`
    /// bytes/s, materialized as a shadow resource appended to the routes
    /// of matching flows issued **after** this call.  Re-configuring an
    /// existing ceiling adjusts the shadow's capacity (taking effect at
    /// the component's next refill).  Returns the shadow resource id.
    pub fn set_class_ceiling(&mut self, r: ResId, class: TrafficClass, ceiling: f64) -> ResId {
        assert!(ceiling > 0.0 && ceiling.is_finite(), "ceiling must be positive");
        if let Some(&shadow) = self.ceilings.get(&(r.0, class.index())) {
            self.core.caps[shadow.0] = ceiling;
            return shadow;
        }
        let name = format!("{}|{}:cap", self.res_names[r.0], class.name());
        let shadow = self.resource(name, ceiling);
        self.ceilings.insert((r.0, class.index()), shadow);
        shadow
    }

    /// Configured ceiling for `class` on `r`, if any.
    pub fn class_ceiling(&self, r: ResId, class: TrafficClass) -> Option<f64> {
        self.ceilings
            .get(&(r.0, class.index()))
            .map(|s| self.core.caps[s.0])
    }

    /// Install (or, with 0, remove) an aggregate rate **floor** for
    /// `class` on `r`: the refill guarantees class members their
    /// weight-share of the floor before sharing the excess.  The sum of
    /// floors on one resource may never exceed its capacity — asserted
    /// here, the engine-level backstop behind [`crate::qos::Policy`]'s
    /// admission budgets.  Floors may change between events; rates pick
    /// the change up at the component's next refill.
    pub fn set_class_floor(&mut self, r: ResId, class: TrafficClass, floor: f64) {
        assert!(floor >= 0.0 && floor.is_finite(), "floor must be non-negative");
        if floor <= 0.0 {
            self.core.floors.remove(&(r.0, class.index()));
        } else {
            self.core.floors.insert((r.0, class.index()), floor);
        }
        let total: f64 = TrafficClass::ALL
            .iter()
            .map(|&c| self.class_floor(r, c))
            .sum();
        assert!(
            total <= self.core.caps[r.0] * (1.0 + 1e-9),
            "floors on {} oversubscribed: {:.3e} B/s > capacity {:.3e} B/s",
            self.res_names[r.0],
            total,
            self.core.caps[r.0]
        );
        if self.core.res_has_floor.len() <= r.0 {
            self.core.res_has_floor.resize(r.0 + 1, false);
        }
        self.core.res_has_floor[r.0] = total > 0.0;
    }

    /// Adjust the floor for `class` on `r` by `delta` (grant install /
    /// release), clamping at zero.
    pub fn add_class_floor(&mut self, r: ResId, class: TrafficClass, delta: f64) {
        let cur = self.class_floor(r, class);
        self.set_class_floor(r, class, (cur + delta).max(0.0));
    }

    /// Configured floor for `class` on `r` (0 when none).
    pub fn class_floor(&self, r: ResId, class: TrafficClass) -> f64 {
        self.core
            .floors
            .get(&(r.0, class.index()))
            .copied()
            .unwrap_or(0.0)
    }

    /// Traffic class `f` was issued under.
    pub fn flow_class(&self, f: FlowId) -> TrafficClass {
        self.core.flows[f.0].class
    }

    /// Was `f` retired by [`Sim::cancel_op`] rather than by completing?
    pub fn was_cancelled(&self, f: FlowId) -> bool {
        self.core.flows[f.0].cancelled
    }

    /// Cancel every not-yet-finished flow of `op`: settle each flow's
    /// progress at the current clock, retire it from its resources and
    /// refill the affected component(s) **now**, so contenders' rates
    /// recover at cancellation time — not at the phantom finish time of
    /// traffic nobody observes anymore (DESIGN.md section 12.4).
    ///
    /// Cancelled flows report [`Sim::poll`] true and [`Sim::completed`]
    /// = the cancellation time (waiters cannot deadlock);
    /// [`Sim::was_cancelled`] distinguishes them.  Pending flows are
    /// cancelled before ever activating (their heap entries go stale and
    /// are skipped).  Returns how many flows were actually cancelled.
    pub fn cancel_op(&mut self, op: &Op) -> usize {
        let core = &mut self.core;
        let now = core.now;
        core.dirty.clear();
        let mut cancelled = 0usize;
        for &f in op.flows() {
            let was_active = {
                let fl = &mut core.flows[f.0];
                match fl.state {
                    FlowState::Done => continue,
                    FlowState::Pending => {
                        // Never consumed bandwidth; the pending-heap entry
                        // becomes stale and step() skips it.
                        fl.state = FlowState::Done;
                        false
                    }
                    FlowState::Active => {
                        if fl.rate > 0.0 {
                            fl.remaining =
                                (fl.remaining - fl.rate * (now - fl.touched_at)).max(0.0);
                        }
                        fl.state = FlowState::Done;
                        true
                    }
                }
            };
            {
                let fl = &mut core.flows[f.0];
                fl.cancelled = true;
                fl.finished_at = now;
                fl.touched_at = now;
                fl.rate = 0.0;
                fl.finish_at = f64::INFINITY;
            }
            cancelled += 1;
            if was_active {
                for &r in &core.flows[f.0].route {
                    let v = &mut core.res_flows[r.0];
                    if let Some(p) = v.iter().position(|&x| x == f) {
                        v.swap_remove(p);
                    }
                }
                core.dirty.push(f);
            }
        }
        if !core.dirty.is_empty() {
            // Cheap cancellation: with the retired flows out of the
            // incidence lists, a contender is any still-active flow on a
            // retired flow's route.  No contender means the owning
            // component is now empty — a refill would walk nothing and
            // assign nothing — so skip the closure walk entirely instead
            // of seeding one from scratch (observationally identical:
            // an empty-component refill touches no rate, prediction or
            // heap entry).
            let contended = core.dirty.iter().any(|f| {
                core.flows[f.0]
                    .route
                    .iter()
                    .any(|r| !core.res_flows[r.0].is_empty())
            });
            if contended {
                core.recompute_component();
            } else {
                core.last_refill_flows = 0;
            }
        }
        cancelled
    }

    /// Cancel a single flow; returns false when it had already finished.
    pub fn cancel_flow(&mut self, f: FlowId) -> bool {
        self.cancel_op(&Op::single(f)) == 1
    }

    /// Completion time of a finished flow.
    pub fn completed(&self, f: FlowId) -> Option<SimTime> {
        let fl = &self.core.flows[f.0];
        (fl.state == FlowState::Done).then_some(fl.finished_at)
    }

    /// Non-advancing completion query: has `f` finished?
    pub fn poll(&self, f: FlowId) -> bool {
        self.core.flows[f.0].state == FlowState::Done
    }

    /// Non-advancing completion query over an [`Op`] (empty ops are done).
    pub fn poll_op(&self, op: &Op) -> bool {
        op.flows.iter().all(|&f| self.poll(f))
    }

    /// Completion time of an [`Op`]: the latest flow completion, or None
    /// while any flow is still in flight.  Empty ops complete at 0.
    pub fn op_completion(&self, op: &Op) -> Option<SimTime> {
        let mut t = 0.0f64;
        for &f in &op.flows {
            t = t.max(self.completed(f)?);
        }
        Some(t)
    }

    /// Block until `op` completes; returns its completion time (now for
    /// empty ops).  The blocking shim every async layer builds on.
    pub fn wait_op(&mut self, op: &Op) -> SimTime {
        if op.flows.is_empty() {
            return self.core.now;
        }
        self.wait_all(&op.flows)
    }

    /// Advance until all `flows` complete; returns the time of the last one.
    /// Other in-flight flows keep progressing (this is how BeeOND's
    /// asynchronous flush overlaps the next compute phase).
    pub fn wait_all(&mut self, flows: &[FlowId]) -> SimTime {
        // Amortized-O(1) completion check: a cursor over the wait set.
        // Each event re-examines exactly one flow (`flows[cursor]`), never
        // the whole set; completions of the others are picked up as the
        // cursor passes them (step() additionally surfaces the per-event
        // finish delta via finished_last_step for wait_any-style waiters).
        let mut cursor = 0;
        while cursor < flows.len() {
            if self.core.flows[flows[cursor].0].state == FlowState::Done {
                cursor += 1;
                continue;
            }
            if !self.core.step() {
                panic!("simulation deadlock: waited-on flow cannot complete");
            }
        }
        self.flush_events();
        flows
            .iter()
            .map(|&f| self.core.flows[f.0].finished_at)
            .fold(0.0, f64::max)
    }

    /// Per-flow completion times, advancing as needed.
    pub fn wait_each(&mut self, flows: &[FlowId]) -> Vec<SimTime> {
        self.wait_all(flows);
        flows
            .iter()
            .map(|&f| self.core.flows[f.0].finished_at)
            .collect()
    }

    /// Advance until the **first** of `flows` completes; returns its index
    /// in the slice and its completion time.  Determinism: when several
    /// flows are already (or become) complete, the winner is the one with
    /// the earliest completion time, ties broken by the smaller flow id —
    /// never by slice position, so permuting the wait set cannot change
    /// the outcome.
    ///
    /// Cost: one full scan of the wait set on entry (flows may have
    /// completed before the call); afterwards only the per-event finish
    /// delta surfaced by `step()` is examined, so a large wait set adds
    /// nothing to the per-event cost.
    pub fn wait_any(&mut self, flows: &[FlowId]) -> (usize, SimTime) {
        assert!(!flows.is_empty(), "wait_any on an empty flow set");
        // Duplicate entries keep their first slice position (that is the
        // index the old full-rescan loop would have reported).
        let mut index_of: HashMap<FlowId, usize> = HashMap::with_capacity(flows.len());
        for (i, &f) in flows.iter().enumerate() {
            index_of.entry(f).or_insert(i);
        }
        let mut best: Option<(SimTime, FlowId)> = None;
        let consider = |best: &mut Option<(SimTime, FlowId)>, t: SimTime, f: FlowId| {
            let better = match *best {
                None => true,
                Some((bt, bf)) => t < bt || (t == bt && f < bf),
            };
            if better {
                *best = Some((t, f));
            }
        };
        for &f in flows {
            if let Some(t) = self.completed(f) {
                consider(&mut best, t, f);
            }
        }
        while best.is_none() {
            if !self.core.step() {
                panic!("simulation deadlock: no waited-on flow can complete");
            }
            for &f in &self.core.finished_step {
                if index_of.contains_key(&f) {
                    let t = self.core.flows[f.0].finished_at;
                    consider(&mut best, t, f);
                }
            }
        }
        self.flush_events();
        let (t, f) = best.unwrap();
        (index_of[&f], t)
    }

    /// Run until no pending/active flows remain.  A closed-horizon
    /// region: with [`Sim::set_threads`] > 1 and at least two live
    /// components it runs component-parallel (DESIGN.md section 14).
    pub fn run_until_idle(&mut self) {
        self.run_region(None);
    }

    /// Jump the clock forward by `seconds` (processing any events
    /// inside).  A closed-horizon region: with [`Sim::set_threads`] > 1
    /// and at least two live components it runs component-parallel
    /// (DESIGN.md section 14).
    ///
    /// Parking the clock between events is safe: per-flow progress is a
    /// function of (remaining, touched_at, rate), not of the event the
    /// bytes were last settled at, so nothing is lost by the jump.
    pub fn advance(&mut self, seconds: SimTime) {
        let target = self.core.now + seconds;
        self.run_region(Some(target));
    }

    /// Jump the clock to the **absolute** virtual time `target`
    /// (processing any events inside); a no-op when `target` is in the
    /// past.  The absolute-time counterpart of [`Sim::advance`] for
    /// callers that schedule against timestamps (e.g. lining a scenario
    /// up with a recorded completion time).
    pub fn advance_until(&mut self, target: SimTime) {
        let dt = target - self.core.now;
        if dt > 0.0 {
            self.advance(dt);
        }
    }

    /// Number of flows ever created (diagnostics).
    pub fn flow_count(&self) -> usize {
        self.core.flows.len()
    }

    /// Events processed by this simulator so far (diagnostics; see
    /// [`events_total`] for the process-wide aggregate and
    /// [`Sim::worker_events`] for the per-worker breakdown).
    pub fn events(&self) -> u64 {
        self.core.events
    }

    /// Largest flow set one rate refill touched (the union of connected
    /// components reachable from an event's changed flows); the scale
    /// bench reports this as "peak component".
    pub fn peak_component_flows(&self) -> usize {
        self.core.peak_component
    }

    /// Flows that completed during the most recent event (the delta
    /// surfaced for [`Sim::wait_any`]-style waiters).  All entries share
    /// the same `finished_at` (the event time).
    pub fn finished_last_step(&self) -> &[FlowId] {
        &self.core.finished_step
    }

    /// Name a resource was registered under (diagnostics).
    pub fn resource_name(&self, r: ResId) -> &str {
        &self.res_names[r.0]
    }

    /// Diagnostic snapshot of every flow ever issued: route, start time,
    /// current rate and completion.  This is the observability surface the
    /// overlap bench prints (`repro bench fig8-async`) and the property
    /// suite uses to audit per-resource rate allocations.
    pub fn op_trace(&self) -> Vec<OpTraceEntry> {
        self.core
            .flows
            .iter()
            .enumerate()
            .map(|(i, fl)| OpTraceEntry {
                id: FlowId(i),
                route: fl.route.clone(),
                start_at: fl.start_at,
                rate: if fl.state == FlowState::Active { fl.rate } else { 0.0 },
                done: fl.state == FlowState::Done,
                finished_at: (fl.state == FlowState::Done).then_some(fl.finished_at),
                class: fl.class,
                weight: fl.weight,
                cancelled: fl.cancelled,
            })
            .collect()
    }

    /// Live remaining bytes of a flow at the current clock (settling is
    /// read-only: the stored state is untouched).  Diagnostics / tests.
    pub fn flow_remaining(&self, f: FlowId) -> f64 {
        self.core.flows[f.0].remaining_at(self.core.now)
    }

    /// Process exactly **one** simulation event; returns false when no
    /// pending or active flows remain.  The public single-step entry for
    /// schedulers that interleave many independent waiters on one clock
    /// (the fleet scheduler polls its jobs' front [`Op`]s between events
    /// instead of blocking inside any single job's wait).  Always serial
    /// — per-event polling is a standing merge barrier, so there is no
    /// closed horizon to parallelize over.
    pub fn step_event(&mut self) -> bool {
        let progressed = self.core.step();
        self.flush_events();
        progressed
    }

    /// Timestamp of the earliest upcoming event, without processing it;
    /// None when the engine is idle.  Observationally pure (`&mut` only
    /// because the peek discards lazily-deleted heap entries on the way).
    /// The service-mode loop races this against the next job arrival to
    /// decide whether to step the engine or jump the clock to the
    /// arrival ([`Sim::advance_until`]).
    pub fn next_event_time(&mut self) -> Option<SimTime> {
        self.core.next_event_time()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_flow_takes_bytes_over_capacity() {
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let f = sim.flow(2e9, 0.0, &[link]);
        let t = sim.wait_all(&[f]);
        assert!((t - 2.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn latency_is_pure_offset() {
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let f = sim.flow(1e9, 0.5, &[link]);
        let t = sim.wait_all(&[f]);
        assert!((t - 1.5).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn two_flows_share_fairly() {
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let a = sim.flow(1e9, 0.0, &[link]);
        let b = sim.flow(1e9, 0.0, &[link]);
        let times = sim.wait_each(&[a, b]);
        for t in times {
            assert!((t - 2.0).abs() < 1e-9, "t={t}");
        }
    }

    #[test]
    fn unequal_flows_release_bandwidth() {
        // 1 GB and 3 GB on a 2 GB/s link: first finishes at 1 s (1 GB/s each),
        // the second then gets the full 2 GB/s: 1 + (3-1)/2 = 2 s total.
        let mut sim = Sim::new();
        let link = sim.resource("l", 2e9);
        let a = sim.flow(1e9, 0.0, &[link]);
        let b = sim.flow(3e9, 0.0, &[link]);
        let times = sim.wait_each(&[a, b]);
        assert!((times[0] - 1.0).abs() < 1e-9, "a={}", times[0]);
        assert!((times[1] - 2.0).abs() < 1e-9, "b={}", times[1]);
    }

    #[test]
    fn multi_resource_route_takes_min() {
        let mut sim = Sim::new();
        let fast = sim.resource("fast", 10e9);
        let slow = sim.resource("slow", 1e9);
        let f = sim.flow(1e9, 0.0, &[fast, slow]);
        let t = sim.wait_all(&[f]);
        assert!((t - 1.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn max_min_respects_bottleneck_and_spare() {
        // Flow A crosses L1 (1 GB/s) and L2 (10 GB/s); flow B crosses only L2.
        // A is capped at 1 GB/s by L1; B gets the rest of L2 (9 GB/s).
        let mut sim = Sim::new();
        let l1 = sim.resource("l1", 1e9);
        let l2 = sim.resource("l2", 10e9);
        let a = sim.flow(1e9, 0.0, &[l1, l2]);
        let b = sim.flow(9e9, 0.0, &[l2]);
        let times = sim.wait_each(&[a, b]);
        assert!((times[0] - 1.0).abs() < 1e-6, "a={}", times[0]);
        assert!((times[1] - 1.0).abs() < 1e-6, "b={}", times[1]);
    }

    #[test]
    fn pure_delay_flow() {
        let mut sim = Sim::new();
        let d = sim.delay(0.25);
        let t = sim.wait_all(&[d]);
        assert!((t - 0.25).abs() < 1e-12);
    }

    #[test]
    fn staggered_arrivals() {
        // B arrives at t=1 on a 1 GB/s link while A (2 GB) is mid-transfer.
        // A: 1 GB done by t=1, shares 0.5 each after; A done at t=3.
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let a = sim.flow(2e9, 0.0, &[link]);
        let b = sim.flow(1e9, 1.0, &[link]);
        let times = sim.wait_each(&[a, b]);
        assert!((times[0] - 3.0).abs() < 1e-9, "a={}", times[0]);
        assert!((times[1] - 3.0).abs() < 1e-9, "b={}", times[1]);
    }

    #[test]
    fn background_flow_keeps_progressing() {
        let mut sim = Sim::new();
        let link = sim.resource("l", 1e9);
        let bg = sim.flow(4e9, 0.0, &[link]);
        let fg = sim.flow(1e9, 0.0, &[link]);
        sim.wait_all(&[fg]);
        // fg done at t=2 (shared 0.5 GB/s each); bg then has 3 GB left at
        // the full 1 GB/s: done at t = 2 + 3 = 5.
        let t = sim.wait_all(&[bg]);
        assert!((t - 5.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn determinism_same_inputs_same_times() {
        let run = || {
            let mut sim = Sim::new();
            let l = sim.resource("l", 3.3e9);
            let flows: Vec<_> = (0..32)
                .map(|i| sim.flow(1e8 * (i + 1) as f64, 1e-6 * i as f64, &[l]))
                .collect();
            sim.wait_each(&flows)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn poll_does_not_advance() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let f = sim.flow(1e9, 0.0, &[l]);
        assert!(!sim.poll(f));
        assert_eq!(sim.now(), 0.0);
        sim.advance(2.0);
        assert!(sim.poll(f));
    }

    #[test]
    fn wait_any_returns_first_completion() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let slow = sim.flow(4e9, 0.0, &[l]);
        let fast = sim.delay(0.5);
        let (idx, t) = sim.wait_any(&[slow, fast]);
        assert_eq!(idx, 1);
        assert!((t - 0.5).abs() < 1e-12, "t={t}");
        assert!(!sim.poll(slow));
    }

    #[test]
    fn wait_any_tie_breaks_by_flow_id() {
        let mut sim = Sim::new();
        let a = sim.delay(1.0);
        let b = sim.delay(1.0);
        // Presented in reverse order: the earlier id must still win.
        let (idx, t) = sim.wait_any(&[b, a]);
        assert_eq!(idx, 1, "tie must resolve to the smaller flow id");
        assert!((t - 1.0).abs() < 1e-12);
    }

    #[test]
    fn wait_any_already_done_prefers_earliest_completion() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let early = sim.flow(1e9, 0.0, &[l]); // alone: done at 1.0
        sim.wait_all(&[early]);
        let late = sim.flow(1e9, 0.0, &[l]); // done at 2.0
        sim.wait_all(&[late]);
        // Both complete before the call: earliest completion wins even
        // though it sits later in the slice.
        let (idx, t) = sim.wait_any(&[late, early]);
        assert_eq!(idx, 1);
        assert!((t - 1.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn op_wait_and_completion() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let op = Op::new(vec![sim.flow(1e9, 0.0, &[l]), sim.flow(2e9, 0.0, &[l])]);
        assert!(!sim.poll_op(&op));
        assert!(sim.op_completion(&op).is_none());
        let t = sim.wait_op(&op);
        assert!((t - 3.0).abs() < 1e-9, "t={t}");
        assert_eq!(sim.op_completion(&op), Some(t));
        // Empty op: trivially complete, waits return `now`.
        let empty = Op::done();
        assert!(sim.poll_op(&empty));
        assert_eq!(sim.wait_op(&empty), sim.now());
    }

    #[test]
    fn opset_poll_reap_wait() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let mut set = OpSet::new();
        set.push(Op::single(sim.flow(1e9, 0.0, &[l])));
        set.push(Op::single(sim.flow(3e9, 0.0, &[l])));
        set.push(Op::done()); // dropped on push
        assert_eq!(set.len(), 2);
        assert!(!set.poll(&sim));
        // Shared link: 0.5 GB/s each, first flow done at t=2; the second
        // then runs at full rate, 2 GB left: done at t=4.
        sim.advance(2.5);
        assert_eq!(set.reap(&sim), 1);
        assert_eq!(set.len(), 1);
        let t = set.wait_all(&mut sim);
        assert!(set.is_empty());
        assert!((t - 4.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn advance_until_is_absolute_and_monotone() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let f = sim.flow(1e9, 0.0, &[l]);
        sim.advance_until(3.0);
        assert_eq!(sim.now(), 3.0);
        assert!(sim.poll(f));
        sim.advance_until(1.0); // in the past: no-op
        assert_eq!(sim.now(), 3.0);
    }

    #[test]
    fn advance_between_events_loses_no_progress() {
        // Park the clock twice between events: lazy progression must not
        // drop the bytes moved across the parks (the eager engine's sweep
        // only ran at events, so mid-gap parking lost the gap's bytes).
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let f = sim.flow(2e9, 0.0, &[l]);
        sim.advance(0.5);
        assert!((sim.flow_remaining(f) - 1.5e9).abs() < 1.0);
        sim.advance(0.5);
        assert!((sim.flow_remaining(f) - 1.0e9).abs() < 1.0);
        let t = sim.wait_all(&[f]);
        assert!((t - 2.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn op_trace_reports_routes_rates_and_times() {
        let mut sim = Sim::new();
        let l = sim.resource("link-a", 1e9);
        let a = sim.flow(2e9, 0.0, &[l]);
        let _b = sim.flow(2e9, 1.0, &[l]);
        sim.advance(0.5); // a active alone at full rate
        let tr = sim.op_trace();
        assert_eq!(tr.len(), 2);
        assert_eq!(tr[0].id, a);
        assert_eq!(sim.resource_name(tr[0].route[0]), "link-a");
        assert!((tr[0].rate - 1e9).abs() < 1.0, "rate={}", tr[0].rate);
        assert_eq!(tr[1].start_at, 1.0);
        assert!(!tr[1].done && tr[1].finished_at.is_none());
        sim.run_until_idle();
        let tr = sim.op_trace();
        assert!(tr.iter().all(|e| e.done && e.rate == 0.0));
    }

    #[test]
    fn advance_moves_clock_past_events() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let f = sim.flow(1e9, 0.0, &[l]);
        sim.advance(5.0);
        assert_eq!(sim.now(), 5.0);
        assert!(sim.completed(f).is_some());
        assert!((sim.completed(f).unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn refill_is_component_scoped() {
        // Two disjoint links with staggered activity: each refill touches
        // only the changed link's component, never the union of both.
        let mut sim = Sim::new();
        let la = sim.resource("la", 1e9);
        let lb = sim.resource("lb", 1e9);
        let a1 = sim.flow(4e9, 0.0, &[la]);
        let a2 = sim.flow(4e9, 0.0, &[la]);
        let _b = sim.flow(1e9, 0.5, &[lb]); // activates alone at t=0.5
        sim.run_until_idle();
        assert!(sim.poll(a1) && sim.poll(a2));
        // Peak refill: the two flows sharing `la` (t=0).  b's activation
        // at t=0.5 and every later finish touch strictly fewer flows.
        assert_eq!(sim.peak_component_flows(), 2);
    }

    #[test]
    fn event_counters_tick() {
        let g0 = events_total();
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        sim.flow(1e9, 0.0, &[l]);
        sim.flow(1e9, 0.1, &[l]);
        sim.run_until_idle();
        assert!(sim.events() >= 3, "events={}", sim.events());
        assert!(events_total() >= g0 + sim.events());
    }

    #[test]
    fn finished_last_step_surfaces_delta() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let a = sim.flow(1e9, 0.0, &[l]);
        let b = sim.flow(1e9, 0.0, &[l]); // same size: both finish at t=2
        sim.advance(3.0);
        // Both completed during the same (final) event.
        assert!(sim.poll(a) && sim.poll(b));
        let delta = sim.finished_last_step();
        assert_eq!(delta.len(), 2, "delta={delta:?}");
        assert!(delta.contains(&a) && delta.contains(&b));
    }

    #[test]
    fn lazy_remaining_matches_rate_integral() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let a = sim.flow(3e9, 0.0, &[l]);
        let _b = sim.flow(1e9, 1.0, &[l]);
        sim.advance(0.25); // a alone at 1 GB/s
        assert!((sim.flow_remaining(a) - 2.75e9).abs() < 1.0);
        sim.advance(1.25); // t=1.5: a ran 1 s at 1 GB/s, then 0.5 s at 0.5
        assert!((sim.flow_remaining(a) - 1.75e9).abs() < 1.0);
    }

    // ------------------------------------------------------------------
    // traffic-class QoS (DESIGN.md section 12)
    // ------------------------------------------------------------------

    #[test]
    fn weighted_sharing_splits_by_weight() {
        // Weights 3:1 on one link: rates 0.75 / 0.25 of capacity while
        // both are active.
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let a = sim.flow_weighted(3e9, 0.0, &[l], TrafficClass::Exchange, 3.0);
        let b = sim.flow_weighted(1e9, 0.0, &[l], TrafficClass::Bulk, 1.0);
        sim.advance(1e-9);
        let tr = sim.op_trace();
        assert!((tr[a.0].rate - 0.75e9).abs() < 1.0, "a rate={}", tr[a.0].rate);
        assert!((tr[b.0].rate - 0.25e9).abs() < 1.0, "b rate={}", tr[b.0].rate);
        // Both carry 3e9/1e9 bytes at 3:1 rates: both finish at t=4.
        let times = sim.wait_each(&[a, b]);
        assert!((times[0] - 4.0).abs() < 1e-9, "a={}", times[0]);
        assert!((times[1] - 4.0).abs() < 1e-9, "b={}", times[1]);
    }

    #[test]
    fn class_weight_table_applies_to_new_flows() {
        let mut sim = Sim::new();
        sim.set_class_weight(TrafficClass::Exchange, 4.0);
        let l = sim.resource("l", 1e9);
        let a = sim.flow_classed(1e9, 0.0, &[l], TrafficClass::Exchange);
        let b = sim.flow_classed(1e9, 0.0, &[l], TrafficClass::Bulk);
        sim.advance(1e-9);
        let tr = sim.op_trace();
        assert!((tr[a.0].rate - 0.8e9).abs() < 1.0, "a rate={}", tr[a.0].rate);
        assert!((tr[b.0].rate - 0.2e9).abs() < 1.0, "b rate={}", tr[b.0].rate);
        assert_eq!(tr[a.0].class, TrafficClass::Exchange);
        assert_eq!(tr[a.0].weight, 4.0);
        assert_eq!(sim.flow_class(b), TrafficClass::Bulk);
    }

    #[test]
    fn ceiling_caps_class_aggregate_and_releases_rest() {
        // Bulk capped at 0.2 GB/s on a 1 GB/s link: the two bulk flows
        // share the cap, the exchange flow takes everything else.
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        sim.set_class_ceiling(l, TrafficClass::Bulk, 0.2e9);
        let b1 = sim.flow_classed(1e9, 0.0, &[l], TrafficClass::Bulk);
        let b2 = sim.flow_classed(1e9, 0.0, &[l], TrafficClass::Bulk);
        let e = sim.flow_classed(1e9, 0.0, &[l], TrafficClass::Exchange);
        sim.advance(1e-9);
        let tr = sim.op_trace();
        let bulk = tr[b1.0].rate + tr[b2.0].rate;
        assert!(bulk <= 0.2e9 * (1.0 + 1e-9) + 1.0, "bulk agg={bulk}");
        assert!((tr[e.0].rate - 0.8e9).abs() < 1.0, "exchange={}", tr[e.0].rate);
        assert_eq!(sim.class_ceiling(l, TrafficClass::Bulk), Some(0.2e9));
    }

    #[test]
    fn floor_guarantees_class_aggregate_under_pressure() {
        // 8 bulk flows vs 1 exchange flow on one link: unprotected the
        // exchange gets 1/9; with a 0.5 GB/s floor it gets >= 0.5 GB/s
        // and bulk shares the rest.
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        sim.set_class_floor(l, TrafficClass::Exchange, 0.5e9);
        let e = sim.flow_classed(4e9, 0.0, &[l], TrafficClass::Exchange);
        let bulk: Vec<_> = (0..8)
            .map(|_| sim.flow_classed(4e9, 0.0, &[l], TrafficClass::Bulk))
            .collect();
        sim.advance(1e-9);
        let tr = sim.op_trace();
        // Floor 0.5 + weighted share of the other 0.5 over 9 flows.
        let expect = 0.5e9 + 0.5e9 / 9.0;
        assert!(
            (tr[e.0].rate - expect).abs() < 1.0,
            "exchange rate {} != {expect}",
            tr[e.0].rate
        );
        let total: f64 = tr.iter().map(|x| x.rate).sum();
        assert!(total <= 1e9 * (1.0 + 1e-9) + 1.0, "conservation: {total}");
        for &b in &bulk {
            assert!((tr[b.0].rate - 0.5e9 / 9.0).abs() < 1.0);
        }
        assert_eq!(sim.class_floor(l, TrafficClass::Exchange), 0.5e9);
    }

    #[test]
    fn floor_grant_cannot_starve_best_effort_on_unfloored_hop() {
        // A 10 GB/s floor on resource B would give the guaranteed flow a
        // 10 GB/s claim, far above the 1 GB/s unfloored hop A it shares
        // with a best-effort flow.  Pass 1 must cap the grant at the
        // flow's plain fair share of A (0.5 GB/s) — the bulk flow keeps
        // a positive rate instead of being starved to zero.
        let mut sim = Sim::new();
        let a = sim.resource("a", 1e9);
        let b = sim.resource("b", 10e9);
        sim.set_class_floor(b, TrafficClass::Exchange, 10e9);
        let g = sim.flow_classed(4e9, 0.0, &[a, b], TrafficClass::Exchange);
        let be = sim.flow_classed(4e9, 0.0, &[a], TrafficClass::Bulk);
        sim.advance(1e-9);
        let tr = sim.op_trace();
        // grant = fair share 0.5e9; pass 2 splits the remaining 0.5e9.
        assert!((tr[g.0].rate - 0.75e9).abs() < 1.0, "g={}", tr[g.0].rate);
        assert!((tr[be.0].rate - 0.25e9).abs() < 1.0, "bulk={}", tr[be.0].rate);
        assert!(tr[be.0].rate > 0.1e9, "best-effort must never be starved to zero");
        let total = tr[g.0].rate + tr[be.0].rate;
        assert!(total <= 1e9 * (1.0 + 1e-9) + 1.0, "conservation on A: {total}");
    }

    #[test]
    fn add_class_floor_accumulates_and_removes() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        sim.add_class_floor(l, TrafficClass::Exchange, 0.3e9);
        sim.add_class_floor(l, TrafficClass::Exchange, 0.2e9);
        assert!((sim.class_floor(l, TrafficClass::Exchange) - 0.5e9).abs() < 1.0);
        sim.add_class_floor(l, TrafficClass::Exchange, -0.5e9);
        assert_eq!(sim.class_floor(l, TrafficClass::Exchange), 0.0);
    }

    #[test]
    #[should_panic(expected = "oversubscribed")]
    fn floor_oversubscription_panics() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        sim.set_class_floor(l, TrafficClass::Exchange, 0.7e9);
        sim.set_class_floor(l, TrafficClass::CkptFlush, 0.7e9);
    }

    #[test]
    fn issue_class_is_scoped_and_default_only_overrides_bulk() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        assert_eq!(sim.issue_class(), TrafficClass::Bulk);
        let prev = sim.default_issue_class(TrafficClass::Exchange);
        assert_eq!(prev, TrafficClass::Bulk);
        let a = sim.flow(1e9, 0.0, &[l]);
        // A nested layer must NOT re-tag a more specific ambient class.
        let prev2 = sim.default_issue_class(TrafficClass::Meta);
        assert_eq!(prev2, TrafficClass::Exchange);
        let b = sim.flow(1e9, 0.0, &[l]);
        sim.set_issue_class(prev2);
        sim.set_issue_class(prev);
        let c = sim.flow(1e9, 0.0, &[l]);
        assert_eq!(sim.flow_class(a), TrafficClass::Exchange);
        assert_eq!(sim.flow_class(b), TrafficClass::Exchange);
        assert_eq!(sim.flow_class(c), TrafficClass::Bulk);
    }

    #[test]
    fn cancel_recovers_neighbor_rate_at_cancel_time() {
        // The §11.4 pin: two equal flows share a 1 GB/s link; cancelling
        // one at t=1 must hand the survivor the full link *immediately* —
        // it finishes at 1 + 3.5 = 4.5 s, not at the phantom-finish time
        // (t=8 would be the "keeps draining" trajectory's implied end).
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let a = sim.flow(4e9, 0.0, &[l]);
        let b = sim.flow(4e9, 0.0, &[l]);
        sim.advance(1.0); // 0.5 GB/s each: both moved 0.5 GB
        assert!(sim.cancel_flow(b));
        assert!(sim.was_cancelled(b));
        assert!(sim.poll(b), "cancelled flows poll complete");
        assert_eq!(sim.completed(b), Some(1.0));
        // Settle-then-retire: the cancelled flow's banked progress stays.
        assert!((sim.flow_remaining(b) - 3.5e9).abs() < 1.0);
        let t = sim.wait_all(&[a]);
        assert!((t - 4.5).abs() < 1e-9, "survivor must recover at cancel time: t={t}");
        // Cancelling an already-finished flow is a no-op.
        assert!(!sim.cancel_flow(a));
        assert!(!sim.was_cancelled(a));
    }

    #[test]
    fn cancel_pending_flow_never_activates() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let a = sim.flow(1e9, 0.0, &[l]);
        let p = sim.flow(1e9, 5.0, &[l]); // would activate at t=5
        assert!(sim.cancel_flow(p));
        let t = sim.wait_all(&[a]);
        assert!((t - 1.0).abs() < 1e-9, "a never shared the link: t={t}");
        sim.advance(10.0);
        assert!(sim.was_cancelled(p));
        let tr = sim.op_trace();
        assert!(tr[p.0].cancelled && tr[p.0].done);
        assert_eq!(tr[p.0].rate, 0.0);
    }

    #[test]
    fn cancel_op_batches_and_waiters_observe_completion() {
        let mut sim = Sim::new();
        let l = sim.resource("l", 1e9);
        let op = Op::new(vec![sim.flow(4e9, 0.0, &[l]), sim.flow(4e9, 0.0, &[l])]);
        let survivor = sim.flow(1e9, 0.0, &[l]);
        sim.advance(0.3);
        assert_eq!(sim.cancel_op(&op), 2);
        assert!(sim.poll_op(&op));
        assert_eq!(sim.op_completion(&op), Some(0.3));
        // Waiting on a cancelled op returns its cancellation time.
        assert_eq!(sim.wait_op(&op), 0.3);
        // Survivor had 1e9 - 0.1e9 left at the full link rate.
        let t = sim.wait_all(&[survivor]);
        assert!((t - 1.2).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn default_qos_path_is_unchanged() {
        // flow() with no QoS configuration must behave exactly as before:
        // the unequal-flows scenario from above, re-run through the
        // classed API with default weights.
        let mut sim = Sim::new();
        let link = sim.resource("l", 2e9);
        let a = sim.flow_classed(1e9, 0.0, &[link], TrafficClass::Meta);
        let b = sim.flow_classed(3e9, 0.0, &[link], TrafficClass::Parity);
        let times = sim.wait_each(&[a, b]);
        assert!((times[0] - 1.0).abs() < 1e-9, "a={}", times[0]);
        assert!((times[1] - 2.0).abs() < 1e-9, "b={}", times[1]);
    }

    #[test]
    fn cancel_without_contenders_skips_refill_walk() {
        // Cancelling the only flow on its resource leaves an empty
        // component: the refill walk is skipped outright (the cheap-
        // cancellation path) and the diagnostic surfaces it.
        let mut sim = Sim::new();
        let a = sim.resource("a", 1e9);
        let b = sim.resource("b", 1e9);
        let lone = sim.flow(5e9, 0.0, &[a]);
        let n1 = sim.flow(2e9, 0.0, &[b]);
        let n2 = sim.flow(2e9, 0.0, &[b]);
        sim.advance(0.5);
        sim.cancel_flow(lone);
        assert_eq!(sim.last_refill_component_flows(), 0, "no contender: walk skipped");
        // With a contender left behind, the refill walks exactly the
        // owning component (resource b's two flows are never touched).
        let c1 = sim.flow(4e9, 0.0, &[a]);
        let c2 = sim.flow(4e9, 0.0, &[a]);
        sim.advance(0.5);
        sim.cancel_flow(c1);
        assert_eq!(sim.last_refill_component_flows(), 1, "only the surviving contender");
        let t = sim.wait_each(&[n1, n2]);
        assert!((t[0] - 4.0).abs() < 1e-9, "neighbors kept their half share: {t:?}");
    }

    #[test]
    fn cancel_refill_stays_in_owning_component() {
        // The neighbor component's event count must be unchanged by a
        // cancel in the other component: run the identical two-component
        // scenario with and without the cancel at threads=2 (component B
        // is the bigger one, so the deterministic greedy assignment pins
        // it to worker 0) and compare B's worker event counter.
        let run = |cancel: bool| {
            let mut sim = Sim::new();
            sim.set_threads(2);
            let a = sim.resource("a", 1e9);
            let b = sim.resource("b", 1e9);
            let fa1 = sim.flow(2e9, 0.0, &[a]);
            let _fa2 = sim.flow(3e9, 0.0, &[a]);
            for i in 0..3 {
                sim.flow(1e9 + 1e8 * i as f64, 1e-3 * i as f64, &[b]);
            }
            sim.advance(0.25);
            if cancel {
                sim.cancel_flow(fa1);
            }
            sim.run_until_idle();
            sim.worker_events()[0]
        };
        assert_eq!(run(false), run(true), "component B's event count is cancel-invariant");
    }

    #[test]
    fn threads_equivalence_smoke() {
        // Sharded execution reports the same completion times as serial
        // on a mixed disjoint/shared workload (the full randomized sweep
        // lives in rust/tests/prop_parallel.rs).
        let run = |threads: usize| {
            let mut sim = Sim::new();
            sim.set_threads(threads);
            let shared = sim.resource("shared", 4e9);
            let mut flows = Vec::new();
            for i in 0..4 {
                let nic = sim.resource("nic", 1e9);
                flows.push(sim.flow(1e9, 1e-4 * i as f64, &[nic, shared]));
                let nvme = sim.resource("nvme", 2e9);
                flows.push(sim.flow(5e8 + 1e8 * i as f64, 0.0, &[nvme]));
            }
            flows.push(sim.delay(0.013));
            sim.run_until_idle();
            let times: Vec<SimTime> =
                flows.iter().map(|&f| sim.completed(f).unwrap()).collect();
            (times, sim.now())
        };
        let baseline = run(1);
        for threads in [2, 4, 8] {
            assert_eq!(baseline, run(threads), "threads={threads}");
        }
    }

    #[test]
    fn worker_events_sum_to_events_and_fold_serial_into_slot_zero() {
        let mut sim = Sim::new();
        sim.set_threads(2);
        let a = sim.resource("a", 1e9);
        let b = sim.resource("b", 1e9);
        // Serial events first (interactive wait is a merge barrier)...
        let w = sim.flow(1e9, 0.0, &[a]);
        sim.wait_all(&[w]);
        // ...then a parallel region over two components.
        sim.flow(2e9, 0.0, &[a]);
        sim.flow(3e9, 0.0, &[b]);
        sim.run_until_idle();
        let per_worker = sim.worker_events();
        assert_eq!(per_worker.len(), 2);
        assert_eq!(per_worker.iter().sum::<u64>(), sim.events());
    }
}

//! Deliberately naive reference engine — the differential oracle for the
//! optimized [`Sim`](crate::sim::Sim) and the *baseline* measurement of
//! the `repro bench scale` exhibit.
//!
//! This is the textbook O(events x flows) formulation the optimized
//! engine replaced: every event sweeps the whole active set
//! (`remaining -= rate * dt`), the next finish is found by a linear scan,
//! and any activation/retirement triggers a **global** progressive-filling
//! recomputation over all active flows.  It is kept semantically aligned
//! with the hot engine — identical activation order ((start, id), bit
//! comparison), identical retirement epsilon (`remaining <= 1e-9 *
//! max(rate, 1)` bytes), identical tie-batched filling epsilons — so
//! randomized workloads must produce the same completion times and rates
//! to within 1e-9 (asserted by `rust/tests/prop_engine_oracle.rs` and, at
//! run time, by the scale bench before it reports a speedup).
//!
//! One deliberate divergence from the *pre-overhaul* engine: parking the
//! clock between events (`advance`) sweeps active flows up to the target
//! first.  The old engine skipped that sweep and silently lost the bytes
//! moved since the last event; the lazy engine is immune by construction,
//! and the oracle models the *intended* fluid semantics.
//!
//! Not a public-API surface for simulations — the I/O layers all build on
//! [`crate::sim::Sim`].  This module exists so the optimized engine can be
//! checked against, and timed against, an implementation too simple to be
//! wrong.

use super::{FlowId, ResId, SimTime};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    Pending,
    Active,
    Done,
}

#[derive(Debug, Clone)]
struct RefFlow {
    route: Vec<usize>,
    remaining: f64,
    state: State,
    start_at: SimTime,
    finished_at: SimTime,
    rate: f64,
}

/// The naive engine.  Mirrors the subset of [`crate::sim::Sim`]'s API the
/// oracle tests and the scale-bench baseline need.
#[derive(Debug, Default)]
pub struct RefSim {
    now: SimTime,
    capacities: Vec<f64>,
    flows: Vec<RefFlow>,
    /// Active flow indices in activation order.
    active: Vec<usize>,
    /// Pending flow indices (scanned linearly — deliberately naive).
    pending: Vec<usize>,
    events: u64,
}

impl RefSim {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Events processed so far (the baseline events/sec numerator).
    pub fn events(&self) -> u64 {
        self.events
    }

    pub fn resource(&mut self, capacity: f64) -> ResId {
        assert!(capacity > 0.0);
        self.capacities.push(capacity);
        ResId(self.capacities.len() - 1)
    }

    /// Naive mirror of [`crate::sim::Sim::set_resource_capacity`]: swap
    /// the stored capacity and redo the **global** fill.  Active flows'
    /// `remaining` is already settled as of `self.now` (every `step`
    /// sweeps the whole active set), so no extra settlement is needed —
    /// the eager formulation is immune by construction.
    pub fn set_capacity(&mut self, r: ResId, capacity: f64) {
        assert!(capacity > 0.0 && capacity.is_finite());
        if self.capacities[r.0] == capacity {
            return;
        }
        self.capacities[r.0] = capacity;
        self.recompute_rates();
    }

    pub fn flow(&mut self, bytes: f64, delay: SimTime, route: &[ResId]) -> FlowId {
        assert!(bytes >= 0.0 && delay >= 0.0 && !route.is_empty());
        let id = self.flows.len();
        self.flows.push(RefFlow {
            route: route.iter().map(|r| r.0).collect(),
            remaining: bytes,
            state: State::Pending,
            start_at: self.now + delay,
            finished_at: f64::INFINITY,
            rate: 0.0,
        });
        self.pending.push(id);
        FlowId(id)
    }

    pub fn delay(&mut self, seconds: SimTime) -> FlowId {
        let id = self.flows.len();
        self.flows.push(RefFlow {
            route: Vec::new(),
            remaining: 0.0,
            state: State::Pending,
            start_at: self.now + seconds,
            finished_at: f64::INFINITY,
            rate: 0.0,
        });
        self.pending.push(id);
        FlowId(id)
    }

    pub fn completed(&self, f: FlowId) -> Option<SimTime> {
        let fl = &self.flows[f.0];
        (fl.state == State::Done).then_some(fl.finished_at)
    }

    /// Current allocated rate (0 for pending/finished flows) — the rate
    /// half of the oracle comparison.
    pub fn rate_of(&self, f: FlowId) -> f64 {
        let fl = &self.flows[f.0];
        if fl.state == State::Active {
            fl.rate
        } else {
            0.0
        }
    }

    pub fn wait_all(&mut self, flows: &[FlowId]) -> SimTime {
        while flows.iter().any(|&f| self.flows[f.0].state != State::Done) {
            if !self.step() {
                panic!("reference engine deadlock");
            }
        }
        flows
            .iter()
            .map(|&f| self.flows[f.0].finished_at)
            .fold(0.0, f64::max)
    }

    pub fn wait_each(&mut self, flows: &[FlowId]) -> Vec<SimTime> {
        self.wait_all(flows);
        flows.iter().map(|&f| self.flows[f.0].finished_at).collect()
    }

    pub fn run_until_idle(&mut self) {
        while self.step() {}
    }

    pub fn advance(&mut self, seconds: SimTime) {
        let target = self.now + seconds;
        loop {
            match self.next_event_time() {
                Some(t) if t <= target => {
                    if !self.step() {
                        break;
                    }
                }
                _ => break,
            }
        }
        if target > self.now {
            // Eager engines must sweep when parking between events (see
            // the module docs); dt is target - now.
            let dt = target - self.now;
            for &f in &self.active {
                let fl = &mut self.flows[f];
                fl.remaining = (fl.remaining - fl.rate * dt).max(0.0);
            }
            self.now = target;
        }
    }

    fn next_event_time(&self) -> Option<SimTime> {
        let mut t = f64::INFINITY;
        for &f in &self.pending {
            t = t.min(self.flows[f].start_at);
        }
        for &f in &self.active {
            let fl = &self.flows[f];
            let fin = if fl.rate > 0.0 {
                self.now + fl.remaining / fl.rate
            } else if fl.remaining == 0.0 {
                self.now
            } else {
                f64::INFINITY
            };
            t = t.min(fin);
        }
        t.is_finite().then_some(t)
    }

    fn step(&mut self) -> bool {
        let Some(t) = self.next_event_time() else {
            return false;
        };
        let dt = (t - self.now).max(0.0);
        for &f in &self.active {
            let fl = &mut self.flows[f];
            fl.remaining = (fl.remaining - fl.rate * dt).max(0.0);
        }
        self.now = t;
        self.events += 1;

        // Activate due pending flows in (start_at, id) order — the same
        // bit-exact order the optimized engine's pending heap pops in.
        let mut due: Vec<usize> = self
            .pending
            .iter()
            .copied()
            .filter(|&f| self.flows[f].start_at <= self.now + 1e-15)
            .collect();
        due.sort_by_key(|&f| (self.flows[f].start_at.to_bits(), f));
        let mut changed = false;
        for &f in &due {
            self.pending.retain(|&p| p != f);
            let fl = &mut self.flows[f];
            if fl.remaining <= 1e-9 {
                fl.remaining = 0.0;
                fl.state = State::Done;
                fl.finished_at = self.now;
            } else {
                fl.state = State::Active;
                self.active.push(f);
            }
            changed = true;
        }

        // Retire finished flows (same epsilon as the optimized engine).
        let now = self.now;
        let flows = &mut self.flows;
        let before = self.active.len();
        self.active.retain(|&f| {
            let fl = &mut flows[f];
            if fl.remaining <= 1e-9 * fl.rate.max(1.0) {
                fl.remaining = 0.0;
                fl.state = State::Done;
                fl.finished_at = now;
                false
            } else {
                true
            }
        });
        changed |= self.active.len() != before;

        if changed {
            self.recompute_rates();
        }
        true
    }

    /// Global progressive-filling max-min allocation over ALL active
    /// flows — fresh allocations every call, no incremental state, no
    /// scratch reuse.  Identical epsilons to the optimized engine.
    fn recompute_rates(&mut self) {
        let nres = self.capacities.len();
        let mut residual = self.capacities.clone();
        let mut unfixed = vec![0u32; nres];
        let mut flows_on: Vec<Vec<usize>> = vec![Vec::new(); nres];
        for &f in &self.active {
            for &r in &self.flows[f].route {
                unfixed[r] += 1;
                flows_on[r].push(f);
            }
        }
        let mut fixed = vec![false; self.flows.len()];
        let mut remaining = self.active.len();
        while remaining > 0 {
            let mut min_share = f64::INFINITY;
            for r in 0..nres {
                if unfixed[r] == 0 {
                    continue;
                }
                let share = residual[r] / unfixed[r] as f64;
                if share < min_share {
                    min_share = share;
                }
            }
            if !min_share.is_finite() {
                for &f in &self.active {
                    if !fixed[f] {
                        self.flows[f].rate = 0.0;
                    }
                }
                break;
            }
            let eps = min_share * 1e-12 + 1e-30;
            let mut progressed = false;
            for r in 0..nres {
                if unfixed[r] == 0 {
                    continue;
                }
                let share = residual[r] / unfixed[r] as f64;
                if share - min_share > eps {
                    continue;
                }
                for &f in &flows_on[r] {
                    if fixed[f] {
                        continue;
                    }
                    fixed[f] = true;
                    self.flows[f].rate = min_share;
                    remaining -= 1;
                    progressed = true;
                    for &fr in &self.flows[f].route {
                        residual[fr] = (residual[fr] - min_share).max(0.0);
                        unfixed[fr] -= 1;
                    }
                }
            }
            if !progressed {
                for &f in &self.active {
                    if !fixed[f] {
                        self.flows[f].rate = 0.0;
                    }
                }
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_textbook_sharing() {
        let mut sim = RefSim::new();
        let l = sim.resource(2e9);
        let a = sim.flow(1e9, 0.0, &[l]);
        let b = sim.flow(3e9, 0.0, &[l]);
        let times = sim.wait_each(&[a, b]);
        assert!((times[0] - 1.0).abs() < 1e-9);
        assert!((times[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bottleneck_and_spare_capacity() {
        let mut sim = RefSim::new();
        let l1 = sim.resource(1e9);
        let l2 = sim.resource(10e9);
        let a = sim.flow(1e9, 0.0, &[l1, l2]);
        let b = sim.flow(9e9, 0.0, &[l2]);
        let times = sim.wait_each(&[a, b]);
        assert!((times[0] - 1.0).abs() < 1e-6);
        assert!((times[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn advance_parks_without_losing_progress() {
        let mut sim = RefSim::new();
        let l = sim.resource(1e9);
        let f = sim.flow(2e9, 0.0, &[l]);
        sim.advance(0.5);
        sim.advance(0.5);
        let t = sim.wait_all(&[f]);
        assert!((t - 2.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn counts_events() {
        let mut sim = RefSim::new();
        let l = sim.resource(1e9);
        sim.flow(1e9, 0.0, &[l]);
        sim.run_until_idle();
        assert!(sim.events() >= 2);
    }
}

//! Component-parallel event execution (DESIGN.md section 14).
//!
//! The engine state that belongs to *one connected component* — flows,
//! the per-resource incidence lists, the pending/finish heaps, the
//! refill scratch and the component clock — lives in an ownable
//! [`ComponentState`].  [`super::Sim`] keeps exactly one monolithic core
//! plus a **partition map** (a union-find over resources, unioned along
//! every issued route), so at any serial point it knows a conservative
//! component decomposition: the map only coarsens over time, which is
//! what makes a new flow whose route bridges two partitions a
//! deterministic **merge barrier** (from then on the two partitions are
//! one group).
//!
//! Closed-horizon regions — [`super::Sim::run_until_idle`] and
//! [`super::Sim::advance`] — are where parallelism engages: the core is
//! split into per-component [`ComponentState`]s (local ids assigned in
//! ascending global order, so every `(time, flow id)` tie-break is
//! preserved), the components are advanced independently on
//! `std::thread` scoped workers, and the results are merged back with
//! order-independent operations (scalar copy-back to disjoint flows,
//! saturating max of clocks, sums of event counters).  Interactive
//! waits ([`super::Sim::wait_all`] / [`super::Sim::wait_any`] /
//! [`super::Sim::step_event`]) stay serial — they *are* the merge
//! barrier.  With `--threads 1` no split ever happens and execution is
//! bit-identical to the pre-partition engine.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap, HashMap};

use super::{
    FinishKey, Flow, FlowId, FlowState, PendingKey, ResId, Sim, SimTime, TrafficClass,
};

/// Pseudo-component for pure-delay flows (empty routes touch no
/// resource, so they form their own timer component).
const TIMER_ROOT: usize = usize::MAX;

/// The per-component engine core: everything one connected component
/// needs to advance its own events without reading any other
/// component's rates, heap entries or clock (the invariant PR 3's
/// component-scoped refill established).  `Sim` owns one monolithic
/// instance; parallel regions split it into per-component instances and
/// merge them back (all fields are owned plain data, so the type is
/// `Send` and moves freely onto scoped worker threads).
#[derive(Debug, Default)]
pub(super) struct ComponentState {
    pub(super) now: SimTime,
    /// Resource capacities in bytes/s (names stay in `Sim`; workers
    /// never need them).
    pub(super) caps: Vec<f64>,
    pub(super) flows: Vec<Flow>,
    /// Incidence index: **active** flows on each resource (one entry per
    /// route occurrence), maintained on activation/retirement.
    pub(super) res_flows: Vec<Vec<FlowId>>,
    /// Pending flows in a min-heap by (start_at, id).
    pub(super) pending: BinaryHeap<Reverse<PendingKey>>,
    /// Predicted finishes, lazy-deletion min-heap (DESIGN.md section 10).
    finish: BinaryHeap<Reverse<FinishKey>>,
    /// Flows whose activation/retirement triggered this event's refill.
    pub(super) dirty: Vec<FlowId>,
    /// Flows that completed during the most recent step.
    pub(super) finished_step: Vec<FlowId>,
    // Scratch buffers reused across rate recomputations (hot path).
    scratch_residual: Vec<f64>,
    scratch_unfixed: Vec<u32>,
    scratch_wsum: Vec<f64>,
    scratch_touched: Vec<ResId>,
    comp_flows: Vec<FlowId>,
    scratch_res_epoch: Vec<u64>,
    scratch_comp_epoch: Vec<u64>,
    scratch_fixed_epoch: Vec<u64>,
    scratch_mcr_epoch: Vec<u64>,
    scratch_pass1: Vec<f64>,
    scratch_floor_w: HashMap<(usize, usize), f64>,
    scratch_guar: Vec<(usize, f64)>,
    epoch: u64,
    /// Rate floors: (resource, class index) -> guaranteed bytes/s.
    pub(super) floors: HashMap<(usize, usize), f64>,
    /// Dense per-resource "has any floor" flag (see DESIGN.md §12).
    pub(super) res_has_floor: Vec<bool>,
    /// Events processed by this core (flushed to the process-wide
    /// counter at region/wait boundaries, never from worker threads).
    pub(super) events: u64,
    /// Largest flow set a single refill had to touch (diagnostics).
    pub(super) peak_component: usize,
    /// Flow count of the most recent refill's closure (0 when the last
    /// cancellation found no contenders and skipped the walk).
    pub(super) last_refill_flows: usize,
    /// Event-kind counters for the trace recorder (DESIGN.md §17):
    /// flows activated, flows finished, refills performed.  They live in
    /// the ownable core so workers count locally; [`super::Sim`] merges
    /// them by summation and delta-flushes serially, keeping totals
    /// thread-count independent.
    pub(super) activations: u64,
    pub(super) finishes: u64,
    pub(super) refills: u64,
    /// Refill component-size histogram: bucket `k >= 1` counts refills
    /// whose closure touched `[2^(k-1), 2^k)` flows (sizes >= 2^30 fold
    /// into the last bucket; bucket 0 = empty closures).
    pub(super) refill_size_log2: [u64; 32],
}

impl ComponentState {
    /// Earliest upcoming event: the pending-heap top or the first *valid*
    /// finish-heap entry (stale entries — re-predicted finishes, and
    /// pending flows cancelled before activation — are discarded on the
    /// way).
    pub(super) fn next_event_time(&mut self) -> Option<SimTime> {
        let start = loop {
            match self.pending.peek() {
                None => break f64::INFINITY,
                Some(&Reverse(k)) => {
                    if self.flows[k.1].state != FlowState::Pending {
                        self.pending.pop(); // cancelled before activation
                    } else {
                        break k.time();
                    }
                }
            }
        };
        let finish = loop {
            match self.finish.peek() {
                None => break f64::INFINITY,
                Some(&Reverse(k)) => {
                    let fl = &self.flows[k.1];
                    if fl.state != FlowState::Active || fl.finish_at.to_bits() != k.0 {
                        self.finish.pop(); // lazy deletion
                    } else {
                        break k.time();
                    }
                }
            }
        };
        let t = start.min(finish);
        t.is_finite().then_some(t)
    }

    /// Process one event; returns false when idle.  No per-flow sweep
    /// happens here: progression is implicit in (remaining, touched_at,
    /// rate), and only the flows whose state changes are settled.
    pub(super) fn step(&mut self) -> bool {
        self.finished_step.clear();
        let Some(t) = self.next_event_time() else {
            return false;
        };
        if t > self.now {
            self.now = t;
        }
        self.events += 1;
        self.dirty.clear();

        // Activate pending flows whose latency elapsed (heap pops in
        // (start_at, id) order, so activation order is deterministic).
        while let Some(&Reverse(k)) = self.pending.peek() {
            if k.time() > self.now + 1e-15 {
                break;
            }
            self.pending.pop();
            let f = k.id();
            let fl = &mut self.flows[f.0];
            if fl.state != FlowState::Pending {
                continue; // cancelled before activation: stale heap entry
            }
            // Sub-nanobyte flows (and pure delays) complete on arrival —
            // the same threshold the retirement check applies to a
            // just-activated (rate 0) flow.
            if fl.remaining <= 1e-9 {
                fl.remaining = 0.0;
                fl.state = FlowState::Done;
                fl.finished_at = self.now;
                self.finished_step.push(f);
                self.finishes += 1;
            } else {
                fl.state = FlowState::Active;
                fl.touched_at = self.now;
                for &r in &self.flows[f.0].route {
                    self.res_flows[r.0].push(f);
                }
                self.dirty.push(f);
                self.activations += 1;
            }
        }

        // Retire due finishes: pop valid heap entries whose flows are
        // within the completion epsilon of `now` (remaining <= 1e-9 *
        // max(rate, 1) bytes — near-simultaneous finishes merge into one
        // event, exactly like the eager engine's retirement scan did).
        loop {
            let Some(&Reverse(k)) = self.finish.peek() else {
                break;
            };
            let f = FlowId(k.1);
            {
                let fl = &self.flows[f.0];
                if fl.state != FlowState::Active || fl.finish_at.to_bits() != k.0 {
                    self.finish.pop(); // stale
                    continue;
                }
                let due = k.time() <= self.now
                    || (k.time() - self.now) * fl.rate <= 1e-9 * fl.rate.max(1.0);
                if !due {
                    break;
                }
            }
            self.finish.pop();
            let fl = &mut self.flows[f.0];
            fl.remaining = 0.0;
            fl.touched_at = self.now;
            fl.state = FlowState::Done;
            fl.finished_at = self.now;
            self.finished_step.push(f);
            self.finishes += 1;
            // One incidence entry is removed per route occurrence; the
            // O(flows-on-resource) scan is dominated by the refill that
            // must visit the same component anyway.
            for &r in &self.flows[f.0].route {
                let v = &mut self.res_flows[r.0];
                if let Some(p) = v.iter().position(|&x| x == f) {
                    v.swap_remove(p);
                }
            }
            self.dirty.push(f);
        }

        if !self.dirty.is_empty() {
            self.recompute_component();
        }
        true
    }

    /// Run until no pending/active flows remain.
    fn run_idle(&mut self) {
        while self.step() {}
    }

    /// Process every event up to and including absolute time `target`,
    /// then park the clock there (the closed-horizon half of
    /// [`super::Sim::advance`]).  Parking between events is safe: per-
    /// flow progress is a function of (remaining, touched_at, rate), not
    /// of the event the bytes were last settled at.
    fn run_to(&mut self, target: SimTime) {
        loop {
            match self.next_event_time() {
                Some(t) if t <= target => {
                    if !self.step() {
                        break;
                    }
                }
                _ => break,
            }
        }
        self.now = self.now.max(target);
    }

    /// Rebuild the incidence lists and both heaps from flow state (after
    /// a parallel region merged scalar results back into this core).
    /// Entries are regenerated in ascending flow-id order; by lazy
    /// deletion this is observationally identical to the organically
    /// grown heaps (only the entry whose bits match `finish_at` is ever
    /// valid, and pending keys are a pure function of the flow).
    fn rebuild_index(&mut self) {
        let ComponentState { flows, res_flows, pending, finish, .. } = self;
        for v in res_flows.iter_mut() {
            v.clear();
        }
        pending.clear();
        finish.clear();
        for (i, fl) in flows.iter().enumerate() {
            match fl.state {
                FlowState::Pending => {
                    pending.push(Reverse(PendingKey::new(fl.start_at, FlowId(i))));
                }
                FlowState::Active => {
                    for &r in &fl.route {
                        res_flows[r.0].push(FlowId(i));
                    }
                    if fl.finish_at.is_finite() {
                        finish.push(Reverse(FinishKey::new(fl.finish_at, FlowId(i))));
                    }
                }
                FlowState::Done => {}
            }
        }
    }

    /// Settle `f`'s progress at `now` and assign a new rate, refreshing
    /// its predicted finish and finish-heap entry.  A no-op when the rate
    /// is unchanged — the standing prediction and heap entry stay valid,
    /// which is what keeps disjoint components entirely untouched.
    ///
    /// An associated function over the two fields it mutates, so callers
    /// can invoke it while iterating the (disjoint) incidence lists.
    fn assign_rate(
        flows: &mut [Flow],
        finish: &mut BinaryHeap<Reverse<FinishKey>>,
        now: SimTime,
        f: FlowId,
        new_rate: f64,
    ) {
        let fl = &mut flows[f.0];
        if fl.rate == new_rate {
            return;
        }
        if fl.rate > 0.0 {
            // Lazy-progression settlement: bank the bytes moved at the
            // old rate since the flow was last touched.
            fl.remaining = (fl.remaining - fl.rate * (now - fl.touched_at)).max(0.0);
        }
        fl.touched_at = now;
        fl.rate = new_rate;
        fl.finish_at = if new_rate > 0.0 {
            now + fl.remaining / new_rate
        } else {
            f64::INFINITY
        };
        if fl.finish_at.is_finite() {
            finish.push(Reverse(FinishKey::new(fl.finish_at, f)));
        }
    }

    /// Component-scoped **weighted** progressive-filling max-min fair
    /// allocation, with per-(resource, class) floors and ceilings.
    ///
    /// Hot-path notes (DESIGN.md section 10): starting from the routes of
    /// this event's changed flows, the incidence index is walked to close
    /// over the connected component(s) they touch; the fill then runs
    /// over exactly that flow/resource set.  Rates, predictions and heap
    /// entries of disjoint subsystems are untouched, and within the
    /// component a flow whose refilled rate is unchanged keeps its
    /// standing finish prediction (no settle, no heap churn).  All
    /// bottlenecks tied at the minimum share fix in one pass (672
    /// independent NVMe writers collapse to a single iteration), and the
    /// "fixed"/"visited" marks are epoch-stamped so nothing is cleared or
    /// re-allocated per call.
    ///
    /// QoS (DESIGN.md section 12): **pass 1** grants each guaranteed flow
    /// its weight-share of the floors on its route, capped on unfloored
    /// hops at the flow's plain fair share so guarantees never starve
    /// best-effort traffic there (clamped to route residuals, granted in
    /// flow-id order); **pass 2** is weighted progressive filling of the
    /// remaining capacity over all flows, so a flow's rate is `pass-1
    /// grant + weighted excess share`.  Ceilings need no code here at
    /// all — they are shadow resources on the routes.  With no floored
    /// resource in the component and all weights exactly 1.0, both
    /// passes reduce bit-identically to the unweighted fill (weight sums
    /// built from 1.0 increments equal the old integer counts, and
    /// `x * 1.0` / `0.0 + x` are exact).
    pub(super) fn recompute_component(&mut self) {
        let nres = self.caps.len();
        if self.scratch_residual.len() < nres {
            self.scratch_residual.resize(nres, 0.0);
            self.scratch_unfixed.resize(nres, 0);
            self.scratch_wsum.resize(nres, 0.0);
            self.scratch_res_epoch.resize(nres, 0);
        }
        let nflows = self.flows.len();
        if self.scratch_fixed_epoch.len() < nflows {
            self.scratch_fixed_epoch.resize(nflows, 0);
            self.scratch_comp_epoch.resize(nflows, 0);
            self.scratch_mcr_epoch.resize(nflows, 0);
            self.scratch_pass1.resize(nflows, 0.0);
        }
        self.epoch += 1;
        let epoch = self.epoch;
        self.scratch_touched.clear();
        self.comp_flows.clear();

        // Seed the walk with the routes of the changed flows (finished
        // flows are already out of the incidence lists but their resources
        // must be refilled; activated flows are in and will be found).
        for &f in &self.dirty {
            for &r in &self.flows[f.0].route {
                if self.scratch_res_epoch[r.0] != epoch {
                    self.scratch_res_epoch[r.0] = epoch;
                    self.scratch_wsum[r.0] = 0.0;
                    self.scratch_touched.push(r);
                }
            }
        }
        // Close over the flow<->resource incidence: `scratch_touched`
        // doubles as the BFS queue (cursor `i`).  Each (resource, flow)
        // incidence pair is visited exactly once here, which is where the
        // per-resource unfixed weight sums are accumulated.
        let mut i = 0;
        while i < self.scratch_touched.len() {
            let r = self.scratch_touched[i];
            i += 1;
            for &f in &self.res_flows[r.0] {
                self.scratch_wsum[r.0] += self.flows[f.0].weight;
                if self.scratch_comp_epoch[f.0] != epoch {
                    self.scratch_comp_epoch[f.0] = epoch;
                    self.comp_flows.push(f);
                    for &r2 in &self.flows[f.0].route {
                        if self.scratch_res_epoch[r2.0] != epoch {
                            self.scratch_res_epoch[r2.0] = epoch;
                            self.scratch_wsum[r2.0] = 0.0;
                            self.scratch_touched.push(r2);
                        }
                    }
                }
            }
        }
        if self.comp_flows.len() > self.peak_component {
            self.peak_component = self.comp_flows.len();
        }
        self.last_refill_flows = self.comp_flows.len();
        self.refills += 1;
        let n = self.comp_flows.len();
        let bucket = if n == 0 { 0 } else { (usize::BITS - n.leading_zeros()).min(31) as usize };
        self.refill_size_log2[bucket] += 1;

        let mut comp_floored = false;
        for &r in &self.scratch_touched {
            self.scratch_residual[r.0] = self.caps[r.0];
            self.scratch_unfixed[r.0] = self.res_flows[r.0].len() as u32;
            comp_floored |= self.res_has_floor.get(r.0).copied().unwrap_or(false);
        }

        let now = self.now;

        // --- pass 1: rate floors (guarantees) ------------------------------
        //
        // A guaranteed flow (>= 1 floored (resource, class) pair on its
        // route) receives min over its route of `floor * w / W_class` on
        // floored hops and its plain weighted fair share on unfloored
        // hops (a guarantee is min(floor, achievable demand) end to end
        // — it can never confiscate a hop that made no promise), clamped
        // to route residuals, granted in flow-id order (deterministic).
        let mut pass1_active = false;
        if comp_floored {
            self.scratch_floor_w.clear();
            for &f in &self.comp_flows {
                let fl = &self.flows[f.0];
                let c = fl.class.index();
                for &r in &fl.route {
                    if self.floors.contains_key(&(r.0, c)) {
                        *self.scratch_floor_w.entry((r.0, c)).or_insert(0.0) += fl.weight;
                    }
                }
            }
            self.scratch_guar.clear();
            for &f in &self.comp_flows {
                let fl = &self.flows[f.0];
                let c = fl.class.index();
                let mut mcr = f64::INFINITY;
                let mut floored = false;
                for &r in &fl.route {
                    if let Some(&g) = self.floors.get(&(r.0, c)) {
                        floored = true;
                        let w_class = self.scratch_floor_w[&(r.0, c)];
                        mcr = mcr.min(g * fl.weight / w_class);
                    } else {
                        // Unfloored hop: the guarantee may claim at most
                        // the flow's plain weighted fair share there, so
                        // pass 1 can never starve best-effort flows on a
                        // hop that made no promise (the guarantee is
                        // min(floor, achievable demand) end to end).
                        mcr = mcr.min(
                            self.caps[r.0] * fl.weight
                                / self.scratch_wsum[r.0].max(1e-300),
                        );
                    }
                }
                if floored && mcr.is_finite() {
                    self.scratch_guar.push((f.0, mcr));
                }
            }
            if !self.scratch_guar.is_empty() {
                pass1_active = true;
                self.scratch_guar.sort_unstable_by_key(|&(id, _)| id);
                for &(fid, mcr) in &self.scratch_guar {
                    let mut grant = mcr;
                    for &r in &self.flows[fid].route {
                        grant = grant.min(self.scratch_residual[r.0]);
                    }
                    let grant = grant.max(0.0);
                    self.scratch_mcr_epoch[fid] = epoch;
                    self.scratch_pass1[fid] = grant;
                    for &r in &self.flows[fid].route {
                        self.scratch_residual[r.0] =
                            (self.scratch_residual[r.0] - grant).max(0.0);
                    }
                }
            }
        }

        // --- pass 2: weighted max-min over the residual capacity -----------
        let mut remaining = self.comp_flows.len();
        while remaining > 0 {
            // Smallest per-unit-weight share among component resources
            // with unfixed flows.
            let mut min_share = f64::INFINITY;
            for &r in &self.scratch_touched {
                let n = self.scratch_unfixed[r.0];
                if n == 0 {
                    continue;
                }
                let share = self.scratch_residual[r.0] / self.scratch_wsum[r.0].max(1e-300);
                if share < min_share {
                    min_share = share;
                }
            }
            if !min_share.is_finite() {
                // Remaining flows have no loaded resource left: their
                // pass-1 grant (0 without floors) is all they get.
                for &f in &self.comp_flows {
                    if self.scratch_fixed_epoch[f.0] != epoch {
                        let base = if pass1_active && self.scratch_mcr_epoch[f.0] == epoch {
                            self.scratch_pass1[f.0]
                        } else {
                            0.0
                        };
                        Self::assign_rate(&mut self.flows, &mut self.finish, now, f, base);
                    }
                }
                break;
            }
            // Fix every unfixed flow on every bottleneck tied at min_share.
            let eps = min_share * 1e-12 + 1e-30;
            let mut progressed = false;
            for &r in &self.scratch_touched {
                let n = self.scratch_unfixed[r.0];
                if n == 0 {
                    continue;
                }
                let share = self.scratch_residual[r.0] / self.scratch_wsum[r.0].max(1e-300);
                if share - min_share > eps {
                    continue;
                }
                // This resource is a bottleneck: fix its unfixed flows.
                for &f in &self.res_flows[r.0] {
                    if self.scratch_fixed_epoch[f.0] == epoch {
                        continue;
                    }
                    self.scratch_fixed_epoch[f.0] = epoch;
                    let w = self.flows[f.0].weight;
                    let extra = min_share * w;
                    let rate = if pass1_active && self.scratch_mcr_epoch[f.0] == epoch {
                        self.scratch_pass1[f.0] + extra
                    } else {
                        extra
                    };
                    Self::assign_rate(&mut self.flows, &mut self.finish, now, f, rate);
                    remaining -= 1;
                    progressed = true;
                    for &fr in &self.flows[f.0].route {
                        self.scratch_residual[fr.0] =
                            (self.scratch_residual[fr.0] - extra).max(0.0);
                        self.scratch_unfixed[fr.0] -= 1;
                        self.scratch_wsum[fr.0] -= w;
                    }
                }
            }
            if !progressed {
                // Numerical corner: nothing progressed; the rest keep
                // only their pass-1 grants.
                for &f in &self.comp_flows {
                    if self.scratch_fixed_epoch[f.0] != epoch {
                        let base = if pass1_active && self.scratch_mcr_epoch[f.0] == epoch {
                            self.scratch_pass1[f.0]
                        } else {
                            0.0
                        };
                        Self::assign_rate(&mut self.flows, &mut self.finish, now, f, base);
                    }
                }
                break;
            }
        }
    }
}

/// Union-find over resource ids, unioned along every issued route with
/// **min-root-wins** (the smallest resource id of a merged set is its
/// root), so component identity is a pure function of the issue history
/// — independent of find() call order and of thread count.  The map
/// only coarsens: a route bridging two partitions merges them for good,
/// which is exactly the deterministic merge-barrier semantics DESIGN.md
/// section 14 specifies (components may be *coarser* than the live
/// incidence graph, never finer — coarser is always safe).
#[derive(Debug, Default)]
pub(super) struct Partition {
    parent: Vec<usize>,
}

impl Partition {
    /// Register the next resource as its own singleton component.
    pub(super) fn push(&mut self) {
        self.parent.push(self.parent.len());
    }

    /// Root of `x`'s component, with path compression.
    fn find(&mut self, x: usize) -> usize {
        let mut root = x;
        while self.parent[root] != root {
            root = self.parent[root];
        }
        let mut cur = x;
        while self.parent[cur] != root {
            let next = self.parent[cur];
            self.parent[cur] = root;
            cur = next;
        }
        root
    }

    /// Union every resource on `route` into one component (min root wins).
    pub(super) fn union_route(&mut self, route: &[ResId]) {
        let Some(&first) = route.first() else {
            return;
        };
        let mut root = self.find(first.0);
        for &r in &route[1..] {
            let other = self.find(r.0);
            if other != root {
                let (lo, hi) = if other < root { (other, root) } else { (root, other) };
                self.parent[hi] = lo;
                root = lo;
            }
        }
    }
}

/// One split-out component: its engine core plus the global flow ids its
/// local flows map back to (`gids[local] = global`, ascending).
struct Part {
    state: ComponentState,
    gids: Vec<usize>,
}

impl Sim {
    /// Advance a closed-horizon region: to idle (`target` None) or up to
    /// the absolute time `target` (the [`Sim::advance`] contract).  With
    /// `threads > 1` and at least two live components the region runs
    /// component-parallel on scoped workers; otherwise (and always with
    /// `--threads 1`) it runs serially on the monolithic core — the
    /// exact pre-partition code path, bit for bit.
    pub(super) fn run_region(&mut self, target: Option<SimTime>) {
        let events0 = self.core.events;
        if !(self.threads > 1 && self.try_parallel_region(target)) {
            match target {
                None => self.core.run_idle(),
                Some(t) => self.core.run_to(t),
            }
        }
        self.flush_events();
        // Region instant (serial context, after the counter flush): one
        // engine-lane tick per region that processed any events, so
        // traces show where simulated activity clusters.
        if let Some(tr) = &self.obs {
            let delta = self.core.events - events0;
            if delta > 0 {
                tr.instant(
                    self.core.now,
                    0,
                    crate::obs::lane::ENGINE,
                    "sim.region",
                    vec![("events", delta.into())],
                );
            }
        }
    }

    /// Run one region component-parallel; false when the live flows span
    /// fewer than two partition groups (caller falls back to serial).
    fn try_parallel_region(&mut self, target: Option<SimTime>) -> bool {
        let Some(parts) = self.split_region() else {
            return false;
        };
        // Deterministic worker assignment: components in descending flow
        // count (stable sort — equal sizes keep ascending-root order) go
        // greedily to the least-loaded worker, ties to the lower index.
        // Pure function of the split, so the event trace per component —
        // and therefore every merged output — is independent of how the
        // OS actually schedules the worker threads.
        let nw = self.threads.min(parts.len());
        let mut order: Vec<usize> = (0..parts.len()).collect();
        order.sort_by_key(|&i| Reverse(parts[i].state.flows.len()));
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); nw];
        let mut load = vec![0usize; nw];
        for i in order {
            let k = (0..nw).min_by_key(|&k| (load[k], k)).expect("nw >= 1");
            load[k] += parts[i].state.flows.len();
            buckets[k].push(i);
        }
        let mut slots: Vec<Option<Part>> = parts.into_iter().map(Some).collect();
        let chunks: Vec<Vec<Part>> = buckets
            .iter()
            .map(|b| b.iter().map(|&i| slots[i].take().expect("assigned once")).collect())
            .collect();
        let done: Vec<Vec<Part>> = std::thread::scope(|s| {
            let handles: Vec<_> = chunks
                .into_iter()
                .map(|mut chunk| {
                    s.spawn(move || {
                        for part in &mut chunk {
                            match target {
                                None => part.state.run_idle(),
                                Some(t) => part.state.run_to(t),
                            }
                        }
                        chunk
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)))
                .collect()
        });
        let parts_run = done.iter().map(Vec::len).sum::<usize>();
        self.merge_region(done, target);
        // Merge barrier (serial context): workers never record, so the
        // per-worker shard sizes surface here, once per parallel region.
        if let Some(tr) = &self.obs {
            tr.with(|r| {
                r.add("sim_merge_barriers_total", 1.0);
                r.push(crate::obs::SpanEvent {
                    t: self.core.now,
                    kind: crate::obs::SpanKind::Instant,
                    pid: 0,
                    tid: crate::obs::lane::ENGINE,
                    name: "sim.merge",
                    attrs: vec![("workers", nw.into()), ("components", parts_run.into())],
                });
            });
        }
        true
    }

    /// Split the monolithic core into per-component [`Part`]s, grouped
    /// by partition root over each live flow's first route hop (pure
    /// delays go to the timer pseudo-component).  Local ids — both flow
    /// and resource — are assigned in ascending global order, so they
    /// are order-isomorphic to the global ids and every `(time, id)`
    /// heap tie-break inside a component is preserved exactly.  Returns
    /// None when fewer than two groups are live.
    fn split_region(&mut self) -> Option<Vec<Part>> {
        let Sim { partition, core, .. } = self;
        let mut groups: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, fl) in core.flows.iter().enumerate() {
            if fl.state == FlowState::Done {
                continue;
            }
            let root = match fl.route.first() {
                None => TIMER_ROOT,
                Some(&r) => partition.find(r.0),
            };
            groups.entry(root).or_default().push(i);
        }
        if groups.len() < 2 {
            return None;
        }
        let mut parts = Vec::with_capacity(groups.len());
        for gids in groups.into_values() {
            let mut res_set: BTreeSet<usize> = BTreeSet::new();
            for &gid in &gids {
                for &r in &core.flows[gid].route {
                    res_set.insert(r.0);
                }
            }
            let mut st = ComponentState { now: core.now, ..ComponentState::default() };
            let mut res_map: HashMap<usize, usize> = HashMap::with_capacity(res_set.len());
            for (local, &g) in res_set.iter().enumerate() {
                res_map.insert(g, local);
                st.caps.push(core.caps[g]);
                st.res_flows.push(Vec::new());
                st.res_has_floor
                    .push(core.res_has_floor.get(g).copied().unwrap_or(false));
                for c in 0..TrafficClass::COUNT {
                    if let Some(&v) = core.floors.get(&(g, c)) {
                        st.floors.insert((local, c), v);
                    }
                }
            }
            for &gid in &gids {
                let gf = &core.flows[gid];
                let route: Vec<ResId> =
                    gf.route.iter().map(|r| ResId(res_map[&r.0])).collect();
                let lid = FlowId(st.flows.len());
                st.flows.push(Flow { route, ..gf.clone() });
                match gf.state {
                    FlowState::Pending => {
                        st.pending.push(Reverse(PendingKey::new(gf.start_at, lid)));
                    }
                    FlowState::Active => {
                        for &r in &st.flows[lid.0].route {
                            st.res_flows[r.0].push(lid);
                        }
                        if gf.finish_at.is_finite() {
                            st.finish.push(Reverse(FinishKey::new(gf.finish_at, lid)));
                        }
                    }
                    FlowState::Done => unreachable!("Done flows were filtered above"),
                }
            }
            parts.push(Part { state: st, gids });
        }
        Some(parts)
    }

    /// Merge per-component results back into the monolithic core.  Every
    /// operation here is order-independent across parts — scalar copies
    /// to disjoint global flows, sums of event counters, maxes of clocks
    /// and peaks — so the merged state is identical for every worker
    /// count and bucket shape.  `chunks[w]` ran on worker `w` (feeds the
    /// per-worker event counters the scale bench reports).
    fn merge_region(&mut self, chunks: Vec<Vec<Part>>, target: Option<SimTime>) {
        let Sim { core, worker_events, .. } = self;
        let mut region_now = core.now;
        for (w, chunk) in chunks.into_iter().enumerate() {
            for part in chunk {
                let st = part.state;
                for (lid, &gid) in part.gids.iter().enumerate() {
                    let lf = &st.flows[lid];
                    let gf = &mut core.flows[gid];
                    gf.remaining = lf.remaining;
                    gf.touched_at = lf.touched_at;
                    gf.state = lf.state;
                    gf.finished_at = lf.finished_at;
                    gf.rate = lf.rate;
                    gf.finish_at = lf.finish_at;
                }
                core.events += st.events;
                worker_events[w] += st.events;
                core.activations += st.activations;
                core.finishes += st.finishes;
                core.refills += st.refills;
                for (a, b) in core.refill_size_log2.iter_mut().zip(st.refill_size_log2.iter()) {
                    *a += b;
                }
                if st.peak_component > core.peak_component {
                    core.peak_component = st.peak_component;
                }
                if st.now > region_now {
                    region_now = st.now;
                }
            }
        }
        core.now = match target {
            Some(t) => region_now.max(t),
            None => region_now,
        };
        core.rebuild_index();
        core.dirty.clear();
        core.finished_step.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::super::Sim;
    use super::Partition;
    use crate::sim::ResId;

    #[test]
    fn union_find_min_root_wins_any_order() {
        let mut a = Partition::default();
        let mut b = Partition::default();
        for _ in 0..6 {
            a.push();
            b.push();
        }
        // Same edges in different orders must yield the same roots.
        a.union_route(&[ResId(4), ResId(2)]);
        a.union_route(&[ResId(2), ResId(5)]);
        a.union_route(&[ResId(1), ResId(3)]);
        b.union_route(&[ResId(5), ResId(4)]);
        b.union_route(&[ResId(3), ResId(1)]);
        b.union_route(&[ResId(4), ResId(2)]);
        for x in 0..6 {
            assert_eq!(a.find(x), b.find(x), "root of {x}");
        }
        assert_eq!(a.find(5), 2, "min id of {{2,4,5}} is the root");
        assert_eq!(a.find(3), 1);
        assert_eq!(a.find(0), 0, "untouched singleton");
    }

    /// Two disjoint components: the sharded run must report the same
    /// completion times and final clock as a serial twin.
    fn two_component_workload(threads: usize) -> (Vec<f64>, f64, u64) {
        let mut sim = Sim::new();
        sim.set_threads(threads);
        let a = sim.resource("a", 1e9);
        let b = sim.resource("b", 2e9);
        let mut flows = Vec::new();
        for i in 0..4 {
            flows.push(sim.flow(1e8 + 3e7 * i as f64, 1e-4 * i as f64, &[a]));
            flows.push(sim.flow(2e8 + 5e7 * i as f64, 2e-4 * i as f64, &[b]));
        }
        flows.push(sim.delay(0.017));
        sim.run_until_idle();
        let times: Vec<f64> = flows.iter().map(|&f| sim.completed(f).unwrap()).collect();
        (times, sim.now(), sim.events())
    }

    #[test]
    fn parallel_region_matches_serial_exactly() {
        let (t1, now1, _) = two_component_workload(1);
        for threads in [2, 4, 8] {
            let (tn, nown, _) = two_component_workload(threads);
            assert_eq!(t1, tn, "completion times at threads={threads}");
            assert_eq!(now1, nown, "final clock at threads={threads}");
        }
    }

    #[test]
    fn worker_event_counters_sum_to_engine_total() {
        let mut sim = Sim::new();
        sim.set_threads(3);
        let a = sim.resource("a", 1e9);
        let b = sim.resource("b", 1e9);
        let c = sim.resource("c", 1e9);
        for (i, &r) in [a, b, c].iter().enumerate() {
            sim.flow(1e8, 1e-5 * i as f64, &[r]);
            sim.flow(2e8, 2e-5 * i as f64, &[r]);
        }
        sim.run_until_idle();
        let per_worker = sim.worker_events();
        assert_eq!(per_worker.len(), 3);
        assert_eq!(per_worker.iter().sum::<u64>(), sim.events());
        assert!(per_worker.iter().all(|&e| e > 0), "three components on three workers: {per_worker:?}");
    }

    #[test]
    fn advance_splits_and_reports_midflight_rates() {
        // Mid-region advance: rates and settled progress after a
        // sharded advance() equal the serial twin's, and the region can
        // be re-entered (second advance + idle run) without drift.
        let build = |threads: usize| {
            let mut sim = Sim::new();
            sim.set_threads(threads);
            let a = sim.resource("a", 1e9);
            let b = sim.resource("b", 4e9);
            let f0 = sim.flow(5e8, 0.0, &[a]);
            let f1 = sim.flow(7e8, 1e-3, &[a]);
            let f2 = sim.flow(9e8, 0.0, &[b]);
            (sim, [f0, f1, f2])
        };
        let (mut s1, fl1) = build(1);
        let (mut s2, fl2) = build(2);
        for s in [&mut s1, &mut s2] {
            s.advance(0.05);
        }
        for (&x, &y) in fl1.iter().zip(fl2.iter()) {
            assert_eq!(s1.flow_remaining(x), s2.flow_remaining(y), "remaining after advance");
        }
        let tr1 = s1.op_trace();
        let tr2 = s2.op_trace();
        for (e1, e2) in tr1.iter().zip(tr2.iter()) {
            assert_eq!(e1.rate, e2.rate, "mid-flight rate of flow {:?}", e1.id);
        }
        s1.advance(0.1);
        s2.advance(0.1);
        s1.run_until_idle();
        s2.run_until_idle();
        assert_eq!(s1.now(), s2.now());
        for (&x, &y) in fl1.iter().zip(fl2.iter()) {
            assert_eq!(s1.completed(x), s2.completed(y));
        }
    }

    #[test]
    fn timer_only_workload_runs_serial_under_threads() {
        let mut sim = Sim::new();
        sim.set_threads(4);
        let d1 = sim.delay(0.25);
        let d2 = sim.delay(0.5);
        sim.run_until_idle();
        assert_eq!(sim.completed(d1), Some(0.25));
        assert_eq!(sim.completed(d2), Some(0.5));
        assert_eq!(sim.now(), 0.5);
    }

    #[test]
    fn bridging_flow_is_a_merge_barrier() {
        // Once a route bridges two partitions they stay one group: the
        // run still completes and matches a serial twin even though the
        // bridge flow finished long before the second region.
        let run = |threads: usize| {
            let mut sim = Sim::new();
            sim.set_threads(threads);
            let a = sim.resource("a", 1e9);
            let b = sim.resource("b", 1e9);
            let bridge = sim.flow(1e8, 0.0, &[a, b]);
            sim.wait_all(&[bridge]);
            let fa = sim.flow(3e8, 0.0, &[a]);
            let fb = sim.flow(4e8, 0.0, &[b]);
            sim.run_until_idle();
            (sim.completed(fa).unwrap(), sim.completed(fb).unwrap())
        };
        assert_eq!(run(1), run(2));
    }
}

//! Deterministic RNG for the simulation: SplitMix64.
//!
//! No external dependency, stable across platforms, splittable per node —
//! which keeps every benchmark run bit-reproducible (a property the figure
//! harnesses and the proptest suites rely on).

/// SplitMix64 generator (Steele, Lea, Flood 2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Derive an independent stream (e.g. one per simulated node).
    pub fn split(&mut self, tag: u64) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Multiply-shift; bias is negligible for simulation purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Exponentially distributed sample with the given mean (MTBF draws).
    pub fn next_exp(&mut self, mean: f64) -> f64 {
        assert!(mean > 0.0);
        let u = 1.0 - self.next_f64(); // avoid ln(0)
        -mean * u.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exp_mean_roughly_right() {
        let mut r = SplitMix64::new(9);
        let n = 20_000;
        let mean = 3.0;
        let sum: f64 = (0..n).map(|_| r.next_exp(mean)).sum();
        let got = sum / n as f64;
        assert!((got - mean).abs() < 0.1 * mean, "mean={got}");
    }

    #[test]
    fn below_bound() {
        let mut r = SplitMix64::new(11);
        for _ in 0..1000 {
            assert!(r.next_below(13) < 13);
        }
    }

    #[test]
    fn split_streams_independent() {
        let mut root = SplitMix64::new(5);
        let mut s1 = root.split(1);
        let mut s2 = root.split(2);
        assert_ne!(s1.next_u64(), s2.next_u64());
    }
}

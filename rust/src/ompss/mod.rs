//! OmpSs data-flow task runtime with the DEEP-ER resiliency features.
//!
//! Paper Sections III-B and III-D2: OmpSs lets applications offload
//! annotated tasks across the Cluster-Booster divide (over ParaStation
//! MPI's `MPI_Comm_spawn`).  DEEP-ER added three resiliency features:
//!
//! * **Lightweight task CP** — task inputs are copied into main memory
//!   before launch; a failed task can be relaunched from the in-memory
//!   copy.  Evicted on success.
//! * **Persistent task CP** — task inputs are written (via SIONlib) to
//!   the cache file system; after a full application crash, the restart
//!   *fast-forwards* to the failure point, restoring inputs from disk.
//! * **Resilient offload** — the ParaStation PMD detects, isolates and
//!   cleans up failures of offloaded task groups; only the failed task
//!   group is re-spawned and re-run while other tasks' completed work is
//!   kept (Fig. 10: 42% time saving vs a full re-run, <1% overhead).

use crate::psmpi::{comm_spawn, Pmd, SPAWN_COST_PER_NODE};
use crate::sim::{FlowId, SimTime};
use crate::system::failure::FailurePlan;
use crate::system::Machine;

/// Task identifier within a [`TaskGraph`].
pub type TaskId = usize;

/// One OmpSs task (the unit of offload and recovery).
#[derive(Debug, Clone)]
pub struct Task {
    pub name: String,
    /// Compute work, flops.
    pub flops: f64,
    /// Input dependencies' payload, bytes (shipped master -> worker).
    pub input_bytes: f64,
    /// Output payload, bytes (shipped worker -> master).
    pub output_bytes: f64,
    /// Tasks that must complete first (their outputs are our inputs).
    pub deps: Vec<TaskId>,
}

/// A DAG of tasks.
#[derive(Debug, Clone, Default)]
pub struct TaskGraph {
    pub tasks: Vec<Task>,
}

impl TaskGraph {
    /// Empty graph; equivalent to [`TaskGraph::default`] (clippy's
    /// `new_without_default` pairing, pinned by `default_matches_new`).
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, task: Task) -> TaskId {
        for &d in &task.deps {
            assert!(d < self.tasks.len(), "dependency on unknown task {d}");
        }
        self.tasks.push(task);
        self.tasks.len() - 1
    }

    /// Wave decomposition: tasks grouped by dependency depth; every wave's
    /// tasks are mutually independent (checked by unit test + proptest).
    pub fn waves(&self) -> Vec<Vec<TaskId>> {
        let n = self.tasks.len();
        let mut depth = vec![0usize; n];
        for i in 0..n {
            for &d in &self.tasks[i].deps {
                depth[i] = depth[i].max(depth[d] + 1);
            }
        }
        let max_d = depth.iter().copied().max().unwrap_or(0);
        let mut waves = vec![Vec::new(); max_d + 1];
        for i in 0..n {
            waves[depth[i]].push(i);
        }
        if self.tasks.is_empty() {
            return Vec::new();
        }
        waves
    }
}

/// Which resiliency feature protects the run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resilience {
    /// No protection: a failure forces a full application re-run.
    None,
    /// Inputs cached in master memory; failed tasks relaunch immediately.
    Lightweight,
    /// Inputs persisted to the cache FS; full crashes fast-forward.
    Persistent,
    /// Lightweight + PMD isolation of offloaded groups (the Fig. 10 mode).
    ResilientOffload,
}

impl Resilience {
    pub fn name(&self) -> &'static str {
        match self {
            Resilience::None => "no resiliency",
            Resilience::Lightweight => "lightweight task CP",
            Resilience::Persistent => "persistent task CP",
            Resilience::ResilientOffload => "OmpSs resilient offload",
        }
    }
}

/// Outcome of an OmpSs run.
#[derive(Debug, Clone, Copy)]
pub struct RunOutcome {
    pub time: SimTime,
    /// Tasks executed in total, incl. re-executions.
    pub tasks_run: usize,
    /// Full application restarts that occurred.
    pub app_restarts: usize,
    /// Checkpoint overhead spent protecting inputs.
    pub protection_overhead: SimTime,
}

/// Memory-copy bandwidth for the lightweight input snapshot (stream-copy
/// rate of a Sandy Bridge node; the snapshot is the only overhead the
/// paper's "<1%" claim is about).
const MEMCPY_BW: f64 = 24e9;

/// The OmpSs runtime, executing a graph over offloaded worker nodes.
#[derive(Debug)]
pub struct OmpssRuntime {
    pub resilience: Resilience,
    /// Master node (runs the main program; Cluster side in DEEP-ER).
    pub master: usize,
}

impl OmpssRuntime {
    pub fn new(master: usize, resilience: Resilience) -> Self {
        Self { master, resilience }
    }

    /// Execute `graph` on `workers` under `failures` (keyed by task id:
    /// a failure at task *t* kills its worker halfway through the task).
    pub fn execute(
        &self,
        m: &mut Machine,
        graph: &TaskGraph,
        workers: &[usize],
        failures: &FailurePlan,
    ) -> RunOutcome {
        assert!(!workers.is_empty());
        let t_start = m.sim.now();
        let mut tasks_run = 0usize;
        let mut app_restarts = 0usize;
        let mut protection = 0.0;
        let mut pmd = Pmd::new();

        // Spawn the offload group once (MPI_Comm_spawn).
        let group = comm_spawn(m, workers.to_vec());
        drop(group);

        let mut injected: Vec<TaskId> = failures
            .at_iterations
            .iter()
            .map(|f| f.at as usize)
            .collect();
        injected.sort_unstable();

        'run: loop {
            let mut executed_in_this_attempt: Vec<TaskId> = Vec::new();
            for wave in graph.waves() {
                // Assign wave tasks round-robin to alive workers.
                let alive: Vec<usize> =
                    workers.iter().copied().filter(|&w| m.nodes[w].alive).collect();
                let alive = if alive.is_empty() { workers.to_vec() } else { alive };
                let mut flows: Vec<FlowId> = Vec::new();
                let mut wave_fail: Option<(TaskId, usize)> = None;

                for (slot, &tid) in wave.iter().enumerate() {
                    let task = &graph.tasks[tid];
                    let worker = alive[slot % alive.len()];
                    // Protection: snapshot inputs before launch.
                    match self.resilience {
                        Resilience::Lightweight | Resilience::ResilientOffload => {
                            let d = task.input_bytes / MEMCPY_BW;
                            protection += d;
                            let f = m.sim.delay(d);
                            m.sim.wait_all(&[f]);
                        }
                        Resilience::Persistent => {
                            // SIONlib write of inputs to the local cache FS
                            // (durable device preferred: NVMe, then HDD,
                            // then RAM-disk as a last resort).
                            let node = &m.nodes[self.master];
                            let dev = node
                                .nvme
                                .as_ref()
                                .or(node.hdd.as_ref())
                                .or(node.ramdisk.as_ref())
                                .cloned();
                            if let Some(dev) = dev {
                                let t0 = m.sim.now();
                                let f = dev.write(&mut m.sim, task.input_bytes, 1, &[]);
                                protection += m.sim.wait_all(&[f]) - t0;
                            }
                        }
                        Resilience::None => {}
                    }
                    if injected.first() == Some(&tid)
                        && !executed_in_this_attempt.contains(&tid)
                    {
                        wave_fail = Some((tid, worker));
                    }
                    // Ship inputs, compute, ship outputs (one chained flow
                    // approximated by sequential segments on the DES).
                    let sm = m.fabric.endpoint_info(m.nodes[self.master].ep);
                    let sw = m.fabric.endpoint_info(m.nodes[worker].ep);
                    let lat = sm.latency + sw.latency;
                    let in_route = m.fabric.path(m.nodes[self.master].ep, m.nodes[worker].ep);
                    let input = m.sim.flow(task.input_bytes, lat, &in_route);
                    m.sim.wait_all(&[input]);
                    let cpu = m.nodes[worker].cpu;
                    let eff_flops = if Some((tid, worker)) == wave_fail {
                        task.flops * 0.5 // dies halfway
                    } else {
                        task.flops
                    };
                    flows.push(m.sim.flow(eff_flops / 0.25, 0.0, &[cpu]));
                    if Some((tid, worker)) != wave_fail {
                        executed_in_this_attempt.push(tid);
                    }
                }
                m.sim.wait_all(&flows);
                // Output shipping for the successful tasks of the wave.
                let mut out_flows = Vec::new();
                for (slot, &tid) in wave.iter().enumerate() {
                    let worker = alive[slot % alive.len()];
                    if Some((tid, worker)) == wave_fail {
                        continue;
                    }
                    let task = &graph.tasks[tid];
                    let sm = m.fabric.endpoint_info(m.nodes[self.master].ep);
                    let sw = m.fabric.endpoint_info(m.nodes[worker].ep);
                    let out_route = m.fabric.path(m.nodes[worker].ep, m.nodes[self.master].ep);
                    out_flows.push(m.sim.flow(
                        task.output_bytes,
                        sm.latency + sw.latency,
                        &out_route,
                    ));
                }
                if !out_flows.is_empty() {
                    m.sim.wait_all(&out_flows);
                }
                tasks_run += wave.len();

                if let Some((tid, worker)) = wave_fail {
                    injected.retain(|&t| t != tid);
                    m.kill_node(worker);
                    match self.resilience {
                        Resilience::None => {
                            // Whole application is lost; repair node, rerun.
                            pmd.detect_and_isolate(m, workers);
                            m.revive_node(worker);
                            pmd.reinstate(worker);
                            app_restarts += 1;
                            // Full re-spawn of the offload side.
                            let _ = comm_spawn(m, workers.to_vec());
                            continue 'run;
                        }
                        Resilience::Lightweight
                        | Resilience::Persistent
                        | Resilience::ResilientOffload => {
                            // PMD detects + isolates; only the failed task
                            // re-runs, from the protected inputs.
                            pmd.detect_and_isolate(m, workers);
                            m.revive_node(worker);
                            pmd.reinstate(worker);
                            // Re-spawn just one group member.
                            let d = m.sim.delay(SPAWN_COST_PER_NODE);
                            m.sim.wait_all(&[d]);
                            if self.resilience == Resilience::Persistent {
                                // Inputs come back from the cache FS.
                                let node = &m.nodes[self.master];
                                let dev = node
                                    .nvme
                                    .as_ref()
                                    .or(node.hdd.as_ref())
                                    .or(node.ramdisk.as_ref())
                                    .cloned();
                                if let Some(dev) = dev
                                {
                                    let f = dev.read(
                                        &mut m.sim,
                                        graph.tasks[tid].input_bytes,
                                        1,
                                        &[],
                                    );
                                    m.sim.wait_all(&[f]);
                                }
                            }
                            // Rerun the single task on the revived worker.
                            let task = &graph.tasks[tid];
                            let sm = m.fabric.endpoint_info(m.nodes[self.master].ep);
                            let sw = m.fabric.endpoint_info(m.nodes[worker].ep);
                            let in_route =
                                m.fabric.path(m.nodes[self.master].ep, m.nodes[worker].ep);
                            let input =
                                m.sim.flow(task.input_bytes, sm.latency + sw.latency, &in_route);
                            m.sim.wait_all(&[input]);
                            let cpu = m.nodes[worker].cpu;
                            let c = m.sim.flow(task.flops / 0.25, 0.0, &[cpu]);
                            m.sim.wait_all(&[c]);
                            let out_route =
                                m.fabric.path(m.nodes[worker].ep, m.nodes[self.master].ep);
                            let out = m.sim.flow(
                                task.output_bytes,
                                sm.latency + sw.latency,
                                &out_route,
                            );
                            m.sim.wait_all(&[out]);
                            tasks_run += 1;
                        }
                    }
                }
            }
            break;
        }

        RunOutcome {
            time: m.sim.now() - t_start,
            tasks_run,
            app_restarts,
            protection_overhead: protection,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::presets;

    fn chain_graph(n: usize, flops: f64) -> TaskGraph {
        let mut g = TaskGraph::new();
        for i in 0..n {
            let deps = if i == 0 { vec![] } else { vec![i - 1] };
            g.add(Task {
                name: format!("t{i}"),
                flops,
                input_bytes: 1e6,
                output_bytes: 1e6,
                deps,
            });
        }
        g
    }

    fn wide_graph(n: usize, flops: f64) -> TaskGraph {
        let mut g = TaskGraph::new();
        for i in 0..n {
            g.add(Task {
                name: format!("t{i}"),
                flops,
                input_bytes: 1e6,
                output_bytes: 1e6,
                deps: vec![],
            });
        }
        g
    }

    #[test]
    fn default_matches_new() {
        // Guards the Default impl clippy's new_without_default pairs
        // with `TaskGraph::new()` (and the same invariant repo-wide:
        // every argless `new()` type derives or implements Default).
        let d = TaskGraph::default();
        let n = TaskGraph::new();
        assert!(d.tasks.is_empty() && n.tasks.is_empty());
        assert!(d.waves().is_empty());
    }

    #[test]
    fn waves_respect_dependencies() {
        let g = chain_graph(5, 1e9);
        let waves = g.waves();
        assert_eq!(waves.len(), 5);
        for (i, w) in waves.iter().enumerate() {
            assert_eq!(w, &vec![i]);
        }
        let g2 = wide_graph(8, 1e9);
        assert_eq!(g2.waves().len(), 1);
        assert_eq!(g2.waves()[0].len(), 8);
    }

    #[test]
    fn clean_run_no_restarts() {
        let mut m = Machine::build(presets::marenostrum3());
        let rt = OmpssRuntime::new(0, Resilience::None);
        let g = wide_graph(16, 1e11);
        let out = rt.execute(&mut m, &g, &[1, 2, 3, 4], &FailurePlan::none());
        assert_eq!(out.app_restarts, 0);
        assert_eq!(out.tasks_run, 16);
        assert!(out.time > 0.0);
    }

    #[test]
    fn fig10_failure_without_resiliency_near_doubles() {
        let g = chain_graph(10, 2e11);
        let fail_late = FailurePlan::one_at_iteration(0, 9); // last task
        let mut m1 = Machine::build(presets::marenostrum3());
        let rt = OmpssRuntime::new(0, Resilience::None);
        let t_clean = rt.execute(&mut m1, &g, &[1, 2], &FailurePlan::none()).time;
        let mut m2 = Machine::build(presets::marenostrum3());
        let out = rt.execute(&mut m2, &g, &[1, 2], &fail_late);
        assert_eq!(out.app_restarts, 1);
        let ratio = out.time / t_clean;
        assert!((1.7..=2.2).contains(&ratio), "ratio={ratio:.2}");
    }

    #[test]
    fn fig10_resilient_offload_saves_most_of_the_rerun() {
        let g = chain_graph(10, 2e11);
        let fail_late = FailurePlan::one_at_iteration(0, 9);
        let mk = || Machine::build(presets::marenostrum3());
        let t_clean = OmpssRuntime::new(0, Resilience::ResilientOffload)
            .execute(&mut mk(), &g, &[1, 2], &FailurePlan::none())
            .time;
        let t_none = OmpssRuntime::new(0, Resilience::None)
            .execute(&mut mk(), &g, &[1, 2], &fail_late)
            .time;
        let t_res = OmpssRuntime::new(0, Resilience::ResilientOffload)
            .execute(&mut mk(), &g, &[1, 2], &fail_late)
            .time;
        // Paper: 42% saving vs unprotected failure run; <= ~15% over clean.
        let saving = 1.0 - t_res / t_none;
        assert!((0.25..=0.55).contains(&saving), "saving={saving:.2}");
        let over_clean = t_res / t_clean - 1.0;
        assert!(over_clean < 0.35, "overhead vs clean = {over_clean:.2}");
    }

    #[test]
    fn fig10_protection_overhead_below_1pct() {
        let g = chain_graph(10, 2e11);
        let mk = || Machine::build(presets::marenostrum3());
        let t_none = OmpssRuntime::new(0, Resilience::None)
            .execute(&mut mk(), &g, &[1, 2], &FailurePlan::none())
            .time;
        let t_prot = OmpssRuntime::new(0, Resilience::ResilientOffload)
            .execute(&mut mk(), &g, &[1, 2], &FailurePlan::none())
            .time;
        let overhead = t_prot / t_none - 1.0;
        assert!(overhead < 0.01, "overhead={overhead:.4}");
    }

    #[test]
    fn persistent_mode_reads_inputs_back() {
        let g = chain_graph(6, 1e11);
        let fail = FailurePlan::one_at_iteration(0, 3);
        let mut m = Machine::build(presets::marenostrum3());
        let rt = OmpssRuntime::new(0, Resilience::Persistent);
        let out = rt.execute(&mut m, &g, &[1, 2], &fail);
        assert_eq!(out.app_restarts, 0);
        assert_eq!(out.tasks_run, 7); // 6 + 1 re-execution
        assert!(out.protection_overhead > 0.0);
    }

    #[test]
    fn empty_graph_is_trivial() {
        let mut m = Machine::build(presets::marenostrum3());
        let rt = OmpssRuntime::new(0, Resilience::None);
        let out = rt.execute(&mut m, &TaskGraph::new(), &[1], &FailurePlan::none());
        assert_eq!(out.tasks_run, 0);
    }
}

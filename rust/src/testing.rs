//! Property-testing runner (proptest is unavailable offline).
//!
//! Proptest-shaped essentials: seeded case generation from a [`Gen`]
//! source, many cases per property, and on failure a greedy *shrink* pass
//! that retries the property with smaller inputs before reporting the
//! minimal failing case.  Used by rust/tests/prop_invariants.rs.
//!
//! [`check_zoo`] additionally sweeps machine-backed properties across the
//! topology zoo: each case runs on a registry member, round-robin, and a
//! failure names the topology alongside the case/seed.

use crate::sim::rng::SplitMix64;
use crate::system::{zoo, MachineSpec};

/// The thread counts the component-parallel engine is swept at by
/// `rust/tests/prop_parallel.rs` (ISSUE 7: completion times must match
/// `--threads 1` exactly at every count).
pub const THREAD_SWEEP: &[usize] = &[1, 2, 4, 8];

/// Random value source handed to properties.
#[derive(Debug)]
pub struct Gen {
    rng: SplitMix64,
}

impl Gen {
    /// Create a generator from a seed (each property case gets its own).
    pub fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed) }
    }

    /// A uniformly random 64-bit value.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform usize in [lo, hi] (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// A fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vec of `len` values drawn by `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// A uniformly random 32-bit signed value.
    pub fn i32(&mut self) -> i32 {
        self.rng.next_u64() as i32
    }
}

/// Configuration for [`check`] and [`check_zoo`].
#[derive(Debug, Clone, Copy)]
pub struct Config {
    /// Number of generated inputs to test the property on.
    pub cases: usize,
    /// Base seed; case `i` derives its own stream from `seed + i`.
    pub seed: u64,
    /// Topology names [`check_zoo`] cycles through (ignored by the plain
    /// runners); defaults to the whole registry.
    pub topologies: &'static [&'static str],
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 256, seed: 0xDEE9E5, topologies: zoo::NAMES }
    }
}

/// Run `prop` on `cases` generated inputs.  `gen_input` draws an input
/// from randomness; `shrink` proposes smaller candidates (may be empty).
/// Panics with the minimal failing input's debug representation.
pub fn check_with<T: Clone + std::fmt::Debug>(
    cfg: Config,
    mut gen_input: impl FnMut(&mut Gen) -> T,
    mut shrink: impl FnMut(&T) -> Vec<T>,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cfg.cases {
        let mut g = Gen::new(cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen_input(&mut g);
        if prop(&input) {
            continue;
        }
        // Greedy shrink: repeatedly take the first smaller failing candidate.
        let mut minimal = input.clone();
        'outer: loop {
            for cand in shrink(&minimal) {
                if !prop(&cand) {
                    minimal = cand;
                    continue 'outer;
                }
            }
            break;
        }
        panic!(
            "property failed (case {case}, seed {}):\n  minimal input: {minimal:?}",
            cfg.seed
        );
    }
}

/// [`check_with`] without shrinking.
pub fn check<T: Clone + std::fmt::Debug>(
    cfg: Config,
    gen_input: impl FnMut(&mut Gen) -> T,
    prop: impl FnMut(&T) -> bool,
) {
    check_with(cfg, gen_input, |_| Vec::new(), prop);
}

/// Run a machine-backed property swept across the topology zoo: case `i`
/// resolves `cfg.topologies[i % len]` to a [`MachineSpec`] and hands it
/// to both closures (clone it to build machines — specs are cheap).  A
/// failing case panics with the topology name so a swept suite pinpoints
/// the family that broke.  No shrinking: machine inputs do not shrink
/// meaningfully, the per-case seed reproduces everything.
pub fn check_zoo<T: std::fmt::Debug>(
    cfg: Config,
    mut gen_input: impl FnMut(&mut Gen, &MachineSpec) -> T,
    mut prop: impl FnMut(&MachineSpec, &T) -> bool,
) {
    assert!(!cfg.topologies.is_empty(), "check_zoo needs at least one topology");
    for case in 0..cfg.cases {
        let name = cfg.topologies[case % cfg.topologies.len()];
        let spec = zoo::by_name(name).expect("Config::topologies entries resolve in the zoo");
        let mut g = Gen::new(cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let input = gen_input(&mut g, &spec);
        if !prop(&spec, &input) {
            panic!(
                "property failed on topology {name} (case {case}, seed {}):\n  input: {input:?}",
                cfg.seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(
            Config { cases: 50, seed: 1, ..Config::default() },
            |g| g.usize_in(0, 100),
            |&x| {
                n += 1;
                x <= 100
            },
        );
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(
            Config { cases: 64, seed: 2, ..Config::default() },
            |g| g.usize_in(0, 100),
            |&x| x < 90,
        );
    }

    #[test]
    #[should_panic(expected = "minimal input: 10")]
    fn shrinking_finds_minimal() {
        // Property fails for x >= 10; shrinking by -1 should land on 10.
        check_with(
            Config { cases: 64, seed: 3, ..Config::default() },
            |g| g.usize_in(0, 1000),
            |&x| if x > 0 { vec![x - 1, x / 2] } else { vec![] },
            |&x| x < 10,
        );
    }

    #[test]
    fn zoo_sweep_visits_every_topology_round_robin() {
        let mut seen = Vec::new();
        check_zoo(
            Config { cases: zoo::NAMES.len() * 2, seed: 4, ..Config::default() },
            |_, spec| spec.topology.label(),
            |spec, label| {
                seen.push(label.clone());
                spec.total_nodes() > 0
            },
        );
        for name in zoo::NAMES {
            assert_eq!(seen.iter().filter(|l| l == name).count(), 2, "{name}");
        }
    }

    #[test]
    #[should_panic(expected = "on topology fat-tree:2,8")]
    fn zoo_failure_names_the_topology() {
        check_zoo(
            Config { cases: 16, seed: 5, ..Config::default() },
            |_, _| 0u32,
            |spec, _| !matches!(spec.topology, crate::fabric::TopologySpec::FatTree { .. }),
        );
    }

    #[test]
    fn gen_ranges_hold() {
        let mut g = Gen::new(9);
        for _ in 0..1000 {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        let v = g.vec(5, |g| g.bool());
        assert_eq!(v.len(), 5);
    }
}

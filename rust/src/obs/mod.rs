//! Deterministic observability: virtual-clock tracing + metrics
//! (DESIGN.md section 17).
//!
//! One telemetry spine for every subsystem: span events (`begin`/`end`/
//! `instant` with a small typed attribute set) and counters/gauges/
//! log-bucket histograms, recorded into a bounded ring-buffer
//! [`Recorder`] behind a cheaply-cloneable [`Trace`] handle.  Two
//! exporters: Chrome trace-event JSON ([`Trace::chrome_trace`],
//! loadable in Perfetto / `chrome://tracing` — jobs as processes,
//! phases/ops as threads/slices) and a Prometheus-style text snapshot
//! ([`Trace::prometheus_text`]).
//!
//! Design invariants:
//!
//! * **Virtual clock only.**  Every timestamp is sim time
//!   ([`SimTime`], seconds), never wall clock, so traces are
//!   byte-deterministic for a fixed seed.
//! * **Zero-cost when disabled.**  The handle lives as an
//!   `Option<Trace>` on [`crate::sim::Sim`]; every instrumentation
//!   site is an `if let Some(..)` on it.  Untraced runs never
//!   allocate, lock, or format.
//! * **Observe, never disturb.**  Recording reads simulation state and
//!   writes only into the recorder; it never advances the clock,
//!   issues flows, or feeds back into any decision.  The
//!   zero-perturbation gate in `rust/tests/integration_obs.rs` pins
//!   reports byte-identical traced vs untraced.
//! * **Serial recording.**  Only serial-phase code records (the
//!   component-parallel workers of `sim::partition` count into their
//!   own [`super::sim`] state, merged and flushed to the recorder at
//!   region/wait barriers), so event order is deterministic.
//! * **Bounded.**  The span ring drops the *oldest* events past
//!   capacity and counts them in `obs_dropped_spans_total` — a
//!   deterministic window over the tail of the run, never unbounded
//!   memory.
//!
//! Naming conventions: span names are dotted (`scr.ckpt`,
//! `phase.compute`, `sched.dispatch_round`), metric names are
//! Prometheus-style snake_case with a unit-ish suffix
//! (`sim_events_total`, `sched_queue_depth`).  Process id 0 is the
//! system (scheduler/engine/serve/qos lanes); process id `job + 1` is
//! fleet job `job`.

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::sync::{Arc, Mutex};

use crate::metrics::LogHist;
use crate::sim::SimTime;
use crate::util::json::Json;

/// Spans recorded before the ring starts dropping the oldest
/// (per-recorder; see the module docs on boundedness).
pub const DEFAULT_SPAN_CAP: usize = 1 << 16;

/// Well-known thread lanes inside a trace process.  On pid 0 (the
/// system process) the lanes are scheduler / engine / serve / qos; on a
/// job process they are lifecycle phases / checkpoint / flush / io.
pub mod lane {
    /// pid 0: scheduler decisions.  Job pids: lifecycle phase slices.
    pub const MAIN: u32 = 0;
    /// pid 0: engine (region/merge events).  Job pids: SCR checkpoints.
    pub const ENGINE: u32 = 1;
    pub const SCR: u32 = 1;
    /// pid 0: serve tumbling windows.  Job pids: multilevel flush tiers.
    pub const SERVE: u32 = 2;
    pub const FLUSH: u32 = 2;
    /// pid 0: qos admission verdicts.  Job pids: other I/O (BeeOND/NAM).
    pub const QOS: u32 = 3;
    pub const IO: u32 = 3;
}

/// A typed attribute value (the `args` of a Chrome trace event).
#[derive(Debug, Clone, PartialEq)]
pub enum AttrVal {
    U64(u64),
    F64(f64),
    Str(&'static str),
}

impl From<u64> for AttrVal {
    fn from(v: u64) -> Self {
        AttrVal::U64(v)
    }
}

impl From<usize> for AttrVal {
    fn from(v: usize) -> Self {
        AttrVal::U64(v as u64)
    }
}

impl From<f64> for AttrVal {
    fn from(v: f64) -> Self {
        AttrVal::F64(v)
    }
}

impl From<&'static str> for AttrVal {
    fn from(v: &'static str) -> Self {
        AttrVal::Str(v)
    }
}

impl AttrVal {
    fn to_json(&self) -> Json {
        match *self {
            AttrVal::U64(v) => Json::Num(v as f64),
            AttrVal::F64(v) => Json::Num(v),
            AttrVal::Str(s) => Json::Str(s.into()),
        }
    }
}

/// Attribute list of one span event.  Static keys keep recording
/// allocation-light and exporter output deterministic.
pub type Attrs = Vec<(&'static str, AttrVal)>;

/// What a [`SpanEvent`] marks: a slice opening (`Begin`), a slice
/// closing (`End`), or a point event (`Instant`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    Begin,
    End,
    Instant,
}

impl SpanKind {
    /// Chrome trace-event phase letter.
    fn ph(self) -> &'static str {
        match self {
            SpanKind::Begin => "B",
            SpanKind::End => "E",
            SpanKind::Instant => "i",
        }
    }
}

/// One recorded trace event on the virtual clock.
#[derive(Debug, Clone)]
pub struct SpanEvent {
    /// Virtual time, seconds.
    pub t: SimTime,
    pub kind: SpanKind,
    /// 0 = system (engine/sched/serve/qos); `job + 1` = fleet job `job`.
    pub pid: u32,
    /// Lane within the process (see [`lane`]).
    pub tid: u32,
    pub name: &'static str,
    pub attrs: Attrs,
}

/// The bounded event store behind a [`Trace`] handle.
#[derive(Debug)]
pub struct Recorder {
    cap: usize,
    spans: VecDeque<SpanEvent>,
    /// Oldest spans evicted past `cap` (exported as
    /// `obs_dropped_spans_total`).
    dropped: u64,
    counters: BTreeMap<&'static str, f64>,
    gauges: BTreeMap<&'static str, f64>,
    hists: BTreeMap<&'static str, LogHist>,
    proc_names: BTreeMap<u32, String>,
    thread_names: BTreeMap<(u32, u32), &'static str>,
}

impl Default for Recorder {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_SPAN_CAP)
    }
}

impl Recorder {
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            cap: cap.max(1),
            spans: VecDeque::new(),
            dropped: 0,
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            hists: BTreeMap::new(),
            proc_names: BTreeMap::new(),
            thread_names: BTreeMap::new(),
        }
    }

    pub fn push(&mut self, ev: SpanEvent) {
        if self.spans.len() == self.cap {
            self.spans.pop_front();
            self.dropped += 1;
        }
        self.spans.push_back(ev);
    }

    pub fn add(&mut self, name: &'static str, delta: f64) {
        *self.counters.entry(name).or_insert(0.0) += delta;
    }

    pub fn gauge_set(&mut self, name: &'static str, v: f64) {
        self.gauges.insert(name, v);
    }

    pub fn observe(&mut self, name: &'static str, v: f64) {
        self.hists.entry(name).or_default().record(v);
    }

    /// Direct histogram access for bucketwise delta merges (the engine
    /// counter flush in [`crate::sim::Sim`]).
    pub fn hist_mut(&mut self, name: &'static str) -> &mut LogHist {
        self.hists.entry(name).or_default()
    }

    pub fn spans(&self) -> impl Iterator<Item = &SpanEvent> {
        self.spans.iter()
    }

    pub fn span_count(&self) -> usize {
        self.spans.len()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn counter(&self, name: &str) -> f64 {
        self.counters.get(name).copied().unwrap_or(0.0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    fn chrome_trace(&self) -> Json {
        let mut events = Vec::new();
        // Metadata first: process and thread names (BTreeMap iteration
        // keeps them sorted, hence byte-stable).
        for (&pid, name) in &self.proc_names {
            let mut args = BTreeMap::new();
            args.insert("name".to_string(), Json::Str(name.clone()));
            let mut o = BTreeMap::new();
            o.insert("ph".into(), Json::Str("M".into()));
            o.insert("name".into(), Json::Str("process_name".into()));
            o.insert("pid".into(), Json::Num(pid as f64));
            o.insert("tid".into(), Json::Num(0.0));
            o.insert("args".into(), Json::Obj(args));
            events.push(Json::Obj(o));
        }
        for (&(pid, tid), &name) in &self.thread_names {
            let mut args = BTreeMap::new();
            args.insert("name".to_string(), Json::Str(name.into()));
            let mut o = BTreeMap::new();
            o.insert("ph".into(), Json::Str("M".into()));
            o.insert("name".into(), Json::Str("thread_name".into()));
            o.insert("pid".into(), Json::Num(pid as f64));
            o.insert("tid".into(), Json::Num(tid as f64));
            o.insert("args".into(), Json::Obj(args));
            events.push(Json::Obj(o));
        }
        for ev in &self.spans {
            let mut o = BTreeMap::new();
            o.insert("ph".into(), Json::Str(ev.kind.ph().into()));
            o.insert("name".into(), Json::Str(ev.name.into()));
            o.insert("pid".into(), Json::Num(ev.pid as f64));
            o.insert("tid".into(), Json::Num(ev.tid as f64));
            // Virtual seconds -> trace microseconds.
            o.insert("ts".into(), Json::Num(ev.t * 1e6));
            if ev.kind == SpanKind::Instant {
                // Thread-scoped instant (renders as a tick, not a line).
                o.insert("s".into(), Json::Str("t".into()));
            }
            if !ev.attrs.is_empty() {
                let mut args = BTreeMap::new();
                for (k, v) in &ev.attrs {
                    args.insert((*k).to_string(), v.to_json());
                }
                o.insert("args".into(), Json::Obj(args));
            }
            events.push(Json::Obj(o));
        }
        let mut doc = BTreeMap::new();
        doc.insert("traceEvents".into(), Json::Arr(events));
        doc.insert("displayTimeUnit".into(), Json::Str("ms".into()));
        Json::Obj(doc)
    }

    fn prometheus_text(&self) -> String {
        let mut out = String::new();
        out.push_str("# Deterministic snapshot on the virtual sim clock.\n");
        out.push_str("# TYPE obs_dropped_spans_total counter\n");
        out.push_str(&format!("obs_dropped_spans_total {}\n", self.dropped));
        for (name, v) in &self.counters {
            out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
        }
        for (name, v) in &self.gauges {
            out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
        }
        for (name, h) in &self.hists {
            out.push_str(&format!("# TYPE {name} histogram\n"));
            let mut cum = 0u64;
            for (i, &n) in h.buckets.iter().enumerate() {
                if n == 0 {
                    continue;
                }
                cum += n;
                if i == 63 {
                    continue; // folded into +Inf below
                }
                let le = LogHist::bucket_lo(i + 1);
                out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
            }
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
            out.push_str(&format!("{name}_count {}\n", h.count));
        }
        out
    }
}

/// Shared handle to a [`Recorder`]: clone-cheap (an `Arc`), records
/// through `&self` (a `Mutex` inside), so immutable-machine contexts
/// like `Scr::checkpoint_commit` can still record.
#[derive(Clone, Default)]
pub struct Trace(Arc<Mutex<Recorder>>);

impl fmt::Debug for Trace {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // No lock in Debug: a trace may be debug-printed (e.g. inside a
        // config dump) while a recording call holds the mutex.
        f.write_str("Trace")
    }
}

impl Trace {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        Trace(Arc::new(Mutex::new(Recorder::with_capacity(cap))))
    }

    /// Run `f` against the locked recorder (bulk/batched recording).
    pub fn with<R>(&self, f: impl FnOnce(&mut Recorder) -> R) -> R {
        f(&mut self.0.lock().unwrap())
    }

    pub fn begin(&self, t: SimTime, pid: u32, tid: u32, name: &'static str, attrs: Attrs) {
        self.with(|r| r.push(SpanEvent { t, kind: SpanKind::Begin, pid, tid, name, attrs }));
    }

    pub fn end(&self, t: SimTime, pid: u32, tid: u32, name: &'static str) {
        self.with(|r| {
            r.push(SpanEvent { t, kind: SpanKind::End, pid, tid, name, attrs: Vec::new() })
        });
    }

    pub fn instant(&self, t: SimTime, pid: u32, tid: u32, name: &'static str, attrs: Attrs) {
        self.with(|r| r.push(SpanEvent { t, kind: SpanKind::Instant, pid, tid, name, attrs }));
    }

    pub fn add(&self, name: &'static str, delta: f64) {
        self.with(|r| r.add(name, delta));
    }

    pub fn gauge_set(&self, name: &'static str, v: f64) {
        self.with(|r| r.gauge_set(name, v));
    }

    pub fn observe(&self, name: &'static str, v: f64) {
        self.with(|r| r.observe(name, v));
    }

    pub fn set_process_name(&self, pid: u32, name: impl Into<String>) {
        let name = name.into();
        self.with(|r| {
            r.proc_names.insert(pid, name);
        });
    }

    pub fn set_thread_name(&self, pid: u32, tid: u32, name: &'static str) {
        self.with(|r| {
            r.thread_names.insert((pid, tid), name);
        });
    }

    pub fn span_count(&self) -> usize {
        self.with(|r| r.span_count())
    }

    pub fn dropped(&self) -> u64 {
        self.with(|r| r.dropped())
    }

    pub fn counter(&self, name: &str) -> f64 {
        self.with(|r| r.counter(name))
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.with(|r| r.gauge(name))
    }

    /// Export the whole recording as a Chrome trace-event JSON document
    /// (the `--trace-out` artifact).
    pub fn chrome_trace(&self) -> Json {
        self.with(|r| r.chrome_trace())
    }

    /// Export counters/gauges/histograms as Prometheus-style text.
    pub fn prometheus_text(&self) -> String {
        self.with(|r| r.prometheus_text())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn ev(t: f64, name: &'static str) -> SpanEvent {
        SpanEvent { t, kind: SpanKind::Instant, pid: 0, tid: 0, name, attrs: Vec::new() }
    }

    #[test]
    fn ring_drops_oldest_deterministically() {
        let tr = Trace::with_capacity(3);
        for (i, name) in ["a", "b", "c", "d", "e"].iter().enumerate() {
            tr.with(|r| r.push(ev(i as f64, name)));
        }
        assert_eq!(tr.span_count(), 3);
        assert_eq!(tr.dropped(), 2);
        let names: Vec<&str> = tr.with(|r| r.spans().map(|e| e.name).collect());
        assert_eq!(names, ["c", "d", "e"]);
        // The drop count is surfaced in both exporters.
        assert!(tr.prometheus_text().contains("obs_dropped_spans_total 2"));
    }

    #[test]
    fn chrome_trace_round_trips_through_util_json() {
        let tr = Trace::new();
        tr.set_process_name(1, "job0");
        tr.set_thread_name(1, lane::MAIN, "phase");
        tr.begin(0.5, 1, lane::MAIN, "phase.compute", vec![("iter", 3usize.into())]);
        tr.end(1.25, 1, lane::MAIN, "phase.compute");
        tr.instant(1.25, 0, lane::QOS, "qos.admit", vec![("job", 0usize.into())]);
        let doc = tr.chrome_trace();
        let text = doc.to_pretty_string();
        let parsed = json::parse(&text).expect("exporter emits valid JSON");
        assert_eq!(parsed, doc, "chrome trace must round-trip byte-faithfully");
        // Structural spot checks: phases, ts scaling, instant scope.
        assert!(text.contains("\"ph\": \"B\""));
        assert!(text.contains("\"ph\": \"E\""));
        assert!(text.contains("\"ph\": \"M\""));
        assert!(text.contains("\"ts\": 500000"));
        assert!(text.contains("\"s\": \"t\""));
        assert!(text.contains("displayTimeUnit"));
    }

    #[test]
    fn export_is_byte_deterministic() {
        let build = || {
            let tr = Trace::new();
            tr.add("sim_events_total", 7.0);
            tr.gauge_set("sched_queue_depth", 2.0);
            tr.observe("flush_blocked_s", 0.25);
            tr.observe("flush_blocked_s", 3.0);
            tr.begin(0.0, 0, 0, "x", Vec::new());
            tr.end(2.0, 0, 0, "x");
            (tr.chrome_trace().to_pretty_string(), tr.prometheus_text())
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn prometheus_text_shape() {
        let tr = Trace::new();
        tr.add("a_total", 2.0);
        tr.add("a_total", 1.0);
        tr.gauge_set("g", 5.5);
        tr.observe("h", 1.5);
        let text = tr.prometheus_text();
        assert!(text.contains("# TYPE a_total counter\na_total 3\n"));
        assert!(text.contains("# TYPE g gauge\ng 5.5\n"));
        // 1.5 lands in the [1, 2) bucket -> le = 2.
        assert!(text.contains("h_bucket{le=\"2\"} 1\n"));
        assert!(text.contains("h_bucket{le=\"+Inf\"} 1\n"));
        assert!(text.contains("h_count 1\n"));
        assert_eq!(tr.counter("a_total"), 3.0);
        assert_eq!(tr.gauge("g"), Some(5.5));
        assert_eq!(tr.gauge("missing"), None);
    }
}

//! BeeGFS parallel file system model + the BeeOND cache layer.
//!
//! Paper Section III-C: DEEP-ER's global storage is BeeGFS — one metadata
//! server (MDS) and two object storage servers (OSS) in the prototype rack.
//! The project added a **cache domain** based on BeeOND: a per-job file
//! system instance over the node-local NVMe devices, usable in synchronous
//! or asynchronous mode, which gives *constant storage bandwidth per node*
//! and shields the global backend (Figs. 6, 7).
//!
//! Model:
//! * metadata ops (create/open/stat/close) are unit flows through the MDS
//!   service resource — many small task-local files queue up there, which
//!   is the effect SIONlib removes (Fig. 5);
//! * file payloads stripe round-robin across OSS targets in
//!   [`STRIPE_CHUNK`] chunks; each stripe is a flow routed client NIC ->
//!   backplane -> server NIC -> server disk, so storage saturation and
//!   incast emerge naturally;
//! * [`BeeOnd`] redirects payloads to the node-local device and (in async
//!   mode) trickles them to the global FS in the background.

pub mod beeond;

pub use beeond::{BeeOnd, CacheMode};

use crate::sim::{FlowId, Op, SimTime, TrafficClass};
use crate::system::Machine;

/// BeeGFS default stripe chunk.
pub const STRIPE_CHUNK: f64 = 512.0 * 1024.0;
/// Client-side software path cost per write call (VFS + net msg setup).
pub const CLIENT_OP_COST: SimTime = 6e-6;

/// Handle for the global BeeGFS instance of a [`Machine`].
///
/// The struct only stores routing metadata; all state lives in the
/// machine's simulator, so several clients can interleave freely.
#[derive(Debug, Clone, Default)]
pub struct BeeGfs {
    /// Round-robin offset so files start on different targets.
    next_target: usize,
}

/// Cost accounting for one completed I/O call.
#[derive(Debug, Clone, Copy, Default)]
pub struct IoReport {
    pub meta_ops: u64,
    pub bytes: f64,
    /// Completion time of the last flow involved.
    pub done_at: SimTime,
}

impl BeeGfs {
    pub fn new() -> Self {
        Self::default()
    }

    /// One metadata operation (create/open/stat/close) issued by `node`.
    /// Returns the flow completing when the MDS has serviced it.
    /// QoS: tagged [`TrafficClass::Meta`] (unless the caller set a more
    /// specific ambient class); payload stripes keep the caller's class.
    pub fn meta_op(&self, m: &mut Machine, node: usize) -> FlowId {
        let ep = m.nodes[node].ep;
        let client = m.fabric.endpoint_info(ep);
        let mds = m.fabric.endpoint_info(m.mds_ep);
        let rtt = 2.0 * (client.latency + mds.latency);
        // "1 op" through the MDS service resource (capacity = ops/s).
        let prev = m.sim.default_issue_class(TrafficClass::Meta);
        let f = m.sim.flow(1.0, rtt, &[m.mds_res]);
        m.sim.set_issue_class(prev);
        f
    }

    /// `count` metadata operations, issued concurrently (they queue at the
    /// MDS resource — the file-create storm of task-local I/O).
    pub fn meta_ops(&self, m: &mut Machine, node: usize, count: u64) -> Vec<FlowId> {
        (0..count).map(|_| self.meta_op(m, node)).collect()
    }

    /// `count` concurrent metadata operations as one [`Op`] handle.
    pub fn meta_ops_op(&self, m: &mut Machine, node: usize, count: u64) -> Op {
        Op::new(self.meta_ops(m, node, count))
    }

    /// Write `bytes` from `node` to the global FS as one logical file
    /// region, striped over the OSS targets.  Returns an [`Op`] handle
    /// that completes when the write is durable on every target; callers
    /// poll or wait it (the async flush path holds these handles across
    /// compute phases).
    pub fn write_striped_op(&mut self, m: &mut Machine, node: usize, bytes: f64) -> Op {
        Op::new(self.transfer_striped(m, node, bytes, true))
    }

    /// Read `bytes` striped from the global FS, as an [`Op`] handle.
    pub fn read_striped_op(&mut self, m: &mut Machine, node: usize, bytes: f64) -> Op {
        Op::new(self.transfer_striped(m, node, bytes, false))
    }

    /// Flow-level shim over [`BeeGfs::write_striped_op`].
    pub fn write_striped(&mut self, m: &mut Machine, node: usize, bytes: f64) -> Vec<FlowId> {
        self.transfer_striped(m, node, bytes, true)
    }

    /// Flow-level shim over [`BeeGfs::read_striped_op`].
    pub fn read_striped(&mut self, m: &mut Machine, node: usize, bytes: f64) -> Vec<FlowId> {
        self.transfer_striped(m, node, bytes, false)
    }

    fn transfer_striped(
        &mut self,
        m: &mut Machine,
        node: usize,
        bytes: f64,
        write: bool,
    ) -> Vec<FlowId> {
        let n_targets = m.servers.len().max(1);
        let start = self.next_target;
        self.next_target = (self.next_target + 1) % n_targets;
        let client = m.fabric.endpoint_info(m.nodes[node].ep);
        // Whole-file bytes split round-robin: with many chunks the share per
        // target is bytes/n (chunk granularity folded into op overhead).
        let n_chunks = (bytes / STRIPE_CHUNK).ceil().max(1.0);
        let per_target = bytes / n_targets as f64;
        let chunks_per_target = (n_chunks / n_targets as f64).ceil() as u64;
        let mut flows = Vec::with_capacity(n_targets);
        for k in 0..n_targets {
            let server_idx = (start + k) % n_targets;
            let (dev_res, srv_ep) = {
                let s = &m.servers[server_idx];
                (
                    if write { s.device.write_res() } else { s.device.read_res() },
                    s.ep,
                )
            };
            let srv = m.fabric.endpoint_info(srv_ep);
            let lat = client.latency
                + srv.latency
                + CLIENT_OP_COST * chunks_per_target as f64
                + m.servers[server_idx].device.params.op_latency;
            let route = if write {
                let mut r = m.fabric.path(m.nodes[node].ep, srv_ep);
                r.push(dev_res);
                r
            } else {
                // Data path server -> client, fronted by the device read.
                let mut r = vec![dev_res];
                r.extend(m.fabric.path(srv_ep, m.nodes[node].ep));
                r
            };
            flows.push(m.sim.flow(per_target, lat, &route));
        }
        flows
    }

    /// Convenience: create + write + close one file, waiting for
    /// durability.  Returns the completion report.  (Blocking shim: the
    /// create must be serviced before payload flows are issued, so the
    /// sequential waits are inherent to the VFS protocol, not the API.)
    pub fn write_file(&mut self, m: &mut Machine, node: usize, bytes: f64) -> IoReport {
        let create = Op::single(self.meta_op(m, node));
        m.sim.wait_op(&create);
        let payload = self.write_striped_op(m, node, bytes);
        let done = m.sim.wait_op(&payload);
        let close = Op::single(self.meta_op(m, node));
        let done_at = m.sim.wait_op(&close).max(done);
        IoReport { meta_ops: 2, bytes, done_at }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::presets;

    fn machine() -> Machine {
        Machine::build(presets::deep_er())
    }

    #[test]
    fn single_writer_hits_server_stripe_bw() {
        let mut m = machine();
        let mut fs = BeeGfs::new();
        let bytes = 4e9;
        let t0 = m.sim.now();
        let flows = fs.write_striped(&mut m, 0, bytes);
        let t = m.sim.wait_all(&flows) - t0;
        let bw = bytes / t;
        // Two servers x 1.2 GB/s = 2.4 GB/s ceiling for one client.
        assert!(bw < 2.5e9 && bw > 1.8e9, "bw={bw:e}");
    }

    #[test]
    fn many_writers_saturate_backend() {
        let mut m = machine();
        let mut fs = BeeGfs::new();
        let per_node = 1e9;
        let mut flows = Vec::new();
        for node in 0..16 {
            flows.extend(fs.write_striped(&mut m, node, per_node));
        }
        let t = m.sim.wait_all(&flows);
        let agg = 16.0 * per_node / t;
        // Aggregate pinned at backend capacity (~2.4 GB/s), NOT 16 links.
        assert!(agg < 2.6e9, "agg={agg:e}");
    }

    #[test]
    fn metadata_storm_queues_at_mds() {
        let mut m = machine();
        let fs = BeeGfs::new();
        let t0 = m.sim.now();
        let one = fs.meta_op(&mut m, 0);
        let t_one = m.sim.wait_all(&[one]) - t0;
        let t1 = m.sim.now();
        let many = fs.meta_ops(&mut m, 0, 256);
        let t_many = m.sim.wait_all(&many) - t1;
        assert!(t_many > 100.0 * t_one, "one={t_one} many={t_many}");
    }

    #[test]
    fn write_file_accounts_meta_and_payload() {
        let mut m = machine();
        let mut fs = BeeGfs::new();
        let r = fs.write_file(&mut m, 0, 1e9);
        assert_eq!(r.meta_ops, 2);
        assert!(r.done_at > 0.4, "done={}", r.done_at); // ~1GB / 2.4GB/s + meta
    }

    #[test]
    fn read_and_write_use_distinct_channels() {
        let mut m = machine();
        let mut fs = BeeGfs::new();
        let w = fs.write_striped(&mut m, 0, 1e9);
        let r = fs.read_striped(&mut m, 1, 1e9);
        let mut all = w;
        all.extend(r);
        let t = m.sim.wait_all(&all);
        // Full-duplex: concurrent read+write finish close to the solo time.
        assert!(t < 1.2, "t={t}");
    }
}

//! BeeOND: the BeeGFS-on-demand cache domain over node-local devices.
//!
//! Paper Section III-C: *"The cache domain — based on BeeGFS on demand
//! (BeeOND) — stores data in fast node-local NVM devices and can be used
//! in a synchronous or asynchronous mode."*  Writing to the cache gives a
//! constant per-node bandwidth (the device is not shared between nodes),
//! and the async mode trickles data to the global file system in the
//! background, overlapping with the application's next compute phase —
//! the mechanism behind the near-perfect weak scaling of Fig. 6 and the
//! Buddy checkpoint's deferred global copy.

use super::BeeGfs;
use crate::sim::{FlowId, Op, OpSet, SimTime, TrafficClass};
use crate::system::Machine;

/// Which node-local device class backs the cache domain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheDevice {
    /// Intel DC P3700 NVMe (the DEEP-ER configuration).
    Nvme,
    /// Conventional spinning disk (the Fig. 7 comparator).
    Hdd,
    /// RAM-disk (the QPACE3 emulation of Fig. 6).
    RamDisk,
}

/// Synchronous (durable on global FS before return) vs asynchronous
/// (durable on the cache; global copy trickles in the background).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheMode {
    Sync,
    Async,
}

/// A per-job BeeOND instance.
#[derive(Debug)]
pub struct BeeOnd {
    pub device: CacheDevice,
    pub mode: CacheMode,
    /// Outstanding background flush operations (async mode).
    flushes: OpSet,
    global: BeeGfs,
}

impl BeeOnd {
    pub fn new(device: CacheDevice, mode: CacheMode) -> Self {
        Self { device, mode, flushes: OpSet::new(), global: BeeGfs::new() }
    }

    /// Write `bytes` from `node` into the cache domain as `ops`
    /// operations, returning the [`Op`] whose completion makes the write
    /// *visible* under the cache mode: cache-durable (async) or
    /// cache+global-durable (sync).
    ///
    /// Async mode trickles the global copy **chunk-by-chunk** in the
    /// background as chunks land in the cache (the flush flows are
    /// issued alongside the cache write, not after it); the flush is
    /// tracked internally and observed via [`BeeOnd::flushes_settled`] /
    /// [`BeeOnd::drain`].  Sync mode is store-and-forward: the global
    /// copy only begins once the cache write is durable, so the sync
    /// path inherently blocks mid-way (that serialization is the
    /// protocol, not an API artifact).
    pub fn write_op(&mut self, m: &mut Machine, node: usize, bytes: f64, ops: u64) -> Op {
        let local = self.local_write_flow(m, node, bytes, ops);
        let prev = m.sim.default_issue_class(TrafficClass::CkptFlush);
        let op = match self.mode {
            CacheMode::Sync => {
                m.sim.wait_all(&[local]);
                let mut op = self.global.write_striped_op(m, node, bytes);
                op.push(local);
                op
            }
            CacheMode::Async => {
                let flush = self.global.write_striped_op(m, node, bytes);
                self.flushes.push(flush);
                Op::single(local)
            }
        };
        m.sim.set_issue_class(prev);
        self.trace_flush(m, node, bytes);
        op
    }

    /// Blocking write with **whole-file store-and-forward** semantics:
    /// the global copy is issued only after the cache write is durable
    /// (the conservative reading of the paper's async mode; the
    /// [`BeeOnd::write_op`] path pipelines chunk-wise instead).  Returns
    /// the completion time of the visible write.
    pub fn write(&mut self, m: &mut Machine, node: usize, bytes: f64, ops: u64) -> SimTime {
        let local = self.local_write_flow(m, node, bytes, ops);
        let t_local = m.sim.wait_all(&[local]);
        let prev = m.sim.default_issue_class(TrafficClass::CkptFlush);
        let t = match self.mode {
            CacheMode::Sync => {
                let op = self.global.write_striped_op(m, node, bytes);
                m.sim.wait_op(&op).max(t_local)
            }
            CacheMode::Async => {
                let flush = self.global.write_striped_op(m, node, bytes);
                self.flushes.push(flush);
                t_local
            }
        };
        m.sim.set_issue_class(prev);
        self.trace_flush(m, node, bytes);
        t
    }

    /// Trace the global-copy flush issue (both cache modes stripe the
    /// same payload to BeeGFS; only the blocking behavior differs).
    fn trace_flush(&self, m: &Machine, node: usize, bytes: f64) {
        if let Some(tr) = m.sim.trace() {
            let pid = m.sim.trace_pid();
            let now = m.sim.now();
            tr.with(|r| {
                r.add("beeond_flushes_total", 1.0);
                r.add("beeond_flush_bytes_total", bytes);
                r.push(crate::obs::SpanEvent {
                    t: now,
                    kind: crate::obs::SpanKind::Instant,
                    pid,
                    tid: crate::obs::lane::IO,
                    name: "beeond.flush",
                    attrs: vec![("node", node.into()), ("bytes", bytes.into())],
                });
            });
        }
    }

    /// Cache-local write flow without global copy (checkpoint strategies
    /// that never leave the node, e.g. SCR Single, use this path).
    /// QoS: tagged [`TrafficClass::CkptLocal`] unless the caller set a
    /// more specific ambient class.
    pub fn local_write_flow(&self, m: &mut Machine, node: usize, bytes: f64, ops: u64) -> FlowId {
        let dev = self.pick_device(m, node).clone();
        let prev = m.sim.default_issue_class(TrafficClass::CkptLocal);
        let f = dev.write(&mut m.sim, bytes, ops, &[]);
        m.sim.set_issue_class(prev);
        f
    }

    /// Cache-local read flow (restart path / partner exchange source).
    pub fn local_read_flow(&self, m: &mut Machine, node: usize, bytes: f64, ops: u64) -> FlowId {
        let dev = self.pick_device(m, node).clone();
        let prev = m.sim.default_issue_class(TrafficClass::CkptLocal);
        let f = dev.read(&mut m.sim, bytes, ops, &[]);
        m.sim.set_issue_class(prev);
        f
    }

    /// Non-advancing query: are all background flushes durable?
    pub fn flushes_settled(&self, m: &Machine) -> bool {
        self.flushes.poll(&m.sim)
    }

    /// Drop flush records that have already completed; returns how many
    /// settled (bookkeeping between compute phases).
    pub fn reap_flushes(&mut self, m: &Machine) -> usize {
        self.flushes.reap(&m.sim)
    }

    /// Block until all background flushes are durable on the global FS
    /// (end-of-job barrier, or a checkpoint being promoted to level N).
    pub fn drain(&mut self, m: &mut Machine) -> SimTime {
        self.flushes.wait_all(&mut m.sim)
    }

    /// Number of in-flight background flush flows.
    pub fn pending_flushes(&self) -> usize {
        self.flushes.flow_count()
    }

    fn pick_device<'a>(&self, m: &'a Machine, node: usize) -> &'a crate::storage::Device {
        let n = &m.nodes[node];
        let dev = match self.device {
            CacheDevice::Nvme => n.nvme.as_ref(),
            CacheDevice::Hdd => n.hdd.as_ref(),
            CacheDevice::RamDisk => n.ramdisk.as_ref(),
        };
        dev.unwrap_or_else(|| {
            panic!(
                "node {node} has no {:?} device (machine preset mismatch)",
                self.device
            )
        })
    }
}

/// Helper shared by benches: per-node cache bandwidth for a concurrent
/// write of `bytes` from every node in `nodes`.
pub fn concurrent_cache_write(
    m: &mut Machine,
    cache: &mut BeeOnd,
    nodes: &[usize],
    bytes: f64,
    ops: u64,
) -> SimTime {
    let t0 = m.sim.now();
    let flows: Vec<FlowId> = nodes
        .iter()
        .map(|&n| cache.local_write_flow(m, n, bytes, ops))
        .collect();
    m.sim.wait_all(&flows) - t0
}

/// Helper shared by benches: concurrent *global* write from every node.
pub fn concurrent_global_write(
    m: &mut Machine,
    nodes: &[usize],
    bytes: f64,
) -> SimTime {
    let t0 = m.sim.now();
    let mut fs = BeeGfs::new();
    let mut flows = Vec::new();
    for &n in nodes {
        flows.extend(fs.write_striped(m, n, bytes));
    }
    m.sim.wait_all(&flows) - t0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::presets;

    #[test]
    fn async_write_returns_at_cache_speed() {
        let mut m = Machine::build(presets::deep_er());
        let mut sync = BeeOnd::new(CacheDevice::Nvme, CacheMode::Sync);
        let mut asyn = BeeOnd::new(CacheDevice::Nvme, CacheMode::Async);
        let t0 = m.sim.now();
        let t_sync = sync.write(&mut m, 0, 2e9, 4) - t0;
        let t1 = m.sim.now();
        let t_async = asyn.write(&mut m, 1, 2e9, 4) - t1;
        assert!(t_async < 0.8 * t_sync, "sync={t_sync} async={t_async}");
        assert!(asyn.pending_flushes() > 0);
        asyn.drain(&mut m);
        assert_eq!(asyn.pending_flushes(), 0);
    }

    #[test]
    fn cache_write_scales_with_nodes_global_does_not() {
        // The Fig. 6 mechanism in miniature: 16 nodes writing 1 GB each.
        let mut m = Machine::build(presets::deep_er());
        let mut cache = BeeOnd::new(CacheDevice::Nvme, CacheMode::Async);
        let nodes: Vec<usize> = (0..16).collect();
        let t_local = concurrent_cache_write(&mut m, &mut cache, &nodes, 1e9, 1);
        let mut m2 = Machine::build(presets::deep_er());
        let t_global = concurrent_global_write(&mut m2, &nodes, 1e9);
        assert!(
            t_global > 3.0 * t_local,
            "local={t_local} global={t_global}"
        );
    }

    #[test]
    fn nvme_cache_beats_hdd_cache() {
        let mut m = Machine::build(presets::deep_er());
        let mut nvme = BeeOnd::new(CacheDevice::Nvme, CacheMode::Async);
        let mut hdd = BeeOnd::new(CacheDevice::Hdd, CacheMode::Async);
        let nodes: Vec<usize> = (0..8).collect();
        let t_nvme = concurrent_cache_write(&mut m, &mut nvme, &nodes, 1e9, 8);
        let t_hdd = concurrent_cache_write(&mut m, &mut hdd, &nodes, 1e9, 8);
        assert!(t_hdd > 4.0 * t_nvme, "nvme={t_nvme} hdd={t_hdd}");
    }

    #[test]
    #[should_panic(expected = "no RamDisk")]
    fn missing_device_panics() {
        let mut m = Machine::build(presets::deep_er());
        let cache = BeeOnd::new(CacheDevice::RamDisk, CacheMode::Sync);
        let _ = cache.local_write_flow(&mut m, 0, 1e6, 1);
    }

    #[test]
    fn flush_poll_and_reap_track_background_progress() {
        let mut m = Machine::build(presets::deep_er());
        let mut cache = BeeOnd::new(CacheDevice::Nvme, CacheMode::Async);
        let visible = Op::merge((0..8).map(|n| cache.write_op(&mut m, n, 1e9, 4)));
        m.sim.wait_op(&visible);
        // Locals durable, but 8 GB of aggregate flush against a ~2.4 GB/s
        // backend is still trickling in the background.
        assert!(!cache.flushes_settled(&m));
        assert_eq!(cache.reap_flushes(&m), 0);
        let t0 = m.sim.now();
        cache.drain(&mut m);
        assert!(m.sim.now() > t0, "drain must advance to flush completion");
        assert!(cache.flushes_settled(&m));
        assert_eq!(cache.pending_flushes(), 0);
    }

    #[test]
    fn drain_on_empty_is_noop() {
        let mut m = Machine::build(presets::deep_er());
        let mut cache = BeeOnd::new(CacheDevice::Nvme, CacheMode::Async);
        let t = cache.drain(&mut m);
        assert_eq!(t, 0.0);
    }
}

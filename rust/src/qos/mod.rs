//! Traffic-class QoS: the class taxonomy every simulated flow carries,
//! and Chameleon-style admission control over per-resource guarantee
//! budgets (DESIGN.md section 12).
//!
//! DEEP-ER's whole point is that checkpoint flushes, BeeGFS stripes and
//! NAM parity traffic share the same EXTOLL fabric and storage servers as
//! the applications' halo exchanges — and the fleet scheduler
//! ([`crate::sched`]) makes that contention multi-tenant.  This module
//! supplies the vocabulary and the admission ledger for protecting one
//! tenant's latency-critical traffic from another tenant's bulk I/O:
//!
//! * [`TrafficClass`] — the class tag on every [`crate::sim`] flow.  The
//!   I/O layers tag the flows they issue (psmpi exchanges, SCR local
//!   writes, BeeOND/L3 flushes, NAM parity, BeeGFS metadata); everything
//!   untagged is [`TrafficClass::Bulk`].
//! * Per-class **weights**, per-(resource, class) rate **floors**
//!   (guarantees) and **ceilings** (shaping caps) live in the engine
//!   ([`crate::sim::Sim::set_class_weight`],
//!   [`crate::sim::Sim::set_class_floor`],
//!   [`crate::sim::Sim::set_class_ceiling`]) and are enforced by the
//!   weighted max-min fill.
//! * [`Policy`] — the admission ledger: a guarantee (floor) is only
//!   installed after [`Policy::try_admit`] checked it against the
//!   resource's budget, so over-subscription of floors is impossible by
//!   construction — the same shape as the fleet scheduler's node-owner
//!   ledger (`Machine::try_allocate`), and the admitted-demand model of
//!   nsg-ethz/Chameleon.

use std::collections::BTreeMap;

use crate::sim::ResId;

/// The traffic class a flow belongs to.  Classes are the granularity of
/// QoS: weights, floors and ceilings are all per class, never per flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub enum TrafficClass {
    /// Latency-critical application communication (halo/moment ring
    /// exchanges between iterations).
    Exchange,
    /// Node-local checkpoint traffic: NVMe/ramdisk writes and reads,
    /// partner/buddy streams (L1/L2 of the multi-level hierarchy).
    CkptLocal,
    /// Checkpoint promotion to shared storage: BeeOND background flushes
    /// and the multi-level L3 flush to BeeGFS.
    CkptFlush,
    /// XOR parity traffic: reduce-scatter exchanges, CPU folds, NAM
    /// pulls/pushes.
    Parity,
    /// Metadata operations (MDS create/open/stat round-trips).
    Meta,
    /// Everything untagged — generic file I/O, compute flows, raw RDMA.
    #[default]
    Bulk,
}

impl TrafficClass {
    /// All classes, in the (deterministic) order used everywhere.
    pub const ALL: [TrafficClass; 6] = [
        TrafficClass::Exchange,
        TrafficClass::CkptLocal,
        TrafficClass::CkptFlush,
        TrafficClass::Parity,
        TrafficClass::Meta,
        TrafficClass::Bulk,
    ];

    /// Number of classes (sizes the engine's per-class tables).
    pub const COUNT: usize = 6;

    /// Dense index into per-class tables.
    pub fn index(self) -> usize {
        self as usize
    }

    /// Stable display name (also used in shadow-resource labels and the
    /// qos bench JSON).
    pub fn name(self) -> &'static str {
        match self {
            TrafficClass::Exchange => "exchange",
            TrafficClass::CkptLocal => "ckpt-local",
            TrafficClass::CkptFlush => "ckpt-flush",
            TrafficClass::Parity => "parity",
            TrafficClass::Meta => "meta",
            TrafficClass::Bulk => "bulk",
        }
    }
}

/// A declared guarantee demand: aggregate rate floors for one class on a
/// set of resources.  This is what a tenant asks the admission ledger
/// for, and what the scheduler installs into the engine once admitted.
#[derive(Debug, Clone)]
pub struct Demand {
    pub class: TrafficClass,
    /// `(resource, bytes/s floor)` pairs; duplicates are summed.
    pub floors: Vec<(ResId, f64)>,
}

#[derive(Debug, Clone, Copy)]
struct Budget {
    /// Grantable guarantee capacity on the resource (set below the real
    /// capacity so non-guaranteed traffic can never be starved outright).
    cap: f64,
    /// Sum of currently admitted floors.
    granted: f64,
}

/// The admission ledger: per-resource guarantee budgets and the grants
/// charged against them.
///
/// Mirrors the fleet scheduler's node-owner ledger: [`Policy::try_admit`]
/// is the **only** path that adds to `granted`, and it checks the budget
/// before stamping, so the invariant `granted <= cap` per resource holds
/// by construction (no caller can over-subscribe floors).
#[derive(Debug, Default)]
pub struct Policy {
    budgets: BTreeMap<usize, Budget>,
    grants: BTreeMap<u64, Demand>,
}

impl Policy {
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare `cap` bytes/s of `r` grantable as guarantees.  Callers
    /// set this below the resource's real capacity (the engine
    /// additionally asserts that installed floors never exceed it).
    pub fn set_budget(&mut self, r: ResId, cap: f64) {
        assert!(cap > 0.0 && cap.is_finite(), "qos budget must be positive");
        let granted = self.budgets.get(&r.0).map(|b| b.granted).unwrap_or(0.0);
        assert!(
            granted <= cap * (1.0 + 1e-9),
            "cannot shrink budget below already-granted floors"
        );
        self.budgets.insert(r.0, Budget { cap, granted });
    }

    /// Grantable budget on `r`, if one was declared.
    pub fn budget(&self, r: ResId) -> Option<f64> {
        self.budgets.get(&r.0).map(|b| b.cap)
    }

    /// Sum of currently admitted floors on `r`.
    pub fn granted(&self, r: ResId) -> f64 {
        self.budgets.get(&r.0).map(|b| b.granted).unwrap_or(0.0)
    }

    /// Remaining grantable capacity on `r` (0 when no budget declared).
    pub fn headroom(&self, r: ResId) -> f64 {
        self.budgets
            .get(&r.0)
            .map(|b| (b.cap - b.granted).max(0.0))
            .unwrap_or(0.0)
    }

    /// Does `owner` currently hold a grant?
    pub fn has_grant(&self, owner: u64) -> bool {
        self.grants.contains_key(&owner)
    }

    /// Number of grants currently outstanding.  A drained fleet must
    /// report 0 here — any residue is a refund leak (the service-mode
    /// report surfaces this as `qos_grants_open`).
    pub fn grant_count(&self) -> usize {
        self.grants.len()
    }

    /// Admit `demand` for `owner`: all-or-nothing.  Returns false (and
    /// charges nothing) when any resource lacks a budget or lacks
    /// headroom.  Panics if `owner` already holds a grant — release
    /// first; one grant per owner keeps the ledger auditable.
    pub fn try_admit(&mut self, owner: u64, demand: &Demand) -> bool {
        assert!(
            !self.grants.contains_key(&owner),
            "owner {owner} already holds a qos grant"
        );
        // Aggregate duplicate resources, then check before charging.
        let mut asks: BTreeMap<usize, f64> = BTreeMap::new();
        for &(r, g) in &demand.floors {
            assert!(g > 0.0 && g.is_finite(), "demanded floor must be positive");
            *asks.entry(r.0).or_insert(0.0) += g;
        }
        for (&r, &g) in &asks {
            match self.budgets.get(&r) {
                None => return false, // resource was never budgeted
                Some(b) if b.granted + g > b.cap * (1.0 + 1e-9) => return false,
                Some(_) => {}
            }
        }
        for (&r, &g) in &asks {
            self.budgets.get_mut(&r).expect("checked above").granted += g;
        }
        self.grants.insert(owner, demand.clone());
        true
    }

    /// Release `owner`'s grant, returning the demand so the caller can
    /// uninstall the matching engine floors.  `None` when no grant held.
    pub fn release(&mut self, owner: u64) -> Option<Demand> {
        let demand = self.grants.remove(&owner)?;
        for &(r, g) in &demand.floors {
            let b = self.budgets.get_mut(&r.0).expect("granted resource has a budget");
            b.granted = (b.granted - g).max(0.0);
        }
        Some(demand)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_table_is_dense_and_named() {
        assert_eq!(TrafficClass::ALL.len(), TrafficClass::COUNT);
        for (i, c) in TrafficClass::ALL.iter().enumerate() {
            assert_eq!(c.index(), i);
            assert!(!c.name().is_empty());
        }
        assert_eq!(TrafficClass::default(), TrafficClass::Bulk);
    }

    #[test]
    fn admit_charges_and_release_refunds() {
        let mut p = Policy::new();
        let r = ResId(0);
        p.set_budget(r, 10e9);
        assert_eq!(p.headroom(r), 10e9);
        let d = Demand { class: TrafficClass::Exchange, floors: vec![(r, 4e9)] };
        assert!(p.try_admit(1, &d));
        assert!(p.has_grant(1));
        assert!((p.granted(r) - 4e9).abs() < 1.0);
        assert!((p.headroom(r) - 6e9).abs() < 1.0);
        let back = p.release(1).expect("grant held");
        assert_eq!(back.floors.len(), 1);
        assert_eq!(p.granted(r), 0.0);
        assert!(p.release(1).is_none(), "double release is a no-op");
    }

    #[test]
    fn oversubscription_is_rejected_all_or_nothing() {
        let mut p = Policy::new();
        let (a, b) = (ResId(0), ResId(1));
        p.set_budget(a, 10e9);
        p.set_budget(b, 1e9);
        assert!(p.try_admit(1, &Demand {
            class: TrafficClass::Exchange,
            floors: vec![(a, 8e9)],
        }));
        // Second ask fits on `b` but not on `a`: nothing may be charged.
        let d = Demand { class: TrafficClass::Exchange, floors: vec![(a, 4e9), (b, 0.5e9)] };
        assert!(!p.try_admit(2, &d));
        assert!((p.granted(a) - 8e9).abs() < 1.0, "rejected ask must charge nothing");
        assert_eq!(p.granted(b), 0.0);
        // Unbudgeted resource: rejected outright.
        assert!(!p.try_admit(2, &Demand {
            class: TrafficClass::Bulk,
            floors: vec![(ResId(9), 1.0)],
        }));
        // After releasing, the big ask fits.
        p.release(1);
        assert!(p.try_admit(2, &d));
    }

    #[test]
    fn duplicate_resources_in_one_demand_are_summed() {
        let mut p = Policy::new();
        let r = ResId(0);
        p.set_budget(r, 5e9);
        // 3 + 3 > 5: must be rejected even though each half fits alone.
        assert!(!p.try_admit(7, &Demand {
            class: TrafficClass::CkptFlush,
            floors: vec![(r, 3e9), (r, 3e9)],
        }));
        assert_eq!(p.granted(r), 0.0);
    }

    #[test]
    #[should_panic(expected = "already holds a qos grant")]
    fn double_grant_panics() {
        let mut p = Policy::new();
        p.set_budget(ResId(0), 10e9);
        let d = Demand { class: TrafficClass::Exchange, floors: vec![(ResId(0), 1e9)] };
        assert!(p.try_admit(1, &d));
        let _ = p.try_admit(1, &d);
    }
}

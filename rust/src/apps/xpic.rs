//! xPic: the KU Leuven space-weather particle-in-cell code.
//!
//! Paper Section IV: a Moment-Implicit PIC with a particle solver (motion
//! of charged particles + moment gathering) and a field solver.  xPic is
//! the workhorse of the evaluation — it appears in Figs. 6, 7, 8 and 9
//! with three experiment setups (Tables II and III):
//!
//! * **DEEP-ER I/O** (Fig. 7): 8 GB per checkpoint, 11 checkpoints.
//! * **QPACE3 I/O** (Fig. 6): 10 GB per node, 2 checkpoints, RAM-disk
//!   node-local storage.
//! * **SCR resiliency** (Fig. 8): 32 GB processed per node, 8 GB per CP,
//!   100 iterations, checkpoint every 10.
//! * **NAM resiliency** (Fig. 9): 20 GB per node processed, 2 GB per CP,
//!   10 checkpoints (2 GB = the NAM HMC capacity, not a coincidence).
//!
//! The real compute path is `xpic_step.hlo.txt`: field gather + Boris
//! push (Pallas) + moment deposit + damped field update.

use super::AppProfile;

/// Fig. 8 setup (Table III, "xPic SCR"): calibrated so that ~9 partner
/// checkpoints of 8 GB cost ~8% of the 100-iteration runtime, matching
/// the paper's measured average overhead.
pub fn profile_deep_er() -> AppProfile {
    AppProfile {
        name: "xpic-deep-er",
        flops_per_iter_per_node: 1.8e12,
        cpu_efficiency: 0.08, // PIC gather/scatter limits achieved flops
        ckpt_bytes_per_node: 8e9,
        halo_bytes: 96e6, // moment + field boundary exchange
        io_tasks_per_node: 24,
        io_records_per_task: 32,
        artifact: "xpic_step",
    }
}

/// Fig. 6 setup (Table II, "xPic on QPACE3"): weak scaling, 10 GB/node.
pub fn profile_qpace3() -> AppProfile {
    AppProfile {
        name: "xpic-qpace3",
        flops_per_iter_per_node: 2.4e12,
        cpu_efficiency: 0.06, // KNL without MCDRAM blocking tuned
        ckpt_bytes_per_node: 10e9,
        halo_bytes: 128e6,
        io_tasks_per_node: 64,
        io_records_per_task: 32,
        artifact: "xpic_step",
    }
}

/// Fig. 9 setup (Table III, "xPic NAM"): 2 GB checkpoints sized to the
/// NAM HMC, 10 checkpoints over the run.
pub fn profile_nam() -> AppProfile {
    AppProfile {
        name: "xpic-nam",
        flops_per_iter_per_node: 1.8e12,
        cpu_efficiency: 0.08,
        ckpt_bytes_per_node: 2e9,
        halo_bytes: 96e6,
        io_tasks_per_node: 24,
        io_records_per_task: 32,
        artifact: "xpic_step",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_iii_payloads() {
        assert_eq!(profile_deep_er().ckpt_bytes_per_node, 8e9);
        assert_eq!(profile_nam().ckpt_bytes_per_node, 2e9);
        assert_eq!(profile_qpace3().ckpt_bytes_per_node, 10e9);
    }

    #[test]
    fn nam_payload_fits_hmc() {
        assert!(profile_nam().ckpt_bytes_per_node <= crate::nam::HMC_CAPACITY);
    }
}

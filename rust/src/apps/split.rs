//! Cluster-Booster application splitting — the architecture's core idea.
//!
//! Paper Section II-A: the Booster is a *stand-alone* cluster of
//! autonomous accelerators, so applications may freely divide themselves
//! over both sides ("full freedom to decide how they distribute their
//! codes"), with ParaStation MPI's spawn-offload carrying the
//! inter-module traffic.  The benefits are quantified in the companion
//! paper (reference [4], Kreuzer et al., IPDPSW 2018) with xPic: the
//! regular, vectorizable **particle solver** suits the KNL Booster; the
//! communication-heavy, latency-sensitive **field solver** suits the
//! Haswell Cluster.
//!
//! This module reproduces that division of labour: one xPic-like
//! iteration = particle phase + moment transfer + field phase + field
//! broadcast, placeable Cluster-only, Booster-only, or Split.  The unit
//! tests pin the headline claim: **Split beats both homogeneous
//! placements** on the DEEP-ER prototype shape, because each phase runs
//! where its achieved flop-rate is highest while the EXTOLL fabric keeps
//! the coupling cheap.

use crate::psmpi::{comm_spawn, Comm};
use crate::sim::{FlowId, SimTime};
use crate::system::{Machine, NodeKind};

/// Where the two solver halves run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    ClusterOnly,
    BoosterOnly,
    Split,
}

impl Placement {
    pub const ALL: [Placement; 3] =
        [Placement::ClusterOnly, Placement::BoosterOnly, Placement::Split];

    pub fn name(&self) -> &'static str {
        match self {
            Placement::ClusterOnly => "Cluster only",
            Placement::BoosterOnly => "Booster only",
            Placement::Split => "Cluster+Booster split",
        }
    }
}

/// Achieved fraction of peak per (phase, node kind) — the co-design
/// numbers behind the split: the particle pusher vectorizes beautifully
/// on KNL's AVX-512 + MCDRAM but starves on Haswell's narrower units;
/// the field solver's irregular halo traffic and short dense kernels run
/// best on the high-clock Haswell cores and suffer on KNL.
pub fn phase_efficiency(kind: NodeKind, particle_phase: bool) -> f64 {
    match (kind, particle_phase) {
        (NodeKind::Booster, true) => 0.14,  // KNL particle solver
        (NodeKind::Cluster, true) => 0.07,  // Haswell particle solver
        (NodeKind::Booster, false) => 0.03, // KNL field solver
        (NodeKind::Cluster, false) => 0.12, // Haswell field solver
    }
}

/// One split-mode workload description.
#[derive(Debug, Clone, Copy)]
pub struct SplitJob {
    /// Total particle-solver work per iteration, flops.
    pub particle_flops: f64,
    /// Total field-solver work per iteration, flops.
    pub field_flops: f64,
    /// Moments shipped particle-side -> field-side per iteration, bytes.
    pub moments_bytes: f64,
    /// Fields shipped back per iteration, bytes.
    pub field_bytes: f64,
    pub iterations: usize,
}

impl SplitJob {
    /// The xPic shape used by the companion paper's evaluation: particle
    /// work dominates ~4:1, coupling volume is grid-sized.
    pub fn xpic_like(iterations: usize) -> Self {
        Self {
            particle_flops: 24e12,
            field_flops: 6e12,
            moments_bytes: 1.5e9,
            field_bytes: 1.0e9,
            iterations,
        }
    }
}

/// Outcome of a placement run.
#[derive(Debug, Clone, Copy)]
pub struct SplitStats {
    pub total_time: SimTime,
    pub particle_time: SimTime,
    pub field_time: SimTime,
    pub coupling_time: SimTime,
    pub spawn_time: SimTime,
}

fn phase(
    m: &mut Machine,
    nodes: &[usize],
    total_flops: f64,
    particle_phase: bool,
) -> SimTime {
    let t0 = m.sim.now();
    let per_node = total_flops / nodes.len() as f64;
    let flows: Vec<FlowId> = nodes
        .iter()
        .map(|&n| {
            let eff = phase_efficiency(m.nodes[n].kind, particle_phase);
            m.compute(n, per_node, eff)
        })
        .collect();
    m.sim.wait_all(&flows) - t0
}

/// Pairwise exchange between the two partitions (or a ring within one
/// partition when both phases share nodes).
fn couple(m: &mut Machine, from: &[usize], to: &[usize], bytes_total: f64) -> SimTime {
    let t0 = m.sim.now();
    if from == to {
        // Same partition: moments stay in memory; only a local barrier.
        return Comm::of(from.to_vec()).barrier(m) - t0;
    }
    let per_pair = bytes_total / from.len() as f64;
    let flows: Vec<FlowId> = from
        .iter()
        .enumerate()
        .map(|(i, &src)| {
            let dst = to[i % to.len()];
            let (s, d) = (m.nodes[src].ep, m.nodes[dst].ep);
            m.fabric.put(&mut m.sim, s, d, per_pair)
        })
        .collect();
    m.sim.wait_all(&flows) - t0
}

/// Run `job` under `placement` on the machine's full partitions.
pub fn run_split(m: &mut Machine, job: &SplitJob, placement: Placement) -> SplitStats {
    let cluster = m.nodes_of(NodeKind::Cluster);
    let booster = m.nodes_of(NodeKind::Booster);
    assert!(!cluster.is_empty());
    let (particle_nodes, field_nodes, spawn_target): (Vec<usize>, Vec<usize>, Option<Vec<usize>>) =
        match placement {
            Placement::ClusterOnly => (cluster.clone(), cluster.clone(), None),
            Placement::BoosterOnly => {
                assert!(!booster.is_empty(), "no booster partition in this preset");
                (booster.clone(), booster.clone(), Some(booster.clone()))
            }
            Placement::Split => {
                assert!(!booster.is_empty(), "no booster partition in this preset");
                (booster.clone(), cluster.clone(), Some(booster.clone()))
            }
        };

    let mut stats = SplitStats {
        total_time: 0.0,
        particle_time: 0.0,
        field_time: 0.0,
        coupling_time: 0.0,
        spawn_time: 0.0,
    };
    let t_start = m.sim.now();

    // MPI_Comm_spawn of the Booster-side group (paper Section III-A).
    if let Some(target) = spawn_target {
        let t0 = m.sim.now();
        let _group = comm_spawn(m, target);
        stats.spawn_time = m.sim.now() - t0;
    }

    for _ in 0..job.iterations {
        stats.particle_time += phase(m, &particle_nodes, job.particle_flops, true);
        stats.coupling_time += couple(m, &particle_nodes, &field_nodes, job.moments_bytes);
        stats.field_time += phase(m, &field_nodes, job.field_flops, false);
        stats.coupling_time += couple(m, &field_nodes, &particle_nodes, job.field_bytes);
    }
    stats.total_time = m.sim.now() - t_start;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::presets;

    fn run(placement: Placement) -> SplitStats {
        let mut m = Machine::build(presets::deep_er());
        run_split(&mut m, &SplitJob::xpic_like(10), placement)
    }

    #[test]
    fn split_beats_both_homogeneous_placements() {
        let cluster = run(Placement::ClusterOnly);
        let booster = run(Placement::BoosterOnly);
        let split = run(Placement::Split);
        assert!(
            split.total_time < cluster.total_time,
            "split {} !< cluster {}",
            split.total_time,
            cluster.total_time
        );
        assert!(
            split.total_time < booster.total_time,
            "split {} !< booster {}",
            split.total_time,
            booster.total_time
        );
    }

    #[test]
    fn particle_phase_faster_on_booster() {
        let cluster = run(Placement::ClusterOnly);
        let split = run(Placement::Split);
        assert!(split.particle_time < cluster.particle_time);
    }

    #[test]
    fn field_phase_faster_on_cluster() {
        let booster = run(Placement::BoosterOnly);
        let split = run(Placement::Split);
        assert!(split.field_time < booster.field_time);
    }

    #[test]
    fn coupling_cost_only_in_split_mode() {
        let cluster = run(Placement::ClusterOnly);
        let split = run(Placement::Split);
        // Homogeneous placements only pay barriers; split moves real bytes.
        assert!(split.coupling_time > cluster.coupling_time);
        // ...but the fabric keeps it a small fraction of the win.
        assert!(split.coupling_time < 0.3 * split.total_time);
    }

    #[test]
    fn spawn_paid_once_not_per_iteration() {
        let mut m = Machine::build(presets::deep_er());
        let s10 = run_split(&mut m, &SplitJob::xpic_like(10), Placement::Split);
        let mut m2 = Machine::build(presets::deep_er());
        let s20 = run_split(&mut m2, &SplitJob::xpic_like(20), Placement::Split);
        assert!((s10.spawn_time - s20.spawn_time).abs() < 1e-9);
    }

    #[test]
    fn efficiency_table_encodes_the_codesign_story() {
        assert!(
            phase_efficiency(NodeKind::Booster, true)
                > phase_efficiency(NodeKind::Cluster, true)
        );
        assert!(
            phase_efficiency(NodeKind::Cluster, false)
                > phase_efficiency(NodeKind::Booster, false)
        );
    }
}

//! The iteration driver: compute + exchange + checkpoint + failure loop.
//!
//! This is the engine behind the Fig. 4 and Fig. 8 experiments: an
//! application executes `iterations` bulk-synchronous steps on a node set;
//! every `cp_interval` iterations SCR takes a checkpoint; a failure plan
//! may kill a node at an iteration boundary, triggering PMD detection and
//! an SCR restart that rolls the run back to the last checkpoint (or to
//! iteration 0 if no usable checkpoint exists — the unprotected baseline).
//!
//! [`run_iterations_multilevel`] is the overlapped variant: checkpoints go
//! through [`MultiLevelScr`], whose L1→L2 promotion can run as a
//! background flush *during* the following compute iterations
//! (`async_flush`), and restarts roll back to the iteration of the level
//! that actually served them (the deepest *settled* one).

use super::AppProfile;
use crate::psmpi::{Comm, Pmd};
use crate::scr::multilevel::MultiLevelScr;
use crate::scr::Scr;
use crate::sim::{FlowId, Op, SimTime};
use crate::system::failure::FailurePlan;
use crate::system::Machine;

/// Configuration of one driver run.
#[derive(Debug, Clone)]
pub struct IterationJob {
    pub profile: AppProfile,
    pub iterations: usize,
    /// Checkpoint every `cp_interval` iterations; 0 disables checkpoints.
    pub cp_interval: usize,
    pub failures: FailurePlan,
}

/// Aggregated timing of a run.
#[derive(Debug, Clone, Copy, Default)]
pub struct RunStats {
    pub total_time: SimTime,
    pub compute_time: SimTime,
    pub exchange_time: SimTime,
    pub ckpt_time: SimTime,
    pub restart_time: SimTime,
    /// Checkpoint work that ran in the background of compute phases
    /// (async flush promotions); zero on the blocking paths.
    pub overlap_time: SimTime,
    /// Wall time the application was stalled on checkpointing: the
    /// blocking checkpoint cost plus any flush back-pressure waits.
    pub blocked_time: SimTime,
    /// Iterations executed, incl. re-executed ones after rollbacks.
    pub iterations_run: usize,
    pub checkpoints_taken: usize,
    pub failures_hit: usize,
}

impl RunStats {
    /// Fractional overhead of checkpointing vs compute+exchange.
    pub fn ckpt_overhead(&self) -> f64 {
        self.ckpt_time / (self.compute_time + self.exchange_time).max(1e-12)
    }
}

/// Execute the iteration loop.  `scr` may be None (no checkpointing at
/// all: the "w/o CP" bars of Fig. 8).
pub fn run_iterations(
    m: &mut Machine,
    nodes: &[usize],
    job: &IterationJob,
    mut scr: Option<&mut Scr>,
) -> RunStats {
    assert!(!nodes.is_empty());
    let mut stats = RunStats::default();
    let t_start = m.sim.now();
    let comm = Comm::of(nodes.to_vec());
    let mut pmd = Pmd::new();

    let mut iter = 0usize;
    let mut last_cp_iter = 0usize;
    let mut pending_failure: Option<usize> = None; // node to fail at iter k
    let mut last_check_time = m.sim.now();

    while iter < job.iterations {
        // Failure injection at this iteration boundary?  Both plan kinds
        // are honoured: iteration-keyed (the paper's targeted errors) and
        // time-keyed (exponential-MTBF schedules) — time-keyed failures
        // are observed at the boundary following their timestamp, which
        // is when application-level checkpointing can react.
        if let Some(f) = job.failures.failure_at_iteration(iter) {
            if pending_failure.is_none() && stats.failures_hit < job.failures.at_iterations.len()
            {
                pending_failure = Some(nodes[f.node % nodes.len()]);
            }
        }
        let now = m.sim.now();
        if pending_failure.is_none() {
            if let Some(f) = job.failures.failures_between(last_check_time, now).first() {
                pending_failure = Some(nodes[f.node % nodes.len()]);
            }
        }
        last_check_time = now;
        if let Some(victim) = pending_failure.take() {
            stats.failures_hit += 1;
            m.kill_node(victim);
            let t0 = m.sim.now();
            pmd.detect_and_isolate(m, nodes);
            m.revive_node(victim);
            pmd.reinstate(victim);
            match scr.as_deref_mut() {
                Some(scr_ref) => {
                    let failed = Some(victim);
                    match scr_ref.restart(m, nodes, failed) {
                        Ok(_) => {
                            // Roll back to the last checkpointed iteration.
                            iter = last_cp_iter;
                        }
                        Err(_) => {
                            // No usable checkpoint: full restart.
                            iter = 0;
                            last_cp_iter = 0;
                        }
                    }
                }
                None => {
                    // Unprotected: lose everything, start over.
                    iter = 0;
                    last_cp_iter = 0;
                }
            }
            stats.restart_time += m.sim.now() - t0;
            continue;
        }

        // Compute phase (all nodes in parallel).
        let t0 = m.sim.now();
        let compute = compute_op(m, nodes, &job.profile);
        m.sim.wait_op(&compute);
        stats.compute_time += m.sim.now() - t0;

        // Halo/moment exchange.
        if job.profile.halo_bytes > 0.0 && nodes.len() > 1 {
            let t1 = m.sim.now();
            comm.ring_exchange(m, job.profile.halo_bytes);
            stats.exchange_time += m.sim.now() - t1;
        }

        iter += 1;
        stats.iterations_run += 1;

        // Checkpoint at interval boundaries.
        if job.cp_interval > 0 && iter % job.cp_interval == 0 && iter < job.iterations {
            if let Some(scr_ref) = scr.as_deref_mut() {
                let t2 = m.sim.now();
                scr_ref
                    .checkpoint(m, nodes, job.profile.ckpt_bytes_per_node)
                    .expect("checkpoint failed");
                stats.ckpt_time += m.sim.now() - t2;
                stats.checkpoints_taken += 1;
                last_cp_iter = iter;
            }
        }
    }

    stats.total_time = m.sim.now() - t_start;
    stats.blocked_time = stats.ckpt_time;
    stats
}

/// Issue one bulk-synchronous compute step on every node as a single
/// [`Op`] (the unit the async flush overlaps with).
fn compute_op(m: &mut Machine, nodes: &[usize], profile: &AppProfile) -> Op {
    let flows: Vec<FlowId> = nodes
        .iter()
        .map(|&n| m.compute(n, profile.flops_per_iter_per_node, profile.cpu_efficiency))
        .collect();
    Op::new(flows)
}

/// Execute the iteration loop through the **multi-level checkpointer**,
/// overlapping compute with in-flight L1→L2 flushes when `ml` has
/// `async_flush` enabled.
///
/// Differences from [`run_iterations`]:
/// * checkpoints go through [`MultiLevelScr::checkpoint_at`], so only the
///   blocked portion of a promotion stalls the loop — the rest settles in
///   the background while later iterations compute;
/// * a restart rolls back to the iteration of the level that actually
///   served it (the deepest *settled* one when a failure lands while a
///   flush is in flight);
/// * `stats.overlap_time` / `stats.blocked_time` report how much flush
///   work was hidden behind compute vs how long the application stalled.
pub fn run_iterations_multilevel(
    m: &mut Machine,
    nodes: &[usize],
    job: &IterationJob,
    ml: &mut MultiLevelScr,
) -> RunStats {
    assert!(!nodes.is_empty());
    assert!(job.cp_interval > 0, "multilevel driver needs a checkpoint cadence");
    let mut stats = RunStats::default();
    let t_start = m.sim.now();
    let comm = Comm::of(nodes.to_vec());
    let mut pmd = Pmd::new();

    let mut iter = 0usize;
    let mut pending_failure: Option<usize> = None;
    let mut last_check_time = m.sim.now();

    while iter < job.iterations {
        if let Some(f) = job.failures.failure_at_iteration(iter) {
            if pending_failure.is_none() && stats.failures_hit < job.failures.at_iterations.len()
            {
                pending_failure = Some(nodes[f.node % nodes.len()]);
            }
        }
        let now = m.sim.now();
        if pending_failure.is_none() {
            if let Some(f) = job.failures.failures_between(last_check_time, now).first() {
                pending_failure = Some(nodes[f.node % nodes.len()]);
            }
        }
        last_check_time = now;
        if let Some(victim) = pending_failure.take() {
            stats.failures_hit += 1;
            // Credit a promotion that settled before the failure; one
            // whose flows are still moving when the node dies is lost
            // (restart_detailed aborts it, never polls it).
            ml.poll_flush(m);
            m.kill_node(victim);
            let t0 = m.sim.now();
            pmd.detect_and_isolate(m, nodes);
            m.revive_node(victim);
            pmd.reinstate(victim);
            match ml.restart_detailed(m, nodes, Some(victim)) {
                // Roll back to the iteration of the level that served the
                // restart — the deepest *settled* checkpoint.
                Ok(outcome) => iter = outcome.iter,
                // No level covers a lost node yet: full restart.
                Err(_) => iter = 0,
            }
            stats.restart_time += m.sim.now() - t0;
            continue;
        }

        // Compute phase (all nodes in parallel); any in-flight flush
        // trickles through the same virtual time.
        let t0 = m.sim.now();
        let compute = compute_op(m, nodes, &job.profile);
        m.sim.wait_op(&compute);
        stats.compute_time += m.sim.now() - t0;

        if job.profile.halo_bytes > 0.0 && nodes.len() > 1 {
            let t1 = m.sim.now();
            comm.ring_exchange(m, job.profile.halo_bytes);
            stats.exchange_time += m.sim.now() - t1;
        }

        iter += 1;
        stats.iterations_run += 1;

        if iter % job.cp_interval == 0 && iter < job.iterations {
            let blocked = ml
                .checkpoint_at(m, nodes, job.profile.ckpt_bytes_per_node, iter)
                .expect("multilevel checkpoint failed");
            stats.ckpt_time += blocked;
            stats.checkpoints_taken += 1;
        }
    }

    // Job-end barrier: the tail of the background work is blocked time.
    let t_drain = m.sim.now();
    ml.drain(m);
    let drain_blocked = m.sim.now() - t_drain;

    stats.total_time = m.sim.now() - t_start;
    stats.overlap_time = ml.stats.flush_overlap;
    stats.blocked_time = stats.ckpt_time + drain_blocked;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::xpic;
    use crate::scr::multilevel::MultiLevelConfig;
    use crate::scr::Strategy;
    use crate::system::presets;

    fn machine() -> Machine {
        Machine::build(presets::deep_er())
    }

    fn fig8_job(cp: bool, fail: bool) -> IterationJob {
        IterationJob {
            profile: xpic::profile_deep_er(),
            iterations: 100,
            cp_interval: if cp { 10 } else { 0 },
            failures: if fail {
                FailurePlan::one_at_iteration(3, 60)
            } else {
                FailurePlan::none()
            },
        }
    }

    #[test]
    fn clean_run_counts() {
        let mut m = machine();
        let nodes = m.nodes_of(crate::system::NodeKind::Cluster);
        let mut scr = Scr::new(Strategy::Partner);
        let stats = run_iterations(&mut m, &nodes, &fig8_job(true, false), Some(&mut scr));
        assert_eq!(stats.iterations_run, 100);
        assert_eq!(stats.checkpoints_taken, 9); // every 10, skipping the last
        assert_eq!(stats.failures_hit, 0);
    }

    #[test]
    fn fig8_overhead_band() {
        // Paper: writing checkpoints costs ~8% on average.
        let mut m1 = machine();
        let nodes = m1.nodes_of(crate::system::NodeKind::Cluster);
        let t_plain = run_iterations(&mut m1, &nodes, &fig8_job(false, false), None).total_time;
        let mut m2 = machine();
        let mut scr = Scr::new(Strategy::Partner);
        let t_cp =
            run_iterations(&mut m2, &nodes, &fig8_job(true, false), Some(&mut scr)).total_time;
        let overhead = t_cp / t_plain - 1.0;
        assert!((0.02..=0.20).contains(&overhead), "overhead={overhead:.3}");
    }

    #[test]
    fn fig8_failure_savings_band() {
        // Paper: with an error at iteration 60, SCR saves ~23% vs rerun.
        let nodes: Vec<usize> = (0..16).collect();
        let mut m1 = machine();
        let t_unprot =
            run_iterations(&mut m1, &nodes, &fig8_job(false, true), None).total_time;
        let mut m2 = machine();
        let mut scr = Scr::new(Strategy::Partner);
        let t_prot =
            run_iterations(&mut m2, &nodes, &fig8_job(true, true), Some(&mut scr)).total_time;
        let saving = 1.0 - t_prot / t_unprot;
        assert!((0.10..=0.40).contains(&saving), "saving={saving:.3}");
    }

    #[test]
    fn unprotected_failure_reruns_everything() {
        let mut m = machine();
        let nodes: Vec<usize> = (0..4).collect();
        let mut job = fig8_job(false, true);
        job.iterations = 20;
        job.failures = FailurePlan::one_at_iteration(0, 10);
        let stats = run_iterations(&mut m, &nodes, &job, None);
        assert_eq!(stats.failures_hit, 1);
        assert_eq!(stats.iterations_run, 30); // 10 lost + 20 clean
    }

    #[test]
    fn time_keyed_failures_from_mtbf_schedule() {
        // An exponential-MTBF plan drives rollbacks through the driver.
        let mut m = machine();
        let nodes: Vec<usize> = (0..8).collect();
        let mut job = fig8_job(true, false);
        job.iterations = 30;
        job.cp_interval = 5;
        // MTBF chosen so a handful of failures land inside the run.
        job.failures = crate::system::failure::FailurePlan::exponential(
            nodes.len(),
            20_000.0, // per-node MTBF (s) -> system rate ~1/2500 s
            5_000.0,
            42,
        );
        let n_failures = job.failures.at_times.len();
        let mut scr = Scr::new(Strategy::Buddy);
        let stats = run_iterations(&mut m, &nodes, &job, Some(&mut scr));
        assert!(stats.iterations_run >= 30);
        assert!(stats.failures_hit <= n_failures);
        if stats.failures_hit > 0 {
            assert!(stats.restart_time > 0.0);
        }
    }

    fn ml_run(async_flush: bool, fail: bool) -> RunStats {
        let mut m = machine();
        let nodes = m.nodes_of(crate::system::NodeKind::Cluster);
        let job = fig8_job(true, fail);
        let cfg = MultiLevelConfig {
            l1_every: 1,
            l2_every: 2,
            l3_every: 2,
            async_flush,
            ..MultiLevelConfig::default()
        };
        let mut ml = MultiLevelScr::new(cfg);
        run_iterations_multilevel(&mut m, &nodes, &job, &mut ml)
    }

    #[test]
    fn multilevel_async_flush_cuts_blocked_time() {
        let blocking = ml_run(false, false);
        let overlapped = ml_run(true, false);
        assert_eq!(blocking.iterations_run, 100);
        assert_eq!(overlapped.iterations_run, 100);
        assert_eq!(blocking.checkpoints_taken, 9);
        assert_eq!(overlapped.checkpoints_taken, 9);
        assert_eq!(blocking.overlap_time, 0.0, "blocking path must not overlap");
        assert!(overlapped.overlap_time > 0.0);
        assert!(
            overlapped.blocked_time < blocking.blocked_time,
            "async {} !< blocking {}",
            overlapped.blocked_time,
            blocking.blocked_time
        );
        assert!(
            overlapped.total_time < blocking.total_time,
            "async {} !< blocking {}",
            overlapped.total_time,
            blocking.total_time
        );
    }

    #[test]
    fn multilevel_async_run_is_deterministic() {
        let a = ml_run(true, true);
        let b = ml_run(true, true);
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.blocked_time, b.blocked_time);
        assert_eq!(a.overlap_time, b.overlap_time);
        assert_eq!(a.iterations_run, b.iterations_run);
        assert_eq!(a.failures_hit, b.failures_hit);
    }

    #[test]
    fn multilevel_failure_rolls_back_and_completes() {
        let stats = ml_run(true, true);
        assert_eq!(stats.failures_hit, 1);
        assert!(stats.iterations_run > 100, "rollback must re-run iterations");
        assert!(stats.restart_time > 0.0);
    }

    #[test]
    fn protected_failure_rolls_back_to_last_cp() {
        let mut m = machine();
        let nodes: Vec<usize> = (0..4).collect();
        let mut job = fig8_job(true, true);
        job.iterations = 20;
        job.cp_interval = 5;
        job.failures = FailurePlan::one_at_iteration(1, 12);
        let mut scr = Scr::new(Strategy::Buddy);
        let stats = run_iterations(&mut m, &nodes, &job, Some(&mut scr));
        assert_eq!(stats.failures_hit, 1);
        // 12 before failure + (12-10)=2 re-run + 8 remaining = 22.
        assert_eq!(stats.iterations_run, 22);
        assert!(stats.restart_time > 0.0);
    }
}

//! The iteration driver: compute + exchange + checkpoint + failure loop.
//!
//! This is the engine behind the Fig. 4 and Fig. 8 experiments: an
//! application executes `iterations` bulk-synchronous steps on a node set;
//! every `cp_interval` iterations SCR takes a checkpoint; a failure plan
//! may kill a node at an iteration boundary, triggering PMD detection and
//! an SCR restart that rolls the run back to the last checkpoint (or to
//! iteration 0 if no usable checkpoint exists — the unprotected baseline).
//!
//! Since the fleet scheduler ([`crate::sched`]) arrived, the loop body
//! lives in a **resumable per-job state machine**, [`JobExec`]: every
//! phase (compute, halo exchange, checkpoint) is issued as a non-blocking
//! [`Op`] and the machine pauses whenever its front op is still in
//! flight.  The classic blocking entry points below are thin runners that
//! wait out each front op immediately, which reproduces the historical
//! blocking semantics flow-for-flow; the scheduler instead interleaves
//! many `JobExec`s on one clock so their I/O genuinely contends.
//!
//! [`run_iterations_multilevel`] is the overlapped variant: checkpoints go
//! through [`MultiLevelScr`], whose L1→L2 promotion can run as a
//! background flush *during* the following compute iterations
//! (`async_flush`), and restarts roll back to the iteration of the level
//! that actually served them (the deepest *settled* one).

use super::AppProfile;
use crate::psmpi::{Comm, Pmd};
use crate::scr::multilevel::MultiLevelScr;
use crate::scr::{PendingCkpt, Scr};
use crate::sim::{FlowId, Op, SimTime};
use crate::system::failure::FailurePlan;
use crate::system::Machine;

/// Configuration of one driver run.
#[derive(Debug, Clone)]
pub struct IterationJob {
    pub profile: AppProfile,
    pub iterations: usize,
    /// Checkpoint every `cp_interval` iterations; 0 disables checkpoints.
    pub cp_interval: usize,
    pub failures: FailurePlan,
}

/// Aggregated timing of a run.
#[derive(Debug, Clone, Copy, Default)]
pub struct RunStats {
    pub total_time: SimTime,
    pub compute_time: SimTime,
    pub exchange_time: SimTime,
    pub ckpt_time: SimTime,
    pub restart_time: SimTime,
    /// Checkpoint work that ran in the background of compute phases
    /// (async flush promotions); zero on the blocking paths.
    pub overlap_time: SimTime,
    /// Wall time the application was stalled on checkpointing: the
    /// blocking checkpoint cost plus any flush back-pressure waits.
    pub blocked_time: SimTime,
    /// Iterations executed, incl. re-executed ones after rollbacks.
    pub iterations_run: usize,
    pub checkpoints_taken: usize,
    pub failures_hit: usize,
    /// Flows of doomed phase attempts that were cancelled
    /// (settle-then-retired) at failure/unbind time instead of draining
    /// unobserved; zero on clean runs.
    pub flows_cancelled: usize,
}

impl RunStats {
    /// Fractional overhead of checkpointing vs compute+exchange.
    pub fn ckpt_overhead(&self) -> f64 {
        self.ckpt_time / (self.compute_time + self.exchange_time).max(1e-12)
    }
}

/// Borrowed view of the checkpoint machinery a job runs with — how the
/// one [`JobExec`] state machine serves the "w/o CP" baseline, the five
/// single-level SCR strategies and the multi-level checkpointer alike.
/// The fleet scheduler owns the backing `Scr`/`MultiLevelScr` per job and
/// re-borrows this view on every advance.
#[derive(Debug)]
pub enum CkptBackendRef<'a> {
    /// No checkpointing (the unprotected "w/o CP" bars of Fig. 8).
    None,
    /// One single-level SCR strategy; checkpoints are issued via
    /// [`Scr::checkpoint_begin`] and committed when their op settles, so
    /// the fleet scheduler never blocks the shared clock on them.
    Scr(&'a mut Scr),
    /// The multi-level checkpointer.  Its `checkpoint_at` keeps its own
    /// (bounded) blocking discipline — L1 cost plus any flush
    /// back-pressure — exactly like the historical driver.
    Multi(&'a mut MultiLevelScr),
}

/// What the job is currently waiting on.
#[derive(Debug)]
enum Phase {
    /// At an iteration boundary: nothing in flight.
    Ready,
    /// Bulk-synchronous compute step on every node.
    Compute(Op),
    /// Halo/moment ring exchange.
    Exchange(Op),
    /// A single-level checkpoint in flight (committed when it settles).
    Ckpt(PendingCkpt),
    /// All iterations executed (and, for multilevel, flushes drained).
    Done,
}

/// Resumable per-job execution state: one bulk-synchronous application
/// run, advanced phase by phase.  Between [`JobExec::bind`] (nodes
/// attached) and completion, callers repeatedly wait out
/// [`JobExec::front_op`] and call [`JobExec::advance`]; the solo runners
/// below do this back-to-back on a private machine, the fleet scheduler
/// round-robins it across many jobs on one shared machine.
#[derive(Debug)]
pub struct JobExec {
    job: IterationJob,
    nodes: Vec<usize>,
    comm: Option<Comm>,
    pmd: Pmd,
    phase: Phase,
    iter: usize,
    last_cp_iter: usize,
    /// Boundary-failure victims queued and not yet processed.  A queue,
    /// not an `Option`: two failures scheduled at the same iteration both
    /// land (one per boundary check — the caller re-enters after each
    /// rollback), instead of the second being silently dropped.
    pending_failures: Vec<usize>,
    last_check_time: SimTime,
    bound_at: SimTime,
    phase_t0: SimTime,
    pub stats: RunStats,
}

impl JobExec {
    pub fn new(job: IterationJob) -> Self {
        Self {
            job,
            nodes: Vec::new(),
            comm: None,
            pmd: Pmd::new(),
            phase: Phase::Ready,
            iter: 0,
            last_cp_iter: 0,
            pending_failures: Vec::new(),
            last_check_time: 0.0,
            bound_at: 0.0,
            phase_t0: 0.0,
            stats: RunStats::default(),
        }
    }

    /// Attach a node set (initial dispatch, or re-dispatch after a
    /// failure requeue).  Execution resumes from the current — possibly
    /// rolled-back — iteration.
    pub fn bind(&mut self, m: &Machine, nodes: Vec<usize>) {
        assert!(!nodes.is_empty());
        assert!(self.nodes.is_empty(), "bind while already bound");
        self.comm = Some(Comm::of(nodes.clone()));
        self.nodes = nodes;
        self.bound_at = m.sim.now();
        self.last_check_time = m.sim.now();
    }

    /// Detach from the node set (fleet requeue): banks the active-segment
    /// wall time and **cancels** whatever phase op was still in flight —
    /// the rolled-back attempt's flows are settle-then-retired so they
    /// stop contending the shared machine immediately, instead of
    /// draining unobserved to a phantom finish (the documented §11.4
    /// wart, fixed).  Returns the released nodes.
    pub fn unbind(&mut self, m: &mut Machine) -> Vec<usize> {
        assert!(!self.is_done(), "unbind after completion");
        assert!(!self.nodes.is_empty(), "unbind while not bound");
        self.trace_close_phase(m);
        if let Some(op) = self.front_op() {
            self.stats.flows_cancelled += m.sim.cancel_op(&op);
        }
        self.stats.total_time += m.sim.now() - self.bound_at;
        self.phase = Phase::Ready;
        self.comm = None;
        std::mem::take(&mut self.nodes)
    }

    /// Iteration the job will (re)start from.
    pub fn current_iter(&self) -> usize {
        self.iter
    }

    /// Target iteration count.
    pub fn iterations(&self) -> usize {
        self.job.iterations
    }

    pub fn is_done(&self) -> bool {
        matches!(self.phase, Phase::Done)
    }

    /// The op the job is currently blocked on (None at a boundary or when
    /// done).  [`JobExec::advance`] must only run once this op polls
    /// complete.
    pub fn front_op(&self) -> Option<Op> {
        match &self.phase {
            Phase::Compute(op) | Phase::Exchange(op) => Some(op.clone()),
            Phase::Ckpt(pending) => Some(pending.op.clone()),
            Phase::Ready | Phase::Done => None,
        }
    }

    /// Drive the state machine as far as it can go without waiting:
    /// settle the completed front op, account its stats, and issue phases
    /// until a new front op is still in flight or the job finishes.
    pub fn advance(&mut self, m: &mut Machine, backend: &mut CkptBackendRef) {
        assert!(!self.nodes.is_empty(), "advance on an unbound job");
        loop {
            match std::mem::replace(&mut self.phase, Phase::Ready) {
                Phase::Done => {
                    self.phase = Phase::Done;
                    return;
                }
                Phase::Ready => {
                    if self.iter >= self.job.iterations {
                        self.finish(m, backend);
                        return;
                    }
                    // Failure injection at this iteration boundary?  Both
                    // plan kinds are honoured: iteration-keyed (the
                    // paper's targeted errors) and time-keyed
                    // (exponential-MTBF schedules) — time-keyed failures
                    // are observed at the boundary following their
                    // timestamp, which is when application-level
                    // checkpointing can react.
                    if self.check_boundary_failure(m, backend) {
                        continue; // re-run the boundary checks post-restart
                    }
                    self.phase_t0 = m.sim.now();
                    let op = compute_op(m, &self.nodes, &self.job.profile);
                    if let Some(tr) = m.sim.trace() {
                        tr.begin(
                            self.phase_t0,
                            m.sim.trace_pid(),
                            crate::obs::lane::MAIN,
                            "phase.compute",
                            vec![("iter", self.iter.into())],
                        );
                    }
                    self.phase = Phase::Compute(op);
                }
                Phase::Compute(op) => {
                    let done = m.sim.op_completion(&op).expect("compute op not settled");
                    self.stats.compute_time += done - self.phase_t0;
                    if let Some(tr) = m.sim.trace() {
                        tr.end(done, m.sim.trace_pid(), crate::obs::lane::MAIN, "phase.compute");
                    }
                    if self.job.profile.halo_bytes > 0.0 && self.nodes.len() > 1 {
                        self.phase_t0 = m.sim.now();
                        let comm = self.comm.as_ref().expect("bound job has a comm");
                        let op = comm.ring_exchange_op(m, self.job.profile.halo_bytes);
                        if let Some(tr) = m.sim.trace() {
                            tr.begin(
                                self.phase_t0,
                                m.sim.trace_pid(),
                                crate::obs::lane::MAIN,
                                "phase.exchange",
                                vec![("iter", self.iter.into())],
                            );
                        }
                        self.phase = Phase::Exchange(op);
                    } else {
                        self.post_iteration(m, backend);
                    }
                }
                Phase::Exchange(op) => {
                    let done = m.sim.op_completion(&op).expect("exchange op not settled");
                    self.stats.exchange_time += done - self.phase_t0;
                    if let Some(tr) = m.sim.trace() {
                        tr.end(done, m.sim.trace_pid(), crate::obs::lane::MAIN, "phase.exchange");
                    }
                    self.post_iteration(m, backend);
                }
                Phase::Ckpt(pending) => {
                    let report = match backend {
                        CkptBackendRef::Scr(scr) => scr.checkpoint_commit(m, pending),
                        _ => unreachable!("Ckpt phase only exists for single-level SCR"),
                    };
                    if let Some(tr) = m.sim.trace() {
                        tr.end(
                            m.sim.now(),
                            m.sim.trace_pid(),
                            crate::obs::lane::MAIN,
                            "phase.ckpt",
                        );
                    }
                    self.stats.ckpt_time += report.blocked;
                    self.stats.checkpoints_taken += 1;
                    self.last_cp_iter = self.iter;
                    // phase is already Ready
                }
            }
            if let Some(op) = self.front_op() {
                if !m.sim.poll_op(&op) {
                    return;
                }
            }
        }
    }

    /// Iteration bookkeeping after compute(+exchange): bump counters and
    /// issue whatever checkpoint level is due.
    fn post_iteration(&mut self, m: &mut Machine, backend: &mut CkptBackendRef) {
        self.iter += 1;
        self.stats.iterations_run += 1;
        let due = self.job.cp_interval > 0
            && self.iter % self.job.cp_interval == 0
            && self.iter < self.job.iterations;
        if !due {
            return;
        }
        let bytes = self.job.profile.ckpt_bytes_per_node;
        match backend {
            CkptBackendRef::None => {}
            CkptBackendRef::Scr(scr) => {
                let pending = scr
                    .checkpoint_begin_iter(m, &self.nodes, bytes, self.iter)
                    .expect("checkpoint failed");
                if let Some(tr) = m.sim.trace() {
                    tr.begin(
                        pending.issued_at(),
                        m.sim.trace_pid(),
                        crate::obs::lane::MAIN,
                        "phase.ckpt",
                        vec![("iter", self.iter.into())],
                    );
                }
                self.phase = Phase::Ckpt(pending);
            }
            CkptBackendRef::Multi(ml) => {
                if let Some(tr) = m.sim.trace() {
                    tr.begin(
                        m.sim.now(),
                        m.sim.trace_pid(),
                        crate::obs::lane::MAIN,
                        "phase.ckpt",
                        vec![("iter", self.iter.into())],
                    );
                }
                let blocked = ml
                    .checkpoint_at(m, &self.nodes, bytes, self.iter)
                    .expect("multilevel checkpoint failed");
                if let Some(tr) = m.sim.trace() {
                    tr.end(m.sim.now(), m.sim.trace_pid(), crate::obs::lane::MAIN, "phase.ckpt");
                }
                self.stats.ckpt_time += blocked;
                self.stats.checkpoints_taken += 1;
                self.last_cp_iter = self.iter;
            }
        }
    }

    /// The boundary failure check of the historical driver: iteration-
    /// keyed failures first, then the earliest time-keyed failure since
    /// the last boundary.  Every failure scheduled for this iteration is
    /// queued (co-scheduled same-iteration failures are no longer
    /// dropped); one victim is processed per check and the caller
    /// re-enters the boundary, so the rest drain on subsequent checks.
    /// Returns true when a failure was handled.
    fn check_boundary_failure(&mut self, m: &mut Machine, backend: &mut CkptBackendRef) -> bool {
        if self.pending_failures.is_empty() {
            for f in self.job.failures.failures_at_iteration(self.iter) {
                // Cap total iteration-keyed hits at the plan length, so a
                // rollback that re-crosses the failure iteration does not
                // re-inject it.
                if self.stats.failures_hit + self.pending_failures.len()
                    < self.job.failures.at_iterations.len()
                {
                    self.pending_failures.push(self.nodes[f.node % self.nodes.len()]);
                }
            }
        }
        let now = m.sim.now();
        if self.pending_failures.is_empty() {
            if let Some(f) = self
                .job
                .failures
                .failures_between(self.last_check_time, now)
                .first()
            {
                self.pending_failures.push(self.nodes[f.node % self.nodes.len()]);
            }
        }
        self.last_check_time = now;
        if self.pending_failures.is_empty() {
            return false;
        }
        let victim = self.pending_failures.remove(0);
        self.handle_failure(m, backend, victim);
        true
    }

    /// Kill `victim`, run PMD detection/isolation, restart from the
    /// backend's best covering checkpoint and roll the iteration counter
    /// back.  Public so the fleet scheduler can inject machine-level
    /// failures into the owning job; any phase op in flight belongs to
    /// the rolled-back attempt and is **cancelled** at kill time — its
    /// flows are settle-then-retired so contenders' rates recover
    /// immediately (no-op for the solo drivers, which only observe
    /// failures at iteration boundaries where no phase is in flight).
    pub fn handle_failure(&mut self, m: &mut Machine, backend: &mut CkptBackendRef, victim: usize) {
        self.stats.failures_hit += 1;
        self.trace_close_phase(m);
        if let Some(tr) = m.sim.trace() {
            tr.instant(
                m.sim.now(),
                m.sim.trace_pid(),
                crate::obs::lane::MAIN,
                "job.failure",
                vec![("victim", victim.into()), ("iter", self.iter.into())],
            );
        }
        if let Some(op) = self.front_op() {
            self.stats.flows_cancelled += m.sim.cancel_op(&op);
        }
        // Credit a promotion that settled before the failure; one whose
        // flows are still moving when the node dies is lost
        // (restart_detailed aborts it — cancelling its flows — and never
        // polls it).
        if let CkptBackendRef::Multi(ml) = backend {
            ml.poll_flush(m);
        }
        m.kill_node(victim);
        let t0 = m.sim.now();
        self.pmd.detect_and_isolate(m, &self.nodes);
        m.revive_node(victim);
        self.pmd.reinstate(victim);
        match backend {
            CkptBackendRef::Multi(ml) => match ml.restart_detailed(m, &self.nodes, Some(victim)) {
                // Roll back to the iteration of the level that served the
                // restart — the deepest *settled and verified* checkpoint.
                Ok(outcome) => {
                    self.iter = outcome.iter;
                    self.last_cp_iter = outcome.iter;
                }
                // No level covers a lost node yet: full restart.
                Err(_) => {
                    self.iter = 0;
                    self.last_cp_iter = 0;
                }
            },
            CkptBackendRef::Scr(scr) => match scr.restart(m, &self.nodes, Some(victim)) {
                // Roll back to the iteration of the record actually
                // served — corruption can push this below the newest
                // checkpoint taken.
                Ok(r) => {
                    self.iter = r.iter;
                    self.last_cp_iter = r.iter;
                }
                // No usable checkpoint: full restart.
                Err(_) => {
                    self.iter = 0;
                    self.last_cp_iter = 0;
                }
            },
            CkptBackendRef::None => {
                // Unprotected: lose everything, start over.
                self.iter = 0;
                self.last_cp_iter = 0;
            }
        }
        self.stats.restart_time += m.sim.now() - t0;
        if !matches!(self.phase, Phase::Done) {
            self.phase = Phase::Ready;
        }
    }

    /// Proactive-migration step 1: take an off-cadence **blocking**
    /// checkpoint at the current iteration, on the current (possibly
    /// degraded) node set, before the scheduler evacuates the job.  Any
    /// phase op in flight belongs to the abandoned attempt and is
    /// cancelled first — its partial iteration is the (small) price of
    /// migrating, versus losing a whole checkpoint interval to the kill
    /// the precursor foreshadows.  No-op for unprotected jobs.
    pub fn migrate_checkpoint(&mut self, m: &mut Machine, backend: &mut CkptBackendRef) {
        assert!(!self.nodes.is_empty(), "migrate_checkpoint on an unbound job");
        if self.is_done() {
            return;
        }
        self.trace_close_phase(m);
        if let Some(op) = self.front_op() {
            self.stats.flows_cancelled += m.sim.cancel_op(&op);
        }
        self.phase = Phase::Ready;
        let bytes = self.job.profile.ckpt_bytes_per_node;
        let taken = match backend {
            CkptBackendRef::None => None,
            CkptBackendRef::Scr(scr) => scr
                .checkpoint_iter(m, &self.nodes, bytes, self.iter)
                .ok()
                .map(|r| r.blocked),
            CkptBackendRef::Multi(ml) => {
                ml.force_checkpoint(m, &self.nodes, bytes, self.iter).ok()
            }
        };
        if let Some(blocked) = taken {
            self.stats.ckpt_time += blocked;
            self.stats.checkpoints_taken += 1;
            self.last_cp_iter = self.iter;
        }
    }

    /// Proactive-migration step 2: after the scheduler rebinds the job on
    /// its new node set, charge the state-transfer cost — a full restart
    /// read of the freshly taken checkpoint.  The iteration counter is
    /// untouched: migration, unlike failure, loses no committed work.
    pub fn migrate_restore(&mut self, m: &mut Machine, backend: &mut CkptBackendRef) {
        assert!(!self.nodes.is_empty(), "migrate_restore on an unbound job");
        let t0 = m.sim.now();
        match backend {
            CkptBackendRef::None => {}
            CkptBackendRef::Scr(scr) => {
                let _ = scr.restart(m, &self.nodes, None);
            }
            CkptBackendRef::Multi(ml) => {
                let _ = ml.restart_detailed(m, &self.nodes, None);
            }
        }
        self.stats.restart_time += m.sim.now() - t0;
    }

    /// Close the open phase slice in the trace, if any.  Cancellation
    /// sites (failure kill, requeue unbind, migration) end the abandoned
    /// phase at the cancel time so Begin/End events stay balanced.
    fn trace_close_phase(&self, m: &Machine) {
        if let Some(tr) = m.sim.trace() {
            let name = match &self.phase {
                Phase::Compute(_) => "phase.compute",
                Phase::Exchange(_) => "phase.exchange",
                Phase::Ckpt(_) => "phase.ckpt",
                Phase::Ready | Phase::Done => return,
            };
            let (now, pid) = (m.sim.now(), m.sim.trace_pid());
            tr.end(now, pid, crate::obs::lane::MAIN, name);
            if matches!(self.phase, Phase::Ckpt(_)) {
                // The pending checkpoint dies with the phase; close its
                // scr-lane slice too (it will never commit).
                tr.end(now, pid, crate::obs::lane::SCR, "scr.ckpt");
            }
        }
    }

    /// Job-end bookkeeping: drain background flushes (multilevel), fill
    /// the derived totals and close the active segment.
    fn finish(&mut self, m: &mut Machine, backend: &mut CkptBackendRef) {
        if let CkptBackendRef::Multi(ml) = backend {
            // Job-end barrier: the tail of the background work is blocked
            // time.
            let t_drain = m.sim.now();
            ml.drain(m);
            let drain_blocked = m.sim.now() - t_drain;
            self.stats.overlap_time = ml.stats.flush_overlap;
            self.stats.blocked_time = self.stats.ckpt_time + drain_blocked;
        } else {
            self.stats.blocked_time = self.stats.ckpt_time;
        }
        self.stats.total_time += m.sim.now() - self.bound_at;
        self.phase = Phase::Done;
    }
}

/// Run a [`JobExec`] to completion solo: wait out every front op
/// immediately, which reproduces the historical blocking drivers
/// flow-for-flow on a private machine.
fn run_to_completion(
    m: &mut Machine,
    nodes: &[usize],
    job: &IterationJob,
    mut backend: CkptBackendRef,
) -> RunStats {
    let mut exec = JobExec::new(job.clone());
    exec.bind(m, nodes.to_vec());
    while !exec.is_done() {
        if let Some(op) = exec.front_op() {
            m.sim.wait_op(&op);
        }
        exec.advance(m, &mut backend);
    }
    exec.stats
}

/// Execute the iteration loop.  `scr` may be None (no checkpointing at
/// all: the "w/o CP" bars of Fig. 8).
pub fn run_iterations(
    m: &mut Machine,
    nodes: &[usize],
    job: &IterationJob,
    scr: Option<&mut Scr>,
) -> RunStats {
    assert!(!nodes.is_empty());
    let backend = match scr {
        Some(s) => CkptBackendRef::Scr(s),
        None => CkptBackendRef::None,
    };
    run_to_completion(m, nodes, job, backend)
}

/// Issue one bulk-synchronous compute step on every node as a single
/// [`Op`] (the unit the async flush overlaps with).
fn compute_op(m: &mut Machine, nodes: &[usize], profile: &AppProfile) -> Op {
    let flows: Vec<FlowId> = nodes
        .iter()
        .map(|&n| m.compute(n, profile.flops_per_iter_per_node, profile.cpu_efficiency))
        .collect();
    Op::new(flows)
}

/// Execute the iteration loop through the **multi-level checkpointer**,
/// overlapping compute with in-flight L1→L2 flushes when `ml` has
/// `async_flush` enabled.
///
/// Differences from [`run_iterations`]:
/// * checkpoints go through [`MultiLevelScr::checkpoint_at`], so only the
///   blocked portion of a promotion stalls the loop — the rest settles in
///   the background while later iterations compute;
/// * a restart rolls back to the iteration of the level that actually
///   served it (the deepest *settled* one when a failure lands while a
///   flush is in flight);
/// * `stats.overlap_time` / `stats.blocked_time` report how much flush
///   work was hidden behind compute vs how long the application stalled.
pub fn run_iterations_multilevel(
    m: &mut Machine,
    nodes: &[usize],
    job: &IterationJob,
    ml: &mut MultiLevelScr,
) -> RunStats {
    assert!(!nodes.is_empty());
    assert!(job.cp_interval > 0, "multilevel driver needs a checkpoint cadence");
    run_to_completion(m, nodes, job, CkptBackendRef::Multi(ml))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::xpic;
    use crate::scr::multilevel::MultiLevelConfig;
    use crate::scr::Strategy;
    use crate::system::presets;

    fn machine() -> Machine {
        Machine::build(presets::deep_er())
    }

    fn fig8_job(cp: bool, fail: bool) -> IterationJob {
        IterationJob {
            profile: xpic::profile_deep_er(),
            iterations: 100,
            cp_interval: if cp { 10 } else { 0 },
            failures: if fail {
                FailurePlan::one_at_iteration(3, 60)
            } else {
                FailurePlan::none()
            },
        }
    }

    #[test]
    fn clean_run_counts() {
        let mut m = machine();
        let nodes = m.nodes_of(crate::system::NodeKind::Cluster);
        let mut scr = Scr::new(Strategy::Partner);
        let stats = run_iterations(&mut m, &nodes, &fig8_job(true, false), Some(&mut scr));
        assert_eq!(stats.iterations_run, 100);
        assert_eq!(stats.checkpoints_taken, 9); // every 10, skipping the last
        assert_eq!(stats.failures_hit, 0);
    }

    #[test]
    fn fig8_overhead_band() {
        // Paper: writing checkpoints costs ~8% on average.
        let mut m1 = machine();
        let nodes = m1.nodes_of(crate::system::NodeKind::Cluster);
        let t_plain = run_iterations(&mut m1, &nodes, &fig8_job(false, false), None).total_time;
        let mut m2 = machine();
        let mut scr = Scr::new(Strategy::Partner);
        let t_cp =
            run_iterations(&mut m2, &nodes, &fig8_job(true, false), Some(&mut scr)).total_time;
        let overhead = t_cp / t_plain - 1.0;
        assert!((0.02..=0.20).contains(&overhead), "overhead={overhead:.3}");
    }

    #[test]
    fn fig8_failure_savings_band() {
        // Paper: with an error at iteration 60, SCR saves ~23% vs rerun.
        let nodes: Vec<usize> = (0..16).collect();
        let mut m1 = machine();
        let t_unprot =
            run_iterations(&mut m1, &nodes, &fig8_job(false, true), None).total_time;
        let mut m2 = machine();
        let mut scr = Scr::new(Strategy::Partner);
        let t_prot =
            run_iterations(&mut m2, &nodes, &fig8_job(true, true), Some(&mut scr)).total_time;
        let saving = 1.0 - t_prot / t_unprot;
        assert!((0.10..=0.40).contains(&saving), "saving={saving:.3}");
    }

    #[test]
    fn unprotected_failure_reruns_everything() {
        let mut m = machine();
        let nodes: Vec<usize> = (0..4).collect();
        let mut job = fig8_job(false, true);
        job.iterations = 20;
        job.failures = FailurePlan::one_at_iteration(0, 10);
        let stats = run_iterations(&mut m, &nodes, &job, None);
        assert_eq!(stats.failures_hit, 1);
        assert_eq!(stats.iterations_run, 30); // 10 lost + 20 clean
    }

    #[test]
    fn time_keyed_failures_from_mtbf_schedule() {
        // An exponential-MTBF plan drives rollbacks through the driver.
        let mut m = machine();
        let nodes: Vec<usize> = (0..8).collect();
        let mut job = fig8_job(true, false);
        job.iterations = 30;
        job.cp_interval = 5;
        // MTBF chosen so a handful of failures land inside the run.
        job.failures = crate::system::failure::FailurePlan::exponential(
            nodes.len(),
            20_000.0, // per-node MTBF (s) -> system rate ~1/2500 s
            5_000.0,
            42,
        );
        let n_failures = job.failures.at_times.len();
        let mut scr = Scr::new(Strategy::Buddy);
        let stats = run_iterations(&mut m, &nodes, &job, Some(&mut scr));
        assert!(stats.iterations_run >= 30);
        assert!(stats.failures_hit <= n_failures);
        if stats.failures_hit > 0 {
            assert!(stats.restart_time > 0.0);
        }
    }

    fn ml_run(async_flush: bool, fail: bool) -> RunStats {
        let mut m = machine();
        let nodes = m.nodes_of(crate::system::NodeKind::Cluster);
        let job = fig8_job(true, fail);
        let cfg = MultiLevelConfig {
            l1_every: 1,
            l2_every: 2,
            l3_every: 2,
            async_flush,
            ..MultiLevelConfig::default()
        };
        let mut ml = MultiLevelScr::new(cfg);
        run_iterations_multilevel(&mut m, &nodes, &job, &mut ml)
    }

    #[test]
    fn multilevel_async_flush_cuts_blocked_time() {
        let blocking = ml_run(false, false);
        let overlapped = ml_run(true, false);
        assert_eq!(blocking.iterations_run, 100);
        assert_eq!(overlapped.iterations_run, 100);
        assert_eq!(blocking.checkpoints_taken, 9);
        assert_eq!(overlapped.checkpoints_taken, 9);
        assert_eq!(blocking.overlap_time, 0.0, "blocking path must not overlap");
        assert!(overlapped.overlap_time > 0.0);
        assert!(
            overlapped.blocked_time < blocking.blocked_time,
            "async {} !< blocking {}",
            overlapped.blocked_time,
            blocking.blocked_time
        );
        assert!(
            overlapped.total_time < blocking.total_time,
            "async {} !< blocking {}",
            overlapped.total_time,
            blocking.total_time
        );
    }

    #[test]
    fn multilevel_async_run_is_deterministic() {
        let a = ml_run(true, true);
        let b = ml_run(true, true);
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.blocked_time, b.blocked_time);
        assert_eq!(a.overlap_time, b.overlap_time);
        assert_eq!(a.iterations_run, b.iterations_run);
        assert_eq!(a.failures_hit, b.failures_hit);
    }

    #[test]
    fn multilevel_failure_rolls_back_and_completes() {
        let stats = ml_run(true, true);
        assert_eq!(stats.failures_hit, 1);
        assert!(stats.iterations_run > 100, "rollback must re-run iterations");
        assert!(stats.restart_time > 0.0);
    }

    #[test]
    fn protected_failure_rolls_back_to_last_cp() {
        let mut m = machine();
        let nodes: Vec<usize> = (0..4).collect();
        let mut job = fig8_job(true, true);
        job.iterations = 20;
        job.cp_interval = 5;
        job.failures = FailurePlan::one_at_iteration(1, 12);
        let mut scr = Scr::new(Strategy::Buddy);
        let stats = run_iterations(&mut m, &nodes, &job, Some(&mut scr));
        assert_eq!(stats.failures_hit, 1);
        // 12 before failure + (12-10)=2 re-run + 8 remaining = 22.
        assert_eq!(stats.iterations_run, 22);
        assert!(stats.restart_time > 0.0);
    }

    #[test]
    fn two_same_iteration_failures_both_hit() {
        // Regression: `failure_at_iteration` (singular) returned only the
        // first match, so a second failure scheduled at the same iteration
        // was silently dropped.  Both must now land: the first rolls the
        // run back to the checkpoint, the boundary re-check drains the
        // second from the queue before any iteration re-runs.
        let mut m = machine();
        let nodes: Vec<usize> = (0..4).collect();
        let mut job = fig8_job(true, true);
        job.iterations = 20;
        job.cp_interval = 5;
        job.failures = FailurePlan {
            at_iterations: vec![
                crate::system::failure::Failure { node: 1, at: 12.0 },
                crate::system::failure::Failure { node: 2, at: 12.0 },
            ],
            at_times: Vec::new(),
        };
        let mut scr = Scr::new(Strategy::Buddy);
        let stats = run_iterations(&mut m, &nodes, &job, Some(&mut scr));
        assert_eq!(stats.failures_hit, 2, "both same-iteration failures must hit");
        // 12 before the double failure + (12-10)=2 re-run + 8 remaining = 22:
        // the second failure drains at the same boundary, before any
        // re-execution, so no extra iterations are lost.
        assert_eq!(stats.iterations_run, 22);
        assert!(stats.restart_time > 0.0);
    }

    // ------------------------------------------------------------------
    // JobExec as a resumable machine (the fleet scheduler's contract)
    // ------------------------------------------------------------------

    #[test]
    fn job_exec_phase_stepping_matches_blocking_run() {
        // Driving the state machine by hand (poll + advance, stepping
        // events in between) must land on the identical trajectory the
        // blocking runner produces.
        let job = fig8_job(true, false);
        let mut m1 = machine();
        let nodes = m1.nodes_of(crate::system::NodeKind::Cluster);
        let mut scr1 = Scr::new(Strategy::Buddy);
        let blocking = run_iterations(&mut m1, &nodes, &job, Some(&mut scr1));

        let mut m2 = machine();
        let mut scr2 = Scr::new(Strategy::Buddy);
        let mut backend = CkptBackendRef::Scr(&mut scr2);
        let mut exec = JobExec::new(job);
        exec.bind(&m2, nodes.clone());
        while !exec.is_done() {
            match exec.front_op() {
                Some(op) if !m2.sim.poll_op(&op) => {
                    assert!(m2.sim.step_event(), "no events while an op is pending");
                }
                _ => exec.advance(&mut m2, &mut backend),
            }
        }
        let stepped = exec.stats;
        assert_eq!(stepped.total_time, blocking.total_time);
        assert_eq!(stepped.compute_time, blocking.compute_time);
        assert_eq!(stepped.exchange_time, blocking.exchange_time);
        assert_eq!(stepped.ckpt_time, blocking.ckpt_time);
        assert_eq!(stepped.iterations_run, blocking.iterations_run);
        assert_eq!(stepped.checkpoints_taken, blocking.checkpoints_taken);
    }

    #[test]
    fn failure_mid_phase_cancels_the_doomed_attempt() {
        // The §11.4 pin at the driver level: a machine-level failure that
        // lands while a phase op is in flight must settle-then-retire the
        // attempt's flows at kill time (stats.flows_cancelled counts
        // them, op_trace shows them cancelled) — not let them drain
        // unobserved against the restart I/O.
        let mut m = machine();
        let nodes: Vec<usize> = (0..4).collect();
        let mut job = fig8_job(true, false);
        job.iterations = 10;
        let mut scr = Scr::new(Strategy::Buddy);
        let mut backend = CkptBackendRef::Scr(&mut scr);
        let mut exec = JobExec::new(job);
        exec.bind(&m, nodes.clone());
        exec.advance(&mut m, &mut backend); // issues the first compute op
        let front = exec.front_op().expect("compute phase in flight");
        assert!(!m.sim.poll_op(&front));
        exec.handle_failure(&mut m, &mut backend, nodes[1]);
        assert_eq!(exec.stats.failures_hit, 1);
        assert_eq!(
            exec.stats.flows_cancelled,
            front.flows().len(),
            "every in-flight phase flow must be cancelled at kill time"
        );
        for &f in front.flows() {
            assert!(m.sim.was_cancelled(f));
            assert!(m.sim.poll(f), "cancelled flows poll complete");
        }
        // The job recovers and completes normally afterwards.
        while !exec.is_done() {
            if let Some(op) = exec.front_op() {
                m.sim.wait_op(&op);
            }
            exec.advance(&mut m, &mut backend);
        }
        assert!(exec.stats.iterations_run >= 10);
    }

    #[test]
    fn job_exec_unbind_rebind_resumes_where_it_left() {
        let mut m = machine();
        let nodes: Vec<usize> = (0..4).collect();
        let mut job = fig8_job(true, false);
        job.iterations = 10;
        job.cp_interval = 3;
        let mut scr = Scr::new(Strategy::Buddy);
        let mut backend = CkptBackendRef::Scr(&mut scr);
        let mut exec = JobExec::new(job);
        exec.bind(&m, nodes.clone());
        // Run a few phases, then pull the nodes out from under the job.
        for _ in 0..4 {
            if let Some(op) = exec.front_op() {
                m.sim.wait_op(&op);
            }
            exec.advance(&mut m, &mut backend);
        }
        let before = exec.current_iter();
        assert!(before > 0 && !exec.is_done());
        let released = exec.unbind(&mut m);
        assert_eq!(released, nodes);
        assert!(exec.front_op().is_none(), "unbind cancels the in-flight phase");
        // Rebind on a different node set and finish.
        let other: Vec<usize> = (4..8).collect();
        exec.bind(&m, other);
        assert_eq!(exec.current_iter(), before, "progress survives the requeue");
        while !exec.is_done() {
            if let Some(op) = exec.front_op() {
                m.sim.wait_op(&op);
            }
            exec.advance(&mut m, &mut backend);
        }
        assert_eq!(exec.stats.iterations_run, 10);
    }
}

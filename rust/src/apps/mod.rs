//! The DEEP-ER co-design applications (paper Section IV).
//!
//! Each application contributes the workload shape its paper experiments
//! need: per-iteration compute, checkpoint payload, I/O pattern, and (for
//! FWI) an OmpSs task graph.  The *compute content* of each app exists
//! twice: as a calibrated cost model driving the simulator (these
//! modules), and as real JAX/Pallas kernels (python/compile/) whose AOT
//! artifacts the e2e example executes through PJRT per iteration.

pub mod driver;
pub mod fwi;
pub mod split;
pub mod gershwin;
pub mod nbody;
pub mod portfolio;
pub mod xpic;

pub use driver::{run_iterations, run_iterations_multilevel, IterationJob, RunStats};

/// Cost/payload profile of an application run (one Table II/III column).
#[derive(Debug, Clone)]
pub struct AppProfile {
    pub name: &'static str,
    /// Compute per iteration per node, flops.
    pub flops_per_iter_per_node: f64,
    /// Achieved fraction of peak (PIC/stencil codes sit at 5-15%).
    pub cpu_efficiency: f64,
    /// Checkpoint payload per node, bytes ("Data per CP" in the paper).
    pub ckpt_bytes_per_node: f64,
    /// Halo/moment exchange per iteration per node, bytes.
    pub halo_bytes: f64,
    /// MPI processes per node doing task-local I/O.
    pub io_tasks_per_node: usize,
    /// Records per task in one I/O phase.
    pub io_records_per_task: u64,
    /// Name of the AOT artifact computing one step (e2e example).
    pub artifact: &'static str,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_profiles_well_formed() {
        for p in [
            nbody::profile(),
            xpic::profile_deep_er(),
            xpic::profile_qpace3(),
            xpic::profile_nam(),
            gershwin::profile_p1(),
            gershwin::profile_p3(),
            fwi::profile(),
        ] {
            assert!(p.flops_per_iter_per_node > 0.0, "{}", p.name);
            assert!(p.cpu_efficiency > 0.0 && p.cpu_efficiency <= 1.0);
            assert!(p.ckpt_bytes_per_node >= 0.0);
            assert!(!p.artifact.is_empty());
        }
    }
}

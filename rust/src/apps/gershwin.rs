//! GERShWIN: Inria's bioelectromagnetics DGTD solver (Fig. 5 workload).
//!
//! Paper Section IV: a Discontinuous Galerkin Time Domain solver for the
//! 3D Maxwell-Debye system, assessing human exposure to electromagnetic
//! fields.  Its Fig. 5 experiment measures task-local output writing with
//! and without SIONlib, for Lagrange order P1 (3 GB per checkpoint) and
//! P3 (6.6 GB) — the smaller-record P1 case gains more (7.4x vs 3.7x)
//! because metadata and small-write costs dominate it.
//!
//! The real compute path is `gershwin_step.hlo.txt`: batched element
//! operator (MXU-shaped) + Debye ADE update.

use super::AppProfile;
use crate::sionlib::TaskLocalWorkload;

/// Total output payload for the P1 (order-1) use case, bytes (Table II).
pub const P1_TOTAL_BYTES: f64 = 3.0e9;
/// Total output payload for the P3 (order-3) use case, bytes (Table II).
pub const P3_TOTAL_BYTES: f64 = 6.6e9;
/// MPI tasks per Cluster node (48 hardware threads).
pub const TASKS_PER_NODE: usize = 48;

/// Lagrange order P1 profile.
pub fn profile_p1() -> AppProfile {
    AppProfile {
        name: "gershwin-p1",
        flops_per_iter_per_node: 0.4e12,
        cpu_efficiency: 0.12,
        ckpt_bytes_per_node: P1_TOTAL_BYTES / 8.0,
        halo_bytes: 24e6, // face flux exchange
        io_tasks_per_node: TASKS_PER_NODE,
        io_records_per_task: 96, // many small per-element records
        artifact: "gershwin_step",
    }
}

/// Lagrange order P3 profile (more data, higher precision).
pub fn profile_p3() -> AppProfile {
    AppProfile {
        name: "gershwin-p3",
        flops_per_iter_per_node: 1.4e12,
        cpu_efficiency: 0.15, // denser element operators, better efficiency
        ckpt_bytes_per_node: P3_TOTAL_BYTES / 8.0,
        halo_bytes: 52e6,
        io_tasks_per_node: TASKS_PER_NODE,
        io_records_per_task: 96,
        artifact: "gershwin_step",
    }
}

/// The Fig. 5 I/O workload for `nodes` nodes at the given order.
/// Total bytes are fixed (strong-scaling style: the mesh is the mesh), so
/// per-task data shrinks as nodes join — which is exactly why the
/// task-local baseline degrades and SIONlib holds up.
pub fn io_workload(nodes: usize, order3: bool) -> TaskLocalWorkload {
    let total = if order3 { P3_TOTAL_BYTES } else { P1_TOTAL_BYTES };
    let tasks = (nodes * TASKS_PER_NODE) as f64;
    TaskLocalWorkload {
        nodes,
        tasks_per_node: TASKS_PER_NODE,
        bytes_per_task: total / tasks,
        records_per_task: 96,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_conserves_total_bytes() {
        for nodes in [1, 2, 4, 8, 16] {
            let w = io_workload(nodes, false);
            assert!((w.total_bytes() - P1_TOTAL_BYTES).abs() / P1_TOTAL_BYTES < 1e-9);
            let w3 = io_workload(nodes, true);
            assert!((w3.total_bytes() - P3_TOTAL_BYTES).abs() / P3_TOTAL_BYTES < 1e-9);
        }
    }

    #[test]
    fn p3_tasks_write_more_than_p1() {
        let p1 = io_workload(8, false);
        let p3 = io_workload(8, true);
        assert!(p3.bytes_per_task > 2.0 * p1.bytes_per_task);
    }
}

//! The remaining co-design applications (paper Section IV):
//!
//! * **SKA data analysis pipeline** (ASTRON) — radio-astronomy ingest:
//!   streaming I/O dominates; the node-local cache tier is the enabling
//!   feature (the SDP design that motivated DEEP-ER's I/O work).
//! * **TurboRvB** (CINECA) — quantum Monte Carlo: compute-dominated,
//!   tiny checkpoint state (walker ensembles), long mean time between
//!   I/O phases.
//! * **SeisSol** (LRZ) — ADER-DG seismic wave propagation: element-local
//!   dense operators (the GERShWIN compute class) with large mesh state.
//! * **CHROMA** (Univ. Regensburg) — lattice QCD: allreduce-heavy solver
//!   iterations (global sums every CG step), moderate checkpoints.
//!
//! The paper reports no figures for these four, so this module carries
//! *profiles only* — no fabricated results.  Their role here matches
//! their role in the project: they broaden the workload portfolio the
//! stack is exercised with (see `examples/portfolio.rs` and the
//! integration tests, which run every profile through the full driver).

use super::AppProfile;
use crate::psmpi::Comm;
use crate::sim::SimTime;
use crate::system::Machine;

/// SKA ingest pipeline: weak compute, heavy sustained output streaming.
pub fn ska() -> AppProfile {
    AppProfile {
        name: "ska-pipeline",
        flops_per_iter_per_node: 0.3e12,
        cpu_efficiency: 0.10,
        ckpt_bytes_per_node: 12e9, // visibility buffers per integration window
        halo_bytes: 8e6,
        io_tasks_per_node: 48,
        io_records_per_task: 256, // many small visibility records
        artifact: "xpic_step",    // stand-in compute content
    }
}

/// TurboRvB quantum Monte Carlo: compute-bound, tiny state.
pub fn turborvb() -> AppProfile {
    AppProfile {
        name: "turborvb",
        flops_per_iter_per_node: 3.2e12,
        cpu_efficiency: 0.30, // dense linear algebra inner loops
        ckpt_bytes_per_node: 0.2e9, // walker ensemble
        halo_bytes: 1e6,
        io_tasks_per_node: 4,
        io_records_per_task: 4,
        artifact: "nbody_step",
    }
}

/// SeisSol ADER-DG: element-local dense operators, large mesh state.
pub fn seissol() -> AppProfile {
    AppProfile {
        name: "seissol",
        flops_per_iter_per_node: 1.6e12,
        cpu_efficiency: 0.20,
        ckpt_bytes_per_node: 6e9,
        halo_bytes: 64e6, // face flux exchange
        io_tasks_per_node: 24,
        io_records_per_task: 48,
        artifact: "gershwin_step",
    }
}

/// CHROMA lattice QCD: allreduce every solver iteration.
pub fn chroma() -> AppProfile {
    AppProfile {
        name: "chroma",
        flops_per_iter_per_node: 1.1e12,
        cpu_efficiency: 0.15,
        ckpt_bytes_per_node: 4e9, // gauge configuration slice
        halo_bytes: 48e6,
        io_tasks_per_node: 16,
        io_records_per_task: 8,
        artifact: "gershwin_step",
    }
}

/// All seven co-design profiles (the "broad user portfolio of a
/// large-scale HPC center").
pub fn all_seven() -> Vec<AppProfile> {
    vec![
        super::xpic::profile_deep_er(),
        super::gershwin::profile_p1(),
        super::fwi::profile(),
        super::nbody::profile(),
        ska(),
        turborvb(),
        seissol(),
        chroma(),
    ]
}

/// CG-style solver phase for CHROMA: compute + allreduce per inner step.
/// Returns the time of `inner_steps` coupled iterations — the pattern
/// that distinguishes LQCD from the embarrassingly-parallel profiles.
pub fn chroma_solver_phase(
    m: &mut Machine,
    nodes: &[usize],
    inner_steps: usize,
) -> SimTime {
    let t0 = m.sim.now();
    let comm = Comm::of(nodes.to_vec());
    let p = chroma();
    for _ in 0..inner_steps {
        let flows: Vec<_> = nodes
            .iter()
            .map(|&n| m.compute(n, p.flops_per_iter_per_node / 20.0, p.cpu_efficiency))
            .collect();
        m.sim.wait_all(&flows);
        comm.allreduce(m, 64.0); // the global sum of one CG step
    }
    m.sim.now() - t0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::{run_iterations, IterationJob};
    use crate::scr::{Scr, Strategy};
    use crate::system::{failure::FailurePlan, presets, NodeKind};

    #[test]
    fn seven_profiles_well_formed() {
        let all = all_seven();
        assert_eq!(all.len(), 8); // xpic, gershwin, fwi, nbody + 4 portfolio
        for p in &all {
            assert!(p.flops_per_iter_per_node > 0.0, "{}", p.name);
            assert!(p.cpu_efficiency > 0.0 && p.cpu_efficiency <= 1.0, "{}", p.name);
        }
    }

    #[test]
    fn portfolio_extremes_differ_as_designed() {
        // SKA is I/O-heavy (big CP, small compute); TurboRvB the opposite.
        let s = ska();
        let t = turborvb();
        assert!(s.ckpt_bytes_per_node > 10.0 * t.ckpt_bytes_per_node);
        assert!(t.flops_per_iter_per_node > 5.0 * s.flops_per_iter_per_node);
    }

    #[test]
    fn every_profile_survives_a_failure_cycle() {
        for profile in all_seven() {
            let mut m = crate::system::Machine::build(presets::deep_er());
            let nodes: Vec<usize> = m.nodes_of(NodeKind::Cluster).into_iter().take(8).collect();
            let job = IterationJob {
                profile: profile.clone(),
                iterations: 12,
                cp_interval: 4,
                failures: FailurePlan::one_at_iteration(2, 6),
            };
            let mut scr = Scr::new(Strategy::Buddy);
            let stats = run_iterations(&mut m, &nodes, &job, Some(&mut scr));
            assert_eq!(stats.failures_hit, 1, "{}", profile.name);
            assert!(stats.iterations_run >= 12, "{}", profile.name);
        }
    }

    #[test]
    fn chroma_solver_dominated_by_latency_at_small_work() {
        let mut m = crate::system::Machine::build(presets::deep_er());
        let nodes: Vec<usize> = m.nodes_of(NodeKind::Cluster);
        let t = chroma_solver_phase(&mut m, &nodes, 10);
        assert!(t > 0.0 && t.is_finite());
        // Allreduce must appear in the cost: more inner steps => more time.
        let t2 = chroma_solver_phase(&mut m, &nodes, 20);
        assert!(t2 > 1.5 * t);
    }

    #[test]
    fn ska_checkpoint_heavier_than_turborvb() {
        let run = |p: AppProfile| {
            let mut m = crate::system::Machine::build(presets::deep_er());
            let nodes: Vec<usize> = m.nodes_of(NodeKind::Cluster).into_iter().take(8).collect();
            let mut scr = Scr::new(Strategy::Buddy);
            scr.checkpoint(&mut m, &nodes, p.ckpt_bytes_per_node).unwrap().blocked
        };
        assert!(run(ska()) > 10.0 * run(turborvb()));
    }
}

//! FWI: BSC's seismic Full Waveform Inversion code (Fig. 10 workload).
//!
//! Paper Section IV: seismic imaging by iterative inversion — several
//! frequency cycles, each a set of forward/adjoint wave propagations per
//! shot, until the velocity model converges.  In DEEP-ER, FWI is the
//! OmpSs-offload showcase: the master offloads per-shot propagation tasks
//! to workers; the Fig. 10 experiment injects an error *right before the
//! end* of the run and compares no-resiliency (nearly doubles the
//! runtime) against OmpSs resilient offload (~42% saving, <1% overhead).
//!
//! The real compute path is `fwi_step.hlo.txt` / `fwi_forward8.hlo.txt`:
//! the Pallas acoustic wave stencil.

use super::AppProfile;
use crate::ompss::{Task, TaskGraph};

/// Per-node data processed in the Fig. 10 runs (Table III).
pub const DATA_PER_NODE: f64 = 1.0e9;

/// Iteration-driver profile (used when FWI runs BSP-style, e.g. in the
/// quickstart example).
pub fn profile() -> AppProfile {
    AppProfile {
        name: "fwi",
        flops_per_iter_per_node: 0.9e12,
        cpu_efficiency: 0.18, // stencil with good cache blocking
        ckpt_bytes_per_node: DATA_PER_NODE,
        halo_bytes: 32e6,
        io_tasks_per_node: 16,
        io_records_per_task: 24,
        artifact: "fwi_step",
    }
}

/// Build the OmpSs task graph of one inversion: `cycles` frequency cycles
/// in sequence; each cycle holds `shots` independent propagation tasks
/// followed by one gradient-update task that depends on all of them.
pub fn task_graph(cycles: usize, shots: usize, flops_per_shot: f64) -> TaskGraph {
    let mut g = TaskGraph::new();
    let mut prev_update: Option<usize> = None;
    for c in 0..cycles {
        let mut shot_ids = Vec::with_capacity(shots);
        for s in 0..shots {
            let deps = prev_update.map(|u| vec![u]).unwrap_or_default();
            shot_ids.push(g.add(Task {
                name: format!("c{c}-shot{s}"),
                flops: flops_per_shot,
                input_bytes: 200e6, // velocity model slice + shot data
                output_bytes: 100e6, // partial gradient
                deps,
            }));
        }
        prev_update = Some(g.add(Task {
            name: format!("c{c}-update"),
            flops: flops_per_shot * 0.1,
            input_bytes: 50e6,
            output_bytes: 50e6,
            deps: shot_ids,
        }));
    }
    g
}

/// Task id of the last task (the Fig. 10 failure target: "an error
/// occurring right before the end of the execution").
pub fn last_task(g: &TaskGraph) -> usize {
    g.tasks.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graph_shape() {
        let g = task_graph(3, 4, 1e12);
        assert_eq!(g.tasks.len(), 3 * (4 + 1));
        let waves = g.waves();
        assert_eq!(waves.len(), 6); // shots, update, shots, update, ...
        assert_eq!(waves[0].len(), 4);
        assert_eq!(waves[1].len(), 1);
    }

    #[test]
    fn update_depends_on_all_shots() {
        let g = task_graph(1, 5, 1e12);
        let update = &g.tasks[5];
        assert_eq!(update.deps.len(), 5);
    }

    #[test]
    fn last_task_is_final_update() {
        let g = task_graph(2, 3, 1e12);
        assert_eq!(last_task(&g), 7);
        assert!(g.tasks[last_task(&g)].name.ends_with("update"));
    }
}

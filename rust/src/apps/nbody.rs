//! The N-body code used for the Fig. 4 checkpoint-strategy study.
//!
//! Weak scaling on the DEEP-ER Cluster: the particle count grows with the
//! node count, every node holds a fixed particle share, and the
//! checkpoint payload (positions + velocities + masses) is constant per
//! node.  Compute is the all-pairs force kernel — the L1 Pallas kernel
//! `nbody_forces`, AOT-lowered into `nbody_step.hlo.txt`.

use super::AppProfile;

/// Particles per node in the weak-scaling series.
pub const PARTICLES_PER_NODE: f64 = 4.0e6;
/// Bytes of state per particle (pos + vel f32x3 + mass f32 = 28, padded).
pub const BYTES_PER_PARTICLE: f64 = 32.0;

/// The Fig. 4 profile: ~2 GB checkpoint per node; all-pairs forces give
/// ~10 flops per interaction over a Barnes-Hut-reduced neighbour set.
pub fn profile() -> AppProfile {
    AppProfile {
        name: "nbody",
        // Tree-reduced interactions: ~N * 2e4 neighbours * 20 flops.
        flops_per_iter_per_node: PARTICLES_PER_NODE * 2.0e4 * 20.0,
        cpu_efficiency: 0.25, // dense FMA kernel, high efficiency
        ckpt_bytes_per_node: PARTICLES_PER_NODE * BYTES_PER_PARTICLE * 16.0,
        halo_bytes: 64e6, // boundary particle exchange
        io_tasks_per_node: 24,
        io_records_per_task: 16,
        artifact: "nbody_step",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ckpt_payload_is_about_2gb() {
        let p = profile();
        assert!((p.ckpt_bytes_per_node - 2.048e9).abs() / 2e9 < 0.05);
    }

    #[test]
    fn iteration_seconds_scale_reasonable() {
        // ~1.6e12 flops/iter at 25% of 1 TF -> ~6 s per iteration, so a
        // ~2-3 s checkpoint every few iterations lands at the ~10%
        // overhead regime the Fig. 4 strategy comparison lives in.
        let p = profile();
        let t_iter = p.flops_per_iter_per_node / (1e12 * p.cpu_efficiency);
        assert!(t_iter > 1e-3 && t_iter < 60.0, "t_iter={t_iter}");
    }
}

//! Figure/table harnesses: regenerate every row/series of the paper's
//! evaluation (Section V) on the simulated testbeds.
//!
//! Each `figN()` builds the exact experiment of the corresponding paper
//! figure (workload, node counts, parameters from Tables II/III), runs it
//! through the full stack, and returns the series the paper plots.  The
//! CLI (`repro bench figN|all`) prints them; the integration tests assert
//! the *shape targets* from DESIGN.md section 4 (who wins, by what factor,
//! where crossovers fall).

use std::collections::BTreeMap;

use crate::apps::{self, run_iterations, run_iterations_multilevel, IterationJob, RunStats};
use crate::beegfs::beeond::{concurrent_cache_write, concurrent_global_write, CacheDevice};
use crate::beegfs::{BeeGfs, BeeOnd, CacheMode};
use crate::fabric::{TopologySpec, TOURMALET_BW};
use crate::metrics::{
    fmt_bytes, fmt_bw, fmt_rate, fmt_time, p50, p95, p99, Figure, KvTable, Series, Summary,
};
use crate::microbench;
use crate::nam::NamDevice;
use crate::ompss::{OmpssRuntime, Resilience};
use crate::psmpi::Comm;
use crate::sched::{self, FleetConfig, FleetReport};
use crate::scr::multilevel::{MultiLevelConfig, MultiLevelScr};
use crate::scr::{Scr, Strategy};
use crate::sim::reference::RefSim;
use crate::sim::rng::SplitMix64;
use crate::sim::{Op, ResId, Sim, TrafficClass};
use crate::sionlib::{write_sionlib, write_task_local};
use crate::storage::DeviceParams;
use crate::system::failure::FailurePlan;
use crate::system::faults::FaultPlan;
use crate::system::{presets, zoo, Machine, MachineSpec, NodeKind};
use crate::util::json::Json;

/// Seed used when the CLI does not pass `--seed` (any fixed value keeps
/// the default bench output reproducible).
pub const DEFAULT_SEED: u64 = 0xDEE9E5;

/// Everything a harness can emit.
#[derive(Debug)]
pub enum Exhibit {
    Fig(Figure),
    Table(KvTable),
}

impl Exhibit {
    pub fn render(&self) -> String {
        match self {
            Exhibit::Fig(f) => f.to_table(),
            Exhibit::Table(t) => t.render(),
        }
    }

    /// CSV form for figures (tables fall back to `k,v` lines).
    pub fn render_csv(&self) -> String {
        match self {
            Exhibit::Fig(f) => format!("# {}\n{}", f.title, f.to_csv()),
            Exhibit::Table(t) => {
                let mut out = format!("# {}\n", t.title);
                for (k, v) in &t.rows {
                    out.push_str(&format!("{},{}\n", k.replace(',', ";"), v.replace(',', ";")));
                }
                out
            }
        }
    }
}

/// Table I: hardware configuration of the DEEP-ER prototype.
pub fn table1() -> Vec<Exhibit> {
    let spec = presets::deep_er();
    let b = spec.booster.as_ref().unwrap();
    let mut t = KvTable::new("Table I: DEEP-ER prototype hardware configuration");
    t.row("Cluster CPU", format!("{} ({} cores @ {} GHz) x16 nodes", spec.cluster.name, spec.cluster.cores, spec.cluster.freq_ghz));
    t.row("Booster CPU", format!("{} ({} cores @ {} GHz) x8 nodes", b.name, b.cores, b.freq_ghz));
    t.row("Cluster memory", fmt_bytes(spec.cluster.mem_bytes));
    t.row("Booster memory", format!("{} MCDRAM + {} DDR4", fmt_bytes(b.fast_mem_bytes), fmt_bytes(b.mem_bytes)));
    t.row("NVMe per node", fmt_bytes(spec.cluster.nvme.as_ref().unwrap().capacity));
    t.row("Fabric", format!("EXTOLL Tourmalet A3, {}", fmt_bw(TOURMALET_BW)));
    t.row("MPI latency Cluster", "1.0 us");
    t.row("MPI latency Booster", "1.8 us");
    t.row("Cluster peak", format!("{:.0} TFlop/s", spec.cluster.peak_flops * spec.n_cluster as f64 / 1e12));
    t.row("Booster peak", format!("{:.0} TFlop/s", b.peak_flops * spec.n_booster as f64 / 1e12));
    t.row("Storage", format!("{} servers + 1 MDS", spec.n_storage_servers));
    t.row("NAM boards", format!("{} x {}", spec.n_nam, fmt_bytes(crate::nam::HMC_CAPACITY)));
    vec![Exhibit::Table(t)]
}

/// Table II: I/O experiment setups.
pub fn table2() -> Vec<Exhibit> {
    let mut t = KvTable::new("Table II: I/O experiment setup");
    t.row("GERShWIN data per CP", "3 GB (P1) / 6.6 GB (P3), 1 CP");
    t.row("xPic on QPACE3", "10 GB per node, 2 CPs");
    t.row("xPic on DEEP-ER", "8 GB, 11 CPs");
    vec![Exhibit::Table(t)]
}

/// Table III: resiliency experiment setups.
pub fn table3() -> Vec<Exhibit> {
    let mut t = KvTable::new("Table III: resiliency experiment setup");
    t.row("xPic SCR", "32 GB per node processed, 8 GB per CP, 4 CPs");
    t.row("xPic NAM", "20 GB per node processed, 2 GB per CP, 10 CPs");
    t.row("FWI", "1 GB per node processed");
    vec![Exhibit::Table(t)]
}

/// Fig. 3: RMA bandwidth and latency on the NAM vs best-achievable EXTOLL.
pub fn fig3() -> Vec<Exhibit> {
    let sizes: Vec<f64> = (3..=22).map(|p| (1u64 << p) as f64).collect(); // 8 B .. 4 MB
    let mut bw_fig = Figure::new(
        "Fig. 3a: RMA bandwidth on the NAM (vs raw EXTOLL)",
        "message B",
        "GB/s",
    );
    let mut lat_fig = Figure::new(
        "Fig. 3b: RMA latency on the NAM (vs raw EXTOLL)",
        "message B",
        "us",
    );
    let mut s_nam_put = Series::new("NAM put");
    let mut s_nam_get = Series::new("NAM get");
    let mut s_raw = Series::new("EXTOLL best");
    let mut l_nam_put = Series::new("NAM put");
    let mut l_nam_get = Series::new("NAM get");
    let mut l_raw = Series::new("EXTOLL best");

    for &size in &sizes {
        // Fresh fabric per size keeps measurements independent.
        let mut sim = Sim::new();
        let mut fabric = crate::fabric::Fabric::new(&mut sim, 1e12);
        let node = fabric.endpoint(&mut sim, "n0", TOURMALET_BW, crate::fabric::LAT_CLUSTER);
        let peer = fabric.endpoint(&mut sim, "n1", TOURMALET_BW, crate::fabric::LAT_CLUSTER);
        let nam = NamDevice::new(&mut sim, &mut fabric, 0);

        let t0 = sim.now();
        let f = nam.put(&mut sim, &fabric, node, size);
        let t_put = sim.wait_all(&[f]) - t0;
        let t1 = sim.now();
        let f = nam.get(&mut sim, &fabric, node, size);
        let t_get = sim.wait_all(&[f]) - t1;
        let t2 = sim.now();
        let f = fabric.put(&mut sim, node, peer, size);
        let t_raw = sim.wait_all(&[f]) - t2;

        s_nam_put.push(size, size / t_put / 1e9);
        s_nam_get.push(size, size / t_get / 1e9);
        s_raw.push(size, size / t_raw / 1e9);
        l_nam_put.push(size, t_put * 1e6);
        l_nam_get.push(size, t_get * 1e6);
        l_raw.push(size, t_raw * 1e6);
    }
    bw_fig.add(s_raw);
    bw_fig.add(s_nam_put);
    bw_fig.add(s_nam_get);
    lat_fig.add(l_raw);
    lat_fig.add(l_nam_put);
    lat_fig.add(l_nam_get);
    vec![Exhibit::Fig(bw_fig), Exhibit::Fig(lat_fig)]
}

/// Fig. 4: N-body weak scaling under the five checkpoint strategies.
pub fn fig4() -> Vec<Exhibit> {
    let mut fig = Figure::new(
        "Fig. 4: N-body checkpoint time by strategy (weak scaling, DEEP-ER Cluster)",
        "nodes",
        "s per checkpoint",
    );
    let profile = apps::nbody::profile();
    for strat in Strategy::ALL {
        let mut s = Series::new(strat.name());
        for &n in &[2usize, 4, 8, 16] {
            let mut m = Machine::build(presets::deep_er());
            let nodes: Vec<usize> = m.nodes_of(NodeKind::Cluster).into_iter().take(n).collect();
            let mut scr = Scr::new(strat);
            let r = scr
                .checkpoint(&mut m, &nodes, profile.ckpt_bytes_per_node)
                .expect("checkpoint");
            s.push(n as f64, r.blocked);
        }
        fig.add(s);
    }
    vec![Exhibit::Fig(fig)]
}

/// Fig. 5: GERShWIN write time with/without SIONlib, P1 and P3.
pub fn fig5() -> Vec<Exhibit> {
    let mut fig = Figure::new(
        "Fig. 5: GERShWIN task-local I/O vs SIONlib",
        "nodes",
        "write s",
    );
    let mut out = Vec::new();
    for (label, order3) in [("P1", false), ("P3", true)] {
        let mut base = Series::new(format!("task-local {label}"));
        let mut sion = Series::new(format!("SIONlib {label}"));
        let mut speedups = Series::new(format!("speedup {label}"));
        for &n in &[1usize, 2, 4, 8, 16] {
            let w = apps::gershwin::io_workload(n, order3);
            let mut m1 = Machine::build(presets::deep_er());
            let b = write_task_local(&mut m1, &w);
            let mut m2 = Machine::build(presets::deep_er());
            let s = write_sionlib(&mut m2, &w);
            base.push(n as f64, b.write_time);
            sion.push(n as f64, s.write_time);
            speedups.push(n as f64, b.write_time / s.write_time);
        }
        fig.add(base);
        fig.add(sion);
        out.push(speedups);
    }
    let mut sp_fig = Figure::new("Fig. 5 (derived): SIONlib speedup", "nodes", "x");
    for s in out {
        sp_fig.add(s);
    }
    vec![Exhibit::Fig(fig), Exhibit::Fig(sp_fig)]
}

/// Fig. 6: xPic weak scaling on QPACE3 — global BeeGFS vs BeeOND-on-RAM.
pub fn fig6() -> Vec<Exhibit> {
    let mut fig = Figure::new(
        "Fig. 6: xPic on QPACE3 — global FS vs node-local BeeOND (10 GB/node)",
        "nodes",
        "write s",
    );
    let bytes = apps::xpic::profile_qpace3().ckpt_bytes_per_node;
    let mut s_global = Series::new("global BeeGFS");
    let mut s_local = Series::new("BeeOND local");
    for &n in &[16usize, 32, 64, 128, 256, 512, 672] {
        let mut m = Machine::build(presets::qpace3().with_cluster_nodes(n));
        let nodes: Vec<usize> = (0..n).collect();
        let t_global = concurrent_global_write(&mut m, &nodes, bytes);
        s_global.push(n as f64, t_global);
        let mut m2 = Machine::build(presets::qpace3().with_cluster_nodes(n));
        let mut cache = BeeOnd::new(CacheDevice::RamDisk, CacheMode::Async);
        let t_local = concurrent_cache_write(&mut m2, &mut cache, &nodes, bytes, 64);
        s_local.push(n as f64, t_local);
    }
    fig.add(s_global);
    fig.add(s_local);
    vec![Exhibit::Fig(fig)]
}

/// Fig. 7: xPic on the DEEP-ER Cluster — node-local NVMe vs HDD.
pub fn fig7() -> Vec<Exhibit> {
    let mut fig = Figure::new(
        "Fig. 7: xPic on DEEP-ER — node-local NVMe vs HDD (8 GB)",
        "nodes",
        "write s",
    );
    let bytes = apps::xpic::profile_deep_er().ckpt_bytes_per_node;
    let mut s_nvme = Series::new("NVMe");
    let mut s_hdd = Series::new("HDD");
    for &n in &[1usize, 2, 4, 8, 16] {
        let nodes: Vec<usize> = (0..n).collect();
        let mut m1 = Machine::build(presets::deep_er());
        let mut c1 = BeeOnd::new(CacheDevice::Nvme, CacheMode::Async);
        s_nvme.push(n as f64, concurrent_cache_write(&mut m1, &mut c1, &nodes, bytes, 24));
        let mut m2 = Machine::build(presets::deep_er());
        let mut c2 = BeeOnd::new(CacheDevice::Hdd, CacheMode::Async);
        s_hdd.push(n as f64, concurrent_cache_write(&mut m2, &mut c2, &nodes, bytes, 24));
    }
    fig.add(s_nvme);
    fig.add(s_hdd);
    vec![Exhibit::Fig(fig)]
}

/// Fig. 8: xPic with SCR_PARTNER — overhead and failure benefit.
/// 100 iterations, CP every 10, optional error at iteration 60.
pub fn fig8() -> Vec<Exhibit> {
    let profile = apps::xpic::profile_deep_er();
    let scenario = |with_cp: bool, with_err: bool| -> f64 {
        let mut m = Machine::build(presets::deep_er());
        let nodes = m.nodes_of(NodeKind::Cluster);
        let job = IterationJob {
            profile: profile.clone(),
            iterations: 100,
            cp_interval: if with_cp { 10 } else { 0 },
            failures: if with_err {
                FailurePlan::one_at_iteration(3, 60)
            } else {
                FailurePlan::none()
            },
        };
        if with_cp {
            let mut scr = Scr::new(Strategy::Partner);
            run_iterations(&mut m, &nodes, &job, Some(&mut scr)).total_time
        } else {
            run_iterations(&mut m, &nodes, &job, None).total_time
        }
    };
    let t_plain = scenario(false, false);
    let t_cp = scenario(true, false);
    let t_err_plain = scenario(false, true);
    let t_err_cp = scenario(true, true);

    let mut t = KvTable::new("Fig. 8: xPic + SCR_PARTNER (100 iters, CP every 10, error at 60)");
    t.row("w/o CP, w/o error", format!("{t_plain:.1} s"));
    t.row("with CP, w/o error", format!("{t_cp:.1} s"));
    t.row("w/o CP, with error", format!("{t_err_plain:.1} s"));
    t.row("with CP, with error", format!("{t_err_cp:.1} s"));
    t.row("CP overhead", format!("{:.1} %", (t_cp / t_plain - 1.0) * 100.0));
    t.row(
        "saving on failure",
        format!("{:.1} %", (1.0 - t_err_cp / t_err_plain) * 100.0),
    );
    vec![Exhibit::Table(t)]
}

/// Compress a simulator's [`Sim::op_trace`] into one diagnostic line:
/// how many flows the run issued, when the last one completed, and the
/// busiest resource (the one the most flows routed through).
fn trace_summary(sim: &Sim) -> String {
    let trace = sim.op_trace();
    let mut last_done: f64 = 0.0;
    let mut counts: std::collections::BTreeMap<ResId, usize> = std::collections::BTreeMap::new();
    for e in &trace {
        if let Some(t) = e.finished_at {
            last_done = last_done.max(t);
        }
        for &r in &e.route {
            *counts.entry(r).or_insert(0) += 1;
        }
    }
    let busiest = counts.iter().max_by_key(|(_, &c)| c);
    match busiest {
        Some((&r, &c)) => format!(
            "{} flows, last completion {}, busiest resource {} ({} flows)",
            trace.len(),
            fmt_time(last_done),
            sim.resource_name(r),
            c
        ),
        None => format!("{} flows", trace.len()),
    }
}

/// Fig. 8 counterpart (extension): the same xPic SCR scenario run through
/// the **multi-level** checkpointer, blocking promotion vs background
/// flush (`--async-flush`).  The failure variant draws its schedule from
/// an exponential-MTBF plan seeded by `seed` (`repro bench --seed N`).
pub fn fig8_async(seed: u64) -> Vec<Exhibit> {
    let profile = apps::xpic::profile_deep_er();
    let scenario = |async_flush: bool, failures: FailurePlan| -> (RunStats, String) {
        let mut m = Machine::build(presets::deep_er());
        let nodes = m.nodes_of(NodeKind::Cluster);
        let job = IterationJob {
            profile: profile.clone(),
            iterations: 100,
            cp_interval: 10,
            failures,
        };
        let cfg = MultiLevelConfig {
            l1_every: 1,
            l2_every: 2,
            l3_every: 2,
            async_flush,
            ..MultiLevelConfig::default()
        };
        let mut ml = MultiLevelScr::new(cfg);
        let stats = run_iterations_multilevel(&mut m, &nodes, &job, &mut ml);
        (stats, trace_summary(&m.sim))
    };
    // ~1 failure expected inside the ~2500 s run: 16 nodes, 40000 s/node.
    let plan = || FailurePlan::exponential(16, 40_000.0, 5_000.0, seed);

    let (block_clean, block_trace) = scenario(false, FailurePlan::none());
    let (async_clean, async_trace) = scenario(true, FailurePlan::none());
    let (block_fail, _) = scenario(false, plan());
    let (async_fail, _) = scenario(true, plan());

    let mut t = KvTable::new(
        "Fig. 8 (async ext): xPic multi-level CP, blocking vs background flush (CP every 10)",
    );
    t.row("blocking: total / blocked", format!(
        "{} / {}",
        fmt_time(block_clean.total_time),
        fmt_time(block_clean.blocked_time)
    ));
    t.row("async: total / blocked / overlap", format!(
        "{} / {} / {}",
        fmt_time(async_clean.total_time),
        fmt_time(async_clean.blocked_time),
        fmt_time(async_clean.overlap_time)
    ));
    t.row(
        "blocked-time saving",
        format!(
            "{:.1} %",
            (1.0 - async_clean.blocked_time / block_clean.blocked_time.max(1e-12)) * 100.0
        ),
    );
    t.row(
        format!("with failures (seed {seed}): blocking total"),
        format!("{} ({} failures)", fmt_time(block_fail.total_time), block_fail.failures_hit),
    );
    t.row(
        format!("with failures (seed {seed}): async total"),
        format!("{} ({} failures)", fmt_time(async_fail.total_time), async_fail.failures_hit),
    );
    t.row("op trace (blocking)", block_trace);
    t.row("op trace (async)", async_trace);
    vec![Exhibit::Table(t)]
}

/// Fig. 9: Distributed XOR vs NAM XOR — bandwidth and write time.
pub fn fig9() -> Vec<Exhibit> {
    let bytes = apps::xpic::profile_nam().ckpt_bytes_per_node; // 2 GB
    let mut bw_fig = Figure::new(
        "Fig. 9a: checkpoint bandwidth, Distributed XOR vs NAM XOR (2 GB/node)",
        "nodes",
        "GB/s",
    );
    let mut time_fig = Figure::new(
        "Fig. 9b: checkpoint write time, Distributed XOR vs NAM XOR",
        "nodes",
        "s",
    );
    let mut bw_dist = Series::new("Distributed XOR");
    let mut bw_nam = Series::new("NAM XOR");
    let mut t_dist = Series::new("Distributed XOR");
    let mut t_nam = Series::new("NAM XOR");
    for &n in &[4usize, 8, 16] {
        let mut m1 = Machine::build(presets::deep_er());
        let nodes: Vec<usize> = m1.nodes_of(NodeKind::Cluster).into_iter().take(n).collect();
        let mut d = Scr::new(Strategy::DistXor);
        let rd = d.checkpoint(&mut m1, &nodes, bytes).unwrap();
        let mut m2 = Machine::build(presets::deep_er());
        let mut nx = Scr::new(Strategy::NamXor);
        let rn = nx.checkpoint(&mut m2, &nodes, bytes).unwrap();
        bw_dist.push(n as f64, rd.bandwidth / 1e9);
        bw_nam.push(n as f64, rn.bandwidth / 1e9);
        t_dist.push(n as f64, rd.blocked);
        t_nam.push(n as f64, rn.blocked);
    }
    bw_fig.add(bw_dist);
    bw_fig.add(bw_nam);
    time_fig.add(t_dist);
    time_fig.add(t_nam);
    vec![Exhibit::Fig(bw_fig), Exhibit::Fig(time_fig)]
}

/// Fig. 10: FWI + OmpSs resilient offload on MareNostrum 3.
pub fn fig10() -> Vec<Exhibit> {
    let graph = apps::fwi::task_graph(5, 4, 3e11);
    let fail_last = FailurePlan::one_at_iteration(0, apps::fwi::last_task(&graph));
    let workers: Vec<usize> = (1..5).collect();

    let run = |res: Resilience, failures: &FailurePlan| -> f64 {
        let mut m = Machine::build(presets::marenostrum3());
        OmpssRuntime::new(0, res).execute(&mut m, &graph, &workers, failures).time
    };

    let t_clean = run(Resilience::None, &FailurePlan::none());
    let t_res_clean = run(Resilience::ResilientOffload, &FailurePlan::none());
    let t_err_none = run(Resilience::None, &fail_last);
    let t_err_res = run(Resilience::ResilientOffload, &fail_last);

    let mut t = KvTable::new("Fig. 10: FWI + OmpSs task resiliency (MareNostrum 3)");
    t.row("w/o CP, w/o error", format!("{t_clean:.1} s"));
    t.row("with CP, w/o error", format!("{t_res_clean:.1} s"));
    t.row("w/o CP, error at end", format!("{t_err_none:.1} s"));
    t.row("with CP, error at end", format!("{t_err_res:.1} s"));
    t.row(
        "resiliency overhead",
        format!("{:.2} %", (t_res_clean / t_clean - 1.0) * 100.0),
    );
    t.row(
        "saving on failure",
        format!("{:.1} %", (1.0 - t_err_res / t_err_none) * 100.0),
    );
    t.row(
        "vs clean run",
        format!("+{:.1} %", (t_err_res / t_clean - 1.0) * 100.0),
    );
    vec![Exhibit::Table(t)]
}

/// Extension exhibit (not a figure of THIS paper, but of its companion
/// reference [4], Kreuzer et al. IPDPSW 2018): the Cluster-Booster
/// division-of-labour benefit the Section II-A architecture exists for.
pub fn cb_split() -> Vec<Exhibit> {
    use crate::apps::split::{run_split, Placement, SplitJob};
    let mut t = KvTable::new(
        "Ref [4]: xPic-like split over Cluster+Booster (10 iterations, DEEP-ER prototype)",
    );
    let mut split_time = f64::INFINITY;
    let mut best_homog = f64::INFINITY;
    for placement in Placement::ALL {
        let mut m = Machine::build(presets::deep_er());
        let stats = run_split(&mut m, &SplitJob::xpic_like(10), placement);
        t.row(
            placement.name(),
            format!(
                "{:.1} s  (particle {:.1} s, field {:.1} s, coupling {:.2} s)",
                stats.total_time, stats.particle_time, stats.field_time, stats.coupling_time
            ),
        );
        if placement == Placement::Split {
            split_time = stats.total_time;
        } else {
            best_homog = best_homog.min(stats.total_time);
        }
    }
    t.row(
        "split speedup vs best homogeneous",
        format!("{:.2}x", best_homog / split_time),
    );
    vec![Exhibit::Table(t)]
}

/// Names of every paper exhibit, in paper order (plus the extensions).
/// The CLI iterates this lazily so it can time each exhibit individually
/// (the `# engine:` events/sec stats line in `--csv` mode).  The `scale`
/// engine bench is intentionally **not** listed: it measures wall-clock,
/// so bundling it into `all` would make `bench all` output machine-
/// dependent.  `fleet` is likewise separate: it takes its own flags
/// (`--sweep`, `--mtbf`, `--json`) and writes a trajectory artifact.
pub fn names() -> &'static [&'static str] {
    &[
        "table1", "table2", "table3", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8",
        "fig8-async", "fig9", "fig10", "cb-split",
    ]
}

/// All exhibits in paper order (plus the extensions).  `seed` drives the
/// stochastic failure schedules (`repro bench all --seed N`); exhibits
/// without randomness ignore it.
pub fn all(seed: u64) -> Vec<(&'static str, Vec<Exhibit>)> {
    names()
        .iter()
        .map(|&n| (n, by_name(n, seed).expect("names() entries resolve")))
        .collect()
}

/// Run one named exhibit (CLI entry point).
pub fn by_name(name: &str, seed: u64) -> Option<Vec<Exhibit>> {
    match name {
        "table1" => Some(table1()),
        "table2" => Some(table2()),
        "table3" => Some(table3()),
        "fig3" => Some(fig3()),
        "fig4" => Some(fig4()),
        "fig5" => Some(fig5()),
        "fig6" => Some(fig6()),
        "fig7" => Some(fig7()),
        "fig8" => Some(fig8()),
        "fig8-async" | "fig8a" => Some(fig8_async(seed)),
        "fig9" => Some(fig9()),
        "fig10" => Some(fig10()),
        "cb-split" | "cb" => Some(cb_split()),
        _ => None,
    }
}

/// Resolve an optional `--topology` name to its zoo machine spec.
/// `None` keeps an exhibit's historical flat scenario byte-for-byte.
/// The CLI validates names before building a config, so a failure here is
/// a programmer error, not user input.
fn resolve_topology(name: &Option<String>) -> Option<MachineSpec> {
    name.as_ref()
        .map(|n| zoo::by_name(n).expect("--topology names are validated before bench configs"))
}

// ----------------------------------------------------------------------
// `repro bench scale` — the engine-throughput exhibit (DESIGN.md §10)
// ----------------------------------------------------------------------

/// Configuration of the engine scale sweep.
#[derive(Debug, Clone)]
pub struct ScaleConfig {
    /// Concurrent-flow counts to sweep (default 1k / 10k / 100k).
    pub sweep: Vec<usize>,
    /// Seed for the workload's sizes/stagger (reproducible sweeps).
    pub seed: u64,
    /// The naive baseline engine is O(events x flows), so it is only
    /// timed on points up to this many flows; larger points report the
    /// optimized engine alone.
    pub baseline_max: usize,
    /// Optional `system::zoo` topology name: route the workload over that
    /// machine's fabric instead of the synthetic flat layout.
    pub topology: Option<String>,
    /// Worker counts to sweep the optimized engine at (the `--threads`
    /// axis of the schema-v2 artifact).  Every count must agree with the
    /// first entry on the last completion time to within 1e-9 relative
    /// (the serial engine merges near-simultaneous finishes *across*
    /// components within its ~1 ns retirement epsilon, which a sharded
    /// run cannot replicate — anything beyond that tolerance panics).
    pub threads: Vec<usize>,
}

impl Default for ScaleConfig {
    fn default() -> Self {
        Self {
            sweep: vec![1_000, 10_000, 100_000],
            seed: DEFAULT_SEED,
            baseline_max: 10_000,
            topology: None,
            threads: vec![1],
        }
    }
}

/// One measured engine (optimized or baseline) at one sweep point.
#[derive(Debug, Clone)]
pub struct ScaleMeasurement {
    pub wall_s: f64,
    pub events: u64,
    pub events_per_sec: f64,
    /// Virtual time of the last completion — the determinism anchor the
    /// equivalence check and the cross-PR trajectory compare.
    pub last_finish: f64,
}

/// The optimized engine measured at one worker count (one entry of a
/// [`ScalePoint`]'s threads axis).
#[derive(Debug, Clone)]
pub struct ThreadRun {
    /// Worker count the engine ran with ([`Sim::set_threads`]).
    pub threads: usize,
    pub engine: ScaleMeasurement,
    /// Largest flow set one component-scoped refill touched.
    pub peak_component: usize,
    /// Events processed per worker ([`Sim::worker_events`]; sums to
    /// `engine.events`).
    pub worker_events: Vec<u64>,
}

/// One sweep point of the scale bench.
#[derive(Debug, Clone)]
pub struct ScalePoint {
    pub flows: usize,
    /// The measurement at the first configured thread count (the anchor
    /// the baseline oracle and the speedup headline compare against).
    pub engine: ScaleMeasurement,
    /// Largest flow set one component-scoped refill touched (at the
    /// first configured thread count).
    pub peak_component: usize,
    /// One optimized-engine run per [`ScaleConfig::threads`] entry.
    pub runs: Vec<ThreadRun>,
    /// Present when `flows <= baseline_max`.
    pub baseline: Option<ScaleMeasurement>,
}

impl ScalePoint {
    /// events/sec ratio over the naive baseline, when measured.
    pub fn speedup(&self) -> Option<f64> {
        self.baseline
            .as_ref()
            .map(|b| self.engine.events_per_sec / b.events_per_sec.max(1e-12))
    }
}

/// Engine-agnostic workload description, shaped like the DEEP-ER presets:
/// per node a private NVMe write channel and a NIC, plus a handful of
/// shared storage backends.  ~90% of flows are node-local (many small
/// disjoint components — the Fig. 6/7 pattern), ~10% fan into the shared
/// backends (one large coupled component — the incast pattern).
struct ScaleWorkload {
    caps: Vec<f64>,
    /// (bytes, delay, route) with route as indices into `caps`.
    flows: Vec<(f64, f64, Vec<usize>)>,
}

const SCALE_OSS: usize = 8;

fn scale_workload(n_flows: usize, seed: u64) -> ScaleWorkload {
    let spec = presets::deep_er();
    let nvme_bw = spec.cluster.nvme.as_ref().expect("deep_er cluster has NVMe").write_bw;
    let nic_bw = spec.cluster.nic_bw;
    let oss_bw = spec.server_device.write_bw;
    let nodes = (n_flows / 16).clamp(16, 4096);
    // Layout: [0, nodes) NVMe channels, [nodes, 2*nodes) NICs, then OSS.
    let mut caps = Vec::with_capacity(2 * nodes + SCALE_OSS);
    caps.resize(nodes, nvme_bw);
    caps.resize(2 * nodes, nic_bw);
    caps.resize(2 * nodes + SCALE_OSS, oss_bw);
    let mut rng = SplitMix64::new(seed ^ (n_flows as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let mut flows = Vec::with_capacity(n_flows);
    for i in 0..n_flows {
        let node = i % nodes;
        let bytes = 64e6 + rng.next_f64() * 192e6;
        let delay = rng.next_f64() * 0.25;
        let route = if i % 10 == 0 {
            vec![nodes + node, 2 * nodes + (i / 10) % SCALE_OSS]
        } else {
            vec![node]
        };
        flows.push((bytes, delay, route));
    }
    ScaleWorkload { caps, flows }
}

/// Same flow mix, routed over a zoo machine's real fabric: ~90% of flows
/// hit the issuing node's local NVMe channel, ~10% stream to a storage
/// server through the topology interior, so leaf crossbars, rails,
/// bridges and spine links all appear in the engine's components.  The
/// machine's resources are compacted to a dense index space so both
/// engines replay the identical workload.
fn scale_workload_zoo(n_flows: usize, seed: u64, mspec: MachineSpec) -> ScaleWorkload {
    let m = Machine::build(mspec);
    let n_nodes = m.nodes.len();
    let mut rng = SplitMix64::new(seed ^ (n_flows as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let mut index: BTreeMap<ResId, usize> = BTreeMap::new();
    let mut caps: Vec<f64> = Vec::new();
    let mut flows = Vec::with_capacity(n_flows);
    for i in 0..n_flows {
        let node = i % n_nodes;
        let bytes = 64e6 + rng.next_f64() * 192e6;
        let delay = rng.next_f64() * 0.25;
        let route: Vec<ResId> = if i % 10 == 0 {
            let srv = &m.servers[(i / 10) % m.servers.len()];
            let mut r = m.fabric.path(m.nodes[node].ep, srv.ep);
            r.push(srv.device.write_res());
            r
        } else if let Some(d) = &m.nodes[node].nvme {
            vec![d.write_res()]
        } else {
            // Device-less node: a fabric put to its neighbor instead.
            m.fabric.path(m.nodes[node].ep, m.nodes[(node + 1) % n_nodes].ep)
        };
        let compact: Vec<usize> = route
            .iter()
            .map(|&r| {
                *index.entry(r).or_insert_with(|| {
                    caps.push(m.sim.capacity(r));
                    caps.len() - 1
                })
            })
            .collect();
        flows.push((bytes, delay, compact));
    }
    ScaleWorkload { caps, flows }
}

fn run_scale_optimized(w: &ScaleWorkload, threads: usize) -> ThreadRun {
    let ((last_finish, events, peak, worker_events), wall) = microbench::time_once(|| {
        let mut sim = Sim::new();
        sim.set_threads(threads);
        let res: Vec<ResId> = w.caps.iter().map(|&c| sim.resource("r", c)).collect();
        let mut route_buf: Vec<ResId> = Vec::new();
        for (bytes, delay, route) in &w.flows {
            route_buf.clear();
            route_buf.extend(route.iter().map(|&i| res[i]));
            sim.flow(*bytes, *delay, &route_buf);
        }
        sim.run_until_idle();
        (sim.now(), sim.events(), sim.peak_component_flows(), sim.worker_events())
    });
    let wall_s = wall.as_secs_f64().max(1e-9);
    ThreadRun {
        threads,
        engine: ScaleMeasurement {
            wall_s,
            events,
            events_per_sec: events as f64 / wall_s,
            last_finish,
        },
        peak_component: peak,
        worker_events,
    }
}

fn run_scale_baseline(w: &ScaleWorkload) -> ScaleMeasurement {
    let ((last_finish, events), wall) = microbench::time_once(|| {
        let mut sim = RefSim::new();
        let res: Vec<ResId> = w.caps.iter().map(|&c| sim.resource(c)).collect();
        let mut route_buf: Vec<ResId> = Vec::new();
        for (bytes, delay, route) in &w.flows {
            route_buf.clear();
            route_buf.extend(route.iter().map(|&i| res[i]));
            sim.flow(*bytes, *delay, &route_buf);
        }
        sim.run_until_idle();
        (sim.now(), sim.events())
    });
    let wall_s = wall.as_secs_f64().max(1e-9);
    ScaleMeasurement { wall_s, events, events_per_sec: events as f64 / wall_s, last_finish }
}

/// Run the sweep.  Every baselined point doubles as a runtime oracle: the
/// optimized and naive engines must agree on the last completion time to
/// within 1e-9 relative, or the measurement panics instead of reporting a
/// speedup over a divergent simulation.  Every additional thread count is
/// gated the same way against the first one, so a thread-count divergence
/// can never be reported as a speedup either.
pub fn scale_points(cfg: &ScaleConfig) -> Vec<ScalePoint> {
    assert!(!cfg.threads.is_empty(), "scale bench needs at least one thread count");
    cfg.sweep
        .iter()
        .map(|&n| {
            let w = match resolve_topology(&cfg.topology) {
                Some(mspec) => scale_workload_zoo(n, cfg.seed, mspec),
                None => scale_workload(n, cfg.seed),
            };
            let runs: Vec<ThreadRun> =
                cfg.threads.iter().map(|&t| run_scale_optimized(&w, t)).collect();
            let anchor = &runs[0];
            for r in &runs[1..] {
                let rel = (r.engine.last_finish - anchor.engine.last_finish).abs()
                    / anchor.engine.last_finish.abs().max(1.0);
                assert!(
                    rel < 1e-9,
                    "thread-count divergence at {n} flows: threads={} finished at {} \
                     vs threads={} at {}",
                    r.threads,
                    r.engine.last_finish,
                    anchor.threads,
                    anchor.engine.last_finish
                );
            }
            let baseline = (n <= cfg.baseline_max).then(|| run_scale_baseline(&w));
            if let Some(b) = &baseline {
                let rel = (anchor.engine.last_finish - b.last_finish).abs()
                    / anchor.engine.last_finish.abs().max(1.0);
                assert!(
                    rel < 1e-9,
                    "engines diverged at {n} flows: optimized {} vs baseline {}",
                    anchor.engine.last_finish,
                    b.last_finish
                );
            }
            ScalePoint {
                flows: n,
                engine: anchor.engine.clone(),
                peak_component: anchor.peak_component,
                runs,
                baseline,
            }
        })
        .collect()
}

fn scale_json(cfg: &ScaleConfig, points: &[ScalePoint]) -> Json {
    let meas = |m: &ScaleMeasurement| {
        let mut o = BTreeMap::new();
        o.insert("wall_s".into(), Json::Num(m.wall_s));
        o.insert("events".into(), Json::Num(m.events as f64));
        o.insert("events_per_sec".into(), Json::Num(m.events_per_sec));
        o.insert("last_finish_virtual_s".into(), Json::Num(m.last_finish));
        Json::Obj(o)
    };
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("sim_scale".into()));
    // Schema v2 (ISSUE 7): a top-level `threads` axis plus a per-point
    // `runs` array with one optimized measurement — including per-worker
    // event counters — per thread count.  The v1 keys (`engine`,
    // `peak_component_flows`, `baseline`, `speedup_events_per_sec`) are
    // kept and anchored at the first thread count, so v1 trajectory
    // tooling keeps parsing.
    doc.insert("schema_version".into(), Json::Num(2.0));
    doc.insert("seed".into(), Json::Num(cfg.seed as f64));
    doc.insert(
        "topology".into(),
        resolve_topology(&cfg.topology)
            .map(|s| Json::Str(s.topology.label()))
            .unwrap_or(Json::Null),
    );
    doc.insert(
        "sweep".into(),
        Json::Arr(cfg.sweep.iter().map(|&n| Json::Num(n as f64)).collect()),
    );
    doc.insert(
        "threads".into(),
        Json::Arr(cfg.threads.iter().map(|&t| Json::Num(t as f64)).collect()),
    );
    doc.insert(
        "baseline_engine".into(),
        Json::Str("sim::reference::RefSim — naive O(events x flows) sweep + global refill".into()),
    );
    doc.insert(
        "points".into(),
        Json::Arr(
            points
                .iter()
                .map(|p| {
                    let mut o = BTreeMap::new();
                    o.insert("flows".into(), Json::Num(p.flows as f64));
                    o.insert("engine".into(), meas(&p.engine));
                    o.insert(
                        "peak_component_flows".into(),
                        Json::Num(p.peak_component as f64),
                    );
                    o.insert(
                        "runs".into(),
                        Json::Arr(
                            p.runs
                                .iter()
                                .map(|r| {
                                    let mut ro = BTreeMap::new();
                                    ro.insert("threads".into(), Json::Num(r.threads as f64));
                                    ro.insert("wall_s".into(), Json::Num(r.engine.wall_s));
                                    ro.insert(
                                        "events".into(),
                                        Json::Num(r.engine.events as f64),
                                    );
                                    ro.insert(
                                        "events_per_sec".into(),
                                        Json::Num(r.engine.events_per_sec),
                                    );
                                    ro.insert(
                                        "last_finish_virtual_s".into(),
                                        Json::Num(r.engine.last_finish),
                                    );
                                    ro.insert(
                                        "peak_component_flows".into(),
                                        Json::Num(r.peak_component as f64),
                                    );
                                    ro.insert(
                                        "worker_events".into(),
                                        Json::Arr(
                                            r.worker_events
                                                .iter()
                                                .map(|&e| Json::Num(e as f64))
                                                .collect(),
                                        ),
                                    );
                                    Json::Obj(ro)
                                })
                                .collect(),
                        ),
                    );
                    o.insert(
                        "baseline".into(),
                        p.baseline.as_ref().map(&meas).unwrap_or(Json::Null),
                    );
                    o.insert(
                        "speedup_events_per_sec".into(),
                        p.speedup().map(Json::Num).unwrap_or(Json::Null),
                    );
                    Json::Obj(o)
                })
                .collect(),
        ),
    );
    // Largest baselined point by flow count — the sweep order is
    // user-controlled and not necessarily ascending.
    let headline = points
        .iter()
        .filter_map(|p| p.speedup().map(|s| (p.flows, s)))
        .max_by_key(|&(flows, _)| flows);
    doc.insert(
        "speedup_at_largest_baselined_point".into(),
        headline.map(|(_, s)| Json::Num(s)).unwrap_or(Json::Null),
    );
    doc.insert(
        "largest_baselined_flows".into(),
        headline.map(|(n, _)| Json::Num(n as f64)).unwrap_or(Json::Null),
    );
    Json::Obj(doc)
}

/// The `repro bench scale` exhibit: sweep the engine over growing
/// concurrent-flow counts, reporting wall-clock, events/sec and peak
/// component size, with the naive reference engine as the in-run
/// baseline.  Returns the printable exhibits plus the
/// `BENCH_sim_scale.json` document (the perf-trajectory artifact the CI
/// bench-smoke job uploads).
pub fn scale_report(cfg: &ScaleConfig) -> (Vec<Exhibit>, Json) {
    let points = scale_points(cfg);
    let json = scale_json(cfg, &points);

    let mut eps_fig = Figure::new(
        "Engine scale: events/sec vs concurrent flows (DEEP-ER-shaped workload)",
        "flows",
        "events/s",
    );
    let mut s_opt = Series::new(format!("optimized engine (threads={})", cfg.threads[0]));
    let mut s_base = Series::new("naive baseline");
    let mut wall_fig = Figure::new("Engine scale: wall-clock per sweep point", "flows", "s");
    let mut w_opt = Series::new(format!("optimized engine (threads={})", cfg.threads[0]));
    let mut w_base = Series::new("naive baseline");
    for p in &points {
        s_opt.push(p.flows as f64, p.engine.events_per_sec);
        w_opt.push(p.flows as f64, p.engine.wall_s);
        if let Some(b) = &p.baseline {
            s_base.push(p.flows as f64, b.events_per_sec);
            w_base.push(p.flows as f64, b.wall_s);
        }
    }
    eps_fig.add(s_opt);
    // One extra events/sec series per additional thread count — the
    // threads axis of the schema-v2 artifact, rendered.
    for (ti, &t) in cfg.threads.iter().enumerate().skip(1) {
        let mut s = Series::new(format!("optimized engine (threads={t})"));
        for p in &points {
            s.push(p.flows as f64, p.runs[ti].engine.events_per_sec);
        }
        eps_fig.add(s);
    }
    eps_fig.add(s_base);
    wall_fig.add(w_opt);
    wall_fig.add(w_base);

    let mut t = KvTable::new("Engine scale summary (events/sec, peak component, speedup)");
    for p in &points {
        let speedup = match p.speedup() {
            Some(s) => format!("{s:.1}x vs naive"),
            None => "baseline skipped (too large for the naive engine)".into(),
        };
        t.row(
            format!("{} flows", p.flows),
            format!(
                "{} over {}, {} events, peak component {} flows, {}",
                fmt_rate(p.engine.events_per_sec),
                fmt_time(p.engine.wall_s),
                p.engine.events,
                p.peak_component,
                speedup
            ),
        );
        for r in p.runs.iter().skip(1) {
            t.row(
                format!("{} flows, threads={}", p.flows, r.threads),
                format!(
                    "{} over {}, {} events",
                    fmt_rate(r.engine.events_per_sec),
                    fmt_time(r.engine.wall_s),
                    r.engine.events,
                ),
            );
        }
    }
    (vec![Exhibit::Fig(eps_fig), Exhibit::Fig(wall_fig), Exhibit::Table(t)], json)
}

// ----------------------------------------------------------------------
// `repro bench fleet` — the co-scheduling exhibit (DESIGN.md section 11)
// ----------------------------------------------------------------------

/// Configuration of the fleet sweep.
#[derive(Debug, Clone)]
pub struct FleetBenchConfig {
    /// Job counts to sweep; each point runs the same synthetic mix under
    /// both policies.
    pub sweep: Vec<usize>,
    pub seed: u64,
    /// Optional exponential per-node MTBF, to exercise the
    /// failure→restart→requeue path inside the sweep.
    pub mtbf_node: Option<f64>,
    /// Optional `system::zoo` topology name: run the fleet on that
    /// machine instead of the flat DEEP-ER prototype.
    pub topology: Option<String>,
}

impl Default for FleetBenchConfig {
    fn default() -> Self {
        Self { sweep: vec![2, 4, 8, 16], seed: DEFAULT_SEED, mtbf_node: None, topology: None }
    }
}

/// One (job count, policy) measurement of the fleet sweep.
#[derive(Debug)]
pub struct FleetPoint {
    pub jobs: usize,
    pub policy: sched::policy::Policy,
    pub report: FleetReport,
}

/// Run the sweep: every job count under both policies, same seed, on a
/// fresh machine each time (the DEEP-ER prototype, or the `--topology`
/// zoo member when one is selected).
pub fn fleet_points(cfg: &FleetBenchConfig) -> Vec<FleetPoint> {
    let mut out = Vec::new();
    for &n in &cfg.sweep {
        for policy in sched::policy::Policy::ALL {
            let fleet_cfg = FleetConfig {
                policy,
                seed: cfg.seed,
                mtbf_node: cfg.mtbf_node,
                ..FleetConfig::default()
            };
            let jobs = sched::synthetic_jobs(n, cfg.seed);
            let report = match resolve_topology(&cfg.topology) {
                Some(mspec) => sched::run_fleet_on(mspec, jobs, fleet_cfg),
                None => sched::run_fleet(jobs, fleet_cfg),
            }
            .expect("synthetic jobs fit the sweep machine");
            out.push(FleetPoint { jobs: n, policy, report });
        }
    }
    out
}

fn fleet_json(cfg: &FleetBenchConfig, points: &[FleetPoint]) -> Json {
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("fleet".into()));
    doc.insert("schema_version".into(), Json::Num(1.0));
    doc.insert("seed".into(), Json::Num(cfg.seed as f64));
    doc.insert(
        "topology".into(),
        resolve_topology(&cfg.topology)
            .map(|s| Json::Str(s.topology.label()))
            .unwrap_or(Json::Null),
    );
    doc.insert(
        "mtbf_node_s".into(),
        cfg.mtbf_node.map(Json::Num).unwrap_or(Json::Null),
    );
    doc.insert(
        "sweep".into(),
        Json::Arr(cfg.sweep.iter().map(|&n| Json::Num(n as f64)).collect()),
    );
    doc.insert(
        "points".into(),
        Json::Arr(
            points
                .iter()
                .map(|p| {
                    let mut o = BTreeMap::new();
                    o.insert("jobs".into(), Json::Num(p.jobs as f64));
                    o.insert("policy".into(), Json::Str(p.policy.name().into()));
                    o.insert("makespan_s".into(), Json::Num(p.report.makespan));
                    o.insert("utilization".into(), Json::Num(p.report.utilization));
                    o.insert("avg_wait_s".into(), Json::Num(p.report.avg_wait));
                    o.insert(
                        "failures_injected".into(),
                        Json::Num(p.report.failures_injected as f64),
                    );
                    o.insert("sim_events".into(), Json::Num(p.report.sim_events as f64));
                    Json::Obj(o)
                })
                .collect(),
        ),
    );
    // Headline: backfill's wait-time win at the largest sweep point.
    let largest = cfg.sweep.iter().copied().max();
    let at = |policy: sched::policy::Policy| {
        points
            .iter()
            .find(|p| Some(p.jobs) == largest && p.policy == policy)
            .map(|p| p.report.avg_wait)
    };
    let headline = match (at(sched::policy::Policy::Fcfs), at(sched::policy::Policy::Backfill)) {
        (Some(f), Some(b)) => Json::Num(f - b),
        _ => Json::Null,
    };
    doc.insert("backfill_wait_saving_at_largest_point_s".into(), headline);
    doc.insert(
        "largest_point_jobs".into(),
        largest.map(|n| Json::Num(n as f64)).unwrap_or(Json::Null),
    );
    Json::Obj(doc)
}

/// The `repro bench fleet` exhibit: sweep co-scheduled job counts under
/// both policies, reporting makespan, utilization and queue waits, and
/// return the `BENCH_fleet.json` trajectory document.
pub fn fleet_report(cfg: &FleetBenchConfig) -> (Vec<Exhibit>, Json) {
    let points = fleet_points(cfg);
    let json = fleet_json(cfg, &points);

    let mut mk_fig = Figure::new(
        "Fleet: makespan vs co-scheduled jobs (DEEP-ER prototype, mixed apps)",
        "jobs",
        "s",
    );
    let mut ut_fig = Figure::new("Fleet: machine utilization vs co-scheduled jobs", "jobs", "frac");
    let mut wait_fig = Figure::new("Fleet: mean queue wait vs co-scheduled jobs", "jobs", "s");
    for policy in sched::policy::Policy::ALL {
        let mut mk = Series::new(policy.name());
        let mut ut = Series::new(policy.name());
        let mut wt = Series::new(policy.name());
        for p in points.iter().filter(|p| p.policy == policy) {
            mk.push(p.jobs as f64, p.report.makespan);
            ut.push(p.jobs as f64, p.report.utilization);
            wt.push(p.jobs as f64, p.report.avg_wait);
        }
        mk_fig.add(mk);
        ut_fig.add(ut);
        wait_fig.add(wt);
    }

    let mut t = KvTable::new("Fleet summary (per sweep point: makespan / utilization / avg wait)");
    for p in &points {
        t.row(
            format!("{} jobs, {}", p.jobs, p.policy.name()),
            format!(
                "{} makespan, {:.1} % util, {} avg wait, {} failures",
                fmt_time(p.report.makespan),
                p.report.utilization * 100.0,
                fmt_time(p.report.avg_wait),
                p.report.failures_injected
            ),
        );
    }
    (
        vec![
            Exhibit::Fig(mk_fig),
            Exhibit::Fig(ut_fig),
            Exhibit::Fig(wait_fig),
            Exhibit::Table(t),
        ],
        json,
    )
}

// ----------------------------------------------------------------------
// `repro bench serve` — steady-state service mode (DESIGN.md section 16)
// ----------------------------------------------------------------------

/// Configuration of the service-mode exhibit: one open-arrival run under
/// Poisson arrivals, reported through the rolling-window SLO lens.
#[derive(Debug, Clone)]
pub struct ServeBenchConfig {
    /// Arrivals to draw before closing the door.
    pub jobs: usize,
    /// Poisson arrival rate, jobs per second.
    pub rate_hz: f64,
    /// Admission bound: arrivals beyond this queue depth are rejected.
    pub queue_cap: usize,
    pub seed: u64,
    /// Optional `system::zoo` topology name (flat DEEP-ER prototype by
    /// default).
    pub topology: Option<String>,
}

impl Default for ServeBenchConfig {
    fn default() -> Self {
        Self {
            jobs: 2000,
            rate_hz: 1.0,
            queue_cap: 1024,
            seed: DEFAULT_SEED,
            topology: None,
        }
    }
}

/// Run the service loop once and return its report.  The bench keeps the
/// service defaults (backfill, reserve depth 32, allocation log off) —
/// only the arrival process and admission bound come from `cfg`.
pub fn serve_point(cfg: &ServeBenchConfig) -> sched::ServeReport {
    let scfg = sched::ServeConfig {
        fleet: FleetConfig {
            seed: cfg.seed,
            ..sched::ServeConfig::default().fleet
        },
        arrivals: sched::ArrivalSpec::Poisson { rate_hz: cfg.rate_hz },
        jobs: cfg.jobs,
        queue_cap: cfg.queue_cap,
        ..sched::ServeConfig::default()
    };
    match resolve_topology(&cfg.topology) {
        Some(mspec) => sched::serve_fleet_on(mspec, scfg),
        None => sched::serve_fleet(scfg),
    }
    .expect("service defaults are valid")
}

/// The `repro bench serve` exhibit: one steady-state open-arrival run,
/// rendered as rolling utilization / p99-wait series plus a summary
/// table, and the `BENCH_serve.json` document (the [`sched::ServeReport`]
/// serialization itself — same artifact `repro serve --json` writes).
pub fn serve_report(cfg: &ServeBenchConfig) -> (Vec<Exhibit>, Json) {
    let r = serve_point(cfg);
    let json = r.to_json();

    let mut ut_fig = Figure::new(
        "Service: rolling machine utilization (open Poisson arrivals)",
        "window end s",
        "frac",
    );
    let mut ut = Series::new("utilization");
    for w in &r.windows {
        ut.push(w.t1_s, w.utilization);
    }
    ut_fig.add(ut);

    let mut wait_fig = Figure::new(
        "Service: per-class p99 queue wait per rolling window",
        "window end s",
        "s",
    );
    for c in 0..3usize {
        let mut s = Series::new(format!("class {c}"));
        for w in &r.windows {
            if let Some(p) = w.p99_wait_s[c] {
                s.push(w.t1_s, p);
            }
        }
        wait_fig.add(s);
    }

    let mut t = KvTable::new("Service summary (steady-state SLOs)");
    t.row(
        "arrivals",
        format!(
            "{} arrived ({} {:?} Hz), {} admitted, {} rejected ({:.2} % rejection)",
            r.jobs_arrived,
            r.arrivals,
            r.rate_hz.unwrap_or(0.0),
            r.jobs_admitted,
            r.jobs_rejected,
            r.rejection_rate * 100.0
        ),
    );
    t.row(
        "drain",
        format!(
            "{} completed over {} ({} horizon), {:.1} % utilization",
            r.jobs_completed,
            fmt_time(r.makespan_s),
            fmt_time(r.horizon_s),
            r.utilization * 100.0
        ),
    );
    for c in &r.classes {
        t.row(
            format!("class {} wait", c.class),
            format!(
                "p50 {}, p99 {}, max {} ({} completed, {} rejected)",
                fmt_time(c.p50_wait_s),
                fmt_time(c.p99_wait_s),
                fmt_time(c.max_wait_s),
                c.completed,
                c.rejected
            ),
        );
    }
    t.row(
        "resilience",
        format!(
            "{} failures, {} requeues, {} migrations, {} qos grants open",
            r.failures_injected, r.requeues, r.migrations, r.qos_grants_open
        ),
    );
    (vec![Exhibit::Fig(ut_fig), Exhibit::Fig(wait_fig), Exhibit::Table(t)], json)
}

// ----------------------------------------------------------------------
// `repro bench resilience` — reactive vs proactive degraded-mode handling
// (DESIGN.md section 15)
// ----------------------------------------------------------------------

/// Configuration of the resilience exhibit: one synthetic co-scheduled
/// mix, one seeded correlated fault schedule, run under both resilience
/// policies.
#[derive(Debug, Clone)]
pub struct ResilienceBenchConfig {
    /// Synthetic jobs in the co-scheduled mix.
    pub jobs: usize,
    /// Fault events in the correlated schedule (degradation windows with
    /// paired kills, plus standalone checkpoint corruptions).
    pub faults: usize,
    pub seed: u64,
    /// Optional `system::zoo` topology name (flat DEEP-ER prototype by
    /// default).
    pub topology: Option<String>,
}

impl Default for ResilienceBenchConfig {
    fn default() -> Self {
        Self { jobs: 8, faults: 6, seed: DEFAULT_SEED, topology: None }
    }
}

/// One policy's outcome under the shared fault schedule.
#[derive(Debug)]
pub struct ResiliencePoint {
    pub policy: sched::ResiliencePolicy,
    pub report: FleetReport,
}

/// Run the exhibit: a fault-free probe sizes the fault horizon (so the
/// schedule lands *inside* the run, not after it), then the identical
/// mix + identical correlated plan runs under reactive and proactive.
/// Returns the probe makespan, the plan horizon, and both points.
pub fn resilience_points(
    cfg: &ResilienceBenchConfig,
) -> (f64, f64, Vec<ResiliencePoint>) {
    let run = |fleet_cfg: FleetConfig| {
        let jobs = sched::synthetic_jobs(cfg.jobs, cfg.seed);
        match resolve_topology(&cfg.topology) {
            Some(mspec) => sched::run_fleet_on(mspec, jobs, fleet_cfg),
            None => sched::run_fleet(jobs, fleet_cfg),
        }
        .expect("synthetic jobs fit the resilience machine")
    };
    let probe = run(FleetConfig { seed: cfg.seed, ..FleetConfig::default() });
    let mspec = resolve_topology(&cfg.topology).unwrap_or_else(presets::deep_er);
    let nodes = mspec.n_cluster + mspec.n_booster;
    // 80 % of the healthy makespan: late-schedule faults still fire even
    // though faults stretch the run they land in.
    let horizon = probe.makespan * 0.8;
    let plan = FaultPlan::correlated(nodes, cfg.faults, horizon, cfg.seed);
    let points = sched::ResiliencePolicy::ALL
        .iter()
        .map(|&policy| ResiliencePoint {
            policy,
            report: run(FleetConfig {
                seed: cfg.seed,
                fault_plan: Some(plan.clone()),
                resilience: policy,
                ..FleetConfig::default()
            }),
        })
        .collect();
    (probe.makespan, horizon, points)
}

fn resilience_json(
    cfg: &ResilienceBenchConfig,
    probe_makespan: f64,
    horizon: f64,
    points: &[ResiliencePoint],
) -> Json {
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("resilience".into()));
    doc.insert("schema_version".into(), Json::Num(1.0));
    doc.insert("seed".into(), Json::Num(cfg.seed as f64));
    doc.insert("jobs".into(), Json::Num(cfg.jobs as f64));
    doc.insert("faults".into(), Json::Num(cfg.faults as f64));
    doc.insert(
        "topology".into(),
        resolve_topology(&cfg.topology)
            .map(|s| Json::Str(s.topology.label()))
            .unwrap_or(Json::Null),
    );
    doc.insert("healthy_makespan_s".into(), Json::Num(probe_makespan));
    doc.insert("fault_horizon_s".into(), Json::Num(horizon));
    doc.insert(
        "points".into(),
        Json::Arr(
            points
                .iter()
                .map(|p| {
                    let rs = p.report.resilience.as_ref().expect("fault plan was active");
                    let requeues: usize = p.report.jobs.iter().map(|j| j.requeues).sum();
                    let mut o = BTreeMap::new();
                    o.insert("policy".into(), Json::Str(p.policy.name().into()));
                    o.insert("makespan_s".into(), Json::Num(p.report.makespan));
                    o.insert("utilization".into(), Json::Num(p.report.utilization));
                    o.insert(
                        "wasted_iterations".into(),
                        Json::Num(rs.wasted_iterations as f64),
                    );
                    o.insert("migrations".into(), Json::Num(rs.migrations as f64));
                    o.insert("requeues".into(), Json::Num(requeues as f64));
                    o.insert(
                        "failures_injected".into(),
                        Json::Num(p.report.failures_injected as f64),
                    );
                    o.insert(
                        "idle_failures".into(),
                        Json::Num(p.report.idle_failures as f64),
                    );
                    o.insert("suspects".into(), Json::Num(rs.suspects as f64));
                    o.insert("link_degrades".into(), Json::Num(rs.link_degrades as f64));
                    o.insert("stragglers".into(), Json::Num(rs.stragglers as f64));
                    o.insert("corruptions".into(), Json::Num(rs.corruptions as f64));
                    o.insert("sim_events".into(), Json::Num(p.report.sim_events as f64));
                    Json::Obj(o)
                })
                .collect(),
        ),
    );
    // Headline: the wasted-work saving of acting on precursors.
    let wasted = |policy: sched::ResiliencePolicy| {
        points
            .iter()
            .find(|p| p.policy == policy)
            .and_then(|p| p.report.resilience.as_ref())
            .map(|rs| rs.wasted_iterations as f64)
    };
    let headline = match (
        wasted(sched::ResiliencePolicy::Reactive),
        wasted(sched::ResiliencePolicy::Proactive),
    ) {
        (Some(r), Some(p)) => Json::Num(r - p),
        _ => Json::Null,
    };
    doc.insert("proactive_wasted_iteration_saving".into(), headline);
    Json::Obj(doc)
}

/// The `repro bench resilience` exhibit: the same co-scheduled mix under
/// the same correlated degrade-then-die fault schedule, reactive vs
/// proactive, reporting wasted work, migrations and makespan, and the
/// `BENCH_resilience.json` document.
pub fn resilience_report(cfg: &ResilienceBenchConfig) -> (Vec<Exhibit>, Json) {
    let (probe_makespan, horizon, points) = resilience_points(cfg);
    let json = resilience_json(cfg, probe_makespan, horizon, &points);

    let mut t = KvTable::new(
        "Resilience: reactive vs proactive under one correlated fault schedule",
    );
    t.row(
        "scenario",
        format!(
            "{} jobs, {} faults over {} (healthy makespan {})",
            cfg.jobs,
            cfg.faults,
            fmt_time(horizon),
            fmt_time(probe_makespan)
        ),
    );
    for p in &points {
        let rs = p.report.resilience.as_ref().expect("fault plan was active");
        let requeues: usize = p.report.jobs.iter().map(|j| j.requeues).sum();
        t.row(
            p.policy.name(),
            format!(
                "{} makespan, {} wasted iterations, {} migrations, {} requeues, {} kills landed ({} idle), {} suspects",
                fmt_time(p.report.makespan),
                rs.wasted_iterations,
                rs.migrations,
                requeues,
                p.report.failures_injected,
                p.report.idle_failures,
                rs.suspects
            ),
        );
    }
    (vec![Exhibit::Table(t)], json)
}

// ----------------------------------------------------------------------
// `repro bench qos` — the traffic-class QoS exhibit (DESIGN.md section 12)
// ----------------------------------------------------------------------

/// Configuration of the qos bench scenario.
#[derive(Debug, Clone)]
pub struct QosBenchConfig {
    /// Exchange iterations of the latency-sensitive victim job.
    pub iterations: usize,
    /// Seeds the per-iteration halo-size jitter; output is
    /// byte-deterministic for a fixed seed (virtual times only).
    pub seed: u64,
    /// Shaped run: CkptFlush ceiling on the backplane, as a fraction of
    /// its capacity.
    pub flush_ceiling_frac: f64,
    /// Shaped run: Exchange floor on the backplane (fraction).
    pub exchange_floor_frac: f64,
    /// Shaped run: Exchange class weight (Bulk stays 1.0).
    pub exchange_weight: f64,
    /// Optional `system::zoo` topology name: stage the scenario on that
    /// machine's fabric instead of the flat oversubscribed switch; the
    /// ceiling/floor fractions then apply to every fabric-core resource.
    pub topology: Option<String>,
    /// Worker threads handed to [`Sim::set_threads`].  The exhibit's
    /// virtual-time results are thread-count independent (the scenario
    /// waits on each exchange op, a standing merge barrier), so 1 — the
    /// default — keeps committed goldens byte-identical.
    pub threads: usize,
    /// Observability sink installed into every scenario machine (None —
    /// the default — records nothing).  The zero-perturbation gate in
    /// `rust/tests/integration_obs.rs` runs the bench traced and
    /// untraced and asserts `BENCH_qos.json` is byte-identical.
    pub trace: Option<crate::obs::Trace>,
}

impl Default for QosBenchConfig {
    fn default() -> Self {
        Self {
            iterations: 120,
            seed: DEFAULT_SEED,
            flush_ceiling_frac: 0.4,
            exchange_floor_frac: 0.3,
            exchange_weight: 4.0,
            topology: None,
            threads: 1,
            trace: None,
        }
    }
}

/// Oversubscribed shared switch: 24 node links of 12.5 GB/s behind
/// 20 GB/s of switching — the regime where a neighbor's bulk flush lands
/// directly on top of latency-critical exchanges.
const QOS_BACKPLANE_BW: f64 = 20e9;
/// Victim halo bytes per rank per iteration (before jitter).
const QOS_HALO_BYTES: f64 = 250e6;
/// One neighbor checkpoint flush (striped to the global FS).  Sized so
/// individual flush flows complete well inside even a reduced-iteration
/// run (the per-class latency summary needs finished flows).
const QOS_FLUSH_BYTES: f64 = 1e9;
/// Victim job: cluster nodes 0..4.
const QOS_VICTIM_NODES: usize = 4;
/// Neighbor flusher job: cluster nodes 8..16.
const QOS_FLUSHERS: std::ops::Range<usize> = 8..16;
/// Outstanding flushes each neighbor node keeps in flight.
const QOS_FLUSH_DEPTH: usize = 2;
/// Victim compute time between exchanges, seconds.
const QOS_COMPUTE_GAP: f64 = 0.01;

/// The scenario machine: by default the DEEP-ER prototype with an
/// oversubscribed flat fabric; with `--topology`, the selected zoo member
/// (whose interior is the contended part).  Either way the storage
/// backend is flash-era (4 fast OSS), so the *fabric* — not the spinning
/// disks — is where flush and exchange traffic meet.
fn qos_machine(cfg: &QosBenchConfig) -> Machine {
    let mut spec = match resolve_topology(&cfg.topology) {
        Some(s) => s,
        None => {
            let mut s = presets::deep_er();
            s.topology = TopologySpec::Flat { backplane_bw: QOS_BACKPLANE_BW };
            s
        }
    };
    spec.n_storage_servers = 4;
    spec.server_device = DeviceParams::qpace3_global();
    let m = Machine::build(spec);
    assert!(
        m.nodes.len() >= QOS_FLUSHERS.end,
        "qos bench scenario needs at least {} nodes (topology {} has {})",
        QOS_FLUSHERS.end,
        m.spec.topology.label(),
        m.nodes.len()
    );
    m
}

/// Shaping applied to the contended run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum QosMode {
    Unshaped,
    Shaped,
}

/// Per-class latency summary of one contended run (nearest-rank
/// percentiles over finished-flow durations).
#[derive(Debug, Clone)]
pub struct ClassLatency {
    pub class: TrafficClass,
    pub n: usize,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// One measured run of the victim + flusher scenario.
#[derive(Debug)]
pub struct QosRun {
    pub mode: &'static str,
    /// Per-iteration exchange-phase durations, virtual seconds.
    pub exchange_s: Vec<f64>,
    /// Per-iteration slowdown vs the isolated baseline (same seed, same
    /// halo jitters, no neighbor).
    pub slowdown: Vec<f64>,
    pub flushes_issued: usize,
    pub class_latency: Vec<ClassLatency>,
}

impl QosRun {
    /// p99 of the per-iteration exchange slowdown — the headline metric.
    pub fn p99_slowdown(&self) -> f64 {
        p99(&self.slowdown)
    }
}

/// The whole exhibit's measurements.
#[derive(Debug)]
pub struct QosBenchResult {
    /// Isolated per-iteration exchange durations (the slowdown divisor).
    pub isolated_s: Vec<f64>,
    pub unshaped: QosRun,
    pub shaped: QosRun,
    /// Canonical topology label of the scenario machine.
    pub topology: String,
    /// Aggregate capacity of the shaped fabric-core resources.
    pub core_bw: f64,
}

/// Run the victim's exchange loop, optionally against the flushing
/// neighbor, returning per-iteration exchange durations, flushes issued
/// and the per-class latency summary.
fn qos_exchange_times(
    cfg: &QosBenchConfig,
    mode: Option<QosMode>,
) -> (Vec<f64>, usize, Vec<ClassLatency>) {
    let mut m = qos_machine(cfg);
    m.sim.set_threads(cfg.threads.max(1));
    if let Some(tr) = &cfg.trace {
        m.sim.set_trace(tr.clone());
    }
    if mode == Some(QosMode::Shaped) {
        // Shape every fabric-core resource (the one backplane on the flat
        // scenario; uplinks/rails/bridges on zoo topologies).
        for r in m.fabric.core_resources() {
            let cap = m.sim.capacity(r);
            m.sim.set_class_ceiling(r, TrafficClass::CkptFlush, cfg.flush_ceiling_frac * cap);
            m.sim.set_class_floor(r, TrafficClass::Exchange, cfg.exchange_floor_frac * cap);
        }
        m.sim.set_class_weight(TrafficClass::Exchange, cfg.exchange_weight);
    }
    let victim = Comm::of((0..QOS_VICTIM_NODES).collect());
    // Pre-draw the halo jitters so isolated and contended runs measure
    // the exact same per-iteration payloads.
    let mut rng = SplitMix64::new(cfg.seed ^ 0x0905_BEEF);
    let halos: Vec<f64> = (0..cfg.iterations)
        .map(|_| QOS_HALO_BYTES * (0.9 + 0.2 * rng.next_f64()))
        .collect();
    let mut fs = BeeGfs::new();
    let mut inflight: Vec<Vec<Op>> = vec![Vec::new(); QOS_FLUSHERS.len()];
    let mut issued = 0usize;
    let mut times = Vec::with_capacity(cfg.iterations);
    for &halo in &halos {
        if mode.is_some() {
            // The neighbor keeps each node QOS_FLUSH_DEPTH checkpoint
            // flushes deep — sustained background pressure, reissued as
            // flushes drain (deterministic: poll + refill per iteration).
            for (k, node) in QOS_FLUSHERS.enumerate() {
                let q = &mut inflight[k];
                q.retain(|op| !m.sim.poll_op(op));
                while q.len() < QOS_FLUSH_DEPTH {
                    let prev = m.sim.default_issue_class(TrafficClass::CkptFlush);
                    let op = fs.write_striped_op(&mut m, node, QOS_FLUSH_BYTES);
                    m.sim.set_issue_class(prev);
                    q.push(op);
                    issued += 1;
                }
            }
        }
        let t0 = m.sim.now();
        let op = victim.ring_exchange_op(&mut m, halo);
        let t = m.sim.wait_op(&op);
        times.push(t - t0);
        // Compute gap between exchanges (flushes keep draining inside).
        let gap = m.sim.delay(QOS_COMPUTE_GAP);
        m.sim.wait_all(&[gap]);
    }
    // Per-class latency summary over every finished flow of the run.
    // Pure-delay timers (empty route — the compute-gap markers above)
    // are instrumentation, not traffic: they would otherwise publish a
    // junk zero-latency "bulk" row.
    let mut per_class: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
    for e in m.sim.op_trace() {
        if e.route.is_empty() {
            continue;
        }
        if let Some(fin) = e.finished_at {
            per_class.entry(e.class.index()).or_default().push(fin - e.start_at);
        }
    }
    let class_latency = TrafficClass::ALL
        .iter()
        .filter_map(|&c| {
            per_class.get(&c.index()).map(|v| {
                // Sort once for all three percentiles ([`Summary`]);
                // bit-identical to the clone-per-call free functions.
                let mut s = Summary::of(v);
                ClassLatency { class: c, n: v.len(), p50: s.p50(), p95: s.p95(), p99: s.p99() }
            })
        })
        .collect();
    (times, issued, class_latency)
}

/// Run the full exhibit: isolated baseline, unshaped contended run,
/// shaped contended run (same seed everywhere).
pub fn qos_points(cfg: &QosBenchConfig) -> QosBenchResult {
    assert!(cfg.iterations > 0, "qos bench needs at least one iteration");
    let (topology, core_bw) = {
        let m = qos_machine(cfg);
        let core_bw = m.fabric.core_resources().iter().map(|&r| m.sim.capacity(r)).sum();
        (m.spec.topology.label(), core_bw)
    };
    let (isolated_s, _, _) = qos_exchange_times(cfg, None);
    let run = |mode: QosMode, name: &'static str| {
        let (exchange_s, flushes_issued, class_latency) = qos_exchange_times(cfg, Some(mode));
        let slowdown = exchange_s
            .iter()
            .zip(&isolated_s)
            .map(|(&c, &i)| c / i.max(1e-12))
            .collect();
        QosRun { mode: name, exchange_s, slowdown, flushes_issued, class_latency }
    };
    QosBenchResult {
        unshaped: run(QosMode::Unshaped, "unshaped"),
        shaped: run(QosMode::Shaped, "shaped"),
        isolated_s,
        topology,
        core_bw,
    }
}

fn dist_json(v: &[f64]) -> Json {
    // One sort serves every order statistic; percentiles stay
    // bit-identical to the nearest-rank free functions.
    let mut s = Summary::of(v);
    let mut o = BTreeMap::new();
    o.insert("p50".into(), Json::Num(s.p50()));
    o.insert("p95".into(), Json::Num(s.p95()));
    o.insert("p99".into(), Json::Num(s.p99()));
    o.insert("max".into(), Json::Num(s.max()));
    o.insert("mean".into(), Json::Num(s.mean()));
    Json::Obj(o)
}

fn qos_json(cfg: &QosBenchConfig, r: &QosBenchResult) -> Json {
    let run_json = |run: &QosRun| {
        let mut o = BTreeMap::new();
        o.insert("mode".into(), Json::Str(run.mode.into()));
        o.insert("flushes_issued".into(), Json::Num(run.flushes_issued as f64));
        o.insert("slowdown".into(), dist_json(&run.slowdown));
        o.insert("exchange_s".into(), dist_json(&run.exchange_s));
        let mut classes = BTreeMap::new();
        for cl in &run.class_latency {
            let mut c = BTreeMap::new();
            c.insert("n".into(), Json::Num(cl.n as f64));
            c.insert("p50_s".into(), Json::Num(cl.p50));
            c.insert("p95_s".into(), Json::Num(cl.p95));
            c.insert("p99_s".into(), Json::Num(cl.p99));
            classes.insert(cl.class.name().into(), Json::Obj(c));
        }
        o.insert("class_latency_s".into(), Json::Obj(classes));
        Json::Obj(o)
    };
    let mut scenario = BTreeMap::new();
    scenario.insert("topology".into(), Json::Str(r.topology.clone()));
    scenario.insert("backplane_bw".into(), Json::Num(r.core_bw));
    scenario.insert("halo_bytes".into(), Json::Num(QOS_HALO_BYTES));
    scenario.insert("flush_bytes".into(), Json::Num(QOS_FLUSH_BYTES));
    scenario.insert("victim_nodes".into(), Json::Num(QOS_VICTIM_NODES as f64));
    scenario.insert("flusher_nodes".into(), Json::Num(QOS_FLUSHERS.len() as f64));
    scenario.insert("flush_depth".into(), Json::Num(QOS_FLUSH_DEPTH as f64));
    let mut shaping = BTreeMap::new();
    shaping.insert("flush_ceiling_frac".into(), Json::Num(cfg.flush_ceiling_frac));
    shaping.insert("exchange_floor_frac".into(), Json::Num(cfg.exchange_floor_frac));
    shaping.insert("exchange_weight".into(), Json::Num(cfg.exchange_weight));
    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("qos".into()));
    doc.insert("schema_version".into(), Json::Num(1.0));
    doc.insert("seed".into(), Json::Num(cfg.seed as f64));
    doc.insert("threads".into(), Json::Num(cfg.threads as f64));
    doc.insert("iterations".into(), Json::Num(cfg.iterations as f64));
    doc.insert("scenario".into(), Json::Obj(scenario));
    doc.insert("shaping".into(), Json::Obj(shaping));
    doc.insert("isolated_exchange_s".into(), dist_json(&r.isolated_s));
    doc.insert(
        "runs".into(),
        Json::Arr(vec![run_json(&r.unshaped), run_json(&r.shaped)]),
    );
    doc.insert("p99_slowdown_unshaped".into(), Json::Num(r.unshaped.p99_slowdown()));
    doc.insert("p99_slowdown_shaped".into(), Json::Num(r.shaped.p99_slowdown()));
    doc.insert(
        "p99_improvement".into(),
        Json::Num(r.unshaped.p99_slowdown() / r.shaped.p99_slowdown().max(1e-12)),
    );
    Json::Obj(doc)
}

/// The `repro bench qos` exhibit: a latency-sensitive job's exchange
/// phases measured against a neighbor's sustained checkpoint flushes on
/// an oversubscribed shared fabric, unshaped vs shaped (CkptFlush
/// ceiling + Exchange floor/weight), reporting per-iteration p50/p95/p99
/// slowdown and a per-class latency summary.  Returns the printable
/// exhibits plus the `BENCH_qos.json` trajectory document.
pub fn qos_report(cfg: &QosBenchConfig) -> (Vec<Exhibit>, Json) {
    let r = qos_points(cfg);
    let json = qos_json(cfg, &r);

    let mut fig = Figure::new(
        "QoS: exchange-phase slowdown per iteration (victim vs flushing neighbor)",
        "iteration",
        "x isolated",
    );
    for run in [&r.unshaped, &r.shaped] {
        let mut s = Series::new(run.mode);
        for (i, &x) in run.slowdown.iter().enumerate() {
            s.push(i as f64, x);
        }
        fig.add(s);
    }

    let mut t = KvTable::new("QoS summary (exchange slowdown vs isolated, nearest-rank)");
    t.row(
        "scenario",
        format!(
            "{} victim ranks vs {} flushers x {} deep, {} fabric core ({})",
            QOS_VICTIM_NODES,
            QOS_FLUSHERS.len(),
            QOS_FLUSH_DEPTH,
            fmt_bw(r.core_bw),
            r.topology
        ),
    );
    t.row(
        "shaping",
        format!(
            "flush ceiling {:.0}% + exchange floor {:.0}% + weight {:.0}x",
            cfg.flush_ceiling_frac * 100.0,
            cfg.exchange_floor_frac * 100.0,
            cfg.exchange_weight
        ),
    );
    t.row(
        "isolated exchange",
        format!(
            "p50 {} / p99 {}",
            fmt_time(p50(&r.isolated_s)),
            fmt_time(p99(&r.isolated_s))
        ),
    );
    for run in [&r.unshaped, &r.shaped] {
        t.row(
            format!("{} slowdown", run.mode),
            format!(
                "p50 {:.2}x / p95 {:.2}x / p99 {:.2}x ({} flushes)",
                p50(&run.slowdown),
                p95(&run.slowdown),
                run.p99_slowdown(),
                run.flushes_issued
            ),
        );
    }
    t.row(
        "p99 improvement",
        format!(
            "{:.2}x lower with shaping",
            r.unshaped.p99_slowdown() / r.shaped.p99_slowdown().max(1e-12)
        ),
    );

    let mut ct = KvTable::new("QoS per-class flow latency (shaped contended run)");
    for cl in &r.shaped.class_latency {
        ct.row(
            cl.class.name(),
            format!(
                "{} flows: p50 {} / p95 {} / p99 {}",
                cl.n,
                fmt_time(cl.p50),
                fmt_time(cl.p95),
                fmt_time(cl.p99)
            ),
        );
    }

    (vec![Exhibit::Fig(fig), Exhibit::Table(t), Exhibit::Table(ct)], json)
}

// ----------------------------------------------------------------------
// `repro bench obs` — observability overhead exhibit (DESIGN.md §17)
// ----------------------------------------------------------------------

/// Configuration of the observability-overhead exhibit: one co-scheduled
/// fleet run, measured untraced and traced with identical inputs.
#[derive(Debug, Clone)]
pub struct ObsBenchConfig {
    /// Co-scheduled jobs in the measured fleet.
    pub jobs: usize,
    /// Seeds the synthetic job mix (and is echoed into the artifact).
    pub seed: u64,
    /// Wall-clock repetitions per arm; the minimum is reported, which
    /// filters scheduler noise the way the scale bench does.
    pub repeats: usize,
    /// Span ring capacity for the traced arm.
    pub span_cap: usize,
}

impl Default for ObsBenchConfig {
    fn default() -> Self {
        Self {
            jobs: 8,
            seed: DEFAULT_SEED,
            repeats: 3,
            span_cap: crate::obs::DEFAULT_SPAN_CAP,
        }
    }
}

/// One fleet run of the obs bench scenario (QoS admission on, so the
/// trace exercises the qos lane too).
fn obs_fleet(cfg: &ObsBenchConfig, trace: Option<crate::obs::Trace>) -> FleetReport {
    let fleet_cfg = FleetConfig { seed: cfg.seed, qos: true, trace, ..FleetConfig::default() };
    let jobs = sched::synthetic_jobs(cfg.jobs, cfg.seed);
    sched::run_fleet(jobs, fleet_cfg).expect("synthetic jobs fit the prototype machine")
}

/// The `repro bench obs` exhibit: the same fleet run untraced and traced
/// (same seed, same jobs), pinning the observability overhead — traced
/// vs untraced wall time — and re-checking the zero-perturbation
/// invariant (reports byte-identical).  Returns the printable exhibit
/// plus the `BENCH_obs.json` document.  Wall-clock fields are
/// machine-dependent (never asserted in tests); the span/counter shape
/// is byte-deterministic for a fixed seed.
pub fn obs_report(cfg: &ObsBenchConfig) -> (Vec<Exhibit>, Json) {
    assert!(cfg.repeats > 0, "obs bench needs at least one repetition");
    assert!(cfg.jobs > 0, "obs bench needs at least one job");
    let mut wall_off = f64::INFINITY;
    let mut report_off = None;
    for _ in 0..cfg.repeats {
        let (r, w) = microbench::time_once(|| obs_fleet(cfg, None));
        wall_off = wall_off.min(w.as_secs_f64());
        report_off = Some(r);
    }
    let mut wall_on = f64::INFINITY;
    let mut report_on = None;
    let mut trace = None;
    for _ in 0..cfg.repeats {
        let tr = crate::obs::Trace::with_capacity(cfg.span_cap);
        let (r, w) = microbench::time_once(|| obs_fleet(cfg, Some(tr.clone())));
        wall_on = wall_on.min(w.as_secs_f64());
        report_on = Some(r);
        trace = Some(tr);
    }
    let trace = trace.expect("repeats >= 1");
    let report_off = report_off.expect("repeats >= 1").to_json().to_pretty_string();
    let report_on = report_on.expect("repeats >= 1").to_json().to_pretty_string();
    let identical = report_on == report_off;
    let wall_off = wall_off.max(1e-9);
    let wall_on = wall_on.max(1e-9);
    let overhead = wall_on / wall_off - 1.0;
    let spans = trace.span_count();
    let dropped = trace.dropped();

    let mut doc = BTreeMap::new();
    doc.insert("bench".into(), Json::Str("obs".into()));
    doc.insert("schema_version".into(), Json::Num(1.0));
    doc.insert("seed".into(), Json::Num(cfg.seed as f64));
    doc.insert("jobs".into(), Json::Num(cfg.jobs as f64));
    doc.insert("repeats".into(), Json::Num(cfg.repeats as f64));
    doc.insert("span_cap".into(), Json::Num(cfg.span_cap as f64));
    doc.insert("spans".into(), Json::Num(spans as f64));
    doc.insert("spans_dropped".into(), Json::Num(dropped as f64));
    doc.insert("sim_events_total".into(), Json::Num(trace.counter("sim_events_total")));
    doc.insert(
        "scr_ckpts_begun_total".into(),
        Json::Num(trace.counter("scr_ckpts_begun_total")),
    );
    doc.insert("qos_admits_total".into(), Json::Num(trace.counter("qos_admits_total")));
    doc.insert("report_identical_traced_vs_untraced".into(), Json::Bool(identical));
    doc.insert("wall_s_untraced".into(), Json::Num(wall_off));
    doc.insert("wall_s_traced".into(), Json::Num(wall_on));
    doc.insert("overhead_frac".into(), Json::Num(overhead));

    let mut t = KvTable::new("Observability overhead (same fleet traced vs untraced)");
    t.row("fleet", format!("{} jobs, seed {:#x}, qos admission on", cfg.jobs, cfg.seed));
    t.row("spans recorded", format!("{spans} ({dropped} dropped, cap {})", cfg.span_cap));
    t.row(
        "counters",
        format!(
            "{} sim events, {} checkpoints begun, {} qos admits",
            trace.counter("sim_events_total"),
            trace.counter("scr_ckpts_begun_total"),
            trace.counter("qos_admits_total")
        ),
    );
    t.row("untraced wall", fmt_time(wall_off));
    t.row("traced wall", fmt_time(wall_on));
    t.row("overhead", format!("{:.1} %", overhead * 100.0));
    t.row("report identical", if identical { "yes (zero perturbation)" } else { "NO" });
    (vec![Exhibit::Table(t)], Json::Obj(doc))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Shape assertions live in rust/tests/integration_apps.rs; here we only
    // smoke the cheap harnesses to keep unit-test time low.

    #[test]
    fn tables_render() {
        for ex in table1().iter().chain(table2().iter()).chain(table3().iter()) {
            assert!(!ex.render().is_empty());
        }
    }

    #[test]
    fn fig3_series_shapes() {
        let ex = fig3();
        assert_eq!(ex.len(), 2);
        if let Exhibit::Fig(bw) = &ex[0] {
            let raw = bw.series_named("EXTOLL best").unwrap();
            let nam = bw.series_named("NAM put").unwrap();
            // Bandwidth grows with message size; NAM close to raw EXTOLL.
            assert!(raw.points.first().unwrap().1 < raw.points.last().unwrap().1);
            let (_, raw_peak) = raw.points.last().unwrap();
            let (_, nam_peak) = nam.points.last().unwrap();
            assert!(nam_peak / raw_peak > 0.9, "nam={nam_peak} raw={raw_peak}");
        } else {
            panic!("fig3[0] should be a figure");
        }
    }

    #[test]
    fn by_name_roundtrip() {
        assert!(by_name("fig9", DEFAULT_SEED).is_some());
        assert!(by_name("fig8-async", 7).is_some());
        assert!(by_name("nope", DEFAULT_SEED).is_none());
    }
}

//! Fleet health monitoring and the resilience policy axis (DESIGN.md §15).
//!
//! The degraded-mode taxonomy ([`crate::system::faults`]) gives the
//! scheduler something fail-stop failures never did: *warning*.  A link
//! that dims or a node that straggles is, in the correlated fault model,
//! a precursor to a kill.  The [`HealthMonitor`] turns those precursors
//! into per-node **suspicion** scores; once a node crosses the threshold
//! it is a *suspect*, and under [`ResiliencePolicy::Proactive`] the
//! scheduler (a) preemptively checkpoints and migrates the job running on
//! it, and (b) steers new allocations away from it.  Under
//! [`ResiliencePolicy::Reactive`] the monitor still watches (the counters
//! feed the bench exhibit) but the scheduler waits for the kill and pays
//! the rollback — the DEEP-ER baseline.
//!
//! Suspicion is **sticky**: the correlated model has no rehabilitation
//! signal, so a node that degraded once stays suspect.  That is the
//! conservative choice for a spare-capacity machine; a decay model is a
//! straightforward extension once the fault model earns one.

use crate::system::faults::FaultKind;

/// How the fleet responds to degraded-mode precursors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResiliencePolicy {
    /// DEEP-ER baseline: wait for the kill, roll back to the last
    /// verified checkpoint, requeue.
    Reactive,
    /// Health-triggered: on suspicion, preemptively checkpoint the
    /// afflicted job, migrate it to healthy nodes, and avoid suspects in
    /// future placements.
    Proactive,
}

impl ResiliencePolicy {
    pub const ALL: [ResiliencePolicy; 2] =
        [ResiliencePolicy::Reactive, ResiliencePolicy::Proactive];

    pub fn name(&self) -> &'static str {
        match self {
            ResiliencePolicy::Reactive => "reactive",
            ResiliencePolicy::Proactive => "proactive",
        }
    }

    /// Parse a CLI spelling (`--resilience reactive|proactive`).
    pub fn parse(s: &str) -> crate::Result<ResiliencePolicy> {
        Ok(match s {
            "reactive" => ResiliencePolicy::Reactive,
            "proactive" => ResiliencePolicy::Proactive,
            other => anyhow::bail!("unknown resilience policy {other}; try reactive or proactive"),
        })
    }
}

/// Per-node suspicion accumulator.
#[derive(Debug, Clone)]
pub struct HealthMonitor {
    suspicion: Vec<f64>,
    threshold: f64,
}

impl HealthMonitor {
    /// Default suspicion threshold: one strong precursor (degradation) or
    /// two weak ones (corruptions) make a node suspect.
    pub const DEFAULT_THRESHOLD: f64 = 1.0;

    pub fn new(nodes: usize) -> Self {
        Self { suspicion: vec![0.0; nodes], threshold: Self::DEFAULT_THRESHOLD }
    }

    /// Record a precursor on `node`; returns whether the node is (now)
    /// suspect.
    pub fn observe(&mut self, node: usize, kind: &FaultKind) -> bool {
        self.suspicion[node] += kind.suspicion_weight();
        self.is_suspect(node)
    }

    pub fn is_suspect(&self, node: usize) -> bool {
        self.suspicion[node] >= self.threshold
    }

    /// All currently suspect nodes, ascending — the allocation avoid-list.
    pub fn suspects(&self) -> Vec<usize> {
        (0..self.suspicion.len()).filter(|&i| self.is_suspect(i)).collect()
    }

    /// Number of suspect nodes (report/bench counter).
    pub fn suspect_count(&self) -> usize {
        self.suspicion.iter().filter(|&&s| s >= self.threshold).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names_round_trip() {
        for p in ResiliencePolicy::ALL {
            assert_eq!(ResiliencePolicy::parse(p.name()).unwrap(), p);
        }
        assert!(ResiliencePolicy::parse("bogus").is_err());
    }

    #[test]
    fn strong_precursor_makes_node_suspect_immediately() {
        let mut h = HealthMonitor::new(4);
        assert!(!h.is_suspect(2));
        assert!(h.observe(2, &FaultKind::Straggler { factor: 4.0 }));
        assert!(h.is_suspect(2));
        assert_eq!(h.suspects(), vec![2]);
        assert_eq!(h.suspect_count(), 1);
    }

    #[test]
    fn weak_precursors_accumulate() {
        let mut h = HealthMonitor::new(4);
        assert!(!h.observe(1, &FaultKind::CkptCorrupt), "0.5 < threshold");
        assert!(h.observe(1, &FaultKind::CkptCorrupt), "1.0 reaches threshold");
        // Sticky: no rehabilitation.
        assert!(h.is_suspect(1));
        assert_eq!(h.suspects(), vec![1]);
    }

    #[test]
    fn suspects_listed_ascending() {
        let mut h = HealthMonitor::new(8);
        h.observe(5, &FaultKind::LinkDegrade { fraction: 0.2 });
        h.observe(3, &FaultKind::Straggler { factor: 2.0 });
        assert_eq!(h.suspects(), vec![3, 5]);
    }
}

//! Scheduling policies: FCFS with head reservation, and conservative
//! backfill over a capacity profile.
//!
//! Everything here is pure bookkeeping over node *counts* (nodes of one
//! kind are fungible — the machine ledger picks concrete indices), which
//! keeps the policies unit-testable without a simulator.
//!
//! **FCFS-with-head-reservation**: jobs start strictly in queue order;
//! the first job that does not fit blocks everything behind it (its
//! implicit reservation is "all future releases until I fit").
//!
//! **Conservative backfill**: every queued job, in queue order, gets a
//! reservation at the earliest time the *capacity profile* (current free
//! nodes + estimated releases of running jobs + reservations of jobs
//! ahead in the queue) can hold it for its whole estimated runtime.  Jobs
//! whose reservation is "now" start immediately.  Because **every** job
//! ahead holds a reservation (not just the head, as in EASY backfill), a
//! backfilled job can never displace any earlier-queued job: with exact
//! runtime estimates no job starts later than it would under FCFS — the
//! invariant `rust/tests/prop_sched.rs` checks.

use crate::sim::SimTime;

/// Which batch policy drives the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Fcfs,
    Backfill,
}

impl Policy {
    pub const ALL: [Policy; 2] = [Policy::Fcfs, Policy::Backfill];

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fcfs => "fcfs",
            Policy::Backfill => "backfill",
        }
    }

    /// Parse a CLI spelling (`--policy fcfs|backfill`).
    pub fn parse(s: &str) -> crate::Result<Policy> {
        Ok(match s {
            "fcfs" => Policy::Fcfs,
            "backfill" => Policy::Backfill,
            other => anyhow::bail!("unknown policy {other}; try fcfs or backfill"),
        })
    }
}

/// A node request split across the two partitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeReq {
    pub cluster: usize,
    pub booster: usize,
}

impl NodeReq {
    pub(crate) fn fits(&self, free: NodeReq) -> bool {
        self.cluster <= free.cluster && self.booster <= free.booster
    }
}

/// One queued job, as the policy sees it.
#[derive(Debug, Clone, Copy)]
pub struct QueuedReq {
    pub id: usize,
    pub req: NodeReq,
    /// Estimated remaining runtime (the scheduler's walltime estimate).
    pub est: SimTime,
}

/// One running job's held nodes and estimated completion.
#[derive(Debug, Clone, Copy)]
pub struct RunningRes {
    pub req: NodeReq,
    pub est_end: SimTime,
}

/// Step-wise capacity profile: `pts[i]` is the available (cluster,
/// booster) node count from `pts[i].0` until the next breakpoint; the
/// last segment extends to infinity.  Breakpoints only exist where
/// capacity changes (releases and reservation edges).
///
/// Rebuilt from scratch every call — O(queue²) per planning round.
/// Production dispatch runs on [`super::profile::ProfileBook`] instead;
/// this structure is retained as the **differential oracle** the
/// incremental profile is checked against (`rust/tests/prop_profile.rs`
/// plus the debug assert in the scheduler's dispatch round).
#[derive(Debug)]
pub struct CapProfile {
    pts: Vec<(SimTime, isize, isize)>,
}

impl CapProfile {
    /// Profile seen at `now`: `free` nodes immediately, plus each running
    /// job's nodes returning at its estimated end.
    pub fn new(now: SimTime, free: NodeReq, running: &[RunningRes]) -> Self {
        let mut p = Self { pts: vec![(now, free.cluster as isize, free.booster as isize)] };
        for r in running {
            p.add(r.est_end.max(now), r.req.cluster as isize, r.req.booster as isize);
        }
        p
    }

    /// Index of the segment containing `t` (t >= first breakpoint).
    fn seg_at(&self, t: SimTime) -> usize {
        // Profiles are tiny (O(jobs)); a linear scan keeps this simple.
        let mut i = 0;
        while i + 1 < self.pts.len() && self.pts[i + 1].0 <= t {
            i += 1;
        }
        i
    }

    /// Insert a breakpoint at `t` (no capacity change), returning its
    /// segment index.
    fn ensure_breakpoint(&mut self, t: SimTime) -> usize {
        let i = self.seg_at(t);
        if self.pts[i].0 == t {
            return i;
        }
        let (_, c, b) = self.pts[i];
        self.pts.insert(i + 1, (t, c, b));
        i + 1
    }

    /// Add (or with negative values, subtract) capacity from `t` onwards.
    fn add(&mut self, t: SimTime, c: isize, b: isize) {
        let i = self.ensure_breakpoint(t);
        for p in &mut self.pts[i..] {
            p.1 += c;
            p.2 += b;
        }
    }

    /// Does `req` fit in every segment overlapping `[t0, t0 + dur)`?
    /// Half-open: a breakpoint at exactly `t0 + dur` is outside the
    /// window (the `>= t1` break below), so a reservation ending at `t`
    /// never conflicts with one starting at `t`.
    pub fn fits_window(&self, t0: SimTime, dur: SimTime, req: NodeReq) -> bool {
        let t1 = t0 + dur;
        let mut i = self.seg_at(t0);
        loop {
            let (_, c, b) = self.pts[i];
            if (req.cluster as isize) > c || (req.booster as isize) > b {
                return false;
            }
            i += 1;
            if i >= self.pts.len() || self.pts[i].0 >= t1 {
                return true;
            }
        }
    }

    /// Earliest `t >= now` at which `req` fits for `dur` — always exists
    /// because the final segment carries every release and reservation
    /// returned (callers validate that `req` fits the whole machine).
    pub fn earliest_fit(&self, now: SimTime, dur: SimTime, req: NodeReq) -> SimTime {
        if self.fits_window(now, dur, req) {
            return now;
        }
        for &(t, _, _) in &self.pts {
            if t > now && self.fits_window(t, dur, req) {
                return t;
            }
        }
        unreachable!("request exceeds total machine capacity (validated at submit)")
    }

    /// Carve a reservation `[t0, t0 + dur)` out of the profile.
    pub fn reserve(&mut self, t0: SimTime, dur: SimTime, req: NodeReq) {
        self.add(t0, -(req.cluster as isize), -(req.booster as isize));
        self.add(t0 + dur, req.cluster as isize, req.booster as isize);
    }
}

/// Decide which queued jobs start **now**.  `queue` must already be in
/// queue order (priority, then submission); the returned ids preserve
/// that order.  `free` is the machine's current unallocated node count
/// per partition; `running` describes the jobs currently holding nodes.
pub fn plan_starts(
    policy: Policy,
    now: SimTime,
    free: NodeReq,
    queue: &[QueuedReq],
    running: &[RunningRes],
) -> Vec<usize> {
    match policy {
        Policy::Fcfs => {
            let mut avail = free;
            let mut starts = Vec::new();
            for q in queue {
                if !q.req.fits(avail) {
                    break; // head reservation: nobody overtakes
                }
                avail.cluster -= q.req.cluster;
                avail.booster -= q.req.booster;
                starts.push(q.id);
            }
            starts
        }
        Policy::Backfill => {
            let mut profile = CapProfile::new(now, free, running);
            let mut starts = Vec::new();
            for q in queue {
                let t = profile.earliest_fit(now, q.est, q.req);
                profile.reserve(t, q.est, q.req);
                if t <= now {
                    starts.push(q.id);
                }
            }
            starts
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(c: usize, b: usize) -> NodeReq {
        NodeReq { cluster: c, booster: b }
    }

    #[test]
    fn fcfs_head_blocks_the_queue() {
        // Head wants 8 of 4 free; the small job behind it fits but must
        // not overtake under FCFS.
        let queue = [
            QueuedReq { id: 0, req: req(8, 0), est: 10.0 },
            QueuedReq { id: 1, req: req(2, 0), est: 1.0 },
        ];
        let running = [RunningRes { req: req(12, 0), est_end: 5.0 }];
        let starts = plan_starts(Policy::Fcfs, 0.0, req(4, 0), &queue, &running);
        assert!(starts.is_empty());
    }

    #[test]
    fn fcfs_starts_in_order_while_it_fits() {
        let queue = [
            QueuedReq { id: 0, req: req(2, 0), est: 10.0 },
            QueuedReq { id: 1, req: req(2, 1), est: 10.0 },
            QueuedReq { id: 2, req: req(8, 0), est: 10.0 },
            QueuedReq { id: 3, req: req(1, 0), est: 10.0 },
        ];
        let starts = plan_starts(Policy::Fcfs, 0.0, req(4, 2), &queue, &[]);
        assert_eq!(starts, vec![0, 1], "id 2 blocks, id 3 must not overtake");
    }

    #[test]
    fn backfill_fills_the_head_shadow() {
        // Head (8 nodes) waits for the running job's release at t=5; the
        // 1-node job ends at t=3 < 5, so it backfills now.
        let queue = [
            QueuedReq { id: 0, req: req(8, 0), est: 10.0 },
            QueuedReq { id: 1, req: req(1, 0), est: 3.0 },
        ];
        let running = [RunningRes { req: req(12, 0), est_end: 5.0 }];
        let starts = plan_starts(Policy::Backfill, 0.0, req(4, 0), &queue, &running);
        assert_eq!(starts, vec![1]);
    }

    #[test]
    fn backfill_never_steals_the_head_reservation() {
        // Same shadow (head starts at t=5 on the released nodes), but the
        // backfill candidate would still be running then *and* its nodes
        // are needed: it must wait.
        let queue = [
            QueuedReq { id: 0, req: req(16, 0), est: 10.0 },
            QueuedReq { id: 1, req: req(2, 0), est: 9.0 },
        ];
        let running = [RunningRes { req: req(12, 0), est_end: 5.0 }];
        let starts = plan_starts(Policy::Backfill, 0.0, req(4, 0), &queue, &running);
        assert!(starts.is_empty(), "candidate overlaps the head reservation");
    }

    #[test]
    fn backfill_uses_nodes_the_head_leaves_over() {
        // Head reserved at t=5 needs only 12 of 16; a long job fitting in
        // the 4 leftover nodes may start now even though it outlives the
        // shadow time.
        let queue = [
            QueuedReq { id: 0, req: req(12, 0), est: 10.0 },
            QueuedReq { id: 1, req: req(4, 0), est: 100.0 },
        ];
        let running = [RunningRes { req: req(12, 0), est_end: 5.0 }];
        let starts = plan_starts(Policy::Backfill, 0.0, req(4, 0), &queue, &running);
        assert_eq!(starts, vec![1]);
    }

    #[test]
    fn backfill_reservations_chain_in_queue_order() {
        // Two big jobs queue behind one runner; the second's reservation
        // must stack *after* the first's, and a small job may only slip
        // into the first gap.
        let queue = [
            QueuedReq { id: 0, req: req(16, 0), est: 10.0 },
            QueuedReq { id: 1, req: req(16, 0), est: 10.0 },
            QueuedReq { id: 2, req: req(4, 0), est: 4.0 },
        ];
        let running = [RunningRes { req: req(16, 0), est_end: 5.0 }];
        let starts = plan_starts(Policy::Backfill, 0.0, req(0, 0), &queue, &running);
        assert!(starts.is_empty(), "4-node job overlaps the t=5 head reservation");
        // With free nodes on the side (12 running, 4 idle) the same small
        // job slips in ahead of both stacked reservations.
        let running2 = [RunningRes { req: req(12, 0), est_end: 5.0 }];
        let starts = plan_starts(Policy::Backfill, 0.0, req(4, 0), &queue, &running2);
        assert_eq!(starts, vec![2], "fits the idle nodes until the t=5 shadow");
    }

    #[test]
    fn both_policies_start_everything_on_an_empty_machine() {
        let queue = [
            QueuedReq { id: 0, req: req(4, 2), est: 10.0 },
            QueuedReq { id: 1, req: req(4, 0), est: 10.0 },
        ];
        for p in Policy::ALL {
            assert_eq!(plan_starts(p, 0.0, req(16, 8), &queue, &[]), vec![0, 1]);
        }
    }

    #[test]
    fn boundary_back_to_back_reservations_do_not_conflict() {
        // Half-open [t0, t0+dur): a full-machine reservation over [0, 5)
        // and a second over [5, 10) coexist; the shared breakpoint t=5
        // belongs to the second window only.
        let mut p = CapProfile::new(0.0, req(4, 0), &[]);
        p.reserve(0.0, 5.0, req(4, 0));
        assert!(
            p.fits_window(5.0, 5.0, req(4, 0)),
            "a window starting exactly where the previous one ends must fit"
        );
        p.reserve(5.0, 5.0, req(4, 0));
        assert!(!p.fits_window(0.0, 1.0, req(1, 0)));
        assert!(!p.fits_window(9.0, 1.0, req(1, 0)));
        assert!(p.fits_window(10.0, 100.0, req(4, 0)));
    }

    #[test]
    fn boundary_earliest_fit_returns_the_shared_breakpoint() {
        // One running job releases the whole machine at t=5; the earliest
        // fit for a full-machine request is exactly the release instant,
        // bit-for-bit — not 5 + epsilon, not the next breakpoint.
        let running = [RunningRes { req: req(4, 0), est_end: 5.0 }];
        let p = CapProfile::new(0.0, req(0, 0), &running);
        let t = p.earliest_fit(0.0, 3.0, req(4, 0));
        assert_eq!(t.to_bits(), 5.0f64.to_bits());
    }

    #[test]
    fn boundary_window_ignores_a_capacity_drop_at_its_end() {
        // Free machine now, a reservation starting at t=5: a window
        // [0, 5) must fit even though capacity vanishes at its endpoint.
        let mut p = CapProfile::new(0.0, req(4, 0), &[]);
        p.reserve(5.0, 10.0, req(4, 0));
        assert!(p.fits_window(0.0, 5.0, req(4, 0)));
        assert_eq!(p.earliest_fit(0.0, 5.0, req(4, 0)), 0.0);
        assert!(!p.fits_window(0.0, 5.0 + 1e-9, req(4, 0)));
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in Policy::ALL {
            assert_eq!(Policy::parse(p.name()).unwrap(), p);
        }
        assert!(Policy::parse("sjf").is_err());
    }
}

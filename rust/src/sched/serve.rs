//! Service mode: an open-arrival fleet at production scale (DESIGN.md
//! §16).
//!
//! The batch fleet ([`super::run_fleet`]) validates co-scheduling by
//! draining a fixed job list — but DEEP-ER's stack was exercised by
//! real codes *arriving continuously* on a shared machine.  `repro
//! serve` reproduces that regime: a Poisson or trace-driven arrival
//! process feeds 10^5–10^6 synthetic jobs through rolling admission (a
//! bounded queue; QoS guarantee budgets still gate dispatch exactly as
//! in batch mode), and the report measures steady-state SLOs — per-class
//! queue-wait percentiles over rolling time windows, utilization, and
//! the rejection rate — rather than closed-batch makespan.
//!
//! Determinism: arrivals come from a seeded [`SplitMix64`] stream (or a
//! validated trace), the loop interleaves arrivals with engine events by
//! racing [`Sim::next_event_time`] against the next arrival timestamp,
//! and the report serializes through the same sorted-key JSON writer as
//! every other exhibit — same seed, byte-identical `BENCH_serve.json`.
//!
//! [`Sim::next_event_time`]: crate::sim::Sim::next_event_time
//! [`SplitMix64`]: crate::sim::rng::SplitMix64

use std::collections::BTreeMap;

use crate::metrics;
use crate::sim::rng::SplitMix64;
use crate::sim::SimTime;
use crate::system::{presets, Machine, MachineSpec};
use crate::util::json::Json;

use super::{synthetic_jobs, FleetConfig, Policy, Scheduler};

/// The arrival process driving service mode.
#[derive(Debug, Clone)]
pub enum ArrivalSpec {
    /// Poisson process: i.i.d. exponential inter-arrival gaps at
    /// `rate_hz` arrivals per second.
    Poisson { rate_hz: f64 },
    /// Trace-driven: explicit arrival offsets in seconds from run start;
    /// must be finite, non-negative and non-decreasing.
    Trace { times: Vec<SimTime> },
}

impl ArrivalSpec {
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalSpec::Poisson { .. } => "poisson",
            ArrivalSpec::Trace { .. } => "trace",
        }
    }

    pub fn rate_hz(&self) -> Option<f64> {
        match self {
            ArrivalSpec::Poisson { rate_hz } => Some(*rate_hz),
            ArrivalSpec::Trace { .. } => None,
        }
    }
}

/// Materialize the first `n` arrival offsets of `spec` (seconds from run
/// start, non-decreasing).  A trace shorter than `n` yields what it has.
pub fn arrival_times(spec: &ArrivalSpec, n: usize, seed: u64) -> crate::Result<Vec<SimTime>> {
    anyhow::ensure!(n > 0, "service mode needs at least one arrival");
    match spec {
        ArrivalSpec::Poisson { rate_hz } => {
            anyhow::ensure!(
                rate_hz.is_finite() && *rate_hz > 0.0,
                "poisson arrival rate must be positive (got {rate_hz})"
            );
            let mut rng = SplitMix64::new(seed ^ 0x5EED_A221);
            let mean = 1.0 / rate_hz;
            let mut t = 0.0;
            Ok((0..n)
                .map(|_| {
                    t += rng.next_exp(mean);
                    t
                })
                .collect())
        }
        ArrivalSpec::Trace { times } => {
            let mut out = times.clone();
            out.truncate(n);
            anyhow::ensure!(!out.is_empty(), "arrival trace is empty");
            let mut prev = 0.0;
            for &t in &out {
                anyhow::ensure!(
                    t.is_finite() && t >= prev,
                    "trace arrivals must be finite, non-negative and sorted (got {t} after {prev})"
                );
                prev = t;
            }
            Ok(out)
        }
    }
}

/// Service-mode configuration on top of the fleet config.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub fleet: FleetConfig,
    pub arrivals: ArrivalSpec,
    /// How many arrivals to draw before closing the door (each is then
    /// admitted or rejected; admitted jobs always run to completion).
    pub jobs: usize,
    /// Admission bound: an arrival finding this many jobs already queued
    /// is rejected (counted per class in the report).
    pub queue_cap: usize,
    /// Rolling SLO window width, seconds.
    pub window_s: f64,
    /// Report-size bound: raw windows are merged into at most this many
    /// groups before serialization (percentiles recomputed over the
    /// merged samples, never averaged from per-window percentiles).
    pub max_windows: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            fleet: FleetConfig {
                policy: Policy::Backfill,
                reserve_depth: 32,
                track_allocations: false,
                ..FleetConfig::default()
            },
            arrivals: ArrivalSpec::Poisson { rate_hz: 0.05 },
            jobs: 2000,
            queue_cap: 1024,
            window_s: 600.0,
            max_windows: 64,
        }
    }
}

/// Busy node-seconds bucketed into fixed windows, fed incrementally as
/// jobs release nodes — so service-mode utilization needs no post-hoc
/// allocation log (which is exactly the memory the mode cannot afford).
#[derive(Debug)]
pub(super) struct UtilWindows {
    window_s: f64,
    busy: Vec<f64>,
}

impl UtilWindows {
    fn new(window_s: f64) -> Self {
        Self { window_s, busy: Vec::new() }
    }

    /// Credit `nodes` busy nodes over `[from, until)` to the windows the
    /// span crosses.
    pub(super) fn add_span(&mut self, from: SimTime, until: SimTime, nodes: usize) {
        if !(until > from) || nodes == 0 {
            return;
        }
        let w = self.window_s;
        let last = (until / w) as usize;
        if self.busy.len() <= last {
            self.busy.resize(last + 1, 0.0);
        }
        let mut i = (from / w) as usize;
        let mut t = from;
        while t < until {
            let end = ((i + 1) as f64 * w).min(until);
            if end <= t {
                // Degenerate float spacing (window edge indistinguishable
                // from t): credit the remainder here and stop.
                self.busy[i.min(last)] += nodes as f64 * (until - t);
                break;
            }
            self.busy[i] += nodes as f64 * (end - t);
            t = end;
            i += 1;
        }
    }
}

/// Per-class steady-state outcome (class = `min(priority, 2)`, so the
/// synthetic workload's three priority levels map onto three classes).
#[derive(Debug, Clone)]
pub struct ClassReport {
    pub class: u32,
    pub arrived: usize,
    pub rejected: usize,
    pub completed: usize,
    pub p50_wait_s: f64,
    pub p99_wait_s: f64,
    pub max_wait_s: f64,
}

/// One (possibly merged) rolling window in the report.
#[derive(Debug, Clone)]
pub struct WindowReport {
    pub t0_s: f64,
    pub t1_s: f64,
    pub arrivals: usize,
    pub rejected: usize,
    /// Busy node-seconds over (total nodes x window span); the final
    /// window's span is clipped to the makespan.
    pub utilization: f64,
    /// Per-class p99 queue wait of the jobs whose first start fell in
    /// this window; None when the class saw no starts here.
    pub p99_wait_s: [Option<f64>; 3],
}

/// Outcome of one service-mode run.
#[derive(Debug)]
pub struct ServeReport {
    pub policy: Policy,
    pub seed: u64,
    pub topology: String,
    pub arrivals: String,
    pub rate_hz: Option<f64>,
    pub jobs_arrived: usize,
    pub jobs_admitted: usize,
    pub jobs_rejected: usize,
    pub jobs_completed: usize,
    pub queue_cap: usize,
    pub window_s: f64,
    pub reserve_depth: usize,
    pub qos: bool,
    /// Last arrival offset (the open-arrival horizon).
    pub horizon_s: f64,
    /// Run-start to last-drain span.
    pub makespan_s: f64,
    pub utilization: f64,
    pub avg_wait_s: f64,
    pub rejection_rate: f64,
    pub classes: Vec<ClassReport>,
    pub windows: Vec<WindowReport>,
    pub failures_injected: usize,
    pub idle_failures: usize,
    pub requeues: usize,
    pub migrations: usize,
    pub flows_cancelled: usize,
    pub sim_events: u64,
    /// QoS grants still outstanding after the drain — must be 0 (a
    /// refund-leak tripwire, surfaced rather than asserted so the
    /// artifact records it).
    pub qos_grants_open: usize,
}

impl ServeReport {
    /// Deterministic JSON (sorted keys, shortest-round-trip floats):
    /// byte-identical across same-seed runs — the acceptance property.
    pub fn to_json(&self) -> Json {
        let mut doc = BTreeMap::new();
        doc.insert("bench".into(), Json::Str("serve".into()));
        doc.insert("schema_version".into(), Json::Num(1.0));
        doc.insert("policy".into(), Json::Str(self.policy.name().into()));
        doc.insert("seed".into(), Json::Num(self.seed as f64));
        doc.insert("topology".into(), Json::Str(self.topology.clone()));
        doc.insert("arrivals".into(), Json::Str(self.arrivals.clone()));
        doc.insert("rate_hz".into(), self.rate_hz.map(Json::Num).unwrap_or(Json::Null));
        doc.insert("jobs_arrived".into(), Json::Num(self.jobs_arrived as f64));
        doc.insert("jobs_admitted".into(), Json::Num(self.jobs_admitted as f64));
        doc.insert("jobs_rejected".into(), Json::Num(self.jobs_rejected as f64));
        doc.insert("jobs_completed".into(), Json::Num(self.jobs_completed as f64));
        doc.insert("queue_cap".into(), Json::Num(self.queue_cap as f64));
        doc.insert("window_s".into(), Json::Num(self.window_s));
        doc.insert(
            "reserve_depth".into(),
            if self.reserve_depth == usize::MAX {
                Json::Null
            } else {
                Json::Num(self.reserve_depth as f64)
            },
        );
        doc.insert("qos".into(), Json::Bool(self.qos));
        doc.insert("horizon_s".into(), Json::Num(self.horizon_s));
        doc.insert("makespan_s".into(), Json::Num(self.makespan_s));
        doc.insert("utilization".into(), Json::Num(self.utilization));
        doc.insert("avg_wait_s".into(), Json::Num(self.avg_wait_s));
        doc.insert("rejection_rate".into(), Json::Num(self.rejection_rate));
        doc.insert("failures_injected".into(), Json::Num(self.failures_injected as f64));
        doc.insert("idle_failures".into(), Json::Num(self.idle_failures as f64));
        doc.insert("requeues".into(), Json::Num(self.requeues as f64));
        doc.insert("migrations".into(), Json::Num(self.migrations as f64));
        doc.insert("flows_cancelled".into(), Json::Num(self.flows_cancelled as f64));
        doc.insert("sim_events".into(), Json::Num(self.sim_events as f64));
        doc.insert("qos_grants_open".into(), Json::Num(self.qos_grants_open as f64));
        doc.insert(
            "classes".into(),
            Json::Arr(
                self.classes
                    .iter()
                    .map(|c| {
                        let mut o = BTreeMap::new();
                        o.insert("class".into(), Json::Num(c.class as f64));
                        o.insert("arrived".into(), Json::Num(c.arrived as f64));
                        o.insert("rejected".into(), Json::Num(c.rejected as f64));
                        o.insert("completed".into(), Json::Num(c.completed as f64));
                        o.insert("p50_wait_s".into(), Json::Num(c.p50_wait_s));
                        o.insert("p99_wait_s".into(), Json::Num(c.p99_wait_s));
                        o.insert("max_wait_s".into(), Json::Num(c.max_wait_s));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        doc.insert(
            "windows".into(),
            Json::Arr(
                self.windows
                    .iter()
                    .map(|w| {
                        let mut o = BTreeMap::new();
                        o.insert("t0_s".into(), Json::Num(w.t0_s));
                        o.insert("t1_s".into(), Json::Num(w.t1_s));
                        o.insert("arrivals".into(), Json::Num(w.arrivals as f64));
                        o.insert("rejected".into(), Json::Num(w.rejected as f64));
                        o.insert("utilization".into(), Json::Num(w.utilization));
                        o.insert(
                            "p99_wait_s".into(),
                            Json::Arr(
                                w.p99_wait_s
                                    .iter()
                                    .map(|p| p.map(Json::Num).unwrap_or(Json::Null))
                                    .collect(),
                            ),
                        );
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        Json::Obj(doc)
    }
}

/// Raw per-window accumulator before merge-down.
#[derive(Debug, Default, Clone)]
struct WinBuf {
    arrivals: usize,
    rejected: usize,
    waits: [Vec<f64>; 3],
}

impl Scheduler {
    /// Run the open-arrival service loop to drain and report.  The
    /// scheduler must be freshly built (no jobs submitted) — service
    /// mode owns the whole submission stream.
    pub fn run_serve(mut self, scfg: &ServeConfig) -> crate::Result<ServeReport> {
        anyhow::ensure!(self.jobs.is_empty(), "run_serve needs a fresh scheduler");
        anyhow::ensure!(scfg.queue_cap > 0, "queue cap must be positive");
        anyhow::ensure!(
            scfg.window_s.is_finite() && scfg.window_s > 0.0,
            "window width must be positive"
        );
        anyhow::ensure!(scfg.max_windows > 0, "report needs at least one window");
        let arrivals = arrival_times(&scfg.arrivals, scfg.jobs, self.cfg.seed)?;
        let mut specs = synthetic_jobs(arrivals.len(), self.cfg.seed).into_iter();
        self.serve_util = Some(UtilWindows::new(scfg.window_s));
        let t0 = self.m.sim.now();
        let events0 = self.m.sim.events();
        let mut next_arr = 0usize;
        // Arrival offset per admitted job, indexed by job id (service
        // mode owns every submit, so ids are dense admission indices).
        let mut arr_of_job: Vec<SimTime> = Vec::new();
        let mut rejects: Vec<(SimTime, u32)> = Vec::new();
        loop {
            self.process_due_faults();
            self.process_due_failures();
            // Admit (or reject) every arrival the clock has reached.
            let now = self.m.sim.now();
            let mut admitted_any = false;
            while next_arr < arrivals.len() && t0 + arrivals[next_arr] <= now {
                let at = arrivals[next_arr];
                next_arr += 1;
                let spec = specs.next().expect("one spec per arrival");
                if self.queue.len() >= scfg.queue_cap {
                    if let Some(tr) = self.m.sim.trace() {
                        tr.add("serve_rejected_total", 1.0);
                        tr.instant(
                            now,
                            0,
                            crate::obs::lane::SERVE,
                            "serve.reject",
                            vec![("class", u64::from(spec.priority.min(2)).into())],
                        );
                    }
                    rejects.push((at, spec.priority.min(2)));
                    continue;
                }
                self.submit(spec)?;
                arr_of_job.push(at);
                admitted_any = true;
            }
            if admitted_any {
                self.dispatch();
            }
            if let Some(id) = self.ready_job() {
                self.advance_job(id);
                continue;
            }
            if self.running.is_empty() && !self.queue.is_empty() {
                self.dispatch();
                assert!(
                    !self.running.is_empty(),
                    "service stall: a queued job cannot be placed on an empty machine"
                );
                continue;
            }
            // Nothing ready: race the engine's next event against the
            // next arrival, and advance whichever comes first.
            let next_arrival = arrivals.get(next_arr).map(|&a| t0 + a);
            match (self.m.sim.next_event_time(), next_arrival) {
                (Some(te), Some(ta)) if ta <= te => self.m.sim.advance_until(ta),
                (Some(_), _) => {
                    if !self.m.sim.step_event() {
                        panic!("service deadlock: a pending event refused to step");
                    }
                }
                (None, Some(ta)) => {
                    assert!(self.running.is_empty(), "running jobs with no engine events");
                    self.m.sim.advance_until(ta);
                }
                (None, None) => {
                    assert!(self.running.is_empty(), "running jobs with no engine events");
                    break;
                }
            }
        }
        assert!(self.queue.is_empty(), "drained service loop left jobs queued");
        Ok(self.into_serve_report(scfg, t0, events0, &arrivals, &arr_of_job, &rejects))
    }

    fn into_serve_report(
        self,
        scfg: &ServeConfig,
        t0: SimTime,
        events0: u64,
        arrivals: &[SimTime],
        arr_of_job: &[SimTime],
        rejects: &[(SimTime, u32)],
    ) -> ServeReport {
        let makespan = self.m.sim.now() - t0;
        let horizon = *arrivals.last().expect("at least one arrival");
        let w = scfg.window_s;
        let nwin = ((makespan / w).ceil() as usize).max(1);
        let clamp = |i: usize| i.min(nwin - 1);

        let mut arrived_c = [0usize; 3];
        let mut rejected_c = [0usize; 3];
        let mut completed_c = [0usize; 3];
        let mut waits_c: [Vec<f64>; 3] = Default::default();
        let mut bufs = vec![WinBuf::default(); nwin];
        for (j, &at) in self.jobs.iter().zip(arr_of_job) {
            let c = j.spec.priority.min(2) as usize;
            arrived_c[c] += 1;
            completed_c[c] += 1;
            let fs = j.first_start.expect("drained job has started") - t0;
            let wait = (fs - at).max(0.0);
            waits_c[c].push(wait);
            bufs[clamp((at / w) as usize)].arrivals += 1;
            // SLO attribution: a wait is charged to the window the job
            // finally *started* in — the window where the queueing delay
            // materialized into service.
            bufs[clamp((fs / w) as usize)].waits[c].push(wait);
        }
        for &(at, c) in rejects {
            arrived_c[c as usize] += 1;
            rejected_c[c as usize] += 1;
            let b = &mut bufs[clamp((at / w) as usize)];
            b.arrivals += 1;
            b.rejected += 1;
        }

        let classes = (0u32..3)
            .map(|c| {
                let waits = &waits_c[c as usize];
                let (p50, p99, max) = if waits.is_empty() {
                    (0.0, 0.0, 0.0)
                } else {
                    // One sort serves all three statistics
                    // ([`metrics::Summary`]), bit-identical to the old
                    // per-call nearest-rank `percentile`.
                    let mut s = metrics::Summary::of(waits);
                    (s.p50(), s.p99(), s.max())
                };
                ClassReport {
                    class: c,
                    arrived: arrived_c[c as usize],
                    rejected: rejected_c[c as usize],
                    completed: completed_c[c as usize],
                    p50_wait_s: p50,
                    p99_wait_s: p99,
                    max_wait_s: max,
                }
            })
            .collect();

        // Merge raw windows down to at most max_windows adjacent groups;
        // percentiles are recomputed over the merged samples.
        let busy = match &self.serve_util {
            Some(u) => u.busy.clone(),
            None => Vec::new(),
        };
        let total_nodes = self.m.nodes.len() as f64;
        let group = nwin.div_ceil(scfg.max_windows);
        let mut windows = Vec::new();
        let mut gi = 0;
        while gi < nwin {
            let ge = (gi + group).min(nwin);
            let t0_s = gi as f64 * w;
            let t1_s = (ge as f64 * w).min(makespan);
            let span = (t1_s - t0_s).max(1e-12);
            let mut arrivals_n = 0;
            let mut rejected_n = 0;
            let mut busy_s = 0.0;
            let mut waits: [Vec<f64>; 3] = Default::default();
            for i in gi..ge {
                arrivals_n += bufs[i].arrivals;
                rejected_n += bufs[i].rejected;
                busy_s += busy.get(i).copied().unwrap_or(0.0);
                for c in 0..3 {
                    waits[c].extend_from_slice(&bufs[i].waits[c]);
                }
            }
            let p99_wait_s = [0, 1, 2].map(|c: usize| {
                (!waits[c].is_empty()).then(|| metrics::Summary::of(&waits[c]).p99())
            });
            if let Some(tr) = self.m.sim.trace() {
                tr.add("serve_windows_total", 1.0);
                tr.instant(
                    t0 + t1_s,
                    0,
                    crate::obs::lane::SERVE,
                    "serve.window",
                    vec![("arrivals", arrivals_n.into()), ("rejected", rejected_n.into())],
                );
            }
            windows.push(WindowReport {
                t0_s,
                t1_s,
                arrivals: arrivals_n,
                rejected: rejected_n,
                utilization: busy_s / (total_nodes * span),
                p99_wait_s,
            });
            gi = ge;
        }

        let admitted = self.jobs.len();
        let rejected = rejects.len();
        let arrived = admitted + rejected;
        let node_seconds: f64 = self.jobs.iter().map(|j| j.node_seconds).sum();
        let avg_wait = if admitted > 0 {
            waits_c.iter().flatten().sum::<f64>() / admitted as f64
        } else {
            0.0
        };
        ServeReport {
            policy: self.cfg.policy,
            seed: self.cfg.seed,
            topology: self.m.spec.topology.label(),
            arrivals: scfg.arrivals.name().into(),
            rate_hz: scfg.arrivals.rate_hz(),
            jobs_arrived: arrived,
            jobs_admitted: admitted,
            jobs_rejected: rejected,
            jobs_completed: self.finish_order.len(),
            queue_cap: scfg.queue_cap,
            window_s: scfg.window_s,
            reserve_depth: self.cfg.reserve_depth,
            qos: self.cfg.qos,
            horizon_s: horizon,
            makespan_s: makespan,
            utilization: if makespan > 0.0 {
                node_seconds / (total_nodes * makespan)
            } else {
                0.0
            },
            avg_wait_s: avg_wait,
            rejection_rate: if arrived > 0 { rejected as f64 / arrived as f64 } else { 0.0 },
            classes,
            windows,
            failures_injected: self.failures_injected,
            idle_failures: self.idle_failures,
            requeues: self.jobs.iter().map(|j| j.requeues).sum(),
            migrations: self.migrations,
            flows_cancelled: self.jobs.iter().map(|j| j.exec.stats.flows_cancelled).sum(),
            sim_events: self.m.sim.events() - events0,
            qos_grants_open: self.qos_policy.as_ref().map(|p| p.grant_count()).unwrap_or(0),
        }
    }
}

/// Build `mspec`, and run the service loop on it — the topology-generic
/// entry point behind `repro serve --topology`.
pub fn serve_fleet_on(mspec: MachineSpec, scfg: ServeConfig) -> crate::Result<ServeReport> {
    let mut m = Machine::build(mspec);
    m.sim.set_threads(scfg.fleet.threads.max(1));
    let s = Scheduler::new(m, scfg.fleet.clone());
    s.run_serve(&scfg)
}

/// Service loop on the DEEP-ER prototype machine.
pub fn serve_fleet(scfg: ServeConfig) -> crate::Result<ServeReport> {
    serve_fleet_on(presets::deep_er(), scfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_arrivals_are_sorted_positive_and_deterministic() {
        let a = arrival_times(&ArrivalSpec::Poisson { rate_hz: 0.5 }, 200, 7).unwrap();
        let b = arrival_times(&ArrivalSpec::Poisson { rate_hz: 0.5 }, 200, 7).unwrap();
        assert_eq!(a.len(), 200);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
        let mut prev = 0.0;
        for &t in &a {
            assert!(t.is_finite() && t > prev, "gaps must be positive");
            prev = t;
        }
        // Mean inter-arrival of a 0.5 Hz process is 2 s; 200 samples land
        // within a loose factor-of-2 band.
        let mean_gap = a.last().unwrap() / 200.0;
        assert!(mean_gap > 1.0 && mean_gap < 4.0, "mean gap {mean_gap}");
        let c = arrival_times(&ArrivalSpec::Poisson { rate_hz: 0.5 }, 200, 8).unwrap();
        assert!(a.iter().zip(&c).any(|(x, y)| x != y), "seed must matter");
    }

    #[test]
    fn trace_arrivals_validate() {
        let ok = ArrivalSpec::Trace { times: vec![0.0, 1.0, 1.0, 5.0] };
        assert_eq!(arrival_times(&ok, 3, 1).unwrap(), vec![0.0, 1.0, 1.0]);
        let unsorted = ArrivalSpec::Trace { times: vec![1.0, 0.5] };
        assert!(arrival_times(&unsorted, 2, 1).is_err());
        let negative = ArrivalSpec::Trace { times: vec![-1.0] };
        assert!(arrival_times(&negative, 1, 1).is_err());
        let nan = ArrivalSpec::Trace { times: vec![f64::NAN] };
        assert!(arrival_times(&nan, 1, 1).is_err());
    }

    #[test]
    fn util_windows_split_spans_and_conserve_node_seconds() {
        let mut u = UtilWindows::new(10.0);
        u.add_span(5.0, 25.0, 2); // 2 nodes, 20 s -> windows 0,1,2
        assert_eq!(u.busy.len(), 3);
        assert!((u.busy[0] - 10.0).abs() < 1e-9);
        assert!((u.busy[1] - 20.0).abs() < 1e-9);
        assert!((u.busy[2] - 10.0).abs() < 1e-9);
        let total: f64 = u.busy.iter().sum();
        assert!((total - 40.0).abs() < 1e-9, "node-seconds must be conserved");
        u.add_span(3.0, 3.0, 4); // empty span: no-op
        assert!((u.busy.iter().sum::<f64>() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn tiny_serve_run_drains_and_reports() {
        let scfg = ServeConfig {
            jobs: 12,
            arrivals: ArrivalSpec::Poisson { rate_hz: 0.05 },
            ..ServeConfig::default()
        };
        let r = serve_fleet(scfg).unwrap();
        assert_eq!(r.jobs_arrived, 12);
        assert_eq!(r.jobs_admitted, 12, "capacious queue rejects nothing");
        assert_eq!(r.jobs_completed, 12);
        assert_eq!(r.jobs_rejected, 0);
        assert_eq!(r.qos_grants_open, 0);
        assert!(r.makespan_s >= r.horizon_s, "drain cannot precede the last arrival");
        assert!(r.utilization > 0.0 && r.utilization <= 1.0);
        assert_eq!(r.classes.len(), 3);
        assert_eq!(
            r.classes.iter().map(|c| c.arrived).sum::<usize>(),
            r.jobs_arrived
        );
        assert!(!r.windows.is_empty() && r.windows.len() <= 64);
        // Window series covers [0, makespan] without gaps.
        assert_eq!(r.windows[0].t0_s, 0.0);
        for p in r.windows.windows(2) {
            assert_eq!(p[0].t1_s.to_bits(), p[1].t0_s.to_bits());
        }
        assert!((r.windows.last().unwrap().t1_s - r.makespan_s).abs() < 1e-9);
    }

    #[test]
    fn tiny_queue_cap_rejects_arrivals() {
        // A burst trace: everything lands at t=0 against a queue bound of
        // 2 — most arrivals must bounce, and the report must say so.
        let scfg = ServeConfig {
            jobs: 10,
            arrivals: ArrivalSpec::Trace { times: vec![0.0; 10] },
            queue_cap: 2,
            ..ServeConfig::default()
        };
        let r = serve_fleet(scfg).unwrap();
        assert_eq!(r.jobs_arrived, 10);
        assert!(r.jobs_rejected > 0, "a 2-deep queue cannot absorb a 10-burst");
        assert_eq!(r.jobs_admitted + r.jobs_rejected, 10);
        assert_eq!(r.jobs_completed, r.jobs_admitted);
        assert!((r.rejection_rate - r.jobs_rejected as f64 / 10.0).abs() < 1e-12);
    }
}

//! Multi-tenant fleet scheduler: co-scheduled Cluster-Booster workloads
//! on one shared machine.
//!
//! The Cluster-Booster value proposition (paper Section II) is
//! *co-scheduling*: heterogeneous applications share one machine, its
//! BeeGFS/NAM I/O tiers and its failure domain.  This module is the batch
//! system on top of everything below it: it admits a queue of
//! [`JobSpec`]s, allocates nodes from one shared [`Machine`] without
//! oversubscription ([`Machine::try_allocate`] is the audited ledger),
//! and drives all running jobs **concurrently on a single virtual
//! clock** through the resumable [`JobExec`] state machine — so
//! checkpoint flushes, halo exchanges and NAM parity pulls of different
//! tenants genuinely contend for the shared BeeGFS servers, NAM boards
//! and fabric instead of running back-to-back.
//!
//! Two policies ([`policy::Policy`]): **FCFS with head reservation** and
//! **conservative backfill** over a capacity profile.  Failure handling
//! follows the requeue/restart resilience pattern (Hukerikar &
//! Engelmann's pattern language): a node loss kills the owning job,
//! triggers its SCR/multilevel restart path, rolls it back to its best
//! settled checkpoint iteration and requeues it; the scheduler then
//! re-dispatches it under the active policy.
//!
//! Determinism: one event-driven control loop over [`Sim::step_event`],
//! jobs advanced in (completion-time, job-id) order, failures drawn from
//! a seeded plan — the same seed reproduces the fleet bit-for-bit
//! (pinned by `rust/tests/integration_fleet.rs`).
//!
//! [`Sim::step_event`]: crate::sim::Sim::step_event

pub mod health;
pub mod policy;
pub mod profile;
pub mod serve;

use std::collections::{BTreeMap, BTreeSet};

use crate::apps::driver::{CkptBackendRef, JobExec};
use crate::apps::{AppProfile, IterationJob, RunStats};
use crate::qos;
use crate::scr::multilevel::{MultiLevelConfig, MultiLevelScr};
use crate::scr::{Scr, Strategy};
use crate::sim::rng::SplitMix64;
use crate::sim::{ResId, SimTime, TrafficClass};
use crate::system::failure::{Failure, FailurePlan};
use crate::system::faults::{Fault, FaultEvent, FaultKind, FaultPlan};
use crate::system::{presets, Machine, MachineSpec, NodeKind, NodeSpec};
use crate::util::json::Json;
use self::health::HealthMonitor;
use self::policy::{NodeReq, QueuedReq, RunningRes};
use self::profile::ProfileBook;
pub use self::health::ResiliencePolicy;
pub use self::policy::Policy;
pub use self::serve::{serve_fleet, serve_fleet_on, ArrivalSpec, ServeConfig, ServeReport};

/// How a fleet job protects itself against failures.
#[derive(Debug, Clone)]
pub enum CkptStrategy {
    /// Unprotected: any failure reruns the job from iteration 0.
    None,
    /// One single-level SCR strategy.
    Scr(Strategy),
    /// The multi-level checkpointer (L1 local / L2 strategy / L3 global),
    /// optionally with the background flush.
    MultiLevel(MultiLevelConfig),
}

impl CkptStrategy {
    fn name(&self) -> String {
        match self {
            CkptStrategy::None => "none".into(),
            CkptStrategy::Scr(s) => s.name().into(),
            CkptStrategy::MultiLevel(c) => format!(
                "multilevel/{}{}",
                c.l2_strategy.name(),
                if c.async_flush { "+async" } else { "" }
            ),
        }
    }
}

/// A guarantee a fleet job may declare: an aggregate rate floor for one
/// traffic class across the fabric's core switching resources.  Admitted
/// against the scheduler's guarantee budget at dispatch ([`qos::Policy`]);
/// installed into the engine as per-(resource, class) floors while the
/// job runs.  On the flat prototype the core is the single backplane and
/// the floor lands there verbatim; on zoo topologies it is split across
/// the core resources (rails, uplinks, split switches) in proportion to
/// their capacity.
#[derive(Debug, Clone, Copy)]
pub struct QosDemand {
    pub class: TrafficClass,
    /// Requested aggregate floor over the fabric core, bytes/s.
    pub backplane_floor: f64,
}

/// One job submission: application profile, node split across the two
/// partitions, checkpoint discipline, priority, and an optional QoS
/// guarantee demand.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    pub profile: AppProfile,
    /// Nodes requested from the Cluster partition.
    pub cluster_nodes: usize,
    /// Nodes requested from the Booster partition.
    pub booster_nodes: usize,
    pub iterations: usize,
    /// Checkpoint every `cp_interval` iterations (0 disables).
    pub cp_interval: usize,
    pub ckpt: CkptStrategy,
    /// Larger runs earlier; ties broken by submission order.
    pub priority: u32,
    /// Declared I/O guarantee; consulted only when the fleet runs with
    /// QoS enabled ([`FleetConfig::qos`]).
    pub qos: Option<QosDemand>,
}

/// Walltime estimate the backfill reservations are built from: exact for
/// the compute part (each node's CPU is private, so compute never
/// contends across jobs), doubled for the contention-dependent exchange
/// and checkpoint terms so the estimate stays an upper bound in ordinary
/// mixes — which is what the conservative-backfill no-delay guarantee
/// leans on.  `from_iter` estimates the *remaining* runtime of a
/// partially executed (requeued) job.
pub fn estimate_runtime(spec: &JobSpec, m: &MachineSpec, from_iter: usize) -> SimTime {
    // x / 1.0 is bit-identical to x in IEEE arithmetic, so the healthy
    // path through the scaled form reproduces the historical estimate
    // exactly — fault-free runs keep their old planning inputs.
    estimate_runtime_scaled(spec, m, from_iter, 1.0, 1.0)
}

/// [`estimate_runtime`] under degraded node capacity: `compute_scale`
/// and `link_scale` are the victim allocation's *current* effective
/// fractions of spec compute and NIC bandwidth (1.0 when healthy, e.g.
/// 0.25 under a 4x straggler).  The compute term stretches by
/// 1/compute_scale and the exchange term by 1/link_scale; the checkpoint
/// term is left unscaled — it drains to the node-local device, which the
/// fault taxonomy never degrades.  This is what the per-round est-end
/// refresh feeds the backfill profile so reservations track degradation
/// instead of planning against healthy-speed release times.
pub fn estimate_runtime_scaled(
    spec: &JobSpec,
    m: &MachineSpec,
    from_iter: usize,
    compute_scale: f64,
    link_scale: f64,
) -> SimTime {
    let iters = spec.iterations.saturating_sub(from_iter) as f64;
    if iters == 0.0 {
        return 0.0;
    }
    let mut peak = f64::INFINITY;
    if spec.cluster_nodes > 0 {
        peak = peak.min(m.cluster.peak_flops);
    }
    if spec.booster_nodes > 0 {
        if let Some(b) = &m.booster {
            peak = peak.min(b.peak_flops);
        }
    }
    assert!(peak.is_finite(), "job requests no schedulable partition");
    // Heterogeneous pools: bound the exchange and checkpoint terms by the
    // *slowest requested* partition's NIC and fastest local device, not
    // unconditionally the cluster's (on the prototype both partitions are
    // identical, so this is a no-op there).
    let dev_bw = |ns: &NodeSpec| {
        ns.nvme
            .as_ref()
            .or(ns.ramdisk.as_ref())
            .or(ns.hdd.as_ref())
            .map(|d| d.write_bw)
            .unwrap_or(1e9)
    };
    let mut nic_bw = f64::INFINITY;
    let mut ckpt_bw = f64::INFINITY;
    if spec.cluster_nodes > 0 {
        nic_bw = nic_bw.min(m.cluster.nic_bw);
        ckpt_bw = ckpt_bw.min(dev_bw(&m.cluster));
    }
    if spec.booster_nodes > 0 {
        if let Some(b) = &m.booster {
            nic_bw = nic_bw.min(b.nic_bw);
            ckpt_bw = ckpt_bw.min(dev_bw(b));
        }
    }
    let p = &spec.profile;
    let t_compute =
        p.flops_per_iter_per_node / (p.cpu_efficiency.clamp(1e-3, 1.0) * peak) / compute_scale;
    let n_nodes = (spec.cluster_nodes + spec.booster_nodes) as f64;
    let t_exch = if p.halo_bytes > 0.0 && n_nodes > 1.0 {
        2.0 * p.halo_bytes / nic_bw / link_scale
    } else {
        0.0
    };
    let cps = if spec.cp_interval == 0 || matches!(spec.ckpt, CkptStrategy::None) {
        0.0
    } else {
        (iters / spec.cp_interval as f64).floor()
    };
    let t_ckpt = 4.0 * p.ckpt_bytes_per_node / ckpt_bw;
    // The tiny relative inflation keeps the estimate an upper bound under
    // floating-point drift on the exactly-predictable compute-only path.
    (iters * (t_compute + t_exch) + cps * t_ckpt) * (1.0 + 1e-9) + 1e-9
}

/// The per-job checkpoint machinery the scheduler owns (the [`JobExec`]
/// borrows it as a [`CkptBackendRef`] on every advance).
#[derive(Debug)]
enum CkptBackend {
    None,
    Scr(Scr),
    Multi(MultiLevelScr),
}

impl CkptBackend {
    fn of(strategy: &CkptStrategy) -> Self {
        match strategy {
            CkptStrategy::None => CkptBackend::None,
            CkptStrategy::Scr(s) => CkptBackend::Scr(Scr::new(*s)),
            CkptStrategy::MultiLevel(cfg) => CkptBackend::Multi(MultiLevelScr::new(cfg.clone())),
        }
    }

    fn as_backend_ref(&mut self) -> CkptBackendRef<'_> {
        match self {
            CkptBackend::None => CkptBackendRef::None,
            CkptBackend::Scr(s) => CkptBackendRef::Scr(s),
            CkptBackend::Multi(ml) => CkptBackendRef::Multi(ml),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum JobStatus {
    Queued,
    Running,
    Done,
}

/// Outcome of one [`Scheduler::start_job`] attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum StartResult {
    Started,
    /// QoS admission rejected the job's guarantee demand.
    NoGrant,
    /// The machine could not place the requested node split.
    NoNodes,
}

#[derive(Debug)]
struct JobState {
    spec: JobSpec,
    exec: JobExec,
    backend: CkptBackend,
    status: JobStatus,
    enqueued_at: SimTime,
    first_start: Option<SimTime>,
    finished_at: Option<SimTime>,
    wait_time: SimTime,
    requeues: usize,
    held: Vec<usize>,
    bind_at: SimTime,
    est_end: SimTime,
    /// Iteration count at the last completed-iteration boundary, and the
    /// simulation time that boundary was crossed — the anchor the
    /// per-round est-end refresh extrapolates from.  Anchoring at the
    /// boundary (not `now`) keeps the refreshed estimate an upper bound
    /// mid-iteration, which the backfill no-delay invariant leans on.
    progress_iter: usize,
    progress_at: SimTime,
    node_seconds: f64,
    open_seg: Option<usize>,
    /// Holds an admitted QoS grant (floors installed in the engine).
    granted: bool,
    /// Evacuated by a proactive migration: the next bind must charge the
    /// state-transfer restore before the job resumes.
    migrated: bool,
}

/// One contiguous interval during which a job held a concrete node set —
/// the audit trail `rust/tests/prop_sched.rs` checks for
/// oversubscription.
#[derive(Debug, Clone)]
pub struct AllocSegment {
    pub job: usize,
    pub nodes: Vec<usize>,
    pub from: SimTime,
    pub until: SimTime,
}

/// Fleet-level configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub policy: Policy,
    /// Seeds the failure schedule (and is echoed into the report).
    pub seed: u64,
    /// Exponential per-node MTBF across the whole machine; None disables
    /// failure injection.
    pub mtbf_node: Option<f64>,
    /// Horizon the failure schedule is sampled over.
    pub failure_horizon: SimTime,
    /// Explicit failure plan (tests); wins over `mtbf_node`.  Only the
    /// time-keyed entries are consumed, and `Failure::node` is a
    /// **machine-global** node index here (not a job-list index as in
    /// the per-job driver plans).
    pub failure_plan: Option<FailurePlan>,
    /// Enable traffic-class QoS: jobs' [`JobSpec::qos`] demands are
    /// admitted against a backplane guarantee budget at dispatch
    /// ([`QOS_BUDGET_FRAC`] of its capacity), and admitted floors are
    /// installed into the engine while the job runs.
    pub qos: bool,
    /// Worker count handed to the engine ([`crate::sim::Sim::set_threads`]) for
    /// closed-horizon regions.  The scheduler's own loop polls jobs
    /// between single events — a standing merge barrier — so fleet runs
    /// are serial today regardless; the knob is plumbed so the `--threads`
    /// surface is uniform across `repro run`/`fleet`/`bench` (DESIGN.md
    /// section 14).  1 keeps the engine bit-identical to the
    /// pre-partition behavior.
    pub threads: usize,
    /// Degraded-mode fault schedule ([`crate::system::faults`]): link
    /// degradations, stragglers and checkpoint corruption, with the
    /// correlated fail-stop kills merged into the failure stream.  None
    /// keeps the fleet byte-identical to the taxonomy-free scheduler.
    pub fault_plan: Option<FaultPlan>,
    /// How the fleet responds to degraded-mode precursors
    /// ([`health::ResiliencePolicy`]); irrelevant without a fault plan.
    pub resilience: ResiliencePolicy,
    /// How many queued jobs each backfill planning round sees (and
    /// reserves for).  `usize::MAX` (the default) plans the whole queue
    /// in one round — the historical batch behavior, bit-identical.
    /// Service mode sets a small window so per-round cost is bounded by
    /// the window, not the 10^5-job queue; windowing is conservative
    /// (beyond-window jobs hold no reservation but also cannot start, so
    /// they delay nobody) and [`Scheduler::dispatch`] keeps planning
    /// rounds going while they make progress.
    pub reserve_depth: usize,
    /// Record the per-allocation audit trail ([`AllocSegment`]).  On by
    /// default (the oversubscription property tests read it); service
    /// mode turns it off so memory stays bounded over 10^6 allocations.
    pub track_allocations: bool,
    /// Observability sink ([`crate::obs::Trace`], DESIGN.md section 17):
    /// installed into the engine at construction so every layer (sim,
    /// scr, sched, qos, serve) records spans and metrics on the virtual
    /// clock.  None (the default) disables all recording — untraced
    /// fleet runs stay byte-identical to the pre-observability
    /// scheduler, pinned by `rust/tests/integration_obs.rs`.
    pub trace: Option<crate::obs::Trace>,
}

/// Fraction of the backplane capacity grantable as QoS floors under
/// [`FleetConfig::qos`] — the rest is always left to best-effort
/// traffic, so guarantees can never starve it outright.
pub const QOS_BUDGET_FRAC: f64 = 0.5;

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            policy: Policy::Fcfs,
            seed: 0xDEE9E5,
            mtbf_node: None,
            failure_horizon: 1e7,
            failure_plan: None,
            qos: false,
            threads: 1,
            fault_plan: None,
            resilience: ResiliencePolicy::Reactive,
            reserve_depth: usize::MAX,
            track_allocations: true,
            trace: None,
        }
    }
}

/// Degraded-mode outcome of a fleet run; present only when a fault plan
/// was active (so no-fault reports stay byte-identical to the
/// taxonomy-free scheduler's).
#[derive(Debug, Clone)]
pub struct ResilienceSummary {
    /// Active [`ResiliencePolicy`] name.
    pub policy: &'static str,
    /// Proactive evacuations performed (checkpoint + re-dispatch).
    pub migrations: usize,
    /// Iterations re-executed after rollbacks, summed over all jobs —
    /// the wasted-work metric the reactive/proactive comparison is about.
    pub wasted_iterations: usize,
    /// Nodes over the suspicion threshold at the end of the run.
    pub suspects: usize,
    /// Per-mode counts of faults actually applied before the fleet
    /// drained (scheduled faults past the makespan never fire).
    pub link_degrades: usize,
    pub stragglers: usize,
    pub corruptions: usize,
}

/// Per-job outcome in the fleet report.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub id: usize,
    pub name: String,
    pub app: &'static str,
    pub ckpt: String,
    pub priority: u32,
    pub cluster: usize,
    pub booster: usize,
    pub iterations: usize,
    pub stats: RunStats,
    pub requeues: usize,
    pub first_start: SimTime,
    pub finished_at: SimTime,
    pub wait_time: SimTime,
}

/// Outcome of one fleet run.
#[derive(Debug)]
pub struct FleetReport {
    pub policy: Policy,
    pub seed: u64,
    pub mtbf_node: Option<f64>,
    pub jobs: Vec<JobReport>,
    /// Job ids in completion order (a golden-determinism anchor).
    pub finish_order: Vec<usize>,
    pub makespan: SimTime,
    /// Allocated node-seconds over (total nodes x makespan).
    pub utilization: f64,
    pub avg_wait: SimTime,
    /// Failures that hit an allocated node (killed a job).
    pub failures_injected: usize,
    /// Failures that landed on idle nodes (no job to kill).
    pub idle_failures: usize,
    /// Events the shared simulator processed (per-`Sim`, so concurrent
    /// test binaries cannot pollute it the way the process-wide counter
    /// could).
    pub sim_events: u64,
    pub allocations: Vec<AllocSegment>,
    /// Whether QoS admission/guarantees were active for this run.
    pub qos: bool,
    /// Total flows of doomed phase attempts cancelled at failure/requeue
    /// time across all jobs (the §11.4 fix's observable).
    pub flows_cancelled: usize,
    /// Canonical label of the machine's fabric topology (`"flat"` for the
    /// prototype presets; a zoo name like `"split:8,16"` otherwise).
    pub topology: String,
    /// Degraded-mode outcome; Some only when a fault plan was active.
    pub resilience: Option<ResilienceSummary>,
}

impl FleetReport {
    /// Deterministic JSON summary (object keys sorted, floats via the
    /// shortest round-trip formatting): byte-identical across same-seed
    /// runs, which is exactly what the golden test compares.
    pub fn to_json(&self) -> Json {
        let mut doc = BTreeMap::new();
        doc.insert("bench".into(), Json::Str("fleet".into()));
        doc.insert("schema_version".into(), Json::Num(1.0));
        doc.insert("policy".into(), Json::Str(self.policy.name().into()));
        doc.insert("seed".into(), Json::Num(self.seed as f64));
        doc.insert(
            "mtbf_node_s".into(),
            self.mtbf_node.map(Json::Num).unwrap_or(Json::Null),
        );
        doc.insert("makespan_s".into(), Json::Num(self.makespan));
        doc.insert("utilization".into(), Json::Num(self.utilization));
        doc.insert("avg_wait_s".into(), Json::Num(self.avg_wait));
        doc.insert("failures_injected".into(), Json::Num(self.failures_injected as f64));
        doc.insert("idle_failures".into(), Json::Num(self.idle_failures as f64));
        doc.insert("sim_events".into(), Json::Num(self.sim_events as f64));
        doc.insert("qos".into(), Json::Bool(self.qos));
        doc.insert("flows_cancelled".into(), Json::Num(self.flows_cancelled as f64));
        doc.insert("topology".into(), Json::Str(self.topology.clone()));
        if let Some(rs) = &self.resilience {
            let mut o = BTreeMap::new();
            o.insert("policy".into(), Json::Str(rs.policy.into()));
            o.insert("migrations".into(), Json::Num(rs.migrations as f64));
            o.insert("wasted_iterations".into(), Json::Num(rs.wasted_iterations as f64));
            o.insert("suspects".into(), Json::Num(rs.suspects as f64));
            o.insert("link_degrades".into(), Json::Num(rs.link_degrades as f64));
            o.insert("stragglers".into(), Json::Num(rs.stragglers as f64));
            o.insert("corruptions".into(), Json::Num(rs.corruptions as f64));
            doc.insert("resilience".into(), Json::Obj(o));
        }
        doc.insert(
            "finish_order".into(),
            Json::Arr(self.finish_order.iter().map(|&i| Json::Num(i as f64)).collect()),
        );
        doc.insert(
            "jobs".into(),
            Json::Arr(
                self.jobs
                    .iter()
                    .map(|j| {
                        let mut o = BTreeMap::new();
                        o.insert("id".into(), Json::Num(j.id as f64));
                        o.insert("name".into(), Json::Str(j.name.clone()));
                        o.insert("app".into(), Json::Str(j.app.into()));
                        o.insert("ckpt".into(), Json::Str(j.ckpt.clone()));
                        o.insert("priority".into(), Json::Num(j.priority as f64));
                        o.insert("cluster_nodes".into(), Json::Num(j.cluster as f64));
                        o.insert("booster_nodes".into(), Json::Num(j.booster as f64));
                        o.insert("iterations".into(), Json::Num(j.iterations as f64));
                        o.insert(
                            "iterations_run".into(),
                            Json::Num(j.stats.iterations_run as f64),
                        );
                        o.insert(
                            "checkpoints".into(),
                            Json::Num(j.stats.checkpoints_taken as f64),
                        );
                        o.insert("failures".into(), Json::Num(j.stats.failures_hit as f64));
                        o.insert(
                            "cancelled_flows".into(),
                            Json::Num(j.stats.flows_cancelled as f64),
                        );
                        o.insert("requeues".into(), Json::Num(j.requeues as f64));
                        o.insert("first_start_s".into(), Json::Num(j.first_start));
                        o.insert("finished_s".into(), Json::Num(j.finished_at));
                        o.insert("wait_s".into(), Json::Num(j.wait_time));
                        o.insert("active_s".into(), Json::Num(j.stats.total_time));
                        o.insert("compute_s".into(), Json::Num(j.stats.compute_time));
                        o.insert("ckpt_s".into(), Json::Num(j.stats.ckpt_time));
                        o.insert("blocked_s".into(), Json::Num(j.stats.blocked_time));
                        o.insert("restart_s".into(), Json::Num(j.stats.restart_time));
                        o.insert("ckpt_overhead".into(), Json::Num(j.stats.ckpt_overhead()));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        Json::Obj(doc)
    }
}

/// The batch system: a queue of jobs over one shared machine.
#[derive(Debug)]
pub struct Scheduler {
    m: Machine,
    cfg: FleetConfig,
    jobs: Vec<JobState>,
    /// Queued job ids, ordered by `(!priority, id)` — the bitwise-not
    /// sorts priority descending, ids ascending within a priority, which
    /// is exactly the old sort_queue order.  A BTreeSet keeps admission,
    /// start and requeue at O(log queue) each where the old Vec paid an
    /// O(queue log queue) re-sort per round and an O(queue) retain per
    /// start — fatal at service-mode queue depths.
    queue: BTreeSet<(u32, usize)>,
    /// Running job ids, so the ready-scan and the est-end refresh walk
    /// O(running) entries, not every job ever submitted.
    running: BTreeSet<usize>,
    /// Maintained capacity profile (holds + per-round reservations) the
    /// backfill planner runs on; [`policy::CapProfile`] is rebuilt from
    /// scratch only as its debug-mode differential oracle.
    book: ProfileBook,
    /// Rolling busy-node-seconds windows; Some only in service mode.
    serve_util: Option<serve::UtilWindows>,
    /// Time-ordered failure schedule and the cursor of the next due one.
    failures: Vec<Failure>,
    next_failure: usize,
    failures_injected: usize,
    idle_failures: usize,
    finish_order: Vec<usize>,
    allocations: Vec<AllocSegment>,
    /// QoS admission ledger (present when [`FleetConfig::qos`]); grants
    /// are charged at dispatch and refunded on completion/requeue.
    qos_policy: Option<qos::Policy>,
    /// Degraded-mode faults and their time-sorted apply/revert events
    /// (the cursor mirrors `next_failure`); empty without a fault plan.
    faults: Vec<Fault>,
    fault_events: Vec<FaultEvent>,
    next_fault: usize,
    /// Per-node suspicion accumulated from applied precursors.
    health: HealthMonitor,
    migrations: usize,
    link_degrades_applied: usize,
    stragglers_applied: usize,
    corruptions_applied: usize,
}

impl Scheduler {
    pub fn new(mut m: Machine, cfg: FleetConfig) -> Self {
        let mut failures = match (&cfg.failure_plan, cfg.mtbf_node) {
            (Some(plan), _) => plan.at_times.clone(),
            (None, Some(mtbf)) => {
                FailurePlan::exponential(m.nodes.len(), mtbf, cfg.failure_horizon, cfg.seed)
                    .at_times
            }
            (None, None) => Vec::new(),
        };
        // The degraded-mode plan's correlated kills join the ordinary
        // failure stream — a kill is a kill, whatever foreshadowed it.
        let (faults, fault_events) = match &cfg.fault_plan {
            Some(plan) => {
                failures.extend(plan.kills.iter().copied());
                (plan.faults.clone(), plan.timeline())
            }
            None => (Vec::new(), Vec::new()),
        };
        // The cursor in process_due_failures assumes time order (the
        // exponential sampler already is; explicit test plans may not be).
        failures.sort_by(|a, b| a.at.partial_cmp(&b.at).expect("finite failure times"));
        let qos_policy = cfg.qos.then(|| {
            // One guarantee budget per core switching resource (the flat
            // backplane, or every rail/uplink/split switch of a zoo
            // topology), each a fixed fraction of that resource's capacity.
            let mut p = qos::Policy::new();
            for r in m.fabric.core_resources() {
                p.set_budget(r, QOS_BUDGET_FRAC * m.sim.capacity(r));
            }
            p
        });
        let health = HealthMonitor::new(m.nodes.len());
        // Install the observability sink into the engine before anything
        // records; pid 0 is the system process, with one lane per
        // subsystem (jobs get their own processes at submit).
        if let Some(tr) = &cfg.trace {
            m.sim.set_trace(tr.clone());
            tr.set_process_name(0, "system");
            tr.set_thread_name(0, crate::obs::lane::MAIN, "sched");
            tr.set_thread_name(0, crate::obs::lane::ENGINE, "engine");
            tr.set_thread_name(0, crate::obs::lane::SERVE, "serve");
            tr.set_thread_name(0, crate::obs::lane::QOS, "qos");
        }
        Self {
            m,
            cfg,
            jobs: Vec::new(),
            queue: BTreeSet::new(),
            running: BTreeSet::new(),
            book: ProfileBook::new(),
            serve_util: None,
            failures,
            next_failure: 0,
            failures_injected: 0,
            idle_failures: 0,
            finish_order: Vec::new(),
            allocations: Vec::new(),
            qos_policy,
            faults,
            fault_events,
            next_fault: 0,
            health,
            migrations: 0,
            link_degrades_applied: 0,
            stragglers_applied: 0,
            corruptions_applied: 0,
        }
    }

    /// Shared machine (read access for tests / reporting).
    pub fn machine(&self) -> &Machine {
        &self.m
    }

    /// Admit a job; validated against the machine's partition sizes so a
    /// queued job can always eventually be placed.
    pub fn submit(&mut self, spec: JobSpec) -> crate::Result<usize> {
        anyhow::ensure!(
            spec.cluster_nodes + spec.booster_nodes > 0,
            "job {:?} requests no nodes",
            spec.name
        );
        anyhow::ensure!(
            spec.cluster_nodes <= self.m.spec.n_cluster,
            "job {:?} wants {} cluster nodes of {}",
            spec.name,
            spec.cluster_nodes,
            self.m.spec.n_cluster
        );
        anyhow::ensure!(
            spec.booster_nodes <= self.m.spec.n_booster,
            "job {:?} wants {} booster nodes of {}",
            spec.name,
            spec.booster_nodes,
            self.m.spec.n_booster
        );
        anyhow::ensure!(spec.iterations > 0, "job {:?} has no iterations", spec.name);
        if matches!(spec.ckpt, CkptStrategy::MultiLevel(_)) {
            anyhow::ensure!(
                spec.cp_interval > 0,
                "job {:?}: multilevel checkpointing needs a cadence",
                spec.name
            );
        }
        // A demand a lone job could never be admitted with would stall
        // the queue forever; reject it at the door instead.
        if let (Some(policy), Some(d)) = (&self.qos_policy, &spec.qos) {
            anyhow::ensure!(
                d.backplane_floor > 0.0,
                "job {:?}: qos floor must be positive",
                spec.name
            );
            let budget: f64 = self
                .m
                .fabric
                .core_resources()
                .iter()
                .map(|&r| policy.budget(r).unwrap_or(0.0))
                .sum();
            anyhow::ensure!(
                d.backplane_floor <= budget,
                "job {:?}: demanded floor {:.3e} B/s exceeds the qos budget {:.3e} B/s",
                spec.name,
                d.backplane_floor,
                budget
            );
        }
        let id = self.jobs.len();
        if let Some(tr) = self.m.sim.trace() {
            let pid = id as u32 + 1;
            tr.set_process_name(pid, format!("job{id} {}", spec.name));
            tr.set_thread_name(pid, crate::obs::lane::MAIN, "phase");
            tr.set_thread_name(pid, crate::obs::lane::SCR, "scr");
            tr.set_thread_name(pid, crate::obs::lane::FLUSH, "flush");
            tr.set_thread_name(pid, crate::obs::lane::IO, "io");
            tr.instant(
                self.m.sim.now(),
                pid,
                crate::obs::lane::MAIN,
                "job.submit",
                vec![("priority", u64::from(spec.priority).into())],
            );
            tr.add("sched_jobs_submitted_total", 1.0);
        }
        let job = IterationJob {
            profile: spec.profile.clone(),
            iterations: spec.iterations,
            cp_interval: spec.cp_interval,
            // Fleet failures are machine-level and injected by the
            // scheduler; the per-job plan stays empty.
            failures: FailurePlan::none(),
        };
        let backend = CkptBackend::of(&spec.ckpt);
        self.jobs.push(JobState {
            exec: JobExec::new(job),
            backend,
            spec,
            status: JobStatus::Queued,
            enqueued_at: self.m.sim.now(),
            first_start: None,
            finished_at: None,
            wait_time: 0.0,
            requeues: 0,
            held: Vec::new(),
            bind_at: 0.0,
            est_end: 0.0,
            progress_iter: 0,
            progress_at: 0.0,
            node_seconds: 0.0,
            open_seg: None,
            granted: false,
            migrated: false,
        });
        let key = self.queue_key(id);
        self.queue.insert(key);
        Ok(id)
    }

    /// Run the fleet to completion and report.
    pub fn run(mut self) -> FleetReport {
        let t0 = self.m.sim.now();
        let events0 = self.m.sim.events();
        self.dispatch();
        loop {
            // Precursors before kills: a degradation landing in the same
            // event gap as its correlated kill must be observed first —
            // that ordering is what gives the proactive policy its window.
            self.process_due_faults();
            self.process_due_failures();
            if let Some(id) = self.ready_job() {
                self.advance_job(id);
                continue;
            }
            if self.running.is_empty() {
                if self.queue.is_empty() {
                    break;
                }
                self.dispatch();
                assert!(
                    !self.running.is_empty(),
                    "scheduler stall: a queued job cannot be placed on an empty machine"
                );
                continue;
            }
            if !self.m.sim.step_event() {
                panic!("fleet deadlock: running jobs with no simulation events");
            }
        }
        self.into_report(t0, events0)
    }

    /// The running job whose front op completed earliest (ties by job
    /// id); jobs at a phase boundary count as ready now.  Walks the
    /// running set, so the scan is O(running), not O(jobs ever seen).
    fn ready_job(&mut self) -> Option<usize> {
        let mut best: Option<(SimTime, usize)> = None;
        for &id in &self.running {
            let j = &self.jobs[id];
            let t = match j.exec.front_op() {
                None => self.m.sim.now(),
                Some(op) => match self.m.sim.op_completion(&op) {
                    Some(t) => t,
                    None => continue,
                },
            };
            let better = match best {
                None => true,
                Some((bt, bid)) => t < bt || (t == bt && id < bid),
            };
            if better {
                best = Some((t, id));
            }
        }
        best.map(|(_, id)| id)
    }

    /// Give one ready job control: settle its completed phase, issue the
    /// next one, and finish/release it when it completes.
    fn advance_job(&mut self, id: usize) {
        let done = {
            // Ambient trace pid: everything the job's state machine
            // records (phases, checkpoints, flushes) lands on its own
            // trace process.
            let prev = self.m.sim.set_trace_pid(id as u32 + 1);
            let job = &mut self.jobs[id];
            let JobState { exec, backend, .. } = job;
            let mut bref = backend.as_backend_ref();
            exec.advance(&mut self.m, &mut bref);
            let done = exec.is_done();
            self.m.sim.set_trace_pid(prev);
            done
        };
        {
            // Anchor the progress clock at the last completed iteration
            // so the est-end refresh only extrapolates genuinely
            // remaining work (never the partially executed iteration).
            let now = self.m.sim.now();
            let job = &mut self.jobs[id];
            let it = job.exec.current_iter();
            if it != job.progress_iter {
                job.progress_iter = it;
                job.progress_at = now;
            }
        }
        if !done {
            return;
        }
        let now = self.m.sim.now();
        let (held, seg) = {
            let job = &mut self.jobs[id];
            job.status = JobStatus::Done;
            job.finished_at = Some(now);
            let span_nodes = job.held.len();
            job.node_seconds += span_nodes as f64 * (now - job.bind_at);
            if let Some(w) = &mut self.serve_util {
                w.add_span(job.bind_at, now, span_nodes);
            }
            // A finished job's checkpoint records are dead weight (nothing
            // reads the backend after completion); dropping them keeps
            // service-mode memory bounded over 10^6 jobs.
            job.backend = CkptBackend::None;
            (std::mem::take(&mut job.held), job.open_seg.take())
        };
        self.running.remove(&id);
        self.book.hold_clear(id);
        if let Some(si) = seg {
            self.allocations[si].until = now;
        }
        self.m.release_nodes(&held, id as u64);
        self.release_grant(id);
        if let Some(tr) = self.m.sim.trace() {
            tr.instant(
                now,
                id as u32 + 1,
                crate::obs::lane::MAIN,
                "job.done",
                vec![("requeues", self.jobs[id].requeues.into())],
            );
            tr.add("sched_jobs_finished_total", 1.0);
        }
        self.finish_order.push(id);
        self.dispatch();
    }

    /// Admit job `id`'s QoS demand and install its floor into the
    /// engine.  True when the job holds a grant afterwards (trivially so
    /// without QoS or without a demand); false leaves nothing charged.
    fn try_grant(&mut self, id: usize) -> bool {
        let Some(policy) = &mut self.qos_policy else {
            self.record_admission(id, true, false);
            return true;
        };
        let Some(d) = self.jobs[id].spec.qos else {
            self.record_admission(id, true, false);
            return true;
        };
        // Split the aggregate floor across the fabric's core resources in
        // proportion to their capacity; the single-core (flat) case keeps
        // the floor bit-exact on the backplane.
        let core = self.m.fabric.core_resources();
        let floors: Vec<(ResId, f64)> = if core.len() == 1 {
            vec![(core[0], d.backplane_floor)]
        } else {
            let total: f64 = core.iter().map(|&r| self.m.sim.capacity(r)).sum();
            core.iter()
                .map(|&r| (r, d.backplane_floor * self.m.sim.capacity(r) / total))
                .collect()
        };
        let demand = qos::Demand { class: d.class, floors: floors.clone() };
        if !policy.try_admit(id as u64, &demand) {
            self.record_admission(id, false, true);
            return false;
        }
        for (r, g) in floors {
            self.m.sim.add_class_floor(r, d.class, g);
        }
        self.jobs[id].granted = true;
        self.record_admission(id, true, true);
        true
    }

    /// Record a QoS admission verdict on the system process' qos lane.
    /// Every dispatch admission check records — including the trivial
    /// no-policy / no-demand admits (`demanded` 0) — so a fleet trace
    /// always carries the admission story.
    fn record_admission(&self, id: usize, admitted: bool, demanded: bool) {
        if let Some(tr) = self.m.sim.trace() {
            let now = self.m.sim.now();
            tr.with(|r| {
                r.add(
                    if admitted { "qos_admits_total" } else { "qos_rejects_total" },
                    1.0,
                );
                r.push(crate::obs::SpanEvent {
                    t: now,
                    kind: crate::obs::SpanKind::Instant,
                    pid: 0,
                    tid: crate::obs::lane::QOS,
                    name: if admitted { "qos.admit" } else { "qos.reject" },
                    attrs: vec![("job", id.into()), ("demanded", u64::from(demanded).into())],
                });
            });
        }
    }

    /// Refund job `id`'s QoS grant (completion or requeue) and remove
    /// its floor from the engine.  No-op when no grant is held.
    fn release_grant(&mut self, id: usize) {
        if !self.jobs[id].granted {
            return;
        }
        self.jobs[id].granted = false;
        if let Some(policy) = &mut self.qos_policy {
            if let Some(d) = policy.release(id as u64) {
                for (r, g) in d.floors {
                    self.m.sim.add_class_floor(r, d.class, -g);
                }
            }
        }
    }

    /// Inject every failure whose timestamp the clock has passed.  A
    /// failure on an allocated node kills the owning job: restart I/O
    /// runs as part of the failure cleanup (rolling the job back to its
    /// best settled checkpoint), then the job is requeued and competes
    /// for nodes again under the active policy.
    fn process_due_failures(&mut self) {
        while self.next_failure < self.failures.len() {
            let f = self.failures[self.next_failure];
            if f.at > self.m.sim.now() {
                break;
            }
            self.next_failure += 1;
            let victim = f.node % self.m.nodes.len();
            let Some(owner) = self.m.node_owner(victim) else {
                self.idle_failures += 1;
                continue;
            };
            let id = owner as usize;
            self.failures_injected += 1;
            if let Some(tr) = self.m.sim.trace() {
                tr.add("sched_failures_total", 1.0);
                tr.instant(
                    self.m.sim.now(),
                    0,
                    crate::obs::lane::MAIN,
                    "sched.failure",
                    vec![("node", victim.into()), ("job", id.into())],
                );
            }
            {
                let prev = self.m.sim.set_trace_pid(id as u32 + 1);
                let job = &mut self.jobs[id];
                let JobState { exec, backend, .. } = job;
                let mut bref = backend.as_backend_ref();
                exec.handle_failure(&mut self.m, &mut bref, victim);
                self.m.sim.set_trace_pid(prev);
            }
            self.requeue(id);
        }
    }

    /// Apply every degraded-mode fault event the clock has passed: link
    /// and compute degradations rescale the victim node's resource
    /// capacities in place (and revert at window end); checkpoint
    /// corruption flips the owning job's newest verified record, so its
    /// next restart falls back a level/record deeper.  Every applied
    /// precursor feeds the health monitor; under
    /// [`ResiliencePolicy::Proactive`] a node crossing the suspicion
    /// threshold triggers preemptive checkpoint + migration of the job
    /// running on it.
    fn process_due_faults(&mut self) {
        while self.next_fault < self.fault_events.len() {
            let ev = self.fault_events[self.next_fault];
            if ev.at > self.m.sim.now() {
                break;
            }
            self.next_fault += 1;
            let f = self.faults[ev.fault];
            let victim = f.node % self.m.nodes.len();
            if !ev.apply {
                match f.kind {
                    FaultKind::LinkDegrade { .. } => self.m.set_node_link_scale(victim, 1.0),
                    FaultKind::Straggler { .. } => self.m.set_node_compute_scale(victim, 1.0),
                    FaultKind::CkptCorrupt => {}
                }
                continue;
            }
            match f.kind {
                FaultKind::LinkDegrade { fraction } => {
                    self.m.set_node_link_scale(victim, fraction);
                    self.link_degrades_applied += 1;
                }
                FaultKind::Straggler { factor } => {
                    self.m.set_node_compute_scale(victim, 1.0 / factor);
                    self.stragglers_applied += 1;
                }
                FaultKind::CkptCorrupt => {
                    self.corruptions_applied += 1;
                    if let Some(owner) = self.m.node_owner(victim) {
                        match &mut self.jobs[owner as usize].backend {
                            CkptBackend::Scr(s) => {
                                s.corrupt_latest();
                            }
                            CkptBackend::Multi(ml) => {
                                ml.corrupt_latest();
                            }
                            CkptBackend::None => {}
                        }
                    }
                }
            }
            let suspect = self.health.observe(victim, &f.kind);
            if suspect && self.cfg.resilience == ResiliencePolicy::Proactive {
                self.try_migrate(victim);
            }
        }
    }

    /// Evacuate the job running on a suspect node: take a preemptive
    /// blocking checkpoint on the (degraded) current nodes, then release
    /// and immediately re-dispatch — the proactive allocator avoids
    /// suspects, so the job lands on healthy spares whenever any exist.
    /// The rebind charges a restart read (state transfer); the iteration
    /// counter is untouched, so a migration wastes at most the partial
    /// iteration that was in flight — versus the up-to-a-full-checkpoint-
    /// interval a reactive rollback loses to the correlated kill.
    fn try_migrate(&mut self, suspect: usize) {
        let Some(owner) = self.m.node_owner(suspect) else {
            return;
        };
        let id = owner as usize;
        if self.jobs[id].status != JobStatus::Running {
            return;
        }
        if let Some(tr) = self.m.sim.trace() {
            tr.add("sched_migrations_total", 1.0);
            tr.instant(
                self.m.sim.now(),
                0,
                crate::obs::lane::MAIN,
                "sched.migrate",
                vec![("node", suspect.into()), ("job", id.into())],
            );
        }
        {
            let prev = self.m.sim.set_trace_pid(id as u32 + 1);
            let job = &mut self.jobs[id];
            job.migrated = true;
            let JobState { exec, backend, .. } = job;
            let mut bref = backend.as_backend_ref();
            exec.migrate_checkpoint(&mut self.m, &mut bref);
            self.m.sim.set_trace_pid(prev);
        }
        self.migrations += 1;
        self.requeue(id);
    }

    fn requeue(&mut self, id: usize) {
        let now = self.m.sim.now();
        if let Some(tr) = self.m.sim.trace() {
            tr.add("sched_requeues_total", 1.0);
            tr.instant(
                now,
                0,
                crate::obs::lane::MAIN,
                "sched.requeue",
                vec![("job", id.into())],
            );
        }
        let (held, seg) = {
            let job = &mut self.jobs[id];
            // unbind cancels any phase op still in flight (§11.4): the
            // rolled-back attempt's flows stop contending at kill time.
            let prev = self.m.sim.set_trace_pid(id as u32 + 1);
            let released = job.exec.unbind(&mut self.m);
            self.m.sim.set_trace_pid(prev);
            debug_assert_eq!(released, job.held);
            let span_nodes = job.held.len();
            job.node_seconds += span_nodes as f64 * (now - job.bind_at);
            if let Some(w) = &mut self.serve_util {
                w.add_span(job.bind_at, now, span_nodes);
            }
            job.status = JobStatus::Queued;
            job.enqueued_at = now;
            job.requeues += 1;
            (std::mem::take(&mut job.held), job.open_seg.take())
        };
        self.running.remove(&id);
        self.book.hold_clear(id);
        if let Some(si) = seg {
            self.allocations[si].until = now;
        }
        self.m.release_nodes(&held, id as u64);
        self.release_grant(id);
        let key = self.queue_key(id);
        self.queue.insert(key);
        self.dispatch();
    }

    /// Queue order, encoded in the BTreeSet key: priority (descending —
    /// the bitwise-not reverses the u32 order), then submission id.
    fn queue_key(&self, id: usize) -> (u32, usize) {
        (!self.jobs[id].spec.priority, id)
    }

    /// Recompute every running job's estimated end from its progress
    /// anchor and its held nodes' *current* compute/link scales, and
    /// shift the corresponding profile holds (O(log n) each; unchanged
    /// estimates are a comparison and no map touch).  This is the stale
    /// est-end bugfix: before it, `est_end` was frozen at dispatch, so a
    /// straggler or link degradation left backfill planning against
    /// release times wrong by the slowdown factor — letting backfilled
    /// jobs outlive the real release and delay the queue head.  Healthy
    /// jobs reproduce their dispatch-time estimate bit-for-bit (the
    /// scales are exactly 1.0 and x/1.0 is exact), with only the anchor
    /// bookkeeping differing from the historical path.
    fn refresh_est_ends(&mut self, now: SimTime) {
        debug_assert!(now >= 0.0);
        let ids: Vec<usize> = self.running.iter().copied().collect();
        for id in ids {
            let (cs, ls) = self.held_scales(id);
            let j = &self.jobs[id];
            let est = estimate_runtime_scaled(&j.spec, &self.m.spec, j.progress_iter, cs, ls);
            let est_end = j.progress_at + est;
            let req = NodeReq { cluster: j.spec.cluster_nodes, booster: j.spec.booster_nodes };
            self.jobs[id].est_end = est_end;
            self.book.hold_set(id, est_end, req);
        }
    }

    /// Effective (compute, link) scale of job `id`'s held nodes: the
    /// minimum across the allocation, since the slowest node paces a
    /// bulk-synchronous iteration.  Healthy nodes report exactly 1.0.
    /// The floor guards a dead-but-still-held node (capacity 0) from
    /// producing an infinite estimate.
    fn held_scales(&self, id: usize) -> (f64, f64) {
        let mut cs = 1.0f64;
        let mut ls = 1.0f64;
        for &n in &self.jobs[id].held {
            cs = cs.min(self.m.node_compute_scale(n));
            ls = ls.min(self.m.node_link_scale(n));
        }
        (cs.max(1e-9), ls.max(1e-9))
    }

    /// Ask the policy which queued jobs start now, and start them.  With
    /// a finite [`FleetConfig::reserve_depth`] each planning round only
    /// sees the window at the head of the queue, so when a round makes
    /// progress and jobs beyond the window exist, the next round gets a
    /// chance at the jobs that just slid into view.  The batch default
    /// (whole-queue window) runs exactly one round, as before.
    fn dispatch(&mut self) {
        loop {
            let windowed = self.queue.len() > self.cfg.reserve_depth;
            let started = self.dispatch_round();
            if started == 0 || !windowed {
                return;
            }
        }
    }

    /// One planning round over the maintained profile; returns how many
    /// jobs actually started.
    fn dispatch_round(&mut self) -> usize {
        if self.queue.is_empty() {
            return 0;
        }
        let now = self.m.sim.now();
        self.refresh_est_ends(now);
        let free = NodeReq {
            cluster: self.m.free_count(NodeKind::Cluster),
            booster: self.m.free_count(NodeKind::Booster),
        };
        let queued: Vec<QueuedReq> = self
            .queue
            .iter()
            .take(self.cfg.reserve_depth.max(1))
            .map(|&(_, id)| {
                let j = &self.jobs[id];
                QueuedReq {
                    id,
                    req: NodeReq {
                        cluster: j.spec.cluster_nodes,
                        booster: j.spec.booster_nodes,
                    },
                    est: estimate_runtime(&j.spec, &self.m.spec, j.exec.current_iter()),
                }
            })
            .collect();
        let starts =
            profile::plan_starts_book(self.cfg.policy, now, free, &queued, &mut self.book);
        // Differential oracle: every debug-build planning round is
        // checked against a from-scratch CapProfile rebuild over the
        // same inputs (skipped for big service windows, where the
        // O(queue^2) rebuild would dominate the run).
        #[cfg(debug_assertions)]
        if queued.len() <= 256 {
            let running: Vec<RunningRes> = self
                .running
                .iter()
                .map(|&id| {
                    let j = &self.jobs[id];
                    RunningRes {
                        req: NodeReq {
                            cluster: j.spec.cluster_nodes,
                            booster: j.spec.booster_nodes,
                        },
                        est_end: j.est_end.max(now),
                    }
                })
                .collect();
            let oracle = policy::plan_starts(self.cfg.policy, now, free, &queued, &running);
            debug_assert_eq!(
                starts, oracle,
                "incremental profile diverged from the from-scratch oracle at t={now}"
            );
        }
        // QoS-budget FIFO: once an earlier-queued job's guarantee demand
        // is rejected for lack of budget, later *demanding* jobs must not
        // snatch the refunds out from under it (they would starve it —
        // the budget has no reservation profile the way nodes do).
        // Best-effort jobs charge nothing and may still start.
        let mut budget_blocked = false;
        let mut started = 0;
        for id in starts {
            if budget_blocked && self.jobs[id].spec.qos.is_some() {
                continue;
            }
            match self.start_job(id, now) {
                StartResult::Started => started += 1,
                StartResult::NoGrant => budget_blocked = true,
                StartResult::NoNodes => {}
            }
        }
        if let Some(tr) = self.m.sim.trace() {
            let depth = self.queue.len();
            tr.with(|r| {
                r.add("sched_dispatch_rounds_total", 1.0);
                r.gauge_set("sched_queue_depth", depth as f64);
                r.push(crate::obs::SpanEvent {
                    t: now,
                    kind: crate::obs::SpanKind::Instant,
                    pid: 0,
                    tid: crate::obs::lane::MAIN,
                    name: "sched.dispatch_round",
                    attrs: vec![("queued", depth.into()), ("started", started.into())],
                });
            });
        }
        started
    }

    /// Bind a planned start to concrete nodes.  A non-`Started` outcome
    /// leaves the job queued: `NoNodes` when the machine cannot actually
    /// place it (the backfill profile treats an *overdue* running job's
    /// nodes as free — its estimate under-predicted, e.g. under heavy
    /// checkpoint contention — so a planned start can exceed the real
    /// free count; deferring to the next dispatch, triggered when the
    /// overdue job actually releases, is the correct degradation, not a
    /// panic), or `NoGrant` when QoS admission rejected its guarantee
    /// demand (deferred until a grant is refunded; dispatch uses this to
    /// keep the budget FIFO).
    fn start_job(&mut self, id: usize, now: SimTime) -> StartResult {
        if !self.try_grant(id) {
            return StartResult::NoGrant; // budget exhausted; stays queued
        }
        let (c, b) = (self.jobs[id].spec.cluster_nodes, self.jobs[id].spec.booster_nodes);
        let Some(mut nodes) = self.allocate(NodeKind::Cluster, c, id) else {
            self.release_grant(id);
            return StartResult::NoNodes;
        };
        match self.allocate(NodeKind::Booster, b, id) {
            Some(more) => nodes.extend(more),
            None => {
                self.m.release_nodes(&nodes, id as u64);
                self.release_grant(id);
                return StartResult::NoNodes;
            }
        }
        let seg = if self.cfg.track_allocations {
            self.allocations.push(AllocSegment {
                job: id,
                nodes: nodes.clone(),
                from: now,
                until: f64::INFINITY,
            });
            Some(self.allocations.len() - 1)
        } else {
            None
        };
        let job = &mut self.jobs[id];
        job.wait_time += now - job.enqueued_at;
        if job.first_start.is_none() {
            job.first_start = Some(now);
        }
        job.bind_at = now;
        job.exec.bind(&self.m, nodes.clone());
        job.held = nodes;
        job.status = JobStatus::Running;
        job.open_seg = seg;
        job.progress_iter = job.exec.current_iter();
        job.progress_at = now;
        if job.migrated {
            // Landed after a proactive evacuation: charge the
            // state-transfer restore on the new node set before resuming.
            job.migrated = false;
            let prev = self.m.sim.set_trace_pid(id as u32 + 1);
            let JobState { exec, backend, .. } = job;
            let mut bref = backend.as_backend_ref();
            exec.migrate_restore(&mut self.m, &mut bref);
            self.m.sim.set_trace_pid(prev);
        }
        let key = self.queue_key(id);
        self.queue.remove(&key);
        self.running.insert(id);
        // Scale-aware initial estimate: a job landing on an
        // already-degraded node plans against its real speed from the
        // first round (healthy scales are exactly 1.0, reproducing the
        // historical dispatch-time estimate bit-for-bit).
        let (cs, ls) = self.held_scales(id);
        let j = &self.jobs[id];
        let est = estimate_runtime_scaled(&j.spec, &self.m.spec, j.progress_iter, cs, ls);
        let est_end = now + est;
        let req = NodeReq { cluster: j.spec.cluster_nodes, booster: j.spec.booster_nodes };
        self.jobs[id].est_end = est_end;
        self.book.hold_set(id, est_end, req);
        StartResult::Started
    }

    /// Node allocation behind [`Scheduler::start_job`]: plain
    /// lowest-index-first, except under the proactive policy with suspects
    /// on record, where healthy free nodes are preferred
    /// ([`Machine::try_allocate_avoiding`]).  The no-suspect path calls
    /// [`Machine::try_allocate`] verbatim, keeping fault-free runs
    /// bit-identical to the taxonomy-free scheduler.
    fn allocate(&mut self, kind: NodeKind, count: usize, id: usize) -> Option<Vec<usize>> {
        if self.cfg.resilience == ResiliencePolicy::Proactive {
            let avoid = self.health.suspects();
            if !avoid.is_empty() {
                return self.m.try_allocate_avoiding(kind, count, id as u64, &avoid);
            }
        }
        self.m.try_allocate(kind, count, id as u64)
    }

    fn into_report(self, t0: SimTime, events0: u64) -> FleetReport {
        let makespan = self.m.sim.now() - t0;
        let total_nodes = self.m.nodes.len() as f64;
        let node_seconds: f64 = self.jobs.iter().map(|j| j.node_seconds).sum();
        let utilization = if makespan > 0.0 {
            node_seconds / (total_nodes * makespan)
        } else {
            0.0
        };
        let n_jobs = self.jobs.len().max(1) as f64;
        let avg_wait = self.jobs.iter().map(|j| j.wait_time).sum::<f64>() / n_jobs;
        let flows_cancelled = self.jobs.iter().map(|j| j.exec.stats.flows_cancelled).sum();
        // Wasted work: every iteration executed beyond the job's target
        // was a re-execution forced by a rollback.
        let wasted_iterations: usize = self
            .jobs
            .iter()
            .map(|j| j.exec.stats.iterations_run.saturating_sub(j.spec.iterations))
            .sum();
        let resilience = self.cfg.fault_plan.as_ref().map(|_| ResilienceSummary {
            policy: self.cfg.resilience.name(),
            migrations: self.migrations,
            wasted_iterations,
            suspects: self.health.suspect_count(),
            link_degrades: self.link_degrades_applied,
            stragglers: self.stragglers_applied,
            corruptions: self.corruptions_applied,
        });
        let jobs = self
            .jobs
            .iter()
            .enumerate()
            .map(|(id, j)| JobReport {
                id,
                name: j.spec.name.clone(),
                app: j.spec.profile.name,
                ckpt: j.spec.ckpt.name(),
                priority: j.spec.priority,
                cluster: j.spec.cluster_nodes,
                booster: j.spec.booster_nodes,
                iterations: j.spec.iterations,
                stats: j.exec.stats,
                requeues: j.requeues,
                first_start: j.first_start.unwrap_or(0.0),
                finished_at: j.finished_at.unwrap_or(0.0),
                wait_time: j.wait_time,
            })
            .collect();
        FleetReport {
            policy: self.cfg.policy,
            seed: self.cfg.seed,
            mtbf_node: self.cfg.mtbf_node,
            topology: self.m.spec.topology.label(),
            jobs,
            finish_order: self.finish_order,
            makespan,
            utilization,
            avg_wait,
            failures_injected: self.failures_injected,
            idle_failures: self.idle_failures,
            sim_events: self.m.sim.events() - events0,
            allocations: self.allocations,
            qos: self.cfg.qos,
            flows_cancelled,
            resilience,
        }
    }
}

/// Build `mspec`, submit `specs` and run the fleet — the topology-generic
/// entry point behind `--topology` (any `system::zoo` member works).
pub fn run_fleet_on(
    mspec: MachineSpec,
    specs: Vec<JobSpec>,
    cfg: FleetConfig,
) -> crate::Result<FleetReport> {
    let mut m = Machine::build(mspec);
    m.sim.set_threads(cfg.threads.max(1));
    let mut s = Scheduler::new(m, cfg);
    for spec in specs {
        s.submit(spec)?;
    }
    Ok(s.run())
}

/// Build the DEEP-ER prototype machine, submit `specs` and run the fleet.
pub fn run_fleet(specs: Vec<JobSpec>, cfg: FleetConfig) -> crate::Result<FleetReport> {
    run_fleet_on(presets::deep_er(), specs, cfg)
}

/// A reproducible mixed workload over the five co-design applications:
/// node splits, iteration counts, checkpoint disciplines and priorities
/// drawn from a seeded stream.  This is what `repro fleet --jobs N` and
/// the `repro bench fleet` exhibit submit.
pub fn synthetic_jobs(n: usize, seed: u64) -> Vec<JobSpec> {
    let mut rng = SplitMix64::new(seed ^ 0xF1EE7D0C);
    (0..n)
        .map(|i| {
            let profile = match i % 5 {
                0 => crate::apps::xpic::profile_deep_er(),
                1 => crate::apps::nbody::profile(),
                2 => crate::apps::gershwin::profile_p1(),
                3 => crate::apps::fwi::profile(),
                _ => crate::apps::xpic::profile_nam(),
            };
            let cluster_nodes = 2 + rng.next_below(5) as usize; // 2..=6
            // Every third job spans the Cluster-Booster divide (the
            // apps::split division-of-labour shape).
            let booster_nodes = if i % 3 == 2 { 1 + rng.next_below(3) as usize } else { 0 };
            let iterations = 16 + rng.next_below(17) as usize; // 16..=32
            let cp_interval = if rng.next_below(2) == 0 { 5 } else { 8 };
            let ckpt = match i % 4 {
                0 => CkptStrategy::Scr(Strategy::Buddy),
                1 => CkptStrategy::MultiLevel(MultiLevelConfig {
                    l1_every: 1,
                    l2_every: 2,
                    l3_every: 2,
                    l2_strategy: Strategy::Buddy,
                    async_flush: true,
                }),
                2 => CkptStrategy::Scr(Strategy::Partner),
                _ => CkptStrategy::None,
            };
            let priority = rng.next_below(3) as u32;
            // Top-priority jobs declare an exchange guarantee; it only
            // takes effect when the fleet runs with QoS enabled.
            let qos = (priority == 2).then_some(QosDemand {
                class: TrafficClass::Exchange,
                backplane_floor: 2e9,
            });
            JobSpec {
                name: format!("job{i}-{}", profile.name),
                profile,
                cluster_nodes,
                booster_nodes,
                iterations,
                cp_interval,
                ckpt,
                priority,
                qos,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn compute_only_spec(name: &str, nodes: usize, iterations: usize) -> JobSpec {
        JobSpec {
            name: name.into(),
            profile: AppProfile {
                name: "compute-only",
                flops_per_iter_per_node: 0.5e12,
                cpu_efficiency: 0.25,
                ckpt_bytes_per_node: 0.0,
                halo_bytes: 0.0,
                io_tasks_per_node: 1,
                io_records_per_task: 1,
                artifact: "xpic_step",
            },
            cluster_nodes: nodes,
            booster_nodes: 0,
            iterations,
            cp_interval: 0,
            ckpt: CkptStrategy::None,
            priority: 0,
            qos: None,
        }
    }

    #[test]
    fn two_jobs_share_the_machine_concurrently() {
        // Both fit at once: both start at t=0 and the makespan is the
        // slower job alone, not the sum.
        let specs = vec![
            compute_only_spec("a", 4, 10),
            compute_only_spec("b", 4, 10),
        ];
        let r = run_fleet(specs, FleetConfig::default()).unwrap();
        assert_eq!(r.jobs.len(), 2);
        for j in &r.jobs {
            assert_eq!(j.first_start, 0.0);
            assert_eq!(j.stats.iterations_run, 10);
        }
        // 0.5e12 flops at 25% of 1 TF/s = 2 s per iteration, 10 iters.
        assert!((r.makespan - 20.0).abs() < 1e-6, "makespan={}", r.makespan);
        assert_eq!(r.finish_order, vec![0, 1], "equal finish times tie by id");
    }

    #[test]
    fn fcfs_queues_when_the_partition_is_full() {
        let specs = vec![
            compute_only_spec("a", 8, 10),
            compute_only_spec("b", 8, 10),
            compute_only_spec("c", 8, 10),
        ];
        let r = run_fleet(specs, FleetConfig::default()).unwrap();
        assert_eq!(r.jobs[0].first_start, 0.0);
        assert_eq!(r.jobs[1].first_start, 0.0);
        assert!(r.jobs[2].wait_time > 0.0, "third 8-node job must queue");
        assert!((r.jobs[2].first_start - 20.0).abs() < 1e-6);
        assert!(r.utilization > 0.0 && r.utilization <= 1.0);
    }

    #[test]
    fn priority_reorders_the_queue() {
        let mut specs = vec![
            compute_only_spec("a", 16, 10), // fills the cluster
            compute_only_spec("b", 8, 10),
            compute_only_spec("c", 8, 10),
        ];
        specs[2].priority = 5; // c outranks b once the machine frees up
        let r = run_fleet(specs, FleetConfig::default()).unwrap();
        assert!(r.jobs[2].first_start <= r.jobs[1].first_start);
    }

    #[test]
    fn failure_requeues_and_the_job_still_completes() {
        // One targeted failure at t=30 on node 0, held by the only job.
        let mut spec = compute_only_spec("a", 4, 20);
        spec.cp_interval = 5;
        spec.ckpt = CkptStrategy::Scr(Strategy::Buddy);
        spec.profile.ckpt_bytes_per_node = 1e9;
        let cfg = FleetConfig {
            failure_plan: Some(FailurePlan {
                at_iterations: Vec::new(),
                at_times: vec![Failure { node: 0, at: 30.0 }],
            }),
            ..FleetConfig::default()
        };
        let r = run_fleet(vec![spec], cfg).unwrap();
        assert_eq!(r.failures_injected, 1);
        assert_eq!(r.jobs[0].stats.failures_hit, 1);
        assert_eq!(r.jobs[0].requeues, 1);
        assert!(
            r.flows_cancelled > 0,
            "a mid-phase kill must cancel the doomed attempt's flows"
        );
        assert_eq!(r.jobs[0].stats.flows_cancelled, r.flows_cancelled);
        assert!(
            r.jobs[0].stats.iterations_run > 20,
            "rollback must re-run iterations ({} run)",
            r.jobs[0].stats.iterations_run
        );
        assert!(r.jobs[0].stats.restart_time > 0.0);
        assert_eq!(r.finish_order, vec![0]);
    }

    #[test]
    fn failure_on_idle_node_kills_nobody() {
        let cfg = FleetConfig {
            failure_plan: Some(FailurePlan {
                at_iterations: Vec::new(),
                // Node 15 is never allocated by a single 4-node job.
                at_times: vec![Failure { node: 15, at: 5.0 }],
            }),
            ..FleetConfig::default()
        };
        let r = run_fleet(vec![compute_only_spec("a", 4, 10)], cfg).unwrap();
        assert_eq!(r.failures_injected, 0);
        assert_eq!(r.idle_failures, 1);
        assert_eq!(r.jobs[0].stats.failures_hit, 0);
    }

    #[test]
    fn synthetic_jobs_are_valid_and_deterministic() {
        let a = synthetic_jobs(10, 7);
        let b = synthetic_jobs(10, 7);
        assert_eq!(a.len(), 10);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.cluster_nodes, y.cluster_nodes);
            assert_eq!(x.booster_nodes, y.booster_nodes);
            assert_eq!(x.iterations, y.iterations);
            assert_eq!(x.priority, y.priority);
        }
        let spec = presets::deep_er();
        for s in &a {
            assert!(s.cluster_nodes >= 2 && s.cluster_nodes <= spec.n_cluster);
            assert!(s.booster_nodes <= spec.n_booster);
            assert!(s.iterations > 0 && s.cp_interval > 0);
            assert!(estimate_runtime(s, &spec, 0) > 0.0);
        }
    }

    #[test]
    fn qos_grants_serialize_when_the_budget_is_exhausted() {
        // DEEP-ER backplane is 400 GB/s -> guarantee budget 200 GB/s.
        // Two jobs each demanding 150 GB/s fit the machine node-wise but
        // not the guarantee budget: admission control must serialize
        // them (the over-subscription-impossible property, end to end).
        let mk = |name: &str| {
            let mut s = compute_only_spec(name, 4, 5);
            s.qos = Some(QosDemand {
                class: TrafficClass::Exchange,
                backplane_floor: 150e9,
            });
            s
        };
        let cfg = FleetConfig { qos: true, ..FleetConfig::default() };
        let r = run_fleet(vec![mk("a"), mk("b")], cfg).unwrap();
        assert!(r.qos);
        assert_eq!(r.finish_order, vec![0, 1]);
        assert_eq!(r.jobs[0].first_start, 0.0);
        assert!(
            (r.jobs[1].first_start - r.jobs[0].finished_at).abs() < 1e-9,
            "second grant must wait for the first refund: start={} vs end={}",
            r.jobs[1].first_start,
            r.jobs[0].finished_at
        );
        assert!(r.jobs[1].wait_time > 0.0);

        // Without QoS the same pair co-schedules immediately.
        let r2 = run_fleet(
            vec![mk("a"), mk("b")],
            FleetConfig { qos: false, ..FleetConfig::default() },
        )
        .unwrap();
        assert!(!r2.qos);
        assert_eq!(r2.jobs[1].first_start, 0.0, "demands are inert without --qos");
    }

    #[test]
    fn qos_budget_is_fifo_and_best_effort_is_not_blocked() {
        // Budget 200 GB/s.  J0 (100) runs; J1 (150) is rejected at t=0;
        // J2 (100) would fit the remaining headroom but must NOT snatch
        // it ahead of J1 (budget FIFO, no starvation); best-effort J3
        // charges nothing and starts immediately.  After J0 finishes,
        // J1 is admitted; J2 follows once J1's grant is refunded.
        let demand = |floor: f64| {
            Some(QosDemand { class: TrafficClass::Exchange, backplane_floor: floor })
        };
        let mut j0 = compute_only_spec("j0", 4, 5);
        j0.qos = demand(100e9);
        let mut j1 = compute_only_spec("j1", 4, 5);
        j1.qos = demand(150e9);
        let mut j2 = compute_only_spec("j2", 4, 5);
        j2.qos = demand(100e9);
        let j3 = compute_only_spec("j3", 4, 5);
        let cfg = FleetConfig { qos: true, ..FleetConfig::default() };
        let r = run_fleet(vec![j0, j1, j2, j3], cfg).unwrap();
        assert_eq!(r.jobs[0].first_start, 0.0);
        assert_eq!(r.jobs[3].first_start, 0.0, "best-effort must not be budget-blocked");
        assert!(
            (r.jobs[1].first_start - r.jobs[0].finished_at).abs() < 1e-9,
            "J1 must get the first refund (got {} vs J0 end {})",
            r.jobs[1].first_start,
            r.jobs[0].finished_at
        );
        assert!(
            (r.jobs[2].first_start - r.jobs[1].finished_at).abs() < 1e-9,
            "J2 must wait for J1's grant, not overtake it (got {} vs J1 end {})",
            r.jobs[2].first_start,
            r.jobs[1].finished_at
        );
    }

    #[test]
    fn qos_demand_above_budget_is_rejected_at_submit() {
        let mut s = compute_only_spec("greedy", 4, 5);
        s.qos = Some(QosDemand {
            class: TrafficClass::Exchange,
            backplane_floor: 300e9, // > 50% of the 400 GB/s backplane
        });
        let cfg = FleetConfig { qos: true, ..FleetConfig::default() };
        assert!(run_fleet(vec![s.clone()], cfg).is_err());
        // The same spec is accepted when QoS is off (demand unread).
        let r = run_fleet(vec![s], FleetConfig::default()).unwrap();
        assert_eq!(r.jobs.len(), 1);
    }

    #[test]
    fn estimate_is_exact_for_compute_only_jobs() {
        let spec = compute_only_spec("a", 4, 10);
        let m = presets::deep_er();
        let est = estimate_runtime(&spec, &m, 0);
        // 10 x 0.5e12 / (0.25 x 1e12) = 20 s (plus the ulp inflation).
        assert!((est - 20.0).abs() < 1e-3, "est={est}");
        // Remaining-work form.
        let half = estimate_runtime(&spec, &m, 5);
        assert!((half - 10.0).abs() < 1e-3, "half={half}");
        assert_eq!(estimate_runtime(&spec, &m, 10), 0.0);
    }
}

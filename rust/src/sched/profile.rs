//! Incremental capacity profile: the maintained ordered structure behind
//! conservative backfill at service scale (DESIGN.md §16).
//!
//! [`super::policy::CapProfile`] rebuilds a step profile from scratch
//! every dispatch round and scans it linearly, so one round costs
//! O(queue²)–O(queue³).  Fine for an 8-job batch; fatal for an open
//! arrival stream with 10^5–10^6 jobs.  [`IncProfile`] stores the same
//! step profile as a BTreeMap of capacity **deltas** keyed by time, so a
//! reservation insert/remove/shift is two O(log n) map updates, and
//! `earliest_fit` is one forward sweep with running prefix sums.
//!
//! [`ProfileBook`] wraps the delta map with the bookkeeping the
//! scheduler needs across rounds: persistent *holds* (running jobs'
//! estimated releases, updated on start/finish/requeue/migration and on
//! every est-end refresh) and per-round *reservations* (carved in queue
//! order during planning, cleared at the next round's start).
//!
//! **Equivalence with the from-scratch rebuild** (the differential
//! oracle `rust/tests/prop_profile.rs` checks): both structures answer
//! `earliest_fit` with the earliest `t >= now` whose window `[t, t+dur)`
//! clears every overlapping segment.  The from-scratch scan enumerates
//! candidates {now} ∪ {breakpoints}; the sweep advances a candidate to
//! the end of every insufficient segment.  Any fitting start's
//! preceding capacity-change point also fits (segments between them are
//! at least as available), so the earliest fit always lies on `now` or
//! a breakpoint where capacity actually changes — zero-delta
//! breakpoints (which the from-scratch profile keeps and the delta map
//! drops) can never be the unique answer.  Overdue holds (`est_end <=
//! now`) fold into the sweep's base availability, mirroring the
//! `est_end.max(now)` clamp in [`super::policy::CapProfile::new`].
//! Windows are half-open `[t0, t0+dur)` in both (pinned by the boundary
//! tests here and in `policy.rs`): a reservation ending at `t` and one
//! starting at `t` never conflict.

use std::collections::BTreeMap;
use std::ops::Bound;

use crate::sim::SimTime;

use super::policy::{NodeReq, Policy, QueuedReq};

/// Map a simulation time to a BTreeMap key whose `u64` order matches
/// `f64` order.  Valid for non-negative finite times only — which every
/// release estimate and reservation edge is (asserted).  `-0.0` is
/// normalised so it can never split the `t == 0.0` bucket.
fn key(t: SimTime) -> u64 {
    debug_assert!(t.is_finite() && t >= 0.0, "profile time {t} outside [0, inf)");
    if t == 0.0 { 0.0f64.to_bits() } else { t.to_bits() }
}

/// Step-wise capacity profile stored as per-instant capacity *deltas*:
/// `deltas[t] = (dc, db)` means the available (cluster, booster) count
/// changes by that much at time `t`.  Absolute availability at any time
/// is a base value plus the prefix sum of deltas — which is what the
/// query sweeps compute.  Entries whose delta cancels to (0, 0) are
/// removed, so the map size is bounded by live holds + reservations.
#[derive(Debug, Default, Clone)]
pub struct IncProfile {
    deltas: BTreeMap<u64, (i64, i64)>,
}

impl IncProfile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of live breakpoints (diagnostics / tests).
    pub fn len(&self) -> usize {
        self.deltas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.deltas.is_empty()
    }

    /// Add a capacity delta at `t`; exact integer arithmetic, so an
    /// insert followed by its inverse leaves no residue.
    pub fn add_delta(&mut self, t: SimTime, dc: i64, db: i64) {
        let e = self.deltas.entry(key(t)).or_insert((0, 0));
        e.0 += dc;
        e.1 += db;
        if *e == (0, 0) {
            self.deltas.remove(&key(t));
        }
    }

    /// Carve a reservation `[t0, t0 + dur)`: capacity drops by `req` at
    /// `t0` and returns at `t0 + dur`.  O(log n).
    pub fn reserve(&mut self, t0: SimTime, dur: SimTime, req: NodeReq) {
        self.add_delta(t0, -(req.cluster as i64), -(req.booster as i64));
        self.add_delta(t0 + dur, req.cluster as i64, req.booster as i64);
    }

    /// Exact inverse of [`IncProfile::reserve`] with the same arguments.
    pub fn unreserve(&mut self, t0: SimTime, dur: SimTime, req: NodeReq) {
        self.add_delta(t0, req.cluster as i64, req.booster as i64);
        self.add_delta(t0 + dur, -(req.cluster as i64), -(req.booster as i64));
    }

    /// Availability at `now`: `free` plus every delta at `t <= now`.
    /// Folding past deltas into the base is what clamps overdue holds to
    /// "released now", mirroring the from-scratch profile's
    /// `est_end.max(now)`.
    fn base_avail(&self, now: SimTime, free: NodeReq) -> (i64, i64) {
        let mut c = free.cluster as i64;
        let mut b = free.booster as i64;
        for (_, &(dc, db)) in self.deltas.range(..=key(now)) {
            c += dc;
            b += db;
        }
        (c, b)
    }

    /// Does `req` fit in every segment overlapping `[t0, t0 + dur)`?
    /// Half-open: a capacity drop at exactly `t0 + dur` is ignored.
    /// `t0 >= now` required; availability is evaluated relative to
    /// (`now`, `free`).
    pub fn fits_window(
        &self,
        now: SimTime,
        free: NodeReq,
        t0: SimTime,
        dur: SimTime,
        req: NodeReq,
    ) -> bool {
        debug_assert!(t0 >= now, "window start {t0} precedes now {now}");
        let (rc, rb) = (req.cluster as i64, req.booster as i64);
        let (mut c, mut b) = self.base_avail(now, free);
        for (_, &(dc, db)) in self
            .deltas
            .range((Bound::Excluded(key(now)), Bound::Included(key(t0))))
        {
            c += dc;
            b += db;
        }
        if c < rc || b < rb {
            return false;
        }
        let t1 = t0 + dur;
        for (&k, &(dc, db)) in self.deltas.range((Bound::Excluded(key(t0)), Bound::Unbounded)) {
            if f64::from_bits(k) >= t1 {
                return true;
            }
            c += dc;
            b += db;
            if c < rc || b < rb {
                return false;
            }
        }
        true
    }

    /// Earliest `t >= now` at which `req` fits for `dur`: one forward
    /// sweep.  The candidate starts at `now` and advances to the end of
    /// every segment that cannot host the window; once the sweep is a
    /// full window past the candidate (or runs out of breakpoints) the
    /// candidate is the answer.  Panics if the request never fits —
    /// callers validate requests against whole-machine capacity at
    /// submit, and every hold/reservation returns its nodes.
    pub fn earliest_fit(&self, now: SimTime, free: NodeReq, dur: SimTime, req: NodeReq) -> SimTime {
        let (rc, rb) = (req.cluster as i64, req.booster as i64);
        let (mut c, mut b) = self.base_avail(now, free);
        let mut cand = now;
        for (&k, &(dc, db)) in self.deltas.range((Bound::Excluded(key(now)), Bound::Unbounded)) {
            let t = f64::from_bits(k);
            if c < rc || b < rb {
                cand = t; // segment ending at t cannot overlap the window
            } else if t >= cand + dur {
                return cand; // window cleared every segment it touches
            }
            c += dc;
            b += db;
        }
        assert!(
            c >= rc && b >= rb,
            "request exceeds total machine capacity (validated at submit)"
        );
        cand
    }
}

/// The scheduler-owned profile state that survives across dispatch
/// rounds: the delta map, the per-running-job holds feeding it, and the
/// reservations carved during the current planning round.
#[derive(Debug, Default)]
pub struct ProfileBook {
    prof: IncProfile,
    /// Running job id → (estimated release time, held node counts).
    /// Exactly one `+req` delta per entry lives in the profile.
    holds: BTreeMap<usize, (SimTime, NodeReq)>,
    /// Reservations carved by the current round's planning, undone by
    /// the next [`ProfileBook::begin_round`].
    round: Vec<(SimTime, SimTime, NodeReq)>,
}

impl ProfileBook {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install or shift job `id`'s estimated release.  O(log n); a
    /// no-op when nothing changed, so the per-round refresh of
    /// unchanged jobs costs only the comparison.
    pub fn hold_set(&mut self, id: usize, est_end: SimTime, req: NodeReq) {
        if let Some(&(old_t, old_r)) = self.holds.get(&id) {
            if old_t == est_end && old_r == req {
                return;
            }
            self.prof
                .add_delta(old_t, -(old_r.cluster as i64), -(old_r.booster as i64));
        }
        self.prof
            .add_delta(est_end, req.cluster as i64, req.booster as i64);
        self.holds.insert(id, (est_end, req));
    }

    /// Remove job `id`'s hold (finish, requeue, migration).  No-op when
    /// no hold is on record.
    pub fn hold_clear(&mut self, id: usize) {
        if let Some((t, r)) = self.holds.remove(&id) {
            self.prof
                .add_delta(t, -(r.cluster as i64), -(r.booster as i64));
        }
    }

    /// Live holds (tests / diagnostics).
    pub fn hold_count(&self) -> usize {
        self.holds.len()
    }

    /// Undo the previous round's reservations.  Every planning round
    /// must begin here so queries never see stale queue reservations.
    pub fn begin_round(&mut self) {
        let round = std::mem::take(&mut self.round);
        for (t0, dur, req) in round {
            self.prof.unreserve(t0, dur, req);
        }
    }

    /// Carve a reservation for the current round.
    pub fn reserve(&mut self, t0: SimTime, dur: SimTime, req: NodeReq) {
        self.prof.reserve(t0, dur, req);
        self.round.push((t0, dur, req));
    }

    pub fn earliest_fit(&self, now: SimTime, free: NodeReq, dur: SimTime, req: NodeReq) -> SimTime {
        self.prof.earliest_fit(now, free, dur, req)
    }

    pub fn fits_window(
        &self,
        now: SimTime,
        free: NodeReq,
        t0: SimTime,
        dur: SimTime,
        req: NodeReq,
    ) -> bool {
        self.prof.fits_window(now, free, t0, dur, req)
    }
}

/// [`super::policy::plan_starts`] over the maintained book instead of a
/// from-scratch rebuild.  The caller keeps the book's holds in sync with
/// the running set (the scheduler refreshes them every dispatch round);
/// this function owns the round reservations.  Output is identical to
/// the from-scratch planner given the same inputs — the property the
/// differential oracle pins.
pub fn plan_starts_book(
    policy: Policy,
    now: SimTime,
    free: NodeReq,
    queue: &[QueuedReq],
    book: &mut ProfileBook,
) -> Vec<usize> {
    match policy {
        Policy::Fcfs => {
            let mut avail = free;
            let mut starts = Vec::new();
            for q in queue {
                if !q.req.fits(avail) {
                    break; // head reservation: nobody overtakes
                }
                avail.cluster -= q.req.cluster;
                avail.booster -= q.req.booster;
                starts.push(q.id);
            }
            starts
        }
        Policy::Backfill => {
            book.begin_round();
            let mut starts = Vec::new();
            for q in queue {
                let t = book.earliest_fit(now, free, q.est, q.req);
                book.reserve(t, q.est, q.req);
                if t <= now {
                    starts.push(q.id);
                }
            }
            starts
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(c: usize, b: usize) -> NodeReq {
        NodeReq { cluster: c, booster: b }
    }

    #[test]
    fn boundary_reservation_ending_at_t_does_not_conflict_with_one_starting_at_t() {
        // Satellite: half-open [t0, t0+dur) windows.  A full-machine
        // reservation over [0, 5) and another over [5, 10) coexist; the
        // shared breakpoint t=5 belongs to the second one only.
        let mut p = IncProfile::new();
        p.reserve(0.0, 5.0, req(4, 0));
        assert!(
            p.fits_window(0.0, req(4, 0), 5.0, 5.0, req(4, 0)),
            "a window starting exactly at a release breakpoint must fit"
        );
        assert_eq!(p.earliest_fit(0.0, req(4, 0), 5.0, req(4, 0)), 5.0);
        p.reserve(5.0, 5.0, req(4, 0));
        // Both reservations live: nothing fits before 10, everything at 10.
        assert_eq!(p.earliest_fit(0.0, req(4, 0), 1.0, req(1, 0)), 10.0);
        assert!(p.fits_window(0.0, req(4, 0), 10.0, 100.0, req(4, 0)));
    }

    #[test]
    fn earliest_fit_returns_the_shared_breakpoint() {
        // A hold releasing 4 nodes at t=5 on an otherwise empty profile:
        // the earliest fit for those 4 nodes is exactly 5.0, not 5+eps.
        let mut p = IncProfile::new();
        p.add_delta(5.0, 4, 0); // running job's estimated release
        let t = p.earliest_fit(0.0, req(0, 0), 3.0, req(4, 0));
        assert_eq!(t.to_bits(), 5.0f64.to_bits());
    }

    #[test]
    fn overdue_holds_fold_into_the_base_availability() {
        // A release estimated at t=3 queried at now=10 counts as free
        // immediately — the est_end.max(now) clamp, delta-map style.
        let mut p = IncProfile::new();
        p.add_delta(3.0, 4, 0);
        assert_eq!(p.earliest_fit(10.0, req(0, 0), 2.0, req(4, 0)), 10.0);
        assert!(p.fits_window(10.0, req(0, 0), 10.0, 2.0, req(4, 0)));
    }

    #[test]
    fn unreserve_leaves_no_residue() {
        let mut p = IncProfile::new();
        p.reserve(2.0, 3.0, req(3, 1));
        p.reserve(2.0, 3.0, req(1, 0));
        p.unreserve(2.0, 3.0, req(3, 1));
        p.unreserve(2.0, 3.0, req(1, 0));
        assert!(p.is_empty(), "exact integer deltas must cancel to nothing");
    }

    #[test]
    fn zero_duration_reservations_are_inert() {
        let mut p = IncProfile::new();
        p.reserve(4.0, 0.0, req(2, 0));
        assert!(p.is_empty());
        assert_eq!(p.earliest_fit(0.0, req(2, 0), 1.0, req(2, 0)), 0.0);
    }

    #[test]
    fn book_round_reservations_are_cleared_and_holds_persist() {
        let mut book = ProfileBook::new();
        book.hold_set(7, 10.0, req(4, 0));
        let queue = [QueuedReq { id: 0, req: req(4, 0), est: 3.0 }];
        // Free 0 now; the hold releases 4 at t=10 — reservation lands there.
        let starts = plan_starts_book(Policy::Backfill, 0.0, req(0, 0), &queue, &mut book);
        assert!(starts.is_empty());
        // Next round at t=10: the hold is gone (job finished), the old
        // round reservation must not linger.
        book.hold_clear(7);
        let starts = plan_starts_book(Policy::Backfill, 10.0, req(4, 0), &queue, &mut book);
        assert_eq!(starts, vec![0]);
        assert_eq!(book.hold_count(), 0);
    }

    #[test]
    fn hold_shift_moves_the_release() {
        let mut book = ProfileBook::new();
        book.hold_set(1, 10.0, req(4, 0));
        assert_eq!(book.earliest_fit(0.0, req(0, 0), 2.0, req(4, 0)), 10.0);
        // Degradation stretched the estimate: shift the hold.
        book.hold_set(1, 40.0, req(4, 0));
        assert_eq!(book.earliest_fit(0.0, req(0, 0), 2.0, req(4, 0)), 40.0);
        // And back (revert): no residue from the shifts.
        book.hold_set(1, 10.0, req(4, 0));
        assert_eq!(book.earliest_fit(0.0, req(0, 0), 2.0, req(4, 0)), 10.0);
    }

    #[test]
    fn negative_zero_times_normalise() {
        let mut p = IncProfile::new();
        p.add_delta(-0.0, 2, 0);
        p.add_delta(0.0, -2, 0);
        assert!(p.is_empty(), "-0.0 and 0.0 must hit the same breakpoint");
    }
}

//! Machine models: nodes, topology, presets, failure injection.
//!
//! [`Machine`] is the assembly point of the reproduction: it instantiates
//! the [`crate::sim::Sim`] resources for every node (CPU, NIC ports,
//! node-local devices), the EXTOLL fabric, the BeeGFS storage servers and
//! the NAM boards, according to a [`MachineSpec`] preset.
//!
//! Presets carry the published configurations:
//! * [`presets::deep_er`] — Table I: 16 Haswell Cluster nodes + 8 KNL
//!   Booster nodes, NVMe everywhere, 2 NAM boards, 1 MDS + 2 storage
//!   servers, uniform Tourmalet fabric.
//! * [`presets::qpace3`] — the 672-node KNL system used for Fig. 6
//!   (no NVMe: RAM-disk emulation, like the paper did).
//! * [`presets::marenostrum3`] — the Sandy Bridge cluster used for the
//!   FWI/OmpSs experiments (Fig. 10).

pub mod failure;
pub mod faults;
pub mod presets;
pub mod zoo;

use crate::fabric::{EpId, Fabric, TopologySpec};
use crate::nam::NamDevice;
use crate::sim::{FlowId, ResId, Sim, SimTime};
use crate::storage::{Device, DeviceParams};

/// Which side of the Cluster-Booster system a node belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    Cluster,
    Booster,
}

/// Static per-node hardware description (one Table I column).
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub name: &'static str,
    pub kind: NodeKind,
    pub cores: u32,
    pub freq_ghz: f64,
    /// Peak double-precision compute, flop/s.
    pub peak_flops: f64,
    /// Main memory per node, bytes.
    pub mem_bytes: f64,
    /// Fast-tier memory (MCDRAM on KNL), bytes; 0 when absent.
    pub fast_mem_bytes: f64,
    pub nic_bw: f64,
    pub nic_latency: SimTime,
    pub nvme: Option<DeviceParams>,
    pub hdd: Option<DeviceParams>,
    pub ramdisk: Option<DeviceParams>,
}

/// Full machine description (a paper testbed).
#[derive(Debug, Clone)]
pub struct MachineSpec {
    pub name: &'static str,
    pub cluster: NodeSpec,
    pub n_cluster: usize,
    pub booster: Option<NodeSpec>,
    pub n_booster: usize,
    /// Global-storage servers (BeeGFS object storage targets).
    pub n_storage_servers: usize,
    pub server_device: DeviceParams,
    pub server_nic_bw: f64,
    /// Metadata operation service time at the MDS (create/open/stat).
    pub mds_op_cost: SimTime,
    pub n_nam: usize,
    /// Fabric interior between the endpoint ports: the flat backplane of
    /// the original presets or a generated shape from [`zoo`].
    pub topology: TopologySpec,
}

impl MachineSpec {
    pub fn total_nodes(&self) -> usize {
        self.n_cluster + self.n_booster
    }

    /// Scale the compute partition (weak-scaling sweeps re-use presets).
    pub fn with_cluster_nodes(mut self, n: usize) -> Self {
        self.n_cluster = n;
        self
    }

    pub fn with_booster_nodes(mut self, n: usize) -> Self {
        self.n_booster = n;
        self
    }
}

/// A live node: resources registered in the simulator.
#[derive(Debug)]
pub struct Node {
    pub kind: NodeKind,
    pub spec: NodeSpec,
    pub ep: EpId,
    pub cpu: ResId,
    pub nvme: Option<Device>,
    pub hdd: Option<Device>,
    pub ramdisk: Option<Device>,
    pub alive: bool,
}

/// A BeeGFS storage server node (object storage target host).
#[derive(Debug)]
pub struct ServerNode {
    pub ep: EpId,
    pub device: Device,
}

/// The assembled machine.
#[derive(Debug)]
pub struct Machine {
    pub sim: Sim,
    pub fabric: Fabric,
    pub spec: MachineSpec,
    pub nodes: Vec<Node>,
    pub servers: Vec<ServerNode>,
    /// Metadata server endpoint + service resource.
    pub mds_ep: EpId,
    pub mds_res: ResId,
    pub nams: Vec<NamDevice>,
    /// Allocation ledger: which fleet job (if any) holds each compute
    /// node.  [`Machine::try_allocate`] is the only path that sets an
    /// entry, so the no-oversubscription invariant the scheduler property
    /// tests audit is enforced here, not re-derived by every caller.
    owners: Vec<Option<u64>>,
}

impl Machine {
    /// Instantiate every resource for `spec`.
    pub fn build(spec: MachineSpec) -> Self {
        // The Split topology partitions endpoints by registration index;
        // nodes register cluster-first, so its booster range must be
        // exactly the booster node block (storage/MDS/NAM endpoints come
        // after and land cluster-side).
        if let TopologySpec::Split { booster_start, booster_end, .. } = spec.topology {
            assert_eq!(
                (booster_start, booster_end),
                (spec.n_cluster, spec.n_cluster + spec.n_booster),
                "split topology range must match the machine's booster partition"
            );
        }
        let mut sim = Sim::new();
        let mut fabric = Fabric::with_topology(&mut sim, &spec.topology);
        let mut nodes = Vec::with_capacity(spec.total_nodes());

        let add_node = |sim: &mut Sim, fabric: &mut Fabric, ns: &NodeSpec, idx: usize| {
            let label = format!("{}{}", if ns.kind == NodeKind::Cluster { "cn" } else { "bn" }, idx);
            let ep = fabric.endpoint(sim, &label, ns.nic_bw, ns.nic_latency);
            let cpu = sim.resource(format!("{label}:cpu"), ns.peak_flops);
            let nvme = ns.nvme.clone().map(|p| Device::new(sim, p, &label));
            let hdd = ns.hdd.clone().map(|p| Device::new(sim, p, &label));
            let ramdisk = ns.ramdisk.clone().map(|p| Device::new(sim, p, &label));
            Node { kind: ns.kind, spec: ns.clone(), ep, cpu, nvme, hdd, ramdisk, alive: true }
        };

        for i in 0..spec.n_cluster {
            let n = add_node(&mut sim, &mut fabric, &spec.cluster, i);
            nodes.push(n);
        }
        if let Some(booster) = &spec.booster {
            for i in 0..spec.n_booster {
                let n = add_node(&mut sim, &mut fabric, booster, i);
                nodes.push(n);
            }
        }

        let mut servers = Vec::with_capacity(spec.n_storage_servers);
        for i in 0..spec.n_storage_servers {
            let label = format!("oss{i}");
            let ep = fabric.endpoint(&mut sim, &label, spec.server_nic_bw, crate::fabric::LAT_CLUSTER);
            let device = Device::new(&mut sim, spec.server_device.clone(), &label);
            servers.push(ServerNode { ep, device });
        }

        let mds_ep = fabric.endpoint(&mut sim, "mds", spec.server_nic_bw, crate::fabric::LAT_CLUSTER);
        // MDS service modelled as a resource of `1/op_cost` ops per second;
        // flows carry "operations" instead of bytes.
        let mds_res = sim.resource("mds:svc", 1.0 / spec.mds_op_cost.max(1e-9));

        let mut nams = Vec::with_capacity(spec.n_nam);
        for i in 0..spec.n_nam {
            nams.push(NamDevice::new(&mut sim, &mut fabric, i));
        }

        let owners = vec![None; nodes.len()];
        Self { sim, fabric, spec, nodes, servers, mds_ep, mds_res, nams, owners }
    }

    /// Indices of compute nodes of a given kind.
    pub fn nodes_of(&self, kind: NodeKind) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.kind == kind)
            .map(|(i, _)| i)
            .collect()
    }

    /// Start a compute phase of `flops` on node `i` (a flow on its CPU).
    /// `efficiency` scales achievable flops (apps never hit peak).
    pub fn compute(&mut self, i: usize, flops: f64, efficiency: f64) -> FlowId {
        assert!(self.nodes[i].alive, "compute on dead node {i}");
        let cpu = self.nodes[i].cpu;
        self.sim.flow(flops / efficiency.clamp(1e-3, 1.0), 0.0, &[cpu])
    }

    /// Mark a node failed (its running work is lost; callers decide how to
    /// recover — that is exactly what the SCR strategies differ in).
    pub fn kill_node(&mut self, i: usize) {
        self.nodes[i].alive = false;
    }

    /// Bring a (repaired or spare) node back.
    pub fn revive_node(&mut self, i: usize) {
        self.nodes[i].alive = true;
    }

    pub fn alive_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| n.alive).count()
    }

    // ------------------------------------------------------------------
    // degraded-mode fault injection ([`faults`], DESIGN.md section 15)
    // ------------------------------------------------------------------

    /// Scale node `i`'s compute capacity to `scale` x its spec peak
    /// (straggler injection: `scale < 1` stretches every compute phase on
    /// the node; `scale = 1.0` restores the healthy rate).  Because the
    /// scale is always applied against the *spec* value, apply/revert
    /// pairs are idempotent and never accumulate rounding drift.
    pub fn set_node_compute_scale(&mut self, i: usize, scale: f64) {
        assert!(scale > 0.0 && scale.is_finite(), "compute scale must be positive");
        let cap = self.nodes[i].spec.peak_flops * scale;
        let cpu = self.nodes[i].cpu;
        self.sim.set_resource_capacity(cpu, cap);
    }

    /// Scale node `i`'s NIC tx/rx capacity to `scale` x its spec bandwidth
    /// (link-degradation injection).  Both directions degrade together —
    /// the paper's EXTOLL links are full-duplex pairs on one physical
    /// cable, so a marginal cable/connector dims both.
    pub fn set_node_link_scale(&mut self, i: usize, scale: f64) {
        assert!(scale > 0.0 && scale.is_finite(), "link scale must be positive");
        let bw = self.nodes[i].spec.nic_bw * scale;
        let ep = self.fabric.endpoint_info(self.nodes[i].ep);
        self.sim.set_resource_capacity(ep.tx, bw);
        self.sim.set_resource_capacity(ep.rx, bw);
    }

    /// Node `i`'s current compute capacity as a fraction of its spec peak
    /// (the inverse read of [`Machine::set_node_compute_scale`]): exactly
    /// 1.0 when healthy, the injected scale while a straggler window is
    /// active.  The scheduler's est-end refresh reads this every dispatch
    /// round instead of caching fault state of its own.
    pub fn node_compute_scale(&self, i: usize) -> f64 {
        self.sim.capacity(self.nodes[i].cpu) / self.nodes[i].spec.peak_flops
    }

    /// Node `i`'s current NIC tx capacity as a fraction of its spec
    /// bandwidth (the inverse read of [`Machine::set_node_link_scale`]).
    pub fn node_link_scale(&self, i: usize) -> f64 {
        let ep = self.fabric.endpoint_info(self.nodes[i].ep);
        self.sim.capacity(ep.tx) / self.nodes[i].spec.nic_bw
    }

    // ------------------------------------------------------------------
    // partition allocation (the fleet scheduler's node ledger)
    // ------------------------------------------------------------------

    /// Nodes of `kind` not currently allocated to any job, in index order.
    pub fn free_nodes_of(&self, kind: NodeKind) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|&(i, n)| n.kind == kind && self.owners[i].is_none())
            .map(|(i, _)| i)
            .collect()
    }

    /// Number of unallocated nodes of `kind`.
    pub fn free_count(&self, kind: NodeKind) -> usize {
        self.free_nodes_of(kind).len()
    }

    /// Allocate `count` nodes of `kind` to `owner` (lowest free indices
    /// first, deterministically); `None` when not enough are free.  A node
    /// is never handed to two owners: the pick comes from the free list
    /// and each entry is asserted unowned before it is stamped.
    pub fn try_allocate(&mut self, kind: NodeKind, count: usize, owner: u64) -> Option<Vec<usize>> {
        let free = self.free_nodes_of(kind);
        if free.len() < count {
            return None;
        }
        let picked: Vec<usize> = free[..count].to_vec();
        for &i in &picked {
            assert!(self.owners[i].is_none(), "node {i} already allocated");
            self.owners[i] = Some(owner);
        }
        Some(picked)
    }

    /// Like [`Machine::try_allocate`], but prefer free nodes *not* in
    /// `avoid` (the health monitor's suspect set).  Healthy free nodes are
    /// taken lowest-index-first; only when those run out does the pick
    /// fall back to suspects — liveness beats placement, a job must never
    /// starve because every spare is suspicious.
    pub fn try_allocate_avoiding(
        &mut self,
        kind: NodeKind,
        count: usize,
        owner: u64,
        avoid: &[usize],
    ) -> Option<Vec<usize>> {
        let free = self.free_nodes_of(kind);
        if free.len() < count {
            return None;
        }
        let mut picked: Vec<usize> = free.iter().copied().filter(|i| !avoid.contains(i)).collect();
        if picked.len() < count {
            picked.extend(free.iter().copied().filter(|i| avoid.contains(i)));
        }
        picked.truncate(count);
        for &i in &picked {
            assert!(self.owners[i].is_none(), "node {i} already allocated");
            self.owners[i] = Some(owner);
        }
        Some(picked)
    }

    /// Release nodes held by `owner`; panics if any entry is not theirs
    /// (the ledger must stay consistent for the oversubscription audit).
    pub fn release_nodes(&mut self, nodes: &[usize], owner: u64) {
        for &i in nodes {
            assert_eq!(self.owners[i], Some(owner), "release of node {i} not held by job {owner}");
            self.owners[i] = None;
        }
    }

    /// Fleet job currently holding node `i`, if any.
    pub fn node_owner(&self, i: usize) -> Option<u64> {
        self.owners[i]
    }

    /// Total nodes currently allocated (utilization accounting).
    pub fn allocated_count(&self) -> usize {
        self.owners.iter().filter(|o| o.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::presets;
    use super::*;

    #[test]
    fn deep_er_matches_table_i() {
        let spec = presets::deep_er();
        assert_eq!(spec.n_cluster, 16);
        assert_eq!(spec.n_booster, 8);
        let b = spec.booster.as_ref().unwrap();
        assert_eq!(b.cores, 64);
        assert!((b.freq_ghz - 1.3).abs() < 1e-9);
        assert!((spec.cluster.freq_ghz - 2.5).abs() < 1e-9);
        // Table I: 16 TFlop/s Cluster, 20 TFlop/s Booster aggregate.
        let cl_agg = spec.cluster.peak_flops * spec.n_cluster as f64;
        let bo_agg = b.peak_flops * spec.n_booster as f64;
        assert!((cl_agg - 16e12).abs() / 16e12 < 0.05, "cluster agg {cl_agg:e}");
        assert!((bo_agg - 20e12).abs() / 20e12 < 0.05, "booster agg {bo_agg:e}");
        assert_eq!(spec.n_nam, 2);
        assert_eq!(spec.n_storage_servers, 2);
    }

    #[test]
    fn build_creates_all_nodes() {
        let m = Machine::build(presets::deep_er());
        assert_eq!(m.nodes.len(), 24);
        assert_eq!(m.nodes_of(NodeKind::Cluster).len(), 16);
        assert_eq!(m.nodes_of(NodeKind::Booster).len(), 8);
        assert_eq!(m.servers.len(), 2);
        assert_eq!(m.nams.len(), 2);
        assert!(m.nodes.iter().all(|n| n.nvme.is_some()));
    }

    #[test]
    fn cluster_has_hdd_booster_not() {
        let m = Machine::build(presets::deep_er());
        for i in m.nodes_of(NodeKind::Cluster) {
            assert!(m.nodes[i].hdd.is_some());
        }
        for i in m.nodes_of(NodeKind::Booster) {
            assert!(m.nodes[i].hdd.is_none());
        }
    }

    #[test]
    fn qpace3_is_booster_like_with_ramdisk() {
        let spec = presets::qpace3();
        assert_eq!(spec.n_cluster, 672);
        assert!(spec.cluster.nvme.is_none());
        assert!(spec.cluster.ramdisk.is_some());
        assert_eq!(spec.n_nam, 0);
    }

    #[test]
    fn compute_scales_with_flops() {
        let mut m = Machine::build(presets::deep_er());
        let f1 = m.compute(0, 1e12, 0.5);
        let t1 = m.sim.wait_all(&[f1]);
        let f2 = m.compute(0, 2e12, 0.5);
        let t2 = m.sim.wait_all(&[f2]) - t1;
        assert!((t2 / t1 - 2.0).abs() < 1e-6);
    }

    #[test]
    fn kill_and_revive() {
        let mut m = Machine::build(presets::deep_er());
        assert_eq!(m.alive_nodes(), 24);
        m.kill_node(3);
        assert_eq!(m.alive_nodes(), 23);
        m.revive_node(3);
        assert_eq!(m.alive_nodes(), 24);
    }

    #[test]
    #[should_panic(expected = "dead node")]
    fn compute_on_dead_node_panics() {
        let mut m = Machine::build(presets::deep_er());
        m.kill_node(0);
        let _ = m.compute(0, 1e9, 0.5);
    }

    #[test]
    fn allocation_ledger_tracks_owners() {
        let mut m = Machine::build(presets::deep_er());
        assert_eq!(m.free_count(NodeKind::Cluster), 16);
        assert_eq!(m.free_count(NodeKind::Booster), 8);
        let a = m.try_allocate(NodeKind::Cluster, 4, 1).unwrap();
        assert_eq!(a, vec![0, 1, 2, 3], "lowest free indices first");
        assert_eq!(m.free_count(NodeKind::Cluster), 12);
        assert_eq!(m.allocated_count(), 4);
        assert_eq!(m.node_owner(0), Some(1));
        assert_eq!(m.node_owner(4), None);
        // A second job never receives an already-held node.
        let b = m.try_allocate(NodeKind::Cluster, 4, 2).unwrap();
        assert!(a.iter().all(|n| !b.contains(n)));
        // Over-ask fails without touching the ledger.
        assert!(m.try_allocate(NodeKind::Cluster, 9, 3).is_none());
        assert_eq!(m.free_count(NodeKind::Cluster), 8);
        m.release_nodes(&a, 1);
        assert_eq!(m.free_count(NodeKind::Cluster), 12);
        assert_eq!(m.node_owner(0), None);
    }

    #[test]
    #[should_panic(expected = "not held by job")]
    fn release_by_wrong_owner_panics() {
        let mut m = Machine::build(presets::deep_er());
        let a = m.try_allocate(NodeKind::Cluster, 2, 7).unwrap();
        m.release_nodes(&a, 8);
    }
}

//! Failure injection: scheduled and stochastic node failures.
//!
//! The paper's resiliency experiments use *targeted* failures (Fig. 8: an
//! error after 60 of 100 iterations; Fig. 10: an error right before the
//! end of the run) — modelled by [`FailurePlan::at_iterations`].  For the
//! wider test/bench sweeps an exponential-MTBF injector generates failure
//! times the way Exascale reliability studies do.

use crate::sim::rng::SplitMix64;
use crate::sim::SimTime;

/// A single injected failure.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Failure {
    /// Node index **within the consuming scope's node list**: for the
    /// per-job plans the iteration driver walks, an index into the job's
    /// node list; for machine-level plans (the fleet scheduler's
    /// `FleetConfig`), an index into the machine's node array.  Both
    /// consumers reduce it modulo their list length.
    pub node: usize,
    /// Either a virtual time or an iteration index, per plan kind.
    pub at: f64,
}

/// When failures strike during a run.
#[derive(Debug, Clone, Default)]
pub struct FailurePlan {
    /// Failures keyed by *iteration* (checked at iteration boundaries, the
    /// way application-level checkpointing observes them).
    pub at_iterations: Vec<Failure>,
    /// Failures keyed by virtual time.
    pub at_times: Vec<Failure>,
}

impl FailurePlan {
    /// No failures.
    pub fn none() -> Self {
        Self::default()
    }

    /// One failure of `node` at iteration `iter` (paper Figs. 8/10 style).
    pub fn one_at_iteration(node: usize, iter: usize) -> Self {
        Self {
            at_iterations: vec![Failure { node, at: iter as f64 }],
            at_times: Vec::new(),
        }
    }

    /// Sample an exponential-MTBF failure schedule over `horizon` seconds
    /// for `nodes` nodes.  `mtbf_node` is the per-node mean time between
    /// failures; the system-level rate is `nodes / mtbf_node`.
    pub fn exponential(nodes: usize, mtbf_node: SimTime, horizon: SimTime, seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let mut at_times = Vec::new();
        if nodes == 0 {
            return Self { at_iterations: Vec::new(), at_times };
        }
        let system_mtbf = mtbf_node / nodes as f64;
        let mut t = 0.0;
        loop {
            t += rng.next_exp(system_mtbf);
            if t >= horizon {
                break;
            }
            let node = rng.next_below(nodes as u64) as usize;
            at_times.push(Failure { node, at: t });
        }
        Self { at_iterations: Vec::new(), at_times }
    }

    /// Every failure scheduled for iteration `iter`, in plan order.  Two
    /// failures at the same iteration are both returned — the driver
    /// queues them and processes one per boundary check, so co-scheduled
    /// same-iteration hits are no longer silently dropped.
    pub fn failures_at_iteration(&self, iter: usize) -> Vec<Failure> {
        self.at_iterations
            .iter()
            .filter(|f| f.at as usize == iter)
            .copied()
            .collect()
    }

    /// Failures with time in `(t0, t1]`.
    pub fn failures_between(&self, t0: SimTime, t1: SimTime) -> Vec<Failure> {
        self.at_times
            .iter()
            .filter(|f| f.at > t0 && f.at <= t1)
            .copied()
            .collect()
    }

    pub fn is_empty(&self) -> bool {
        self.at_iterations.is_empty() && self.at_times.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn targeted_failure_found_at_its_iteration() {
        let plan = FailurePlan::one_at_iteration(3, 60);
        assert!(plan.failures_at_iteration(59).is_empty());
        let fs = plan.failures_at_iteration(60);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].node, 3);
        assert!(plan.failures_at_iteration(61).is_empty());
    }

    #[test]
    fn same_iteration_failures_all_returned() {
        let plan = FailurePlan {
            at_iterations: vec![
                Failure { node: 1, at: 60.0 },
                Failure { node: 4, at: 60.0 },
            ],
            at_times: Vec::new(),
        };
        let fs = plan.failures_at_iteration(60);
        assert_eq!(fs.len(), 2, "both same-iteration failures must surface");
        assert_eq!(fs[0].node, 1);
        assert_eq!(fs[1].node, 4);
    }

    #[test]
    fn exponential_rate_scales_with_nodes() {
        let horizon = 1e6;
        let few = FailurePlan::exponential(10, 1e5, horizon, 1).at_times.len();
        let many = FailurePlan::exponential(100, 1e5, horizon, 1).at_times.len();
        assert!(many > 5 * few, "few={few} many={many}");
    }

    #[test]
    fn exponential_deterministic_per_seed() {
        let a = FailurePlan::exponential(32, 1e5, 1e6, 7).at_times;
        let b = FailurePlan::exponential(32, 1e5, 1e6, 7).at_times;
        assert_eq!(a, b);
    }

    #[test]
    fn failures_between_is_half_open() {
        let plan = FailurePlan {
            at_iterations: Vec::new(),
            at_times: vec![
                Failure { node: 0, at: 1.0 },
                Failure { node: 1, at: 2.0 },
                Failure { node: 2, at: 3.0 },
            ],
        };
        let mid = plan.failures_between(1.0, 3.0);
        assert_eq!(mid.len(), 2);
        assert_eq!(mid[0].node, 1);
        assert_eq!(mid[1].node, 2);
    }

    #[test]
    fn zero_nodes_no_failures() {
        let plan = FailurePlan::exponential(0, 1e5, 1e6, 3);
        assert!(plan.is_empty());
    }
}

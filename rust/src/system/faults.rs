//! Degraded-mode fault taxonomy: the failure modes *between* healthy and
//! dead (DESIGN.md §15).
//!
//! [`super::failure::FailurePlan`] models fail-stop node death — the only
//! mode the original reactive resiliency path knows.  Real exascale-class
//! machines (and the HPC resilience pattern language, arXiv 1710.09074)
//! also degrade: links dim before cables die, nodes straggle before DIMMs
//! fail, and checkpoints rot silently in storage (DAOS, arXiv 1712.00423,
//! treats detectable corruption as a first-class event).  This module
//! names those modes and generates seeded *correlated* schedules — a
//! degradation window that ends in a kill — which is exactly the signal a
//! proactive health monitor can exploit and a reactive one cannot.
//!
//! A [`FaultPlan`] is consumed by the fleet scheduler
//! ([`crate::sched::Scheduler`]): degradations apply/revert through
//! [`crate::system::Machine::set_node_link_scale`] /
//! [`set_node_compute_scale`](crate::system::Machine::set_node_compute_scale)
//! (both built on [`crate::sim::Sim::set_resource_capacity`]), corruption
//! flips the newest checkpoint record's verification flag, and the
//! correlated kills merge into the scheduler's ordinary failure stream.

use crate::sim::rng::SplitMix64;
use crate::sim::SimTime;
use crate::system::failure::Failure;

/// One of the three degraded modes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The node's NIC tx/rx capacity drops to `fraction` of spec for the
    /// fault window.
    LinkDegrade { fraction: f64 },
    /// The node's compute slows by `factor` (capacity becomes
    /// `peak_flops / factor`) for the fault window.
    Straggler { factor: f64 },
    /// The newest committed checkpoint record covering the node's job
    /// fails verification.  Instantaneous — there is no window to revert.
    CkptCorrupt,
}

impl FaultKind {
    /// Suspicion raised on the afflicted node when the precursor is
    /// observed (DESIGN.md §15: degradations are strong kill precursors,
    /// corruption is storage-side and only weakly implicates the node).
    pub fn suspicion_weight(&self) -> f64 {
        match self {
            FaultKind::LinkDegrade { .. } | FaultKind::Straggler { .. } => 1.0,
            FaultKind::CkptCorrupt => 0.5,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::LinkDegrade { .. } => "link_degrade",
            FaultKind::Straggler { .. } => "straggler",
            FaultKind::CkptCorrupt => "ckpt_corrupt",
        }
    }
}

/// A scheduled degraded-mode fault on one node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fault {
    /// Machine node index (reduced modulo the node count by the consumer,
    /// like [`Failure::node`]).
    pub node: usize,
    pub kind: FaultKind,
    /// Virtual time the degradation begins (or the corruption lands).
    pub from: SimTime,
    /// Virtual time the degradation reverts; `until == from` for
    /// instantaneous faults ([`FaultKind::CkptCorrupt`]).
    pub until: SimTime,
}

/// One entry of a [`FaultPlan::timeline`]: apply or revert fault
/// `fault` (an index into [`FaultPlan::faults`]) at time `at`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at: SimTime,
    pub fault: usize,
    pub apply: bool,
}

/// A full degraded-mode schedule: windowed faults plus the correlated
/// fail-stop kills they foreshadow.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
    /// Fail-stop kills keyed by virtual time (merged into the scheduler's
    /// failure stream alongside any `FleetConfig::failure_plan` entries).
    pub kills: Vec<Failure>,
}

impl FaultPlan {
    /// No faults.
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty() && self.kills.is_empty()
    }

    /// Flatten the plan into a time-sorted apply/revert event list the
    /// scheduler walks with a cursor.  Ordering is total and
    /// deterministic: by time (`total_cmp`), then by fault index, with a
    /// fault's apply preceding its revert (stable sort; apply is pushed
    /// first and `from <= until`).
    pub fn timeline(&self) -> Vec<FaultEvent> {
        let mut ev = Vec::with_capacity(self.faults.len() * 2);
        for (i, f) in self.faults.iter().enumerate() {
            assert!(f.until >= f.from, "fault window must not be negative");
            ev.push(FaultEvent { at: f.from, fault: i, apply: true });
            if !matches!(f.kind, FaultKind::CkptCorrupt) {
                ev.push(FaultEvent { at: f.until, fault: i, apply: false });
            }
        }
        ev.sort_by(|a, b| a.at.total_cmp(&b.at).then(a.fault.cmp(&b.fault)));
        ev
    }

    /// Seeded correlated schedule: `count` fault events spread evenly over
    /// `horizon`, each picking a node uniformly.  Every 4th event is a
    /// standalone checkpoint corruption; the rest alternate link
    /// degradation (capacity drops to 10–50 % of spec) and straggling
    /// (2–8x compute slowdown), each opening a precursor window that ends
    /// in a correlated fail-stop kill of the same node — the
    /// degrade-then-die signature the proactive policy is built to catch.
    /// Deterministic per `(nodes, count, horizon, seed)`.
    pub fn correlated(nodes: usize, count: usize, horizon: SimTime, seed: u64) -> Self {
        assert!(nodes > 0, "correlated plan needs at least one node");
        let mut rng = SplitMix64::new(seed ^ 0x0FA0_17D5);
        let mut faults = Vec::with_capacity(count);
        let mut kills = Vec::new();
        let spacing = horizon / (count as f64 + 1.0);
        for k in 1..=count {
            let node = rng.next_below(nodes as u64) as usize;
            // Jitter keeps windows off exact grid points without letting
            // neighbouring windows overlap on the same node by accident.
            let mid = spacing * k as f64 + spacing * 0.2 * (rng.next_f64() - 0.5);
            if k % 4 == 0 {
                faults.push(Fault { node, kind: FaultKind::CkptCorrupt, from: mid, until: mid });
                continue;
            }
            let window = spacing * (0.3 + 0.2 * rng.next_f64());
            let kind = if k % 2 == 1 {
                FaultKind::LinkDegrade { fraction: 0.1 + 0.4 * rng.next_f64() }
            } else {
                FaultKind::Straggler { factor: 2.0 + 6.0 * rng.next_f64() }
            };
            faults.push(Fault { node, kind, from: mid - window, until: mid });
            kills.push(Failure { node, at: mid });
        }
        Self { faults, kills }
    }

    /// Per-kind fault counts `(link_degrades, stragglers, corruptions)` —
    /// the bench exhibit's per-mode columns.
    pub fn count_by_kind(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for f in &self.faults {
            match f.kind {
                FaultKind::LinkDegrade { .. } => c.0 += 1,
                FaultKind::Straggler { .. } => c.1 += 1,
                FaultKind::CkptCorrupt => c.2 += 1,
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn correlated_deterministic_per_seed() {
        let a = FaultPlan::correlated(24, 8, 1e6, 42);
        let b = FaultPlan::correlated(24, 8, 1e6, 42);
        assert_eq!(a.faults, b.faults);
        assert_eq!(a.kills, b.kills);
        let c = FaultPlan::correlated(24, 8, 1e6, 43);
        assert_ne!(a.faults, c.faults, "different seeds must differ");
    }

    #[test]
    fn correlated_pairs_degradations_with_kills() {
        let plan = FaultPlan::correlated(24, 8, 1e6, 1);
        let degradations = plan
            .faults
            .iter()
            .filter(|f| !matches!(f.kind, FaultKind::CkptCorrupt))
            .count();
        assert_eq!(plan.kills.len(), degradations, "one kill per precursor window");
        for (f, kill) in plan
            .faults
            .iter()
            .filter(|f| !matches!(f.kind, FaultKind::CkptCorrupt))
            .zip(&plan.kills)
        {
            assert_eq!(f.node, kill.node, "kill strikes the degraded node");
            assert!((f.until - kill.at).abs() < 1e-9, "kill lands at window end");
            assert!(f.from < f.until, "precursor opens before the kill");
        }
    }

    #[test]
    fn correlated_mixes_all_three_modes() {
        let (links, stragglers, corruptions) =
            FaultPlan::correlated(24, 8, 1e6, 1).count_by_kind();
        assert!(links > 0 && stragglers > 0 && corruptions > 0);
        assert_eq!(links + stragglers + corruptions, 8);
    }

    #[test]
    fn timeline_sorted_with_apply_before_revert() {
        let plan = FaultPlan::correlated(24, 12, 1e6, 5);
        let tl = plan.timeline();
        for w in tl.windows(2) {
            assert!(w[0].at <= w[1].at, "timeline must be time-sorted");
        }
        for (i, f) in plan.faults.iter().enumerate() {
            let apply = tl.iter().position(|e| e.fault == i && e.apply).unwrap();
            match f.kind {
                FaultKind::CkptCorrupt => {
                    assert!(!tl.iter().any(|e| e.fault == i && !e.apply));
                }
                _ => {
                    let revert = tl.iter().position(|e| e.fault == i && !e.apply).unwrap();
                    assert!(apply < revert);
                }
            }
        }
    }

    #[test]
    fn empty_plan_has_empty_timeline() {
        assert!(FaultPlan::none().is_empty());
        assert!(FaultPlan::none().timeline().is_empty());
    }
}

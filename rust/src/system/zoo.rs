//! Topology zoo: named, parameterized machine shapes (DESIGN.md §13).
//!
//! Every exhibit and invariant used to run on the one DEEP-ER prototype
//! shape; the zoo turns the fabric interior into a swept axis.  Each entry
//! is a [`MachineSpec`] built from the Table I node hardware with a
//! generated [`TopologySpec`] interior, so `Machine::build`, the fleet
//! scheduler, the QoS engine and the benches all work unchanged on any
//! member.
//!
//! Names are `family[:params]` and round-trip through
//! [`TopologySpec::label`]: `by_name(name).topology.label() == name` for
//! every canonical name in [`NAMES`].  Partial parameter lists take
//! defaults (`"fat-tree:2"` is canonicalized to `"fat-tree:2,8"`).
//!
//! Selection: `repro run/fleet/bench … --topology <name>` on the CLI;
//! `testing::Config::topologies` + `check_zoo` in the property suites.

use super::{presets, MachineSpec};
use crate::fabric::{TopologySpec, TOURMALET_BW};

/// Canonical names of every registry member, one per topology family.
pub const NAMES: &[&str] = &[
    "flat",
    "fat-tree:2,8",
    "dragonfly:8,4",
    "multi-rail:4",
    "split:8,16",
    "tiered:8",
];

/// Every registry member as `(canonical_name, spec)`, in [`NAMES`] order.
pub fn all() -> Vec<(String, MachineSpec)> {
    NAMES
        .iter()
        .map(|n| (n.to_string(), by_name(n).expect("registry names resolve")))
        .collect()
}

/// Resolve a `family[:params]` name to a machine spec.  Missing trailing
/// parameters take the family defaults; unknown families and malformed
/// parameters are errors (not panics) so the CLI can report them.
pub fn by_name(name: &str) -> crate::Result<MachineSpec> {
    let (family, params) = match name.split_once(':') {
        Some((f, p)) => (f, p.split(',').collect::<Vec<_>>()),
        None => (name, Vec::new()),
    };
    let usize_at = |i: usize, default: usize| -> crate::Result<usize> {
        match params.get(i) {
            None => Ok(default),
            Some(s) => s
                .trim()
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("topology {name:?}: bad integer parameter {s:?}")),
        }
    };
    let f64_at = |i: usize, default: f64| -> crate::Result<f64> {
        match params.get(i) {
            None => Ok(default),
            Some(s) => s
                .trim()
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("topology {name:?}: bad numeric parameter {s:?}")),
        }
    };

    // All members share the Table I node/storage hardware; only the
    // fabric interior (and, for split, the partition sizes) varies.
    let mut spec = presets::deep_er();
    match family {
        "flat" => {
            anyhow::ensure!(params.is_empty(), "topology \"flat\" takes no parameters");
        }
        "fat-tree" => {
            let oversub = f64_at(0, 2.0)?;
            let arity = usize_at(1, 8)?;
            anyhow::ensure!(
                oversub > 0.0 && arity >= 1,
                "fat-tree needs oversub > 0 and arity >= 1"
            );
            spec.name = "zoo fat-tree";
            spec.topology = TopologySpec::FatTree { arity, link_bw: TOURMALET_BW, oversub };
        }
        "dragonfly" => {
            let group_size = usize_at(0, 8)?;
            let taper = f64_at(1, 4.0)?;
            anyhow::ensure!(
                group_size >= 1 && taper > 0.0,
                "dragonfly needs group_size >= 1 and taper > 0"
            );
            spec.name = "zoo dragonfly";
            spec.topology = TopologySpec::Dragonfly { group_size, link_bw: TOURMALET_BW, taper };
        }
        "multi-rail" => {
            let rails = usize_at(0, 4)?;
            anyhow::ensure!(rails >= 1, "multi-rail needs rails >= 1");
            spec.name = "zoo multi-rail";
            spec.topology = TopologySpec::MultiRail { rails, rail_bw: 8.0 * TOURMALET_BW };
        }
        "split" => {
            // Asymmetric Cluster/Booster partition: a thin cluster front
            // feeding a wide booster through a constrained bridge.
            let n_cluster = usize_at(0, 8)?;
            let n_booster = usize_at(1, 16)?;
            anyhow::ensure!(
                n_cluster >= 1 && n_booster >= 1,
                "split needs at least one node per side"
            );
            spec.name = "zoo split";
            spec.n_cluster = n_cluster;
            spec.n_booster = n_booster;
            spec.topology = TopologySpec::Split {
                booster_start: n_cluster,
                booster_end: n_cluster + n_booster,
                // Cluster side also hosts storage/MDS/NAM endpoints.
                cluster_bw: (n_cluster as f64 + 8.0) * TOURMALET_BW,
                booster_bw: n_booster as f64 * TOURMALET_BW,
                bridge_bw: 4.0 * TOURMALET_BW,
            };
        }
        "tiered" => {
            let leaf_ports = usize_at(0, 8)?;
            anyhow::ensure!(leaf_ports >= 1, "tiered needs leaf_ports >= 1");
            spec.name = "zoo tiered";
            spec.topology = TopologySpec::Tiered {
                leaf_ports,
                leaf_bw: leaf_ports as f64 * TOURMALET_BW,
                top_bw: 12.0 * TOURMALET_BW,
            };
        }
        _ => anyhow::bail!(
            "unknown topology {name:?} (families: flat, fat-tree, dragonfly, multi-rail, split, tiered)"
        ),
    }
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_at_least_five_families_and_round_trips() {
        assert!(NAMES.len() >= 5);
        let entries = all();
        assert_eq!(entries.len(), NAMES.len());
        for (name, spec) in &entries {
            assert_eq!(
                &spec.topology.label(),
                name,
                "canonical name must round-trip through the topology label"
            );
        }
    }

    #[test]
    fn partial_parameters_canonicalize() {
        assert_eq!(by_name("fat-tree:2").unwrap().topology.label(), "fat-tree:2,8");
        assert_eq!(by_name("fat-tree").unwrap().topology.label(), "fat-tree:2,8");
        assert_eq!(by_name("dragonfly").unwrap().topology.label(), "dragonfly:8,4");
        assert_eq!(by_name("split").unwrap().topology.label(), "split:8,16");
        assert_eq!(by_name("multi-rail:2").unwrap().topology.label(), "multi-rail:2");
    }

    #[test]
    fn split_resizes_the_partitions() {
        let s = by_name("split:8,16").unwrap();
        assert_eq!(s.n_cluster, 8);
        assert_eq!(s.n_booster, 16);
        let t = by_name("split:4,2").unwrap();
        assert_eq!((t.n_cluster, t.n_booster), (4, 2));
    }

    #[test]
    fn junk_names_error_cleanly() {
        assert!(by_name("nope").is_err());
        assert!(by_name("fat-tree:abc").is_err());
        assert!(by_name("multi-rail:0").is_err());
        assert!(by_name("flat:1").is_err());
        assert!(by_name("split:0,4").is_err());
    }
}

//! Published machine configurations (paper Table I and Section V testbeds).

use super::{MachineSpec, NodeKind, NodeSpec};
use crate::fabric::{TopologySpec, LAT_BOOSTER, LAT_CLUSTER, TOURMALET_BW};
use crate::storage::DeviceParams;

/// DEEP-ER prototype Cluster node (Table I, left column):
/// 2x Intel Xeon E5-2680 v3 (Haswell), 24 cores @ 2.5 GHz, 128 GB RAM,
/// 400 GB NVMe, EXTOLL Tourmalet A3.  16 nodes -> 16 TFlop/s aggregate,
/// i.e. 1 TFlop/s per node.
pub fn deep_er_cluster_node() -> NodeSpec {
    NodeSpec {
        name: "haswell-e5-2680v3",
        kind: NodeKind::Cluster,
        cores: 24,
        freq_ghz: 2.5,
        peak_flops: 1.0e12,
        mem_bytes: 128e9,
        fast_mem_bytes: 0.0,
        nic_bw: TOURMALET_BW,
        nic_latency: LAT_CLUSTER,
        nvme: Some(DeviceParams::nvme_p3700()),
        hdd: Some(DeviceParams::hdd()), // Fig. 7 compares NVMe vs node-local HDD
        ramdisk: None,
    }
}

/// DEEP-ER prototype Booster node (Table I, right column):
/// Intel Xeon Phi 7210 (KNL), 64 cores @ 1.3 GHz, 16 GB MCDRAM + 96 GB
/// DDR4, 400 GB NVMe.  8 nodes -> 20 TFlop/s aggregate = 2.5 TFlop/s each.
pub fn deep_er_booster_node() -> NodeSpec {
    NodeSpec {
        name: "knl-7210",
        kind: NodeKind::Booster,
        cores: 64,
        freq_ghz: 1.3,
        peak_flops: 2.5e12,
        mem_bytes: 96e9,
        fast_mem_bytes: 16e9,
        nic_bw: TOURMALET_BW,
        nic_latency: LAT_BOOSTER,
        nvme: Some(DeviceParams::nvme_p3700()),
        hdd: None,
        ramdisk: None,
    }
}

/// The DEEP-ER prototype at JSC (paper Section II-B, Table I): 16 Cluster
/// + 8 Booster nodes, one MDS + two storage servers (57 TB spinning disk),
/// two NAM boards, uniform Tourmalet fabric in a single non-blocking rack.
pub fn deep_er() -> MachineSpec {
    MachineSpec {
        name: "DEEP-ER prototype (JSC, 2016)",
        cluster: deep_er_cluster_node(),
        n_cluster: 16,
        booster: Some(deep_er_booster_node()),
        n_booster: 8,
        n_storage_servers: 2,
        server_device: DeviceParams::server_raid(),
        server_nic_bw: TOURMALET_BW,
        mds_op_cost: 0.8e-3,
        n_nam: 2,
        // 24 nodes + servers on a non-blocking Tourmalet switch group.
        topology: TopologySpec::Flat { backplane_bw: 32.0 * TOURMALET_BW },
    }
}

/// QPACE3 (paper Section V-A, Fig. 6): 672 KNL nodes, Omni-Path-class
/// fabric, global BeeGFS; **no node-local NVMe** — the paper emulated
/// node-local storage with RAM-disks.  The global backend aggregate is
/// calibrated so the local-vs-global gap at full scale reproduces the
/// published ~7x application-level speedup.
pub fn qpace3() -> MachineSpec {
    let knl = NodeSpec {
        name: "knl-7210-qpace3",
        kind: NodeKind::Cluster, // one homogeneous (Booster-like) partition
        cores: 64,
        freq_ghz: 1.3,
        peak_flops: 2.5e12,
        mem_bytes: 96e9,
        fast_mem_bytes: 16e9,
        nic_bw: 12.5e9,
        nic_latency: LAT_BOOSTER,
        nvme: None,
        hdd: None,
        ramdisk: Some(DeviceParams::ramdisk_knl()),
    };
    MachineSpec {
        name: "QPACE3 (672x KNL)",
        cluster: knl,
        n_cluster: 672,
        booster: None,
        n_booster: 0,
        n_storage_servers: 8,
        server_device: DeviceParams::qpace3_global(),
        server_nic_bw: 40e9,
        mds_op_cost: 0.5e-3,
        n_nam: 0,
        // torus bisection fraction
        topology: TopologySpec::Flat { backplane_bw: 672.0 * 12.5e9 * 0.4 },
    }
}

/// MareNostrum 3 partition used for the FWI + OmpSs resiliency runs
/// (paper Section V-B, Fig. 10): Sandy Bridge nodes, InfiniBand FDR10.
pub fn marenostrum3() -> MachineSpec {
    let sandy = NodeSpec {
        name: "sandybridge-e5-2670",
        kind: NodeKind::Cluster,
        cores: 16,
        freq_ghz: 2.6,
        peak_flops: 0.33e12,
        mem_bytes: 32e9,
        fast_mem_bytes: 0.0,
        nic_bw: 5.0e9, // FDR10
        nic_latency: 1.5e-6,
        nvme: None,
        hdd: Some(DeviceParams::hdd()),
        ramdisk: Some(DeviceParams::ramdisk_knl()), // /tmp in RAM for task state
    };
    MachineSpec {
        name: "MareNostrum 3 (Sandy Bridge / FDR10)",
        cluster: sandy,
        n_cluster: 64,
        booster: None,
        n_booster: 0,
        n_storage_servers: 4,
        server_device: DeviceParams::server_raid(),
        server_nic_bw: 5.0e9,
        mds_op_cost: 1.0e-3,
        n_nam: 0,
        topology: TopologySpec::Flat { backplane_bw: 64.0 * 5.0e9 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_consistent() {
        for spec in [deep_er(), qpace3(), marenostrum3()] {
            assert!(spec.n_cluster > 0);
            match spec.topology {
                TopologySpec::Flat { backplane_bw } => assert!(backplane_bw > 0.0),
                ref t => panic!("published presets are flat, got {}", t.label()),
            }
            assert!(spec.mds_op_cost > 0.0);
            if let Some(b) = &spec.booster {
                assert!(spec.n_booster > 0);
                assert!(b.peak_flops > 0.0);
            }
        }
    }

    #[test]
    fn booster_node_has_mcdram_tier() {
        let b = deep_er_booster_node();
        assert!((b.fast_mem_bytes - 16e9).abs() < 1.0);
        assert!((b.mem_bytes - 96e9).abs() < 1.0);
    }

    #[test]
    fn scaling_helpers() {
        let s = deep_er().with_cluster_nodes(4).with_booster_nodes(2);
        assert_eq!(s.n_cluster, 4);
        assert_eq!(s.n_booster, 2);
    }
}

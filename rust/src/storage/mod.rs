//! Node-local and server storage device models.
//!
//! The DEEP-ER multi-level memory hierarchy (paper Section II-B1) hangs a
//! 400 GB Intel DC P3700 NVMe off every Cluster and Booster node, next to
//! conventional HDDs on the Cluster and the spinning-disk global storage
//! servers.  QPACE3 (the Fig. 6 platform) has no NVMe, so node-local
//! storage is emulated with RAM-disks — the paper notes KNL RAM is ~75x
//! faster than the NVMe.
//!
//! A device is a pair of [`crate::sim`] resources (read / write channel) plus a
//! service model: fixed per-operation latency (controller round-trip or
//! seek) and a queue-depth-dependent efficiency curve — the P3700's
//! headline property is that throughput *holds up* under many parallel
//! requests, while the HDD collapses to seeks.  Capacity is tracked so the
//! 400 GB NVMe and the 2 GB NAM HMC can reject oversubscription like the
//! real parts.

use crate::sim::{FlowId, Op, ResId, Sim};

/// Static description of a storage device model.
#[derive(Debug, Clone)]
pub struct DeviceParams {
    pub name: &'static str,
    /// Sequential read bandwidth, bytes/s.
    pub read_bw: f64,
    /// Sequential write bandwidth, bytes/s.
    pub write_bw: f64,
    /// Fixed latency per operation (controller / seek), seconds.
    pub op_latency: f64,
    /// Additional per-operation software cost (request setup), seconds.
    pub op_overhead: f64,
    /// Fraction of peak bandwidth available to a single stream at QD=1;
    /// parallel streams recover the rest (NVMe ~0.55, HDD 1.0 — a spinning
    /// disk is *slower* with parallel streams, modelled via seek storms).
    pub qd1_efficiency: f64,
    /// Usable capacity in bytes.
    pub capacity: f64,
}

impl DeviceParams {
    /// Intel DC P3700 400 GB (PCIe gen3 x4): ~2.8 GB/s read, ~1.9 GB/s
    /// write, ~20 us access, sustains throughput at high queue depth.
    pub fn nvme_p3700() -> Self {
        Self {
            name: "nvme-p3700",
            read_bw: 2.8e9,
            write_bw: 1.9e9,
            op_latency: 20e-6,
            op_overhead: 10e-6,
            qd1_efficiency: 0.55,
            capacity: 400e9,
        }
    }

    /// Conventional node-local spinning disk (the Fig. 7 comparator).
    pub fn hdd() -> Self {
        Self {
            name: "hdd",
            read_bw: 160e6,
            write_bw: 150e6,
            op_latency: 8e-3,
            op_overhead: 50e-6,
            qd1_efficiency: 1.0,
            capacity: 1e12,
        }
    }

    /// RAM-disk on KNL DDR4 (QPACE3 emulation): the paper calibrates this
    /// as 75x the NVMe device speed.
    pub fn ramdisk_knl() -> Self {
        let nvme = Self::nvme_p3700();
        Self {
            name: "ramdisk-knl",
            read_bw: 75.0 * nvme.read_bw,
            write_bw: 75.0 * nvme.write_bw,
            op_latency: 0.5e-6,
            op_overhead: 0.5e-6,
            qd1_efficiency: 1.0,
            capacity: 96e9,
        }
    }

    /// One spindle set behind a DEEP-ER storage server (57 TB over two
    /// servers of RAID-ed spinning disks; ~1.2 GB/s streaming per server).
    pub fn server_raid() -> Self {
        Self {
            name: "server-raid",
            read_bw: 1.4e9,
            write_bw: 1.2e9,
            op_latency: 4e-3,
            op_overhead: 30e-6,
            qd1_efficiency: 1.0,
            capacity: 28.5e12,
        }
    }

    /// Aggregate backend of a large BeeGFS installation (QPACE3's global
    /// storage) — calibrated in `system::presets` against Fig. 6.
    pub fn qpace3_global() -> Self {
        Self {
            name: "qpace3-global",
            read_bw: 40e9,
            write_bw: 28e9,
            op_latency: 1e-3,
            op_overhead: 30e-6,
            qd1_efficiency: 1.0,
            capacity: 1e15,
        }
    }
}

/// A live device instance bound to simulation resources.
#[derive(Debug, Clone)]
pub struct Device {
    pub params: DeviceParams,
    read_res: ResId,
    write_res: ResId,
    used: f64,
}

impl Device {
    pub fn new(sim: &mut Sim, params: DeviceParams, label: &str) -> Self {
        let read_res = sim.resource(format!("{label}:{}/r", params.name), params.read_bw);
        let write_res = sim.resource(format!("{label}:{}/w", params.name), params.write_bw);
        Self { params, read_res, write_res, used: 0.0 }
    }

    /// Resource carrying read traffic (for multi-hop routes).
    pub fn read_res(&self) -> ResId {
        self.read_res
    }

    /// Resource carrying write traffic.
    pub fn write_res(&self) -> ResId {
        self.write_res
    }

    /// Bytes currently allocated on the device.
    pub fn used(&self) -> f64 {
        self.used
    }

    pub fn free_capacity(&self) -> f64 {
        (self.params.capacity - self.used).max(0.0)
    }

    /// Reserve space for a file/checkpoint; errors when the device is full
    /// (the 2 GB NAM HMC limit from the paper is enforced this way).
    pub fn allocate(&mut self, bytes: f64) -> crate::Result<()> {
        if bytes > self.free_capacity() {
            anyhow::bail!(
                "{}: allocation of {:.1} MB exceeds free capacity {:.1} MB",
                self.params.name,
                bytes / 1e6,
                self.free_capacity() / 1e6
            );
        }
        self.used += bytes;
        Ok(())
    }

    /// Release previously allocated space.
    pub fn release(&mut self, bytes: f64) {
        self.used = (self.used - bytes).max(0.0);
    }

    /// Issue a write of `bytes` split over `ops` operations, returning an
    /// [`Op`] completion handle (poll/wait via [`Sim::poll_op`] /
    /// [`Sim::wait_op`]).
    ///
    /// Per-op latency and software overhead serialize ahead of the
    /// transfer; the payload then streams through the device write channel
    /// (which is *shared*, so concurrent writers contend).  An extra
    /// route may be supplied (e.g. the PCIe/NIC path to reach the device).
    pub fn write_op(&self, sim: &mut Sim, bytes: f64, ops: u64, extra_route: &[ResId]) -> Op {
        let lat = self.params.op_latency + self.params.op_overhead * ops as f64;
        let mut route = vec![self.write_res];
        route.extend_from_slice(extra_route);
        Op::single(sim.flow(self.effective_bytes(bytes, ops, self.params.write_bw), lat, &route))
    }

    /// Issue a read of `bytes` split over `ops` operations, returning an
    /// [`Op`] completion handle.
    pub fn read_op(&self, sim: &mut Sim, bytes: f64, ops: u64, extra_route: &[ResId]) -> Op {
        let lat = self.params.op_latency + self.params.op_overhead * ops as f64;
        let mut route = vec![self.read_res];
        route.extend_from_slice(extra_route);
        Op::single(sim.flow(self.effective_bytes(bytes, ops, self.params.read_bw), lat, &route))
    }

    /// Flow-level shim over [`Device::write_op`] (single-flow callers).
    pub fn write(&self, sim: &mut Sim, bytes: f64, ops: u64, extra_route: &[ResId]) -> FlowId {
        self.write_op(sim, bytes, ops, extra_route).flows()[0]
    }

    /// Flow-level shim over [`Device::read_op`] (single-flow callers).
    pub fn read(&self, sim: &mut Sim, bytes: f64, ops: u64, extra_route: &[ResId]) -> FlowId {
        self.read_op(sim, bytes, ops, extra_route).flows()[0]
    }

    /// Single-stream inefficiency: at QD=1 a lone stream only reaches
    /// `qd1_efficiency` of peak; we charge the shortfall as inflated bytes.
    /// (Concurrent flows on the shared resource model QD>1 naturally.)
    fn effective_bytes(&self, bytes: f64, ops: u64, bw: f64) -> f64 {
        // Small ops also pay a bandwidth penalty when the op size drops
        // under 1 MB (write amplification / partial stripes).
        let per_op = if ops > 0 { bytes / ops as f64 } else { bytes };
        let small_penalty = if per_op < 1e6 && per_op > 0.0 {
            (1e6 / per_op).min(8.0).sqrt()
        } else {
            1.0
        };
        let _ = bw;
        bytes * small_penalty / self.params.qd1_efficiency.max(0.05)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nvme_write_time_scales_with_bytes() {
        let mut sim = Sim::new();
        let dev = Device::new(&mut sim, DeviceParams::nvme_p3700(), "n0");
        let f1 = dev.write(&mut sim, 1e9, 1, &[]);
        let t1 = sim.wait_all(&[f1]);
        let f2 = dev.write(&mut sim, 2e9, 1, &[]);
        let t2 = sim.wait_all(&[f2]) - t1;
        assert!((t2 / t1 - 2.0).abs() < 0.01, "t1={t1} t2={t2}");
    }

    #[test]
    fn nvme_much_faster_than_hdd() {
        let mut sim = Sim::new();
        let nvme = Device::new(&mut sim, DeviceParams::nvme_p3700(), "n0");
        let hdd = Device::new(&mut sim, DeviceParams::hdd(), "n0");
        let fa = nvme.write(&mut sim, 8e9, 8, &[]);
        let fb = hdd.write(&mut sim, 8e9, 8, &[]);
        let times = sim.wait_each(&[fa, fb]);
        assert!(times[1] / times[0] > 4.0, "nvme={} hdd={}", times[0], times[1]);
    }

    #[test]
    fn ramdisk_is_75x_nvme() {
        let r = DeviceParams::ramdisk_knl();
        let n = DeviceParams::nvme_p3700();
        assert!((r.write_bw / n.write_bw - 75.0).abs() < 1e-9);
    }

    #[test]
    fn many_small_ops_slower_than_one_large() {
        let mut sim = Sim::new();
        let dev = Device::new(&mut sim, DeviceParams::nvme_p3700(), "n0");
        let big = dev.write(&mut sim, 64e6, 1, &[]);
        let t_big = sim.wait_all(&[big]);
        let small = dev.write(&mut sim, 64e6, 4096, &[]); // 16 KB ops
        let t_small = sim.wait_all(&[small]) - t_big;
        assert!(t_small > 1.5 * t_big, "big={t_big} small={t_small}");
    }

    #[test]
    fn capacity_enforced() {
        let mut sim = Sim::new();
        let mut dev = Device::new(&mut sim, DeviceParams::nvme_p3700(), "n0");
        assert!(dev.allocate(399e9).is_ok());
        assert!(dev.allocate(2e9).is_err());
        dev.release(399e9);
        assert!(dev.allocate(2e9).is_ok());
    }

    #[test]
    fn hdd_seek_dominates_tiny_ops() {
        let mut sim = Sim::new();
        let dev = Device::new(&mut sim, DeviceParams::hdd(), "n0");
        // 100 ops x 8 ms seek-ish latency ~ >= 0.8 s even for tiny payload
        let f = dev.write(&mut sim, 1e6, 100, &[]);
        let t = sim.wait_all(&[f]);
        assert!(t > 5e-3, "t={t}");
    }

    #[test]
    fn concurrent_writers_share_device() {
        let mut sim = Sim::new();
        let dev = Device::new(&mut sim, DeviceParams::nvme_p3700(), "n0");
        let a = dev.write(&mut sim, 1e9, 1, &[]);
        let b = dev.write(&mut sim, 1e9, 1, &[]);
        let solo_sim = &mut Sim::new();
        let dev2 = Device::new(solo_sim, DeviceParams::nvme_p3700(), "n1");
        let s = dev2.write(solo_sim, 1e9, 1, &[]);
        let t_solo = solo_sim.wait_all(&[s]);
        let t_pair = sim.wait_all(&[a, b]);
        assert!(t_pair > 1.8 * t_solo, "solo={t_solo} pair={t_pair}");
    }
}

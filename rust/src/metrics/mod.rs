//! Series collection and fixed-width table printing for the figure
//! harnesses (every `repro bench figN` prints the same rows/series the
//! paper reports through these helpers).

use std::fmt::Write as _;

/// A named series of (x, y) points — one line in a paper figure.
#[derive(Debug, Clone)]
pub struct Series {
    /// Legend label of the series (e.g. `"NAM XOR"`).
    pub name: String,
    /// Data points in insertion order; x values need not be unique across
    /// series, which is how figures with different sweeps compose.
    pub points: Vec<(f64, f64)>,
}

impl Series {
    /// Create an empty series with the given legend label.
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), points: Vec::new() }
    }

    /// Append one (x, y) point.
    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    /// The y value at `x` (exact match within 1e-9), if present.
    pub fn y_at(&self, x: f64) -> Option<f64> {
        self.points
            .iter()
            .find(|(px, _)| (px - x).abs() < 1e-9)
            .map(|&(_, y)| y)
    }

    /// The y value of the last point pushed, if any.
    pub fn last_y(&self) -> Option<f64> {
        self.points.last().map(|&(_, y)| y)
    }
}

/// A figure: several series over a shared x axis, with labels.
#[derive(Debug, Clone)]
pub struct Figure {
    /// Figure caption, printed as the table header.
    pub title: String,
    /// Label of the shared x axis (e.g. `"nodes"`).
    pub x_label: String,
    /// Label of the y axis (e.g. `"GB/s"`).
    pub y_label: String,
    /// The plotted series, in legend order.
    pub series: Vec<Series>,
}

impl Figure {
    /// Create an empty figure with the given caption and axis labels.
    pub fn new(
        title: impl Into<String>,
        x_label: impl Into<String>,
        y_label: impl Into<String>,
    ) -> Self {
        Self {
            title: title.into(),
            x_label: x_label.into(),
            y_label: y_label.into(),
            series: Vec::new(),
        }
    }

    /// Append a series to the figure.
    pub fn add(&mut self, s: Series) {
        self.series.push(s);
    }

    /// Find a series by its legend label.
    pub fn series_named(&self, name: &str) -> Option<&Series> {
        self.series.iter().find(|s| s.name == name)
    }

    /// Render as CSV: header `x,<series...>`, one row per x value.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = write!(out, "{}", self.x_label.replace(',', ";"));
        for s in &self.series {
            let _ = write!(out, ",{}", s.name.replace(',', ";"));
        }
        let _ = writeln!(out);
        let mut xs: Vec<f64> = self
            .series
            .iter()
            .flat_map(|s| s.points.iter().map(|&(x, _)| x))
            .collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        for x in xs {
            let _ = write!(out, "{x}");
            for s in &self.series {
                match s.y_at(x) {
                    Some(y) => {
                        let _ = write!(out, ",{y}");
                    }
                    None => {
                        let _ = write!(out, ",");
                    }
                }
            }
            let _ = writeln!(out);
        }
        out
    }

    /// Render as a fixed-width table: one row per x, one column per series.
    pub fn to_table(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let mut xs: Vec<f64> = self
            .series
            .iter()
            .flat_map(|s| s.points.iter().map(|&(x, _)| x))
            .collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        let _ = write!(out, "{:>14}", self.x_label);
        for s in &self.series {
            let _ = write!(out, "{:>22}", s.name);
        }
        let _ = writeln!(out, "    [{}]", self.y_label);
        for x in xs {
            let _ = write!(out, "{x:>14.3}");
            for s in &self.series {
                match s.y_at(x) {
                    Some(y) => {
                        let _ = write!(out, "{y:>22.4}");
                    }
                    None => {
                        let _ = write!(out, "{:>22}", "-");
                    }
                }
            }
            let _ = writeln!(out);
        }
        out
    }
}

/// A key/value summary table (Table I style).
#[derive(Debug, Clone, Default)]
pub struct KvTable {
    /// Table caption, printed as the header.
    pub title: String,
    /// (key, rendered value) rows in insertion order.
    pub rows: Vec<(String, String)>,
}

impl KvTable {
    /// Create an empty table with the given caption.
    pub fn new(title: impl Into<String>) -> Self {
        Self { title: title.into(), rows: Vec::new() }
    }

    /// Append one key/value row (the value is rendered via `Display`).
    pub fn row(&mut self, k: impl Into<String>, v: impl std::fmt::Display) {
        self.rows.push((k.into(), v.to_string()));
    }

    /// Render as an aligned two-column text table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let w = self.rows.iter().map(|(k, _)| k.len()).max().unwrap_or(0);
        for (k, v) in &self.rows {
            let _ = writeln!(out, "  {k:<w$}  {v}");
        }
        out
    }
}

/// Human-readable bytes.
pub fn fmt_bytes(b: f64) -> String {
    if b >= 1e12 {
        format!("{:.1} TB", b / 1e12)
    } else if b >= 1e9 {
        format!("{:.1} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.1} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1} KB", b / 1e3)
    } else {
        format!("{b:.0} B")
    }
}

/// Human-readable bandwidth.
pub fn fmt_bw(bps: f64) -> String {
    format!("{}/s", fmt_bytes(bps))
}

/// Human-readable event/operation rate (the events/sec column of the
/// `repro bench scale` exhibit and the `# engine:` CSV stats line).
pub fn fmt_rate(per_sec: f64) -> String {
    if per_sec >= 1e9 {
        format!("{:.2} G/s", per_sec / 1e9)
    } else if per_sec >= 1e6 {
        format!("{:.2} M/s", per_sec / 1e6)
    } else if per_sec >= 1e3 {
        format!("{:.2} K/s", per_sec / 1e3)
    } else {
        format!("{per_sec:.1} /s")
    }
}

/// Nearest-rank percentile over f64 samples, `p` in [0, 100]:
/// the smallest sample whose rank is `ceil(p/100 * n)` (1-based), i.e.
/// the classic inclusive nearest-rank definition — deterministic (sorts
/// by IEEE total order, no interpolation), so same samples always give
/// the same answer bit-for-bit.  `p = 0` returns the minimum, `p = 100`
/// the maximum.  Panics on an empty slice.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty(), "percentile of an empty sample set");
    assert!((0.0..=100.0).contains(&p), "percentile {p} outside [0, 100]");
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    if p <= 0.0 {
        return sorted[0];
    }
    let rank = (p / 100.0 * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Median via nearest rank (see [`percentile`]).
pub fn p50(samples: &[f64]) -> f64 {
    percentile(samples, 50.0)
}

/// 95th percentile via nearest rank (see [`percentile`]).
pub fn p95(samples: &[f64]) -> f64 {
    percentile(samples, 95.0)
}

/// 99th percentile via nearest rank (see [`percentile`]) — the tail
/// metric the qos bench reports for exchange-phase slowdown.
pub fn p99(samples: &[f64]) -> f64 {
    percentile(samples, 99.0)
}

/// Sort-once percentile summary: accumulate samples, sort lazily on the
/// first query after an insert, answer every subsequent percentile in
/// O(1).  Exact mode — queries are bit-identical to the nearest-rank
/// [`percentile`] on the same samples (same `f64::total_cmp` sort, same
/// `ceil(p/100 * n)` rank), without the clone-and-sort per call.  This is
/// what `sched::serve` per-window p99s and the qos bench class summaries
/// use instead of [`percentile`].
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    /// `samples` is sorted by IEEE total order up to this prefix length;
    /// pushes past it mark the tail dirty without resorting eagerly.
    sorted_len: usize,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build a summary over an existing sample set (sorts once, now).
    pub fn of(samples: &[f64]) -> Self {
        let mut s = Self { samples: samples.to_vec(), sorted_len: 0 };
        s.ensure_sorted();
        s
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if self.sorted_len != self.samples.len() {
            self.samples.sort_by(f64::total_cmp);
            self.sorted_len = self.samples.len();
        }
    }

    /// Nearest-rank percentile, bit-identical to [`percentile`] on the
    /// pushed samples.  Panics on an empty summary or `p` outside
    /// [0, 100], exactly like the free function.
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!(!self.samples.is_empty(), "percentile of an empty sample set");
        assert!((0.0..=100.0).contains(&p), "percentile {p} outside [0, 100]");
        self.ensure_sorted();
        if p <= 0.0 {
            return self.samples[0];
        }
        let rank = (p / 100.0 * self.samples.len() as f64).ceil() as usize;
        self.samples[rank.clamp(1, self.samples.len()) - 1]
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    /// Maximum sample (IEEE total order, same as `percentile(100)`).
    pub fn max(&mut self) -> f64 {
        self.percentile(100.0)
    }

    /// Arithmetic mean (0 on an empty summary).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().sum::<f64>() / self.samples.len() as f64
        }
    }
}

/// Deterministic log-bucketed histogram of non-negative f64 samples —
/// the O(1)-insert companion to [`Summary`] for unbounded streams (the
/// `obs` recorder's histograms).  Buckets are powers of two keyed off
/// the IEEE exponent bits (no `log2()` libm call, so bucketing is
/// bit-deterministic across platforms): bucket `i` covers
/// `[2^(i-32), 2^(i-31))`, clamped to 64 buckets, with zero/subnormal in
/// bucket 0 and everything >= 2^32 (incl. inf/NaN) in bucket 63.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LogHist {
    pub buckets: [u64; 64],
    pub count: u64,
}

impl LogHist {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bucket index of `v` (see the type docs for the mapping).
    pub fn bucket_of(v: f64) -> usize {
        let exp = ((v.to_bits() >> 52) & 0x7ff) as i64;
        if exp == 0 {
            return 0; // zero and subnormals
        }
        (exp - 1023 + 32).clamp(0, 63) as usize
    }

    /// Lower edge of bucket `i`, i.e. `2^(i-32)` (bucket 0 is the
    /// zero/underflow bucket, so its edge is 0).
    pub fn bucket_lo(i: usize) -> f64 {
        if i == 0 {
            0.0
        } else {
            (2.0f64).powi(i as i32 - 32)
        }
    }

    pub fn record(&mut self, v: f64) {
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
    }

    /// Nearest-rank percentile at bucket resolution: the **lower edge**
    /// of the bucket holding the rank-`ceil(p/100 * n)` sample.  Within
    /// a factor of 2 of the exact answer by construction; 0 on empty.
    pub fn percentile(&self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p), "percentile {p} outside [0, 100]");
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0 * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return Self::bucket_lo(i);
            }
        }
        Self::bucket_lo(63)
    }

    /// Merge another histogram in (bucketwise sum).
    pub fn merge(&mut self, other: &LogHist) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
    }
}

/// Human-readable seconds.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_lookup() {
        let mut s = Series::new("a");
        s.push(1.0, 10.0);
        s.push(2.0, 20.0);
        assert_eq!(s.y_at(2.0), Some(20.0));
        assert_eq!(s.y_at(3.0), None);
        assert_eq!(s.last_y(), Some(20.0));
    }

    #[test]
    fn figure_table_renders_all_series() {
        let mut f = Figure::new("Fig X", "nodes", "seconds");
        let mut a = Series::new("local");
        a.push(1.0, 0.5);
        a.push(2.0, 0.5);
        let mut b = Series::new("global");
        b.push(1.0, 0.5);
        b.push(2.0, 1.0);
        f.add(a);
        f.add(b);
        let t = f.to_table();
        assert!(t.contains("Fig X"));
        assert!(t.contains("local"));
        assert!(t.contains("global"));
        assert!(t.lines().count() >= 4);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_bytes(2.5e9), "2.5 GB");
        assert_eq!(fmt_bytes(100.0), "100 B");
        assert!(fmt_bw(12.5e9).contains("GB/s"));
        assert!(fmt_time(0.5e-3).contains("us") || fmt_time(0.5e-3).contains("ms"));
        assert_eq!(fmt_rate(3.2e6), "3.20 M/s");
        assert_eq!(fmt_rate(450.0), "450.0 /s");
    }

    #[test]
    fn percentile_nearest_rank_is_exact() {
        // Classic nearest-rank worked example.
        let s = [15.0, 20.0, 35.0, 40.0, 50.0];
        assert_eq!(percentile(&s, 5.0), 15.0); // ceil(0.25) = rank 1
        assert_eq!(percentile(&s, 30.0), 20.0); // ceil(1.5) = rank 2
        assert_eq!(percentile(&s, 40.0), 20.0); // ceil(2.0) = rank 2
        assert_eq!(percentile(&s, 50.0), 35.0); // ceil(2.5) = rank 3
        assert_eq!(percentile(&s, 100.0), 50.0);
        assert_eq!(percentile(&s, 0.0), 15.0);
        assert_eq!(p50(&s), 35.0);
    }

    #[test]
    fn percentile_tails_on_hundred_samples() {
        // 1..=100: p99 = ceil(99) = rank 99 -> value 99; p95 -> 95.
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(p99(&s), 99.0);
        assert_eq!(p95(&s), 95.0);
        assert_eq!(p50(&s), 50.0);
        assert_eq!(percentile(&s, 100.0), 100.0);
    }

    #[test]
    fn percentile_is_order_independent_and_deterministic() {
        let a = [3.0, 1.0, 2.0, 5.0, 4.0];
        let b = [5.0, 4.0, 3.0, 2.0, 1.0];
        for p in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(percentile(&a, p).to_bits(), percentile(&b, p).to_bits());
        }
        // Single sample: every percentile is that sample.
        assert_eq!(percentile(&[7.5], 99.0), 7.5);
        assert_eq!(percentile(&[7.5], 0.0), 7.5);
    }

    #[test]
    #[should_panic(expected = "empty sample set")]
    fn percentile_empty_panics() {
        let _ = percentile(&[], 50.0);
    }

    #[test]
    fn summary_bit_equal_to_nearest_rank() {
        // Exact mode must reproduce the free-function nearest-rank
        // definition bit-for-bit, including after interleaved pushes.
        let samples = [3.25, -0.0, 1e-300, 7.5, 7.5, f64::INFINITY, 2.0, -4.0, 0.125];
        let mut s = Summary::new();
        for &v in &samples[..4] {
            s.push(v);
        }
        for p in [0.0, 5.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(s.percentile(p).to_bits(), percentile(&samples[..4], p).to_bits());
        }
        // Push more after querying (dirty tail) and re-check.
        for &v in &samples[4..] {
            s.push(v);
        }
        for p in [0.0, 5.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(s.percentile(p).to_bits(), percentile(&samples, p).to_bits());
        }
        let mut of = Summary::of(&samples);
        assert_eq!(of.p99().to_bits(), p99(&samples).to_bits());
        assert_eq!(of.p50().to_bits(), p50(&samples).to_bits());
        assert_eq!(of.p95().to_bits(), p95(&samples).to_bits());
        assert_eq!(of.len(), samples.len());
    }

    #[test]
    #[should_panic(expected = "empty sample set")]
    fn summary_empty_panics() {
        let _ = Summary::new().percentile(50.0);
    }

    #[test]
    fn loghist_buckets_are_powers_of_two() {
        assert_eq!(LogHist::bucket_of(0.0), 0);
        assert_eq!(LogHist::bucket_of(1.0), 32); // [1, 2)
        assert_eq!(LogHist::bucket_of(1.999), 32);
        assert_eq!(LogHist::bucket_of(2.0), 33);
        assert_eq!(LogHist::bucket_of(0.5), 31);
        assert_eq!(LogHist::bucket_of(1e-300), 0); // clamped underflow
        assert_eq!(LogHist::bucket_of(f64::INFINITY), 63);
        assert_eq!(LogHist::bucket_lo(32), 1.0);
        assert_eq!(LogHist::bucket_lo(33), 2.0);
        assert_eq!(LogHist::bucket_lo(0), 0.0);
    }

    #[test]
    fn loghist_percentile_within_bucket_resolution() {
        let mut h = LogHist::new();
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        for &v in &samples {
            h.record(v);
        }
        assert_eq!(h.count, 100);
        // Nearest-rank at rank 99 is 99.0, whose bucket lower edge is 64.
        let exact = p99(&samples);
        let approx = h.percentile(99.0);
        assert!(approx <= exact && exact < approx * 2.0, "{approx} vs {exact}");
        assert_eq!(h.percentile(0.0), 1.0);
        // Merge doubles every count but moves no percentile.
        let before = h.percentile(50.0);
        let other = h.clone();
        h.merge(&other);
        assert_eq!(h.count, 200);
        assert_eq!(h.percentile(50.0), before);
        assert_eq!(LogHist::new().percentile(99.0), 0.0);
    }

    #[test]
    fn kv_table() {
        let mut t = KvTable::new("Table I");
        t.row("Cluster nodes", 16);
        t.row("Booster nodes", 8);
        let r = t.render();
        assert!(r.contains("Cluster nodes") && r.contains("16"));
    }
}
